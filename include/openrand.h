/* openrand.h — C ABI for the openrand counter-based RNG core.
 *
 * Hand-maintained (no cbindgen): this header IS the ABI document, and
 * ffi/tests/kat_harness.c compiles against it in CI to keep it honest.
 * The implementing library is the `openrand_ffi` crate (ffi/src/lib.rs,
 * built as libopenrand_ffi.{a,so}); the full contract — error-code
 * table, ownership rules, panic-surface audit, and a worked example —
 * lives in docs/ffi.md.
 *
 * Reproducibility contract: for a given engine tag and (seed, ctr),
 * every function below returns bit-identical values to the Rust crate
 * and the Python/JAX oracle. The shared known-answer vectors are pinned
 * in rust/src/selftest.rs, python/tests/test_ffi_vectors.py, and
 * ffi/tests/kat_harness.c; openrand_selftest() replays them in-process.
 *
 * Thread model: handles are NOT thread-safe. Streams are cheap — open
 * one engine per thread/work item (the paper's model) instead of
 * sharing one behind a lock.
 */

#ifndef OPENRAND_H
#define OPENRAND_H

#include <stddef.h>
#include <stdint.h>

#ifdef __cplusplus
extern "C" {
#endif

/* ---- error codes ----------------------------------------------------
 * Every fallible function returns int: OPENRAND_OK (0) on success, a
 * positive code otherwise. No function aborts the process: conditions
 * that panic in the Rust API (range bound 0, jump() on tyche) are
 * pre-checked into codes, and a catch-all unwind guard turns any
 * library bug into OPENRAND_ERR_PANIC instead of UB across the FFI
 * boundary. Out-parameters are untouched on error.
 */
#define OPENRAND_OK 0
#define OPENRAND_ERR_NULL 1          /* required pointer was NULL       */
#define OPENRAND_ERR_BAD_GENERATOR 2 /* unknown engine tag              */
#define OPENRAND_ERR_EMPTY_RANGE 3   /* range bound == 0                */
#define OPENRAND_ERR_NO_JUMP 4       /* engine has no O(1) jump         */
#define OPENRAND_ERR_PANIC 5         /* internal panic caught (a bug)   */
#define OPENRAND_ERR_SELFTEST 6      /* KAT battery found a divergence  */

/* Opaque handles. Allocated by this library; release engines with
 * openrand_destroy and keys with openrand_key_free — never free(3). */
typedef struct openrand_engine openrand_engine;
typedef struct openrand_key openrand_key;

/* Static "openrand_ffi <version>" string (do not free). */
const char *openrand_version(void);

/* Static message for an OPENRAND_* code (do not free). */
const char *openrand_strerror(int code);

/* Replay the pinned cross-language known-answer battery in-process:
 * all seven engine word tables, the normative u64/f64/f32 conversions,
 * stream-key derivation, and the jump-ahead literals. OPENRAND_OK
 * means this build reproduces the shared vectors bitwise. */
int openrand_selftest(void);

/* ---- engines --------------------------------------------------------
 * gen_tag is one of: "philox" (Philox4x32-10), "philox2x32",
 * "threefry" (Threefry4x32-20), "threefry2x32", "squares", "tyche",
 * "tyche_i". (seed, ctr) identifies the stream: seed names the work
 * item, ctr the sub-stream (timestep / kernel launch / epoch).
 */
int openrand_create(const char *gen_tag, uint64_t seed, uint32_t ctr,
                    openrand_engine **out);
int openrand_create_keyed(const char *gen_tag, const openrand_key *key,
                          openrand_engine **out);
void openrand_destroy(openrand_engine *e);

/* Scalar draws. next_u64 composes two stream words first-word-high;
 * uniform_f32 is the top 24 bits of one word times 2^-24; uniform_f64
 * is the top 53 bits of the composed u64 times 2^-53 (the normative
 * conversions — bit-identical across Rust, Python, and C). */
int openrand_next_u32(openrand_engine *e, uint32_t *out);
int openrand_next_u64(openrand_engine *e, uint64_t *out);
int openrand_uniform_f32(openrand_engine *e, float *out);
int openrand_uniform_f64(openrand_engine *e, double *out);

/* Uniform integer in [0, bound) via Lemire rejection (one word plus
 * rare retries). bound == 0 returns OPENRAND_ERR_EMPTY_RANGE without
 * consuming stream words. */
int openrand_range_u32(openrand_engine *e, uint32_t bound, uint32_t *out);

/* Bulk fills through the engines' block path — bit-identical to len
 * scalar calls (double i consumes stream words 2i, 2i+1). len == 0 is
 * OK with any buf. */
int openrand_fill_u32(openrand_engine *e, uint32_t *buf, size_t len);
int openrand_fill_f64(openrand_engine *e, double *buf, size_t len);

/* Stream positioning. advance(n) == draw-and-discard n words (O(1) on
 * counter engines, O(n) on tyche/tyche_i); set_position is absolute;
 * jump skips the engine's fixed stride (2^33 words for the 4x32
 * engines, 2^16 for philox2x32/threefry2x32/squares) in O(1) and
 * returns OPENRAND_ERR_NO_JUMP on tyche/tyche_i. */
int openrand_advance(openrand_engine *e, uint64_t n);
int openrand_set_position(openrand_engine *e, uint64_t pos);
int openrand_jump(openrand_engine *e);

/* ---- stream keys ----------------------------------------------------
 * The hierarchical addressing scheme (docs/stream-contracts.md §2):
 * root(seed) is (seed, 0); child(id) derives a statistically
 * independent seed via the normative splitmix64 mix; epoch(t) sets the
 * counter absolutely (last call wins). Derivation functions return
 * fresh handles; inputs are unchanged and remain live.
 */
int openrand_key_root(uint64_t seed, openrand_key **out);
int openrand_key_raw(uint64_t seed, uint32_t ctr, openrand_key **out);
int openrand_key_child(const openrand_key *key, uint64_t id,
                       openrand_key **out);
int openrand_key_epoch(const openrand_key *key, uint32_t epoch,
                       openrand_key **out);
int openrand_key_seed(const openrand_key *key, uint64_t *out);
int openrand_key_ctr(const openrand_key *key, uint32_t *out);
void openrand_key_free(openrand_key *key);

#ifdef __cplusplus
} /* extern "C" */
#endif

#endif /* OPENRAND_H */
