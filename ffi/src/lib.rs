//! `openrand_ffi` — the C ABI over the `no_std` openrand core.
//!
//! This crate exports the portable surface (the seven engines, the
//! serial fill paths, the normative conversions, and `StreamKey`
//! derivation) through opaque handles and plain C types, so that C,
//! Fortran-via-ISO-C, and any FFI-capable language replay the exact
//! streams the Rust and Python layers pin. The contract is documented
//! in `docs/ffi.md`; the C header is hand-maintained at
//! `include/openrand.h` (no cbindgen in the container — the header IS
//! the ABI document, and `ffi/tests/kat_harness.c` compiles against it
//! in CI to keep it honest).
//!
//! ## Error discipline
//!
//! Unwinding across an `extern "C"` boundary is undefined behavior, so
//! no panic may escape. Every entry point:
//!
//! 1. checks pointers and preconditions first, returning a typed error
//!    code (`OPENRAND_ERR_*`) for each documented panic source in the
//!    core (`range_u32(0)`, `jump()` on Tyche/TycheI), and
//! 2. wraps the remaining call in [`catch_unwind`] as a backstop, so an
//!    unanticipated panic surfaces as `OPENRAND_ERR_PANIC` instead of
//!    an abort in the host process.
//!
//! The full panic-surface audit lives in `docs/ffi.md` §Errors;
//! `ffi/tests/ffi.rs` and the C harness both drive the error paths.
//!
//! ## Ownership
//!
//! Handles returned through `openrand_create*` / `openrand_key_*` are
//! heap-allocated by this crate and MUST be released with the matching
//! `openrand_destroy` / `openrand_key_free` — never with `free(3)`.
//! Handles are not thread-safe; one handle belongs to one thread at a
//! time (streams are cheap — open one per thread, per the paper's
//! one-stream-per-work-item model).

use std::ffi::{c_char, CStr};
use std::panic::{catch_unwind, AssertUnwindSafe};

use openrand::core::fill::u01_f64;
use openrand::core::{
    CounterRng, Generator, Philox, Philox2x32, Rng, Squares, Threefry, Threefry2x32, Tyche, TycheI,
};
use openrand::selftest;
use openrand::stream::StreamKey;

/// Success.
pub const OPENRAND_OK: i32 = 0;
/// A required pointer argument was NULL.
pub const OPENRAND_ERR_NULL: i32 = 1;
/// The generator tag is not one of the seven engine names.
pub const OPENRAND_ERR_BAD_GENERATOR: i32 = 2;
/// `bound == 0` passed to `openrand_range_u32` (the core's normative
/// panic, surfaced as a code).
pub const OPENRAND_ERR_EMPTY_RANGE: i32 = 3;
/// `openrand_jump` on an engine with no O(1) jump (tyche, tyche_i).
pub const OPENRAND_ERR_NO_JUMP: i32 = 4;
/// A panic was caught at the FFI boundary (backstop — indicates a bug).
pub const OPENRAND_ERR_PANIC: i32 = 5;
/// The built-in KAT battery found a diverging vector.
pub const OPENRAND_ERR_SELFTEST: i32 = 6;

/// Concrete-engine dispatch. The C side names engines by tag string;
/// internally each handle owns one monomorphized engine so the draw
/// paths are the same code the native Rust benches measure (no `dyn`
/// indirection on the hot path).
enum Engine {
    Philox(Philox),
    Philox2x32(Philox2x32),
    Threefry(Threefry),
    Threefry2x32(Threefry2x32),
    Squares(Squares),
    Tyche(Tyche),
    TycheI(TycheI),
}

macro_rules! with_engine {
    ($e:expr, $r:ident => $body:expr) => {
        match $e {
            Engine::Philox($r) => $body,
            Engine::Philox2x32($r) => $body,
            Engine::Threefry($r) => $body,
            Engine::Threefry2x32($r) => $body,
            Engine::Squares($r) => $body,
            Engine::Tyche($r) => $body,
            Engine::TycheI($r) => $body,
        }
    };
}

fn make_engine(gen: Generator, seed: u64, ctr: u32) -> Engine {
    match gen {
        Generator::Philox => Engine::Philox(Philox::new(seed, ctr)),
        Generator::Philox2x32 => Engine::Philox2x32(Philox2x32::new(seed, ctr)),
        Generator::Threefry => Engine::Threefry(Threefry::new(seed, ctr)),
        Generator::Threefry2x32 => Engine::Threefry2x32(Threefry2x32::new(seed, ctr)),
        Generator::Squares => Engine::Squares(Squares::new(seed, ctr)),
        Generator::Tyche => Engine::Tyche(Tyche::new(seed, ctr)),
        Generator::TycheI => Engine::TycheI(TycheI::new(seed, ctr)),
    }
}

fn jump_log2(e: &Engine) -> Option<u32> {
    fn jl<G: CounterRng>(_: &G) -> Option<u32> {
        G::JUMP_LOG2
    }
    with_engine!(e, r => jl(r))
}

/// Opaque engine handle (C: `openrand_engine`).
pub struct OpenrandEngine {
    inner: Engine,
}

/// Opaque stream-key handle (C: `openrand_key`).
pub struct OpenrandKey {
    inner: StreamKey,
}

unsafe fn parse_tag(gen_tag: *const c_char) -> Result<Generator, i32> {
    if gen_tag.is_null() {
        return Err(OPENRAND_ERR_NULL);
    }
    let tag = CStr::from_ptr(gen_tag).to_str().map_err(|_| OPENRAND_ERR_BAD_GENERATOR)?;
    Generator::parse(tag).ok_or(OPENRAND_ERR_BAD_GENERATOR)
}

/// `"<name> <semver>"` of this library, as a static NUL-terminated
/// string (never freed by the caller).
#[no_mangle]
pub extern "C" fn openrand_version() -> *const c_char {
    const VERSION: &[u8] = b"openrand_ffi 0.1.0\0";
    VERSION.as_ptr().cast()
}

/// A static human-readable message for an `OPENRAND_*` code (never
/// freed by the caller; unknown codes get a placeholder, not NULL).
#[no_mangle]
pub extern "C" fn openrand_strerror(code: i32) -> *const c_char {
    let msg: &[u8] = match code {
        OPENRAND_OK => b"ok\0",
        OPENRAND_ERR_NULL => b"null pointer argument\0",
        OPENRAND_ERR_BAD_GENERATOR => b"unknown generator tag\0",
        OPENRAND_ERR_EMPTY_RANGE => b"empty range (bound == 0)\0",
        OPENRAND_ERR_NO_JUMP => b"engine has no O(1) jump; use openrand_advance\0",
        OPENRAND_ERR_PANIC => b"internal panic caught at FFI boundary\0",
        OPENRAND_ERR_SELFTEST => b"known-answer selftest failed\0",
        _ => b"unknown openrand error code\0",
    };
    msg.as_ptr().cast()
}

/// Run the pinned known-answer battery (`openrand::selftest::run`):
/// every engine's word table, the normative conversions, key
/// derivation, and the jump-ahead literals. Returns `OPENRAND_OK` when
/// the linked library reproduces the cross-language vectors bitwise.
#[no_mangle]
pub extern "C" fn openrand_selftest() -> i32 {
    match catch_unwind(selftest::run) {
        Ok(Ok(())) => OPENRAND_OK,
        Ok(Err(_)) => OPENRAND_ERR_SELFTEST,
        Err(_) => OPENRAND_ERR_PANIC,
    }
}

/// Open the stream `(seed, ctr)` of the engine named `gen_tag` (one of
/// `"philox"`, `"philox2x32"`, `"threefry"`, `"threefry2x32"`,
/// `"squares"`, `"tyche"`, `"tyche_i"`). On success writes a handle to
/// `*out`; release it with [`openrand_destroy`].
///
/// # Safety
///
/// `gen_tag` must be NULL or a NUL-terminated string; `out` must be
/// NULL or valid for writing one pointer.
#[no_mangle]
pub unsafe extern "C" fn openrand_create(
    gen_tag: *const c_char,
    seed: u64,
    ctr: u32,
    out: *mut *mut OpenrandEngine,
) -> i32 {
    if out.is_null() {
        return OPENRAND_ERR_NULL;
    }
    let gen = match parse_tag(gen_tag) {
        Ok(g) => g,
        Err(code) => return code,
    };
    match catch_unwind(|| Box::new(OpenrandEngine { inner: make_engine(gen, seed, ctr) })) {
        Ok(handle) => {
            *out = Box::into_raw(handle);
            OPENRAND_OK
        }
        Err(_) => OPENRAND_ERR_PANIC,
    }
}

/// Open the stream a [`OpenrandKey`] addresses — exactly
/// [`openrand_create`] with the key's `(seed, ctr)`; the key is not
/// consumed.
///
/// # Safety
///
/// As [`openrand_create`]; `key` must be NULL or a live key handle.
#[no_mangle]
pub unsafe extern "C" fn openrand_create_keyed(
    gen_tag: *const c_char,
    key: *const OpenrandKey,
    out: *mut *mut OpenrandEngine,
) -> i32 {
    let Some(k) = key.as_ref() else {
        return OPENRAND_ERR_NULL;
    };
    openrand_create(gen_tag, k.inner.seed(), k.inner.ctr(), out)
}

/// Release an engine handle. NULL is a no-op.
///
/// # Safety
///
/// `e` must be NULL or a handle from `openrand_create*` not yet
/// destroyed.
#[no_mangle]
pub unsafe extern "C" fn openrand_destroy(e: *mut OpenrandEngine) {
    if !e.is_null() {
        drop(Box::from_raw(e));
    }
}

/// Draw the next 32-bit word of the stream into `*out`.
///
/// # Safety
///
/// `e` must be NULL or a live engine handle owned by this thread; `out`
/// NULL or writable.
#[no_mangle]
pub unsafe extern "C" fn openrand_next_u32(e: *mut OpenrandEngine, out: *mut u32) -> i32 {
    let (Some(h), false) = (e.as_mut(), out.is_null()) else {
        return OPENRAND_ERR_NULL;
    };
    match catch_unwind(AssertUnwindSafe(|| with_engine!(&mut h.inner, r => r.next_u32()))) {
        Ok(v) => {
            *out = v;
            OPENRAND_OK
        }
        Err(_) => OPENRAND_ERR_PANIC,
    }
}

/// Draw the next 64-bit value (two stream words, first word high — the
/// normative composition).
///
/// # Safety
///
/// As [`openrand_next_u32`].
#[no_mangle]
pub unsafe extern "C" fn openrand_next_u64(e: *mut OpenrandEngine, out: *mut u64) -> i32 {
    let (Some(h), false) = (e.as_mut(), out.is_null()) else {
        return OPENRAND_ERR_NULL;
    };
    match catch_unwind(AssertUnwindSafe(|| with_engine!(&mut h.inner, r => r.next_u64()))) {
        Ok(v) => {
            *out = v;
            OPENRAND_OK
        }
        Err(_) => OPENRAND_ERR_PANIC,
    }
}

/// Draw a uniform `float` in `[0, 1)` — top 24 bits of one stream word
/// times 2^-24 (the normative f32 conversion).
///
/// # Safety
///
/// As [`openrand_next_u32`].
#[no_mangle]
pub unsafe extern "C" fn openrand_uniform_f32(e: *mut OpenrandEngine, out: *mut f32) -> i32 {
    let (Some(h), false) = (e.as_mut(), out.is_null()) else {
        return OPENRAND_ERR_NULL;
    };
    match catch_unwind(AssertUnwindSafe(|| with_engine!(&mut h.inner, r => r.draw_float()))) {
        Ok(v) => {
            *out = v;
            OPENRAND_OK
        }
        Err(_) => OPENRAND_ERR_PANIC,
    }
}

/// Draw a uniform `double` in `[0, 1)` — top 53 bits of the composed
/// u64 times 2^-53 (the normative f64 conversion; consumes two words).
///
/// # Safety
///
/// As [`openrand_next_u32`].
#[no_mangle]
pub unsafe extern "C" fn openrand_uniform_f64(e: *mut OpenrandEngine, out: *mut f64) -> i32 {
    let (Some(h), false) = (e.as_mut(), out.is_null()) else {
        return OPENRAND_ERR_NULL;
    };
    match catch_unwind(AssertUnwindSafe(|| with_engine!(&mut h.inner, r => r.draw_double()))) {
        Ok(v) => {
            *out = v;
            OPENRAND_OK
        }
        Err(_) => OPENRAND_ERR_PANIC,
    }
}

/// Draw a uniform integer in `[0, bound)` (Lemire rejection, one word
/// plus rare retries). `bound == 0` — a panic in the Rust API — returns
/// `OPENRAND_ERR_EMPTY_RANGE` without touching the stream.
///
/// # Safety
///
/// As [`openrand_next_u32`].
#[no_mangle]
pub unsafe extern "C" fn openrand_range_u32(
    e: *mut OpenrandEngine,
    bound: u32,
    out: *mut u32,
) -> i32 {
    let (Some(h), false) = (e.as_mut(), out.is_null()) else {
        return OPENRAND_ERR_NULL;
    };
    if bound == 0 {
        return OPENRAND_ERR_EMPTY_RANGE;
    }
    match catch_unwind(AssertUnwindSafe(|| with_engine!(&mut h.inner, r => r.range_u32(bound)))) {
        Ok(v) => {
            *out = v;
            OPENRAND_OK
        }
        Err(_) => OPENRAND_ERR_PANIC,
    }
}

/// Fill `buf[0..len]` with the next `len` stream words through the
/// engines' block path — bit-identical to `len` calls of
/// [`openrand_next_u32`], and the bulk surface `benches/fig_ffi.rs`
/// holds to within 1.2x of the native Rust fill.
///
/// # Safety
///
/// `e` as [`openrand_next_u32`]; `buf` must be NULL or valid for `len`
/// writes of `uint32_t` (`len == 0` accepts any `buf`).
#[no_mangle]
pub unsafe extern "C" fn openrand_fill_u32(
    e: *mut OpenrandEngine,
    buf: *mut u32,
    len: usize,
) -> i32 {
    let Some(h) = e.as_mut() else {
        return OPENRAND_ERR_NULL;
    };
    if len == 0 {
        return OPENRAND_OK;
    }
    if buf.is_null() {
        return OPENRAND_ERR_NULL;
    }
    let out = std::slice::from_raw_parts_mut(buf, len);
    match catch_unwind(AssertUnwindSafe(|| with_engine!(&mut h.inner, r => r.fill_u32(out)))) {
        Ok(()) => OPENRAND_OK,
        Err(_) => OPENRAND_ERR_PANIC,
    }
}

/// Fill `buf[0..len]` with uniform doubles in `[0, 1)` — bit-identical
/// to `len` calls of [`openrand_uniform_f64`] (words are pulled in
/// tiles through the block path; double `i` consumes stream words
/// `2i, 2i + 1`).
///
/// # Safety
///
/// `e` as [`openrand_next_u32`]; `buf` must be NULL or valid for `len`
/// writes of `double` (`len == 0` accepts any `buf`).
#[no_mangle]
pub unsafe extern "C" fn openrand_fill_f64(
    e: *mut OpenrandEngine,
    buf: *mut f64,
    len: usize,
) -> i32 {
    let Some(h) = e.as_mut() else {
        return OPENRAND_ERR_NULL;
    };
    if len == 0 {
        return OPENRAND_OK;
    }
    if buf.is_null() {
        return OPENRAND_ERR_NULL;
    }
    let out = std::slice::from_raw_parts_mut(buf, len);
    let filled = catch_unwind(AssertUnwindSafe(|| {
        with_engine!(&mut h.inner, r => {
            const TILE: usize = 512;
            let mut words = [0u32; 2 * TILE];
            let mut done = 0usize;
            while done < out.len() {
                let n = (out.len() - done).min(TILE);
                let tile = &mut words[..2 * n];
                r.fill_u32(tile);
                for k in 0..n {
                    out[done + k] = u01_f64(tile[2 * k], tile[2 * k + 1]);
                }
                done += n;
            }
        })
    }));
    match filled {
        Ok(()) => OPENRAND_OK,
        Err(_) => OPENRAND_ERR_PANIC,
    }
}

/// Advance the stream by `n` words — bit-identical to drawing and
/// discarding `n` words. O(1) for the counter engines, O(n) for
/// tyche/tyche_i.
///
/// # Safety
///
/// `e` must be NULL or a live engine handle owned by this thread.
#[no_mangle]
pub unsafe extern "C" fn openrand_advance(e: *mut OpenrandEngine, n: u64) -> i32 {
    let Some(h) = e.as_mut() else {
        return OPENRAND_ERR_NULL;
    };
    match catch_unwind(AssertUnwindSafe(|| with_engine!(&mut h.inner, r => r.advance(n)))) {
        Ok(()) => OPENRAND_OK,
        Err(_) => OPENRAND_ERR_PANIC,
    }
}

/// Position the stream at absolute word `pos` in O(1) (engines with a
/// shorter period reduce `pos` modulo it).
///
/// # Safety
///
/// As [`openrand_advance`].
#[no_mangle]
pub unsafe extern "C" fn openrand_set_position(e: *mut OpenrandEngine, pos: u64) -> i32 {
    let Some(h) = e.as_mut() else {
        return OPENRAND_ERR_NULL;
    };
    match catch_unwind(AssertUnwindSafe(|| with_engine!(&mut h.inner, r => r.set_position(pos)))) {
        Ok(()) => OPENRAND_OK,
        Err(_) => OPENRAND_ERR_PANIC,
    }
}

/// O(1) far jump by the engine's fixed stride (2^33 words for the 4x32
/// engines, 2^16 for the 2x32/squares engines). Engines without an
/// O(1) jump (tyche, tyche_i — a panic in the Rust API) return
/// `OPENRAND_ERR_NO_JUMP` without touching the stream.
///
/// # Safety
///
/// As [`openrand_advance`].
#[no_mangle]
pub unsafe extern "C" fn openrand_jump(e: *mut OpenrandEngine) -> i32 {
    let Some(h) = e.as_mut() else {
        return OPENRAND_ERR_NULL;
    };
    if jump_log2(&h.inner).is_none() {
        return OPENRAND_ERR_NO_JUMP;
    }
    match catch_unwind(AssertUnwindSafe(|| with_engine!(&mut h.inner, r => r.jump()))) {
        Ok(()) => OPENRAND_OK,
        Err(_) => OPENRAND_ERR_PANIC,
    }
}

fn key_out(key: StreamKey, out: *mut *mut OpenrandKey) -> i32 {
    if out.is_null() {
        return OPENRAND_ERR_NULL;
    }
    unsafe {
        *out = Box::into_raw(Box::new(OpenrandKey { inner: key }));
    }
    OPENRAND_OK
}

/// The root key of a stream tree: `(seed, ctr = 0)`. Release with
/// [`openrand_key_free`].
///
/// # Safety
///
/// `out` must be NULL or valid for writing one pointer.
#[no_mangle]
pub unsafe extern "C" fn openrand_key_root(seed: u64, out: *mut *mut OpenrandKey) -> i32 {
    key_out(StreamKey::root(seed), out)
}

/// A key naming an explicit `(seed, ctr)` address (interoperates with
/// raw `openrand_create` calls by construction).
///
/// # Safety
///
/// As [`openrand_key_root`].
#[no_mangle]
pub unsafe extern "C" fn openrand_key_raw(seed: u64, ctr: u32, out: *mut *mut OpenrandKey) -> i32 {
    key_out(StreamKey::raw(seed, ctr), out)
}

/// Derive child `id` of `key` through the normative splitmix64 mix
/// (`derive_child_seed`) — a fresh key handle; `key` is unchanged.
///
/// # Safety
///
/// `key` must be NULL or a live key handle; `out` as
/// [`openrand_key_root`].
#[no_mangle]
pub unsafe extern "C" fn openrand_key_child(
    key: *const OpenrandKey,
    id: u64,
    out: *mut *mut OpenrandKey,
) -> i32 {
    let Some(k) = key.as_ref() else {
        return OPENRAND_ERR_NULL;
    };
    key_out(k.inner.child(id), out)
}

/// Set the epoch (counter) absolutely — last call wins, per the stream
/// contract. A fresh key handle; `key` is unchanged.
///
/// # Safety
///
/// As [`openrand_key_child`].
#[no_mangle]
pub unsafe extern "C" fn openrand_key_epoch(
    key: *const OpenrandKey,
    epoch: u32,
    out: *mut *mut OpenrandKey,
) -> i32 {
    let Some(k) = key.as_ref() else {
        return OPENRAND_ERR_NULL;
    };
    key_out(k.inner.epoch(epoch), out)
}

/// Read the derived seed a key addresses.
///
/// # Safety
///
/// `key` must be NULL or a live key handle; `out` NULL or writable.
#[no_mangle]
pub unsafe extern "C" fn openrand_key_seed(key: *const OpenrandKey, out: *mut u64) -> i32 {
    let (Some(k), false) = (key.as_ref(), out.is_null()) else {
        return OPENRAND_ERR_NULL;
    };
    *out = k.inner.seed();
    OPENRAND_OK
}

/// Read the counter (epoch) a key addresses.
///
/// # Safety
///
/// As [`openrand_key_seed`].
#[no_mangle]
pub unsafe extern "C" fn openrand_key_ctr(key: *const OpenrandKey, out: *mut u32) -> i32 {
    let (Some(k), false) = (key.as_ref(), out.is_null()) else {
        return OPENRAND_ERR_NULL;
    };
    *out = k.inner.ctr();
    OPENRAND_OK
}

/// Release a key handle. NULL is a no-op.
///
/// # Safety
///
/// `key` must be NULL or a handle from `openrand_key_*` not yet freed.
#[no_mangle]
pub unsafe extern "C" fn openrand_key_free(key: *mut OpenrandKey) {
    if !key.is_null() {
        drop(Box::from_raw(key));
    }
}
