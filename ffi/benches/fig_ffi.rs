//! fig_ffi — what the C ABI costs over native Rust.
//!
//! The claim under test (docs/ffi.md §Performance): the FFI layer adds
//! one indirect call, one enum dispatch, and an unwind guard per entry
//! point — negligible against a bulk fill, real against scalar draws.
//! The acceptance gate is on the bulk path: `openrand_fill_u32` /
//! `openrand_fill_f64` through the C ABI must stay within 1.2x of the
//! native `core::fill` serial path for megaword buffers. Scalar
//! next_u32 over FFI is reported for the table but not gated (a
//! function call per word is the known cost of a C-callable scalar
//! API; C callers that care use the fill entry points).
//!
//! ```bash
//! cargo bench -p openrand_ffi --bench fig_ffi          # full
//! OPENRAND_BENCH_QUICK=1 cargo bench -p openrand_ffi --bench fig_ffi
//! ```

use std::ptr;

use openrand::bench::harness::black_box;
use openrand::bench::{Bencher, Series};
use openrand::core::{fill, CounterRng, Philox, Rng};
use openrand_ffi::{
    openrand_create, openrand_destroy, openrand_fill_f64, openrand_fill_u32, openrand_next_u32,
    OpenrandEngine, OPENRAND_OK,
};

/// 1 Mword buffers: large enough that per-call overhead is amortized
/// exactly as a real C consumer would amortize it.
const N: usize = 1 << 20;

fn ffi_engine(seed: u64, ctr: u32) -> *mut OpenrandEngine {
    let mut e: *mut OpenrandEngine = ptr::null_mut();
    let rc = unsafe { openrand_create(b"philox\0".as_ptr().cast(), seed, ctr, &mut e) };
    assert_eq!(rc, OPENRAND_OK);
    e
}

fn main() {
    let b = Bencher::from_env();
    eprintln!("fig_ffi: C-ABI overhead vs native Rust (philox, {N}-word buffers)");

    // --- u32 bulk fill: native vs FFI -------------------------------
    let mut buf = vec![0u32; N];
    let mut ctr = 0u32;
    let native_u32 = b.run("native/fill_u32", N as u64, || {
        ctr = ctr.wrapping_add(1);
        fill::fill_u32::<Philox>(1, ctr, &mut buf);
        black_box(buf[N - 1]);
    });
    eprintln!("  {}", native_u32.summary());

    let mut ctr = 0u32;
    let ffi_u32 = b.run("ffi/fill_u32", N as u64, || {
        ctr = ctr.wrapping_add(1);
        let e = ffi_engine(1, ctr);
        let rc = unsafe { openrand_fill_u32(e, buf.as_mut_ptr(), N) };
        assert_eq!(rc, OPENRAND_OK);
        unsafe { openrand_destroy(e) };
        black_box(buf[N - 1]);
    });
    eprintln!("  {}", ffi_u32.summary());

    // --- f64 bulk fill: native vs FFI -------------------------------
    let mut dbuf = vec![0.0f64; N / 2];
    let mut ctr = 0u32;
    let native_f64 = b.run("native/fill_f64", (N / 2) as u64, || {
        ctr = ctr.wrapping_add(1);
        fill::fill_f64::<Philox>(1, ctr, &mut dbuf);
        black_box(dbuf[N / 2 - 1]);
    });
    eprintln!("  {}", native_f64.summary());

    let mut ctr = 0u32;
    let ffi_f64 = b.run("ffi/fill_f64", (N / 2) as u64, || {
        ctr = ctr.wrapping_add(1);
        let e = ffi_engine(1, ctr);
        let rc = unsafe { openrand_fill_f64(e, dbuf.as_mut_ptr(), N / 2) };
        assert_eq!(rc, OPENRAND_OK);
        unsafe { openrand_destroy(e) };
        black_box(dbuf[N / 2 - 1]);
    });
    eprintln!("  {}", ffi_f64.summary());

    // --- scalar draws (reported, not gated) -------------------------
    const SCALAR_N: usize = 1 << 16;
    let native_scalar = b.run("native/next_u32_scalar", SCALAR_N as u64, || {
        let mut g = Philox::new(1, 7);
        let mut acc = 0u32;
        for _ in 0..SCALAR_N {
            acc ^= g.next_u32();
        }
        black_box(acc);
    });
    eprintln!("  {}", native_scalar.summary());
    let ffi_scalar = b.run("ffi/next_u32_scalar", SCALAR_N as u64, || {
        let e = ffi_engine(1, 7);
        let mut acc = 0u32;
        let mut w = 0u32;
        for _ in 0..SCALAR_N {
            let rc = unsafe { openrand_next_u32(e, &mut w) };
            debug_assert_eq!(rc, OPENRAND_OK);
            acc ^= w;
        }
        unsafe { openrand_destroy(e) };
        black_box(acc);
    });
    eprintln!("  {}", ffi_scalar.summary());

    let per_word = |r: &openrand::bench::BenchResult, n: usize| r.median_ns / n as f64;
    let rows = [
        ("fill_u32", per_word(&native_u32, N), per_word(&ffi_u32, N)),
        ("fill_f64", per_word(&native_f64, N / 2), per_word(&ffi_f64, N / 2)),
        ("next_u32", per_word(&native_scalar, SCALAR_N), per_word(&ffi_scalar, SCALAR_N)),
    ];
    let mut fig =
        Series::new("Fig FFI — C ABI vs native", "path", "ns_per_elem", vec![0.0, 1.0]);
    for (name, native, ffi) in rows {
        eprintln!("  row {name}: native {native:.3} ns vs ffi {ffi:.3} ns ({:.3}x)", ffi / native);
        fig.push(name, vec![native, ffi]);
    }
    println!("{}", fig.render(|y| format!("{y:.3}")));

    // Sanity: the FFI stream is the native stream (same bytes).
    let e = ffi_engine(1, ctr);
    let mut a = [0u32; 64];
    assert_eq!(unsafe { openrand_fill_u32(e, a.as_mut_ptr(), a.len()) }, OPENRAND_OK);
    unsafe { openrand_destroy(e) };
    let mut want = [0u32; 64];
    fill::fill_u32::<Philox>(1, ctr, &mut want);
    assert_eq!(a, want, "FFI fill diverged from the native stream");

    // The acceptance gate (docs/ffi.md): bulk FFI within 1.2x native.
    // The quick profile widens to 1.5x — shared CI runners jitter, and
    // the quick gate exists to catch "accidentally O(n) slower", not to
    // measure — while the full profile enforces the documented bar.
    let quick = std::env::var("OPENRAND_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let limit = if quick { 1.5 } else { 1.2 };
    for (name, native, ffi) in [
        ("fill_u32", per_word(&native_u32, N), per_word(&ffi_u32, N)),
        ("fill_f64", per_word(&native_f64, N / 2), per_word(&ffi_f64, N / 2)),
    ] {
        let ratio = ffi / native;
        println!(
            "shape check: ffi {name} {ratio:.3}x native {}",
            if ratio <= 1.2 { "(<= 1.2x target — OK)" } else { "(above the 1.2x target)" }
        );
        assert!(
            ratio <= limit,
            "ffi {name} ({ffi:.3} ns/elem) must stay within {limit}x of native ({native:.3} ns/elem)"
        );
    }
}
