/* kat_harness.c — the C leg of the three-language bitwise KAT.
 *
 * Replays the shared known-answer vectors through the public C ABI
 * (include/openrand.h) and exits non-zero on the first byte of drift.
 * The same table lives in rust/src/selftest.rs (asserted natively and
 * by `cargo test`) and python/tests/test_ffi_vectors.py (pinned against
 * the JAX oracle) — three languages, one table.
 *
 * Build (what the CI lane runs from the repo root):
 *
 *   cargo build --release -p openrand_ffi
 *   gcc -std=c99 -Wall -Wextra -Werror -Iinclude \
 *       ffi/tests/kat_harness.c \
 *       target/release/libopenrand_ffi.a -lpthread -ldl -lm \
 *       -o target/kat_harness
 *   ./target/kat_harness
 *
 * Also exercises the error-code surface: every condition that panics in
 * the Rust API must come back as a typed code here, never an abort.
 */

#include <stdint.h>
#include <stdio.h>
#include <string.h>

#include "openrand.h"

static int failures = 0;

#define CHECK(cond, name)                                                      \
    do {                                                                       \
        if (!(cond)) {                                                         \
            failures++;                                                        \
            fprintf(stderr, "FAIL %s (%s:%d)\n", name, __FILE__, __LINE__);    \
        }                                                                      \
    } while (0)

/* Stream words 0..10 of (seed = 7, ctr = 1) for every engine — the
 * shared engine-word table (ENGINE_WORDS_S7_C1 in rust/src/selftest.rs,
 * python/tests/test_ffi_vectors.py). */
static const char *const TAGS[7] = {
    "philox", "philox2x32", "threefry", "threefry2x32",
    "squares", "tyche", "tyche_i",
};

static const uint32_t ENGINE_WORDS_S7_C1[7][10] = {
    {0x2EC4F55Du, 0x249EF5F4u, 0xF681EC7Fu, 0x807A6601u, 0x3CBE7593u,
     0x21951225u, 0x66BA2E25u, 0x5159B36Au, 0x8DB4CE21u, 0x498FF58Bu},
    {0x5DD09A2Fu, 0x6B00841Eu, 0xAC55AAD4u, 0x858C5948u, 0xDCC223D7u,
     0xB92B6CACu, 0x07242571u, 0x304D3D15u, 0x20C6D682u, 0xC8FCCB4Fu},
    {0xD73CEA92u, 0xD56DC136u, 0xD744F371u, 0x6D239EE4u, 0xBE200A6Eu,
     0x00481B5Cu, 0xF8EB5F46u, 0x3405B98Cu, 0xDF0D1159u, 0x35B542BAu},
    {0x3AA75E81u, 0x7DBDB64Cu, 0xECA70012u, 0x97F16955u, 0x636D7473u,
     0x6ECE15CEu, 0xC93D5ECFu, 0xD0222576u, 0x1E98EC3Eu, 0x975E8B5Fu},
    {0xC58E0D20u, 0x4C1EEAB3u, 0xB2CF997Fu, 0x7900D050u, 0x6B50E8E1u,
     0x648DD2AAu, 0x7BCCBCFBu, 0xCE63EFD7u, 0x5B5236D3u, 0xD33D98F1u},
    {0x3CB80C83u, 0x0128E5AFu, 0x9C1F4904u, 0xECA46A3Cu, 0x2ACC26BEu,
     0x6912D082u, 0x98318013u, 0x44F8C1FAu, 0x08703B44u, 0xFD4C1C53u},
    {0x208BEFEAu, 0x3079BF27u, 0xA8606EB3u, 0x8839063Au, 0x647330F1u,
     0xC1170F7Eu, 0xC298E6A6u, 0x41925E91u, 0x5902AA9Du, 0xC3E537E3u},
};

/* Conversion and key-derivation literals (same names as selftest.rs). */
static const uint64_t PHILOX_S7_C1_U64 = 0x2EC4F55D249EF5F4ull;
static const uint64_t PHILOX_S7_C1_F64_BITS = 0x3FC7627AAE924F78ull;
static const uint32_t PHILOX_S7_C1_F32_BITS = 0x3E3B13D4u;
static const uint64_t CHILD_SEED_R7_C3 = 0xBC8312B734DE4237ull;
static const uint64_t GRANDCHILD_SEED_R7_C3_C5 = 0x2D4C1D0A85956C49ull;
static const uint64_t CHILD_SEED_R7_E2_C3 = 0x2E49EAEDC17E2B71ull;
static const uint32_t CHILD_STREAM_WORDS[2] = {0x90229F37u, 0x89AF95F5u};
static const uint64_t CHILD_STREAM_F64_BITS = 0x3FE20453E6F135F2ull;

static uint64_t f64_bits(double x) {
    uint64_t b;
    memcpy(&b, &x, sizeof b);
    return b;
}

static uint32_t f32_bits(float x) {
    uint32_t b;
    memcpy(&b, &x, sizeof b);
    return b;
}

/* Word tables, drawn twice per engine: word-at-a-time and bulk fill. */
static void engine_word_tables(void) {
    for (int g = 0; g < 7; g++) {
        openrand_engine *e = NULL;
        CHECK(openrand_create(TAGS[g], 7, 1, &e) == OPENRAND_OK, TAGS[g]);
        for (int i = 0; i < 10; i++) {
            uint32_t w = 0;
            CHECK(openrand_next_u32(e, &w) == OPENRAND_OK, "next_u32 rc");
            CHECK(w == ENGINE_WORDS_S7_C1[g][i], "next_u32 word table");
        }
        openrand_destroy(e);

        uint32_t buf[10] = {0};
        CHECK(openrand_create(TAGS[g], 7, 1, &e) == OPENRAND_OK, TAGS[g]);
        CHECK(openrand_fill_u32(e, buf, 10) == OPENRAND_OK, "fill_u32 rc");
        CHECK(memcmp(buf, ENGINE_WORDS_S7_C1[g], sizeof buf) == 0,
              "fill_u32 word table");
        openrand_destroy(e);
    }
}

/* The normative u64 / f64 / f32 conversions, scalar and bulk. */
static void conversions(void) {
    openrand_engine *e = NULL;
    uint64_t v64 = 0;
    double d = 0.0;
    float f = 0.0f;

    CHECK(openrand_create("philox", 7, 1, &e) == OPENRAND_OK, "create");
    CHECK(openrand_next_u64(e, &v64) == OPENRAND_OK, "next_u64 rc");
    CHECK(v64 == PHILOX_S7_C1_U64, "u64 word order");
    openrand_destroy(e);

    CHECK(openrand_create("philox", 7, 1, &e) == OPENRAND_OK, "create");
    CHECK(openrand_uniform_f64(e, &d) == OPENRAND_OK, "uniform_f64 rc");
    CHECK(f64_bits(d) == PHILOX_S7_C1_F64_BITS, "f64 bits");
    openrand_destroy(e);

    CHECK(openrand_create("philox", 7, 1, &e) == OPENRAND_OK, "create");
    CHECK(openrand_uniform_f32(e, &f) == OPENRAND_OK, "uniform_f32 rc");
    CHECK(f32_bits(f) == PHILOX_S7_C1_F32_BITS, "f32 bits");
    openrand_destroy(e);

    /* Bulk doubles == repeated scalar draws; element 0 is the pinned
     * conversion literal. */
    double bulk[7] = {0};
    CHECK(openrand_create("philox", 7, 1, &e) == OPENRAND_OK, "create");
    CHECK(openrand_fill_f64(e, bulk, 7) == OPENRAND_OK, "fill_f64 rc");
    openrand_destroy(e);
    CHECK(f64_bits(bulk[0]) == PHILOX_S7_C1_F64_BITS, "fill_f64[0] bits");
    CHECK(openrand_create("philox", 7, 1, &e) == OPENRAND_OK, "create");
    for (int i = 0; i < 7; i++) {
        CHECK(openrand_uniform_f64(e, &d) == OPENRAND_OK, "uniform_f64 rc");
        CHECK(f64_bits(d) == f64_bits(bulk[i]), "fill_f64 == scalar");
    }
    openrand_destroy(e);
}

/* StreamKey derivation and the streams it addresses. */
static void key_derivation(void) {
    openrand_key *root = NULL, *child = NULL, *grand = NULL, *epoch = NULL;
    uint64_t seed = 0;
    uint32_t ctr = 0;

    CHECK(openrand_key_root(7, &root) == OPENRAND_OK, "key_root");
    CHECK(openrand_key_child(root, 3, &child) == OPENRAND_OK, "key_child");
    CHECK(openrand_key_seed(child, &seed) == OPENRAND_OK, "key_seed rc");
    CHECK(seed == CHILD_SEED_R7_C3, "child seed");
    CHECK(openrand_key_ctr(child, &ctr) == OPENRAND_OK, "key_ctr rc");
    CHECK(ctr == 0, "child ctr");

    CHECK(openrand_key_child(child, 5, &grand) == OPENRAND_OK, "grandchild");
    CHECK(openrand_key_seed(grand, &seed) == OPENRAND_OK, "key_seed rc");
    CHECK(seed == GRANDCHILD_SEED_R7_C3_C5, "grandchild seed");
    openrand_key_free(grand);

    /* Epoch separates child spaces: root(7).epoch(2).child(3). */
    CHECK(openrand_key_epoch(root, 2, &epoch) == OPENRAND_OK, "key_epoch");
    CHECK(openrand_key_child(epoch, 3, &grand) == OPENRAND_OK, "epoch child");
    CHECK(openrand_key_seed(grand, &seed) == OPENRAND_OK, "key_seed rc");
    CHECK(seed == CHILD_SEED_R7_E2_C3, "epoch-separated child seed");
    openrand_key_free(grand);
    openrand_key_free(epoch);

    /* Open the derived stream root(7).child(3).epoch(1) and replay its
     * pinned opening words and f64 bits. */
    CHECK(openrand_key_epoch(child, 1, &epoch) == OPENRAND_OK, "key_epoch");
    openrand_engine *e = NULL;
    uint32_t w = 0;
    CHECK(openrand_create_keyed("philox", epoch, &e) == OPENRAND_OK,
          "create_keyed");
    for (int i = 0; i < 2; i++) {
        CHECK(openrand_next_u32(e, &w) == OPENRAND_OK, "next_u32 rc");
        CHECK(w == CHILD_STREAM_WORDS[i], "derived stream words");
    }
    openrand_destroy(e);
    double d = 0.0;
    CHECK(openrand_create_keyed("philox", epoch, &e) == OPENRAND_OK,
          "create_keyed");
    CHECK(openrand_uniform_f64(e, &d) == OPENRAND_OK, "uniform_f64 rc");
    CHECK(f64_bits(d) == CHILD_STREAM_F64_BITS, "derived stream f64 bits");
    openrand_destroy(e);

    /* key_raw(seed, ctr) opens the same stream as openrand_create. */
    openrand_key *raw = NULL;
    CHECK(openrand_key_raw(7, 1, &raw) == OPENRAND_OK, "key_raw");
    CHECK(openrand_create_keyed("philox", raw, &e) == OPENRAND_OK,
          "create_keyed raw");
    CHECK(openrand_next_u32(e, &w) == OPENRAND_OK, "next_u32 rc");
    CHECK(w == ENGINE_WORDS_S7_C1[0][0], "raw key == (seed, ctr)");
    openrand_destroy(e);
    openrand_key_free(raw);

    openrand_key_free(child);
    openrand_key_free(root);
}

/* Jump-ahead literals (test_jump_ahead.py / selftest.rs). */
static void jump_ahead(void) {
    openrand_engine *e = NULL;
    uint32_t w = 0;

    CHECK(openrand_create("philox", 7, 1, &e) == OPENRAND_OK, "create");
    CHECK(openrand_jump(e) == OPENRAND_OK, "philox jump rc");
    CHECK(openrand_next_u32(e, &w) == OPENRAND_OK, "next_u32 rc");
    CHECK(w == 0x3A294131u, "philox jump 2^33");
    openrand_destroy(e);

    CHECK(openrand_create("philox", 7, 1, &e) == OPENRAND_OK, "create");
    CHECK(openrand_set_position(e, (1ull << 34) + 2) == OPENRAND_OK,
          "set_position rc");
    CHECK(openrand_next_u32(e, &w) == OPENRAND_OK, "next_u32 rc");
    CHECK(w == 0x275A0C0Fu, "philox word 2^34+2");
    openrand_destroy(e);

    CHECK(openrand_create("philox", 7, 1, &e) == OPENRAND_OK, "create");
    CHECK(openrand_advance(e, 9) == OPENRAND_OK, "advance rc");
    CHECK(openrand_next_u32(e, &w) == OPENRAND_OK, "next_u32 rc");
    CHECK(w == ENGINE_WORDS_S7_C1[0][9], "philox advance(9)");
    openrand_destroy(e);

    CHECK(openrand_create("squares", 7, 1, &e) == OPENRAND_OK, "create");
    CHECK(openrand_jump(e) == OPENRAND_OK, "squares jump rc");
    CHECK(openrand_next_u32(e, &w) == OPENRAND_OK, "next_u32 rc");
    CHECK(w == 0x853F0F97u, "squares jump 2^16");
    openrand_destroy(e);

    /* Tyche: advance is exact O(n) stepping; jump is a typed error
     * (checked in error_codes below). */
    CHECK(openrand_create("tyche", 7, 1, &e) == OPENRAND_OK, "create");
    CHECK(openrand_advance(e, 5) == OPENRAND_OK, "tyche advance rc");
    CHECK(openrand_next_u32(e, &w) == OPENRAND_OK, "next_u32 rc");
    CHECK(w == ENGINE_WORDS_S7_C1[5][5], "tyche advance(5)");
    openrand_destroy(e);
}

/* The panic-surface contract: typed codes, never an abort. */
static void error_codes(void) {
    openrand_engine *e = NULL;
    uint32_t w = 0;

    CHECK(openrand_create("not-an-engine", 1, 0, &e) ==
              OPENRAND_ERR_BAD_GENERATOR,
          "bad generator tag");
    CHECK(openrand_create(NULL, 1, 0, &e) == OPENRAND_ERR_NULL, "null tag");
    CHECK(openrand_create("philox", 1, 0, NULL) == OPENRAND_ERR_NULL,
          "null out");
    CHECK(openrand_next_u32(NULL, &w) == OPENRAND_ERR_NULL, "null engine");

    CHECK(openrand_create("philox", 1, 0, &e) == OPENRAND_OK, "create");
    CHECK(openrand_next_u32(e, NULL) == OPENRAND_ERR_NULL, "null out param");
    /* range_u32(0) panics in Rust; here it must be a code, and the
     * stream must be untouched by the failed call. */
    CHECK(openrand_range_u32(e, 0, &w) == OPENRAND_ERR_EMPTY_RANGE,
          "empty range code");
    CHECK(openrand_next_u32(e, &w) == OPENRAND_OK, "stream still usable");
    CHECK(openrand_fill_u32(e, NULL, 4) == OPENRAND_ERR_NULL, "null buf");
    CHECK(openrand_fill_u32(e, NULL, 0) == OPENRAND_OK, "len 0 any buf");
    openrand_destroy(e);

    /* jump() on tyche/tyche_i panics in Rust; a code here. */
    CHECK(openrand_create("tyche", 1, 0, &e) == OPENRAND_OK, "create");
    CHECK(openrand_jump(e) == OPENRAND_ERR_NO_JUMP, "tyche no-jump code");
    openrand_destroy(e);
    CHECK(openrand_create("tyche_i", 1, 0, &e) == OPENRAND_OK, "create");
    CHECK(openrand_jump(e) == OPENRAND_ERR_NO_JUMP, "tyche_i no-jump code");
    openrand_destroy(e);

    /* Key surface null discipline. */
    openrand_key *k = NULL;
    uint64_t seed = 0;
    CHECK(openrand_key_child(NULL, 1, &k) == OPENRAND_ERR_NULL, "null key");
    CHECK(openrand_key_seed(NULL, &seed) == OPENRAND_ERR_NULL, "null key");
    CHECK(openrand_key_root(7, NULL) == OPENRAND_ERR_NULL, "null key out");
    CHECK(openrand_create_keyed("philox", NULL, &e) == OPENRAND_ERR_NULL,
          "null key to create_keyed");

    /* Null handles are no-op frees, and strerror never returns NULL. */
    openrand_destroy(NULL);
    openrand_key_free(NULL);
    for (int code = -1; code < 8; code++) {
        CHECK(openrand_strerror(code) != NULL, "strerror non-null");
    }
}

int main(void) {
    printf("kat_harness: %s\n", openrand_version());
    CHECK(openrand_selftest() == OPENRAND_OK, "openrand_selftest");
    engine_word_tables();
    conversions();
    key_derivation();
    jump_ahead();
    error_codes();
    if (failures) {
        fprintf(stderr, "kat_harness: %d FAILURE(S)\n", failures);
        return 1;
    }
    printf("kat_harness: all C-side KATs passed\n");
    return 0;
}
