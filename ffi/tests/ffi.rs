//! Rust-side exercise of the C ABI: the same calls `kat_harness.c`
//! makes, driven through the `extern "C"` symbols so `cargo test -p
//! openrand_ffi` covers the boundary even where no C toolchain exists.
//! The KAT literals come straight from `openrand::selftest` — one
//! table, asserted here through the FFI layer instead of natively.

use std::ffi::CStr;
use std::os::raw::c_char;
use std::ptr;

use openrand::core::{CounterRng, Philox, Rng};
use openrand::selftest;
use openrand_ffi::*;

/// NUL-terminated tag strings in `Generator::ALL` order (matches the
/// selftest table).
const TAGS: [&[u8]; 7] = [
    b"philox\0",
    b"philox2x32\0",
    b"threefry\0",
    b"threefry2x32\0",
    b"squares\0",
    b"tyche\0",
    b"tyche_i\0",
];

fn tag_ptr(tag: &[u8]) -> *const c_char {
    tag.as_ptr().cast()
}

fn open(tag: &[u8], seed: u64, ctr: u32) -> *mut OpenrandEngine {
    let mut e: *mut OpenrandEngine = ptr::null_mut();
    let rc = unsafe { openrand_create(tag_ptr(tag), seed, ctr, &mut e) };
    assert_eq!(rc, OPENRAND_OK);
    assert!(!e.is_null());
    e
}

#[test]
fn selftest_passes_through_ffi() {
    assert_eq!(openrand_selftest(), OPENRAND_OK);
}

#[test]
fn engine_word_tables_through_ffi() {
    for (gi, tag) in TAGS.into_iter().enumerate() {
        let want = &selftest::ENGINE_WORDS_S7_C1[gi];
        let e = open(tag, 7, 1);
        for (i, w) in want.iter().enumerate() {
            let mut v = 0u32;
            assert_eq!(unsafe { openrand_next_u32(e, &mut v) }, OPENRAND_OK);
            assert_eq!(v, *w, "{tag:?} word {i}");
        }
        unsafe { openrand_destroy(e) };

        let e = open(tag, 7, 1);
        let mut buf = [0u32; 10];
        assert_eq!(unsafe { openrand_fill_u32(e, buf.as_mut_ptr(), buf.len()) }, OPENRAND_OK);
        assert_eq!(buf, *want, "{tag:?} bulk");
        unsafe { openrand_destroy(e) };
    }
}

#[test]
fn conversions_through_ffi() {
    let e = open(TAGS[0], 7, 1);
    let mut v = 0u64;
    assert_eq!(unsafe { openrand_next_u64(e, &mut v) }, OPENRAND_OK);
    assert_eq!(v, selftest::PHILOX_S7_C1_U64);
    unsafe { openrand_destroy(e) };

    let e = open(TAGS[0], 7, 1);
    let mut d = 0.0f64;
    assert_eq!(unsafe { openrand_uniform_f64(e, &mut d) }, OPENRAND_OK);
    assert_eq!(d.to_bits(), selftest::PHILOX_S7_C1_F64_BITS);
    unsafe { openrand_destroy(e) };

    let e = open(TAGS[0], 7, 1);
    let mut f = 0.0f32;
    assert_eq!(unsafe { openrand_uniform_f32(e, &mut f) }, OPENRAND_OK);
    assert_eq!(f.to_bits(), selftest::PHILOX_S7_C1_F32_BITS);
    unsafe { openrand_destroy(e) };
}

#[test]
fn fill_f64_matches_scalar_draws_across_tile_boundaries() {
    // 0, 1, tile-1, tile, tile+1, and a multi-tile length (TILE = 512).
    for n in [0usize, 1, 511, 512, 513, 1500] {
        let e = open(TAGS[0], 21, 4);
        let mut bulk = vec![0.0f64; n];
        assert_eq!(unsafe { openrand_fill_f64(e, bulk.as_mut_ptr(), n) }, OPENRAND_OK);
        unsafe { openrand_destroy(e) };
        let mut r = Philox::new(21, 4);
        for (i, v) in bulk.iter().enumerate() {
            assert_eq!(v.to_bits(), r.draw_double().to_bits(), "n={n} i={i}");
        }
    }
}

#[test]
fn positioning_through_ffi() {
    let e = open(TAGS[0], 7, 1);
    assert_eq!(unsafe { openrand_jump(e) }, OPENRAND_OK);
    let mut w = 0u32;
    assert_eq!(unsafe { openrand_next_u32(e, &mut w) }, OPENRAND_OK);
    assert_eq!(w, 0x3A29_4131, "philox jump 2^33");
    unsafe { openrand_destroy(e) };

    let e = open(TAGS[0], 7, 1);
    assert_eq!(unsafe { openrand_set_position(e, (1 << 34) + 2) }, OPENRAND_OK);
    assert_eq!(unsafe { openrand_next_u32(e, &mut w) }, OPENRAND_OK);
    assert_eq!(w, 0x275A_0C0F, "philox word 2^34+2");
    unsafe { openrand_destroy(e) };

    let e = open(TAGS[0], 7, 1);
    assert_eq!(unsafe { openrand_advance(e, 9) }, OPENRAND_OK);
    assert_eq!(unsafe { openrand_next_u32(e, &mut w) }, OPENRAND_OK);
    assert_eq!(w, selftest::ENGINE_WORDS_S7_C1[0][9], "philox advance(9)");
    unsafe { openrand_destroy(e) };
}

#[test]
fn key_surface_through_ffi() {
    unsafe {
        let mut root: *mut OpenrandKey = ptr::null_mut();
        assert_eq!(openrand_key_root(7, &mut root), OPENRAND_OK);
        let mut child: *mut OpenrandKey = ptr::null_mut();
        assert_eq!(openrand_key_child(root, 3, &mut child), OPENRAND_OK);
        let mut seed = 0u64;
        assert_eq!(openrand_key_seed(child, &mut seed), OPENRAND_OK);
        assert_eq!(seed, selftest::CHILD_SEED_R7_C3);

        let mut epoch: *mut OpenrandKey = ptr::null_mut();
        assert_eq!(openrand_key_epoch(child, 1, &mut epoch), OPENRAND_OK);
        let mut ctr = 0u32;
        assert_eq!(openrand_key_ctr(epoch, &mut ctr), OPENRAND_OK);
        assert_eq!(ctr, 1);

        let mut e: *mut OpenrandEngine = ptr::null_mut();
        assert_eq!(openrand_create_keyed(tag_ptr(TAGS[0]), epoch, &mut e), OPENRAND_OK);
        let mut w = 0u32;
        assert_eq!(openrand_next_u32(e, &mut w), OPENRAND_OK);
        assert_eq!(w, selftest::CHILD_STREAM_WORDS[0]);
        assert_eq!(openrand_next_u32(e, &mut w), OPENRAND_OK);
        assert_eq!(w, selftest::CHILD_STREAM_WORDS[1]);
        openrand_destroy(e);

        openrand_key_free(epoch);
        openrand_key_free(child);
        openrand_key_free(root);
    }
}

#[test]
fn panics_become_error_codes_not_aborts() {
    unsafe {
        // Unknown tag and null arguments.
        let mut e: *mut OpenrandEngine = ptr::null_mut();
        let bad: &[u8] = b"not-an-engine\0";
        assert_eq!(openrand_create(tag_ptr(bad), 1, 0, &mut e), OPENRAND_ERR_BAD_GENERATOR);
        assert_eq!(openrand_create(ptr::null(), 1, 0, &mut e), OPENRAND_ERR_NULL);
        assert_eq!(openrand_create(tag_ptr(TAGS[0]), 1, 0, ptr::null_mut()), OPENRAND_ERR_NULL);
        let mut w = 0u32;
        assert_eq!(openrand_next_u32(ptr::null_mut(), &mut w), OPENRAND_ERR_NULL);

        // The two documented panic sources come back as typed codes.
        let e = open(TAGS[0], 1, 0);
        assert_eq!(openrand_range_u32(e, 0, &mut w), OPENRAND_ERR_EMPTY_RANGE);
        // The failed call consumed no words: the stream replays from 0.
        assert_eq!(openrand_next_u32(e, &mut w), OPENRAND_OK);
        assert_eq!(w, Philox::new(1, 0).next_u32());
        assert_eq!(openrand_next_u32(e, ptr::null_mut()), OPENRAND_ERR_NULL);
        assert_eq!(openrand_fill_u32(e, ptr::null_mut(), 4), OPENRAND_ERR_NULL);
        assert_eq!(openrand_fill_u32(e, ptr::null_mut(), 0), OPENRAND_OK);
        openrand_destroy(e);

        for tag in [&b"tyche\0"[..], &b"tyche_i\0"[..]] {
            let e = open(tag, 1, 0);
            assert_eq!(openrand_jump(e), OPENRAND_ERR_NO_JUMP);
            openrand_destroy(e);
        }

        // Null keys and no-op frees.
        let mut k: *mut OpenrandKey = ptr::null_mut();
        assert_eq!(openrand_key_child(ptr::null(), 1, &mut k), OPENRAND_ERR_NULL);
        assert_eq!(openrand_key_root(7, ptr::null_mut()), OPENRAND_ERR_NULL);
        let mut seed = 0u64;
        assert_eq!(openrand_key_seed(ptr::null(), &mut seed), OPENRAND_ERR_NULL);
        assert_eq!(openrand_create_keyed(tag_ptr(TAGS[0]), ptr::null(), &mut e), OPENRAND_ERR_NULL);
        openrand_destroy(ptr::null_mut());
        openrand_key_free(ptr::null_mut());
    }
}

#[test]
fn strerror_and_version_are_static_c_strings() {
    let version = openrand_version();
    assert!(!version.is_null());
    let v = unsafe { CStr::from_ptr(version) }.to_str().unwrap();
    assert!(v.starts_with("openrand_ffi "), "{v}");
    for code in -1..8 {
        let msg: *const c_char = openrand_strerror(code);
        assert!(!msg.is_null());
        assert!(!unsafe { CStr::from_ptr(msg) }.to_str().unwrap().is_empty());
    }
    assert_eq!(
        unsafe { CStr::from_ptr(openrand_strerror(OPENRAND_ERR_NO_JUMP)) }.to_str().unwrap(),
        "engine has no O(1) jump; use openrand_advance"
    );
}
