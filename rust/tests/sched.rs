//! Shard-scheduler suite: `Sched` must stitch **byte-identical** output
//! to the serial `core::fill` layout for *any* shard plan — arbitrary
//! boundaries, host and device arms interleaved — across random
//! `(gen, seed, ctr, len, plan)` tuples. Device shards degrade to the
//! host fill of their span on stub builds, so the property holds
//! unconditionally; on artifact builds the same plans land interior
//! spans on the `_at` artifacts.

use openrand::backend::{
    CostModel, CrossoverTable, FillBackend, Sched, Shard, ShardArm, ShardPlan,
};
use openrand::core::counter::splitmix64;
use openrand::core::{fill, Generator};
use openrand::coordinator::repro;
use openrand::testing::prop::{Gen, Prop};

fn serial_words(gen: Generator, seed: u64, ctr: u32, n: usize) -> Vec<u32> {
    let mut out = vec![0u32; n];
    fill::fill_u32_gen(gen, seed, ctr, &mut out);
    out
}

/// Derive a random-but-deterministic plan for `len` words from `rng`
/// state: shard lengths are arbitrary (down to a single word), arms
/// alternate pseudo-randomly.
fn random_plan(state: &mut u64, len: usize) -> ShardPlan {
    let mut next = |s: &mut u64| {
        *s = s.wrapping_add(0x9E37_79B9_7F4A_7C15);
        splitmix64(*s)
    };
    let mut shards = Vec::new();
    let mut pos = 0usize;
    while pos < len {
        let r = next(state);
        let chunk = 1 + (r as usize >> 8) % (len / 3 + 1);
        let chunk = chunk.min(len - pos);
        let arm = if r & 1 == 0 { ShardArm::Host } else { ShardArm::Device };
        shards.push(Shard { start: pos as u64, len: chunk, arm });
        pos += chunk;
    }
    ShardPlan::new(shards).expect("contiguous by construction")
}

#[test]
fn prop_random_shard_plans_stitch_serial_bytes() {
    // The tentpole property: for random (gen, seed, ctr, len) tuples
    // and random shard plans over them, the stitched output equals the
    // serial reference byte-for-byte.
    let gens = [Generator::Philox, Generator::Threefry, Generator::Squares, Generator::Tyche];
    Prop::new("sched random plans == serial bytes").cases(25).check3(
        Gen::u64(),
        Gen::u32(),
        Gen::usize_in(1, 6000),
        move |seed, ctr, len| {
            let mut sched = Sched::new(3);
            let mut plan_state = seed ^ (len as u64).rotate_left(17);
            for gen in gens {
                let want = serial_words(gen, seed, ctr, len);
                for _ in 0..2 {
                    let plan = random_plan(&mut plan_state, len);
                    let mut got = vec![0u32; len];
                    sched.fill_u32_plan(gen, seed, ctr, &plan, &mut got).unwrap();
                    if got != want {
                        eprintln!("plan {} diverged for {}", plan.describe(), gen.name());
                        return false;
                    }
                }
            }
            true
        },
    );
}

#[test]
fn prop_sched_backend_equals_serial_bytes() {
    // The FillBackend face (cost-model planning included) over random
    // tuples, with a crossover low enough that device shards appear on
    // artifact builds.
    let model = CostModel::from_crossover(CrossoverTable { device_min_words: 512 });
    Prop::new("sched backend == serial bytes").cases(15).check3(
        Gen::u64(),
        Gen::u32(),
        Gen::usize_in(0, 3000),
        move |seed, ctr, len| {
            let mut sched = Sched::with_model(4, model);
            let mut got = vec![0u32; len];
            sched.fill_u32(Generator::Philox, seed, ctr, &mut got).unwrap();
            got == serial_words(Generator::Philox, seed, ctr, len)
        },
    );
}

#[test]
fn sched_invariance_ladder_passes() {
    // The acceptance ladder at test scale (the `repro` r7 rung): model
    // plan + random mixed-arm plans, byte-compared against serial.
    for gen in [Generator::Philox, Generator::Tyche] {
        let r = repro::verify_sched_invariance(gen, 30_000, 0x5C_4ED, 5, 6, 8);
        assert!(r.consistent, "{}", r.render());
    }
}

#[test]
fn single_word_shards_and_typed_fills() {
    // Degenerate plans: every word its own shard, alternating arms.
    let n = 257usize;
    let shards = (0..n)
        .map(|i| Shard {
            start: i as u64,
            len: 1,
            arm: if i % 2 == 0 { ShardArm::Host } else { ShardArm::Device },
        })
        .collect::<Vec<_>>();
    let plan = ShardPlan::new(shards).unwrap();
    let mut sched = Sched::new(2);
    let mut got = vec![0u32; n];
    sched.fill_u32_plan(Generator::Squares, 9, 2, &plan, &mut got).unwrap();
    assert_eq!(got, serial_words(Generator::Squares, 9, 2, n));
    // Typed fills ride the same words through the trait defaults.
    let mut gf = vec![0.0f64; 400];
    sched.fill_f64(Generator::Philox, 5, 1, &mut gf).unwrap();
    let mut wf = vec![0.0f64; 400];
    openrand::backend::HostSerial.fill_f64(Generator::Philox, 5, 1, &mut wf).unwrap();
    assert_eq!(
        gf.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        wf.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
    );
}
