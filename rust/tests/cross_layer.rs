//! Cross-layer integration: the Rust engines (L3) against the AOT
//! artifacts lowered from the JAX + Pallas stack (L2 + L1).
//!
//! This is the test that makes the whole three-layer architecture honest:
//! three independent implementations of every generator (Rust, pure-jnp
//! oracle, Pallas kernel) must agree **bitwise** through the PJRT
//! runtime. Requires `make artifacts` **and** a real xla_extension
//! backend; on a fresh checkout (no artifacts, vendored PJRT stub) every
//! test here skips with a note instead of failing, so the host-only
//! tier-1 suite stays green.

use openrand::core::{CounterRng, Rng};
use openrand::core::{Philox, Squares, Threefry, Tyche};
use openrand::runtime::exec::{Arg, DeviceGraph};
use openrand::runtime::ArtifactStore;

/// With `OPENRAND_REQUIRE_ARTIFACTS=1` the skips below become hard
/// failures — set it wherever `make artifacts` has run, so a broken
/// manifest/loader can never masquerade as a clean skip.
fn strict() -> bool {
    std::env::var("OPENRAND_REQUIRE_ARTIFACTS").as_deref() == Ok("1")
}

/// The artifact store, or `None` (with a note) when the AOT artifacts
/// have not been generated in this checkout.
fn store() -> Option<ArtifactStore> {
    match ArtifactStore::open_default() {
        Ok(st) => Some(st),
        Err(e) if strict() => panic!("OPENRAND_REQUIRE_ARTIFACTS=1 but store failed: {e:#}"),
        Err(e) => {
            eprintln!("skipping cross-layer test (run `make artifacts`): {e:#}");
            None
        }
    }
}

/// Load a graph, or `None` (with a note) when the executable cannot be
/// built — e.g. the vendored PJRT stub without a real backend.
fn load(st: &ArtifactStore, name: &str) -> Option<DeviceGraph> {
    match DeviceGraph::load(st, name) {
        Ok(g) => Some(g),
        Err(e) if strict() => panic!("OPENRAND_REQUIRE_ARTIFACTS=1 but '{name}' failed: {e:#}"),
        Err(e) => {
            eprintln!("skipping cross-layer test (no executable backend): {e:#}");
            None
        }
    }
}

macro_rules! require {
    ($opt:expr) => {
        match $opt {
            Some(v) => v,
            None => return,
        }
    };
}

fn host_stream<G: CounterRng>(seed: u64, ctr: u32, n: usize) -> Vec<u32> {
    let mut out = vec![0u32; n];
    G::new(seed, ctr).fill_u32(&mut out);
    out
}

#[test]
fn philox_block_bitwise() {
    let st = require!(store());
    let graph = require!(load(&st, "philox_u32_65536"));
    for (seed, ctr) in [(0u64, 0u32), (42, 0), (0xDEAD_BEEF_1234_5678, 7)] {
        let dev = graph
            .call_u32(&[Arg::U32(&[seed as u32, (seed >> 32) as u32, ctr, 0])])
            .unwrap();
        assert_eq!(dev, host_stream::<Philox>(seed, ctr, 65_536), "seed={seed:x} ctr={ctr}");
    }
}

#[test]
fn threefry_block_bitwise() {
    let st = require!(store());
    let graph = require!(load(&st, "threefry_u32_65536"));
    let (seed, ctr) = (0xABCD_EF01_2345_6789u64, 3u32);
    let dev = graph
        .call_u32(&[Arg::U32(&[seed as u32, (seed >> 32) as u32, ctr, 0])])
        .unwrap();
    assert_eq!(dev, host_stream::<Threefry>(seed, ctr, 65_536));
}

#[test]
fn squares_block_bitwise() {
    let st = require!(store());
    let graph = require!(load(&st, "squares_u32_65536"));
    let (seed, ctr) = (0x0123_4567_89AB_CDEFu64, 5u32);
    // The kernel takes the derived key (splitmix64(seed)|1), as common.py
    // documents.
    let key = openrand::core::counter::squares_key(seed);
    let dev = graph
        .call_u32(&[Arg::U32(&[key as u32, (key >> 32) as u32, ctr, 0])])
        .unwrap();
    assert_eq!(dev, host_stream::<Squares>(seed, ctr, 65_536));
}

#[test]
fn tyche_block_bitwise() {
    let st = require!(store());
    let graph = require!(load(&st, "tyche_u32_65536"));
    let (seed, base) = (0xFEED_FACE_0000_1111u64, 2u32);
    let dev = graph
        .call_u32(&[Arg::U32(&[seed as u32, (seed >> 32) as u32, base, 0])])
        .unwrap();
    // Lane i = first output of stream (seed, base ^ i).
    for (i, &w) in dev.iter().enumerate().step_by(4097) {
        let mut t = Tyche::new(seed, base ^ i as u32);
        assert_eq!(w, t.next_u32(), "lane {i}");
    }
    // And densely over the first 2048 lanes.
    for (i, &w) in dev.iter().take(2048).enumerate() {
        let mut t = Tyche::new(seed, base ^ i as u32);
        assert_eq!(w, t.next_u32(), "lane {i}");
    }
}

#[test]
fn uniform_f64_matches_host_conversion() {
    let st = require!(store());
    let graph = require!(load(&st, "philox_f64_32768"));
    let (seed, ctr) = (7u64, 1u32);
    let dev = graph
        .call_f64(&[Arg::U32(&[seed as u32, (seed >> 32) as u32, ctr, 0])])
        .unwrap();
    let mut rng = Philox::new(seed, ctr);
    for (i, &d) in dev.iter().enumerate() {
        let host = rng.draw_double();
        assert_eq!(d.to_bits(), host.to_bits(), "double {i}");
    }
}

#[test]
fn normal_graph_matches_box_muller_shape() {
    use openrand::dist::{BoxMuller, Distribution};
    let st = require!(store());
    let graph = require!(load(&st, "normal_f64_32768"));
    let dev = graph.call_f64(&[Arg::U32(&[7, 0, 1, 0])]).unwrap();
    // Same formula, same stream; libm vs XLA trig may differ in final
    // ulps, so compare with tolerance rather than bitwise.
    let mut rng = Philox::new(7, 1);
    let bm = BoxMuller::standard();
    for (i, &d) in dev.iter().enumerate().take(4096) {
        let host = bm.sample_pair(&mut rng).0;
        assert!(
            (d - host).abs() <= 1e-12 * host.abs().max(1.0),
            "normal {i}: dev {d} host {host}"
        );
    }
    // Moments on the full block.
    let n = dev.len() as f64;
    let mean = dev.iter().sum::<f64>() / n;
    let var = dev.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    assert!(mean.abs() < 0.03 && (var - 1.0).abs() < 0.05, "mean {mean}, var {var}");
}

#[test]
fn brownian_init_matches_host_grid() {
    use openrand::sim::brownian::{BrownianParams, BrownianSim, RngStyle};
    let st = require!(store());
    let graph = require!(load(&st, "brownian_init_16384"));
    let dev = graph.call_f64(&[]).unwrap();
    let sim = BrownianSim::new(BrownianParams {
        n_particles: 16_384,
        steps: 0,
        global_seed: 0,
        style: RngStyle::OpenRand,
    });
    assert_eq!(dev, sim.to_rows());
}

#[test]
fn brownian_step_host_device_agree() {
    use openrand::coordinator::{Backend, SimDriver};
    use openrand::sim::brownian::{BrownianParams, RngStyle};
    let params = BrownianParams {
        n_particles: 16_384,
        steps: 25,
        global_seed: 0xC0FFEE,
        style: RngStyle::OpenRand,
    };
    let (host, _) = SimDriver::new(Backend::Host { threads: 2 }).run(params).unwrap();
    let (dev, _) = match SimDriver::new(Backend::Device).run(params) {
        Ok(r) => r,
        Err(e) if strict() => panic!("OPENRAND_REQUIRE_ARTIFACTS=1 but device run failed: {e:#}"),
        Err(e) => {
            eprintln!("skipping device-backend test (run `make artifacts`): {e:#}");
            return;
        }
    };
    let mut max_rel: f64 = 0.0;
    for i in 0..params.n_particles {
        for (a, b) in [
            (host.x[i], dev.x[i]),
            (host.y[i], dev.y[i]),
            (host.vx[i], dev.vx[i]),
            (host.vy[i], dev.vy[i]),
        ] {
            max_rel = max_rel.max((a - b).abs() / a.abs().max(1e-12));
        }
    }
    assert!(max_rel < 1e-9, "max rel err {max_rel}");
}

#[test]
fn stateful_step_matches_host_curand_analog() {
    use openrand::coordinator::{Backend, SimDriver};
    use openrand::sim::brownian::{BrownianParams, RngStyle};
    // Host cuRAND-analog vs device stateful graph: same state layout,
    // same streams, same physics.
    let params = BrownianParams {
        n_particles: 16_384,
        steps: 10,
        global_seed: 42,
        style: RngStyle::CurandStyle,
    };
    let (host, _) = SimDriver::new(Backend::Host { threads: 1 }).run(params).unwrap();
    let (dev, m) = match SimDriver::new(Backend::Device).run(params) {
        Ok(r) => r,
        Err(e) if strict() => panic!("OPENRAND_REQUIRE_ARTIFACTS=1 but device run failed: {e:#}"),
        Err(e) => {
            eprintln!("skipping device-backend test (run `make artifacts`): {e:#}");
            return;
        }
    };
    assert!(m.rng_state_bytes >= 16_384 * 64, "device path must carry the state tensor");
    let mut max_rel: f64 = 0.0;
    for i in 0..params.n_particles {
        max_rel = max_rel.max((host.x[i] - dev.x[i]).abs() / host.x[i].abs().max(1e-12));
    }
    assert!(max_rel < 1e-9, "max rel err {max_rel}");
}

#[test]
fn manifest_signatures_honoured() {
    let st = require!(store());
    let graph = require!(load(&st, "philox_u32_65536"));
    // Wrong arity.
    assert!(graph.call(&[]).is_err());
    // Wrong element count.
    assert!(graph.call(&[Arg::U32(&[1, 2, 3])]).is_err());
    // Wrong dtype.
    assert!(graph.call(&[Arg::F64(&[1.0, 2.0, 3.0, 4.0])]).is_err());
}

#[test]
fn splitmix_contract_pinned_across_layers() {
    // The Squares key derivation must match the python side; pin the
    // shared reference vector here (python pins it in test_kat.py).
    assert_eq!(openrand::core::counter::splitmix64(0), 0xE220_A839_7B1D_CDAF);
    assert_eq!(openrand::core::counter::squares_key(0) & 1, 1);
}
