//! End-to-end integration: coordinator + sim + stats working together,
//! host-only (no artifacts needed — the artifact-dependent paths live in
//! cross_layer.rs).

use openrand::coordinator::repro;
use openrand::coordinator::{Backend, SimDriver};
use openrand::core::{CounterRng, Philox, Rng};
use openrand::sim::brownian::{BrownianParams, RngStyle};
use openrand::sim::pi;
use openrand::stats::run_battery;
use openrand::stream::{DynStream, StreamKey};

#[test]
fn full_repro_ladder() {
    let params = BrownianParams {
        n_particles: 4096,
        steps: 20,
        global_seed: 12345,
        style: RngStyle::OpenRand,
    };
    let r = repro::verify_thread_invariance(params, 16).unwrap();
    assert!(r.consistent, "{}", r.render());
    let r = repro::verify_rerun(params, 8).unwrap();
    assert!(r.consistent, "{}", r.render());
}

#[test]
fn all_styles_all_backends_host() {
    for style in RngStyle::ALL {
        for threads in [1usize, 4] {
            let params = BrownianParams {
                n_particles: 2048,
                steps: 10,
                global_seed: 7,
                style,
            };
            let (sim, m) = SimDriver::new(Backend::Host { threads }).run(params).unwrap();
            assert_eq!(sim.step, 10);
            assert!(m.throughput() > 0.0);
        }
    }
}

#[test]
fn seed_changes_trajectory() {
    let mk = |seed| {
        let params = BrownianParams {
            n_particles: 512,
            steps: 5,
            global_seed: seed,
            style: RngStyle::OpenRand,
        };
        let (sim, _) = SimDriver::new(Backend::Host { threads: 2 }).run(params).unwrap();
        sim.state_hash()
    };
    assert_ne!(mk(1), mk(2));
}

#[test]
fn pi_pipeline_reproducible_and_correct() {
    let a = pi::estimate_pi::<Philox>(64, 5_000, 3);
    let b = pi::estimate_pi::<Philox>(64, 5_000, 3);
    assert_eq!(a.to_bits(), b.to_bits());
    assert!((a - std::f64::consts::PI).abs() < 0.02);
}

#[test]
fn quick_battery_smoke_all_generators() {
    use openrand::core::Generator;
    for g in [Generator::Philox, Generator::Squares, Generator::Tyche] {
        let report = run_battery(g.name(), 1 << 16, |i| -> Box<dyn Rng> {
            match g {
                Generator::Philox => Box::new(openrand::core::Philox::new(i as u64, 0)),
                Generator::Squares => Box::new(openrand::core::Squares::new(i as u64, 0)),
                _ => Box::new(openrand::core::Tyche::new(i as u64, 0)),
            }
        });
        assert!(report.passed(), "{}", report.render());
    }
}

#[test]
fn keyed_battery_e2e_and_zero_drift_ladder() {
    use openrand::core::Generator;
    // The facade end to end: the repro ladder's zero-drift check, a
    // battery fed by derived child streams, and dist sampling through
    // DynStream — all from one root key.
    let root = StreamKey::root(0xE2E);
    let r = repro::verify_key_equivalence(root.seed(), root.ctr(), 8_192);
    assert!(r.consistent, "{}", r.render());
    let report = run_battery("philox@keys", 1 << 16, |i| -> Box<dyn Rng> {
        Box::new(DynStream::open(Generator::Philox, root.child(i as u64)))
    });
    assert!(report.passed(), "{}", report.render());
    // A derived stream replays bitwise through an independent handle.
    let key = root.child(3).epoch(1);
    let mut a = DynStream::open(Generator::Philox, key);
    let mut b = DynStream::open(Generator::Philox, key);
    for _ in 0..64 {
        assert_eq!(a.next_u32(), b.next_u32());
    }
}

#[test]
fn stream_independence_across_pids() {
    // Different pids at the same step draw uncorrelated kicks: compare
    // empirical correlation across 10k adjacent pid pairs.
    let n = 10_000;
    let (mut sx, mut sy, mut sxy, mut sxx, mut syy) = (0.0, 0.0, 0.0, 0.0, 0.0);
    for pid in 0..n {
        let x = Philox::new(pid as u64, 0).draw_double();
        let y = Philox::new(pid as u64 + 1, 0).draw_double();
        sx += x;
        sy += y;
        sxy += x * y;
        sxx += x * x;
        syy += y * y;
    }
    let nf = n as f64;
    let cov = sxy / nf - (sx / nf) * (sy / nf);
    let rho = cov / ((sxx / nf - (sx / nf).powi(2)) * (syy / nf - (sy / nf).powi(2))).sqrt();
    assert!(rho.abs() < 0.05, "adjacent-pid correlation {rho}");
}
