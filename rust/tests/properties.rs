//! Property-based tests over the core invariants, driven by the in-house
//! `testing::prop` framework (the proptest substitute).

use openrand::core::{CounterRng, Philox, Rng, Squares, Threefry, Tyche, TycheI};
use openrand::testing::prop::{Gen, Prop};

fn stream<G: CounterRng>(seed: u64, ctr: u32, n: usize) -> Vec<u32> {
    let mut rng = G::new(seed, ctr);
    (0..n).map(|_| rng.next_u32()).collect()
}

#[test]
fn prop_determinism_all_engines() {
    Prop::new("same (seed, ctr) -> same stream").cases(60).check2(
        Gen::u64(),
        Gen::u32(),
        |seed, ctr| {
            stream::<Philox>(seed, ctr, 16) == stream::<Philox>(seed, ctr, 16)
                && stream::<Threefry>(seed, ctr, 16) == stream::<Threefry>(seed, ctr, 16)
                && stream::<Squares>(seed, ctr, 16) == stream::<Squares>(seed, ctr, 16)
                && stream::<Tyche>(seed, ctr, 16) == stream::<Tyche>(seed, ctr, 16)
        },
    );
}

#[test]
fn prop_seed_sensitivity() {
    Prop::new("different seeds -> different streams").cases(60).check2(
        Gen::u64(),
        Gen::u64(),
        |a, b| {
            if a == b {
                return true;
            }
            stream::<Philox>(a, 0, 8) != stream::<Philox>(b, 0, 8)
                && stream::<Squares>(a, 0, 8) != stream::<Squares>(b, 0, 8)
        },
    );
}

#[test]
fn prop_ctr_sensitivity() {
    Prop::new("different ctrs -> different streams").cases(60).check2(
        Gen::u64(),
        Gen::u32(),
        |seed, ctr| {
            let other = ctr.wrapping_add(1);
            stream::<Philox>(seed, ctr, 8) != stream::<Philox>(seed, other, 8)
                && stream::<Tyche>(seed, ctr, 8) != stream::<Tyche>(seed, other, 8)
        },
    );
}

#[test]
fn prop_avalanche_seed_bitflip() {
    // Flipping any single seed bit flips 35-65% of the first 512 output
    // bits (counter-based avalanche, the property that lets users pick
    // ANY seeds — §2 of the paper).
    Prop::new("philox avalanche on seed bit").cases(40).check2(
        Gen::u64(),
        Gen::u32_below(64),
        |seed, bit| {
            let a = stream::<Philox>(seed, 0, 16);
            let b = stream::<Philox>(seed ^ (1u64 << bit), 0, 16);
            let flipped: u32 = a.iter().zip(&b).map(|(x, y)| (x ^ y).count_ones()).sum();
            let frac = flipped as f64 / 512.0;
            (0.35..0.65).contains(&frac)
        },
    );
}

#[test]
fn prop_avalanche_ctr_bitflip() {
    Prop::new("threefry avalanche on ctr bit").cases(40).check2(
        Gen::u64(),
        Gen::u32_below(32),
        |seed, bit| {
            let a = stream::<Threefry>(seed, 0, 16);
            let b = stream::<Threefry>(seed, 1u32 << bit, 16);
            let flipped: u32 = a.iter().zip(&b).map(|(x, y)| (x ^ y).count_ones()).sum();
            let frac = flipped as f64 / 512.0;
            (0.35..0.65).contains(&frac)
        },
    );
}

#[test]
fn prop_set_position_matches_sequential() {
    Prop::new("set_position == n draws").cases(60).check3(
        Gen::u64(),
        Gen::u32_below(200),
        Gen::u32_below(1000),
        |seed, ctr, pos| {
            let words = stream::<Philox>(seed, ctr, pos as usize + 1);
            let mut r = Philox::new(seed, ctr);
            r.set_position(pos);
            let jump_ok = r.next_u32() == words[pos as usize];

            let words_s = stream::<Squares>(seed, ctr, pos as usize + 1);
            let mut s = Squares::new(seed, ctr);
            s.set_position(pos);
            jump_ok && s.next_u32() == words_s[pos as usize]
        },
    );
}

#[test]
fn prop_draws_in_unit_interval() {
    Prop::new("draw_double in [0,1)").cases(100).check2(Gen::u64(), Gen::u32(), |seed, ctr| {
        let mut r = TycheI::new(seed, ctr);
        (0..32).all(|_| {
            let d = r.draw_double();
            (0.0..1.0).contains(&d)
        })
    });
}

#[test]
fn prop_range_u32_bounds() {
    Prop::new("range_u32 < bound").cases(200).check3(
        Gen::u64(),
        Gen::u32(),
        Gen::u32(),
        |seed, ctr, bound| {
            let bound = bound.max(1);
            let mut r = Philox::new(seed, ctr);
            (0..16).all(|_| r.range_u32(bound) < bound)
        },
    );
}

#[test]
fn prop_fill_equals_sequential() {
    Prop::new("fill_u32 == repeated next_u32").cases(60).check3(
        Gen::u64(),
        Gen::u32_below(7),
        Gen::u32_below(70),
        |seed, pre, len| {
            let mut a = Threefry::new(seed, 1);
            let mut b = Threefry::new(seed, 1);
            for _ in 0..pre {
                a.next_u32();
                b.next_u32();
            }
            let mut buf = vec![0u32; len as usize];
            a.fill_u32(&mut buf);
            buf.iter().all(|&w| w == b.next_u32()) && a.next_u32() == b.next_u32()
        },
    );
}

#[test]
fn prop_stream_nonoverlap_window() {
    // Distinct (seed, ctr) streams share no 4-word window in their first
    // 64 words (overlap would be a catastrophic counter-layout bug; for
    // honest 128-bit block space the collision probability is ~0).
    Prop::new("no 4-word window overlap").cases(30).check2(Gen::u64(), Gen::u64(), |s1, s2| {
        if s1 == s2 {
            return true;
        }
        let a = stream::<Philox>(s1, 0, 64);
        let b = stream::<Philox>(s2, 0, 64);
        for wa in a.windows(4) {
            for wb in b.windows(4) {
                if wa == wb {
                    return false;
                }
            }
        }
        true
    });
}
