//! Property-based tests over the core invariants, driven by the in-house
//! `testing::prop` framework (the proptest substitute) — plus the
//! feature-matrix guard.
//!
//! This is the ONE integration-test target built in both CI lanes
//! (`cargo test --test properties` and `cargo test --no-default-features
//! --test properties`; every other target carries `required-features =
//! ["std"]`). [`feature_matrix`] exercises only the `no_std`-available
//! surface against the pinned literals, so a stream that drifts across
//! the feature boundary fails the lane that drifted. The test binary
//! itself always links `std` — the constraint is on which `openrand`
//! APIs exist, which is exactly what the gated [`std_properties`]
//! wrapper encodes.

/// The feature-matrix guard: the `no_std` surface must produce the same
/// pinned words as the `std` build. Runs in BOTH feature lanes.
mod feature_matrix {
    use openrand::core::{fill, CounterRng, Generator, Philox, Rng};
    use openrand::selftest;
    use openrand::stream::{Stream, StreamKey};

    #[test]
    fn selftest_battery_passes() {
        // The full no_std KAT battery: engine word tables, normative
        // conversions, key derivation, jump-ahead literals.
        selftest::run().unwrap();
    }

    #[test]
    fn pinned_words_via_no_std_surface_only() {
        // Re-assert the headline literals through each no_std entry
        // point (engine, dispatch enum, serial fill, stream facade).
        let mut r = Philox::new(7, 1);
        assert_eq!(r.next_u32(), 0x2EC4_F55D);
        assert_eq!(Generator::Philox.with_rng(7, 1, |r| r.next_u32()), 0x2EC4_F55D);
        let mut buf = [0u32; 4];
        fill::fill_u32::<Philox>(7, 1, &mut buf);
        assert_eq!(buf, selftest::ENGINE_WORDS_S7_C1[0][..4]);
        let mut s = Stream::<Philox>::new(StreamKey::raw(7, 1));
        assert_eq!(s.next_u64(), selftest::PHILOX_S7_C1_U64);
    }

    #[test]
    fn key_derivation_via_no_std_surface() {
        let k = StreamKey::root(7).child(3).epoch(1);
        assert_eq!(k.seed(), selftest::CHILD_SEED_R7_C3);
        assert_eq!(k.ctr(), 1);
        let mut s = Stream::<Philox>::new(k);
        let mut out = [0u32; 2];
        s.fill_u32_at(0, &mut out);
        assert_eq!(out, selftest::CHILD_STREAM_WORDS);
    }

    #[test]
    fn scalar_dist_path_via_no_std_surface() {
        use openrand::dist::{Bernoulli, Binomial, Distribution, Uniform};
        let mut r = Philox::new(7, 1);
        let u = Uniform::standard().sample(&mut r);
        assert_eq!(u.to_bits(), selftest::PHILOX_S7_C1_F64_BITS);
        let mut r = Philox::new(7, 1);
        let _ = Bernoulli::new(0.5).sample(&mut r);
        let _ = Binomial::new(4, 0.5).sample(&mut r);
    }
}

#[cfg(feature = "std")]
mod std_properties {

use openrand::baseline::{Lcg64, Pcg32, SplitMix64};
use openrand::core::{
    fill, BlockBuffered, BlockRng, CounterRng, Philox, Philox2x32, Rng, Squares, Threefry,
    Threefry2x32, Tyche, TycheI,
};
use openrand::dist::{
    Bernoulli, Binomial, BoxMuller, DiscreteAlias, Distribution, Exponential, Poisson, Uniform,
    ZigguratNormal,
};
use openrand::stream::{derive_child_seed, DynStream, Stream, StreamKey};
use openrand::testing::prop::{Gen, Prop};

fn stream<G: CounterRng>(seed: u64, ctr: u32, n: usize) -> Vec<u32> {
    let mut rng = G::new(seed, ctr);
    (0..n).map(|_| rng.next_u32()).collect()
}

#[test]
fn prop_determinism_all_engines() {
    Prop::new("same (seed, ctr) -> same stream").cases(60).check2(
        Gen::u64(),
        Gen::u32(),
        |seed, ctr| {
            stream::<Philox>(seed, ctr, 16) == stream::<Philox>(seed, ctr, 16)
                && stream::<Threefry>(seed, ctr, 16) == stream::<Threefry>(seed, ctr, 16)
                && stream::<Squares>(seed, ctr, 16) == stream::<Squares>(seed, ctr, 16)
                && stream::<Tyche>(seed, ctr, 16) == stream::<Tyche>(seed, ctr, 16)
        },
    );
}

#[test]
fn prop_seed_sensitivity() {
    Prop::new("different seeds -> different streams").cases(60).check2(
        Gen::u64(),
        Gen::u64(),
        |a, b| {
            if a == b {
                return true;
            }
            stream::<Philox>(a, 0, 8) != stream::<Philox>(b, 0, 8)
                && stream::<Squares>(a, 0, 8) != stream::<Squares>(b, 0, 8)
        },
    );
}

#[test]
fn prop_ctr_sensitivity() {
    Prop::new("different ctrs -> different streams").cases(60).check2(
        Gen::u64(),
        Gen::u32(),
        |seed, ctr| {
            let other = ctr.wrapping_add(1);
            stream::<Philox>(seed, ctr, 8) != stream::<Philox>(seed, other, 8)
                && stream::<Tyche>(seed, ctr, 8) != stream::<Tyche>(seed, other, 8)
        },
    );
}

#[test]
fn prop_avalanche_seed_bitflip() {
    // Flipping any single seed bit flips 35-65% of the first 512 output
    // bits (counter-based avalanche, the property that lets users pick
    // ANY seeds — §2 of the paper).
    Prop::new("philox avalanche on seed bit").cases(40).check2(
        Gen::u64(),
        Gen::u32_below(64),
        |seed, bit| {
            let a = stream::<Philox>(seed, 0, 16);
            let b = stream::<Philox>(seed ^ (1u64 << bit), 0, 16);
            let flipped: u32 = a.iter().zip(&b).map(|(x, y)| (x ^ y).count_ones()).sum();
            let frac = flipped as f64 / 512.0;
            (0.35..0.65).contains(&frac)
        },
    );
}

#[test]
fn prop_avalanche_ctr_bitflip() {
    Prop::new("threefry avalanche on ctr bit").cases(40).check2(
        Gen::u64(),
        Gen::u32_below(32),
        |seed, bit| {
            let a = stream::<Threefry>(seed, 0, 16);
            let b = stream::<Threefry>(seed, 1u32 << bit, 16);
            let flipped: u32 = a.iter().zip(&b).map(|(x, y)| (x ^ y).count_ones()).sum();
            let frac = flipped as f64 / 512.0;
            (0.35..0.65).contains(&frac)
        },
    );
}

#[test]
fn prop_set_position_matches_sequential() {
    Prop::new("set_position == n draws").cases(60).check3(
        Gen::u64(),
        Gen::u32_below(200),
        Gen::u32_below(1000),
        |seed, ctr, pos| {
            let words = stream::<Philox>(seed, ctr, pos as usize + 1);
            let mut r = Philox::new(seed, ctr);
            r.set_position(pos as u64);
            let jump_ok = r.next_u32() == words[pos as usize];

            let words_s = stream::<Squares>(seed, ctr, pos as usize + 1);
            let mut s = Squares::new(seed, ctr);
            s.set_position(pos as u64);
            jump_ok && s.next_u32() == words_s[pos as usize]
        },
    );
}

#[test]
fn prop_advance_matches_sequential_all_engines() {
    // The jump-ahead contract (docs/stream-contracts.md §5): from ANY
    // phase, advance(n) lands exactly where n next_u32 draws would, for
    // every engine — O(1) counter engines and O(n) Tyche alike.
    fn check<G: CounterRng>(seed: u64, ctr: u32, pre: u32, n: u32) -> bool {
        let mut a = G::new(seed, ctr);
        let mut b = G::new(seed, ctr);
        for _ in 0..pre {
            a.next_u32();
            b.next_u32();
        }
        a.advance(n as u64);
        for _ in 0..n {
            b.next_u32();
        }
        (0..3).all(|_| a.next_u32() == b.next_u32())
    }
    Prop::new("advance(n) == n draws, any phase").cases(30).check3(
        Gen::u64(),
        Gen::u32_below(9),
        Gen::u32_below(300),
        |seed, pre, n| {
            check::<Philox>(seed, 1, pre, n)
                && check::<Philox2x32>(seed, 1, pre, n)
                && check::<Threefry>(seed, 1, pre, n)
                && check::<Threefry2x32>(seed, 1, pre, n)
                && check::<Squares>(seed, 1, pre, n)
                && check::<Tyche>(seed, 1, pre, n)
                && check::<TycheI>(seed, 1, pre, n)
        },
    );
}

#[test]
fn prop_advance_composes() {
    // advance(a) then advance(b) == advance(a + b): positions are
    // absolute counter arithmetic for the block engines, so composition
    // must be exact — including across the u32 block-id boundary.
    fn check<G: CounterRng>(seed: u64, a: u64, b: u64) -> bool {
        let mut two = G::new(seed, 2);
        two.advance(a);
        two.advance(b);
        let mut one = G::new(seed, 2);
        one.advance(a + b);
        (0..3).all(|_| two.next_u32() == one.next_u32())
    }
    Prop::new("advance(a);advance(b) == advance(a+b)").cases(40).check3(
        Gen::u64(),
        Gen::u32(),
        Gen::u32(),
        |seed, a, b| {
            // Stretch one leg past 2^32 words to cross the widened
            // block-id boundary on the 4x32 engines.
            let big = (a as u64) << 8;
            check::<Philox>(seed, big, b as u64)
                && check::<Threefry>(seed, big, b as u64)
                && check::<Philox2x32>(seed, a as u64, b as u64)
                && check::<Squares>(seed, a as u64, b as u64)
        },
    );
}

#[test]
fn prop_set_position_beyond_4g_words() {
    // Regression for the u32->u64 position widening: addressing past
    // 2^32 words must stay consistent with drawing forward from there.
    Prop::new("set_position crosses 4G words").cases(30).check3(
        Gen::u64(),
        Gen::u32_below(1 << 20),
        Gen::u32_below(40),
        |seed, off, k| {
            let base = (1u64 << 32) + off as u64;
            let mut a = Philox::new(seed, 3);
            a.set_position(base);
            for _ in 0..k {
                a.next_u32();
            }
            let mut b = Philox::new(seed, 3);
            b.set_position(base + k as u64);
            let mut t = Threefry::new(seed, 3);
            t.set_position(base);
            for _ in 0..k {
                t.next_u32();
            }
            let mut t2 = Threefry::new(seed, 3);
            t2.set_position(base + k as u64);
            a.next_u32() == b.next_u32() && t.next_u32() == t2.next_u32()
        },
    );
}

#[test]
fn prop_baseline_advance_matches_stepping() {
    // The sequential baselines' skip-ahead (lcg_skip / Weyl multiply)
    // == repeated stepping, from any phase, at random small strides.
    Prop::new("baseline advance == n steps").cases(40).check3(
        Gen::u64(),
        Gen::u32_below(7),
        Gen::u32_below(400),
        |seed, pre, n| {
            let mut pa = Pcg32::new(seed, 54);
            let mut pb = Pcg32::new(seed, 54);
            let mut la = Lcg64::new(seed);
            let mut lb = Lcg64::new(seed);
            let mut sa = SplitMix64::new(seed);
            let mut sb = SplitMix64::new(seed);
            for _ in 0..pre {
                pa.next_u32();
                pb.next_u32();
                la.next_u32();
                lb.next_u32();
                sa.next_u32();
                sb.next_u32();
            }
            pa.advance(n as u64);
            la.advance(n as u64);
            sa.advance(n as u64);
            for _ in 0..n {
                pb.next_u32();
                lb.next_u32();
                sb.next_u32();
            }
            pa.next_u32() == pb.next_u32()
                && la.next_u32() == lb.next_u32()
                && sa.next_u32() == sb.next_u32()
        },
    );
}

#[test]
fn prop_draws_in_unit_interval() {
    Prop::new("draw_double in [0,1)").cases(100).check2(Gen::u64(), Gen::u32(), |seed, ctr| {
        let mut r = TycheI::new(seed, ctr);
        (0..32).all(|_| {
            let d = r.draw_double();
            (0.0..1.0).contains(&d)
        })
    });
}

#[test]
fn prop_range_u32_bounds() {
    Prop::new("range_u32 < bound").cases(200).check3(
        Gen::u64(),
        Gen::u32(),
        Gen::u32(),
        |seed, ctr, bound| {
            let bound = bound.max(1);
            let mut r = Philox::new(seed, ctr);
            (0..16).all(|_| r.range_u32(bound) < bound)
        },
    );
}

#[test]
fn prop_range_u32_edge_bounds() {
    // The Lemire rejection path at its extremes: bound = 1 (always 0),
    // bound = u32::MAX, and exact powers of two (where the rejection
    // threshold `(-bound) % bound` is 0 and no retry can occur).
    let edges: Vec<u32> =
        std::iter::once(1).chain((0..32).map(|e| 1u32 << e)).chain([u32::MAX, u32::MAX - 1]).collect();
    Prop::new("range_u32 edge bounds").cases(60).check2(Gen::u64(), Gen::u32(), |seed, ctr| {
        let mut r = Philox::new(seed, ctr);
        edges.iter().all(|&bound| {
            let v = r.range_u32(bound);
            v < bound && (bound != 1 || v == 0)
        })
    });
}

#[test]
fn prop_range_u32_powers_of_two_consume_one_word() {
    // Power-of-two bounds never reject, so each call consumes exactly
    // one stream word and stays in lockstep with raw next_u32 draws.
    Prop::new("pow2 range_u32 word-lockstep").cases(60).check2(
        Gen::u64(),
        Gen::u32_below(32),
        |seed, shift| {
            let bound = 1u32 << shift;
            let mut a = Philox::new(seed, 3);
            let mut b = Philox::new(seed, 3);
            for _ in 0..8 {
                let _ = a.range_u32(bound);
                let _ = b.next_u32();
            }
            a.next_u32() == b.next_u32()
        },
    );
}

/// Bitwise sample fingerprints from a fresh engine for every
/// distribution the `dist` subsystem ships (f64 bits, or the integer
/// sample widened), in a fixed interleaved order.
fn dist_fingerprint<G: CounterRng>(seed: u64, ctr: u32, n: usize) -> Vec<u64> {
    let mut rng = G::new(seed, ctr);
    let uni = Uniform::new(-2.0, 5.0);
    let bm = BoxMuller::standard();
    let zig = ZigguratNormal::standard();
    let expo = Exponential::new(0.8);
    let pois_small = Poisson::new(3.5);
    let pois_large = Poisson::new(30.0);
    let bern = Bernoulli::new(0.25);
    let bino = Binomial::new(9, 0.6);
    let alias = DiscreteAlias::new(&[0.1, 0.2, 0.3, 0.4]);
    let mut out = Vec::with_capacity(9 * n);
    for _ in 0..n {
        out.push(uni.sample(&mut rng).to_bits());
        out.push(bm.sample(&mut rng).to_bits());
        out.push(zig.sample(&mut rng).to_bits());
        out.push(expo.sample(&mut rng).to_bits());
        out.push(pois_small.sample(&mut rng));
        out.push(pois_large.sample(&mut rng));
        out.push(bern.sample(&mut rng) as u64);
        out.push(bino.sample(&mut rng));
        out.push(alias.sample(&mut rng) as u64);
    }
    out
}

#[test]
fn prop_distribution_determinism_all_engines() {
    // The tentpole reproducibility property: same (seed, ctr) =>
    // bitwise-identical samples across two fresh engines, for every
    // distribution, even the variable-word-consumption ones.
    Prop::new("dist samples replay bitwise").cases(25).check2(
        Gen::u64(),
        Gen::u32(),
        |seed, ctr| {
            dist_fingerprint::<Philox>(seed, ctr, 8) == dist_fingerprint::<Philox>(seed, ctr, 8)
                && dist_fingerprint::<Squares>(seed, ctr, 8)
                    == dist_fingerprint::<Squares>(seed, ctr, 8)
                && dist_fingerprint::<Tyche>(seed, ctr, 8)
                    == dist_fingerprint::<Tyche>(seed, ctr, 8)
        },
    );
}

#[test]
fn prop_distribution_seed_sensitivity() {
    // Different seeds must decorrelate the sampled sequences too.
    Prop::new("dist samples differ across seeds").cases(25).check2(
        Gen::u64(),
        Gen::u64(),
        |a, b| {
            a == b || dist_fingerprint::<Philox>(a, 0, 4) != dist_fingerprint::<Philox>(b, 0, 4)
        },
    );
}

#[test]
fn prop_fill_equals_sequential() {
    Prop::new("fill_u32 == repeated next_u32").cases(60).check3(
        Gen::u64(),
        Gen::u32_below(7),
        Gen::u32_below(70),
        |seed, pre, len| {
            let mut a = Threefry::new(seed, 1);
            let mut b = Threefry::new(seed, 1);
            for _ in 0..pre {
                a.next_u32();
                b.next_u32();
            }
            let mut buf = vec![0u32; len as usize];
            a.fill_u32(&mut buf);
            buf.iter().all(|&w| w == b.next_u32()) && a.next_u32() == b.next_u32()
        },
    );
}

#[test]
fn prop_generate_block_equals_serial_draws() {
    // The BlockRng contract (docs/stream-contracts.md §3): for every
    // core generator and any stream phase, generate_block yields exactly
    // the next WORDS_PER_BLOCK next_u32 draws, and leaves the stream in
    // lockstep afterwards.
    fn check<G: BlockRng>(seed: u64, ctr: u32, pre: u32) -> bool {
        let mut a = G::new(seed, ctr);
        let mut b = G::new(seed, ctr);
        for _ in 0..pre {
            a.next_u32();
            b.next_u32();
        }
        for _ in 0..3 {
            let mut blk = G::Block::default();
            a.generate_block(&mut blk);
            if blk.as_ref().iter().any(|&w| w != b.next_u32()) {
                return false;
            }
        }
        a.next_u32() == b.next_u32()
    }
    Prop::new("generate_block == W next_u32 draws").cases(40).check3(
        Gen::u64(),
        Gen::u32(),
        Gen::u32_below(9),
        |seed, ctr, pre| {
            check::<Philox>(seed, ctr, pre)
                && check::<Philox2x32>(seed, ctr, pre)
                && check::<Threefry>(seed, ctr, pre)
                && check::<Threefry2x32>(seed, ctr, pre)
                && check::<Squares>(seed, ctr, pre)
                && check::<Tyche>(seed, ctr, pre)
                && check::<TycheI>(seed, ctr, pre)
        },
    );
}

#[test]
fn prop_block_buffered_adapter_is_transparent() {
    // The safe buffered adapter preserves word-at-a-time semantics
    // bit-identically over any BlockRng.
    Prop::new("BlockBuffered == raw engine stream").cases(40).check2(
        Gen::u64(),
        Gen::u32(),
        |seed, ctr| {
            let mut raw4 = Threefry::new(seed, ctr);
            let mut ad4 = BlockBuffered::<Threefry>::new(seed, ctr);
            let mut raw1 = Squares::new(seed, ctr);
            let mut ad1 = BlockBuffered::<Squares>::new(seed, ctr);
            (0..24).all(|_| raw4.next_u32() == ad4.next_u32() && raw1.next_u32() == ad1.next_u32())
        },
    );
}

#[test]
fn prop_parallel_fill_bitwise_thread_invariant() {
    // The fill-engine contract (docs/stream-contracts.md §4): par_fill
    // output equals the serial word-at-a-time stream for 1, 2, and 8
    // threads, for u32 and f64, on counter engines and the sequential
    // Tyche alike.
    fn check<G: BlockRng>(seed: u64, ctr: u32, n: usize) -> bool {
        let words: Vec<u32> = {
            let mut g = G::new(seed, ctr);
            (0..n).map(|_| g.next_u32()).collect()
        };
        let doubles: Vec<u64> = {
            let mut g = G::new(seed, ctr);
            (0..n / 2).map(|_| g.draw_double().to_bits()).collect()
        };
        for threads in [1usize, 2, 8] {
            let mut out = vec![0u32; n];
            fill::par_fill_u32::<G>(seed, ctr, &mut out, threads);
            if out != words {
                return false;
            }
            let mut fout = vec![0.0f64; n / 2];
            fill::par_fill_f64::<G>(seed, ctr, &mut fout, threads);
            if fout.iter().map(|v| v.to_bits()).ne(doubles.iter().copied()) {
                return false;
            }
        }
        true
    }
    Prop::new("par fill bitwise thread-invariant").cases(12).check2(
        Gen::u64(),
        Gen::usize_in(1, 300),
        |seed, n| {
            check::<Philox>(seed, 1, n) && check::<Squares>(seed, 1, n) && check::<Tyche>(seed, 1, n)
        },
    );
}

#[test]
fn prop_streamkey_raw_equals_counter_rng_all_engines() {
    // The facade's zero-drift guarantee, property-tested over random
    // (seed, ctr) for all 7 engines: StreamKey::raw streams are
    // byte-identical to CounterRng::new streams.
    Prop::new("StreamKey::raw == CounterRng::new").cases(40).check2(
        Gen::u64(),
        Gen::u32(),
        |seed, ctr| {
            openrand::core::Generator::ALL.iter().all(|&g| {
                let mut keyed = DynStream::open(g, StreamKey::raw(seed, ctr));
                let mut legacy = g.boxed(seed, ctr);
                (0..32).all(|_| keyed.next_u32() == legacy.next_u32())
            })
        },
    );
}

#[test]
fn prop_streamkey_child_ids_distinct() {
    // Distinct child ids under the same parent derive distinct (seed,
    // ctr) addresses — guaranteed (the mix is bijective in the id for a
    // fixed parent), so this must hold for EVERY pair, not just
    // overwhelmingly often.
    Prop::new("distinct child ids -> distinct keys").cases(120).check3(
        Gen::u64(),
        Gen::u64(),
        Gen::u64(),
        |parent_seed, a, b| {
            let parent = StreamKey::root(parent_seed);
            a == b || parent.child(a) != parent.child(b)
        },
    );
}

#[test]
fn prop_streamkey_epoch_absolute_and_child_path_dependent() {
    Prop::new("epoch last-wins; child mixes parent ctr").cases(80).check3(
        Gen::u64(),
        Gen::u32(),
        Gen::u32(),
        |seed, t1, t2| {
            let k = StreamKey::root(seed);
            // Documented order independence: epoch is absolute.
            let absolute = k.epoch(t1).epoch(t2) == k.epoch(t2)
                && k.epoch(t2) == StreamKey::raw(seed, t2);
            // Child derivation sees the parent epoch (separate spaces).
            let separated = t1 == t2 || k.epoch(t1).child(5) != k.epoch(t2).child(5);
            // And the mix is the single normative function.
            let normative = k.epoch(t1).child(9).seed() == derive_child_seed(seed, t1, 9);
            absolute && separated && normative
        },
    );
}

#[test]
fn prop_streamkey_path_roundtrip() {
    // The CLI path spelling parses back to the structural derivation.
    Prop::new("parse_path == root().child().epoch()").cases(80).check3(
        Gen::u64(),
        Gen::u64(),
        Gen::u32(),
        |seed, child, epoch| {
            let spec = format!("{seed}/c{child}/e{epoch}");
            StreamKey::parse_path(&spec).unwrap() == StreamKey::root(seed).child(child).epoch(epoch)
        },
    );
}

#[test]
fn prop_stream_facade_draws_match_engine() {
    // One handle, same words: scalar draws through Stream<E> equal the
    // raw engine, and the key-addressed bulk fill equals the serial
    // fill contract.
    Prop::new("Stream<E> == raw engine").cases(40).check2(Gen::u64(), Gen::u32(), |seed, ctr| {
        let key = StreamKey::raw(seed, ctr);
        let mut s = Stream::<Philox>::new(key);
        let mut e = Philox::new(seed, ctr);
        if (0..16).any(|_| s.next_u32() != e.next_u32()) {
            return false;
        }
        let mut bulk = vec![0u32; 64];
        s.fill_u32(None, &mut bulk).unwrap();
        bulk == stream::<Philox>(seed, ctr, 64)
    });
}

#[test]
fn prop_stream_nonoverlap_window() {
    // Distinct (seed, ctr) streams share no 4-word window in their first
    // 64 words (overlap would be a catastrophic counter-layout bug; for
    // honest 128-bit block space the collision probability is ~0).
    Prop::new("no 4-word window overlap").cases(30).check2(Gen::u64(), Gen::u64(), |s1, s2| {
        if s1 == s2 {
            return true;
        }
        let a = stream::<Philox>(s1, 0, 64);
        let b = stream::<Philox>(s2, 0, 64);
        for wa in a.windows(4) {
            for wb in b.windows(4) {
                if wa == wb {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn prop_campaign_resume_bitwise() {
    // The PR-8 acceptance property: resume-from-checkpoint is
    // byte-identical to the uninterrupted run, for random (seed, n,
    // split point), across thread counts {1, 2, 8} on both sides of the
    // split, and across explicit host/par fill-backend arms. The tile
    // is kept small so even tiny n exercises multi-tile stripes.
    use openrand::backend::{FillBackend, HostParallel, HostSerial};
    use openrand::campaign::{Campaign, CampaignParams, Checkpoint, Model};

    const TILE: usize = 128;
    const TOTAL: u32 = 10;
    Prop::new("campaign resume == never-stopped (bitwise)").cases(6).check3(
        Gen::u64(),
        Gen::usize_in(64, 700),
        Gen::usize_in(1, TOTAL as usize),
        |seed, n, split| {
            let params = |threads: usize| {
                let mut p = CampaignParams::new(Model::Brownian, n, StreamKey::root(seed));
                p.tile = TILE;
                p.threads = threads;
                p
            };
            // Reference: uninterrupted serial run.
            let mut full = Campaign::new(params(1)).unwrap();
            full.run_to(TOTAL).unwrap();
            let want = full.checkpoint().encode();

            for head_threads in [1usize, 2, 8] {
                let mut head = Campaign::new(params(head_threads)).unwrap();
                head.run_to(split as u32).unwrap();
                // Round-trip through the byte format, as a real pause would.
                let mid = Checkpoint::decode(&head.checkpoint().encode()).unwrap();
                for tail_threads in [1usize, 2, 8] {
                    let mut tail = Campaign::resume(&mid, tail_threads).unwrap();
                    tail.run_to(TOTAL).unwrap();
                    if tail.checkpoint().encode() != want {
                        return false;
                    }
                }
            }

            // Explicit fill-backend arms: HostSerial and HostParallel
            // must drive the identical trajectory as the default path.
            for backend in [
                &mut HostSerial as &mut dyn FillBackend,
                &mut HostParallel::new(4) as &mut dyn FillBackend,
            ] {
                let mut c = Campaign::new(params(1)).unwrap();
                while c.epoch() < TOTAL {
                    c.step_with(backend).unwrap();
                }
                if c.checkpoint().encode() != want {
                    return false;
                }
            }
            true
        },
    );
}

} // mod std_properties
