//! `openrand serve` end-to-end tests: the determinism property (every
//! concurrent client's bytes equal a fresh single-threaded `Stream`
//! replay, across cache sizes including zero), typed BUSY backpressure,
//! STATS content, clean shutdown, and the CLI serve/fetch round trip.
//!
//! The reference replay below is built exclusively from the public
//! word-level primitives (`Generator::boxed_at` + the §2 conversion
//! helpers + `BoxMuller::transform_words`), so agreement with the
//! server is a real cross-implementation check, not the serve code
//! testing itself.

use std::io::{BufRead, BufReader};
use std::net::TcpStream;
use std::process::{Command, Stdio};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use openrand::backend::{HostParallel, HostSerial};
use openrand::core::traits::{u01_f32, u01_f64, u64_from_words};
use openrand::core::{CounterRng, Generator, Philox, Rng as _};
use openrand::dist::BoxMuller;
use openrand::serve::proto::{decode_reply, read_frame, MAX_REPLY_FRAME};
use openrand::serve::{
    resolve_key, Client, FillRequest, Metrics, PayloadKind, Reply, Request, ServeConfig, Server,
    StreamService,
};

/// Single-threaded replay of one FILL request: position a boxed engine
/// at the request's first stream word, pull the raw words, and apply
/// the normative conversions element by element.
fn reference(req: &FillRequest) -> Vec<u8> {
    let key = resolve_key(req.tenant, &req.path).expect("valid key");
    let wpe = req.kind.words_per_elem();
    let n = req.len as usize;
    let first_word = req.offset as usize * wpe;
    let mut words = vec![0u32; n * wpe];
    let mut rng = req.gen.boxed_at(key.seed(), key.ctr(), first_word as u64);
    rng.fill_u32(&mut words);
    let mut out = Vec::with_capacity(n * req.kind.bytes_per_elem());
    match req.kind {
        PayloadKind::U32 => {
            for w in &words {
                out.extend_from_slice(&w.to_le_bytes());
            }
        }
        PayloadKind::U64 => {
            for pair in words.chunks_exact(2) {
                out.extend_from_slice(&u64_from_words(pair[0], pair[1]).to_le_bytes());
            }
        }
        PayloadKind::F32 => {
            for &w in &words {
                out.extend_from_slice(&u01_f32(w).to_le_bytes());
            }
        }
        PayloadKind::F64 => {
            for pair in words.chunks_exact(2) {
                out.extend_from_slice(&u01_f64(pair[0], pair[1]).to_le_bytes());
            }
        }
        PayloadKind::Normal => {
            let mut tmp = vec![0.0f64; n];
            BoxMuller::standard().transform_words(&words, &mut tmp);
            for v in &tmp {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
    out
}

fn start(cache_blocks: usize, workers: usize, queue: usize) -> Server {
    Server::start(ServeConfig {
        addr: "127.0.0.1:0".into(),
        workers,
        queue,
        cache_blocks,
        fill_threads: 2,
        metrics_interval: None,
    })
    .expect("server starts")
}

/// The headline property: N concurrent clients with randomized request
/// interleavings all read bytes identical to the single-threaded
/// replay — for a cache-off, a thrashing-small, and a comfortable cache.
#[test]
fn concurrent_clients_match_single_threaded_replay() {
    const CLIENTS: u64 = 6;
    const REQUESTS: usize = 12;
    for cache_blocks in [0usize, 2, 256] {
        let mut server = start(cache_blocks, 4, 64);
        let addr = server.local_addr();
        let handles: Vec<_> = (0..CLIENTS)
            .map(|id| {
                thread::spawn(move || {
                    let paths = ["", "c3", "c3/e1", "c5"];
                    let gens = [Generator::Philox, Generator::Threefry, Generator::Squares];
                    // Deterministic per-client randomization: a Philox
                    // stream keyed by the client id drives the request
                    // parameters, so interleavings differ across
                    // clients but the workload is replayable.
                    let mut r = Philox::new(0xD1CE, id as u32);
                    let mut client = Client::connect(addr).expect("connect");
                    for i in 0..REQUESTS {
                        let req = if i % 4 == 0 {
                            // Shared hot request: every client asks for
                            // the same span concurrently, which is what
                            // exercises coalescing and cache hits.
                            FillRequest {
                                tenant: 7,
                                path: "c3".into(),
                                gen: Generator::Philox,
                                kind: PayloadKind::U32,
                                offset: 0,
                                len: 2048,
                            }
                        } else {
                            FillRequest {
                                tenant: 7 + (r.next_u32() as u64 % 2) * 2,
                                path: paths[r.next_u32() as usize % paths.len()].into(),
                                gen: gens[r.next_u32() as usize % gens.len()],
                                kind: PayloadKind::ALL[r.next_u32() as usize % 5],
                                offset: (r.next_u32() % 3000) as u64,
                                len: 1 + r.next_u32() % 700,
                            }
                        };
                        let got = client.fill(&req).expect("fill succeeds");
                        assert_eq!(
                            got,
                            reference(&req),
                            "client {id} request {i} diverged (cache={cache_blocks}, req={req:?})"
                        );
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("client thread");
        }
        let m = server.metrics();
        use std::sync::atomic::Ordering;
        assert_eq!(
            m.requests.load(Ordering::Relaxed),
            CLIENTS * REQUESTS as u64,
            "cache={cache_blocks}"
        );
        assert_eq!(m.errors.load(Ordering::Relaxed), 0);
        if cache_blocks > 0 {
            // The shared hot request guarantees reuse one way or the
            // other: a later asker either hit the cache or coalesced
            // onto an in-flight fill.
            let reused = m.cache_hits.load(Ordering::Relaxed) + m.coalesced.load(Ordering::Relaxed);
            assert!(reused > 0, "no reuse observed with cache={cache_blocks}");
        } else {
            assert_eq!(m.cache_hits.load(Ordering::Relaxed), 0);
            assert_eq!(m.coalesced.load(Ordering::Relaxed), 0);
        }
        server.shutdown();
    }
}

/// Satellite 3's property, at the service level and on both host arms:
/// cache hits are byte-identical to uncached backend fills at arbitrary
/// offsets. Every request runs twice (miss path, then hit path) against
/// a cache-off service and the replay reference.
#[test]
fn cache_hits_byte_identical_to_uncached_fills() {
    let cached = StreamService::new(8, Arc::new(Metrics::new()));
    let uncached = StreamService::new(0, Arc::new(Metrics::new()));
    let mut serial = HostSerial;
    let mut par = HostParallel::new(3);
    let mut r = Philox::new(0xCAC4E, 0);
    for i in 0..40 {
        let req = FillRequest {
            tenant: 11,
            path: if i % 3 == 0 { "c1/e2".into() } else { String::new() },
            gen: Generator::Philox,
            kind: PayloadKind::ALL[r.next_u32() as usize % 5],
            offset: (r.next_u32() % 20_000) as u64,
            len: 1 + r.next_u32() % 1500,
        };
        let want = reference(&req);
        let miss = cached.serve_fill(&mut serial, &req).expect("miss fill");
        let hit = cached.serve_fill(&mut serial, &req).expect("hit fill");
        let hit_par = cached.serve_fill(&mut par, &req).expect("hit fill (par)");
        let plain = uncached.serve_fill(&mut par, &req).expect("uncached fill");
        assert_eq!(miss, want, "request {i}: miss path diverged ({req:?})");
        assert_eq!(hit, want, "request {i}: hit path diverged ({req:?})");
        assert_eq!(hit_par, want, "request {i}: par hit diverged ({req:?})");
        assert_eq!(plain, want, "request {i}: passthrough diverged ({req:?})");
    }
    use std::sync::atomic::Ordering;
    let m = cached.metrics();
    assert!(m.cache_hits.load(Ordering::Relaxed) > 0, "hit path never exercised");
    assert_eq!(uncached.metrics().cache_hits.load(Ordering::Relaxed), 0);
}

/// Backpressure: with one worker and a one-deep queue, a third
/// connection gets a typed BUSY reply at admission — and the shed never
/// corrupts the parked clients' streams.
#[test]
fn busy_shed_is_typed_and_never_corrupts_other_streams() {
    let mut server = start(16, 1, 1);
    let addr = server.local_addr();
    let req = FillRequest {
        tenant: 7,
        path: "c3/e1".into(),
        gen: Generator::Philox,
        kind: PayloadKind::U64,
        offset: 5,
        len: 64,
    };
    // A occupies the single worker (held through handle_conn between
    // frames after its first reply)...
    let mut a = Client::connect(addr).expect("connect A");
    assert_eq!(a.fill(&req).expect("A fill"), reference(&req));
    // ...B occupies the single queue slot (accepted, never dequeued
    // while A's connection is open)...
    let b = TcpStream::connect(addr).expect("connect B");
    // ...so C must be shed with a typed BUSY frame written at accept
    // time. Poll until the accept thread has processed B and C in
    // order; each probe is its own connection.
    let mut shed = false;
    for _ in 0..100 {
        let mut c = TcpStream::connect(addr).expect("connect C");
        let frame = read_frame(&mut c, MAX_REPLY_FRAME).expect("read C");
        if let Some(payload) = frame {
            if decode_reply(&payload).expect("decode C") == Reply::Busy {
                shed = true;
                break;
            }
        }
        thread::sleep(Duration::from_millis(10));
    }
    assert!(shed, "never observed a BUSY shed");
    use std::sync::atomic::Ordering;
    assert!(server.metrics().shed.load(Ordering::Relaxed) >= 1);
    // A's stream is unharmed: same connection, next span still exact.
    let req2 = FillRequest { offset: 69, len: 33, ..req.clone() };
    assert_eq!(a.fill(&req2).expect("A fill 2"), reference(&req2));
    // Release the worker; B gets dequeued and served byte-identically.
    drop(a);
    let mut b = Client::from_stream(b);
    assert_eq!(b.fill(&req).expect("B fill"), reference(&req));
    server.shutdown();
}

/// STATS reflects traffic, and a SHUTDOWN request stops the daemon
/// (both threads join; `shutdown()` afterwards is an idempotent no-op).
#[test]
fn stats_reports_counters_and_shutdown_is_clean() {
    let mut server = start(64, 2, 8);
    let addr = server.local_addr();
    let mut client = Client::connect(addr).expect("connect");
    let req = FillRequest {
        tenant: 3,
        path: "c1".into(),
        gen: Generator::Tyche,
        kind: PayloadKind::F32,
        offset: 0,
        len: 100,
    };
    client.fill(&req).expect("fill");
    client.fill(&req).expect("refill");
    let stats = client.stats().expect("stats");
    for needle in
        ["requests=2", "cache_hits=", "cache_hit_ratio=", "queue_depth=", "shed=0", "errors=0"]
    {
        assert!(stats.contains(needle), "missing `{needle}` in:\n{stats}");
    }
    client.shutdown().expect("shutdown handshake");
    server.join();
    server.shutdown();
}

/// Malformed bytes get an ERROR reply (counted, connection dropped) and
/// the server keeps serving well-formed clients afterwards.
#[test]
fn bad_request_gets_error_reply_and_server_survives() {
    let mut server = start(16, 2, 8);
    let addr = server.local_addr();
    let mut evil = Client::connect(addr).expect("connect evil");
    let rep = evil.request(&Request::Fill(FillRequest {
        tenant: 1,
        path: "x9".into(), // bad segment grammar
        gen: Generator::Philox,
        kind: PayloadKind::U32,
        offset: 0,
        len: 1,
    }));
    match rep.expect("transport ok") {
        Reply::Error(msg) => assert!(msg.contains("x9"), "{msg}"),
        other => panic!("expected ERROR, got {other:?}"),
    }
    let req = FillRequest {
        tenant: 1,
        path: String::new(),
        gen: Generator::Philox,
        kind: PayloadKind::U32,
        offset: 0,
        len: 16,
    };
    let mut fine = Client::connect(addr).expect("connect fine");
    assert_eq!(fine.fill(&req).expect("fill"), reference(&req));
    use std::sync::atomic::Ordering;
    assert!(server.metrics().errors.load(Ordering::Relaxed) >= 1);
    server.shutdown();
}

/// CLI round trip: `openrand serve` on an ephemeral port, `fetch` the
/// keyed stream, byte-compare against `generate --key`, then a clean
/// `fetch --shutdown`. This is the CI smoke in test form.
#[test]
fn cli_fetch_matches_generate() {
    let bin = env!("CARGO_BIN_EXE_openrand");
    let mut server = Command::new(bin)
        .args(["serve", "--addr", "127.0.0.1:0", "--workers", "2", "--cache-blocks", "64"])
        .stdout(Stdio::piped())
        .spawn()
        .expect("serve spawns");
    let mut line = String::new();
    BufReader::new(server.stdout.take().expect("stdout piped"))
        .read_line(&mut line)
        .expect("banner line");
    let addr = line.trim().strip_prefix("serving on ").expect("banner format").to_string();
    let run = |args: &[&str]| {
        let out = Command::new(bin).args(args).output().expect("runs");
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        String::from_utf8_lossy(&out.stdout).to_string()
    };
    for (format, dist_args) in [
        ("f64", vec!["--format", "f64"]),
        ("u32", vec!["--format", "u32"]),
        ("normal", vec!["--dist", "normal"]),
    ] {
        let mut gen_args = vec!["generate", "--key", "7/c3/e1", "--n", "64"];
        gen_args.extend(dist_args);
        let want = run(&gen_args);
        let got = run(&[
            "fetch", "--addr", &addr, "--key", "7/c3/e1", "--n", "64", "--format", format,
        ]);
        assert_eq!(got, want, "fetch/{format} diverged from generate");
    }
    let stats = run(&["fetch", "--addr", &addr, "--stats"]);
    assert!(stats.contains("requests="), "{stats}");
    run(&["fetch", "--addr", &addr, "--shutdown"]);
    let status = server.wait().expect("serve exits");
    assert!(status.success(), "serve exited uncleanly: {status:?}");
}
