//! Backend-equivalence suite: every fill backend arm must produce the
//! same bytes as the serial host reference for the same
//! `(gen, seed, ctr, len)` — the `openrand::backend` contract
//! (`docs/backends.md`).
//!
//! Host arms are property-tested across random tuples; the device arm
//! gets a KAT that self-skips on fresh checkouts (no artifacts / PJRT
//! stub) and hard-fails under `OPENRAND_REQUIRE_ARTIFACTS=1`, exactly
//! like the cross-layer suite.

use openrand::backend::{
    self, Auto, BackendKind, CrossoverTable, DeviceFill, FillBackend, HostParallel, HostSerial,
};
use openrand::core::{fill, Generator};
use openrand::coordinator::repro;
use openrand::testing::prop::{Gen, Prop};

fn serial_words(gen: Generator, seed: u64, ctr: u32, n: usize) -> Vec<u32> {
    let mut out = vec![0u32; n];
    fill::fill_u32_gen(gen, seed, ctr, &mut out);
    out
}

#[test]
fn prop_host_parallel_equals_serial_bytes() {
    // The satellite property: HostParallel == HostSerial byte-for-byte
    // across random (seed, ctr, len) tuples and a thread ladder.
    Prop::new("par backend == serial backend bytes").cases(30).check3(
        Gen::u64(),
        Gen::u32(),
        Gen::usize_in(0, 3000),
        |seed, ctr, len| {
            for gen in [Generator::Philox, Generator::Squares, Generator::TycheI] {
                let want = serial_words(gen, seed, ctr, len);
                for threads in [1usize, 2, 5, 8] {
                    let mut got = vec![0u32; len];
                    HostParallel::new(threads).fill_u32(gen, seed, ctr, &mut got).unwrap();
                    if got != want {
                        return false;
                    }
                }
            }
            true
        },
    );
}

#[test]
fn prop_typed_fills_equal_across_host_arms() {
    Prop::new("typed par fills == serial fills bytes").cases(20).check3(
        Gen::u64(),
        Gen::u32(),
        Gen::usize_in(0, 1500),
        |seed, ctr, len| {
            let gen = Generator::Threefry;
            let bits64 = |v: &[u64]| v.to_vec();
            let bitsf = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            let bits32 = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            let mut wu = vec![0u64; len];
            HostSerial.fill_u64(gen, seed, ctr, &mut wu).unwrap();
            let mut wf = vec![0.0f64; len];
            HostSerial.fill_f64(gen, seed, ctr, &mut wf).unwrap();
            let mut ws = vec![0.0f32; len];
            HostSerial.fill_f32(gen, seed, ctr, &mut ws).unwrap();
            for threads in [2usize, 7] {
                let mut b = HostParallel::new(threads);
                let mut gu = vec![0u64; len];
                b.fill_u64(gen, seed, ctr, &mut gu).unwrap();
                let mut gf = vec![0.0f64; len];
                b.fill_f64(gen, seed, ctr, &mut gf).unwrap();
                let mut gs = vec![0.0f32; len];
                b.fill_f32(gen, seed, ctr, &mut gs).unwrap();
                if bits64(&gu) != bits64(&wu)
                    || bitsf(&gf) != bitsf(&wf)
                    || bits32(&gs) != bits32(&ws)
                {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn prop_auto_equals_serial_bytes() {
    // Auto must match the serial reference no matter which arm its
    // table picks (device degradation included).
    // Few cases: each constructs an Auto (and on real-artifact builds,
    // probes/compiles the device graph).
    let table = CrossoverTable { device_min_words: 256 };
    Prop::new("auto backend == serial backend bytes").cases(8).check3(
        Gen::u64(),
        Gen::u32(),
        Gen::usize_in(0, 2000),
        move |seed, ctr, len| {
            let mut auto = Auto::with_table(4, table);
            let mut got = vec![0u32; len];
            auto.fill_u32(Generator::Philox, seed, ctr, &mut got).unwrap();
            got == serial_words(Generator::Philox, seed, ctr, len)
        },
    );
}

/// With `OPENRAND_REQUIRE_ARTIFACTS=1` the device skips below become
/// hard failures, so a broken loader can never masquerade as a skip.
fn strict() -> bool {
    std::env::var("OPENRAND_REQUIRE_ARTIFACTS").as_deref() == Ok("1")
}

fn device() -> Option<DeviceFill> {
    match DeviceFill::try_new() {
        Ok(d) => Some(d),
        Err(e) if strict() => panic!("OPENRAND_REQUIRE_ARTIFACTS=1 but device arm failed: {e:#}"),
        Err(e) => {
            eprintln!("skipping device-arm test (run `make artifacts`): {e:#}");
            None
        }
    }
}

#[test]
fn device_arm_kat_or_skip() {
    let Some(mut dev) = device() else { return };
    // Pinned (seed, ctr) cases for every stream-ordered artifact engine,
    // at sizes below / at the artifact boundary.
    for gen in [Generator::Philox, Generator::Threefry, Generator::Squares] {
        assert!(dev.supports(gen), "{}", gen.name());
        for (seed, ctr) in [(0u64, 0u32), (42, 7), (0xDEAD_BEEF_1234_5678, 3)] {
            for n in [1usize, 5, 4096, 65_535, 65_536] {
                let mut got = vec![0u32; n];
                dev.fill_u32(gen, seed, ctr, &mut got).unwrap();
                assert_eq!(
                    got,
                    serial_words(gen, seed, ctr, n),
                    "{} seed={seed:#x} ctr={ctr} n={n}",
                    gen.name()
                );
            }
        }
    }
    // Typed conversions ride the same words.
    let mut gf = vec![0.0f64; 1000];
    dev.fill_f64(Generator::Philox, 9, 1, &mut gf).unwrap();
    let mut wf = vec![0.0f64; 1000];
    HostSerial.fill_f64(Generator::Philox, 9, 1, &mut wf).unwrap();
    assert_eq!(
        gf.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        wf.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
    );
    // The params pool kicks in on repeated fills of the same stream.
    let (_, uploads_before) = dev.pool_stats();
    let mut buf = vec![0u32; 1024];
    dev.fill_u32(Generator::Philox, 77, 7, &mut buf).unwrap();
    dev.fill_u32(Generator::Philox, 77, 7, &mut buf).unwrap();
    let (hits, uploads) = dev.pool_stats();
    assert!(uploads > uploads_before, "first fill uploads params");
    assert!(hits >= 1, "second fill reuses the pooled params buffer");
}

#[test]
fn device_arm_refuses_unsupported_engines() {
    let Some(mut dev) = device() else {
        // Stub path: the arm must fail with a diagnostic, not panic.
        let err = backend::make(BackendKind::Device, 1).err().expect("stub device unavailable");
        assert!(!format!("{err:#}").is_empty());
        return;
    };
    // Tyche graduated to the `_at` scan artifacts (PR 4 carry-over);
    // only the engines with no artifact of either family still refuse.
    let mut out = vec![0u32; 64];
    for gen in [Generator::TycheI, Generator::Philox2x32, Generator::Threefry2x32] {
        let err = dev.fill_u32(gen, 1, 0, &mut out).unwrap_err();
        assert!(
            format!("{err:#}").contains("stream-ordered"),
            "{}: {err:#}",
            gen.name()
        );
    }
}

#[test]
fn device_arm_serves_tyche_stream_order_or_skip() {
    // The former refusal path: the lane-major tyche artifact could not
    // serve stream-ordered fills, so `DeviceFill` refused the engine.
    // The `tyche_u32_at_{n}` scan artifacts lower the true sequential
    // stream; prefix fills route through them at base 0.
    let Some(mut dev) = device() else { return };
    if !dev.supports(Generator::Tyche) {
        assert!(
            !strict(),
            "OPENRAND_REQUIRE_ARTIFACTS=1 but the tyche `_at` artifacts are missing \
             (artifacts predate the offset family; re-run `make artifacts`)"
        );
        eprintln!("skipping tyche device KAT (artifacts predate the `_at` family)");
        return;
    }
    for (seed, ctr) in [(0u64, 0u32), (42, 7), (0xDEAD_BEEF_1234_5678, 3)] {
        for n in [1usize, 5, 4096] {
            let mut got = vec![0u32; n];
            dev.fill_u32(Generator::Tyche, seed, ctr, &mut got).unwrap();
            assert_eq!(
                got,
                serial_words(Generator::Tyche, seed, ctr, n),
                "tyche seed={seed:#x} ctr={ctr} n={n}"
            );
        }
    }
}

#[test]
fn device_offset_artifact_kat_or_skip() {
    // The offset-fill KAT: `fill_u32_at(gen, seed, ctr, start, out)`
    // through the `{gen}_u32_at_{n}` artifacts must be bitwise the
    // `[start..]` slice of the serial prefix fill (§4 offset-fill
    // layout), including starts that are not block-aligned (the skip
    // path) and engines whose base counts 4-word blocks.
    let Some(mut dev) = device() else { return };
    let engines =
        [Generator::Philox, Generator::Threefry, Generator::Squares, Generator::Tyche];
    for gen in engines {
        if !dev.supports_fill_at(gen, 4, 64) {
            assert!(
                !strict(),
                "OPENRAND_REQUIRE_ARTIFACTS=1 but the '{}' `_at` artifacts are missing \
                 (re-run `make artifacts`)",
                gen.name()
            );
            eprintln!("skipping {} offset KAT (no `_at` artifacts)", gen.name());
            continue;
        }
        for (seed, ctr) in [(7u64, 1u32), (0xDEAD_BEEF_1234_5678, 3)] {
            // Unaligned and aligned starts; spans crossing the artifact
            // pick boundary.
            for (start, n) in [(1u64, 63usize), (3, 500), (4, 4096), (1027, 1), (65_000, 1000)] {
                let whole = serial_words(gen, seed, ctr, start as usize + n);
                let mut got = vec![0u32; n];
                dev.fill_u32_at(gen, seed, ctr, start, &mut got).unwrap();
                assert_eq!(
                    got,
                    whole[start as usize..],
                    "{} seed={seed:#x} ctr={ctr} start={start} n={n}",
                    gen.name()
                );
            }
        }
    }
    // Beyond-period starts: squares wraps (its stream period is 2^32
    // words), the others refuse rather than alias.
    if dev.supports_fill_at(Generator::Tyche, 4, 64) {
        let mut out = vec![0u32; 8];
        assert!(dev.fill_u32_at(Generator::Tyche, 1, 0, 1u64 << 32, &mut out).is_err());
    }
}

#[test]
fn backend_invariance_ladder_passes() {
    // The acceptance ladder at test scale: host / par{1,2,8} / device
    // (when available) / auto, byte-compared.
    for gen in [Generator::Philox, Generator::Squares] {
        let r = repro::verify_backend_invariance(gen, 30_000, 0xACC3_97, 5, 8);
        assert!(r.consistent, "{}", r.render());
    }
}
