//! CLI integration tests: drive the real `openrand` binary end to end
//! (cargo exposes the path via CARGO_BIN_EXE_openrand).

use std::process::Command;

fn openrand(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_openrand"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("binary runs");
    (
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
        out.status.success(),
    )
}

#[test]
fn help_lists_commands_and_options() {
    let (stdout, _, ok) = openrand(&["--help"]);
    assert!(ok);
    for needle in ["generate", "brownian", "stats", "repro", "artifacts", "--generator", "--seed"] {
        assert!(stdout.contains(needle), "missing {needle}");
    }
}

#[test]
fn generate_is_deterministic_and_formatted() {
    let (a, _, ok) = openrand(&["generate", "--generator", "squares", "--seed", "42", "--n", "5"]);
    assert!(ok);
    let (b, _, _) = openrand(&["generate", "--generator", "squares", "--seed", "42", "--n", "5"]);
    assert_eq!(a, b);
    assert_eq!(a.lines().count(), 5);
    for line in a.lines() {
        line.parse::<u32>().expect("u32 output");
    }
    // f64 format stays in [0, 1).
    let (f, _, _) = openrand(&["generate", "--format", "f64", "--n", "3", "--seed", "0x1F"]);
    for line in f.lines() {
        let v: f64 = line.parse().unwrap();
        assert!((0.0..1.0).contains(&v));
    }
}

#[test]
fn generate_differs_across_generators_and_ctrs() {
    let run = |g: &str, c: &str| openrand(&["generate", "--generator", g, "--ctr", c, "--n", "4"]).0;
    assert_ne!(run("philox", "0"), run("threefry", "0"));
    assert_ne!(run("philox", "0"), run("philox", "1"));
}

#[test]
fn unknown_arguments_rejected() {
    let (_, err, ok) = openrand(&["generate", "--bogus", "1"]);
    assert!(!ok);
    assert!(err.contains("unknown option"));
    let (_, err, ok) = openrand(&["teleport"]);
    assert!(!ok);
    assert!(err.contains("unknown command"));
    let (_, err, ok) = openrand(&["generate", "--generator", "mt19937x"]);
    assert!(!ok);
    assert!(err.contains("unknown generator"));
}

#[test]
fn brownian_host_reports_metrics_and_hash() {
    let (out, err, ok) = openrand(&["brownian", "--n", "1k", "--steps", "5", "--threads", "2"]);
    assert!(ok, "{err}");
    assert!(out.contains("throughput="));
    assert!(out.contains("trajectory hash:"));
    // Hash is thread-count invariant.
    let (out1, _, _) = openrand(&["brownian", "--n", "1k", "--steps", "5", "--threads", "1"]);
    let hash = |s: &str| s.lines().find(|l| l.contains("hash")).unwrap().to_string();
    assert_eq!(hash(&out), hash(&out1));
}

#[test]
fn artifacts_lists_manifest() {
    let (out, err, ok) = openrand(&["artifacts"]);
    assert!(ok, "{err}");
    assert!(out.contains("brownian_step_16384"));
    assert!(out.contains("philox_u32_65536"));
}

#[test]
fn stats_quick_battery_passes() {
    let (out, err, ok) = openrand(&["stats", "--generator", "squares", "--words", "64k"]);
    assert!(ok, "{err}");
    assert!(out.contains("battery: squares"));
    assert!(out.contains("0 failures"), "{out}");
}
