//! CLI integration tests: drive the real `openrand` binary end to end
//! (cargo exposes the path via CARGO_BIN_EXE_openrand).

use std::process::Command;

fn openrand(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_openrand"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("binary runs");
    (
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
        out.status.success(),
    )
}

#[test]
fn help_lists_commands_and_options() {
    let (stdout, _, ok) = openrand(&["--help"]);
    assert!(ok);
    for needle in [
        "generate", "brownian", "stats", "repro", "artifacts", "serve", "fetch", "campaign",
        "--generator", "--seed",
    ] {
        assert!(stdout.contains(needle), "missing {needle}");
    }
}

#[test]
fn campaign_run_resume_cmp_is_bitwise() {
    // The CI smoke tier in miniature: uninterrupted run vs checkpoint at
    // a mid epoch + resume (different thread count) — the end-state
    // checkpoint files must be byte-identical.
    let dir = std::env::temp_dir();
    let tag = std::process::id();
    let full = dir.join(format!("openrand_cli_full_{tag}.ck"));
    let mid = dir.join(format!("openrand_cli_mid_{tag}.ck"));
    let resumed = dir.join(format!("openrand_cli_resumed_{tag}.ck"));
    let base = [
        "campaign", "run", "--n", "3000", "--tile", "256", "--seed", "42", "--steps",
    ];
    let (_, err, ok) = openrand(
        &[&base[..], &["20", "--threads", "4", "--checkpoint", full.to_str().unwrap()]].concat(),
    );
    assert!(ok, "{err}");
    let (_, err, ok) = openrand(
        &[&base[..], &["9", "--checkpoint", mid.to_str().unwrap()]].concat(),
    );
    assert!(ok, "{err}");
    let (out, err, ok) = openrand(&[
        "campaign", "resume", "--from", mid.to_str().unwrap(), "--steps", "20", "--threads", "2",
        "--checkpoint", resumed.to_str().unwrap(),
    ]);
    assert!(ok, "{err}");
    assert!(out.contains("resumed from"), "{out}");
    let a = std::fs::read(&full).unwrap();
    let b = std::fs::read(&resumed).unwrap();
    for p in [&full, &mid, &resumed] {
        std::fs::remove_file(p).ok();
    }
    assert_eq!(a, b, "resumed end checkpoint diverged from uninterrupted run");
}

#[test]
fn campaign_rejects_bad_invocations() {
    // No action.
    let (_, err, ok) = openrand(&["campaign"]);
    assert!(!ok);
    assert!(err.contains("run|resume|validate"), "{err}");
    // Unknown action.
    let (_, err, ok) = openrand(&["campaign", "replay"]);
    assert!(!ok);
    assert!(err.contains("replay"), "{err}");
    // Resume without --from.
    let (_, err, ok) = openrand(&["campaign", "resume", "--steps", "10"]);
    assert!(!ok);
    assert!(err.contains("--from"), "{err}");
    // Epoch baked into the key is rejected, not silently dropped.
    let (_, err, ok) = openrand(&["campaign", "run", "--key", "7/e3", "--n", "100", "--steps", "2"]);
    assert!(!ok);
    assert!(err.contains("epoch"), "{err}");
    // A corrupt checkpoint is a typed decode error, not a panic.
    let dir = std::env::temp_dir();
    let junk = dir.join(format!("openrand_cli_junk_{}.ck", std::process::id()));
    std::fs::write(&junk, b"definitely not a checkpoint").unwrap();
    let (_, err, ok) = openrand(&["campaign", "resume", "--from", junk.to_str().unwrap()]);
    std::fs::remove_file(&junk).ok();
    assert!(!ok);
    assert!(err.contains("checkpoint") || err.contains("magic"), "{err}");
    assert!(!err.contains("panicked"), "{err}");
}

#[test]
fn campaign_validate_gates_on_tolerance() {
    // Tiny-N validate: generous tolerance passes, absurdly tight fails
    // with a diagnostic (not a panic). Small n keeps this test cheap;
    // CI runs the reduced-N gate at real scale.
    let base = [
        "campaign", "validate", "--n", "4096", "--steps", "500", "--relax", "200",
        "--sample-every", "50", "--threads", "2",
    ];
    let (out, err, ok) = openrand(&[&base[..], &["--tolerance", "0.5"]].concat());
    assert!(ok, "{err}");
    assert!(out.contains("PASS"), "{out}");
    assert!(out.contains("D_est"), "{out}");
    let (_, err, ok) = openrand(&[&base[..], &["--tolerance", "1e-9"]].concat());
    assert!(!ok);
    assert!(err.contains("tolerance"), "{err}");
}

#[test]
fn generate_is_deterministic_and_formatted() {
    let (a, _, ok) = openrand(&["generate", "--generator", "squares", "--seed", "42", "--n", "5"]);
    assert!(ok);
    let (b, _, _) = openrand(&["generate", "--generator", "squares", "--seed", "42", "--n", "5"]);
    assert_eq!(a, b);
    assert_eq!(a.lines().count(), 5);
    for line in a.lines() {
        line.parse::<u32>().expect("u32 output");
    }
    // f64 format stays in [0, 1).
    let (f, _, _) = openrand(&["generate", "--format", "f64", "--n", "3", "--seed", "0x1F"]);
    for line in f.lines() {
        let v: f64 = line.parse().unwrap();
        assert!((0.0..1.0).contains(&v));
    }
}

#[test]
fn generate_differs_across_generators_and_ctrs() {
    let run = |g: &str, c: &str| openrand(&["generate", "--generator", g, "--ctr", c, "--n", "4"]).0;
    assert_ne!(run("philox", "0"), run("threefry", "0"));
    assert_ne!(run("philox", "0"), run("philox", "1"));
}

#[test]
fn generate_backend_par_bitwise_matches_word_at_a_time() {
    // The block-fill contract, end to end: --backend par output is byte
    // identical to the plain path for every format, and independent of
    // --threads.
    for format in ["u32", "u64", "f32", "f64"] {
        let base_args = ["generate", "--seed", "9", "--ctr", "2", "--n", "33", "--format", format];
        let (base, _, ok) = openrand(&base_args);
        assert!(ok, "{format}");
        let mut one_args = base_args.to_vec();
        one_args.extend_from_slice(&["--backend", "par"]);
        let (one, _, ok1) = openrand(&one_args);
        assert!(ok1, "{format}");
        let mut par_args = one_args.clone();
        par_args.extend_from_slice(&["--threads", "4"]);
        let (par, _, ok2) = openrand(&par_args);
        assert!(ok2, "{format}");
        assert_eq!(base, one, "{format}: serial block fill diverged");
        assert_eq!(base, par, "{format}: parallel block fill diverged");
    }
    // Non-default engines ride the same contract (tyche has the O(pos)
    // set_position exception; it must still be bitwise identical).
    for generator in ["threefry", "squares", "tyche"] {
        let (plain, _, _) = openrand(&["generate", "--generator", generator, "--n", "17"]);
        let (filled, _, ok) = openrand(&[
            "generate", "--generator", generator, "--n", "17", "--backend", "par", "--threads", "3",
        ]);
        assert!(ok, "{generator}");
        assert_eq!(plain, filled, "{generator}");
    }
    // Backends are a raw-format path; combining one with --dist errors.
    let (_, err, ok) = openrand(&["generate", "--dist", "normal", "--backend", "par"]);
    assert!(!ok);
    assert!(err.contains("--backend"), "{err}");
}

#[test]
fn generate_backend_arms_byte_identical() {
    // The backend-subsystem contract at the CLI surface: every host arm
    // (and auto) matches the plain word-at-a-time path byte-for-byte.
    for format in ["u32", "u64", "f32", "f64"] {
        let base = ["generate", "--seed", "11", "--ctr", "3", "--n", "41", "--format", format];
        let (plain, _, ok) = openrand(&base);
        assert!(ok, "{format}");
        for backend_args in [
            &["--backend", "host"][..],
            &["--backend", "par", "--threads", "4"][..],
            &["--backend", "auto"][..],
        ] {
            let mut args = base.to_vec();
            args.extend_from_slice(backend_args);
            let (out, err, ok) = openrand(&args);
            assert!(ok, "{format} {backend_args:?}: {err}");
            assert_eq!(plain, out, "{format} {backend_args:?} diverged");
        }
    }
    // --crossover steers the auto arm without changing bytes.
    let (plain, _, _) = openrand(&["generate", "--seed", "5", "--n", "20"]);
    let (steered, err, ok) =
        openrand(&["generate", "--seed", "5", "--n", "20", "--backend", "auto", "--crossover", "1k"]);
    assert!(ok, "{err}");
    assert_eq!(plain, steered);
    // ... and is rejected (not silently ignored) on any other arm.
    let (_, err, ok) = openrand(&["generate", "--n", "8", "--backend", "par", "--crossover", "1k"]);
    assert!(!ok);
    assert!(err.contains("crossover"), "{err}");
}

#[test]
fn generate_backend_device_matches_or_reports_unavailable() {
    // Fresh checkout (vendored PJRT stub / no artifacts): a clean error.
    // Real backend + artifacts: byte-identical to the plain path.
    let (plain, _, _) = openrand(&["generate", "--seed", "2", "--ctr", "1", "--n", "29"]);
    let (out, err, ok) =
        openrand(&["generate", "--seed", "2", "--ctr", "1", "--n", "29", "--backend", "device"]);
    if ok {
        assert_eq!(plain, out, "device arm diverged from the plain path");
    } else {
        assert!(
            err.contains("error"),
            "device unavailability must be a diagnostic, got: {err}"
        );
    }
}

#[test]
fn generate_backend_rejects_unknown_arm() {
    let (_, err, ok) = openrand(&["generate", "--backend", "gpu", "--n", "4"]);
    assert!(!ok);
    assert!(err.contains("unknown backend"), "{err}");
}

#[test]
fn generate_key_addressing_byte_identical_to_seed_ctr() {
    // The hierarchical-key CLI surface: '--key S/eT' must be
    // byte-identical to '--seed S --ctr T' (the StreamKey::raw
    // equivalence, end to end), for raw words and dist samples alike.
    for format in ["u32", "f64"] {
        let (legacy, _, ok) =
            openrand(&["generate", "--seed", "7", "--ctr", "1", "--n", "23", "--format", format]);
        assert!(ok, "{format}");
        let (keyed, _, ok) =
            openrand(&["generate", "--key", "7/e1", "--n", "23", "--format", format]);
        assert!(ok, "{format}");
        assert_eq!(legacy, keyed, "{format}: --key 7/e1 diverged from --seed 7 --ctr 1");
    }
    let (legacy, _, _) = openrand(&["generate", "--dist", "normal", "--seed", "7", "--ctr", "1", "--n", "4"]);
    let (keyed, _, _) = openrand(&["generate", "--dist", "normal", "--key", "7/e1", "--n", "4"]);
    assert_eq!(legacy, keyed, "dist sampling under --key diverged");
    // A bare root is (seed, ctr=0).
    let (legacy, _, _) = openrand(&["generate", "--seed", "42", "--n", "6"]);
    let (keyed, _, _) = openrand(&["generate", "--key", "42", "--n", "6"]);
    assert_eq!(legacy, keyed);
    // Child derivation opens a NEW stream (deterministically).
    let (child_a, _, ok) = openrand(&["generate", "--key", "7/c3/e1", "--n", "6"]);
    assert!(ok);
    let (child_b, _, _) = openrand(&["generate", "--key", "7/c3/e1", "--n", "6"]);
    assert_eq!(child_a, child_b, "derived streams must replay");
    let (root, _, _) = openrand(&["generate", "--key", "7/e1", "--n", "6"]);
    assert_ne!(child_a, root, "child stream must differ from its parent");
    // The first word of root(7).child(3).epoch(1) is the cross-layer
    // derivation KAT literal (pinned in rust + python suites).
    assert_eq!(child_a.lines().next().unwrap(), format!("{}", 0x9022_9F37u32));
}

#[test]
fn generate_key_conflicts_and_errors() {
    let (_, err, ok) = openrand(&["generate", "--key", "7/e1", "--seed", "7", "--n", "4"]);
    assert!(!ok);
    assert!(err.contains("--key"), "{err}");
    let (_, err, ok) = openrand(&["generate", "--key", "7/z9", "--n", "4"]);
    assert!(!ok);
    assert!(err.contains("key"), "{err}");
    let (_, err, ok) = openrand(&["generate", "--key", "", "--n", "4"]);
    assert!(!ok);
    assert!(err.contains("key"), "{err}");
}

#[test]
fn generate_block_fill_alias_removed() {
    // The PR-2 `--block-fill` spelling (deprecated in PR 5) is gone:
    // an unknown option is a hard parse error, not a silent ignore.
    let (_, err, ok) = openrand(&["generate", "--n", "4", "--block-fill"]);
    assert!(!ok);
    assert!(err.contains("unknown option"), "{err}");
    // The supported spelling works and stays silent on stderr.
    let (_, err, ok) = openrand(&["generate", "--n", "4", "--backend", "par"]);
    assert!(ok);
    assert!(err.is_empty(), "{err}");
}

#[test]
fn stats_dist_battery_keyed_passes() {
    let (out, err, ok) =
        openrand(&["stats", "--dist-battery", "--key", "7/c1", "--words", "64k"]);
    assert!(ok, "{err}");
    assert!(out.contains("[distributions @"), "{out}");
    assert!(out.contains("0 failures"), "{out}");
}

#[test]
fn generate_dist_samples_deterministic() {
    let run = || openrand(&["generate", "--dist", "normal", "--seed", "7", "--ctr", "1", "--n", "6"]);
    let (a, _, ok) = run();
    assert!(ok);
    let (b, _, _) = run();
    assert_eq!(a, b);
    assert_eq!(a.lines().count(), 6);
    for line in a.lines() {
        let z: f64 = line.parse().expect("normal sample parses as f64");
        assert!(z.abs() < 10.0, "{z}");
    }
    // First sample = the cosine branch of the (seed=7, ctr=1) Box-Muller
    // pair — the same value pinned by the KATs on both layers.
    let first: f64 = a.lines().next().unwrap().parse().unwrap();
    assert!((first - 1.7940642507332762).abs() < 1e-12, "{first}");
}

#[test]
fn generate_dist_families_run_and_differ() {
    let run = |dist: &str, extra: &[&str]| {
        let mut args = vec!["generate", "--dist", dist, "--seed", "3", "--n", "5"];
        args.extend_from_slice(extra);
        openrand(&args)
    };
    // Integer families parse as integers.
    for (dist, extra) in [
        ("poisson", &["--lambda", "4.5"][..]),
        ("binomial", &["--trials", "12", "--p", "0.4"][..]),
        ("alias", &["--weights", "1,2,3"][..]),
        ("bernoulli", &[][..]),
    ] {
        let (out, err, ok) = run(dist, extra);
        assert!(ok, "{dist}: {err}");
        assert_eq!(out.lines().count(), 5, "{dist}");
        for line in out.lines() {
            line.parse::<u64>().unwrap_or_else(|_| panic!("{dist}: bad line {line}"));
        }
    }
    // Continuous families parse as floats; exp is nonnegative.
    for dist in ["uniform", "normal", "ziggurat", "exp"] {
        let (out, err, ok) = run(dist, &[]);
        assert!(ok, "{dist}: {err}");
        for line in out.lines() {
            let v: f64 = line.parse().unwrap();
            assert!(dist != "exp" || v >= 0.0);
        }
    }
    // Normative Box-Muller and ziggurat draw from the same stream but
    // through different transforms.
    assert_ne!(run("normal", &[]).0, run("ziggurat", &[]).0);
}

#[test]
fn generate_dist_bad_parameters_rejected() {
    let (_, err, ok) = openrand(&["generate", "--dist", "warp"]);
    assert!(!ok);
    assert!(err.contains("unknown dist"), "{err}");
    let (_, err, ok) = openrand(&["generate", "--dist", "poisson", "--lambda", "-2"]);
    assert!(!ok);
    assert!(err.contains("lambda"), "{err}");
    let (_, err, ok) = openrand(&["generate", "--dist", "uniform", "--lo", "5", "--hi", "1"]);
    assert!(!ok);
    assert!(err.contains("--lo"), "{err}");
    // Non-finite bounds and oversized trial counts get clean errors,
    // not constructor panics or silent u32 truncation.
    let (_, err, ok) = openrand(&["generate", "--dist", "uniform", "--lo", "inf"]);
    assert!(!ok);
    assert!(err.contains("--lo"), "{err}");
    let (_, err, ok) = openrand(&["generate", "--dist", "binomial", "--trials", "4294967296"]);
    assert!(!ok);
    assert!(err.contains("--trials"), "{err}");
}

#[test]
fn stats_dist_battery_passes() {
    let (out, err, ok) =
        openrand(&["stats", "--dist-battery", "--generator", "philox", "--words", "64k"]);
    assert!(ok, "{err}");
    assert!(out.contains("[distributions]"), "{out}");
    assert!(out.contains("normal_box_muller_ks"), "{out}");
    assert!(out.contains("0 failures"), "{out}");
}

#[test]
fn unknown_arguments_rejected() {
    let (_, err, ok) = openrand(&["generate", "--bogus", "1"]);
    assert!(!ok);
    assert!(err.contains("unknown option"));
    let (_, err, ok) = openrand(&["teleport"]);
    assert!(!ok);
    assert!(err.contains("unknown command"));
    let (_, err, ok) = openrand(&["generate", "--generator", "mt19937x"]);
    assert!(!ok);
    assert!(err.contains("unknown generator"));
}

#[test]
fn brownian_key_addressing() {
    // --key seeds the run like --seed (same trajectory hash)...
    let hash = |s: &str| s.lines().find(|l| l.contains("hash")).unwrap().to_string();
    let (a, err, ok) = openrand(&["brownian", "--n", "512", "--steps", "3", "--seed", "9"]);
    assert!(ok, "{err}");
    let (b, err, ok) = openrand(&["brownian", "--n", "512", "--steps", "3", "--key", "9"]);
    assert!(ok, "{err}");
    assert_eq!(hash(&a), hash(&b));
    // ... and an epoch in the key is rejected, not silently dropped
    // (brownian owns its per-step epochs).
    let (_, err, ok) = openrand(&["brownian", "--n", "512", "--steps", "3", "--key", "9/e2"]);
    assert!(!ok);
    assert!(err.contains("epoch"), "{err}");
}

#[test]
fn brownian_host_reports_metrics_and_hash() {
    let (out, err, ok) = openrand(&["brownian", "--n", "1k", "--steps", "5", "--threads", "2"]);
    assert!(ok, "{err}");
    assert!(out.contains("throughput="));
    assert!(out.contains("trajectory hash:"));
    // Hash is thread-count invariant.
    let (out1, _, _) = openrand(&["brownian", "--n", "1k", "--steps", "5", "--threads", "1"]);
    let hash = |s: &str| s.lines().find(|l| l.contains("hash")).unwrap().to_string();
    assert_eq!(hash(&out), hash(&out1));
}

#[test]
fn artifacts_lists_manifest() {
    let (out, err, ok) = openrand(&["artifacts"]);
    if !ok {
        // Fresh checkout: AOT artifacts are built separately. Same
        // strict escape hatch as cross_layer.rs.
        assert!(
            std::env::var("OPENRAND_REQUIRE_ARTIFACTS").as_deref() != Ok("1"),
            "OPENRAND_REQUIRE_ARTIFACTS=1 but `openrand artifacts` failed: {err}"
        );
        eprintln!("skipping artifact listing (run `make artifacts`): {err}");
        return;
    }
    assert!(out.contains("brownian_step_16384"));
    assert!(out.contains("philox_u32_65536"));
}

#[test]
fn stats_quick_battery_passes() {
    let (out, err, ok) = openrand(&["stats", "--generator", "squares", "--words", "64k"]);
    assert!(ok, "{err}");
    assert!(out.contains("battery: squares"));
    assert!(out.contains("0 failures"), "{out}");
}
