//! Hierarchical stream addressing and the one-handle drawing facade.
//!
//! After the core (`(seed, ctr)` engines), fill (`core::fill`), backend
//! (`openrand::backend`), and distribution (`dist`) layers, the crate
//! exposed four uncoordinated ways to name and drain a stream — and
//! every consumer still hand-assembled raw `(seed, ctr)` integers, the
//! collision-prone bookkeeping a reproducible-RNG library should own
//! (Shoverand and Randompack both make this argument). This module is
//! the single entry point that replaces that bookkeeping:
//!
//! * [`StreamKey`] — a typed, hierarchical stream address. Build one
//!   from a root seed and derive sub-addresses structurally:
//!   `root(run).child(particle).epoch(step)`. Derivation goes through
//!   one **normative mix function** ([`derive_child_seed`], a
//!   splitmix64 chain shared bit-exactly with
//!   `python/compile/kernels/common.py::derive_child_seed`), so host
//!   and device layers agree on every derived stream.
//! * [`Stream<E>`] / [`DynStream`] — one handle over a keyed stream
//!   that unifies scalar draws (the [`Rng`] API), key-addressed bulk
//!   fills (routed through any [`FillBackend`] arm, defaulting to the
//!   calibrated `Auto` arm), positioned block fills, and distribution
//!   sampling ([`Stream::sample`], [`Stream::sample_fill`]).
//! * [`BackendWords`] — a word source that serves a key's stream with
//!   its opening words delivered as one backend prefix fill (how the
//!   statistical batteries drain keyed streams).
//!
//! ## Zero drift (normative)
//!
//! [`StreamKey::raw(seed, ctr)`](StreamKey::raw) is the documented
//! equivalence with the legacy spelling: its stream is **byte-identical**
//! to [`CounterRng::new(seed, ctr)`](CounterRng::new) for every engine
//! — the facade renames nothing and re-mixes nothing. `root(s)` is
//! `raw(s, 0)` and `epoch(t)` sets the counter absolutely, so
//! `root(s).epoch(t) == raw(s, t)`: simple uses of the new API read the
//! exact streams the old API read. Only [`StreamKey::child`] derives a
//! *new* 64-bit seed (and resets the counter), via the normative mix.
//!
//! The full derivation contract, worked examples, and the old-API →
//! new-API migration table live in `docs/stream-keys.md`.
//!
//! ```
//! use openrand::core::Philox;
//! use openrand::dist::{BoxMuller, Distribution};
//! use openrand::stream::{Stream, StreamKey};
//!
//! // Address streams structurally instead of packing integers by hand:
//! let run = StreamKey::root(42);
//! let key = run.child(/*particle=*/ 17).epoch(/*step=*/ 3);
//! let mut s = Stream::<Philox>::new(key);
//! let kick = BoxMuller::standard().sample(&mut s);
//! assert!(kick.is_finite());
//!
//! // The legacy spelling is a thin, documented equivalence:
//! use openrand::core::{CounterRng, Rng};
//! let mut a = Stream::<Philox>::new(StreamKey::raw(7, 1));
//! let mut b = Philox::new(7, 1);
//! assert_eq!(a.next_u32(), b.next_u32());
//! ```

#[cfg(feature = "std")]
use anyhow::Result;

#[cfg(feature = "std")]
use crate::backend::{self, FillBackend};
use crate::core::counter::splitmix64;
use crate::core::{fill, BlockRng, CounterRng, Generator, Rng};
use crate::dist::Distribution;

/// Domain-separation tag of the child derivation (ASCII `"chld"`).
/// Mixed into every [`derive_child_seed`] call so child seeds can never
/// collide with a future derivation family that uses a different tag.
pub const DOMAIN_CHILD: u64 = 0x6368_6C64;

/// The normative child-key mix — the single 64 → `(seed, ctr)` function
/// behind [`StreamKey::child`], shared bit-exactly with
/// `python/compile/kernels/common.py::derive_child_seed` (pinned by
/// `python/tests/test_stream_keys.py` and the KATs below).
///
/// A splitmix64 chain over the parent identity and the child id:
///
/// ```text
/// tag        = (parent_ctr << 32) | DOMAIN_CHILD
/// child_seed = splitmix64( splitmix64( splitmix64(parent_seed) ^ tag ) ^ id )
/// child_ctr  = 0
/// ```
///
/// For a fixed parent, `id -> child_seed` is a **bijection** (xor with a
/// constant composed with the splitmix64 permutation), so distinct child
/// ids are *guaranteed* distinct seeds — not merely probable.
///
/// ```
/// use openrand::stream::derive_child_seed;
/// // The cross-layer KAT literal (same constant in python/tests):
/// assert_eq!(derive_child_seed(7, 0, 3), 0xBC83_12B7_34DE_4237);
/// // Parent counter separates child spaces per epoch:
/// assert_ne!(derive_child_seed(7, 2, 3), derive_child_seed(7, 0, 3));
/// ```
#[inline]
pub fn derive_child_seed(parent_seed: u64, parent_ctr: u32, id: u64) -> u64 {
    let tag = ((parent_ctr as u64) << 32) | DOMAIN_CHILD;
    splitmix64(splitmix64(splitmix64(parent_seed) ^ tag) ^ id)
}

/// A typed, hierarchical stream address.
///
/// A key *is* a `(seed: u64, ctr: u32)` pair — the same identity the
/// engines consume — reached structurally instead of assembled by hand:
///
/// * [`StreamKey::root`]`(s)` — the run/root address `(s, 0)`.
/// * [`StreamKey::child`]`(id)` — a derived address for a sub-entity
///   (particle, chunk, test index): fresh seed via the normative mix
///   ([`derive_child_seed`]), counter reset to 0. Path-dependent:
///   `root(s).child(a).child(b)` names a grandchild, and deriving under
///   a different epoch gives a different child space.
/// * [`StreamKey::epoch`]`(t)` — the sub-stream counter, set
///   **absolutely** (timestep, kernel launch): `k.epoch(a).epoch(b) ==
///   k.epoch(b)` (last wins, documented order independence).
/// * [`StreamKey::raw`]`(seed, ctr)` — the legacy equivalence: streams
///   byte-identical to `CounterRng::new(seed, ctr)`.
///
/// ```
/// use openrand::stream::StreamKey;
/// // The cross-layer derivation KAT (python/tests/test_stream_keys.py
/// // pins the identical literals):
/// let k = StreamKey::root(7).child(3).epoch(1);
/// assert_eq!((k.seed(), k.ctr()), (0xBC83_12B7_34DE_4237, 1));
/// // Legacy equivalence and epoch absoluteness:
/// assert_eq!(StreamKey::root(7).epoch(1), StreamKey::raw(7, 1));
/// assert_eq!(StreamKey::root(9).epoch(5).epoch(2), StreamKey::raw(9, 2));
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct StreamKey {
    seed: u64,
    ctr: u32,
}

impl StreamKey {
    /// The root address of a run: `(seed, ctr = 0)`.
    #[inline]
    pub fn root(seed: u64) -> StreamKey {
        StreamKey { seed, ctr: 0 }
    }

    /// The legacy `(seed, ctr)` spelling, verbatim — byte-identical
    /// streams to `CounterRng::new(seed, ctr)` (the zero-drift
    /// equivalence; `coordinator::repro::verify_key_equivalence` checks
    /// it for all seven engines on every `openrand repro` run).
    #[inline]
    pub fn raw(seed: u64, ctr: u32) -> StreamKey {
        StreamKey { seed, ctr }
    }

    /// Derive the address of sub-entity `id` via the normative mix
    /// ([`derive_child_seed`]): fresh seed, counter reset to 0.
    /// Distinct ids map to distinct seeds (bijective for a fixed
    /// parent).
    #[inline]
    pub fn child(self, id: u64) -> StreamKey {
        StreamKey { seed: derive_child_seed(self.seed, self.ctr, id), ctr: 0 }
    }

    /// Select sub-stream `t` of this entity (timestep, kernel launch).
    /// Absolute, not cumulative: the counter is *set* to `t`, so the
    /// last `epoch` wins and `root(s).epoch(t) == raw(s, t)`.
    #[inline]
    pub fn epoch(self, t: u32) -> StreamKey {
        StreamKey { seed: self.seed, ctr: t }
    }

    /// The engine-level seed this key resolves to.
    #[inline]
    pub fn seed(self) -> u64 {
        self.seed
    }

    /// The engine-level counter this key resolves to.
    #[inline]
    pub fn ctr(self) -> u32 {
        self.ctr
    }

    /// Parse the CLI path spelling: `SEED[/cID|/eT]...` — a root seed
    /// (decimal or `0x` hex) followed by `c`-prefixed child ids and
    /// `e`-prefixed epochs, applied left to right. `7/c3/e1` is
    /// `root(7).child(3).epoch(1)`; `7/e1` is the legacy `--seed 7
    /// --ctr 1`. (`std`: error strings allocate.)
    #[cfg(feature = "std")]
    pub fn parse_path(spec: &str) -> Result<StreamKey, String> {
        fn int(s: &str, what: &str) -> Result<u64, String> {
            let s = s.trim();
            // No sign spellings anywhere (incl. after '0x', which
            // from_str_radix would accept): the accepted grammar stays
            // identical to the python mirror (`common.stream_key_path`).
            if s.contains('+') {
                return Err(format!("bad {what} '{s}'"));
            }
            if let Some(h) = s.strip_prefix("0x") {
                return u64::from_str_radix(h, 16).map_err(|_| format!("bad hex {what} '{s}'"));
            }
            s.parse::<u64>().map_err(|_| format!("bad {what} '{s}'"))
        }
        let mut segs = spec.split('/');
        let root = segs.next().unwrap_or("");
        if root.is_empty() {
            return Err("empty key path (expected 'SEED[/cID|/eT]...', e.g. 7/c3/e1)".to_string());
        }
        let mut key = StreamKey::root(int(root, "root seed")?);
        for seg in segs {
            if let Some(id) = seg.strip_prefix('c') {
                key = key.child(int(id, "child id")?);
            } else if let Some(t) = seg.strip_prefix('e') {
                let t = int(t, "epoch")?;
                if t > u32::MAX as u64 {
                    return Err(format!("epoch '{seg}' exceeds the 32-bit counter"));
                }
                key = key.epoch(t as u32);
            } else {
                return Err(format!("bad key segment '{seg}' (expected cID or eT)"));
            }
        }
        Ok(key)
    }
}

impl core::fmt::Display for StreamKey {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        write!(f, "0x{:016x}/e{}", self.seed, self.ctr)
    }
}

/// Construct the default bulk-fill backend: the calibrated `Auto` arm
/// (host/device by buffer size from the persisted crossover table,
/// degrading to the sharded host arm on stub builds) over auto-sized
/// host threads. This is what every `backend: None` fill in this module
/// runs on — the ROADMAP "Auto-backend consumers" item made uniform.
///
/// The `None` route does not call this per fill: it reuses one cached
/// instance per thread, so the device probe, the crossover-table load,
/// and `DeviceFill`'s compiled-graph / buffer pools are paid once per
/// thread, not once per call. First use on a thread pins that thread's
/// calibration table.
#[cfg(feature = "std")]
pub fn default_backend() -> Box<dyn FillBackend> {
    Box::new(backend::Auto::new(backend::HostParallel::auto_threads().threads()))
}

#[cfg(feature = "std")]
thread_local! {
    /// The per-thread cached default backend ([`FillBackend`] is not
    /// `Send` — the device arm is thread-confined like the PJRT client
    /// it wraps, so per-thread is exactly the right sharing granularity).
    static DEFAULT_BACKEND: std::cell::RefCell<Option<Box<dyn FillBackend>>> =
        const { std::cell::RefCell::new(None) };
}

/// Run `f` on `backend`, or on this thread's cached [`default_backend`]
/// when none was supplied. The cached instance is *taken* for the
/// duration of `f` and put back afterwards, so a re-entrant `None` fill
/// constructs a fresh temporary instead of panicking on a double
/// borrow.
#[cfg(feature = "std")]
fn route<R>(
    backend: Option<&mut dyn FillBackend>,
    f: impl FnOnce(&mut dyn FillBackend) -> R,
) -> R {
    match backend {
        Some(b) => f(b),
        None => DEFAULT_BACKEND.with(|slot| {
            let mut b = slot.borrow_mut().take().unwrap_or_else(default_backend);
            let r = f(b.as_mut());
            *slot.borrow_mut() = Some(b);
            r
        }),
    }
}

/// Key-addressed bulk fill: stream words `0..out.len()` of `key`'s
/// stream of `gen`, through `backend` (`None` = the calibrated
/// [`default_backend`]). Byte-identical on every arm by the backend
/// contract (`docs/backends.md`).
#[cfg(feature = "std")]
pub fn fill_u32_key(
    backend: Option<&mut dyn FillBackend>,
    gen: Generator,
    key: StreamKey,
    out: &mut [u32],
) -> Result<()> {
    route(backend, |b| b.fill_u32(gen, key.seed(), key.ctr(), out))
}

/// Key-addressed `u64` fill — element `i` ← words `2i, 2i+1`
/// (first word high), per the §2 conversion contract.
#[cfg(feature = "std")]
pub fn fill_u64_key(
    backend: Option<&mut dyn FillBackend>,
    gen: Generator,
    key: StreamKey,
    out: &mut [u64],
) -> Result<()> {
    route(backend, |b| b.fill_u64(gen, key.seed(), key.ctr(), out))
}

/// Key-addressed `f32` fill — element `i` ← word `i` (top 24 bits).
#[cfg(feature = "std")]
pub fn fill_f32_key(
    backend: Option<&mut dyn FillBackend>,
    gen: Generator,
    key: StreamKey,
    out: &mut [f32],
) -> Result<()> {
    route(backend, |b| b.fill_f32(gen, key.seed(), key.ctr(), out))
}

/// Key-addressed `f64` fill — element `i` ← words `2i, 2i+1`
/// (top 53 bits).
#[cfg(feature = "std")]
pub fn fill_f64_key(
    backend: Option<&mut dyn FillBackend>,
    gen: Generator,
    key: StreamKey,
    out: &mut [f64],
) -> Result<()> {
    route(backend, |b| b.fill_f64(gen, key.seed(), key.ctr(), out))
}

/// One handle over the keyed stream of a concrete engine `E`.
///
/// Unifies the crate's drawing surfaces behind a single object:
///
/// * **Scalar draws** — `Stream<E>` implements [`Rng`], delegating to
///   the engine, so `next_u32`/`draw_double`/… and every
///   [`Distribution`] compose with it directly and advance the handle's
///   cursor.
/// * **Key-addressed bulk fills** — [`Stream::fill_u32`] and friends
///   write stream words `0..n` of the *key* (not the cursor) through a
///   [`FillBackend`], defaulting to the calibrated `Auto` arm.
/// * **Positioned block fills** — [`Stream::fill_u32_at`] writes words
///   `pos..pos + n` through the backend offset entry point
///   ([`FillBackend::fill_u32_at`]; the engine's host block path on
///   `no_std`).
/// * **Distribution sampling** — [`Stream::sample`] (cursor-advancing)
///   and [`Stream::sample_fill`] (key-addressed bulk, backend-routed
///   for fixed-pattern samplers) are the one distribution surface (the
///   per-sampler backend spellings they replaced are gone).
///
/// The cursor (trait) and key (inherent) surfaces are deliberately
/// distinct operations: the first continues the stream, the second
/// re-reads it from word 0 — the same split the draw API and the fill
/// engine have always had, now on one handle.
///
/// Note on method resolution: the inherent `fill_u32(backend, out)`
/// shadows [`Rng::fill_u32`]`(out)` for direct calls on a concrete
/// handle (inherent methods win before arity is checked). Generic and
/// `dyn Rng` contexts are unaffected; to call the cursor-advancing
/// trait version on a concrete `Stream`, use UFCS:
/// `Rng::fill_u32(&mut s, out)`.
pub struct Stream<E: CounterRng> {
    key: StreamKey,
    rng: E,
}

impl<E: CounterRng> Stream<E> {
    /// Open the stream `key` addresses, cursor at word 0.
    pub fn new(key: StreamKey) -> Stream<E> {
        Stream { key, rng: E::new(key.seed(), key.ctr()) }
    }

    /// The address this handle draws from.
    pub fn key(&self) -> StreamKey {
        self.key
    }

    /// Rewind the cursor to word 0 (streams replay bitwise).
    pub fn reset(&mut self) {
        self.rng = E::new(self.key.seed(), self.key.ctr());
    }

    /// The underlying engine (block-API access, e.g.
    /// [`BlockRng::generate_block`]).
    pub fn rng_mut(&mut self) -> &mut E {
        &mut self.rng
    }

    /// Open the derived child handle (fresh stream, cursor at 0).
    pub fn child(&self, id: u64) -> Stream<E> {
        Stream::new(self.key.child(id))
    }

    /// Open the sub-stream handle for epoch `t`.
    pub fn epoch(&self, t: u32) -> Stream<E> {
        Stream::new(self.key.epoch(t))
    }

    /// Draw one sample, advancing the cursor (delegates to
    /// [`Distribution::sample`] — the word-consumption contract of the
    /// sampler applies unchanged).
    pub fn sample<T, D: Distribution<T> + ?Sized>(&mut self, d: &D) -> T {
        d.sample(&mut self.rng)
    }
}

impl<E: CounterRng + BlockRng> Stream<E> {
    /// The runtime tag of `E`, when it is one of the seven core engines
    /// (backend routing needs the tag; unknown engines fill host-side).
    pub fn generator(&self) -> Option<Generator> {
        Generator::parse(E::NAME)
    }

    /// Positioned block fill: stream words `pos..pos + out.len()` of
    /// the key. Under `std` this routes through the backend **offset
    /// entry point** ([`FillBackend::fill_u32_at`] on the thread's
    /// cached [`default_backend`]) — device-capable for interior spans
    /// via the `_at` artifacts, byte-identical to the positioned host
    /// fill by the §4 offset-fill layout. Without `std` (the serial
    /// core the C ABI exports) it is the engine's own block path:
    /// O(1) jump for the counter engines; Tyche's documented O(pos)
    /// exception applies.
    #[cfg(not(feature = "std"))]
    pub fn fill_u32_at(&self, pos: u64, out: &mut [u32]) {
        let mut g = E::new(self.key.seed(), self.key.ctr());
        if pos != 0 {
            g.set_position(pos);
        }
        fill::fill_from(&mut g, pos, out);
    }

    /// Positioned block fill (std: routed through the offset entry
    /// point — see the `no_std` twin above for the full contract).
    #[cfg(feature = "std")]
    pub fn fill_u32_at(&self, pos: u64, out: &mut [u32]) {
        match Generator::parse(E::NAME) {
            Some(gen) => {
                route(None, |b| b.fill_u32_at(gen, self.key.seed(), self.key.ctr(), pos, out))
                    .expect("offset fills degrade to the infallible host path")
            }
            None => {
                let mut g = E::new(self.key.seed(), self.key.ctr());
                if pos != 0 {
                    g.set_position(pos);
                }
                fill::fill_from(&mut g, pos, out);
            }
        }
    }
}

#[cfg(feature = "std")]
impl<E: CounterRng + BlockRng> Stream<E> {
    /// Key-addressed bulk fill: stream words `0..out.len()` of the key,
    /// through `backend` (`None` = the calibrated [`default_backend`]).
    /// Independent of — and not advancing — the scalar cursor.
    pub fn fill_u32(&self, backend: Option<&mut dyn FillBackend>, out: &mut [u32]) -> Result<()> {
        match Generator::parse(E::NAME) {
            Some(gen) => fill_u32_key(backend, gen, self.key, out),
            None => {
                fill::fill_u32::<E>(self.key.seed(), self.key.ctr(), out);
                Ok(())
            }
        }
    }

    /// Key-addressed `u64` fill (element `i` ← words `2i, 2i+1`).
    pub fn fill_u64(&self, backend: Option<&mut dyn FillBackend>, out: &mut [u64]) -> Result<()> {
        match Generator::parse(E::NAME) {
            Some(gen) => fill_u64_key(backend, gen, self.key, out),
            None => {
                fill::fill_u64::<E>(self.key.seed(), self.key.ctr(), out);
                Ok(())
            }
        }
    }

    /// Key-addressed `f32` fill (element `i` ← word `i`).
    pub fn fill_f32(&self, backend: Option<&mut dyn FillBackend>, out: &mut [f32]) -> Result<()> {
        match Generator::parse(E::NAME) {
            Some(gen) => fill_f32_key(backend, gen, self.key, out),
            None => {
                fill::fill_f32::<E>(self.key.seed(), self.key.ctr(), out);
                Ok(())
            }
        }
    }

    /// Key-addressed `f64` fill (element `i` ← words `2i, 2i+1`).
    pub fn fill_f64(&self, backend: Option<&mut dyn FillBackend>, out: &mut [f64]) -> Result<()> {
        match Generator::parse(E::NAME) {
            Some(gen) => fill_f64_key(backend, gen, self.key, out),
            None => {
                fill::fill_f64::<E>(self.key.seed(), self.key.ctr(), out);
                Ok(())
            }
        }
    }

    /// Key-addressed bulk sampling: samples `0..out.len()` of the key's
    /// sample sequence under `d`, routed through
    /// [`Distribution::fill_backend`] (`None` backend = the calibrated
    /// [`default_backend`]). Bit-identical to repeated
    /// [`Stream::sample`] calls on a fresh handle.
    pub fn sample_fill<T, D: Distribution<T> + ?Sized>(
        &self,
        d: &D,
        backend: Option<&mut dyn FillBackend>,
        out: &mut [T],
    ) -> Result<()> {
        match Generator::parse(E::NAME) {
            Some(gen) => route(backend, |b| d.fill_backend(b, gen, self.key.seed(), self.key.ctr(), out)),
            None => {
                let mut rng = E::new(self.key.seed(), self.key.ctr());
                d.fill(&mut rng, out);
                Ok(())
            }
        }
    }
}

impl<E: CounterRng> Rng for Stream<E> {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        self.rng.next_u32()
    }

    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    #[inline]
    fn fill_u32(&mut self, out: &mut [u32]) {
        self.rng.fill_u32(out)
    }
}

/// The object-safe stream handle: [`Stream`] over the runtime
/// [`Generator`] tag (built on the same boxed dispatch the CLI and the
/// batteries use). Same surface as [`Stream`], minus the generic.
#[cfg(feature = "std")]
pub struct DynStream {
    key: StreamKey,
    gen: Generator,
    rng: Box<dyn Rng>,
}

#[cfg(feature = "std")]
impl DynStream {
    /// Open the stream `key` addresses on engine `gen`, cursor at 0.
    pub fn open(gen: Generator, key: StreamKey) -> DynStream {
        DynStream { key, gen, rng: gen.boxed(key.seed(), key.ctr()) }
    }

    /// Open with the cursor positioned at absolute stream word `pos`
    /// (O(1) counter jump; Tyche's documented O(pos) exception
    /// applies).
    pub fn open_at(gen: Generator, key: StreamKey, pos: u64) -> DynStream {
        DynStream { key, gen, rng: gen.boxed_at(key.seed(), key.ctr(), pos) }
    }

    pub fn key(&self) -> StreamKey {
        self.key
    }

    pub fn generator(&self) -> Generator {
        self.gen
    }

    /// Rewind the cursor to word 0.
    pub fn reset(&mut self) {
        self.rng = self.gen.boxed(self.key.seed(), self.key.ctr());
    }

    /// Open the derived child handle.
    pub fn child(&self, id: u64) -> DynStream {
        DynStream::open(self.gen, self.key.child(id))
    }

    /// Open the sub-stream handle for epoch `t`.
    pub fn epoch(&self, t: u32) -> DynStream {
        DynStream::open(self.gen, self.key.epoch(t))
    }

    /// Draw one sample, advancing the cursor.
    pub fn sample<T, D: Distribution<T> + ?Sized>(&mut self, d: &D) -> T {
        d.sample(self.rng.as_mut())
    }

    /// Key-addressed bulk fill (see [`Stream::fill_u32`]).
    pub fn fill_u32(&self, backend: Option<&mut dyn FillBackend>, out: &mut [u32]) -> Result<()> {
        fill_u32_key(backend, self.gen, self.key, out)
    }

    /// Key-addressed `u64` fill.
    pub fn fill_u64(&self, backend: Option<&mut dyn FillBackend>, out: &mut [u64]) -> Result<()> {
        fill_u64_key(backend, self.gen, self.key, out)
    }

    /// Key-addressed `f32` fill.
    pub fn fill_f32(&self, backend: Option<&mut dyn FillBackend>, out: &mut [f32]) -> Result<()> {
        fill_f32_key(backend, self.gen, self.key, out)
    }

    /// Key-addressed `f64` fill.
    pub fn fill_f64(&self, backend: Option<&mut dyn FillBackend>, out: &mut [f64]) -> Result<()> {
        fill_f64_key(backend, self.gen, self.key, out)
    }

    /// Positioned block fill: words `pos..pos + out.len()` of the key,
    /// routed through the backend offset entry point
    /// ([`FillBackend::fill_u32_at`] on the thread's cached
    /// [`default_backend`]) instead of the host-only positioned cursor
    /// — byte-identical by the §4 offset-fill layout, device-capable
    /// for interior spans.
    pub fn fill_u32_at(&self, pos: u64, out: &mut [u32]) {
        route(None, |b| b.fill_u32_at(self.gen, self.key.seed(), self.key.ctr(), pos, out))
            .expect("offset fills degrade to the infallible host path")
    }

    /// Key-addressed bulk sampling (see [`Stream::sample_fill`]).
    pub fn sample_fill<T, D: Distribution<T> + ?Sized>(
        &self,
        d: &D,
        backend: Option<&mut dyn FillBackend>,
        out: &mut [T],
    ) -> Result<()> {
        route(backend, |b| d.fill_backend(b, self.gen, self.key.seed(), self.key.ctr(), out))
    }
}

#[cfg(feature = "std")]
impl Rng for DynStream {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        self.rng.next_u32()
    }

    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    #[inline]
    fn fill_u32(&mut self, out: &mut [u32]) {
        self.rng.fill_u32(out)
    }
}

/// Hard cap on [`BackendWords`] prefetch (16 MiB of words) — a word
/// source is a streaming abstraction, not a license to materialize the
/// whole period.
pub const MAX_PREFETCH_WORDS: usize = 1 << 22;

/// A keyed word source whose opening words arrive as **one backend
/// prefix fill** (the calibrated `Auto` arm by default) and whose tail
/// — if a consumer reads past the prefetch — continues word-at-a-time
/// from an engine positioned at the boundary.
///
/// Served words are bit-identical to drawing the key's stream directly
/// (the prefetch size is invisible, like the
/// [`crate::stats::battery::BufferedWords`] chunk size); what the
/// prefix fill buys is that bulk generation runs on whichever backend
/// arm the crossover table picks. This is how the statistical batteries
/// drain keyed streams (`openrand stats --key ...`).
#[cfg(feature = "std")]
pub struct BackendWords {
    buf: Vec<u32>,
    pos: usize,
    spill: DynStream,
}

#[cfg(feature = "std")]
impl BackendWords {
    /// A source for `key`'s stream of `gen` with `prefetch` words
    /// (capped at [`MAX_PREFETCH_WORDS`]) materialized through
    /// `backend` (`None` = the calibrated [`default_backend`]).
    pub fn new(
        gen: Generator,
        key: StreamKey,
        prefetch: usize,
        backend: Option<&mut dyn FillBackend>,
    ) -> Result<BackendWords> {
        let n = prefetch.min(MAX_PREFETCH_WORDS);
        let mut buf = vec![0u32; n];
        fill_u32_key(backend, gen, key, &mut buf)?;
        Ok(BackendWords { buf, pos: 0, spill: DynStream::open_at(gen, key, n as u64) })
    }

    /// [`BackendWords::new`] on the default `Auto` route (host arms are
    /// infallible and `Auto` degrades to host, so this cannot fail).
    pub fn auto(gen: Generator, key: StreamKey, prefetch: usize) -> BackendWords {
        BackendWords::new(gen, key, prefetch, None).expect("auto backend fill is infallible")
    }
}

#[cfg(feature = "std")]
impl Rng for BackendWords {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        if self.pos < self.buf.len() {
            let w = self.buf[self.pos];
            self.pos += 1;
            return w;
        }
        self.spill.next_u32()
    }

    #[inline]
    fn fill_u32(&mut self, out: &mut [u32]) {
        let take = (self.buf.len() - self.pos).min(out.len());
        out[..take].copy_from_slice(&self.buf[self.pos..self.pos + take]);
        self.pos += take;
        if take < out.len() {
            Rng::fill_u32(&mut self.spill, &mut out[take..]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Philox, Squares, Tyche};
    use crate::dist::{BoxMuller, Uniform};

    #[test]
    fn derivation_kat_root7_child3_epoch1() {
        // The cross-layer KAT: identical literals pinned by
        // python/tests/test_stream_keys.py.
        let k = StreamKey::root(7).child(3).epoch(1);
        assert_eq!(k.seed(), 0xBC83_12B7_34DE_4237);
        assert_eq!(k.ctr(), 1);
        // Grandchild literal.
        assert_eq!(StreamKey::root(7).child(3).child(5).seed(), 0x2D4C_1D0A_8595_6C49);
        // Epoch separates child spaces.
        assert_eq!(StreamKey::root(7).epoch(2).child(3).seed(), 0x2E49_EAED_C17E_2B71);
    }

    #[test]
    fn derived_stream_kat_philox_words() {
        // The derived stream itself, not just the key: Philox words of
        // root(7).child(3).epoch(1) — the same literals
        // python/tests/test_stream_keys.py pins through the jnp oracle,
        // so host and device agree on *derived* streams end to end.
        let mut s = Stream::<Philox>::new(StreamKey::root(7).child(3).epoch(1));
        assert_eq!(s.next_u32(), 0x9022_9F37);
        assert_eq!(s.next_u32(), 0x89AF_95F5);
        let mut s2 = Stream::<Philox>::new(StreamKey::root(7).child(3).epoch(1));
        assert_eq!(s2.draw_double(), 0.5630282888975542);
    }

    #[test]
    fn raw_is_byte_identical_to_counter_rng_all_engines() {
        for gen in Generator::ALL {
            let key = StreamKey::raw(0xFACE, 9);
            let mut s = DynStream::open(gen, key);
            let mut legacy = gen.boxed(0xFACE, 9);
            for i in 0..256 {
                assert_eq!(s.next_u32(), legacy.next_u32(), "{} word {i}", gen.name());
            }
        }
    }

    #[test]
    fn child_ids_injective_for_fixed_parent() {
        let parent = StreamKey::root(0xABCD).epoch(4);
        let mut seen = std::collections::HashSet::new();
        for id in 0..4096u64 {
            assert!(seen.insert(parent.child(id).seed()), "collision at id {id}");
        }
    }

    #[test]
    fn epoch_is_absolute_and_order_independent() {
        let k = StreamKey::root(0xBEEF);
        assert_eq!(k.epoch(5).epoch(2), k.epoch(2));
        assert_eq!(k.epoch(2), StreamKey::raw(0xBEEF, 2));
        // Children are path-dependent, by contrast.
        assert_ne!(k.child(1).child(2), k.child(2).child(1));
    }

    #[test]
    fn parse_path_spellings() {
        assert_eq!(StreamKey::parse_path("7").unwrap(), StreamKey::root(7));
        assert_eq!(StreamKey::parse_path("0x1F/e3").unwrap(), StreamKey::raw(0x1F, 3));
        assert_eq!(
            StreamKey::parse_path("7/c3/e1").unwrap(),
            StreamKey::root(7).child(3).epoch(1)
        );
        assert_eq!(
            StreamKey::parse_path("42/c0x10/c2").unwrap(),
            StreamKey::root(42).child(0x10).child(2)
        );
        for bad in [
            "",
            "x",
            "7/z3",
            "7/c",
            "7/e",
            "7/e4294967296",
            "7//e1",
            // Signed/underscored/oversized spellings: rejected in
            // lockstep with the python mirror's test_path_errors.
            "7/e-1",
            "7/c-1",
            "-7",
            "+7",
            "0x+1F",
            "1_000",
            "18446744073709551616",
        ] {
            assert!(StreamKey::parse_path(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn display_names_the_resolved_pair() {
        let s = format!("{}", StreamKey::root(7).epoch(3));
        assert!(s.contains("0x0000000000000007") && s.contains("e3"), "{s}");
    }

    #[test]
    fn stream_scalar_draws_match_engine() {
        let mut s = Stream::<Squares>::new(StreamKey::raw(11, 2));
        let mut e = Squares::new(11, 2);
        assert_eq!(s.next_u32(), e.next_u32());
        assert_eq!(s.next_u64(), e.next_u64());
        assert_eq!(s.draw_double().to_bits(), e.draw_double().to_bits());
        s.reset();
        let mut e2 = Squares::new(11, 2);
        assert_eq!(s.next_u32(), e2.next_u32());
    }

    #[test]
    fn stream_fill_matches_serial_fill_and_ignores_cursor() {
        let s = Stream::<Philox>::new(StreamKey::raw(21, 4));
        let mut got = vec![0u32; 300];
        s.fill_u32(None, &mut got).unwrap();
        let mut want = vec![0u32; 300];
        fill::fill_u32::<Philox>(21, 4, &mut want);
        assert_eq!(got, want);
        // f64 path, explicit serial arm.
        let mut f_got = vec![0.0f64; 150];
        s.fill_f64(Some(&mut crate::backend::HostSerial), &mut f_got).unwrap();
        let mut f_want = vec![0.0f64; 150];
        fill::fill_f64::<Philox>(21, 4, &mut f_want);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&f_got), bits(&f_want));
    }

    #[test]
    fn positioned_fill_matches_offset_words() {
        let s = Stream::<Philox>::new(StreamKey::raw(5, 5));
        let mut all = vec![0u32; 100];
        s.fill_u32(None, &mut all).unwrap();
        let mut tail = vec![0u32; 63];
        s.fill_u32_at(37, &mut tail);
        assert_eq!(tail, all[37..], "typed positioned fill");
        let d = DynStream::open(Generator::Philox, StreamKey::raw(5, 5));
        let mut dtail = vec![0u32; 63];
        d.fill_u32_at(37, &mut dtail);
        assert_eq!(dtail, all[37..], "dyn positioned fill");
        // The O(pos) engine exception still lands on the same words.
        let t = DynStream::open(Generator::Tyche, StreamKey::raw(5, 5));
        let mut t_all = vec![0u32; 100];
        t.fill_u32(Some(&mut crate::backend::HostSerial), &mut t_all).unwrap();
        let mut t_tail = vec![0u32; 50];
        t.fill_u32_at(50, &mut t_tail);
        assert_eq!(t_tail, t_all[50..], "tyche positioned fill");
    }

    #[test]
    fn sample_and_sample_fill_match_distribution_paths() {
        let d = BoxMuller::standard();
        let key = StreamKey::root(55).epoch(6);
        // sample == Distribution::sample on the raw engine.
        let mut s = Stream::<Philox>::new(key);
        let mut e = Philox::new(key.seed(), key.ctr());
        for _ in 0..16 {
            assert_eq!(s.sample(&d).to_bits(), crate::dist::Distribution::sample(&d, &mut e).to_bits());
        }
        // sample_fill == repeated sample on a fresh handle, every arm.
        let mut want = vec![0.0f64; 200];
        d.sample_fill(&mut Philox::new(key.seed(), key.ctr()), &mut want);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        let mut got = vec![0.0f64; 200];
        s.sample_fill(&d, None, &mut got).unwrap();
        assert_eq!(bits(&got), bits(&want), "default auto arm");
        let mut par = crate::backend::HostParallel::new(3);
        let mut got2 = vec![0.0f64; 200];
        s.sample_fill(&d, Some(&mut par), &mut got2).unwrap();
        assert_eq!(bits(&got2), bits(&want), "parallel arm");
        // DynStream surface, uniform sampler.
        let u = Uniform::new(-2.0, 2.0);
        let dstream = DynStream::open(Generator::Philox, key);
        let mut uwant = vec![0.0f64; 99];
        u.sample_fill(&mut Philox::new(key.seed(), key.ctr()), &mut uwant);
        let mut ugot = vec![0.0f64; 99];
        dstream.sample_fill(&u, None, &mut ugot).unwrap();
        assert_eq!(bits(&ugot), bits(&uwant));
    }

    #[test]
    fn backend_words_bit_identical_across_prefetch_boundary() {
        let key = StreamKey::root(0xB0B).child(2);
        let gen = Generator::Philox;
        // Tiny prefetch so the test crosses the spill boundary; serving
        // must be seamless and bit-identical to the direct stream.
        let mut src = BackendWords::new(gen, key, 64, None).unwrap();
        let mut direct = DynStream::open(gen, key);
        for i in 0..300 {
            assert_eq!(src.next_u32(), direct.next_u32(), "word {i}");
        }
        // Bulk serving straddling the boundary too.
        let mut src = BackendWords::auto(gen, key, 64);
        let mut direct = DynStream::open(gen, key);
        for len in [10usize, 50, 10, 200] {
            let mut a = vec![0u32; len];
            let mut b = vec![0u32; len];
            Rng::fill_u32(&mut src, &mut a);
            Rng::fill_u32(&mut direct, &mut b);
            assert_eq!(a, b, "len {len}");
        }
        // The sequential engines honor the same boundary contract.
        let key = StreamKey::root(3).child(9);
        let mut src = BackendWords::auto(Generator::Tyche, key, 32);
        let mut direct = DynStream::open(Generator::Tyche, key);
        for i in 0..100 {
            assert_eq!(src.next_u32(), direct.next_u32(), "tyche word {i}");
        }
    }

    #[test]
    fn zero_prefetch_serves_from_the_spill_engine() {
        let key = StreamKey::root(1);
        let mut src = BackendWords::auto(Generator::Squares, key, 0);
        let mut direct = DynStream::open(Generator::Squares, key);
        for _ in 0..50 {
            assert_eq!(src.next_u32(), direct.next_u32());
        }
    }
}
