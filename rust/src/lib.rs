//! # OpenRAND (reproduction) — performance-portable, reproducible RNG for parallel computations
//!
//! Three-layer reproduction of *OpenRAND* (Khan et al., 2023):
//!
//! * **L3 (this crate)** — the counter-based RNG library itself
//!   ([`core`], including the block-granular [`core::BlockRng`] API and
//!   the deterministic bulk [`core::fill`] engine whose output is
//!   bitwise independent of thread count — contracts in
//!   `docs/stream-contracts.md`), the pluggable fill-backend subsystem
//!   ([`backend`]: serial / sharded-parallel / device arms plus a
//!   calibrated `Auto` selector, all byte-identical — see
//!   `docs/backends.md`), baselines ([`baseline`]), distributions ([`dist`]), a
//!   TestU01/PractRand-substitute statistical battery ([`stats`]), the
//!   Brownian-dynamics macro-benchmark substrate ([`sim`]), a
//!   reproducibility-preserving parallel coordinator ([`coordinator`]),
//!   a PJRT runtime ([`runtime`]) that executes the AOT-compiled
//!   device kernels, and a keyed-stream TCP service ([`serve`]) whose
//!   replies are pinned byte-identical to the local CLI — caching,
//!   coalescing, and backpressure without touching a byte
//!   (`docs/serve.md`), and the Tier-1 end-to-end scenario: large-N
//!   simulation campaigns with bitwise checkpoint/resume and a
//!   diffusion-constant physics gate ([`campaign`],
//!   `docs/campaigns.md`).
//! * **L2/L1 (build time)** — JAX graphs + Pallas kernels in
//!   `python/compile/`, lowered once to `artifacts/*.hlo.txt`. Python is
//!   never on the request path.
//!
//! ## Quick start
//!
//! Streams are addressed by typed hierarchical keys and drawn through
//! one handle ([`stream::StreamKey`] + [`stream::Stream`] — the crate's
//! public entry point; the raw engine layer below stays available):
//!
//! ```
//! use openrand::core::{Philox, Rng};
//! use openrand::stream::{Stream, StreamKey};
//! // One unique, reproducible stream per key — no global state, no
//! // init kernel, no hand-assembled (seed, ctr) integers:
//! let key = StreamKey::root(42).child(/*particle=*/ 7).epoch(/*step=*/ 0);
//! let mut s = Stream::<Philox>::new(key);
//! let u = s.draw_float();
//! assert!((0.0..1.0).contains(&u));
//! ```
//!
//! The legacy spelling is a documented equivalence
//! (`StreamKey::raw(seed, ctr)` ⇔ `CounterRng::new(seed, ctr)`,
//! byte-identical):
//!
//! ```
//! use openrand::core::{CounterRng, Philox, Rng};
//! let mut rng = Philox::new(/*seed=*/ 42, /*ctr=*/ 0);
//! let u = rng.draw_float();
//! assert!((0.0..1.0).contains(&u));
//! ```
//!
//! Distribution draws compose with any engine and inherit the stream's
//! reproducibility (every sampler's word consumption is documented in
//! [`dist`]'s contract table):
//!
//! ```
//! use openrand::core::{CounterRng, Philox};
//! use openrand::dist::{BoxMuller, DiscreteAlias, Distribution, ZigguratNormal};
//! let mut rng = Philox::new(42, 0);
//! // Normative normal: bit-compatible with the device graphs.
//! let z = BoxMuller::standard().sample(&mut rng);
//! // Host fast path: ~1 word/sample instead of 4 + trig.
//! let z2 = ZigguratNormal::standard().sample(&mut rng);
//! // O(1) weighted categorical via Walker's alias method.
//! let idx = DiscreteAlias::new(&[0.6, 0.3, 0.1]).sample(&mut rng);
//! assert!(z.is_finite() && z2.is_finite() && idx < 3);
//! ```

// Style policy: explicit index loops are kept wherever the index
// arithmetic *is* the stream contract (word offsets like `2i, 2i+1` —
// see docs/stream-contracts.md §2); iterator rewrites would hide the
// normative offsets clippy-cleanly but reviewer-opaquely.
#![allow(clippy::needless_range_loop)]
// Portability split (the paper's "drop into anything" claim): with
// `--no-default-features` the crate is `#![no_std]` and ships only the
// layers a freestanding target (or the C ABI in `ffi/`) needs — the
// seven engines + `BlockRng` + serial fills ([`core`]), `StreamKey`
// derivation ([`stream`]), the scalar `dist` samplers, and the pinned
// KAT smoke ([`selftest`]). Everything that needs threads, I/O,
// `Instant`, or allocation lives behind the `std` feature below.
#![cfg_attr(not(feature = "std"), no_std)]

#[cfg(feature = "std")]
pub mod backend;
#[cfg(feature = "std")]
pub mod baseline;
#[cfg(feature = "std")]
pub mod bench;
#[cfg(feature = "std")]
pub mod campaign;
#[cfg(feature = "std")]
pub mod coordinator;
pub mod core;
pub mod dist;
#[cfg(feature = "std")]
pub mod runtime;
pub mod selftest;
#[cfg(feature = "std")]
pub mod serve;
#[cfg(feature = "std")]
pub mod sim;
#[cfg(feature = "std")]
pub mod stats;
pub mod stream;
#[cfg(feature = "std")]
pub mod testing;
#[cfg(feature = "std")]
pub mod util;
