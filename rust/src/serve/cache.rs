//! Capacity-bounded LRU cache over fixed-size stream-word blocks.
//!
//! The serve layer materializes streams in aligned [`BLOCK_WORDS`]-word
//! blocks keyed by [`BlockKey`] `(stream key, generator, block index)`.
//! Because a block's content is a pure function of its key — stream
//! words `block·W .. (block+1)·W` of the `(seed, ctr)` stream, exactly
//! what a fresh backend fill would produce — cache hits, misses, and
//! evictions are *byte-invisible by construction*: the only observable
//! difference is latency. `rust/tests/serve.rs` pins that property
//! against uncached fills at arbitrary offsets.
//!
//! Implementation: a `HashMap` into a slab of entries threaded on an
//! intrusive doubly-linked recency list (no per-access allocation, O(1)
//! get/insert/evict). Capacity 0 is a supported degenerate mode: every
//! `insert` is a no-op and every `get` misses, so the serve path runs
//! fully uncached — the property tests exercise exactly this.

use std::collections::HashMap;
use std::sync::Arc;

use crate::core::Generator;
use crate::stream::StreamKey;

/// Words per cache block. 4096 words = 16 KiB per block; with Philox,
/// exactly 1024 counter blocks. Chosen to amortize fill dispatch without
/// making single-element requests fetch megabytes.
pub const BLOCK_WORDS: usize = 4096;

/// Identity of one cached block: stream words
/// `block·BLOCK_WORDS .. (block+1)·BLOCK_WORDS` of `key`'s stream under
/// `gen`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BlockKey {
    pub key: StreamKey,
    pub gen: Generator,
    pub block: u64,
}

/// Sentinel for "no slot" in the intrusive list.
const NIL: usize = usize::MAX;

#[derive(Debug)]
struct Entry {
    key: BlockKey,
    data: Arc<Vec<u32>>,
    prev: usize,
    next: usize,
}

/// LRU block cache. Not internally synchronized — the serve layer wraps
/// it (together with the in-flight fill table) in one mutex.
#[derive(Debug)]
pub struct BlockCache {
    cap: usize,
    map: HashMap<BlockKey, usize>,
    slab: Vec<Entry>,
    free: Vec<usize>,
    /// Most recently used slot.
    head: usize,
    /// Least recently used slot (next eviction victim).
    tail: usize,
}

impl BlockCache {
    /// A cache holding at most `cap` blocks (`cap == 0` disables it).
    pub fn new(cap: usize) -> BlockCache {
        BlockCache {
            cap,
            map: HashMap::new(),
            slab: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Look up a block, promoting it to most-recently-used on a hit.
    pub fn get(&mut self, key: &BlockKey) -> Option<Arc<Vec<u32>>> {
        let slot = *self.map.get(key)?;
        self.unlink(slot);
        self.push_front(slot);
        Some(Arc::clone(&self.slab[slot].data))
    }

    /// Insert (or refresh) a block, evicting the least-recently-used
    /// entry when over capacity. Returns the number of evictions (0 or
    /// 1). With `cap == 0` this is a no-op returning 0.
    pub fn insert(&mut self, key: BlockKey, data: Arc<Vec<u32>>) -> usize {
        if self.cap == 0 {
            return 0;
        }
        if let Some(&slot) = self.map.get(&key) {
            // Same key re-filled: identical bytes by determinism, but
            // refresh the Arc and recency anyway.
            self.slab[slot].data = data;
            self.unlink(slot);
            self.push_front(slot);
            return 0;
        }
        let mut evicted = 0;
        if self.map.len() >= self.cap {
            let victim = self.tail;
            debug_assert_ne!(victim, NIL);
            self.unlink(victim);
            self.map.remove(&self.slab[victim].key);
            self.free.push(victim);
            evicted = 1;
        }
        let slot = match self.free.pop() {
            Some(s) => {
                self.slab[s] = Entry { key, data, prev: NIL, next: NIL };
                s
            }
            None => {
                self.slab.push(Entry { key, data, prev: NIL, next: NIL });
                self.slab.len() - 1
            }
        };
        self.map.insert(key, slot);
        self.push_front(slot);
        evicted
    }

    /// Keys in recency order, most recent first (test introspection).
    pub fn keys_mru(&self) -> Vec<BlockKey> {
        let mut out = Vec::with_capacity(self.map.len());
        let mut slot = self.head;
        while slot != NIL {
            out.push(self.slab[slot].key);
            slot = self.slab[slot].next;
        }
        out
    }

    fn unlink(&mut self, slot: usize) {
        let (prev, next) = (self.slab[slot].prev, self.slab[slot].next);
        match prev {
            NIL => {
                if self.head == slot {
                    self.head = next;
                }
            }
            p => self.slab[p].next = next,
        }
        match next {
            NIL => {
                if self.tail == slot {
                    self.tail = prev;
                }
            }
            n => self.slab[n].prev = prev,
        }
        self.slab[slot].prev = NIL;
        self.slab[slot].next = NIL;
    }

    fn push_front(&mut self, slot: usize) {
        self.slab[slot].prev = NIL;
        self.slab[slot].next = self.head;
        if self.head != NIL {
            self.slab[self.head].prev = slot;
        }
        self.head = slot;
        if self.tail == NIL {
            self.tail = slot;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bk(block: u64) -> BlockKey {
        BlockKey { key: StreamKey::root(7), gen: Generator::Philox, block }
    }

    fn data(v: u32) -> Arc<Vec<u32>> {
        Arc::new(vec![v; 4])
    }

    #[test]
    fn eviction_is_lru_order() {
        let mut c = BlockCache::new(3);
        for b in 0..3 {
            assert_eq!(c.insert(bk(b), data(b)), 0);
        }
        assert_eq!(c.keys_mru(), vec![bk(2), bk(1), bk(0)]);
        // Touch block 0: it becomes most recent, block 1 is now LRU.
        assert!(c.get(&bk(0)).is_some());
        assert_eq!(c.keys_mru(), vec![bk(0), bk(2), bk(1)]);
        // Inserting a 4th block evicts exactly the LRU (block 1).
        assert_eq!(c.insert(bk(3), data(3)), 1);
        assert!(c.get(&bk(1)).is_none());
        assert_eq!(c.keys_mru(), vec![bk(3), bk(0), bk(2)]);
        // Continue evicting in recency order: 2, then 0.
        assert_eq!(c.insert(bk(4), data(4)), 1);
        assert!(c.get(&bk(2)).is_none());
        assert_eq!(c.insert(bk(5), data(5)), 1);
        assert!(c.get(&bk(0)).is_none());
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn capacity_zero_is_passthrough() {
        let mut c = BlockCache::new(0);
        assert_eq!(c.insert(bk(0), data(0)), 0);
        assert!(c.get(&bk(0)).is_none());
        assert_eq!(c.len(), 0);
        assert!(c.is_empty());
        assert!(c.keys_mru().is_empty());
    }

    #[test]
    fn reinsert_refreshes_without_eviction() {
        let mut c = BlockCache::new(2);
        c.insert(bk(0), data(0));
        c.insert(bk(1), data(1));
        // Re-inserting an existing key evicts nothing and promotes it.
        assert_eq!(c.insert(bk(0), data(9)), 0);
        assert_eq!(c.keys_mru(), vec![bk(0), bk(1)]);
        assert_eq!(c.get(&bk(0)).unwrap()[0], 9);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn get_returns_inserted_bytes() {
        let mut c = BlockCache::new(4);
        let d = data(0xABCD);
        c.insert(bk(11), Arc::clone(&d));
        assert_eq!(c.get(&bk(11)).unwrap(), d);
        // Distinct generators / keys / blocks are distinct entries.
        let other = BlockKey { key: StreamKey::root(8), gen: Generator::Philox, block: 11 };
        assert!(c.get(&other).is_none());
        let other = BlockKey { key: StreamKey::root(7), gen: Generator::Squares, block: 11 };
        assert!(c.get(&other).is_none());
    }

    #[test]
    fn capacity_one_churns_correctly() {
        let mut c = BlockCache::new(1);
        for b in 0..16 {
            let ev = c.insert(bk(b), data(b));
            assert_eq!(ev, usize::from(b > 0));
            assert_eq!(c.len(), 1);
            assert_eq!(c.get(&bk(b)).unwrap()[0], b);
        }
    }
}
