//! `openrand serve` — keyed-stream RNG over TCP, byte-identical to the
//! local CLI.
//!
//! The serving thesis (ROADMAP direction 1): once streams are addressed
//! by [`StreamKey`](crate::stream::StreamKey) and fills are positioned,
//! *where* the bytes are produced stops mattering — a remote daemon can
//! hand any client any slice of any stream, and the bytes are pinned
//! byte-identical to `openrand generate --key` for the same
//! `(key path, generator, kind, offset, len)` tuple. That one contract
//! makes the whole stack testable: every reply is checked against a
//! fresh single-threaded replay, across caching, coalescing, and
//! concurrency (`rust/tests/serve.rs`).
//!
//! Layout (each module's docs are normative for its layer; the wire
//! format is additionally documented in `docs/serve.md`):
//!
//! * [`proto`] — length-prefixed binary frames, request/reply types
//!   (FILL / STATS / SHUTDOWN → OK / BUSY / ERROR / STATS_OK / BYE),
//!   and the blocking [`Client`].
//! * [`cache`] — the LRU [`BlockCache`] over aligned
//!   [`BLOCK_WORDS`]-word blocks; byte-invisible by construction.
//! * [`server`] — the coalescing [`StreamService`] core and the
//!   [`Server`] accept/worker topology with bounded-queue backpressure
//!   (typed BUSY shedding).
//! * [`metrics`] — atomic counters behind the STATS request and the
//!   `--metrics-interval` stderr line.
//!
//! Per-tenant namespacing: a FILL names `(tenant, path)` and the server
//! resolves `root(tenant)` extended by `path` — tenants are disjoint by
//! [`derive_child_seed`](crate::stream::derive_child_seed)'s domain
//! separation, and a client cannot name another tenant's derived
//! streams without its tenant id.

pub mod cache;
pub mod metrics;
pub mod proto;
pub mod server;

pub use cache::{BlockCache, BlockKey, BLOCK_WORDS};
pub use metrics::Metrics;
pub use proto::{Client, FillRequest, PayloadKind, Reply, Request};
pub use server::{resolve_key, ServeConfig, Server, StreamService};
