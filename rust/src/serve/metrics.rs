//! Atomic serve counters, exposed over the STATS request and the
//! `--metrics-interval` stderr line.
//!
//! Every counter is observational only: by the cache/coalescing
//! contract (`docs/serve.md` §"Byte-invisibility"), no value here may
//! correlate with a byte difference in any reply. The property tests
//! run the same workload across cache sizes and assert identical bytes
//! while these counters diverge wildly — that is the point.

use std::sync::atomic::{AtomicU64, Ordering};

/// Shared serve counters. All increments are `Relaxed` — they are
/// statistics, not synchronization; the reply bytes are ordered by the
/// service's own locks.
#[derive(Debug, Default)]
pub struct Metrics {
    /// FILL requests received (whether served, errored, or shed).
    pub requests: AtomicU64,
    /// Reply payload bytes written for OK replies.
    pub bytes_out: AtomicU64,
    /// Backend fill calls issued (each covers a run of ≥ 1 blocks).
    pub backend_fills: AtomicU64,
    /// Block fetches satisfied by waiting on another request's in-flight
    /// fill instead of issuing a new one.
    pub coalesced: AtomicU64,
    /// Block fetches served from the LRU cache.
    pub cache_hits: AtomicU64,
    /// Block fetches that had to fill (cache miss, not in flight).
    pub cache_misses: AtomicU64,
    /// Blocks evicted from the LRU cache.
    pub evictions: AtomicU64,
    /// Connections shed with BUSY because the work queue was full.
    pub shed: AtomicU64,
    /// Requests answered with an ERROR reply.
    pub errors: AtomicU64,
    /// Connections currently queued for a worker (gauge).
    pub queue_depth: AtomicU64,
    /// Device param-buffer pool hits (delta-aggregated from the worker
    /// backends' `DeviceFill::pool_stats`; 0 on host-only builds).
    pub pool_hits: AtomicU64,
    /// Device param-buffer pool uploads (same source).
    pub pool_uploads: AtomicU64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    #[inline]
    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn dec(counter: &AtomicU64) {
        counter.fetch_sub(1, Ordering::Relaxed);
    }

    /// Fraction of block fetches served from cache, in [0, 1] (0 when
    /// nothing has been fetched).
    pub fn cache_hit_ratio(&self) -> f64 {
        let hits = self.cache_hits.load(Ordering::Relaxed) as f64;
        let misses = self.cache_misses.load(Ordering::Relaxed) as f64;
        if hits + misses == 0.0 {
            0.0
        } else {
            hits / (hits + misses)
        }
    }

    /// STATS reply body: one `key=value` line per counter. `cache_len`
    /// and `cache_capacity` come from the caller (they live behind the
    /// service lock, not in an atomic).
    pub fn render(&self, cache_len: usize, cache_capacity: usize) -> String {
        let g = |c: &AtomicU64| c.load(Ordering::Relaxed);
        format!(
            "requests={}\nbytes_out={}\nbackend_fills={}\ncoalesced={}\n\
             cache_hits={}\ncache_misses={}\ncache_hit_ratio={:.4}\n\
             cache_evictions={}\ncache_len={}\ncache_capacity={}\n\
             queue_depth={}\nshed={}\nerrors={}\npool_hits={}\npool_uploads={}\n",
            g(&self.requests),
            g(&self.bytes_out),
            g(&self.backend_fills),
            g(&self.coalesced),
            g(&self.cache_hits),
            g(&self.cache_misses),
            self.cache_hit_ratio(),
            g(&self.evictions),
            cache_len,
            cache_capacity,
            g(&self.queue_depth),
            g(&self.shed),
            g(&self.errors),
            g(&self.pool_hits),
            g(&self.pool_uploads),
        )
    }

    /// One-line `--metrics-interval` summary for stderr.
    pub fn summary_line(&self) -> String {
        let g = |c: &AtomicU64| c.load(Ordering::Relaxed);
        format!(
            "serve: requests={} fills={} coalesced={} hit_ratio={:.2} queue={} shed={} errors={}",
            g(&self.requests),
            g(&self.backend_fills),
            g(&self.coalesced),
            self.cache_hit_ratio(),
            g(&self.queue_depth),
            g(&self.shed),
            g(&self.errors),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_contains_every_counter() {
        let m = Metrics::new();
        Metrics::add(&m.requests, 5);
        Metrics::add(&m.cache_hits, 3);
        Metrics::inc(&m.cache_misses);
        let text = m.render(2, 64);
        for needle in [
            "requests=5",
            "cache_hits=3",
            "cache_misses=1",
            "cache_hit_ratio=0.7500",
            "cache_len=2",
            "cache_capacity=64",
            "queue_depth=0",
            "shed=0",
            "pool_hits=0",
        ] {
            assert!(text.contains(needle), "missing {needle} in:\n{text}");
        }
    }

    #[test]
    fn hit_ratio_handles_zero_traffic() {
        assert_eq!(Metrics::new().cache_hit_ratio(), 0.0);
    }

    #[test]
    fn gauge_inc_dec() {
        let m = Metrics::new();
        Metrics::inc(&m.queue_depth);
        Metrics::inc(&m.queue_depth);
        Metrics::dec(&m.queue_depth);
        assert_eq!(m.queue_depth.load(Ordering::Relaxed), 1);
        assert!(m.summary_line().contains("queue=1"));
    }
}
