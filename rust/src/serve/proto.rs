//! The serve wire protocol: length-prefixed binary frames.
//!
//! Normative layout (`docs/serve.md` mirrors this module):
//!
//! ```text
//! frame   := len:u32le payload[len]
//! payload := type:u8 body
//! ```
//!
//! Request types:
//!
//! | type | name     | body |
//! |------|----------|------|
//! | 0x01 | FILL     | tenant:u64le path_len:u16le path[path_len] gen:u8 kind:u8 offset:u64le len:u32le |
//! | 0x02 | STATS    | (empty) |
//! | 0x03 | SHUTDOWN | (empty) |
//!
//! Reply types:
//!
//! | type | name     | body |
//! |------|----------|------|
//! | 0x81 | OK       | raw little-endian element bytes |
//! | 0x82 | BUSY     | (empty) — server queue full, retry later |
//! | 0x83 | ERROR    | UTF-8 message |
//! | 0x84 | STATS_OK | UTF-8 `key=value` lines |
//! | 0x85 | BYE      | (empty) — shutdown acknowledged |
//!
//! A FILL names a stream by `(tenant, path)`: the effective
//! [`StreamKey`](crate::stream::StreamKey) is `parse_path("{tenant}/{path}")`
//! (just `root(tenant)` when `path` is empty), so the server's bytes are
//! pinned byte-identical to `openrand generate --key {tenant}/{path}`
//! *by construction* — both sides resolve the same path grammar.
//! `gen` is an index into [`Generator::ALL`]; `kind` a [`PayloadKind`];
//! `offset`/`len` are in **elements** of that kind (the server maps them
//! onto stream words via [`PayloadKind::words_per_elem`]).

use std::io::{self, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};

use anyhow::{anyhow, bail, Result};

use crate::core::Generator;

/// Hard cap on a relative key path on the wire (defense against
/// malformed length fields; real paths are tens of bytes).
pub const MAX_PATH_BYTES: usize = 512;

/// Hard cap on one FILL's element count (2^22 elements; ≤ 32 MiB of f64
/// payload). Larger consumers split requests — same bytes either way,
/// by the positioned-fill contract.
pub const MAX_FILL_ELEMS: u32 = 1 << 22;

/// Request frames are small and fixed-shape; reject anything larger.
pub const MAX_REQUEST_FRAME: usize = 1024 + MAX_PATH_BYTES;

/// Reply frames carry at most `MAX_FILL_ELEMS` f64s plus the type byte,
/// with slack for STATS text.
pub const MAX_REPLY_FRAME: usize = (MAX_FILL_ELEMS as usize) * 8 + 4096;

const REQ_FILL: u8 = 0x01;
const REQ_STATS: u8 = 0x02;
const REQ_SHUTDOWN: u8 = 0x03;
const REP_OK: u8 = 0x81;
const REP_BUSY: u8 = 0x82;
const REP_ERROR: u8 = 0x83;
const REP_STATS: u8 = 0x84;
const REP_BYE: u8 = 0x85;

/// Element type of a FILL payload. `U32`/`U64`/`F32`/`F64` are the raw
/// formats of `generate --format`; `Normal` is the normative Box–Muller
/// cosine branch of `generate --dist normal` (4 stream words/sample).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PayloadKind {
    U32,
    U64,
    F32,
    F64,
    Normal,
}

impl PayloadKind {
    pub const ALL: [PayloadKind; 5] = [
        PayloadKind::U32,
        PayloadKind::U64,
        PayloadKind::F32,
        PayloadKind::F64,
        PayloadKind::Normal,
    ];

    /// Wire code (index into [`PayloadKind::ALL`]).
    pub fn code(self) -> u8 {
        PayloadKind::ALL.iter().position(|k| *k == self).unwrap() as u8
    }

    pub fn from_code(c: u8) -> Option<PayloadKind> {
        PayloadKind::ALL.get(c as usize).copied()
    }

    /// CLI spelling (`fetch --format`).
    pub fn name(self) -> &'static str {
        match self {
            PayloadKind::U32 => "u32",
            PayloadKind::U64 => "u64",
            PayloadKind::F32 => "f32",
            PayloadKind::F64 => "f64",
            PayloadKind::Normal => "normal",
        }
    }

    pub fn parse(s: &str) -> Option<PayloadKind> {
        PayloadKind::ALL.into_iter().find(|k| k.name() == s)
    }

    /// Stream words consumed per element (§2 conversions; Normal is the
    /// 4-word Box–Muller pair draw).
    pub fn words_per_elem(self) -> usize {
        match self {
            PayloadKind::U32 | PayloadKind::F32 => 1,
            PayloadKind::U64 | PayloadKind::F64 => 2,
            PayloadKind::Normal => 4,
        }
    }

    /// Bytes per element on the wire (little-endian).
    pub fn bytes_per_elem(self) -> usize {
        match self {
            PayloadKind::U32 | PayloadKind::F32 => 4,
            PayloadKind::U64 | PayloadKind::F64 | PayloadKind::Normal => 8,
        }
    }
}

/// One FILL request: elements `offset .. offset+len` of `kind` drawn
/// from the stream `root(tenant)` extended by the relative `path`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FillRequest {
    pub tenant: u64,
    /// Relative key path under the tenant root: `""`, `"c3"`, `"c3/e1"`…
    /// (same segment grammar as `StreamKey::parse_path`, minus the seed).
    pub path: String,
    pub gen: Generator,
    pub kind: PayloadKind,
    /// First element index.
    pub offset: u64,
    /// Element count.
    pub len: u32,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    Fill(FillRequest),
    Stats,
    Shutdown,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Reply {
    /// Raw little-endian element bytes.
    Ok(Vec<u8>),
    /// Bounded queue full — shed, retry later.
    Busy,
    Error(String),
    Stats(String),
    Bye,
}

/// Generator wire code = index into [`Generator::ALL`].
pub fn gen_code(gen: Generator) -> u8 {
    Generator::ALL.iter().position(|g| *g == gen).unwrap() as u8
}

pub fn gen_from_code(c: u8) -> Option<Generator> {
    Generator::ALL.get(c as usize).copied()
}

pub fn encode_request(req: &Request) -> Vec<u8> {
    match req {
        Request::Fill(f) => {
            let mut p = Vec::with_capacity(25 + f.path.len());
            p.push(REQ_FILL);
            p.extend_from_slice(&f.tenant.to_le_bytes());
            p.extend_from_slice(&(f.path.len() as u16).to_le_bytes());
            p.extend_from_slice(f.path.as_bytes());
            p.push(gen_code(f.gen));
            p.push(f.kind.code());
            p.extend_from_slice(&f.offset.to_le_bytes());
            p.extend_from_slice(&f.len.to_le_bytes());
            p
        }
        Request::Stats => vec![REQ_STATS],
        Request::Shutdown => vec![REQ_SHUTDOWN],
    }
}

pub fn decode_request(payload: &[u8]) -> Result<Request> {
    let (&ty, body) = payload.split_first().ok_or_else(|| anyhow!("empty request frame"))?;
    match ty {
        REQ_FILL => {
            let mut c = Cursor::new(body);
            let tenant = c.u64()?;
            let path_len = c.u16()? as usize;
            if path_len > MAX_PATH_BYTES {
                bail!("path length {path_len} exceeds {MAX_PATH_BYTES}");
            }
            let path = String::from_utf8(c.bytes(path_len)?.to_vec())
                .map_err(|_| anyhow!("path is not UTF-8"))?;
            let gen = gen_from_code(c.u8()?).ok_or_else(|| anyhow!("unknown generator code"))?;
            let kind =
                PayloadKind::from_code(c.u8()?).ok_or_else(|| anyhow!("unknown payload kind"))?;
            let offset = c.u64()?;
            let len = c.u32()?;
            c.finish()?;
            Ok(Request::Fill(FillRequest { tenant, path, gen, kind, offset, len }))
        }
        REQ_STATS => {
            ensure_empty(body)?;
            Ok(Request::Stats)
        }
        REQ_SHUTDOWN => {
            ensure_empty(body)?;
            Ok(Request::Shutdown)
        }
        other => bail!("unknown request type 0x{other:02x}"),
    }
}

pub fn encode_reply(rep: &Reply) -> Vec<u8> {
    match rep {
        Reply::Ok(bytes) => {
            let mut p = Vec::with_capacity(1 + bytes.len());
            p.push(REP_OK);
            p.extend_from_slice(bytes);
            p
        }
        Reply::Busy => vec![REP_BUSY],
        Reply::Error(msg) => {
            let mut p = Vec::with_capacity(1 + msg.len());
            p.push(REP_ERROR);
            p.extend_from_slice(msg.as_bytes());
            p
        }
        Reply::Stats(text) => {
            let mut p = Vec::with_capacity(1 + text.len());
            p.push(REP_STATS);
            p.extend_from_slice(text.as_bytes());
            p
        }
        Reply::Bye => vec![REP_BYE],
    }
}

pub fn decode_reply(payload: &[u8]) -> Result<Reply> {
    let (&ty, body) = payload.split_first().ok_or_else(|| anyhow!("empty reply frame"))?;
    match ty {
        REP_OK => Ok(Reply::Ok(body.to_vec())),
        REP_BUSY => {
            ensure_empty(body)?;
            Ok(Reply::Busy)
        }
        REP_ERROR => Ok(Reply::Error(String::from_utf8_lossy(body).into_owned())),
        REP_STATS => Ok(Reply::Stats(String::from_utf8_lossy(body).into_owned())),
        REP_BYE => {
            ensure_empty(body)?;
            Ok(Reply::Bye)
        }
        other => bail!("unknown reply type 0x{other:02x}"),
    }
}

/// Write one `len:u32le + payload` frame.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> io::Result<()> {
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one frame. `Ok(None)` on a clean close (EOF at the length
/// prefix); an error on a mid-frame EOF, or a frame above `max` bytes.
pub fn read_frame(r: &mut impl Read, max: usize) -> io::Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e),
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > max {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame of {len} bytes exceeds the {max}-byte cap"),
        ));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(Some(payload))
}

fn ensure_empty(body: &[u8]) -> Result<()> {
    if !body.is_empty() {
        bail!("{} trailing bytes after request", body.len());
    }
    Ok(())
}

/// Byte-cursor over a request body (strict: over-reads and trailing
/// garbage are protocol errors, not silent truncations).
struct Cursor<'a> {
    buf: &'a [u8],
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() < n {
            bail!("truncated frame (wanted {n} more bytes, have {})", self.buf.len());
        }
        let (head, rest) = self.buf.split_at(n);
        self.buf = rest;
        Ok(head)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.bytes(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn finish(self) -> Result<()> {
        ensure_empty(self.buf)
    }
}

/// Blocking client for the serve protocol (CLI `fetch`, tests, bench).
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Client> {
        Ok(Client { stream: TcpStream::connect(addr)? })
    }

    /// Wrap an already-connected socket (backpressure tests park raw
    /// connections in the server queue and speak the protocol later).
    pub fn from_stream(stream: TcpStream) -> Client {
        Client { stream }
    }

    /// One request/reply round trip.
    pub fn request(&mut self, req: &Request) -> Result<Reply> {
        write_frame(&mut self.stream, &encode_request(req))?;
        let payload = read_frame(&mut self.stream, MAX_REPLY_FRAME)?
            .ok_or_else(|| anyhow!("server closed the connection"))?;
        decode_reply(&payload)
    }

    /// FILL round trip returning the raw element bytes; BUSY and ERROR
    /// become errors (retry policy belongs to the caller).
    pub fn fill(&mut self, req: &FillRequest) -> Result<Vec<u8>> {
        match self.request(&Request::Fill(req.clone()))? {
            Reply::Ok(bytes) => Ok(bytes),
            Reply::Busy => bail!("server busy (queue full)"),
            Reply::Error(msg) => bail!("server error: {msg}"),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    pub fn stats(&mut self) -> Result<String> {
        match self.request(&Request::Stats)? {
            Reply::Stats(text) => Ok(text),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    pub fn shutdown(&mut self) -> Result<()> {
        match self.request(&Request::Shutdown)? {
            Reply::Bye => Ok(()),
            other => bail!("unexpected reply {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_request_roundtrip() {
        let req = Request::Fill(FillRequest {
            tenant: 0xDEAD_BEEF_0123_4567,
            path: "c3/e1".into(),
            gen: Generator::Threefry,
            kind: PayloadKind::F64,
            offset: 9_000_000_000,
            len: 4096,
        });
        assert_eq!(decode_request(&encode_request(&req)).unwrap(), req);
        for req in [Request::Stats, Request::Shutdown] {
            assert_eq!(decode_request(&encode_request(&req)).unwrap(), req);
        }
    }

    #[test]
    fn reply_roundtrip() {
        for rep in [
            Reply::Ok(vec![1, 2, 3, 4]),
            Reply::Ok(vec![]),
            Reply::Busy,
            Reply::Error("no such path".into()),
            Reply::Stats("requests=3\n".into()),
            Reply::Bye,
        ] {
            assert_eq!(decode_reply(&encode_reply(&rep)).unwrap(), rep);
        }
    }

    #[test]
    fn gen_and_kind_codes_roundtrip() {
        for g in Generator::ALL {
            assert_eq!(gen_from_code(gen_code(g)), Some(g));
        }
        assert_eq!(gen_from_code(200), None);
        for k in PayloadKind::ALL {
            assert_eq!(PayloadKind::from_code(k.code()), Some(k));
            assert_eq!(PayloadKind::parse(k.name()), Some(k));
        }
        assert_eq!(PayloadKind::from_code(200), None);
        assert_eq!(PayloadKind::parse("u128"), None);
    }

    #[test]
    fn malformed_requests_rejected() {
        assert!(decode_request(&[]).is_err());
        assert!(decode_request(&[0x7F]).is_err());
        // Truncated FILL body.
        assert!(decode_request(&[REQ_FILL, 1, 2, 3]).is_err());
        // Trailing garbage after a well-formed FILL.
        let mut p = encode_request(&Request::Fill(FillRequest {
            tenant: 1,
            path: String::new(),
            gen: Generator::Philox,
            kind: PayloadKind::U32,
            offset: 0,
            len: 1,
        }));
        p.push(0);
        assert!(decode_request(&p).is_err());
        // Trailing garbage after STATS.
        assert!(decode_request(&[REQ_STATS, 0]).is_err());
        // Over-long path length field.
        let mut p = vec![REQ_FILL];
        p.extend_from_slice(&7u64.to_le_bytes());
        p.extend_from_slice(&u16::MAX.to_le_bytes());
        assert!(decode_request(&p).is_err());
    }

    #[test]
    fn frame_io_roundtrip_and_limits() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r, 64).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut r, 64).unwrap().unwrap(), b"");
        assert_eq!(read_frame(&mut r, 64).unwrap(), None);
        // Over-cap frame rejected without allocating it.
        let mut big = Vec::new();
        write_frame(&mut big, &[0u8; 128]).unwrap();
        assert!(read_frame(&mut &big[..], 64).is_err());
        // Mid-frame EOF is an error, not a clean close.
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        buf.truncate(6);
        assert!(read_frame(&mut &buf[..], 64).is_err());
    }

    #[test]
    fn wire_layout_is_pinned_little_endian() {
        // The endianness pin (portability audit, docs/ffi.md §Layout):
        // every multi-byte integer on the wire is little-endian, byte
        // for byte, regardless of host. A roundtrip test cannot catch a
        // host-endian encode (it would roundtrip fine on the same
        // machine), so this asserts the exact octets of a FILL frame.
        let req = Request::Fill(FillRequest {
            tenant: 0x0102_0304_0506_0708,
            path: "c3/e1".into(),
            gen: Generator::Threefry,
            kind: PayloadKind::F64,
            offset: 0x1122_3344_5566_7788,
            len: 0x000A_0B0C,
        });
        let mut frame = Vec::new();
        write_frame(&mut frame, &encode_request(&req)).unwrap();
        #[rustfmt::skip]
        let want: [u8; 34] = [
            0x1E, 0x00, 0x00, 0x00,                         // len = 30, u32le
            0x01,                                           // REQ_FILL
            0x08, 0x07, 0x06, 0x05, 0x04, 0x03, 0x02, 0x01, // tenant u64le
            0x05, 0x00,                                     // path_len u16le
            b'c', b'3', b'/', b'e', b'1',                   // path bytes
            0x02,                                           // gen = Threefry
            0x03,                                           // kind = F64
            0x88, 0x77, 0x66, 0x55, 0x44, 0x33, 0x22, 0x11, // offset u64le
            0x0C, 0x0B, 0x0A, 0x00,                         // len u32le
        ];
        assert_eq!(frame, want);

        // Wire codes are Generator::ALL / PayloadKind::ALL indices —
        // part of the frozen layout, so pin them by value.
        let gens: Vec<u8> = Generator::ALL.into_iter().map(gen_code).collect();
        assert_eq!(gens, [0, 1, 2, 3, 4, 5, 6]);
        let kinds: Vec<u8> = PayloadKind::ALL.into_iter().map(PayloadKind::code).collect();
        assert_eq!(kinds, [0, 1, 2, 3, 4]);

        // Reply payloads are raw little-endian element bytes.
        let rep = Reply::Ok(0xAABB_CCDDu32.to_le_bytes().to_vec());
        assert_eq!(encode_reply(&rep), [0x81, 0xDD, 0xCC, 0xBB, 0xAA]);
    }
}
