//! The serve daemon: a std-only multi-threaded TCP server whose replies
//! are pinned byte-identical to `openrand generate --key` for the same
//! `(key path, generator, kind, offset, len)` tuple.
//!
//! ## Topology
//!
//! One accept thread pushes connections into a **bounded**
//! `mpsc::sync_channel`; when the queue is full the connection is shed
//! with a typed [`Reply::Busy`] frame instead of stalling the acceptor
//! or growing an unbounded backlog — explicit backpressure, never OOM.
//! A fixed pool of worker threads drains the queue, one connection at a
//! time per worker. Each worker owns its *own* [`Auto`] backend:
//! [`crate::backend::FillBackend`] is deliberately not `Send` (the
//! device arm is thread-confined like the PJRT client it wraps), so
//! backends are constructed inside the worker thread and never cross it.
//!
//! ## Byte pinning
//!
//! [`StreamService::fill_words`] materializes streams in aligned
//! [`BLOCK_WORDS`] blocks through one shared state: an LRU
//! [`BlockCache`] plus an in-flight table that **coalesces** concurrent
//! fills of the same block — the second requester waits on the first
//! fill's slot instead of issuing a duplicate backend call. Because a
//! block's bytes are a pure function of `(key, gen, block)`, hits,
//! waits, and fresh fills are indistinguishable in the reply bytes;
//! only the metrics differ. Runs of missing blocks are filled through
//! the worker's backend arm (host / par / device / auto / sched — the
//! §4 sharding contract makes them all identical): prefix runs via
//! `fill_u32`, interior runs via the offset entry point
//! ([`FillBackend::fill_u32_at`], device-served by the `_at`
//! artifacts). `rust/tests/serve.rs` holds the whole stack to the
//! single-threaded `Stream` replay, across cache sizes including zero.

use std::collections::HashMap;
use std::io::Write as _;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, TrySendError};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::backend::{convert, Auto, FillBackend};
use crate::core::{Generator, Rng as _};
use crate::dist::BoxMuller;
use crate::stream::StreamKey;

use super::cache::{BlockCache, BlockKey, BLOCK_WORDS};
use super::metrics::Metrics;
use super::proto::{
    decode_request, encode_reply, read_frame, write_frame, FillRequest, PayloadKind, Reply,
    Request, MAX_FILL_ELEMS, MAX_REQUEST_FRAME,
};

/// Serve daemon configuration (CLI `openrand serve` flags map 1:1).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address; port 0 picks an ephemeral port (CI smoke uses it).
    pub addr: String,
    /// Worker threads (each owns one backend; one connection at a time).
    pub workers: usize,
    /// Bounded connection-queue depth; beyond it, BUSY is shed.
    pub queue: usize,
    /// LRU cache capacity in [`BLOCK_WORDS`] blocks (0 disables).
    pub cache_blocks: usize,
    /// Host threads inside each worker's `Auto` backend.
    pub fill_threads: usize,
    /// Emit a one-line metrics summary to stderr at this period.
    pub metrics_interval: Option<Duration>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            workers: 4,
            queue: 64,
            cache_blocks: 1024,
            fill_threads: 1,
            metrics_interval: None,
        }
    }
}

/// Resolve a wire `(tenant, path)` pair to the effective [`StreamKey`]:
/// the same `parse_path` grammar `generate --key` uses, rooted at the
/// tenant seed — `root(tenant)` when `path` is empty, else
/// `parse_path("{tenant}/{path}")`. This is what pins serve replies
/// byte-identical to `openrand generate --key {tenant}/{path}`.
pub fn resolve_key(tenant: u64, path: &str) -> Result<StreamKey> {
    if path.is_empty() {
        return Ok(StreamKey::root(tenant));
    }
    StreamKey::parse_path(&format!("{tenant}/{path}"))
        .map_err(|e| anyhow!("bad key path '{path}': {e}"))
}

/// State of one in-flight block fill.
enum SlotState {
    Pending,
    Ready(Arc<Vec<u32>>),
    Failed(String),
}

/// Rendezvous for coalesced waiters on one block fill.
struct Slot {
    state: Mutex<SlotState>,
    cv: Condvar,
}

impl Slot {
    fn new() -> Slot {
        Slot { state: Mutex::new(SlotState::Pending), cv: Condvar::new() }
    }
}

struct Shared {
    cache: BlockCache,
    inflight: HashMap<BlockKey, Arc<Slot>>,
}

/// How the claim pass resolved one block of a request.
enum Got {
    /// Served from the LRU cache.
    Hit(Arc<Vec<u32>>),
    /// Another request is filling it — wait on its slot.
    Wait(Arc<Slot>),
    /// This request owns the fill (slot registered in `inflight`).
    Own(Arc<Slot>),
}

/// The TCP-free serving core: block cache + coalescing + request
/// decoding into bytes. The tests and bench hammer this directly;
/// [`Server`] wraps it in the accept/worker topology.
pub struct StreamService {
    shared: Mutex<Shared>,
    cache_capacity: usize,
    metrics: Arc<Metrics>,
}

impl StreamService {
    pub fn new(cache_blocks: usize, metrics: Arc<Metrics>) -> StreamService {
        StreamService {
            shared: Mutex::new(Shared {
                cache: BlockCache::new(cache_blocks),
                inflight: HashMap::new(),
            }),
            cache_capacity: cache_blocks,
            metrics,
        }
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// STATS reply body (counters + live cache occupancy).
    pub fn stats_text(&self) -> String {
        let shared = self.shared.lock().unwrap();
        self.metrics.render(shared.cache.len(), self.cache_capacity)
    }

    /// Serve one FILL: validate, resolve the key, fetch the word span
    /// through the cache/coalescing core, convert per the §2 contract,
    /// and serialize little-endian. Everything that can be wrong with a
    /// request surfaces here as an error (→ ERROR reply), never a panic.
    pub fn serve_fill(
        &self,
        backend: &mut dyn FillBackend,
        req: &FillRequest,
    ) -> Result<Vec<u8>> {
        if req.len > MAX_FILL_ELEMS {
            bail!("len {} exceeds the per-request cap ({MAX_FILL_ELEMS})", req.len);
        }
        let wpe = req.kind.words_per_elem() as u64;
        let first_word = req
            .offset
            .checked_mul(wpe)
            .filter(|w| *w < 1 << 32)
            .ok_or_else(|| anyhow!("offset {} is outside the 2^32-word stream", req.offset))?;
        let nwords = req.len as u64 * wpe;
        if first_word + nwords > 1 << 32 {
            bail!(
                "offset {} + len {} exceeds the 2^32-word stream period",
                req.offset,
                req.len
            );
        }
        let key = resolve_key(req.tenant, &req.path)?;
        let mut words = vec![0u32; nwords as usize];
        self.fill_words(backend, req.gen, key, first_word, &mut words)?;
        let n = req.len as usize;
        let mut out = Vec::with_capacity(n * req.kind.bytes_per_elem());
        match req.kind {
            PayloadKind::U32 => {
                for w in &words {
                    out.extend_from_slice(&w.to_le_bytes());
                }
            }
            PayloadKind::U64 => {
                let mut tmp = vec![0u64; n];
                convert::u64s(&words, &mut tmp);
                for v in &tmp {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            PayloadKind::F32 => {
                let mut tmp = vec![0.0f32; n];
                convert::f32s(&words, &mut tmp);
                for v in &tmp {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            PayloadKind::F64 => {
                let mut tmp = vec![0.0f64; n];
                convert::f64s(&words, &mut tmp);
                for v in &tmp {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            PayloadKind::Normal => {
                // Standard normal, the Box–Muller cosine branch —
                // sample i ← words 4i..4i+4, exactly `generate --dist
                // normal`'s consumption.
                let mut tmp = vec![0.0f64; n];
                BoxMuller::standard().transform_words(&words, &mut tmp);
                for v in &tmp {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
        Ok(out)
    }

    /// Fetch stream words `first_word .. first_word + out.len()` of
    /// `key`'s stream under `gen`, through the block cache with
    /// coalescing. The caller has validated the span against the 2^32
    /// stream period.
    pub fn fill_words(
        &self,
        backend: &mut dyn FillBackend,
        gen: Generator,
        key: StreamKey,
        first_word: u64,
        out: &mut [u32],
    ) -> Result<()> {
        if out.is_empty() {
            return Ok(());
        }
        let m = &*self.metrics;
        if self.cache_capacity == 0 {
            // Passthrough mode: no cache, no coalescing — one direct
            // fill per request (byte-identical by the fill contracts).
            Metrics::inc(&m.backend_fills);
            return fill_span(backend, gen, key, first_word, out);
        }
        let bw = BLOCK_WORDS as u64;
        let b0 = first_word / bw;
        let b1 = (first_word + out.len() as u64 - 1) / bw;

        // Claim pass: classify every covering block under one lock so
        // concurrent requests agree on exactly one owner per block.
        let mut plan: Vec<(u64, Got)> = Vec::with_capacity((b1 - b0 + 1) as usize);
        {
            let mut shared = self.shared.lock().unwrap();
            for b in b0..=b1 {
                let bk = BlockKey { key, gen, block: b };
                let got = if let Some(data) = shared.cache.get(&bk) {
                    Metrics::inc(&m.cache_hits);
                    Got::Hit(data)
                } else if let Some(slot) = shared.inflight.get(&bk) {
                    Metrics::inc(&m.coalesced);
                    Got::Wait(Arc::clone(slot))
                } else {
                    Metrics::inc(&m.cache_misses);
                    let slot = Arc::new(Slot::new());
                    shared.inflight.insert(bk, Arc::clone(&slot));
                    Got::Own(slot)
                };
                plan.push((b, got));
            }
        }

        // Fill owned blocks in maximal contiguous runs (one backend /
        // positioned fill per run, not per block).
        let owned: Vec<u64> = plan
            .iter()
            .filter_map(|(b, g)| matches!(g, Got::Own(_)).then_some(*b))
            .collect();
        let mut filled: HashMap<u64, Arc<Vec<u32>>> = HashMap::new();
        let mut fill_err: Option<anyhow::Error> = None;
        let mut i = 0;
        while i < owned.len() {
            let mut j = i;
            while j + 1 < owned.len() && owned[j + 1] == owned[j] + 1 {
                j += 1;
            }
            let (rs, re) = (owned[i], owned[j]);
            let span_first = rs * bw;
            let mut buf = vec![0u32; (re - rs + 1) as usize * BLOCK_WORDS];
            Metrics::inc(&m.backend_fills);
            match fill_span(backend, gen, key, span_first, &mut buf) {
                Ok(()) => {
                    for (k, b) in (rs..=re).enumerate() {
                        let chunk = buf[k * BLOCK_WORDS..(k + 1) * BLOCK_WORDS].to_vec();
                        filled.insert(b, Arc::new(chunk));
                    }
                }
                Err(e) => {
                    fill_err = Some(e);
                    break;
                }
            }
            i = j + 1;
        }

        // Publish: cache + un-register under the shared lock, then wake
        // waiters slot by slot (lock order is always shared → slot, and
        // waiters never hold the shared lock — no deadlock).
        {
            let mut shared = self.shared.lock().unwrap();
            for &b in &owned {
                let bk = BlockKey { key, gen, block: b };
                if let Some(data) = filled.get(&b) {
                    let ev = shared.cache.insert(bk, Arc::clone(data));
                    Metrics::add(&m.evictions, ev as u64);
                }
                shared.inflight.remove(&bk);
            }
        }
        for (b, got) in &plan {
            if let Got::Own(slot) = got {
                let mut state = slot.state.lock().unwrap();
                *state = match filled.get(b) {
                    Some(data) => SlotState::Ready(Arc::clone(data)),
                    None => SlotState::Failed(
                        fill_err
                            .as_ref()
                            .map(|e| format!("{e:#}"))
                            .unwrap_or_else(|| "fill aborted".into()),
                    ),
                };
                slot.cv.notify_all();
            }
        }
        if let Some(e) = fill_err {
            return Err(e);
        }

        // Assemble the request span from hit / waited / freshly filled
        // blocks.
        for (b, got) in plan {
            let data = match got {
                Got::Hit(d) => d,
                Got::Wait(slot) => await_slot(&slot)?,
                Got::Own(_) => Arc::clone(filled.get(&b).expect("owned block filled")),
            };
            let block_first = b * bw;
            let lo = first_word.max(block_first);
            let hi = (first_word + out.len() as u64).min(block_first + bw);
            out[(lo - first_word) as usize..(hi - first_word) as usize]
                .copy_from_slice(&data[(lo - block_first) as usize..(hi - block_first) as usize]);
        }
        Ok(())
    }
}

/// One span fill through the worker's backend arm: a prefix span via
/// `fill_u32`, an interior span via the offset entry point
/// ([`FillBackend::fill_u32_at`], served by the `_at` artifacts on the
/// device arm) — byte-identical either way by the backend and §4
/// offset-fill contracts.
fn fill_span(
    backend: &mut dyn FillBackend,
    gen: Generator,
    key: StreamKey,
    first_word: u64,
    out: &mut [u32],
) -> Result<()> {
    if first_word == 0 {
        backend.fill_u32(gen, key.seed(), key.ctr(), out)
    } else {
        backend.fill_u32_at(gen, key.seed(), key.ctr(), first_word, out)
    }
}

/// Wait for a coalesced fill to publish (bounded — a wedged owner
/// surfaces as an ERROR reply, not a hung connection).
fn await_slot(slot: &Slot) -> Result<Arc<Vec<u32>>> {
    let mut state = slot.state.lock().unwrap();
    loop {
        match &*state {
            SlotState::Ready(data) => return Ok(Arc::clone(data)),
            SlotState::Failed(msg) => bail!("coalesced fill failed: {msg}"),
            SlotState::Pending => {
                let (next, timeout) =
                    slot.cv.wait_timeout(state, Duration::from_secs(60)).unwrap();
                state = next;
                if timeout.timed_out() && matches!(&*state, SlotState::Pending) {
                    bail!("timed out waiting for a coalesced fill");
                }
            }
        }
    }
}

/// A running serve daemon (accept thread + worker pool + optional
/// metrics reporter). Dropping without [`Server::shutdown`] /
/// [`Server::run`] detaches the threads; tests always join.
pub struct Server {
    addr: SocketAddr,
    service: Arc<StreamService>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    reporter: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving. Returns once the listener is live (the
    /// resolved address is [`Server::local_addr`] — bind to port 0 for
    /// an ephemeral port).
    pub fn start(cfg: ServeConfig) -> Result<Server> {
        if cfg.workers == 0 {
            bail!("serve needs at least one worker");
        }
        if cfg.queue == 0 {
            // sync_channel(0) is a rendezvous channel — every accept
            // would block on a worker, which is stalling, not shedding.
            bail!("serve queue depth must be at least 1");
        }
        if cfg.fill_threads == 0 {
            bail!("fill threads must be positive");
        }
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let metrics = Arc::new(Metrics::new());
        let service = Arc::new(StreamService::new(cfg.cache_blocks, Arc::clone(&metrics)));
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::sync_channel::<TcpStream>(cfg.queue);
        let rx = Arc::new(Mutex::new(rx));

        let mut workers = Vec::with_capacity(cfg.workers);
        for _ in 0..cfg.workers {
            let rx = Arc::clone(&rx);
            let service = Arc::clone(&service);
            let stop = Arc::clone(&stop);
            let fill_threads = cfg.fill_threads;
            workers.push(std::thread::spawn(move || {
                worker_loop(&rx, &service, &stop, addr, fill_threads)
            }));
        }

        let accept = {
            let metrics = Arc::clone(&metrics);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                for conn in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(conn) = conn else { continue };
                    // Gauge before try_send so a fast worker's decrement
                    // can never observe the counter at zero.
                    Metrics::inc(&metrics.queue_depth);
                    match tx.try_send(conn) {
                        Ok(()) => {}
                        Err(TrySendError::Full(mut conn)) => {
                            Metrics::dec(&metrics.queue_depth);
                            Metrics::inc(&metrics.shed);
                            // Best-effort typed shed; the client sees
                            // BUSY instead of a hang or a reset.
                            let _ = conn.set_nodelay(true);
                            let _ = write_frame(&mut conn, &encode_reply(&Reply::Busy));
                            let _ = conn.flush();
                        }
                        Err(TrySendError::Disconnected(_)) => {
                            Metrics::dec(&metrics.queue_depth);
                            break;
                        }
                    }
                }
                // Dropping tx lets the workers drain the queue and exit.
            })
        };

        let reporter = cfg.metrics_interval.map(|period| {
            let service = Arc::clone(&service);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut elapsed = Duration::ZERO;
                let tick = Duration::from_millis(50);
                while !stop.load(Ordering::SeqCst) {
                    std::thread::sleep(tick);
                    elapsed += tick;
                    if elapsed >= period {
                        elapsed = Duration::ZERO;
                        eprintln!("{}", service.metrics().summary_line());
                    }
                }
            })
        });

        Ok(Server {
            addr,
            service,
            metrics,
            stop,
            accept: Some(accept),
            workers,
            reporter,
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    pub fn service(&self) -> &Arc<StreamService> {
        &self.service
    }

    /// Block until the daemon stops (a client SHUTDOWN request, or
    /// [`Server::shutdown`] from another thread).
    pub fn join(&mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.reporter.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting, drain, and join all threads. Idempotent.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        poke(self.addr);
        self.join();
    }

    /// Run until a client SHUTDOWN arrives (the CLI foreground mode).
    pub fn run(mut self) {
        self.join();
    }
}

/// Wake a listener blocked in `accept` so it can observe the stop flag.
fn poke(addr: SocketAddr) {
    let _ = TcpStream::connect(addr);
}

fn worker_loop(
    rx: &Mutex<Receiver<TcpStream>>,
    service: &StreamService,
    stop: &AtomicBool,
    addr: SocketAddr,
    fill_threads: usize,
) {
    // The backend lives and dies inside this thread (`FillBackend` is
    // not `Send`; the device arm is thread-confined).
    let mut backend = Auto::new(fill_threads);
    let mut last_pool = (0u64, 0u64);
    loop {
        // Holding the receiver lock while blocked in `recv` serializes
        // dequeues across workers; each worker releases it the moment a
        // connection (or disconnect) arrives.
        let conn = { rx.lock().unwrap().recv() };
        let Ok(conn) = conn else { break };
        Metrics::dec(&service.metrics().queue_depth);
        handle_conn(service, &mut backend, conn, stop, addr);
        // Satellite observability: fold the device param-pool deltas
        // into the shared counters after every connection.
        if let Some((hits, uploads)) = backend.device_pool_stats() {
            let m = service.metrics();
            Metrics::add(&m.pool_hits, hits - last_pool.0);
            Metrics::add(&m.pool_uploads, uploads - last_pool.1);
            last_pool = (hits, uploads);
        }
    }
}

/// Serve one connection until it closes, errors, times out, or issues
/// SHUTDOWN.
fn handle_conn(
    service: &StreamService,
    backend: &mut Auto,
    mut conn: TcpStream,
    stop: &AtomicBool,
    addr: SocketAddr,
) {
    let m = Arc::clone(service.metrics());
    let _ = conn.set_nodelay(true);
    // A worker parked on a dead connection is a denial of service on a
    // small pool; bound the idle read.
    let _ = conn.set_read_timeout(Some(Duration::from_secs(30)));
    loop {
        let payload = match read_frame(&mut conn, MAX_REQUEST_FRAME) {
            Ok(Some(p)) => p,
            // Clean close, idle timeout, or transport error: drop the
            // connection; per-stream state lives server-side keyed by
            // the request tuple, so nothing is corrupted.
            Ok(None) | Err(_) => return,
        };
        let req = match decode_request(&payload) {
            Ok(r) => r,
            Err(e) => {
                // A malformed frame means the framing itself is suspect;
                // answer once and hang up rather than desync.
                Metrics::inc(&m.errors);
                let _ = write_frame(&mut conn, &encode_reply(&Reply::Error(format!("{e:#}"))));
                return;
            }
        };
        let ok = match req {
            Request::Fill(f) => {
                Metrics::inc(&m.requests);
                match service.serve_fill(backend, &f) {
                    Ok(bytes) => {
                        Metrics::add(&m.bytes_out, bytes.len() as u64);
                        write_frame(&mut conn, &encode_reply(&Reply::Ok(bytes)))
                    }
                    Err(e) => {
                        Metrics::inc(&m.errors);
                        write_frame(&mut conn, &encode_reply(&Reply::Error(format!("{e:#}"))))
                    }
                }
            }
            Request::Stats => {
                write_frame(&mut conn, &encode_reply(&Reply::Stats(service.stats_text())))
            }
            Request::Shutdown => {
                let _ = write_frame(&mut conn, &encode_reply(&Reply::Bye));
                stop.store(true, Ordering::SeqCst);
                poke(addr);
                return;
            }
        };
        if ok.is_err() {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::HostSerial;
    use crate::core::fill;

    fn service(cache_blocks: usize) -> StreamService {
        StreamService::new(cache_blocks, Arc::new(Metrics::new()))
    }

    fn req(kind: PayloadKind, offset: u64, len: u32) -> FillRequest {
        FillRequest { tenant: 7, path: "c3/e1".into(), gen: Generator::Philox, kind, offset, len }
    }

    /// Reference bytes: a fresh serial engine fill of the same span.
    fn reference(r: &FillRequest) -> Vec<u8> {
        let key = resolve_key(r.tenant, &r.path).unwrap();
        let wpe = r.kind.words_per_elem();
        let n = r.len as usize;
        let mut words = vec![0u32; n * wpe];
        let mut rng = r.gen.boxed_at(key.seed(), key.ctr(), r.offset * wpe as u64);
        rng.fill_u32(&mut words);
        let mut out = Vec::new();
        match r.kind {
            PayloadKind::U32 => {
                words.iter().for_each(|w| out.extend_from_slice(&w.to_le_bytes()))
            }
            PayloadKind::U64 => {
                let mut t = vec![0u64; n];
                convert::u64s(&words, &mut t);
                t.iter().for_each(|v| out.extend_from_slice(&v.to_le_bytes()));
            }
            PayloadKind::F32 => {
                let mut t = vec![0.0f32; n];
                convert::f32s(&words, &mut t);
                t.iter().for_each(|v| out.extend_from_slice(&v.to_le_bytes()));
            }
            PayloadKind::F64 => {
                let mut t = vec![0.0f64; n];
                convert::f64s(&words, &mut t);
                t.iter().for_each(|v| out.extend_from_slice(&v.to_le_bytes()));
            }
            PayloadKind::Normal => {
                let mut t = vec![0.0f64; n];
                BoxMuller::standard().transform_words(&words, &mut t);
                t.iter().for_each(|v| out.extend_from_slice(&v.to_le_bytes()));
            }
        }
        out
    }

    #[test]
    fn serve_fill_matches_reference_all_kinds() {
        for cache_blocks in [0usize, 2, 64] {
            let svc = service(cache_blocks);
            for kind in PayloadKind::ALL {
                for (offset, len) in [(0u64, 16u32), (5, 100), (4096, 7), (10_000, 3000)] {
                    let r = req(kind, offset, len);
                    let got = svc.serve_fill(&mut HostSerial, &r).unwrap();
                    assert_eq!(
                        got,
                        reference(&r),
                        "kind={} offset={offset} len={len} cache={cache_blocks}",
                        kind.name()
                    );
                }
            }
        }
    }

    #[test]
    fn cached_refetch_is_byte_identical_and_hits() {
        let svc = service(64);
        let r = req(PayloadKind::U32, 3, 9000);
        let first = svc.serve_fill(&mut HostSerial, &r).unwrap();
        let misses = svc.metrics().cache_misses.load(Ordering::Relaxed);
        assert!(misses > 0);
        let second = svc.serve_fill(&mut HostSerial, &r).unwrap();
        assert_eq!(first, second);
        assert_eq!(svc.metrics().cache_misses.load(Ordering::Relaxed), misses);
        assert!(svc.metrics().cache_hits.load(Ordering::Relaxed) >= 3);
    }

    #[test]
    fn prefix_words_match_backend_prefix_fill() {
        // Offset-0 spans must equal a plain backend prefix fill — the
        // `generate --key` pinning at the word level.
        let svc = service(16);
        let key = resolve_key(7, "c3/e1").unwrap();
        let mut got = vec![0u32; 6000];
        svc.fill_words(&mut HostSerial, Generator::Philox, key, 0, &mut got).unwrap();
        let mut want = vec![0u32; 6000];
        fill::fill_u32_gen(Generator::Philox, key.seed(), key.ctr(), &mut want);
        assert_eq!(got, want);
        // First word of 7/c3/e1 is the cross-layer KAT value.
        assert_eq!(got[0], 0x9022_9F37);
    }

    #[test]
    fn validation_rejects_bad_requests() {
        let svc = service(4);
        let mut b = HostSerial;
        // Over the per-request cap.
        let r = FillRequest { len: MAX_FILL_ELEMS + 1, ..req(PayloadKind::U32, 0, 0) };
        assert!(svc.serve_fill(&mut b, &r).is_err());
        // Past the stream period (f64: 2 words/elem).
        let r = req(PayloadKind::F64, 1 << 31, 1);
        assert!(svc.serve_fill(&mut b, &r).is_err());
        // Bad path.
        let r = FillRequest { path: "x9".into(), ..req(PayloadKind::U32, 0, 1) };
        assert!(svc.serve_fill(&mut b, &r).is_err());
        // Empty request is fine (zero bytes).
        let r = req(PayloadKind::U32, 0, 0);
        assert_eq!(svc.serve_fill(&mut b, &r).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn last_block_of_stream_serves() {
        // The span ending exactly at word 2^32 must work.
        let svc = service(4);
        let r = req(PayloadKind::U32, (1u64 << 32) - 64, 64);
        let got = svc.serve_fill(&mut HostSerial, &r).unwrap();
        assert_eq!(got, reference(&r));
        // One element past it must not.
        let r = req(PayloadKind::U32, (1u64 << 32) - 64, 65);
        assert!(svc.serve_fill(&mut HostSerial, &r).is_err());
    }

    #[test]
    fn resolve_key_matches_cli_grammar() {
        assert_eq!(resolve_key(7, "").unwrap(), StreamKey::root(7));
        assert_eq!(
            resolve_key(7, "c3/e1").unwrap(),
            StreamKey::parse_path("7/c3/e1").unwrap()
        );
        assert!(resolve_key(7, "bogus").is_err());
    }
}
