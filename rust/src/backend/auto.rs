//! The `Auto` arm: size-based host/device selection from a persisted
//! calibration table.
//!
//! Device block generation only pays off past a dispatch-amortization
//! crossover (`benches/ablation_block.rs` measures it; PRAND and
//! Shoverand report the same shape). [`CrossoverTable`] holds that
//! crossover as "device from N words"; [`Auto`] consults it per fill and
//! otherwise behaves exactly like the arm it selects — all arms are
//! byte-identical, so selection is purely a performance decision and can
//! never change output.
//!
//! Resolution order for the table: `OPENRAND_BACKEND_CROSSOVER` env var
//! (a word count, `k/M/G` suffixes accepted; CLI `--crossover` sets the
//! same knob) → the persisted file next to the artifacts
//! (`<artifacts>/backend_crossover.txt`, written by
//! `benches/fig_backend.rs` under `OPENRAND_PERSIST_CROSSOVER=1`) → the
//! built-in default.

use anyhow::Result;
use std::path::{Path, PathBuf};
use std::time::Instant;

use super::{convert, BackendKind, DeviceFill, FillBackend, HostParallel};
use crate::core::Generator;

/// Persisted host/device crossover calibration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CrossoverTable {
    /// Fills of at least this many u32 words go to the device (when one
    /// is available and supports the engine).
    pub device_min_words: usize,
}

impl Default for CrossoverTable {
    fn default() -> Self {
        CrossoverTable { device_min_words: Self::DEFAULT_DEVICE_MIN_WORDS }
    }
}

impl CrossoverTable {
    /// Conservative default: the `ablation_block` sweep shape — per-call
    /// dispatch overhead swamps device throughput below ~1 Mword on the
    /// CPU PJRT stand-in, so only the largest lowered artifact size
    /// defaults to the device. `fig_backend` re-measures and persists
    /// the real value for the machine at hand.
    pub const DEFAULT_DEVICE_MIN_WORDS: usize = 1 << 20;

    /// Default persistence location: next to the artifacts the device
    /// arm runs (the calibration is meaningless without them).
    pub fn default_path() -> PathBuf {
        crate::runtime::artifact::default_artifact_dir().join("backend_crossover.txt")
    }

    /// Env override → persisted file → default.
    pub fn load() -> CrossoverTable {
        if let Ok(v) = std::env::var("OPENRAND_BACKEND_CROSSOVER") {
            if let Some(t) = Self::from_env_value(&v) {
                return t;
            }
        }
        Self::load_from(&Self::default_path()).unwrap_or_default()
    }

    /// Parse the env/CLI spelling: a word count with optional `k/M/G`.
    pub fn from_env_value(v: &str) -> Option<CrossoverTable> {
        crate::util::cli::parse_with_suffix(v)
            .filter(|&n| n > 0)
            .map(|n| CrossoverTable { device_min_words: n })
    }

    /// Read a persisted table; `None` when missing or malformed (a stale
    /// or hand-mangled calibration must never poison selection).
    pub fn load_from(path: &Path) -> Option<CrossoverTable> {
        let text = std::fs::read_to_string(path).ok()?;
        Self::parse(&text)
    }

    /// Line format: `device_min_words=N` (+ `#` comments).
    pub fn parse(text: &str) -> Option<CrossoverTable> {
        let mut table = None;
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (key, val) = line.split_once('=')?;
            if key.trim() == "device_min_words" {
                let n: usize = val.trim().parse().ok()?;
                if n == 0 {
                    return None;
                }
                table = Some(CrossoverTable { device_min_words: n });
            }
        }
        table
    }

    pub fn render(&self) -> String {
        format!(
            "# openrand backend crossover calibration (see docs/backends.md)\n\
             # measured by `cargo bench --bench fig_backend`\n\
             device_min_words={}\n",
            self.device_min_words
        )
    }

    /// Persist for future `Auto` arms on this machine.
    pub fn persist(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.render())
    }
}

/// The generalized calibration: the legacy crossover *switch* plus
/// measured per-arm sustained throughput, which turns size-based
/// selection into genuine scheduling — [`crate::backend::Sched`] sizes
/// its device shard as the device's fair share of the fill,
/// `device_words_per_sec / (host + device)`.
///
/// Persisted as `<artifacts>/backend_cost_model.txt` (written by
/// `benches/fig_backend.rs` under `OPENRAND_PERSIST_CROSSOVER=1`, a
/// strict superset of the `backend_crossover.txt` line format); loading
/// falls back to a legacy `backend_crossover.txt` (crossover only,
/// rates uncalibrated), so existing calibration files keep working.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    /// The host/device switch point ([`Auto`]'s selection input).
    pub crossover: CrossoverTable,
    /// Sustained host-parallel fill rate (u32 words/sec); `None` until
    /// measured.
    pub host_words_per_sec: Option<f64>,
    /// Sustained device fill rate (words/sec); `None` when unmeasured
    /// or no device arm ever ran.
    pub device_words_per_sec: Option<f64>,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel::from_crossover(CrossoverTable::default())
    }
}

impl CostModel {
    /// A model holding only the crossover switch (rates uncalibrated) —
    /// the shape a legacy `backend_crossover.txt` loads as.
    pub fn from_crossover(crossover: CrossoverTable) -> CostModel {
        CostModel { crossover, host_words_per_sec: None, device_words_per_sec: None }
    }

    /// Default persistence location, next to the artifacts.
    pub fn default_path() -> PathBuf {
        crate::runtime::artifact::default_artifact_dir().join("backend_cost_model.txt")
    }

    /// Cost-model file → legacy crossover file → default, then the
    /// `OPENRAND_BACKEND_CROSSOVER` env override (crossover knob only)
    /// on top — the same resolution order [`CrossoverTable::load`] uses,
    /// extended with the richer file.
    pub fn load() -> CostModel {
        let mut m = Self::load_from(&Self::default_path())
            .or_else(|| {
                CrossoverTable::load_from(&CrossoverTable::default_path())
                    .map(CostModel::from_crossover)
            })
            .unwrap_or_default();
        if let Ok(v) = std::env::var("OPENRAND_BACKEND_CROSSOVER") {
            if let Some(t) = CrossoverTable::from_env_value(&v) {
                m.crossover = t;
            }
        }
        m
    }

    /// Read a persisted model; `None` when missing or malformed.
    pub fn load_from(path: &Path) -> Option<CostModel> {
        let text = std::fs::read_to_string(path).ok()?;
        Self::parse(&text)
    }

    /// Line format: `device_min_words=N` (required) plus optional
    /// `host_words_per_sec=F` / `device_words_per_sec=F` and `#`
    /// comments. Unknown `key=value` lines are skipped (forward
    /// compatibility), any non-`key=value` line poisons the parse —
    /// the exact discipline of [`CrossoverTable::parse`], which can
    /// itself read these files by skipping the rate lines.
    pub fn parse(text: &str) -> Option<CostModel> {
        let mut min_words = None;
        let mut host = None;
        let mut device = None;
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (key, val) = line.split_once('=')?;
            let val = val.trim();
            match key.trim() {
                "device_min_words" => {
                    let n: usize = val.parse().ok()?;
                    if n == 0 {
                        return None;
                    }
                    min_words = Some(n);
                }
                "host_words_per_sec" => {
                    host = val.parse::<f64>().ok().filter(|v| v.is_finite() && *v > 0.0);
                }
                "device_words_per_sec" => {
                    device = val.parse::<f64>().ok().filter(|v| v.is_finite() && *v > 0.0);
                }
                _ => {}
            }
        }
        min_words.map(|n| CostModel {
            crossover: CrossoverTable { device_min_words: n },
            host_words_per_sec: host,
            device_words_per_sec: device,
        })
    }

    pub fn render(&self) -> String {
        let mut s = format!(
            "# openrand backend cost model (see docs/backends.md §Scheduler)\n\
             # measured by `cargo bench --bench fig_backend`; superset of the\n\
             # legacy backend_crossover.txt line format.\n\
             device_min_words={}\n",
            self.crossover.device_min_words
        );
        if let Some(h) = self.host_words_per_sec {
            s.push_str(&format!("host_words_per_sec={h:.0}\n"));
        }
        if let Some(d) = self.device_words_per_sec {
            s.push_str(&format!("device_words_per_sec={d:.0}\n"));
        }
        s
    }

    /// Persist for future scheduler arms on this machine.
    pub fn persist(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.render())
    }

    /// Fraction of a large fill the device shard should take so both
    /// arms finish together: `device / (host + device)` from the
    /// measured rates, `0.5` while uncalibrated. Always in `(0, 1)`.
    pub fn device_fraction(&self) -> f64 {
        match (self.host_words_per_sec, self.device_words_per_sec) {
            (Some(h), Some(d)) if h > 0.0 && d > 0.0 => d / (h + d),
            _ => 0.5,
        }
    }
}

/// One point of the calibration sweep (`fig_backend`).
#[derive(Debug, Clone, Copy)]
pub struct CrossoverSample {
    pub words: usize,
    pub host_ns: f64,
    /// `None` when the device arm is unavailable or refused the size.
    pub device_ns: Option<f64>,
}

/// Measure host-parallel vs device fill latency across `sizes` (median
/// of `reps` timed calls each, ctr bumped per call so the device pool's
/// upload cost is honestly included). This is the `ablation_block`
/// dispatch-amortization measurement, packaged so the bench and tests
/// share it.
pub fn measure_crossover(
    threads: usize,
    sizes: &[usize],
    reps: usize,
) -> Result<Vec<CrossoverSample>> {
    let mut host = HostParallel::new(threads);
    let mut device = DeviceFill::try_new().ok();
    let gen = Generator::Philox;
    let median = |mut v: Vec<f64>| -> f64 {
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[v.len() / 2]
    };
    let mut out = Vec::with_capacity(sizes.len());
    let mut ctr = 0u32;
    for &words in sizes {
        let mut buf = vec![0u32; words];
        let mut host_ns = Vec::with_capacity(reps);
        for _ in 0..reps.max(1) {
            ctr = ctr.wrapping_add(1);
            let t = Instant::now();
            host.fill_u32(gen, 1, ctr, &mut buf)?;
            host_ns.push(t.elapsed().as_nanos() as f64);
        }
        let device_ns = match device.as_mut() {
            Some(d) if d.supports_fill(gen, words) => {
                let mut ns = Vec::with_capacity(reps);
                let mut failed = false;
                for _ in 0..reps.max(1) {
                    ctr = ctr.wrapping_add(1);
                    let t = Instant::now();
                    if d.fill_u32(gen, 1, ctr, &mut buf).is_err() {
                        failed = true;
                        break;
                    }
                    ns.push(t.elapsed().as_nanos() as f64);
                }
                if failed {
                    None
                } else {
                    Some(median(ns))
                }
            }
            _ => None,
        };
        out.push(CrossoverSample { words, host_ns: median(host_ns), device_ns });
    }
    Ok(out)
}

/// Smallest swept size where the device beat the host — the measured
/// `device_min_words`. `None` when the device never won (or never ran):
/// callers should then keep the previous/default table rather than
/// persisting "never", so a flaky run can't disable the device forever.
pub fn recommend(samples: &[CrossoverSample]) -> Option<CrossoverTable> {
    samples
        .iter()
        .find(|s| s.device_ns.is_some_and(|d| d < s.host_ns))
        .map(|s| CrossoverTable { device_min_words: s.words })
}

/// Build a [`CostModel`] from a calibration sweep: the crossover from
/// [`recommend`] (falling back to `fallback` when the device never won,
/// same "no flaky-run poisoning" rule) plus sustained per-arm rates
/// taken from the largest swept size of each arm, where dispatch
/// overhead is best amortized — the regime the shard scheduler
/// operates in.
pub fn cost_model(samples: &[CrossoverSample], fallback: CrossoverTable) -> CostModel {
    let crossover = recommend(samples).unwrap_or(fallback);
    let host = samples
        .iter()
        .filter(|s| s.words > 0 && s.host_ns > 0.0)
        .last()
        .map(|s| s.words as f64 / (s.host_ns * 1e-9));
    let device = samples
        .iter()
        .filter_map(|s| s.device_ns.map(|ns| (s.words, ns)))
        .filter(|&(w, ns)| w > 0 && ns > 0.0)
        .last()
        .map(|(w, ns)| w as f64 / (ns * 1e-9));
    CostModel { crossover, host_words_per_sec: host, device_words_per_sec: device }
}

/// The size-based selector. Owns a host arm, an optional device arm
/// (absent on stub/artifact-less builds), and the calibration table.
pub struct Auto {
    host: HostParallel,
    device: Option<DeviceFill>,
    table: CrossoverTable,
}

impl Auto {
    /// Standard construction: probe the device, load the table through
    /// the env → file → default chain.
    pub fn new(threads: usize) -> Auto {
        Auto::with_table(threads, CrossoverTable::load())
    }

    /// Injection point for tests / CLI `--crossover`.
    pub fn with_table(threads: usize, table: CrossoverTable) -> Auto {
        Auto { host: HostParallel::new(threads), device: DeviceFill::try_new().ok(), table }
    }

    pub fn table(&self) -> CrossoverTable {
        self.table
    }

    pub fn device_available(&self) -> bool {
        self.device.is_some()
    }

    /// `(pool hits, uploads)` of the device arm's param-buffer pool
    /// ([`DeviceFill::pool_stats`]), `None` without a device. The serve
    /// metrics layer delta-aggregates this across worker backends;
    /// `repro --verbose` prints it directly.
    pub fn device_pool_stats(&self) -> Option<(u64, u64)> {
        self.device.as_ref().map(|d| d.pool_stats())
    }

    /// Which arm a `words`-word fill of `gen` will run on. Pure function
    /// of `(gen, words, table, availability)` — the repro ladder asserts
    /// the output is byte-identical either way.
    pub fn selection(&self, gen: Generator, words: usize) -> BackendKind {
        match &self.device {
            Some(d) if words >= self.table.device_min_words && d.supports_fill(gen, words) => {
                BackendKind::Device
            }
            _ => BackendKind::HostParallel,
        }
    }

    /// Route one u32 fill. A device-side execution error degrades to the
    /// host arm (byte-identical by contract), it never aborts the fill.
    fn route_u32(&mut self, gen: Generator, seed: u64, ctr: u32, out: &mut [u32]) -> Result<()> {
        if self.selection(gen, out.len()) == BackendKind::Device {
            if let Some(d) = self.device.as_mut() {
                if d.fill_u32(gen, seed, ctr, out).is_ok() {
                    return Ok(());
                }
            }
        }
        self.host.fill_u32(gen, seed, ctr, out)
    }
}

impl FillBackend for Auto {
    fn kind(&self) -> BackendKind {
        BackendKind::Auto
    }

    fn fill_u32(&mut self, gen: Generator, seed: u64, ctr: u32, out: &mut [u32]) -> Result<()> {
        self.route_u32(gen, seed, ctr, out)
    }

    fn fill_u32_at(
        &mut self,
        gen: Generator,
        seed: u64,
        ctr: u32,
        start: u64,
        out: &mut [u32],
    ) -> Result<()> {
        if self.selection(gen, out.len()) == BackendKind::Device {
            if let Some(d) = self.device.as_mut() {
                if d.supports_fill_at(gen, start, out.len())
                    && d.fill_u32_at(gen, seed, ctr, start, out).is_ok()
                {
                    return Ok(());
                }
            }
        }
        self.host.fill_u32_at(gen, seed, ctr, start, out)
    }

    // Typed fills: selection is by *word* count (2 words per u64/f64
    // element). The host arm keeps its native alloc-free paths; the
    // device route fetches words via `route_u32` (which itself degrades
    // to host on a device error, so it cannot fail) and applies the
    // shared `convert` helpers — the same bytes by the conversion
    // contract.

    fn fill_u64(&mut self, gen: Generator, seed: u64, ctr: u32, out: &mut [u64]) -> Result<()> {
        if self.selection(gen, 2 * out.len()) == BackendKind::Device {
            let mut words = vec![0u32; 2 * out.len()];
            self.route_u32(gen, seed, ctr, &mut words)?;
            convert::u64s(&words, out);
            return Ok(());
        }
        self.host.fill_u64(gen, seed, ctr, out)
    }

    fn fill_f32(&mut self, gen: Generator, seed: u64, ctr: u32, out: &mut [f32]) -> Result<()> {
        if self.selection(gen, out.len()) == BackendKind::Device {
            let mut words = vec![0u32; out.len()];
            self.route_u32(gen, seed, ctr, &mut words)?;
            convert::f32s(&words, out);
            return Ok(());
        }
        self.host.fill_f32(gen, seed, ctr, out)
    }

    fn fill_f64(&mut self, gen: Generator, seed: u64, ctr: u32, out: &mut [f64]) -> Result<()> {
        if self.selection(gen, 2 * out.len()) == BackendKind::Device {
            let mut words = vec![0u32; 2 * out.len()];
            self.route_u32(gen, seed, ctr, &mut words)?;
            convert::f64s(&words, out);
            return Ok(());
        }
        self.host.fill_f64(gen, seed, ctr, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::HostSerial;

    #[test]
    fn table_parse_roundtrip() {
        let t = CrossoverTable { device_min_words: 123_456 };
        assert_eq!(CrossoverTable::parse(&t.render()), Some(t));
        assert_eq!(
            CrossoverTable::parse("# only comments\n\n"),
            None,
            "no key -> no table"
        );
        assert_eq!(CrossoverTable::parse("device_min_words=0"), None);
        assert_eq!(CrossoverTable::parse("garbage"), None);
        assert_eq!(
            CrossoverTable::parse("device_min_words=64\n"),
            Some(CrossoverTable { device_min_words: 64 })
        );
    }

    #[test]
    fn env_value_spellings() {
        assert_eq!(
            CrossoverTable::from_env_value("64k"),
            Some(CrossoverTable { device_min_words: 65_536 })
        );
        assert_eq!(
            CrossoverTable::from_env_value("1M"),
            Some(CrossoverTable { device_min_words: 1 << 20 })
        );
        assert_eq!(CrossoverTable::from_env_value("0"), None);
        assert_eq!(CrossoverTable::from_env_value("nope"), None);
    }

    #[test]
    fn cost_model_parse_roundtrip_and_legacy_interop() {
        let m = CostModel {
            crossover: CrossoverTable { device_min_words: 262_144 },
            host_words_per_sec: Some(2.0e9),
            device_words_per_sec: Some(6.0e9),
        };
        assert_eq!(CostModel::parse(&m.render()), Some(m));
        // A legacy crossover file is a valid (rate-less) cost model...
        let legacy = CrossoverTable { device_min_words: 4096 };
        assert_eq!(
            CostModel::parse(&legacy.render()),
            Some(CostModel::from_crossover(legacy))
        );
        // ...and the legacy parser reads the new file, skipping rates.
        assert_eq!(
            CrossoverTable::parse(&m.render()),
            Some(CrossoverTable { device_min_words: 262_144 })
        );
        // Same poison rules as the table.
        assert_eq!(CostModel::parse("host_words_per_sec=1e9\n"), None, "no crossover -> no model");
        assert_eq!(CostModel::parse("device_min_words=0"), None);
        assert_eq!(CostModel::parse("garbage"), None);
        // Bad rates degrade to uncalibrated, they don't poison.
        assert_eq!(
            CostModel::parse("device_min_words=64\nhost_words_per_sec=-3\n"),
            Some(CostModel::from_crossover(CrossoverTable { device_min_words: 64 }))
        );
    }

    #[test]
    fn cost_model_device_fraction() {
        let mut m = CostModel::default();
        assert_eq!(m.device_fraction(), 0.5, "uncalibrated -> even split");
        m.host_words_per_sec = Some(1.0e9);
        assert_eq!(m.device_fraction(), 0.5, "one-sided -> still even");
        m.device_words_per_sec = Some(3.0e9);
        assert!((m.device_fraction() - 0.75).abs() < 1e-12);
        let f = m.device_fraction();
        assert!(f > 0.0 && f < 1.0);
    }

    #[test]
    fn cost_model_from_samples() {
        let s = |w: usize, h: f64, d: Option<f64>| CrossoverSample {
            words: w,
            host_ns: h,
            device_ns: d,
        };
        let samples = vec![
            s(1 << 16, 100.0, Some(120.0)),
            // Largest size: 2^20 words in 1 ms host / 0.5 ms device.
            s(1 << 20, 1.0e6, Some(0.5e6)),
        ];
        let m = cost_model(&samples, CrossoverTable::default());
        assert_eq!(m.crossover.device_min_words, 1 << 20);
        let h = m.host_words_per_sec.unwrap();
        let d = m.device_words_per_sec.unwrap();
        assert!((h - (1u64 << 20) as f64 / 1.0e-3).abs() / h < 1e-9);
        assert!((d - (1u64 << 20) as f64 / 0.5e-3).abs() / d < 1e-9);
        // Device never ran: crossover keeps the fallback, host rate still
        // measured, device rate absent.
        let host_only = cost_model(
            &[s(1 << 16, 100.0, None)],
            CrossoverTable { device_min_words: 777 },
        );
        assert_eq!(host_only.crossover.device_min_words, 777);
        assert!(host_only.host_words_per_sec.is_some());
        assert!(host_only.device_words_per_sec.is_none());
    }

    #[test]
    fn cost_model_persist_and_reload() {
        let dir = std::env::temp_dir().join("openrand_cost_model_test");
        let path = dir.join("backend_cost_model.txt");
        let m = CostModel {
            crossover: CrossoverTable { device_min_words: 8192 },
            host_words_per_sec: Some(1.5e9),
            device_words_per_sec: None,
        };
        m.persist(&path).unwrap();
        assert_eq!(CostModel::load_from(&path), Some(m));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn persist_and_reload() {
        let dir = std::env::temp_dir().join("openrand_crossover_test");
        let path = dir.join("backend_crossover.txt");
        let t = CrossoverTable { device_min_words: 4096 };
        t.persist(&path).unwrap();
        assert_eq!(CrossoverTable::load_from(&path), Some(t));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn auto_is_byte_identical_to_its_selection() {
        // Below and above the crossover, with and without a device, the
        // bytes must equal the serial reference.
        let table = CrossoverTable { device_min_words: 512 };
        let mut auto = Auto::with_table(3, table);
        for gen in [Generator::Philox, Generator::Tyche] {
            for n in [100usize, 511, 512, 4096] {
                let sel = auto.selection(gen, n);
                let mut got = vec![0u32; n];
                auto.fill_u32(gen, 0xA0, 9, &mut got).unwrap();
                let mut want = vec![0u32; n];
                HostSerial.fill_u32(gen, 0xA0, 9, &mut want).unwrap();
                assert_eq!(got, want, "{} n={n} sel={}", gen.name(), sel.name());
            }
        }
    }

    #[test]
    fn selection_respects_support_and_size() {
        let mut auto = Auto::with_table(2, CrossoverTable { device_min_words: 1000 });
        // TycheI has no device artifact of either family: always host.
        assert_eq!(auto.selection(Generator::TycheI, 1 << 20), BackendKind::HostParallel);
        // Below the crossover: host, regardless of device availability.
        assert_eq!(auto.selection(Generator::Philox, 999), BackendKind::HostParallel);
        if auto.device_available() {
            assert_eq!(auto.selection(Generator::Philox, 65_536), BackendKind::Device);
        } else {
            // Stub build: everything host; fills still work.
            assert_eq!(auto.selection(Generator::Philox, 1 << 20), BackendKind::HostParallel);
            let mut out = vec![0.0f64; 64];
            auto.fill_f64(Generator::Philox, 1, 1, &mut out).unwrap();
        }
    }

    #[test]
    fn recommend_picks_first_device_win() {
        let s = |w: usize, h: f64, d: Option<f64>| CrossoverSample {
            words: w,
            host_ns: h,
            device_ns: d,
        };
        let samples = vec![
            s(1 << 12, 10.0, Some(100.0)),
            s(1 << 16, 100.0, Some(120.0)),
            s(1 << 20, 1000.0, Some(800.0)),
        ];
        assert_eq!(
            recommend(&samples),
            Some(CrossoverTable { device_min_words: 1 << 20 })
        );
        assert_eq!(recommend(&[s(1 << 12, 10.0, None)]), None);
    }

    #[test]
    fn measure_runs_host_side_everywhere() {
        // Tiny smoke: the measurement harness itself must work without
        // a device (device_ns = None on stub builds).
        let samples = measure_crossover(2, &[1 << 10, 1 << 12], 3).unwrap();
        assert_eq!(samples.len(), 2);
        for s in &samples {
            assert!(s.host_ns > 0.0, "host timing at {} words", s.words);
        }
    }
}
