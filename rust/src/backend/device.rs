//! The device arm: AOT block artifacts through the PJRT runtime.
//!
//! ## Counter-layout mapping (why this is bitwise-safe)
//!
//! The `{gen}_u32_{n}` artifacts (lowered by `python/compile/aot.py`)
//! emit **stream order**: grid block `j` of the Pallas kernel computes
//! counter block `j` of the `(seed, ctr)` stream and writes it at output
//! words `W·j .. W·j + W` — the same `position → word` mapping
//! `core::fill` uses on the host, so `device_output[0..len]` is exactly
//! `fill_u32(seed, ctr, out[0..len])`. The host sharding in
//! `par_fill_*` shards that same index space, which is how all three
//! arms land every output element in the same position.
//!
//! Supported engines: Philox, Threefry, Squares (their `{gen}_u32_{n}`
//! artifacts are stream-ordered) and Tyche through the stream-ordered
//! `tyche_u32_at_{n}` artifact (a sequential scan graph — the *other*
//! tyche artifact, `tyche_u32_{n}`, is **lane-major**: lane `i` holds
//! the first word of stream `(seed, ctr ^ i)`, a breadth-first layout
//! for per-lane micro-streams, see `kernels/tyche.py`, and is never used
//! for fills). The 2x32 engines and Tyche-i have no lowered stream
//! artifacts and report unsupported.
//!
//! ## Offset fills (`fill_u32_at`)
//!
//! The `{gen}_u32_at_{n}` artifact family parameterizes the formerly
//! unused 4th params word as the **starting counter-block index**
//! (philox/threefry; stream word = `4·base`) or **starting word index**
//! (squares/tyche). An interior span `start..start+len` is served by the
//! artifact at `base = start / W` with the first `start % W` words of
//! the returned block skipped — bitwise the same slice the host engines
//! produce, which is what lets the shard scheduler hand the device an
//! interior shard. Stores lowered before this family existed simply
//! error here (and schedulers degrade to host), exactly like a missing
//! prefix artifact.
//!
//! ## Buffer pool
//!
//! PJRT dispatch cost is dominated by host↔device marshalling of inputs
//! for small calls (`benches/ablation_block.rs`). The only input of a
//! block artifact is the 16-byte `(seed, ctr)` params vector, so the
//! pool caches the **uploaded device buffer per `(artifact, params)`**:
//! repeated fills of the same stream (the common bench/sim shape —
//! refill every step with a bumped ctr is one upload per distinct ctr,
//! re-running the same stream is zero) skip the upload entirely and go
//! straight to `execute_b`. Non-chainable (tuple-wrapped legacy)
//! artifacts fall back to the literal path per call.

use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

use super::{BackendKind, FillBackend};
use crate::core::{counter, Generator};
use crate::runtime::exec::{Arg, DeviceGraph};
use crate::runtime::ArtifactStore;

/// Block sizes `aot.py` lowers for every stream-ordered generator.
pub const ARTIFACT_SIZES: [usize; 2] = [65_536, 1_048_576];

/// Largest buffer a single device fill can serve (the biggest artifact).
pub const MAX_DEVICE_WORDS: usize = ARTIFACT_SIZES[ARTIFACT_SIZES.len() - 1];

/// Cap on pooled param buffers (16 B each on device; the cap only guards
/// against pathological ctr churn).
const POOL_CAP: usize = 256;

/// Map a generator to its artifact name prefix and the 4-word params
/// vector its kernel expects (`kernels/*.py` headers are normative),
/// with params word 3 (the base index) zero. `None` = no stream-ordered
/// artifact family for this engine.
fn artifact_params(gen: Generator, seed: u64, ctr: u32) -> Option<(&'static str, [u32; 4])> {
    match gen {
        // philox/threefry kernels take [seed_lo, seed_hi, ctr, base].
        Generator::Philox => Some(("philox", [seed as u32, (seed >> 32) as u32, ctr, 0])),
        Generator::Threefry => Some(("threefry", [seed as u32, (seed >> 32) as u32, ctr, 0])),
        // squares takes the derived key: [key_lo, key_hi, ctr, base].
        Generator::Squares => {
            let key = counter::squares_key(seed);
            Some(("squares", [key as u32, (key >> 32) as u32, ctr, 0]))
        }
        // tyche is served by the stream-ordered scan artifact
        // (`tyche_u32_at_{n}`, [seed_lo, seed_hi, ctr, base_word]) —
        // NOT the lane-major `tyche_u32_{n}`.
        Generator::Tyche => Some(("tyche", [seed as u32, (seed >> 32) as u32, ctr, 0])),
        // 2x32 and tyche_i have no lowered stream artifacts.
        _ => None,
    }
}

/// Output words per counter block of `gen`'s artifact — the unit of the
/// base index in params word 3 (stream word = `W·base`).
fn words_per_block(gen: Generator) -> u64 {
    match gen {
        Generator::Philox | Generator::Threefry => 4,
        _ => 1,
    }
}

/// Whether prefix fills of `gen` run through the `_at` artifact family
/// at base 0 (true only for tyche, whose base-less artifact name is the
/// unrelated lane-major layout).
fn prefix_uses_at_family(gen: Generator) -> bool {
    gen == Generator::Tyche
}

/// Artifact name for `prefix` at block size `n`, `_at` family or not.
fn artifact_name(prefix: &str, at: bool, n: usize) -> String {
    if at {
        format!("{prefix}_u32_at_{n}")
    } else {
        format!("{prefix}_u32_{n}")
    }
}

/// Base index (params word 3) and leading words to skip for a span
/// starting at stream word `start`. `None` when the base exceeds the
/// artifact's u32 parameter — except Squares, whose stream period *is*
/// 2^32 words, so the u32 wrap is the engine's own counter arithmetic.
fn base_and_skip(gen: Generator, start: u64) -> Option<(u32, usize)> {
    let w = words_per_block(gen);
    let base = start / w;
    let skip = (start % w) as usize;
    if gen == Generator::Squares || base <= u32::MAX as u64 {
        Some((base as u32, skip))
    } else {
        None
    }
}

/// The device fill backend. Thread-confined (wraps the per-thread PJRT
/// client); construct one per driver thread.
pub struct DeviceFill {
    store: ArtifactStore,
    /// Compiled graphs by artifact name (compile-once on top of the
    /// store's own executable cache — this keeps the parsed signature).
    graphs: HashMap<String, DeviceGraph>,
    /// Uploaded params buffers by `(artifact, params)` — the pool.
    params_pool: HashMap<(String, [u32; 4]), xla::PjRtBuffer>,
    pool_hits: u64,
    pool_uploads: u64,
}

impl DeviceFill {
    /// Open the artifact store and prove a real PJRT backend exists by
    /// compiling the first stream-ordered block graph the store holds.
    /// Fails cleanly (so callers can degrade to host) when artifacts
    /// are missing or the vendored `xla` stub is in use.
    pub fn try_new() -> Result<DeviceFill> {
        let store = ArtifactStore::open_default()?;
        let mut dev = DeviceFill {
            store,
            graphs: HashMap::new(),
            params_pool: HashMap::new(),
            pool_hits: 0,
            pool_uploads: 0,
        };
        // Availability probe: compiling requires a real backend; with
        // the stub this is where "unavailable" surfaces. Probe whichever
        // stream-ordered artifact the store actually has — a store
        // missing one engine's blocks must not disable the others.
        let probe = dev.probe_artifact().ok_or_else(|| {
            anyhow!("no stream-ordered block artifacts in the store (run `make artifacts`)")
        })?;
        dev.graph(&probe)?;
        Ok(dev)
    }

    /// First stream-ordered artifact present in the manifest.
    fn probe_artifact(&self) -> Option<String> {
        ["philox", "threefry", "squares"].iter().find_map(|prefix| {
            ARTIFACT_SIZES.iter().find_map(|n| {
                [artifact_name(prefix, false, *n), artifact_name(prefix, true, *n)]
                    .into_iter()
                    .find(|name| self.store.manifest.get(name).is_some())
            })
        })
    }

    /// Whether this arm can serve `gen` at all (a stream-ordered
    /// artifact family is lowered for it — for tyche that is the `_at`
    /// scan family, see the module header).
    pub fn supports(&self, gen: Generator) -> bool {
        artifact_params(gen, 0, 0)
            .map(|(prefix, _)| {
                let at = prefix_uses_at_family(gen);
                ARTIFACT_SIZES
                    .iter()
                    .any(|&n| self.store.manifest.get(&artifact_name(prefix, at, n)).is_some())
            })
            .unwrap_or(false)
    }

    /// Whether a `len`-word prefix fill of `gen` fits a single lowered
    /// artifact.
    pub fn supports_fill(&self, gen: Generator, len: usize) -> bool {
        artifact_params(gen, 0, 0)
            .map(|(prefix, _)| {
                self.pick_artifact(prefix, prefix_uses_at_family(gen), len).is_some()
            })
            .unwrap_or(false)
    }

    /// Whether an interior span `start..start + len` of `gen` can be
    /// served through the `_at` artifact family (present, span fits,
    /// base index representable — the `fill_u32_at` preconditions).
    pub fn supports_fill_at(&self, gen: Generator, start: u64, len: usize) -> bool {
        if start == 0 {
            return self.supports_fill(gen, len);
        }
        let Some((prefix, _)) = artifact_params(gen, 0, 0) else { return false };
        match base_and_skip(gen, start) {
            Some((_, skip)) => self.pick_artifact(prefix, true, skip + len).is_some(),
            None => false,
        }
    }

    /// `(pool hits, uploads)` — observability for the pool's claim that
    /// repeated fills don't re-upload counters.
    pub fn pool_stats(&self) -> (u64, u64) {
        (self.pool_hits, self.pool_uploads)
    }

    /// Smallest lowered artifact (name, size) covering `len` words, in
    /// the prefix (`at=false`) or offset (`at=true`) family.
    fn pick_artifact(&self, prefix: &str, at: bool, len: usize) -> Option<(String, usize)> {
        ARTIFACT_SIZES.iter().copied().filter(|&n| n >= len).find_map(|n| {
            let name = artifact_name(prefix, at, n);
            self.store.manifest.get(&name).map(|_| (name, n))
        })
    }

    fn graph(&mut self, name: &str) -> Result<&DeviceGraph> {
        if !self.graphs.contains_key(name) {
            let g = DeviceGraph::load(&self.store, name)?;
            self.graphs.insert(name.to_string(), g);
        }
        Ok(&self.graphs[name])
    }

    /// Run artifact `name` with `params`, pooling the uploaded params
    /// buffer so repeated fills of the same stream skip the upload.
    fn call_block(&mut self, name: &str, params: [u32; 4]) -> Result<Vec<u32>> {
        // Populate the graph cache, then re-index: the field borrow of
        // `graphs` stays disjoint from the pool mutations below.
        self.graph(name)?;
        let graph = &self.graphs[name];
        if !graph.chainable() {
            // Legacy tuple-wrapped artifact: literal path, no pooling.
            return graph.call_u32(&[Arg::U32(&params)]);
        }
        let key = (name.to_string(), params);
        if !self.params_pool.contains_key(&key) {
            if self.params_pool.len() >= POOL_CAP {
                self.params_pool.clear();
            }
            let buf = graph.buffer_from_u32(&params, 0)?;
            self.params_pool.insert(key.clone(), buf);
            self.pool_uploads += 1;
        } else {
            self.pool_hits += 1;
        }
        let params_buf = &self.params_pool[&key];
        let out_buf = graph.call_b(&[params_buf])?;
        graph.buffer_to_u32(&out_buf)
    }
}

impl FillBackend for DeviceFill {
    fn kind(&self) -> BackendKind {
        BackendKind::Device
    }

    fn fill_u32(&mut self, gen: Generator, seed: u64, ctr: u32, out: &mut [u32]) -> Result<()> {
        if out.is_empty() {
            return Ok(());
        }
        let (prefix, params) = artifact_params(gen, seed, ctr).ok_or_else(|| {
            anyhow!(
                "no stream-ordered device artifact for generator '{}' \
                 (device arm serves philox|threefry|squares|tyche)",
                gen.name()
            )
        })?;
        let at = prefix_uses_at_family(gen);
        let Some((name, n_art)) = self.pick_artifact(prefix, at, out.len()) else {
            bail!(
                "fill of {} words exceeds the largest '{prefix}' block artifact \
                 ({MAX_DEVICE_WORDS}) or the family is not lowered; \
                 use a host arm or split across ctr values",
                out.len()
            );
        };
        debug_assert!(n_art >= out.len());
        let words = self.call_block(&name, params)?;
        if words.len() < out.len() {
            bail!("artifact '{name}' returned {} words, need {}", words.len(), out.len());
        }
        // The artifact computes the full block; a shorter request is the
        // stream prefix (identical to the host fill from position 0).
        out.copy_from_slice(&words[..out.len()]);
        Ok(())
    }

    fn fill_u32_at(
        &mut self,
        gen: Generator,
        seed: u64,
        ctr: u32,
        start: u64,
        out: &mut [u32],
    ) -> Result<()> {
        if start == 0 {
            // Byte-stable with pre-`_at` artifact stores: prefix fills
            // keep running through the prefix family.
            return self.fill_u32(gen, seed, ctr, out);
        }
        if out.is_empty() {
            return Ok(());
        }
        let (prefix, mut params) = artifact_params(gen, seed, ctr).ok_or_else(|| {
            anyhow!(
                "no stream-ordered device artifact for generator '{}' \
                 (device arm serves philox|threefry|squares|tyche)",
                gen.name()
            )
        })?;
        let Some((base, skip)) = base_and_skip(gen, start) else {
            bail!(
                "offset {start} exceeds the u32 base index of the '{prefix}' \
                 offset artifacts; use a host arm",
            );
        };
        let Some((name, _)) = self.pick_artifact(prefix, true, skip + out.len()) else {
            bail!(
                "no '{prefix}' offset artifact covers {} words (+{skip} skip) — \
                 artifacts predate the `_at` family or the span exceeds \
                 {MAX_DEVICE_WORDS}; re-run `make artifacts` or use a host arm",
                out.len()
            );
        };
        params[3] = base;
        let words = self.call_block(&name, params)?;
        if words.len() < skip + out.len() {
            bail!(
                "artifact '{name}' returned {} words, need {}",
                words.len(),
                skip + out.len()
            );
        }
        // The artifact emits words W·base .. W·base + n_art; the request
        // begins `skip` words into that block.
        out.copy_from_slice(&words[skip..skip + out.len()]);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_params_layouts() {
        let seed = 0x0123_4567_89AB_CDEFu64;
        let (p, v) = artifact_params(Generator::Philox, seed, 7).unwrap();
        assert_eq!((p, v), ("philox", [0x89AB_CDEF, 0x0123_4567, 7, 0]));
        let (p, v) = artifact_params(Generator::Threefry, seed, 3).unwrap();
        assert_eq!(p, "threefry");
        assert_eq!(v[2], 3);
        // Squares passes the derived key, not the raw seed.
        let key = counter::squares_key(seed);
        let (p, v) = artifact_params(Generator::Squares, seed, 5).unwrap();
        assert_eq!(p, "squares");
        assert_eq!(v, [key as u32, (key >> 32) as u32, 5, 0]);
        // Tyche is served by the stream-ordered `_at` scan family.
        let (p, v) = artifact_params(Generator::Tyche, seed, 9).unwrap();
        assert_eq!((p, v), ("tyche", [0x89AB_CDEF, 0x0123_4567, 9, 0]));
        assert!(prefix_uses_at_family(Generator::Tyche));
        assert!(!prefix_uses_at_family(Generator::Philox));
        // Unlowered engines are refused.
        for g in [Generator::TycheI, Generator::Philox2x32, Generator::Threefry2x32] {
            assert!(artifact_params(g, seed, 0).is_none(), "{}", g.name());
        }
    }

    #[test]
    fn base_and_skip_units_and_bounds() {
        // philox/threefry: base is a 4-word counter block index.
        assert_eq!(base_and_skip(Generator::Philox, 0), Some((0, 0)));
        assert_eq!(base_and_skip(Generator::Philox, 7), Some((1, 3)));
        assert_eq!(base_and_skip(Generator::Threefry, 4096), Some((1024, 0)));
        // Representable up to 2^34 words (2^32 blocks), refused past it.
        assert_eq!(base_and_skip(Generator::Philox, (1u64 << 34) - 1), Some((u32::MAX, 3)));
        assert_eq!(base_and_skip(Generator::Philox, 1u64 << 34), None);
        // squares/tyche: base is a word index.
        assert_eq!(base_and_skip(Generator::Squares, 77), Some((77, 0)));
        assert_eq!(base_and_skip(Generator::Tyche, 77), Some((77, 0)));
        // Squares wraps at its 2^32-word period; tyche refuses instead.
        assert_eq!(base_and_skip(Generator::Squares, (1u64 << 32) + 5), Some((5, 0)));
        assert_eq!(base_and_skip(Generator::Tyche, (1u64 << 32) + 5), None);
    }

    #[test]
    fn artifact_names_cover_both_families() {
        assert_eq!(artifact_name("philox", false, 65_536), "philox_u32_65536");
        assert_eq!(artifact_name("philox", true, 65_536), "philox_u32_at_65536");
        assert_eq!(artifact_name("tyche", true, 1_048_576), "tyche_u32_at_1048576");
    }

    #[test]
    fn unavailable_device_fails_cleanly_or_matches_host() {
        // On a fresh checkout (no artifacts / vendored stub) try_new
        // must error with a diagnostic, not panic. With a real backend
        // it must satisfy the byte contract. Both paths are exercised by
        // rust/tests/backend.rs; here we only pin the no-panic half.
        match DeviceFill::try_new() {
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(!msg.is_empty());
            }
            Ok(mut d) => {
                let mut dev = vec![0u32; 1000];
                d.fill_u32(Generator::Philox, 1, 2, &mut dev).unwrap();
                let mut host = vec![0u32; 1000];
                crate::core::fill::fill_u32_gen(Generator::Philox, 1, 2, &mut host);
                assert_eq!(dev, host);
            }
        }
    }
}
