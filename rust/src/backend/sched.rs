//! The shard scheduler: one fill, host threads and the device at once.
//!
//! [`Sched`] splits a single keyed fill into contiguous word-index
//! shards ([`ShardPlan`]), dispatches every host shard to its own
//! scoped thread and every device shard to the offset entry point
//! ([`FillBackend::fill_u32_at`], backed by the `{gen}_u32_at_{n}`
//! artifacts), and stitches the result in place. Because each arm
//! writes exactly the stream words its shard names — bitwise the
//! `[start..]` slice of the serial prefix fill, by the §4 offset-fill
//! layout — the stitched buffer is byte-identical to serial
//! [`crate::core::fill::fill_u32`] for *any* plan. Planning is
//! therefore purely a performance decision, exactly like `Auto`'s
//! host/device selection, and `coordinator::repro` asserts it over
//! random plans.
//!
//! Shard sizing comes from the persisted [`CostModel`]: the device
//! takes the *tail* `device_fraction()` of the fill (capped at the
//! largest lowered artifact), the host prefix splits evenly across the
//! worker threads. The device runs on the calling thread — the PJRT
//! client is thread-confined — and overlaps with the host workers. A
//! device execution error degrades to the serial host fill of that
//! span mid-flight, so a plan can fail to be *fast* but never fail to
//! be *correct*. On the vendored `xla` stub there is no device arm and
//! every plan is host-only, the same degradation `DeviceFill` and
//! `Auto` exhibit.

use anyhow::{bail, Result};
use std::thread;

use super::auto::CostModel;
use super::device::{DeviceFill, MAX_DEVICE_WORDS};
use super::{BackendKind, FillBackend};
use crate::core::fill;
use crate::core::Generator;

/// Which execution arm a shard runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ShardArm {
    /// A scoped host worker thread (serial fill of the shard).
    Host,
    /// The device offset artifact, driven from the calling thread.
    Device,
}

impl ShardArm {
    pub fn name(self) -> &'static str {
        match self {
            ShardArm::Host => "host",
            ShardArm::Device => "device",
        }
    }
}

/// One contiguous span of the output: stream words
/// `start..start + len`, produced by `arm`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    /// First stream word index of the span.
    pub start: u64,
    /// Span length in u32 words (never 0 in a valid plan).
    pub len: usize,
    /// Where the span is generated.
    pub arm: ShardArm,
}

/// A validated tiling of a fill: shards are non-empty and contiguous
/// from word 0 (shard `i+1` starts exactly where shard `i` ends), so a
/// plan names every output word exactly once — the precondition for
/// the stitch guarantee.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    shards: Vec<Shard>,
}

impl ShardPlan {
    /// Validate an arbitrary tiling (the repro ladder feeds random
    /// ones). Rejects empty shards, gaps, overlaps, and plans not
    /// anchored at word 0.
    pub fn new(shards: Vec<Shard>) -> Result<ShardPlan> {
        let mut pos = 0u64;
        for (i, s) in shards.iter().enumerate() {
            if s.len == 0 {
                bail!("shard {i} is empty");
            }
            if s.start != pos {
                bail!(
                    "shard {i} starts at word {} but the plan covers 0..{pos}: \
                     shards must tile the fill contiguously from word 0",
                    s.start
                );
            }
            pos = match pos.checked_add(s.len as u64) {
                Some(p) => p,
                None => bail!("shard {i} overflows the u64 word index space"),
            };
        }
        Ok(ShardPlan { shards })
    }

    /// An all-host plan: `len` words split into at most `pieces`
    /// near-equal contiguous shards (fewer when `len < pieces`).
    pub fn host_only(len: usize, pieces: usize) -> ShardPlan {
        let pieces = pieces.max(1).min(len.max(1));
        let (base, rem) = (len / pieces, len % pieces);
        let mut shards = Vec::with_capacity(pieces);
        let mut pos = 0u64;
        for i in 0..pieces {
            let n = base + usize::from(i < rem);
            if n == 0 {
                continue;
            }
            shards.push(Shard { start: pos, len: n, arm: ShardArm::Host });
            pos += n as u64;
        }
        ShardPlan { shards }
    }

    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    /// Total words the plan covers (== the output length it fills).
    pub fn total_words(&self) -> u64 {
        self.shards.iter().map(|s| s.len as u64).sum()
    }

    /// Words assigned to the device arm.
    pub fn device_words(&self) -> u64 {
        self.shards
            .iter()
            .filter(|s| s.arm == ShardArm::Device)
            .map(|s| s.len as u64)
            .sum()
    }

    /// Compact human form for reports: `host:0+512,device:512+4096`.
    pub fn describe(&self) -> String {
        self.shards
            .iter()
            .map(|s| format!("{}:{}+{}", s.arm.name(), s.start, s.len))
            .collect::<Vec<_>>()
            .join(",")
    }
}

/// The heterogeneous scheduler arm (CLI `--backend sched`).
pub struct Sched {
    host_threads: usize,
    device: Option<DeviceFill>,
    model: CostModel,
}

impl Sched {
    /// Standard construction: probe the device, load the cost model
    /// through the env → cost-model file → legacy crossover → default
    /// chain.
    pub fn new(threads: usize) -> Sched {
        Sched::with_model(threads, CostModel::load())
    }

    /// Injection point for tests and the bench.
    pub fn with_model(threads: usize, model: CostModel) -> Sched {
        assert!(threads > 0, "threads must be positive");
        Sched { host_threads: threads, device: DeviceFill::try_new().ok(), model }
    }

    pub fn host_threads(&self) -> usize {
        self.host_threads
    }

    pub fn model(&self) -> CostModel {
        self.model
    }

    pub fn device_available(&self) -> bool {
        self.device.is_some()
    }

    /// `(pool hits, uploads)` of the device arm's param-buffer pool,
    /// `None` without a device (mirrors [`super::Auto::device_pool_stats`]).
    pub fn device_pool_stats(&self) -> Option<(u64, u64)> {
        self.device.as_ref().map(|d| d.pool_stats())
    }

    /// Words of a `len`-word fill the device tail shard should take:
    /// the cost model's `device_fraction()`, capped at the largest
    /// lowered artifact, zero when the fill is below the crossover or
    /// the device cannot serve the span.
    fn device_shard_len(&self, gen: Generator, len: usize) -> usize {
        let Some(d) = &self.device else { return 0 };
        if len < self.model.crossover.device_min_words {
            return 0;
        }
        let want = ((len as f64) * self.model.device_fraction()) as usize;
        let want = want.min(MAX_DEVICE_WORDS).min(len);
        if want == 0 {
            return 0;
        }
        // Align the shard start UP to a 4-word boundary (a multiple of
        // every engine's counter-block width), so the device shard has
        // skip = 0 and never burns artifact capacity on discarded
        // leading words. Alignment can push a tiny shard past the end
        // of the fill — not worth a device dispatch anyway.
        let start = ((len - want) as u64 + 3) & !3;
        if start as usize >= len {
            return 0;
        }
        let want = len - start as usize;
        if d.supports_fill_at(gen, start, want) {
            want
        } else {
            0
        }
    }

    /// Build the performance plan for a `len`-word fill of `gen`: host
    /// prefix split across the worker threads, device tail sized by the
    /// cost model. Any plan is equally correct; this one is merely the
    /// fast one for the measured rates.
    pub fn plan_for(&self, gen: Generator, len: usize) -> ShardPlan {
        let device_len = self.device_shard_len(gen, len);
        let host_len = len - device_len;
        let mut plan = ShardPlan::host_only(host_len, self.host_threads);
        if device_len > 0 {
            plan.shards.push(Shard {
                start: host_len as u64,
                len: device_len,
                arm: ShardArm::Device,
            });
        }
        plan
    }

    /// Execute an explicit plan. Host shards run on scoped threads
    /// (serial within a shard — the plan already is the parallelism);
    /// device shards run on the calling thread, overlapping the host
    /// workers, and degrade to the serial host fill of their span on
    /// any device error. Fails only on a plan/buffer length mismatch.
    pub fn fill_u32_plan(
        &mut self,
        gen: Generator,
        seed: u64,
        ctr: u32,
        plan: &ShardPlan,
        out: &mut [u32],
    ) -> Result<()> {
        if plan.total_words() != out.len() as u64 {
            bail!(
                "plan covers {} words but the buffer holds {}",
                plan.total_words(),
                out.len()
            );
        }
        let mut host_spans: Vec<(u64, &mut [u32])> = Vec::new();
        let mut device_spans: Vec<(u64, &mut [u32])> = Vec::new();
        let mut rest = out;
        for s in plan.shards() {
            let (span, tail) = rest.split_at_mut(s.len);
            rest = tail;
            match s.arm {
                ShardArm::Host => host_spans.push((s.start, span)),
                ShardArm::Device => device_spans.push((s.start, span)),
            }
        }
        let device = &mut self.device;
        thread::scope(|scope| {
            let mut workers = Vec::with_capacity(host_spans.len());
            for (start, span) in host_spans {
                workers.push(scope.spawn(move || fill::fill_u32_at_gen(gen, seed, ctr, start, span)));
            }
            for (start, span) in device_spans {
                let served = device
                    .as_mut()
                    .map(|d| d.fill_u32_at(gen, seed, ctr, start, span).is_ok())
                    .unwrap_or(false);
                if !served {
                    fill::fill_u32_at_gen(gen, seed, ctr, start, span);
                }
            }
            for w in workers {
                w.join().expect("host shard worker panicked");
            }
        });
        Ok(())
    }
}

impl FillBackend for Sched {
    fn kind(&self) -> BackendKind {
        BackendKind::Sched
    }

    fn fill_u32(&mut self, gen: Generator, seed: u64, ctr: u32, out: &mut [u32]) -> Result<()> {
        let plan = self.plan_for(gen, out.len());
        self.fill_u32_plan(gen, seed, ctr, &plan, out)
    }

    fn fill_u32_at(
        &mut self,
        gen: Generator,
        seed: u64,
        ctr: u32,
        start: u64,
        out: &mut [u32],
    ) -> Result<()> {
        // Interior spans are already sub-fill-sized: the sharded host
        // fill is the right tool, no device tail worth planning.
        fill::par_fill_u32_at_gen(gen, seed, ctr, start, out, self.host_threads);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::HostSerial;

    fn serial(gen: Generator, seed: u64, ctr: u32, n: usize) -> Vec<u32> {
        let mut v = vec![0u32; n];
        HostSerial.fill_u32(gen, seed, ctr, &mut v).unwrap();
        v
    }

    #[test]
    fn plan_validation() {
        let h = |start: u64, len: usize| Shard { start, len, arm: ShardArm::Host };
        assert!(ShardPlan::new(vec![]).is_ok(), "empty plan covers an empty fill");
        assert!(ShardPlan::new(vec![h(0, 10), h(10, 5)]).is_ok());
        assert!(ShardPlan::new(vec![h(0, 10), h(11, 5)]).is_err(), "gap");
        assert!(ShardPlan::new(vec![h(0, 10), h(9, 5)]).is_err(), "overlap");
        assert!(ShardPlan::new(vec![h(1, 10)]).is_err(), "not anchored at 0");
        assert!(ShardPlan::new(vec![h(0, 0)]).is_err(), "empty shard");
    }

    #[test]
    fn host_only_tiles_exactly() {
        for (len, pieces) in [(0usize, 4usize), (1, 4), (7, 3), (4096, 8), (5, 16)] {
            let plan = ShardPlan::host_only(len, pieces);
            assert_eq!(plan.total_words(), len as u64, "len={len} pieces={pieces}");
            assert!(plan.shards().len() <= pieces.max(1));
            assert_eq!(plan.device_words(), 0);
            // Re-validate through the public constructor.
            assert!(ShardPlan::new(plan.shards().to_vec()).is_ok());
        }
    }

    #[test]
    fn plan_for_covers_fill_exactly() {
        let sched = Sched::new(3);
        for gen in [Generator::Philox, Generator::Tyche, Generator::Squares] {
            for len in [0usize, 100, 1 << 16, (1 << 20) + 17] {
                let plan = sched.plan_for(gen, len);
                assert_eq!(plan.total_words(), len as u64, "{} len={len}", gen.name());
                assert!(ShardPlan::new(plan.shards().to_vec()).is_ok());
                if !sched.device_available() {
                    assert_eq!(plan.device_words(), 0, "stub build plans host-only");
                }
            }
        }
    }

    #[test]
    fn sched_matches_serial_reference() {
        let mut sched = Sched::new(4);
        for gen in [Generator::Philox, Generator::Threefry, Generator::Squares, Generator::Tyche] {
            for len in [1usize, 37, 4096, 1 << 17] {
                let mut got = vec![0u32; len];
                sched.fill_u32(gen, 0xC0FFEE, 5, &mut got).unwrap();
                assert_eq!(got, serial(gen, 0xC0FFEE, 5, len), "{} len={len}", gen.name());
            }
        }
    }

    #[test]
    fn explicit_mixed_plans_stitch_bitwise() {
        // Device shards in the plan are legal even without a device:
        // they degrade to the serial host fill of the span, so the
        // stitched bytes never depend on what hardware showed up.
        let mut sched = Sched::new(2);
        let n = 10_000usize;
        let want = serial(Generator::Philox, 7, 1, n);
        let plans = [
            vec![
                Shard { start: 0, len: 3, arm: ShardArm::Host },
                Shard { start: 3, len: 4093, arm: ShardArm::Device },
                Shard { start: 4096, len: 5904, arm: ShardArm::Host },
            ],
            vec![Shard { start: 0, len: n, arm: ShardArm::Device }],
            vec![
                Shard { start: 0, len: 5000, arm: ShardArm::Device },
                Shard { start: 5000, len: 5000, arm: ShardArm::Device },
            ],
        ];
        for shards in plans {
            let plan = ShardPlan::new(shards).unwrap();
            let mut got = vec![0u32; n];
            sched.fill_u32_plan(Generator::Philox, 7, 1, &plan, &mut got).unwrap();
            assert_eq!(got, want, "plan {}", plan.describe());
        }
    }

    #[test]
    fn plan_length_mismatch_rejected() {
        let mut sched = Sched::new(2);
        let plan = ShardPlan::host_only(100, 2);
        let mut out = vec![0u32; 99];
        assert!(sched.fill_u32_plan(Generator::Philox, 1, 0, &plan, &mut out).is_err());
    }

    #[test]
    fn typed_and_offset_paths_match_serial() {
        let mut sched = Sched::new(3);
        let mut a = vec![0.0f64; 600];
        sched.fill_f64(Generator::Threefry, 11, 2, &mut a).unwrap();
        let mut b = vec![0.0f64; 600];
        HostSerial.fill_f64(Generator::Threefry, 11, 2, &mut b).unwrap();
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        let whole = serial(Generator::Tyche, 3, 9, 2048);
        let mut tail = vec![0u32; 1000];
        sched.fill_u32_at(Generator::Tyche, 3, 9, 1048, &mut tail).unwrap();
        assert_eq!(tail, whole[1048..]);
    }
}
