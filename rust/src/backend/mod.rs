//! Pluggable fill backends with bitwise host/device reproducibility.
//!
//! The paper's core promise is performance-*portable* reproducibility:
//! the same `(seed, ctr)` stream replays bitwise whether it is generated
//! serially on one core, sharded across host threads, or produced in bulk
//! on the device. This module makes that promise a first-class, swappable
//! execution policy:
//!
//! * [`HostSerial`] — the gold arm: `core::fill::fill_*_gen`, one engine,
//!   stream order.
//! * [`HostParallel`] — `core::fill::par_fill_*_gen`: the output index
//!   space is sharded deterministically and each worker jumps to its
//!   shard's stream position, so output is bitwise independent of thread
//!   count.
//! * [`DeviceFill`] — the `{gen}_u32_{n}` AOT artifacts through
//!   [`crate::runtime::exec::DeviceGraph`]. The Pallas block kernels emit
//!   **stream order** (grid block `j` writes words `W·j .. W·j+W` of the
//!   `(seed, ctr)` stream — see `python/compile/kernels/*.py`), which is
//!   the same index→word mapping the host sharding produces, so a device
//!   block fill is byte-identical to the host fills by construction.
//! * [`Auto`] — picks host vs device per buffer size from a persisted
//!   calibration table ([`CrossoverTable`], measured the way
//!   `benches/ablation_block.rs` measures dispatch amortization,
//!   re-measured by `benches/fig_backend.rs`).
//! * [`Sched`] — the shard scheduler: splits one fill into contiguous
//!   word-index shards and runs host threads and the device
//!   *simultaneously* on disjoint spans of the same stream, stitched
//!   bitwise-identical to the serial layout via the
//!   [`FillBackend::fill_u32_at`] offset entry point and sized by the
//!   persisted [`auto::CostModel`].
//!
//! ## The backend contract (normative — `docs/backends.md`)
//!
//! For every arm, `fill_u32(gen, seed, ctr, out)` writes **stream words
//! `0..out.len()` of the `(seed, ctr)` stream of `gen`** — bitwise
//! identical to serial [`crate::core::fill::fill_u32`] for the same
//! inputs. The typed variants consume the identical word groups the draw
//! API consumes (`u64`/`f64` element `i` ← words `2i, 2i+1` first-word-
//! high; `f32` element `i` ← word `i`). An arm that cannot satisfy the
//! contract for a given `(gen, len)` must return an error, never an
//! approximation — [`Auto`] turns such errors into a host fallback,
//! everything else surfaces them.
//!
//! ## Degradation
//!
//! With the vendored `xla` stub (no real PJRT backend) or without AOT
//! artifacts, [`DeviceFill::try_new`] fails with a diagnostic, `--backend
//! device` reports unavailable, and [`Auto`] silently runs on the host —
//! the same self-skip discipline the artifact-dependent test suite uses.

pub mod auto;
pub mod device;
pub mod sched;

pub use auto::{Auto, CostModel, CrossoverTable};
pub use device::DeviceFill;
pub use sched::{Sched, Shard, ShardArm, ShardPlan};

use anyhow::Result;

use crate::core::fill;
use crate::core::Generator;

/// Runtime tag for the backend arms (CLI `--backend`, reports).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// Serial host fill (the gold reference arm).
    HostSerial,
    /// Deterministically sharded multi-threaded host fill.
    HostParallel,
    /// AOT block artifacts through the PJRT runtime.
    Device,
    /// Size-based host/device selection from the calibration table.
    Auto,
    /// Heterogeneous shard scheduler: host threads and the device fill
    /// disjoint contiguous shards of one stream concurrently.
    Sched,
}

impl BackendKind {
    pub const ALL: [BackendKind; 5] = [
        BackendKind::HostSerial,
        BackendKind::HostParallel,
        BackendKind::Device,
        BackendKind::Auto,
        BackendKind::Sched,
    ];

    pub fn name(self) -> &'static str {
        match self {
            BackendKind::HostSerial => "host",
            BackendKind::HostParallel => "par",
            BackendKind::Device => "device",
            BackendKind::Auto => "auto",
            BackendKind::Sched => "sched",
        }
    }

    /// Parse a CLI spelling (`host|par|device|auto|sched`; `serial` and
    /// `parallel` accepted as aliases).
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "host" | "serial" => Some(BackendKind::HostSerial),
            "par" | "parallel" => Some(BackendKind::HostParallel),
            "device" => Some(BackendKind::Device),
            "auto" => Some(BackendKind::Auto),
            "sched" => Some(BackendKind::Sched),
            _ => None,
        }
    }
}

/// The normative word→element conversions (§2 of the stream contracts)
/// applied to an already-fetched word buffer — the single definition the
/// trait defaults and the `Auto` device route both use, so the two
/// paths cannot silently diverge.
pub(crate) mod convert {
    use crate::core::fill;

    /// `u64` element `i` ← words `2i, 2i+1` (first word high).
    pub fn u64s(words: &[u32], out: &mut [u64]) {
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = fill::u64_from_words(words[2 * i], words[2 * i + 1]);
        }
    }

    /// `f32` element `i` ← word `i`.
    pub fn f32s(words: &[u32], out: &mut [f32]) {
        for (slot, &w) in out.iter_mut().zip(words.iter()) {
            *slot = fill::u01_f32(w);
        }
    }

    /// `f64` element `i` ← words `2i, 2i+1`.
    pub fn f64s(words: &[u32], out: &mut [f64]) {
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = fill::u01_f64(words[2 * i], words[2 * i + 1]);
        }
    }
}

/// A bulk-generation strategy whose output is bitwise identical to the
/// serial `core::fill` reference for the same `(gen, seed, ctr, len)`.
///
/// Object-safe so consumers can hold `&mut dyn FillBackend` handles.
/// Implementations may cache device state (`&mut self`); the device arm
/// is thread-confined like the PJRT client it wraps, so the trait does
/// not require `Send`.
pub trait FillBackend {
    /// Which arm this is (for reports and the invariance ladder).
    fn kind(&self) -> BackendKind;

    /// Stream words `0..out.len()` of the `(seed, ctr)` stream of `gen`.
    fn fill_u32(&mut self, gen: Generator, seed: u64, ctr: u32, out: &mut [u32]) -> Result<()>;

    /// Stream words `start..start + out.len()` — the **offset entry
    /// point** (§4 offset-fill layout): bitwise the `[start..]` slice of
    /// a serial prefix fill of `start + out.len()` words. This is what
    /// the shard scheduler stitches with, and what positioned stream /
    /// serve interior fills route through. Default: the serial
    /// positioned host fill, so host arms satisfy the contract with no
    /// code of their own; the device arm overrides it with the
    /// base-block-parameterized `{gen}_u32_at_{n}` artifacts.
    fn fill_u32_at(
        &mut self,
        gen: Generator,
        seed: u64,
        ctr: u32,
        start: u64,
        out: &mut [u32],
    ) -> Result<()> {
        fill::fill_u32_at_gen(gen, seed, ctr, start, out);
        Ok(())
    }

    /// `u64` element `i` ← words `2i, 2i+1` (first word high) — the
    /// [`crate::core::Rng::next_u64`] pattern. Default: fetch words via
    /// [`FillBackend::fill_u32`] and convert with the normative helpers.
    fn fill_u64(&mut self, gen: Generator, seed: u64, ctr: u32, out: &mut [u64]) -> Result<()> {
        let mut words = vec![0u32; 2 * out.len()];
        self.fill_u32(gen, seed, ctr, &mut words)?;
        convert::u64s(&words, out);
        Ok(())
    }

    /// `f32` element `i` ← word `i` (the `draw_float` pattern).
    fn fill_f32(&mut self, gen: Generator, seed: u64, ctr: u32, out: &mut [f32]) -> Result<()> {
        let mut words = vec![0u32; out.len()];
        self.fill_u32(gen, seed, ctr, &mut words)?;
        convert::f32s(&words, out);
        Ok(())
    }

    /// `f64` element `i` ← words `2i, 2i+1` (the `draw_double` pattern).
    fn fill_f64(&mut self, gen: Generator, seed: u64, ctr: u32, out: &mut [f64]) -> Result<()> {
        let mut words = vec![0u32; 2 * out.len()];
        self.fill_u32(gen, seed, ctr, &mut words)?;
        convert::f64s(&words, out);
        Ok(())
    }
}

/// The gold arm: serial block fill on the calling thread.
#[derive(Debug, Clone, Copy, Default)]
pub struct HostSerial;

impl FillBackend for HostSerial {
    fn kind(&self) -> BackendKind {
        BackendKind::HostSerial
    }

    fn fill_u32(&mut self, gen: Generator, seed: u64, ctr: u32, out: &mut [u32]) -> Result<()> {
        fill::fill_u32_gen(gen, seed, ctr, out);
        Ok(())
    }

    fn fill_u64(&mut self, gen: Generator, seed: u64, ctr: u32, out: &mut [u64]) -> Result<()> {
        fill::fill_u64_gen(gen, seed, ctr, out);
        Ok(())
    }

    fn fill_f32(&mut self, gen: Generator, seed: u64, ctr: u32, out: &mut [f32]) -> Result<()> {
        fill::fill_f32_gen(gen, seed, ctr, out);
        Ok(())
    }

    fn fill_f64(&mut self, gen: Generator, seed: u64, ctr: u32, out: &mut [f64]) -> Result<()> {
        fill::fill_f64_gen(gen, seed, ctr, out);
        Ok(())
    }
}

/// Deterministically sharded multi-threaded host fill (wraps the
/// `par_fill_*` engine — same bytes as [`HostSerial`] for every thread
/// count, per the §4 sharding contract).
#[derive(Debug, Clone, Copy)]
pub struct HostParallel {
    threads: usize,
}

impl HostParallel {
    /// A parallel arm using `threads` workers. `threads` must be > 0.
    pub fn new(threads: usize) -> HostParallel {
        assert!(threads > 0, "threads must be positive");
        HostParallel { threads }
    }

    /// One worker per available core (capped at 16 — fill sharding gains
    /// flatten out well before that on memory-bound buffers).
    pub fn auto_threads() -> HostParallel {
        let t = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        HostParallel::new(t.min(16))
    }

    pub fn threads(&self) -> usize {
        self.threads
    }
}

impl FillBackend for HostParallel {
    fn kind(&self) -> BackendKind {
        BackendKind::HostParallel
    }

    fn fill_u32(&mut self, gen: Generator, seed: u64, ctr: u32, out: &mut [u32]) -> Result<()> {
        fill::par_fill_u32_gen(gen, seed, ctr, out, self.threads);
        Ok(())
    }

    fn fill_u32_at(
        &mut self,
        gen: Generator,
        seed: u64,
        ctr: u32,
        start: u64,
        out: &mut [u32],
    ) -> Result<()> {
        fill::par_fill_u32_at_gen(gen, seed, ctr, start, out, self.threads);
        Ok(())
    }

    fn fill_u64(&mut self, gen: Generator, seed: u64, ctr: u32, out: &mut [u64]) -> Result<()> {
        fill::par_fill_u64_gen(gen, seed, ctr, out, self.threads);
        Ok(())
    }

    fn fill_f32(&mut self, gen: Generator, seed: u64, ctr: u32, out: &mut [f32]) -> Result<()> {
        fill::par_fill_f32_gen(gen, seed, ctr, out, self.threads);
        Ok(())
    }

    fn fill_f64(&mut self, gen: Generator, seed: u64, ctr: u32, out: &mut [f64]) -> Result<()> {
        fill::par_fill_f64_gen(gen, seed, ctr, out, self.threads);
        Ok(())
    }
}

/// Construct a backend by kind. `threads` feeds the parallel arm (and
/// `Auto`'s host side); `Device` errors when no artifacts / no real PJRT
/// backend exist, while `Auto` degrades to host in the same situation.
pub fn make(kind: BackendKind, threads: usize) -> Result<Box<dyn FillBackend>> {
    match kind {
        BackendKind::HostSerial => Ok(Box::new(HostSerial)),
        BackendKind::HostParallel => Ok(Box::new(HostParallel::new(threads))),
        BackendKind::Device => Ok(Box::new(DeviceFill::try_new()?)),
        BackendKind::Auto => Ok(Box::new(Auto::new(threads))),
        BackendKind::Sched => Ok(Box::new(Sched::new(threads))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for k in BackendKind::ALL {
            assert_eq!(BackendKind::parse(k.name()), Some(k));
        }
        assert_eq!(BackendKind::parse("parallel"), Some(BackendKind::HostParallel));
        assert_eq!(BackendKind::parse("serial"), Some(BackendKind::HostSerial));
        assert_eq!(BackendKind::parse("gpu"), None);
    }

    #[test]
    fn host_arms_bitwise_identical_all_generators() {
        for gen in Generator::ALL {
            let mut serial = vec![0u32; 2048];
            HostSerial.fill_u32(gen, 0xBACC, 5, &mut serial).unwrap();
            for t in [1usize, 2, 7] {
                let mut par = vec![0u32; 2048];
                HostParallel::new(t).fill_u32(gen, 0xBACC, 5, &mut par).unwrap();
                assert_eq!(serial, par, "{} t={t}", gen.name());
            }
        }
    }

    #[test]
    fn typed_defaults_match_host_specializations() {
        // The trait's scratch-buffer defaults (what the device arm uses)
        // must produce the same bytes as the host arms' native paths.
        struct ViaWords;
        impl FillBackend for ViaWords {
            fn kind(&self) -> BackendKind {
                BackendKind::HostSerial
            }
            fn fill_u32(
                &mut self,
                gen: Generator,
                seed: u64,
                ctr: u32,
                out: &mut [u32],
            ) -> Result<()> {
                fill::fill_u32_gen(gen, seed, ctr, out);
                Ok(())
            }
        }
        let gen = Generator::Philox;
        let (mut a64, mut b64) = (vec![0u64; 333], vec![0u64; 333]);
        ViaWords.fill_u64(gen, 7, 1, &mut a64).unwrap();
        HostSerial.fill_u64(gen, 7, 1, &mut b64).unwrap();
        assert_eq!(a64, b64);
        let (mut a32, mut b32) = (vec![0.0f32; 333], vec![0.0f32; 333]);
        ViaWords.fill_f32(gen, 7, 1, &mut a32).unwrap();
        HostSerial.fill_f32(gen, 7, 1, &mut b32).unwrap();
        assert_eq!(
            a32.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b32.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        let (mut af, mut bf) = (vec![0.0f64; 333], vec![0.0f64; 333]);
        ViaWords.fill_f64(gen, 7, 1, &mut af).unwrap();
        HostParallel::new(3).fill_f64(gen, 7, 1, &mut bf).unwrap();
        assert_eq!(
            af.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            bf.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn offset_entry_point_matches_prefix_slice() {
        // The trait default and the parallel override must both produce
        // the [start..] slice of the serial prefix fill — the contract
        // the shard scheduler stitches against.
        for gen in [Generator::Philox, Generator::Squares, Generator::Tyche] {
            let mut whole = vec![0u32; 4096];
            HostSerial.fill_u32(gen, 0xF00, 3, &mut whole).unwrap();
            for start in [1u64, 4, 777, 4000] {
                let n = 4096 - start as usize;
                let mut a = vec![0u32; n];
                HostSerial.fill_u32_at(gen, 0xF00, 3, start, &mut a).unwrap();
                assert_eq!(a, whole[start as usize..], "{} start={start}", gen.name());
                let mut b = vec![0u32; n];
                HostParallel::new(3).fill_u32_at(gen, 0xF00, 3, start, &mut b).unwrap();
                assert_eq!(b, a, "{} start={start} par", gen.name());
            }
        }
    }

    #[test]
    fn make_constructs_host_arms() {
        let mut b = make(BackendKind::HostSerial, 1).unwrap();
        assert_eq!(b.kind(), BackendKind::HostSerial);
        let mut out = vec![0u32; 16];
        b.fill_u32(Generator::Squares, 1, 0, &mut out).unwrap();
        let mut want = vec![0u32; 16];
        fill::fill_u32_gen(Generator::Squares, 1, 0, &mut want);
        assert_eq!(out, want);
        let b = make(BackendKind::HostParallel, 4).unwrap();
        assert_eq!(b.kind(), BackendKind::HostParallel);
        // Auto always constructs (degrades to host without a device).
        let b = make(BackendKind::Auto, 2).unwrap();
        assert_eq!(b.kind(), BackendKind::Auto);
    }

    #[test]
    #[should_panic]
    fn zero_threads_rejected() {
        let _ = HostParallel::new(0);
    }
}
