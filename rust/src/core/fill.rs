//! The deterministic block-fill engine — bulk stream generation whose
//! output is a pure function of `(seed, ctr, n)`, bitwise independent of
//! thread count.
//!
//! This is the buffer-oriented counterpart of the draw API: instead of
//! pulling words one at a time through [`Rng::next_u32`], consumers hand
//! over a whole output buffer and the engine walks the counter space in
//! `WORDS_PER_BLOCK` strides through [`BlockRng::generate_block`] — the
//! Fig. 4a hot loop with the per-word buffer bookkeeping removed.
//!
//! ## Determinism (normative — see `docs/stream-contracts.md` §4)
//!
//! `fill_*::<G>(seed, ctr, out)` writes **stream words `0..len` of the
//! `(seed, ctr)` stream** (elements of wider types consume consecutive
//! word groups exactly as the draw API does: `u64`/`f64` element `i`
//! uses words `2i, 2i+1` first-word-high; `f32` element `i` uses word
//! `i`). The `par_fill_*` variants shard the **output index space** with
//! [`coordinator::partition_ranges`](crate::coordinator::partition_ranges)
//! and jump each worker to its shard's stream position via
//! [`CounterRng::set_position`](crate::core::CounterRng::set_position) —
//! so every output element is the same
//! stream word(s) no matter how many threads ran, and the result is
//! bitwise identical to the serial fill and to a word-at-a-time loop.
//! `coordinator::repro::verify_fill_invariance` and
//! `rust/tests/properties.rs` hold this invariant.
//!
//! A fill of `n` words occupies stream positions `0..n`; the parallel
//! entry points assert `n < 2^32` words, the period of the
//! shortest-period engine (Squares — Philox/Threefry now run 2^66-word
//! streams and address the first 2^64 words directly, see
//! `docs/stream-contracts.md` §5).
//!
//! For Tyche/Tyche-i, `set_position` is O(pos) (documented engine
//! exception), so parallel fills pay an O(start) warm-up per shard;
//! the counter engines jump in O(1).

use super::block::BlockRng;
use super::traits::Rng;
use super::Generator;
#[cfg(feature = "std")]
use crate::coordinator::partition_ranges;

// The normative word → value conversions live next to the draw API in
// `traits.rs` (single source of truth); re-exported here because the
// fill paths and their consumers are where the free-function forms are
// used.
pub use super::traits::{u01_f32, u01_f64, u01_f64_from_bits, u64_from_words};

/// Words converted per tile in the typed fill paths (stack scratch).
const TILE_WORDS: usize = 1024;

/// Fill `out` with the next `out.len()` words of `g`, whose current
/// stream position is `pos` (phase information — needed to locate block
/// boundaries so the bulk of the work runs on the aligned fast path).
/// Bit-identical to `out.len()` consecutive `next_u32` calls.
pub fn fill_from<G: BlockRng>(g: &mut G, pos: u64, out: &mut [u32]) {
    let w = G::WORDS_PER_BLOCK;
    let phase = (pos % w as u64) as usize;
    let mut i = 0usize;
    // Up-align to a block boundary word-at-a-time.
    while i < out.len() && (phase + i) % w != 0 {
        out[i] = g.next_u32();
        i += 1;
    }
    // Whole blocks through the raw block path.
    let mut blk = G::Block::default();
    while i + w <= out.len() {
        g.generate_block(&mut blk);
        out[i..i + w].copy_from_slice(blk.as_ref());
        i += w;
    }
    // Tail.
    while i < out.len() {
        out[i] = g.next_u32();
        i += 1;
    }
}

/// Fresh engine for stream `(seed, ctr)` positioned at word `word`.
#[inline]
fn start_engine<G: BlockRng>(seed: u64, ctr: u32, word: u64) -> G {
    let mut g = G::new(seed, ctr);
    if word != 0 {
        g.set_position(word);
    }
    g
}

/// Fill one shard: stream words `start..start + out.len()`.
fn shard_u32<G: BlockRng>(seed: u64, ctr: u32, start: u64, out: &mut [u32]) {
    let mut g = start_engine::<G>(seed, ctr, start);
    fill_from(&mut g, start, out);
}

/// Fill one shard of u64s: elements `start..start + out.len()`, element
/// `i` composed from words `2i, 2i+1` (first word high).
fn shard_u64<G: BlockRng>(seed: u64, ctr: u32, start: u64, out: &mut [u64]) {
    let word0 = start.wrapping_mul(2);
    let mut g = start_engine::<G>(seed, ctr, word0);
    let mut words = [0u32; TILE_WORDS];
    let mut done = 0usize;
    while done < out.len() {
        let n = (out.len() - done).min(TILE_WORDS / 2);
        let tile = &mut words[..2 * n];
        fill_from(&mut g, word0.wrapping_add((2 * done) as u64), tile);
        for k in 0..n {
            out[done + k] = u64_from_words(tile[2 * k], tile[2 * k + 1]);
        }
        done += n;
    }
}

/// Fill one shard of f32s: element `i` from word `i`.
fn shard_f32<G: BlockRng>(seed: u64, ctr: u32, start: u64, out: &mut [f32]) {
    let mut g = start_engine::<G>(seed, ctr, start);
    let mut words = [0u32; TILE_WORDS];
    let mut done = 0usize;
    while done < out.len() {
        let n = (out.len() - done).min(TILE_WORDS);
        let tile = &mut words[..n];
        fill_from(&mut g, start.wrapping_add(done as u64), tile);
        for k in 0..n {
            out[done + k] = u01_f32(tile[k]);
        }
        done += n;
    }
}

/// Fill one shard of f64s: element `i` from words `2i, 2i+1`.
fn shard_f64<G: BlockRng>(seed: u64, ctr: u32, start: u64, out: &mut [f64]) {
    let word0 = start.wrapping_mul(2);
    let mut g = start_engine::<G>(seed, ctr, word0);
    let mut words = [0u32; TILE_WORDS];
    let mut done = 0usize;
    while done < out.len() {
        let n = (out.len() - done).min(TILE_WORDS / 2);
        let tile = &mut words[..2 * n];
        fill_from(&mut g, word0.wrapping_add((2 * done) as u64), tile);
        for k in 0..n {
            out[done + k] = u01_f64(tile[2 * k], tile[2 * k + 1]);
        }
        done += n;
    }
}

/// Serial block fill: stream words `0..out.len()` of `(seed, ctr)`.
/// Bit-identical to a `next_u32` loop over a fresh engine.
pub fn fill_u32<G: BlockRng>(seed: u64, ctr: u32, out: &mut [u32]) {
    shard_u32::<G>(seed, ctr, 0, out);
}

/// Serial offset fill: stream words `start..start + out.len()` of
/// `(seed, ctr)` — bitwise the `[start..]` slice of a longer serial
/// prefix fill (the §4 index-space contract). This is the reference
/// semantics of [`crate::backend::FillBackend::fill_u32_at`] and the
/// per-shard primitive the shard scheduler stitches with.
pub fn fill_u32_at<G: BlockRng>(seed: u64, ctr: u32, start: u64, out: &mut [u32]) {
    shard_u32::<G>(seed, ctr, start, out);
}

/// Serial block fill of u64s — element `i` == the `i`-th [`Rng::next_u64`]
/// of a fresh engine.
pub fn fill_u64<G: BlockRng>(seed: u64, ctr: u32, out: &mut [u64]) {
    shard_u64::<G>(seed, ctr, 0, out);
}

/// Serial block fill of `[0, 1)` f32s — element `i` == the `i`-th
/// [`Rng::draw_float`] of a fresh engine.
pub fn fill_f32<G: BlockRng>(seed: u64, ctr: u32, out: &mut [f32]) {
    shard_f32::<G>(seed, ctr, 0, out);
}

/// Serial block fill of `[0, 1)` f64s — element `i` == the `i`-th
/// [`Rng::draw_double`] of a fresh engine.
pub fn fill_f64<G: BlockRng>(seed: u64, ctr: u32, out: &mut [f64]) {
    shard_f64::<G>(seed, ctr, 0, out);
}

/// Shard `out` into `threads` deterministic contiguous ranges (the
/// coordinator partition) and run `shard(range_start, chunk)` on scoped
/// threads. Output depends only on what each shard writes at its
/// absolute positions — never on scheduling.
#[cfg(feature = "std")]
fn par_shards<T: Send>(out: &mut [T], threads: usize, shard: impl Fn(u64, &mut [T]) + Sync) {
    assert!(threads > 0, "threads must be positive");
    if threads == 1 || out.len() <= 1 {
        shard(0, out);
        return;
    }
    let ranges = partition_ranges(out.len(), threads);
    std::thread::scope(|scope| {
        let shard = &shard;
        let mut rest = out;
        for r in ranges {
            let (head, tail) = rest.split_at_mut(r.len());
            rest = tail;
            if head.is_empty() {
                continue;
            }
            let start = r.start as u64;
            scope.spawn(move || shard(start, head));
        }
    });
}

/// Parallel block fill: same output as [`fill_u32`] for every `threads`.
#[cfg(feature = "std")]
pub fn par_fill_u32<G: BlockRng>(seed: u64, ctr: u32, out: &mut [u32], threads: usize) {
    assert!(out.len() <= u32::MAX as usize, "fill exceeds the 2^32-word period of the shortest-period engine");
    par_shards(out, threads, move |start, chunk| shard_u32::<G>(seed, ctr, start, chunk));
}

/// Parallel offset fill: same output as [`fill_u32_at`] for every
/// `threads` (each worker jumps to `start` + its shard offset).
#[cfg(feature = "std")]
pub fn par_fill_u32_at<G: BlockRng>(
    seed: u64,
    ctr: u32,
    start: u64,
    out: &mut [u32],
    threads: usize,
) {
    assert!(out.len() <= u32::MAX as usize, "fill exceeds the 2^32-word period of the shortest-period engine");
    par_shards(out, threads, move |s, chunk| {
        shard_u32::<G>(seed, ctr, start.wrapping_add(s), chunk)
    });
}

/// Parallel block fill: same output as [`fill_u64`] for every `threads`.
#[cfg(feature = "std")]
pub fn par_fill_u64<G: BlockRng>(seed: u64, ctr: u32, out: &mut [u64], threads: usize) {
    assert!(out.len() <= (u32::MAX / 2) as usize, "fill exceeds the 2^32-word period of the shortest-period engine");
    par_shards(out, threads, move |start, chunk| shard_u64::<G>(seed, ctr, start, chunk));
}

/// Parallel block fill: same output as [`fill_f32`] for every `threads`.
#[cfg(feature = "std")]
pub fn par_fill_f32<G: BlockRng>(seed: u64, ctr: u32, out: &mut [f32], threads: usize) {
    assert!(out.len() <= u32::MAX as usize, "fill exceeds the 2^32-word period of the shortest-period engine");
    par_shards(out, threads, move |start, chunk| shard_f32::<G>(seed, ctr, start, chunk));
}

/// Parallel block fill: same output as [`fill_f64`] for every `threads`.
#[cfg(feature = "std")]
pub fn par_fill_f64<G: BlockRng>(seed: u64, ctr: u32, out: &mut [f64], threads: usize) {
    assert!(out.len() <= (u32::MAX / 2) as usize, "fill exceeds the 2^32-word period of the shortest-period engine");
    par_shards(out, threads, move |start, chunk| shard_f64::<G>(seed, ctr, start, chunk));
}

/// Monomorphize a fill entry point over the runtime [`Generator`] tag.
/// These are the dispatch points the [`crate::backend`] host arms call —
/// the backend subsystem owns *which* strategy runs; this module owns
/// *what* the strategy computes (the §4 stream contract).
macro_rules! gen_dispatch {
    ($(#[$doc:meta])* $name:ident, $target:ident, $t:ty) => {
        $(#[$doc])*
        pub fn $name(gen: Generator, seed: u64, ctr: u32, out: &mut [$t]) {
            use super::{Philox, Philox2x32, Squares, Threefry, Threefry2x32, Tyche, TycheI};
            match gen {
                Generator::Philox => $target::<Philox>(seed, ctr, out),
                Generator::Philox2x32 => $target::<Philox2x32>(seed, ctr, out),
                Generator::Threefry => $target::<Threefry>(seed, ctr, out),
                Generator::Threefry2x32 => $target::<Threefry2x32>(seed, ctr, out),
                Generator::Squares => $target::<Squares>(seed, ctr, out),
                Generator::Tyche => $target::<Tyche>(seed, ctr, out),
                Generator::TycheI => $target::<TycheI>(seed, ctr, out),
            }
        }
    };
}

/// Same, for the `par_fill_*` family (extra `threads` parameter).
#[cfg(feature = "std")]
macro_rules! gen_dispatch_par {
    ($(#[$doc:meta])* $name:ident, $target:ident, $t:ty) => {
        $(#[$doc])*
        pub fn $name(gen: Generator, seed: u64, ctr: u32, out: &mut [$t], threads: usize) {
            use super::{Philox, Philox2x32, Squares, Threefry, Threefry2x32, Tyche, TycheI};
            match gen {
                Generator::Philox => $target::<Philox>(seed, ctr, out, threads),
                Generator::Philox2x32 => $target::<Philox2x32>(seed, ctr, out, threads),
                Generator::Threefry => $target::<Threefry>(seed, ctr, out, threads),
                Generator::Threefry2x32 => $target::<Threefry2x32>(seed, ctr, out, threads),
                Generator::Squares => $target::<Squares>(seed, ctr, out, threads),
                Generator::Tyche => $target::<Tyche>(seed, ctr, out, threads),
                Generator::TycheI => $target::<TycheI>(seed, ctr, out, threads),
            }
        }
    };
}

/// Same, for the offset (`_at`) family (extra `start` parameter).
macro_rules! gen_dispatch_at {
    ($(#[$doc:meta])* $name:ident, $target:ident $(, $threads:ident)?) => {
        $(#[$doc])*
        pub fn $name(gen: Generator, seed: u64, ctr: u32, start: u64, out: &mut [u32] $(, $threads: usize)?) {
            use super::{Philox, Philox2x32, Squares, Threefry, Threefry2x32, Tyche, TycheI};
            match gen {
                Generator::Philox => $target::<Philox>(seed, ctr, start, out $(, $threads)?),
                Generator::Philox2x32 => $target::<Philox2x32>(seed, ctr, start, out $(, $threads)?),
                Generator::Threefry => $target::<Threefry>(seed, ctr, start, out $(, $threads)?),
                Generator::Threefry2x32 => $target::<Threefry2x32>(seed, ctr, start, out $(, $threads)?),
                Generator::Squares => $target::<Squares>(seed, ctr, start, out $(, $threads)?),
                Generator::Tyche => $target::<Tyche>(seed, ctr, start, out $(, $threads)?),
                Generator::TycheI => $target::<TycheI>(seed, ctr, start, out $(, $threads)?),
            }
        }
    };
}

gen_dispatch!(
    /// [`fill_u32`] dispatched over the runtime [`Generator`] tag.
    fill_u32_gen, fill_u32, u32);
gen_dispatch_at!(
    /// [`fill_u32_at`] dispatched over the runtime [`Generator`] tag.
    fill_u32_at_gen, fill_u32_at);
#[cfg(feature = "std")]
gen_dispatch_at!(
    /// [`par_fill_u32_at`] dispatched over the runtime [`Generator`] tag.
    par_fill_u32_at_gen, par_fill_u32_at, threads);
gen_dispatch!(
    /// [`fill_u64`] dispatched over the runtime [`Generator`] tag.
    fill_u64_gen, fill_u64, u64);
gen_dispatch!(
    /// [`fill_f32`] dispatched over the runtime [`Generator`] tag.
    fill_f32_gen, fill_f32, f32);
gen_dispatch!(
    /// [`fill_f64`] dispatched over the runtime [`Generator`] tag.
    fill_f64_gen, fill_f64, f64);
#[cfg(feature = "std")]
gen_dispatch_par!(
    /// [`par_fill_u32`] dispatched over the runtime [`Generator`] tag.
    par_fill_u32_gen, par_fill_u32, u32);
#[cfg(feature = "std")]
gen_dispatch_par!(
    /// [`par_fill_u64`] dispatched over the runtime [`Generator`] tag.
    par_fill_u64_gen, par_fill_u64, u64);
#[cfg(feature = "std")]
gen_dispatch_par!(
    /// [`par_fill_f32`] dispatched over the runtime [`Generator`] tag.
    par_fill_f32_gen, par_fill_f32, f32);
#[cfg(feature = "std")]
gen_dispatch_par!(
    /// [`par_fill_f64`] dispatched over the runtime [`Generator`] tag.
    par_fill_f64_gen, par_fill_f64, f64);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{CounterRng, Philox, Philox2x32, Squares, Threefry, Tyche};

    fn serial_words<G: BlockRng>(seed: u64, ctr: u32, n: usize) -> Vec<u32> {
        let mut g = G::new(seed, ctr);
        (0..n).map(|_| g.next_u32()).collect()
    }

    #[test]
    fn fill_u32_matches_word_at_a_time() {
        fn check<G: BlockRng>() {
            for n in [0usize, 1, 3, 4, 7, 64, 129] {
                let mut out = vec![0u32; n];
                fill_u32::<G>(0xFEED, 5, &mut out);
                assert_eq!(out, serial_words::<G>(0xFEED, 5, n), "{} n={n}", G::NAME);
            }
        }
        check::<Philox>();
        check::<Philox2x32>();
        check::<Threefry>();
        check::<Squares>();
        check::<Tyche>();
    }

    #[test]
    fn fill_u64_matches_next_u64() {
        let mut out = vec![0u64; 33];
        fill_u64::<Philox>(9, 1, &mut out);
        let mut g = Philox::new(9, 1);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, g.next_u64(), "elem {i}");
        }
    }

    #[test]
    fn fill_f32_matches_draw_float() {
        let mut out = vec![0.0f32; 100];
        fill_f32::<Squares>(0x51, 2, &mut out);
        let mut g = Squares::new(0x51, 2);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v.to_bits(), g.draw_float().to_bits(), "elem {i}");
        }
    }

    #[test]
    fn fill_f64_matches_draw_double() {
        let mut out = vec![0.0f64; 100];
        fill_f64::<Philox>(0x52, 3, &mut out);
        let mut g = Philox::new(0x52, 3);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v.to_bits(), g.draw_double().to_bits(), "elem {i}");
        }
    }

    #[test]
    fn fill_crosses_tile_boundaries_seamlessly() {
        // Lengths straddling the TILE_WORDS scratch: the typed paths must
        // keep the stream continuous across tiles.
        let n = TILE_WORDS + TILE_WORDS / 2 + 3;
        let mut out = vec![0.0f64; n];
        fill_f64::<Philox>(1, 1, &mut out);
        let mut g = Philox::new(1, 1);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v.to_bits(), g.draw_double().to_bits(), "elem {i}");
        }
    }

    #[test]
    fn par_fill_bitwise_thread_invariant() {
        fn check<G: BlockRng>(n: usize) {
            let want = serial_words::<G>(0xC0FFEE, 7, n);
            for threads in [1usize, 2, 3, 8, 16] {
                let mut out = vec![0u32; n];
                par_fill_u32::<G>(0xC0FFEE, 7, &mut out, threads);
                assert_eq!(out, want, "{} n={n} threads={threads}", G::NAME);
            }
        }
        for n in [0usize, 1, 5, 63, 1000] {
            check::<Philox>(n);
            check::<Squares>(n);
            check::<Tyche>(n);
        }
    }

    #[test]
    fn par_fill_f64_thread_invariant_and_element_sharded() {
        let n = 777usize;
        let mut g = Philox::new(3, 3);
        let want: Vec<u64> = (0..n).map(|_| g.draw_double().to_bits()).collect();
        for threads in [1usize, 2, 8] {
            let mut out = vec![0.0f64; n];
            par_fill_f64::<Philox>(3, 3, &mut out, threads);
            let got: Vec<u64> = out.iter().map(|v| v.to_bits()).collect();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn fill_u32_at_is_a_slice_of_the_prefix_fill() {
        for g in Generator::ALL {
            let mut whole = vec![0u32; 512];
            fill_u32_gen(g, 0xA7, 2, &mut whole);
            for start in [0u64, 1, 3, 4, 129, 500] {
                let n = 512 - start as usize;
                let mut out = vec![0u32; n];
                fill_u32_at_gen(g, 0xA7, 2, start, &mut out);
                assert_eq!(out, whole[start as usize..], "{} start={start}", g.name());
                let mut par = vec![0u32; n];
                par_fill_u32_at_gen(g, 0xA7, 2, start, &mut par, 3);
                assert_eq!(par, out, "{} start={start} par", g.name());
            }
        }
    }

    #[test]
    fn par_fill_more_threads_than_elements() {
        let mut out = vec![0u32; 3];
        par_fill_u32::<Philox>(1, 0, &mut out, 16);
        assert_eq!(out, serial_words::<Philox>(1, 0, 3));
    }

    #[test]
    fn gen_dispatch_matches_monomorphic() {
        for g in Generator::ALL {
            let mut a = vec![0u32; 300];
            fill_u32_gen(g, 0xD15, 3, &mut a);
            assert_eq!(a, serial_with(g, 0xD15, 3, 300), "{}", g.name());
            let mut b = vec![0u32; 300];
            par_fill_u32_gen(g, 0xD15, 3, &mut b, 4);
            assert_eq!(a, b, "{}", g.name());
            let mut d = vec![0.0f64; 100];
            fill_f64_gen(g, 0xD15, 3, &mut d);
            let first = g.with_rng(0xD15, 3, |r| r.draw_double());
            assert_eq!(d[0].to_bits(), first.to_bits(), "{}", g.name());
        }
    }

    fn serial_with(g: Generator, seed: u64, ctr: u32, n: usize) -> Vec<u32> {
        g.with_rng(seed, ctr, |r| (0..n).map(|_| r.next_u32()).collect())
    }

    #[test]
    fn conversion_helpers_match_draw_api() {
        let mut a = Threefry::new(11, 4);
        let mut b = Threefry::new(11, 4);
        for _ in 0..16 {
            let (hi, lo) = (a.next_u32(), a.next_u32());
            assert_eq!(u64_from_words(hi, lo), b.next_u64());
        }
        let mut c = Threefry::new(12, 4);
        let mut d = Threefry::new(12, 4);
        for _ in 0..16 {
            let w = c.next_u32();
            assert_eq!(u01_f32(w).to_bits(), d.draw_float().to_bits());
        }
        let mut e = Threefry::new(13, 4);
        let mut f = Threefry::new(13, 4);
        for _ in 0..16 {
            let (hi, lo) = (e.next_u32(), e.next_u32());
            assert_eq!(u01_f64(hi, lo).to_bits(), f.draw_double().to_bits());
        }
    }
}
