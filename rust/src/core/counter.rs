//! The normative `(seed, ctr)` → raw-counter/key layout contract.
//!
//! This file and `python/compile/kernels/common.py` are the two normative
//! definitions of how an OpenRAND stream maps onto raw CBRNG invocations;
//! the cross-layer integration test (`rust/tests/cross_layer.rs`) and the
//! pytest suite hold them bit-identical. Change one, change both.
//!
//! | engine          | key                                   | block `j` counter      |
//! |-----------------|---------------------------------------|------------------------|
//! | Philox4x32-10   | `[seed_lo, seed_hi]`                  | `[j, ctr, 0, 0]`       |
//! | Philox2x32-10   | `seed_lo ^ (seed_hi * 0x9E3779B9)`    | `[j, ctr]`             |
//! | Threefry4x32-20 | `[seed_lo, seed_hi, 0, 0]`            | `[j, ctr, 0, 0]`       |
//! | Threefry2x32-20 | `[seed_lo, seed_hi]`                  | `[j, ctr]`             |
//! | Squares         | `splitmix64(seed) \| 1`               | `(ctr << 32) \| j` (u64) |
//! | Tyche/Tyche-i   | state `(seed_hi, seed_lo, 2654435769, 1367130551 ^ ctr)`, 20 warm-up MIXes | sequential |
//!
//! Stream word `i` lives in block `j = i / W`, word `i % W` (W = words per
//! block). The user-visible period per `(seed, ctr)` stream is `2^32`
//! words for every engine.

/// Split a 64-bit seed into `(lo, hi)` 32-bit halves.
#[inline]
pub fn split_seed(seed: u64) -> (u32, u32) {
    (seed as u32, (seed >> 32) as u32)
}

/// The Philox2x32 single-word key: mixes both seed halves so the full
/// 64-bit seed space maps onto distinct streams as well as possible.
#[inline]
pub fn philox2_key(seed: u64) -> u32 {
    let (lo, hi) = split_seed(seed);
    lo ^ hi.wrapping_mul(0x9E37_79B9)
}

/// splitmix64 — the Squares key-mixing function (and the seeding function
/// for the xoshiro baseline). Reference: Steele, Lea & Flood (2014).
#[inline]
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Normative Squares key derivation: well-mixed and odd.
#[inline]
pub fn squares_key(seed: u64) -> u64 {
    splitmix64(seed) | 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_seed_halves() {
        assert_eq!(split_seed(0x0123_4567_89AB_CDEF), (0x89AB_CDEF, 0x0123_4567));
    }

    #[test]
    fn splitmix64_reference_vector() {
        // splitmix64(x) == first output of Vigna's splitmix64.c seeded
        // with state x. Known vector for state 0, also pinned against the
        // python reference (common.splitmix64) in the cross-layer test.
        assert_eq!(splitmix64(0), 0xE220_A839_7B1D_CDAF);
        // Stateless: same input, same output; distinct inputs differ.
        assert_eq!(splitmix64(1234567), splitmix64(1234567));
        assert_ne!(splitmix64(1), splitmix64(2));
    }

    #[test]
    fn squares_key_is_odd_and_mixed() {
        for seed in [0u64, 1, 2, u64::MAX, 0xDEAD_BEEF] {
            let k = squares_key(seed);
            assert_eq!(k & 1, 1);
        }
        // Adjacent seeds give wildly different keys (avalanche).
        let d = (squares_key(100) ^ squares_key(101)).count_ones();
        assert!(d > 16, "{d}");
    }

    #[test]
    fn philox2_key_uses_both_halves() {
        assert_ne!(philox2_key(0x1), philox2_key(0x1 | (1 << 40)));
    }
}
