//! The OpenRAND draw API — the C++-random-engine-shaped interface.
//!
//! [`Rng`] mirrors the paper's generator interface (and the C++17 uniform
//! random bit generator requirements): `next_u32`/`next_u64` are the raw
//! engine calls (`operator()`, `min`, `max`), the `draw_*` helpers are the
//! OpenRAND conveniences used throughout the paper's examples.
//!
//! Conversions are **normative** and shared bit-exactly with
//! `python/compile/kernels/common.py`:
//!
//! * `f32 in [0,1)`: top 24 bits of one u32 word,
//! * `f64 in [0,1)`: top 53 bits of `(word_2m << 32) | word_2m+1`.

/// One stream word to a uniform `f32` in `[0, 1)` — top 24 bits. The
/// single normative definition; [`Rng::draw_float`] and the bulk fill
/// paths both route through it.
#[inline]
pub fn u01_f32(word: u32) -> f32 {
    (word >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
}

/// Two consecutive stream words (first word high) to a `u64` — the
/// single normative composition behind [`Rng::next_u64`].
#[inline]
pub fn u64_from_words(hi: u32, lo: u32) -> u64 {
    ((hi as u64) << 32) | lo as u64
}

/// 64 stream bits to a uniform `f64` in `[0, 1)` — top 53 bits. The
/// single normative definition; [`Rng::draw_double`] routes through it.
#[inline]
pub fn u01_f64_from_bits(bits: u64) -> f64 {
    (bits >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Two consecutive stream words to a uniform `f64` in `[0, 1)` —
/// [`u64_from_words`] composed with [`u01_f64_from_bits`].
#[inline]
pub fn u01_f64(hi: u32, lo: u32) -> f64 {
    u01_f64_from_bits(u64_from_words(hi, lo))
}

/// Uniform random bit generator + OpenRAND draw helpers.
///
/// Object-safe: the CLI and battery dispatch over `&mut dyn Rng`; the hot
/// paths monomorphize via generics instead.
///
/// Every method consumes a fixed, documented number of stream words —
/// the normative word-consumption rules (shared bit-exactly with the
/// device layer) are consolidated in `docs/stream-contracts.md`.
pub trait Rng {
    /// Next 32-bit word of the stream (the raw engine output).
    fn next_u32(&mut self) -> u32;

    /// Next 64 bits: two consecutive 32-bit words, **first word high**.
    ///
    /// This composition is normative (`docs/stream-contracts.md` §2): it
    /// is what `python/compile/kernels/common.py::u32x2_to_f64` feeds the
    /// f64 conversion, so reordering it would silently desynchronize the
    /// host f64 path from the device graphs. The doctest below and
    /// `python/tests/test_kat.py::test_next_u64_word_order_kat` pin the
    /// same literal on both layers.
    ///
    /// ```
    /// use openrand::core::{CounterRng, Philox, Rng};
    /// // Stream (seed=7, ctr=1) opens with words 0x2EC4F55D, 0x249EF5F4.
    /// let mut w = Philox::new(7, 1);
    /// let (w0, w1) = (w.next_u32(), w.next_u32());
    /// assert_eq!((w0, w1), (0x2EC4_F55D, 0x249E_F5F4));
    /// // next_u64 packs them first-word-high:
    /// assert_eq!(Philox::new(7, 1).next_u64(), 0x2EC4_F55D_249E_F5F4);
    /// assert_eq!(((w0 as u64) << 32) | w1 as u64, 0x2EC4_F55D_249E_F5F4);
    /// assert_ne!(((w1 as u64) << 32) | w0 as u64, 0x2EC4_F55D_249E_F5F4); // not low-word-first
    /// // ... and the f64 path inherits the ordering (top 53 bits):
    /// assert_eq!(Philox::new(7, 1).draw_double(), 0.1826928474807763);
    /// ```
    #[inline]
    fn next_u64(&mut self) -> u64 {
        let hi = self.next_u32();
        let lo = self.next_u32();
        u64_from_words(hi, lo)
    }

    /// Uniform `f32` in `[0, 1)` — top 24 bits of one word
    /// ([`u01_f32`]).
    #[inline]
    fn draw_float(&mut self) -> f32 {
        u01_f32(self.next_u32())
    }

    /// Uniform `f64` in `[0, 1)` — top 53 bits of two words
    /// ([`u01_f64_from_bits`] of [`Rng::next_u64`]).
    #[inline]
    fn draw_double(&mut self) -> f64 {
        u01_f64_from_bits(self.next_u64())
    }

    /// Two uniform `f64`s — the paper's `draw_double2` (Fig. 1 line 16),
    /// one Philox block's worth of bits.
    #[inline]
    fn draw_double2(&mut self) -> (f64, f64) {
        (self.draw_double(), self.draw_double())
    }

    /// Two uniform `f32`s.
    #[inline]
    fn draw_float2(&mut self) -> (f32, f32) {
        (self.draw_float(), self.draw_float())
    }

    /// Unbiased uniform integer in `[0, bound)` — Lemire's multiply-shift
    /// rejection method (no modulo on the happy path).
    ///
    /// # Panics
    ///
    /// Panics when `bound == 0` — in **all** build profiles. `[0, 0)` is
    /// empty, so there is no uniform value to return; the former
    /// `debug_assert!` let release builds silently return 0 (one stream
    /// word still consumed), which is exactly the kind of quiet
    /// divergence a reproducibility library cannot ship.
    #[inline]
    fn range_u32(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "range_u32: bound must be positive (empty range has no uniform value)");
        let mut x = self.next_u32();
        let mut m = (x as u64) * (bound as u64);
        let mut l = m as u32;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (bound as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.draw_double()
    }

    /// Fill a slice with raw stream words. Engines with block structure
    /// override this with an unbuffered bulk path (the fill loop is the
    /// Fig. 4a hot loop).
    #[inline]
    fn fill_u32(&mut self, out: &mut [u32]) {
        for w in out.iter_mut() {
            *w = self.next_u32();
        }
    }
}

/// A counter-based engine: constructible from `(seed, ctr)` in O(1) with
/// no global state — the property the whole paper is about.
pub trait CounterRng: Rng + Sized {
    /// Engine name as used by the CLI, benches, and artifact files.
    const NAME: &'static str;

    /// In-register state footprint in bytes (key + counter + buffer +
    /// bookkeeping) — the GPU register-pressure metric from the paper.
    const STATE_BYTES: usize = core::mem::size_of::<Self>();

    /// Create the stream identified by `(seed, ctr)`. `seed` names the
    /// processing element (particle id, pixel index, ...); `ctr` names
    /// the sub-stream (timestep, kernel launch, ...).
    fn new(seed: u64, ctr: u32) -> Self;

    /// Position the stream at the `pos`-th 32-bit word — an **absolute**
    /// index, valid from any current state — in O(1) (counter
    /// arithmetic; Tyche documents its O(pos) exception, replaying from
    /// its warm-up origin).
    ///
    /// `pos` addresses the first `2^64` words of the stream; engines with
    /// a shorter period (Philox2x32/Threefry2x32: `2^33` words, Squares:
    /// `2^32`) reduce `pos` modulo their period, exactly matching where
    /// `pos` sequential `next_u32` draws would land.
    fn set_position(&mut self, pos: u64);

    /// log2 of the stride of one [`CounterRng::jump`] call, or `None`
    /// when the engine has no O(1) far jump (Tyche/TycheI, whose state
    /// only steps forward). Chosen per engine as roughly the square root
    /// of the period, so `jump()` partitions a stream into
    /// period/2^JUMP_LOG2 non-overlapping subsequences.
    const JUMP_LOG2: Option<u32>;

    /// Advance the stream by `n` words — bit-identical to calling
    /// [`Rng::next_u32`] `n` times and discarding the results, from any
    /// starting phase. O(1) for the counter-addressable engines
    /// (Philox/Threefry/Squares families); O(n) for Tyche/TycheI, which
    /// step their mix function forward. Wraps modulo the engine period
    /// like [`CounterRng::set_position`].
    fn advance(&mut self, n: u64);

    /// Far jump: skip `2^JUMP_LOG2` words in O(1), for carving one
    /// logical stream into provably disjoint subsequences (the
    /// PRAND-style block-splitting contract; see
    /// `docs/stream-contracts.md` §5 for the per-engine strides).
    ///
    /// # Panics
    ///
    /// Panics for engines with `JUMP_LOG2 == None` (Tyche/TycheI): a
    /// "jump" that silently cost O(2^k) stepping would defeat its point.
    #[inline]
    fn jump(&mut self) {
        match Self::JUMP_LOG2 {
            Some(k) => self.advance(1u64 << k),
            None => panic!(
                "{}: jump() unsupported (no O(1) skip-ahead; use advance(n) — O(n) stepping)",
                Self::NAME
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A fake engine emitting a known word sequence, to pin the trait's
    /// default conversions independently of any real generator.
    struct Seq(Vec<u32>, usize);
    impl Rng for Seq {
        fn next_u32(&mut self) -> u32 {
            let v = self.0[self.1 % self.0.len()];
            self.1 += 1;
            v
        }
    }

    #[test]
    fn u64_packs_first_word_high() {
        let mut s = Seq(vec![0xDEADBEEF, 0x01234567], 0);
        assert_eq!(s.next_u64(), 0xDEADBEEF_01234567);
    }

    #[test]
    fn draw_float_uses_top_24_bits() {
        assert_eq!(Seq(vec![0], 0).draw_float(), 0.0);
        let almost = Seq(vec![u32::MAX], 0).draw_float();
        assert!(almost < 1.0 && almost > 0.9999);
        // Exactly (2^24 - 1) / 2^24:
        assert_eq!(almost, (0xFFFFFF as f32) / (1 << 24) as f32);
    }

    #[test]
    fn draw_double_uses_top_53_bits() {
        assert_eq!(Seq(vec![0, 0], 0).draw_double(), 0.0);
        let almost = Seq(vec![u32::MAX, u32::MAX], 0).draw_double();
        assert!(almost < 1.0);
        assert_eq!(almost, ((1u64 << 53) - 1) as f64 / (1u64 << 53) as f64);
    }

    #[test]
    fn range_u32_is_in_bounds_and_hits_all_values() {
        let mut s = Seq((0..1024u32).map(|i| i.wrapping_mul(0x9E37_79B9)).collect(), 0);
        let mut seen = [false; 7];
        for _ in 0..1024 {
            seen[s.range_u32(7) as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn range_u32_bound_one_is_zero() {
        let mut s = Seq(vec![u32::MAX, 123], 0);
        assert_eq!(s.range_u32(1), 0);
    }

    #[test]
    #[should_panic(expected = "bound must be positive")]
    fn range_u32_zero_bound_panics_in_all_profiles() {
        // Documented hard panic: a plain assert!, not debug_assert!, so
        // release builds fail loudly instead of returning garbage.
        let mut s = Seq(vec![7, 8, 9], 0);
        let _ = s.range_u32(0);
    }

    #[test]
    fn fill_matches_repeated_next() {
        let mut a = Seq((0..64).collect(), 0);
        let mut b = Seq((0..64).collect(), 0);
        let mut buf = [0u32; 64];
        a.fill_u32(&mut buf);
        for w in buf {
            assert_eq!(w, b.next_u32());
        }
    }
}
