//! Philox4x32-10 and Philox2x32-10 (Salmon et al., SC'11) — the paper's
//! default engine and the one used by every library in the Fig. 4
//! benchmarks (OpenRAND, cuRAND and Random123 all run their Philox).
//!
//! The raw block functions [`philox4x32_r`] / [`philox2x32_r`] are public:
//! they are the Random123-style low-level API (paper Fig. 3), the building
//! block of the cuRAND-analog baseline, and what the statistical battery's
//! parallel-stream test drives directly.

use super::block::BlockRng;
use super::counter::{philox2_key, split_seed};
use super::traits::{CounterRng, Rng};

const M4_0: u32 = 0xD251_1F53;
const M4_1: u32 = 0xCD9E_8D57;
const M2_0: u32 = 0xD256_D193;
/// Weyl constants: golden ratio and sqrt(3)-1 in 0.32 fixed point.
pub const W_0: u32 = 0x9E37_79B9;
pub const W_1: u32 = 0xBB67_AE85;

#[inline(always)]
fn mulhilo(a: u32, b: u32) -> (u32, u32) {
    let p = (a as u64) * (b as u64);
    ((p >> 32) as u32, p as u32)
}

/// One Philox4x32 round.
#[inline(always)]
fn round4(c: [u32; 4], k: [u32; 2]) -> [u32; 4] {
    let (hi0, lo0) = mulhilo(M4_0, c[0]);
    let (hi1, lo1) = mulhilo(M4_1, c[2]);
    [hi1 ^ c[1] ^ k[0], lo1, hi0 ^ c[3] ^ k[1], lo0]
}

/// Philox4x32-R raw block function (R rounds; the paper uses R = 10).
#[inline]
pub fn philox4x32_r(mut ctr: [u32; 4], mut key: [u32; 2], rounds: u32) -> [u32; 4] {
    for r in 0..rounds {
        if r > 0 {
            key[0] = key[0].wrapping_add(W_0);
            key[1] = key[1].wrapping_add(W_1);
        }
        ctr = round4(ctr, key);
    }
    ctr
}

/// Philox4x32-10 — the standard-strength block function.
#[inline]
pub fn philox4x32(ctr: [u32; 4], key: [u32; 2]) -> [u32; 4] {
    philox4x32_r(ctr, key, 10)
}

/// Philox2x32-R raw block function.
#[inline]
pub fn philox2x32_r(mut ctr: [u32; 2], mut key: u32, rounds: u32) -> [u32; 2] {
    for r in 0..rounds {
        if r > 0 {
            key = key.wrapping_add(W_0);
        }
        let (hi, lo) = mulhilo(M2_0, ctr[0]);
        ctr = [hi ^ key ^ ctr[1], lo];
    }
    ctr
}

/// Philox2x32-10.
#[inline]
pub fn philox2x32(ctr: [u32; 2], key: u32) -> [u32; 2] {
    philox2x32_r(ctr, key, 10)
}

/// Counter block `j` (64-bit block index) of stream `(key, ctr)`.
///
/// The normative layout (`docs/stream-contracts.md` §1): words 0 and 2
/// carry the low/high halves of the block index, word 1 the user
/// counter, word 3 is spare. For `j < 2^32` this is bit-identical to the
/// historical `[j, ctr, 0, 0]` layout, so all pre-widening output is
/// unchanged; the high half extends the per-stream period to `2^66`
/// words and is what makes >4G-word `set_position`/`advance` exact.
#[inline(always)]
fn ctr4(j: u64, ctr: u32) -> [u32; 4] {
    [j as u32, ctr, (j >> 32) as u32, 0]
}

/// The OpenRAND default engine: Philox4x32-10 in counter mode.
///
/// State: 96-bit stream identity (key + user counter) + 64-bit block
/// index + 4-word output buffer — all in registers, nothing in memory.
/// Period `2^66` words; the first `2^64` are addressable via
/// [`CounterRng::set_position`]/[`CounterRng::advance`].
#[derive(Debug, Clone)]
pub struct Philox {
    key: [u32; 2],
    ctr: u32,
    /// Next counter block index to generate.
    blk: u64,
    buf: [u32; 4],
    /// Consumed words within `buf`; 4 means empty.
    pos: u8,
}

impl Philox {
    /// Number of rounds — fixed to the standard 10; the ablation bench
    /// drives `philox4x32_r` directly for reduced-round variants.
    pub const ROUNDS: u32 = 10;

    #[inline]
    fn refill(&mut self) {
        self.buf = philox4x32(ctr4(self.blk, self.ctr), self.key);
        self.blk = self.blk.wrapping_add(1);
        self.pos = 0;
    }

    /// Generate counter block `j` of this stream without disturbing the
    /// sequential position (pure function of the stream identity).
    #[inline]
    pub fn block(&self, j: u64) -> [u32; 4] {
        philox4x32(ctr4(j, self.ctr), self.key)
    }

    /// Absolute word index of the next `next_u32` result, in the
    /// `2^64`-word addressable window (wrapping there like
    /// `set_position`).
    #[inline]
    fn position(&self) -> u64 {
        if self.pos >= 4 {
            self.blk.wrapping_mul(4)
        } else {
            self.blk.wrapping_sub(1).wrapping_mul(4).wrapping_add(self.pos as u64)
        }
    }
}

impl Rng for Philox {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        if self.pos >= 4 {
            self.refill();
        }
        let w = self.buf[self.pos as usize];
        self.pos += 1;
        w
    }

    #[inline]
    fn fill_u32(&mut self, out: &mut [u32]) {
        let mut i = 0;
        // Drain buffered words first so fill == repeated next_u32.
        while self.pos < 4 && i < out.len() {
            out[i] = self.buf[self.pos as usize];
            self.pos += 1;
            i += 1;
        }
        // Whole blocks straight into the output slice (no buffer bounce).
        // §Perf L3 note: 2-way and 4-way counter-block interleaving were
        // both tried here and REVERTED — on this narrow single-issue-mul
        // core they cost 30-33% (461 -> 321/310 Mwords/s); the simple
        // loop is the measured optimum. Revisit on wider hardware.
        while i + 4 <= out.len() {
            let b = philox4x32(ctr4(self.blk, self.ctr), self.key);
            out[i..i + 4].copy_from_slice(&b);
            self.blk = self.blk.wrapping_add(1);
            i += 4;
        }
        while i < out.len() {
            out[i] = self.next_u32();
            i += 1;
        }
    }
}

impl BlockRng for Philox {
    const WORDS_PER_BLOCK: usize = 4;
    type Block = [u32; 4];

    #[inline]
    fn generate_block(&mut self, out: &mut [u32; 4]) {
        if self.pos >= 4 {
            // Block-aligned: one raw block function call, no buffer bounce.
            *out = self.block(self.blk);
            self.blk = self.blk.wrapping_add(1);
        } else {
            // Mid-block phase: route through fill so the output stays
            // bit-identical to four sequential next_u32 draws.
            self.fill_u32(&mut out[..]);
        }
    }
}

impl CounterRng for Philox {
    const NAME: &'static str = "philox";

    /// Half the 2^66-word period: `jump()` partitions a stream into
    /// 2^33 disjoint 8G-word subsequences.
    const JUMP_LOG2: Option<u32> = Some(33);

    #[inline]
    fn new(seed: u64, ctr: u32) -> Self {
        let (lo, hi) = split_seed(seed);
        Philox { key: [lo, hi], ctr, blk: 0, buf: [0; 4], pos: 4 }
    }

    #[inline]
    fn set_position(&mut self, pos: u64) {
        self.blk = pos / 4;
        self.refill();
        self.pos = (pos % 4) as u8;
    }

    #[inline]
    fn advance(&mut self, n: u64) {
        self.set_position(self.position().wrapping_add(n));
    }
}

/// Philox2x32-10 engine — half-width block, single-word key. Period
/// `2^33` words (32-bit block counter × 2-word blocks);
/// `set_position`/`advance` reduce modulo that period, matching where
/// sequential draws wrap.
#[derive(Debug, Clone)]
pub struct Philox2x32 {
    key: u32,
    ctr: u32,
    blk: u32,
    buf: [u32; 2],
    pos: u8,
}

impl Philox2x32 {
    /// Stream period in words: 2^32 counter blocks × 2 words.
    const PERIOD: u64 = 1 << 33;

    /// Absolute word index of the next `next_u32` result, mod the
    /// 2^33-word period.
    #[inline]
    fn position(&self) -> u64 {
        let p = if self.pos >= 2 {
            (self.blk as u64).wrapping_mul(2)
        } else {
            (self.blk.wrapping_sub(1) as u64).wrapping_mul(2) + self.pos as u64
        };
        p % Self::PERIOD
    }
}

impl Rng for Philox2x32 {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        if self.pos >= 2 {
            self.buf = philox2x32([self.blk, self.ctr], self.key);
            self.blk = self.blk.wrapping_add(1);
            self.pos = 0;
        }
        let w = self.buf[self.pos as usize];
        self.pos += 1;
        w
    }
}

impl BlockRng for Philox2x32 {
    const WORDS_PER_BLOCK: usize = 2;
    type Block = [u32; 2];

    #[inline]
    fn generate_block(&mut self, out: &mut [u32; 2]) {
        if self.pos >= 2 {
            *out = philox2x32([self.blk, self.ctr], self.key);
            self.blk = self.blk.wrapping_add(1);
        } else {
            out[0] = self.next_u32();
            out[1] = self.next_u32();
        }
    }
}

impl CounterRng for Philox2x32 {
    const NAME: &'static str = "philox2x32";

    /// ~sqrt of the 2^33-word period.
    const JUMP_LOG2: Option<u32> = Some(16);

    #[inline]
    fn new(seed: u64, ctr: u32) -> Self {
        Philox2x32 { key: philox2_key(seed), ctr, blk: 0, buf: [0; 2], pos: 2 }
    }

    #[inline]
    fn set_position(&mut self, pos: u64) {
        let pos = pos % Self::PERIOD;
        self.blk = (pos / 2) as u32;
        self.buf = philox2x32([self.blk, self.ctr], self.key);
        self.blk = self.blk.wrapping_add(1);
        self.pos = (pos % 2) as u8;
    }

    #[inline]
    fn advance(&mut self, n: u64) {
        self.set_position(self.position() + n % Self::PERIOD);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const M: u32 = u32::MAX;
    // pi digits, the Random123 kat_vectors pattern.
    const PI: [u32; 6] = [0x243F_6A88, 0x85A3_08D3, 0x1319_8A2E, 0x0370_7344, 0xA409_3822, 0x299F_31D0];

    #[test]
    fn philox4x32_known_answers() {
        // Random123 kat_vectors.
        assert_eq!(
            philox4x32([0, 0, 0, 0], [0, 0]),
            [0x6627_E8D5, 0xE169_C58D, 0xBC57_AC4C, 0x9B00_DBD8]
        );
        assert_eq!(
            philox4x32([M, M, M, M], [M, M]),
            [0x408F_276D, 0x41C8_3B0E, 0xA20B_C7C6, 0x6D54_51FD]
        );
        assert_eq!(
            philox4x32([PI[0], PI[1], PI[2], PI[3]], [PI[4], PI[5]]),
            [0xD16C_FE09, 0x94FD_CCEB, 0x5001_E420, 0x2412_6EA1]
        );
    }

    #[test]
    fn philox2x32_known_answers() {
        assert_eq!(philox2x32([0, 0], 0), [0xFF1D_AE59, 0x6CD1_0DF2]);
        assert_eq!(philox2x32([M, M], M), [0x2C3F_628B, 0xAB4F_D7AD]);
        assert_eq!(philox2x32([PI[0], PI[1]], PI[2]), [0xDD7C_E038, 0xF62A_4C12]);
    }

    #[test]
    fn stream_is_block_sequence() {
        let mut rng = Philox::new(0xABCD_EF01_2345_6789, 7);
        let direct = rng.block(0);
        let drawn: Vec<u32> = (0..8).map(|_| rng.next_u32()).collect();
        assert_eq!(&drawn[..4], &direct);
        assert_eq!(&drawn[4..], &rng.block(1));
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a: Vec<u32> = {
            let mut r = Philox::new(5, 0);
            (0..16).map(|_| r.next_u32()).collect()
        };
        let b: Vec<u32> = {
            let mut r = Philox::new(5, 0);
            (0..16).map(|_| r.next_u32()).collect()
        };
        assert_eq!(a, b);
        let c: Vec<u32> = {
            let mut r = Philox::new(6, 0);
            (0..16).map(|_| r.next_u32()).collect()
        };
        assert_ne!(a, c);
        let d: Vec<u32> = {
            let mut r = Philox::new(5, 1);
            (0..16).map(|_| r.next_u32()).collect()
        };
        assert_ne!(a, d);
    }

    #[test]
    fn fill_matches_sequential_draws_any_phase() {
        for pre in 0..5 {
            for len in [0usize, 1, 3, 4, 5, 17, 64] {
                let mut a = Philox::new(99, 3);
                let mut b = Philox::new(99, 3);
                for _ in 0..pre {
                    a.next_u32();
                    b.next_u32();
                }
                let mut buf = vec![0u32; len];
                a.fill_u32(&mut buf);
                for (i, w) in buf.iter().enumerate() {
                    assert_eq!(*w, b.next_u32(), "pre={pre} len={len} i={i}");
                }
                // Positions stay in sync afterwards too.
                assert_eq!(a.next_u32(), b.next_u32());
            }
        }
    }

    #[test]
    fn set_position_skips_ahead() {
        let mut seq = Philox::new(1, 2);
        let words: Vec<u32> = (0..40).map(|_| seq.next_u32()).collect();
        for pos in [0u64, 1, 4, 7, 13, 39] {
            let mut r = Philox::new(1, 2);
            r.set_position(pos);
            assert_eq!(r.next_u32(), words[pos as usize], "pos={pos}");
        }
    }

    #[test]
    fn philox2x32_stream_and_skip() {
        let mut seq = Philox2x32::new(42, 1);
        let words: Vec<u32> = (0..20).map(|_| seq.next_u32()).collect();
        let mut r = Philox2x32::new(42, 1);
        r.set_position(11);
        assert_eq!(r.next_u32(), words[11]);
        // Distinct from the 4x32 stream of the same identity.
        let mut p4 = Philox::new(42, 1);
        assert_ne!(words[0], p4.next_u32());
    }

    #[test]
    fn advance_matches_sequential_draws() {
        let mut seq = Philox::new(3, 9);
        let words: Vec<u32> = (0..64).map(|_| seq.next_u32()).collect();
        for start in [0usize, 1, 2, 5] {
            for n in [0u64, 1, 3, 4, 9, 32] {
                let mut r = Philox::new(3, 9);
                for _ in 0..start {
                    r.next_u32();
                }
                r.advance(n);
                assert_eq!(r.next_u32(), words[start + n as usize], "start={start} n={n}");
            }
        }
    }

    /// Regression (widened addressing): positions past 2^32 words used
    /// to be unreachable. Block index 2^32 must land in counter
    /// `[0, ctr, 1, 0]` — the high half of the 64-bit block index in the
    /// formerly-spare third word.
    #[test]
    fn set_position_beyond_4g_words() {
        let pos = (1u64 << 34) + 2; // block 2^32, word 2 of the block
        let mut r = Philox::new(7, 1);
        r.set_position(pos);
        let b = philox4x32([0, 1, 1, 0], [7, 0]); // split_seed(7) = (7, 0)
        assert_eq!(r.next_u32(), b[2]);
        assert_eq!(r.next_u32(), b[3]);
        assert_eq!(r.next_u32(), philox4x32([1, 1, 1, 0], [7, 0])[0]);
        // advance across the former u32 boundary == absolute positioning.
        let mut a = Philox::new(7, 1);
        a.set_position(u32::MAX as u64 - 1);
        a.advance(6);
        let mut s = Philox::new(7, 1);
        s.set_position(u32::MAX as u64 + 5);
        assert_eq!(a.next_u32(), s.next_u32());
    }

    #[test]
    fn jump_is_2_33_words_and_composes() {
        let mut a = Philox::new(5, 2);
        a.jump();
        let mut b = Philox::new(5, 2);
        b.set_position(1 << 33);
        assert_eq!(a.next_u32(), b.next_u32());
        a.jump(); // now at 2^33 + 1 + 2^33
        let mut c = Philox::new(5, 2);
        c.set_position((1 << 34) + 1);
        assert_eq!(a.next_u32(), c.next_u32());
    }

    #[test]
    fn philox2x32_advance_wraps_at_period() {
        let mut seq = Philox2x32::new(11, 4);
        let words: Vec<u32> = (0..32).map(|_| seq.next_u32()).collect();
        let mut r = Philox2x32::new(11, 4);
        r.advance(13);
        assert_eq!(r.next_u32(), words[13]);
        // Period 2^33: advancing by it is a no-op on the position.
        let mut w = Philox2x32::new(11, 4);
        w.advance(1 << 33);
        assert_eq!(w.next_u32(), words[0]);
        w.advance((1 << 33) - 1); // drew 1 word, +period-1 => back to 0
        assert_eq!(w.next_u32(), words[0]);
    }

    /// Cross-layer jump-ahead KAT: python/tests/test_jump_ahead.py pins
    /// the identical literals from the jnp oracle.
    #[test]
    fn jump_kats_match_python_oracle() {
        let mut j = Philox::new(7, 1);
        j.jump(); // 2^33 words = block 0x8000_0000
        assert_eq!(j.next_u32(), 0x3A29_4131);
        let mut far = Philox::new(7, 1);
        far.set_position((1 << 34) + 2); // block 2^32 (j_hi = 1), lane 2
        assert_eq!(far.next_u32(), 0x275A_0C0F);
        let mut a = Philox::new(7, 1);
        a.advance(9);
        assert_eq!(a.next_u32(), 0x498F_F58B);
        let mut j2 = Philox2x32::new(7, 1);
        j2.jump(); // 2^16 words = block 0x8000
        assert_eq!(j2.next_u32(), 0x44EF_38AA);
        let mut w = Philox2x32::new(7, 1);
        w.advance((1 << 33) + 5); // period wrap: == advance(5)
        assert_eq!(w.next_u32(), 0xB92B_6CAC);
    }

    #[test]
    fn reduced_round_variants_differ() {
        let c = [1, 2, 3, 4];
        let k = [5, 6];
        assert_ne!(philox4x32_r(c, k, 6), philox4x32_r(c, k, 10));
        assert_ne!(philox4x32_r(c, k, 7), philox4x32_r(c, k, 10));
    }
}
