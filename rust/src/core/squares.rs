//! Squares (Widynski, arXiv:2004.06278) — the middle-square Weyl-sequence
//! counter RNG. Smallest state in the family (one u64 key + one u64
//! counter) and the fastest per-draw on CPUs with a 64-bit multiplier;
//! the paper's Fig. 4a shows it (with Tyche) beating `mt19937` even at
//! long stream lengths.
//!
//! Widynski's construction requires keys with "well-mixed" hex digits
//! (his published key file); OpenRAND instead derives the key from the
//! arbitrary user seed with splitmix64 (forced odd) — documented in
//! `core::counter` and mirrored in the python oracle.

use super::block::BlockRng;
use super::counter::squares_key;
use super::traits::{CounterRng, Rng};

/// The 4-round `squares32` block function: one u32 per (ctr, key).
#[inline]
pub fn squares32(ctr: u64, key: u64) -> u32 {
    let mut x = ctr.wrapping_mul(key);
    let y = x;
    let z = y.wrapping_add(key);
    x = x.wrapping_mul(x).wrapping_add(y).rotate_left(32); // round 1
    x = x.wrapping_mul(x).wrapping_add(z).rotate_left(32); // round 2
    x = x.wrapping_mul(x).wrapping_add(y).rotate_left(32); // round 3
    (x.wrapping_mul(x).wrapping_add(z) >> 32) as u32 // round 4
}

/// The 5-round `squares64` variant: a full u64 per (ctr, key).
#[inline]
pub fn squares64(ctr: u64, key: u64) -> u64 {
    let mut x = ctr.wrapping_mul(key);
    let y = x;
    let z = y.wrapping_add(key);
    x = x.wrapping_mul(x).wrapping_add(y).rotate_left(32);
    x = x.wrapping_mul(x).wrapping_add(z).rotate_left(32);
    x = x.wrapping_mul(x).wrapping_add(y).rotate_left(32);
    let t = x.wrapping_mul(x).wrapping_add(z);
    x = t.rotate_left(32);
    t ^ (x.wrapping_mul(x).wrapping_add(y) >> 32)
}

/// Squares engine in counter mode: word `j` of stream `(seed, ctr)` is
/// `squares32((ctr << 32) | j, squares_key(seed))`.
#[derive(Debug, Clone)]
pub struct Squares {
    key: u64,
    /// High half: user ctr; low half: output index j.
    ctr: u64,
}

impl Rng for Squares {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        let w = squares32(self.ctr, self.key);
        // Only the low 32 bits advance; the user-ctr half is immutable
        // (2^32-word stream period, like the rest of the family).
        self.ctr = (self.ctr & 0xFFFF_FFFF_0000_0000) | ((self.ctr as u32).wrapping_add(1) as u64);
        w
    }
}

impl BlockRng for Squares {
    // One output word per (ctr, key) invocation — the block IS the word,
    // so there is no alignment phase to manage.
    const WORDS_PER_BLOCK: usize = 1;
    type Block = [u32; 1];

    #[inline]
    fn generate_block(&mut self, out: &mut [u32; 1]) {
        out[0] = self.next_u32();
    }
}

impl CounterRng for Squares {
    const NAME: &'static str = "squares";

    /// sqrt of the 2^32-word period: `jump()` carves a stream into
    /// 2^16 subsequences of 2^16 words.
    const JUMP_LOG2: Option<u32> = Some(16);

    #[inline]
    fn new(seed: u64, ctr: u32) -> Self {
        Squares { key: squares_key(seed), ctr: (ctr as u64) << 32 }
    }

    /// Reduces `pos` mod the 2^32-word period — exactly where `pos`
    /// sequential draws land, since only the low counter half advances.
    #[inline]
    fn set_position(&mut self, pos: u64) {
        self.ctr = (self.ctr & 0xFFFF_FFFF_0000_0000) | (pos as u32 as u64);
    }

    #[inline]
    fn advance(&mut self, n: u64) {
        let j = (self.ctr as u32).wrapping_add(n as u32);
        self.ctr = (self.ctr & 0xFFFF_FFFF_0000_0000) | j as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Transcription check against a u128-arithmetic implementation
    /// (independent of the wrapping-u64 one above).
    fn squares32_wide(ctr: u64, key: u64) -> u32 {
        fn sq(x: u64) -> u64 {
            ((x as u128 * x as u128) & 0xFFFF_FFFF_FFFF_FFFF) as u64
        }
        let x0 = ((ctr as u128 * key as u128) & 0xFFFF_FFFF_FFFF_FFFF) as u64;
        let y = x0;
        let z = y.wrapping_add(key);
        let mut x = sq(x0).wrapping_add(y).rotate_left(32);
        x = sq(x).wrapping_add(z).rotate_left(32);
        x = sq(x).wrapping_add(y).rotate_left(32);
        (sq(x).wrapping_add(z) >> 32) as u32
    }

    #[test]
    fn squares32_matches_wide_arithmetic() {
        let key = squares_key(0xDEAD_BEEF_1234_5678);
        for ctr in [0u64, 1, 2, 0xFFFF_FFFF, 0x1234_5678_9ABC_DEF0, u64::MAX] {
            assert_eq!(squares32(ctr, key), squares32_wide(ctr, key), "ctr={ctr:x}");
        }
    }

    #[test]
    fn stream_layout_is_ctr_high_j_low() {
        let mut rng = Squares::new(42, 7);
        let w0 = rng.next_u32();
        let w1 = rng.next_u32();
        let key = squares_key(42);
        assert_eq!(w0, squares32((7u64 << 32) | 0, key));
        assert_eq!(w1, squares32((7u64 << 32) | 1, key));
    }

    #[test]
    fn set_position_random_access() {
        let mut seq = Squares::new(9, 1);
        let w: Vec<u32> = (0..32).map(|_| seq.next_u32()).collect();
        let mut r = Squares::new(9, 1);
        r.set_position(17);
        assert_eq!(r.next_u32(), w[17]);
    }

    #[test]
    fn advance_and_jump_wrap_the_low_half() {
        let mut seq = Squares::new(9, 1);
        let w: Vec<u32> = (0..32).map(|_| seq.next_u32()).collect();
        let mut r = Squares::new(9, 1);
        r.advance(13);
        assert_eq!(r.next_u32(), w[13]);
        r.advance(5); // from 14 -> 19
        assert_eq!(r.next_u32(), w[19]);
        // Wrap mod 2^32 never touches the user-ctr half.
        let mut z = Squares::new(9, 1);
        z.advance(1 << 32);
        assert_eq!(z.next_u32(), w[0]);
        let mut far = Squares::new(9, 1);
        far.set_position((1u64 << 32) + 3); // reduces to 3
        assert_eq!(far.next_u32(), w[3]);
        // jump == advance(2^16).
        let mut j = Squares::new(9, 1);
        j.jump();
        let mut p = Squares::new(9, 1);
        p.set_position(1 << 16);
        assert_eq!(j.next_u32(), p.next_u32());
        // Cross-layer KAT: python/tests/test_jump_ahead.py pins the
        // identical literals from the jnp oracle.
        let mut j = Squares::new(7, 1);
        j.jump();
        assert_eq!(j.next_u32(), 0x853F_0F97);
        let mut w = Squares::new(7, 1);
        w.advance((1u64 << 32) + 3); // period wrap: == advance(3)
        assert_eq!(w.next_u32(), 0x7900_D050);
    }

    #[test]
    fn distinct_streams_per_seed_and_ctr() {
        let a: Vec<u32> = {
            let mut r = Squares::new(1, 0);
            (0..8).map(|_| r.next_u32()).collect()
        };
        for (s, c) in [(1u64, 1u32), (2, 0), (u64::MAX, 0)] {
            let b: Vec<u32> = {
                let mut r = Squares::new(s, c);
                (0..8).map(|_| r.next_u32()).collect()
            };
            assert_ne!(a, b, "seed={s} ctr={c}");
        }
    }

    #[test]
    fn squares64_extends_squares32() {
        // By construction (Widynski), the high half of squares64 IS the
        // squares32 output; round 5 only fills the low half.
        let key = squares_key(5);
        for ctr in [0u64, 3, 0xFFFF_FFFF_0000_0001] {
            let w64 = squares64(ctr, key);
            assert_eq!((w64 >> 32) as u32, squares32(ctr, key));
            assert_ne!(w64 as u32, squares32(ctr, key)); // low half is new
        }
    }
}
