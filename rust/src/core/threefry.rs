//! Threefry4x32-20 and Threefry2x32-20 (Salmon et al., SC'11) — the
//! add-rotate-xor member of the family. No multiplies at all, which makes
//! it the preferred engine on hardware without a fast 32x32→64 multiplier
//! (the paper's portability argument); the ablation bench quantifies the
//! trade against Philox on this host.

use super::block::BlockRng;
use super::counter::split_seed;
use super::traits::{CounterRng, Rng};

/// Skein key-schedule parity constant.
pub const SKEIN_PARITY: u32 = 0x1BD1_1BDA;

/// Rotation schedule for Threefry4x32 (pairs per round mod 8).
const R4: [(u32, u32); 8] =
    [(10, 26), (11, 21), (13, 27), (23, 5), (6, 20), (17, 11), (25, 10), (18, 20)];
/// Rotation schedule for Threefry2x32.
const R2: [u32; 8] = [13, 15, 26, 6, 17, 29, 16, 24];

/// Threefry4x32-R raw block function (R rounds; standard strength R = 20).
#[inline]
pub fn threefry4x32_r(ctr: [u32; 4], key: [u32; 4], rounds: u32) -> [u32; 4] {
    let ks = [
        key[0],
        key[1],
        key[2],
        key[3],
        SKEIN_PARITY ^ key[0] ^ key[1] ^ key[2] ^ key[3],
    ];
    let mut x = [
        ctr[0].wrapping_add(ks[0]),
        ctr[1].wrapping_add(ks[1]),
        ctr[2].wrapping_add(ks[2]),
        ctr[3].wrapping_add(ks[3]),
    ];
    for r in 0..rounds as usize {
        let (r0, r1) = R4[r % 8];
        if r % 2 == 0 {
            x[0] = x[0].wrapping_add(x[1]);
            x[1] = x[1].rotate_left(r0) ^ x[0];
            x[2] = x[2].wrapping_add(x[3]);
            x[3] = x[3].rotate_left(r1) ^ x[2];
        } else {
            x[0] = x[0].wrapping_add(x[3]);
            x[3] = x[3].rotate_left(r0) ^ x[0];
            x[2] = x[2].wrapping_add(x[1]);
            x[1] = x[1].rotate_left(r1) ^ x[2];
        }
        if (r + 1) % 4 == 0 {
            let q = (r + 1) / 4;
            for i in 0..4 {
                x[i] = x[i].wrapping_add(ks[(q + i) % 5]);
            }
            x[3] = x[3].wrapping_add(q as u32);
        }
    }
    x
}

/// Threefry4x32-20.
#[inline]
pub fn threefry4x32(ctr: [u32; 4], key: [u32; 4]) -> [u32; 4] {
    threefry4x32_r(ctr, key, 20)
}

/// Threefry2x32-R raw block function.
#[inline]
pub fn threefry2x32_r(ctr: [u32; 2], key: [u32; 2], rounds: u32) -> [u32; 2] {
    let ks = [key[0], key[1], SKEIN_PARITY ^ key[0] ^ key[1]];
    let mut x0 = ctr[0].wrapping_add(ks[0]);
    let mut x1 = ctr[1].wrapping_add(ks[1]);
    for r in 0..rounds as usize {
        x0 = x0.wrapping_add(x1);
        x1 = x1.rotate_left(R2[r % 8]) ^ x0;
        if (r + 1) % 4 == 0 {
            let q = (r + 1) / 4;
            x0 = x0.wrapping_add(ks[q % 3]);
            x1 = x1.wrapping_add(ks[(q + 1) % 3]).wrapping_add(q as u32);
        }
    }
    [x0, x1]
}

/// Threefry2x32-20.
#[inline]
pub fn threefry2x32(ctr: [u32; 2], key: [u32; 2]) -> [u32; 2] {
    threefry2x32_r(ctr, key, 20)
}

/// Threefry4x32-20 engine in counter mode. Like [`super::Philox`], the
/// 64-bit block index splits across counter words 0 (low) and 2 (high) —
/// bit-identical to the historical `[j, ctr, 0, 0]` layout below 2^32
/// blocks — for a `2^66`-word period with O(1) `advance`/`set_position`
/// over the first `2^64` words.
#[derive(Debug, Clone)]
pub struct Threefry {
    key: [u32; 4],
    ctr: u32,
    blk: u64,
    buf: [u32; 4],
    pos: u8,
}

impl Threefry {
    /// Counter block `j` of this stream.
    #[inline]
    pub fn block(&self, j: u64) -> [u32; 4] {
        threefry4x32([j as u32, self.ctr, (j >> 32) as u32, 0], self.key)
    }

    /// Absolute word index of the next `next_u32` result (wrapping in
    /// the `2^64`-word addressable window).
    #[inline]
    fn position(&self) -> u64 {
        if self.pos >= 4 {
            self.blk.wrapping_mul(4)
        } else {
            self.blk.wrapping_sub(1).wrapping_mul(4).wrapping_add(self.pos as u64)
        }
    }
}

impl Rng for Threefry {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        if self.pos >= 4 {
            self.buf = self.block(self.blk);
            self.blk = self.blk.wrapping_add(1);
            self.pos = 0;
        }
        let w = self.buf[self.pos as usize];
        self.pos += 1;
        w
    }

    #[inline]
    fn fill_u32(&mut self, out: &mut [u32]) {
        let mut i = 0;
        while self.pos < 4 && i < out.len() {
            out[i] = self.buf[self.pos as usize];
            self.pos += 1;
            i += 1;
        }
        while i + 4 <= out.len() {
            let b = self.block(self.blk);
            out[i..i + 4].copy_from_slice(&b);
            self.blk = self.blk.wrapping_add(1);
            i += 4;
        }
        while i < out.len() {
            out[i] = self.next_u32();
            i += 1;
        }
    }
}

impl BlockRng for Threefry {
    const WORDS_PER_BLOCK: usize = 4;
    type Block = [u32; 4];

    #[inline]
    fn generate_block(&mut self, out: &mut [u32; 4]) {
        if self.pos >= 4 {
            *out = self.block(self.blk);
            self.blk = self.blk.wrapping_add(1);
        } else {
            self.fill_u32(&mut out[..]);
        }
    }
}

impl CounterRng for Threefry {
    const NAME: &'static str = "threefry";

    /// Half the 2^66-word period, as for Philox.
    const JUMP_LOG2: Option<u32> = Some(33);

    #[inline]
    fn new(seed: u64, ctr: u32) -> Self {
        let (lo, hi) = split_seed(seed);
        Threefry { key: [lo, hi, 0, 0], ctr, blk: 0, buf: [0; 4], pos: 4 }
    }

    #[inline]
    fn set_position(&mut self, pos: u64) {
        self.blk = pos / 4;
        self.buf = self.block(self.blk);
        self.blk = self.blk.wrapping_add(1);
        self.pos = (pos % 4) as u8;
    }

    #[inline]
    fn advance(&mut self, n: u64) {
        self.set_position(self.position().wrapping_add(n));
    }
}

/// Threefry2x32-20 engine. Period `2^33` words;
/// `set_position`/`advance` reduce modulo that period.
#[derive(Debug, Clone)]
pub struct Threefry2x32 {
    key: [u32; 2],
    ctr: u32,
    blk: u32,
    buf: [u32; 2],
    pos: u8,
}

impl Threefry2x32 {
    /// Stream period in words: 2^32 counter blocks × 2 words.
    const PERIOD: u64 = 1 << 33;

    /// Absolute word index of the next `next_u32` result, mod the
    /// 2^33-word period.
    #[inline]
    fn position(&self) -> u64 {
        let p = if self.pos >= 2 {
            (self.blk as u64).wrapping_mul(2)
        } else {
            (self.blk.wrapping_sub(1) as u64).wrapping_mul(2) + self.pos as u64
        };
        p % Self::PERIOD
    }
}

impl Rng for Threefry2x32 {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        if self.pos >= 2 {
            self.buf = threefry2x32([self.blk, self.ctr], self.key);
            self.blk = self.blk.wrapping_add(1);
            self.pos = 0;
        }
        let w = self.buf[self.pos as usize];
        self.pos += 1;
        w
    }
}

impl BlockRng for Threefry2x32 {
    const WORDS_PER_BLOCK: usize = 2;
    type Block = [u32; 2];

    #[inline]
    fn generate_block(&mut self, out: &mut [u32; 2]) {
        if self.pos >= 2 {
            *out = threefry2x32([self.blk, self.ctr], self.key);
            self.blk = self.blk.wrapping_add(1);
        } else {
            out[0] = self.next_u32();
            out[1] = self.next_u32();
        }
    }
}

impl CounterRng for Threefry2x32 {
    const NAME: &'static str = "threefry2x32";

    /// ~sqrt of the 2^33-word period.
    const JUMP_LOG2: Option<u32> = Some(16);

    #[inline]
    fn new(seed: u64, ctr: u32) -> Self {
        let (lo, hi) = split_seed(seed);
        Threefry2x32 { key: [lo, hi], ctr, blk: 0, buf: [0; 2], pos: 2 }
    }

    #[inline]
    fn set_position(&mut self, pos: u64) {
        let pos = pos % Self::PERIOD;
        self.blk = (pos / 2) as u32;
        self.buf = threefry2x32([self.blk, self.ctr], self.key);
        self.blk = self.blk.wrapping_add(1);
        self.pos = (pos % 2) as u8;
    }

    #[inline]
    fn advance(&mut self, n: u64) {
        self.set_position(self.position() + n % Self::PERIOD);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const M: u32 = u32::MAX;

    #[test]
    fn threefry4x32_known_answers() {
        // Random123 kat_vectors.
        assert_eq!(
            threefry4x32([0, 0, 0, 0], [0, 0, 0, 0]),
            [0x9C6C_A96A, 0xE17E_AE66, 0xFC10_ECD4, 0x5256_A7D8]
        );
        assert_eq!(
            threefry4x32([M, M, M, M], [M, M, M, M]),
            [0x2A88_1696, 0x5701_2287, 0xF6C7_446E, 0xA16A_6732]
        );
    }

    #[test]
    fn threefry2x32_known_answers() {
        assert_eq!(threefry2x32([0, 0], [0, 0]), [0x6B20_0159, 0x99BA_4EFE]);
        assert_eq!(threefry2x32([M, M], [M, M]), [0x1CB9_96FC, 0xBB00_2BE7]);
    }

    #[test]
    fn engine_stream_matches_blocks() {
        let mut rng = Threefry::new(0xFEED_FACE_CAFE_BEEF, 3);
        let w: Vec<u32> = (0..8).map(|_| rng.next_u32()).collect();
        assert_eq!(&w[..4], &rng.block(0));
        assert_eq!(&w[4..], &rng.block(1));
    }

    #[test]
    fn fill_matches_sequential() {
        let mut a = Threefry::new(7, 0);
        let mut b = Threefry::new(7, 0);
        a.next_u32();
        b.next_u32();
        let mut buf = [0u32; 13];
        a.fill_u32(&mut buf);
        for w in buf {
            assert_eq!(w, b.next_u32());
        }
    }

    #[test]
    fn set_position_all_engines() {
        let mut seq4 = Threefry::new(1, 1);
        let w4: Vec<u32> = (0..20).map(|_| seq4.next_u32()).collect();
        let mut r4 = Threefry::new(1, 1);
        r4.set_position(9);
        assert_eq!(r4.next_u32(), w4[9]);

        let mut seq2 = Threefry2x32::new(1, 1);
        let w2: Vec<u32> = (0..20).map(|_| seq2.next_u32()).collect();
        let mut r2 = Threefry2x32::new(1, 1);
        r2.set_position(9);
        assert_eq!(r2.next_u32(), w2[9]);
    }

    #[test]
    fn advance_and_jump_match_positions() {
        let mut seq = Threefry::new(2, 6);
        let w: Vec<u32> = (0..48).map(|_| seq.next_u32()).collect();
        for start in [0usize, 3] {
            for n in [0u64, 1, 4, 7, 19] {
                let mut r = Threefry::new(2, 6);
                for _ in 0..start {
                    r.next_u32();
                }
                r.advance(n);
                assert_eq!(r.next_u32(), w[start + n as usize], "start={start} n={n}");
            }
        }
        // jump == set_position(2^33) == counter block 2^31; the hex
        // literals are the cross-layer KAT
        // (python/tests/test_jump_ahead.py pins the same values).
        let mut j = Threefry::new(2, 6);
        j.jump();
        assert_eq!(j.next_u32(), threefry4x32([0x8000_0000, 6, 0, 0], [2, 0, 0, 0])[0]);
        let mut j = Threefry::new(2, 6);
        j.jump();
        assert_eq!(j.next_u32(), 0xDFC6_93FF);
        // >4G-word regression: block 2^32 spills into counter word 2.
        let mut far = Threefry::new(2, 6);
        far.set_position(1 << 34);
        assert_eq!(far.next_u32(), threefry4x32([0, 6, 1, 0], [2, 0, 0, 0])[0]);
        let mut far = Threefry::new(2, 6);
        far.set_position(1 << 34);
        assert_eq!(far.next_u32(), 0x31AD_C0A0);
        let mut j2 = Threefry2x32::new(5, 3);
        j2.jump(); // 2^16 words = block 0x8000
        assert_eq!(j2.next_u32(), 0xFB12_54E1);

        let mut seq2 = Threefry2x32::new(2, 6);
        let w2: Vec<u32> = (0..24).map(|_| seq2.next_u32()).collect();
        let mut r2 = Threefry2x32::new(2, 6);
        r2.advance(17);
        assert_eq!(r2.next_u32(), w2[17]);
        let mut p2 = Threefry2x32::new(2, 6);
        p2.advance(1 << 33); // full period: no-op on the position
        assert_eq!(p2.next_u32(), w2[0]);
    }

    #[test]
    fn rounds_ablation_distinct() {
        let c = [9, 8, 7, 6];
        let k = [1, 2, 3, 4];
        assert_ne!(threefry4x32_r(c, k, 12), threefry4x32_r(c, k, 20));
    }
}
