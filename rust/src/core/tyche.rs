//! Tyche and Tyche-i (Neves & Araujo, PPAM'11) — ChaCha-quarter-round
//! based small-state generators. Not strictly counter-based: a stream is
//! seeded from `(seed, ctr)` with 20 warm-up rounds and then advances
//! sequentially. The paper includes them for their CPU speed (Fig. 4a)
//! and runs them through the first published parallel-stream correlation
//! tests (§5.2) — reproduced here by `stats::parallel`.

use super::block::BlockRng;
use super::counter::split_seed;
use super::traits::{CounterRng, Rng};

pub const TYCHE_C: u32 = 2_654_435_769;
pub const TYCHE_D: u32 = 1_367_130_551;

#[derive(Debug, Clone, Copy)]
struct State {
    a: u32,
    b: u32,
    c: u32,
    d: u32,
}

#[inline(always)]
fn mix(s: State) -> State {
    let State { mut a, mut b, mut c, mut d } = s;
    a = a.wrapping_add(b);
    d = (d ^ a).rotate_left(16);
    c = c.wrapping_add(d);
    b = (b ^ c).rotate_left(12);
    a = a.wrapping_add(b);
    d = (d ^ a).rotate_left(8);
    c = c.wrapping_add(d);
    b = (b ^ c).rotate_left(7);
    State { a, b, c, d }
}

#[inline(always)]
fn mix_i(s: State) -> State {
    let State { mut a, mut b, mut c, mut d } = s;
    b = b.rotate_right(7) ^ c;
    c = c.wrapping_sub(d);
    d = d.rotate_right(8) ^ a;
    a = a.wrapping_sub(b);
    b = b.rotate_right(12) ^ c;
    c = c.wrapping_sub(d);
    d = d.rotate_right(16) ^ a;
    a = a.wrapping_sub(b);
    State { a, b, c, d }
}

#[inline]
fn init(seed: u64, ctr: u32, inverse: bool) -> State {
    let (lo, hi) = split_seed(seed);
    let mut s = State { a: hi, b: lo, c: TYCHE_C, d: TYCHE_D ^ ctr };
    for _ in 0..20 {
        s = if inverse { mix_i(s) } else { mix(s) };
    }
    s
}

/// Tyche: one MIX per output, returns `b`.
#[derive(Debug, Clone)]
pub struct Tyche {
    s: State,
    /// Post-warm-up stream origin: `set_position` replays from here, so
    /// jumps are absolute from any current state (matching the trait
    /// contract) at the documented O(pos) cost.
    s0: State,
}

impl Rng for Tyche {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        self.s = mix(self.s);
        self.s.b
    }
}

impl BlockRng for Tyche {
    // Sequential generator: one MIX per word, block size 1.
    const WORDS_PER_BLOCK: usize = 1;
    type Block = [u32; 1];

    #[inline]
    fn generate_block(&mut self, out: &mut [u32; 1]) {
        out[0] = self.next_u32();
    }
}

impl CounterRng for Tyche {
    const NAME: &'static str = "tyche";

    /// No O(1) far jump: the state only steps forward one MIX at a time,
    /// so `jump()` panics (an O(2^k) "jump" would defeat its point).
    const JUMP_LOG2: Option<u32> = None;

    #[inline]
    fn new(seed: u64, ctr: u32) -> Self {
        let s0 = init(seed, ctr, false);
        Tyche { s: s0, s0 }
    }

    /// O(pos): Tyche has no counter to jump — documented exception.
    /// Absolute (replays from the warm-up origin), like the rest of the
    /// family.
    fn set_position(&mut self, pos: u64) {
        self.s = self.s0;
        for _ in 0..pos {
            self.s = mix(self.s);
        }
    }

    /// O(n) — steps the MIX forward from the *current* state (no
    /// replay), so `advance` is the cheap way to stride a Tyche stream.
    fn advance(&mut self, n: u64) {
        for _ in 0..n {
            self.s = mix(self.s);
        }
    }
}

/// Tyche-i: the inverse quarter-round, ~20% faster on superscalar CPUs
/// (shorter dependency chain), returns `a`.
#[derive(Debug, Clone)]
pub struct TycheI {
    s: State,
    /// Post-warm-up stream origin (see [`Tyche`]).
    s0: State,
}

impl Rng for TycheI {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        self.s = mix_i(self.s);
        self.s.a
    }
}

impl BlockRng for TycheI {
    const WORDS_PER_BLOCK: usize = 1;
    type Block = [u32; 1];

    #[inline]
    fn generate_block(&mut self, out: &mut [u32; 1]) {
        out[0] = self.next_u32();
    }
}

impl CounterRng for TycheI {
    const NAME: &'static str = "tyche_i";

    /// No O(1) far jump — same exception as [`Tyche`].
    const JUMP_LOG2: Option<u32> = None;

    #[inline]
    fn new(seed: u64, ctr: u32) -> Self {
        let s0 = init(seed, ctr, true);
        TycheI { s: s0, s0 }
    }

    /// O(pos) — same exception (and same absolute semantics) as
    /// [`Tyche`].
    fn set_position(&mut self, pos: u64) {
        self.s = self.s0;
        for _ in 0..pos {
            self.s = mix_i(self.s);
        }
    }

    /// O(n) stepping from the current state, as for [`Tyche`].
    fn advance(&mut self, n: u64) {
        for _ in 0..n {
            self.s = mix_i(self.s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Plain-u64-arithmetic transcription of the Tyche paper's MIX, as an
    /// independent implementation check (mirrors the python test).
    fn mix_reference(v: [u32; 4]) -> [u32; 4] {
        let rotl = |x: u32, n: u32| x.rotate_left(n);
        let (mut a, mut b, mut c, mut d) = (v[0], v[1], v[2], v[3]);
        a = a.wrapping_add(b);
        d = rotl(d ^ a, 16);
        c = c.wrapping_add(d);
        b = rotl(b ^ c, 12);
        a = a.wrapping_add(b);
        d = rotl(d ^ a, 8);
        c = c.wrapping_add(d);
        b = rotl(b ^ c, 7);
        [a, b, c, d]
    }

    #[test]
    fn mix_matches_reference() {
        let s = mix(State { a: 1, b: 2, c: 3, d: 4 });
        assert_eq!([s.a, s.b, s.c, s.d], mix_reference([1, 2, 3, 4]));
    }

    #[test]
    fn mix_i_inverts_mix() {
        // MIX-i is the algebraic inverse of MIX (that's its derivation).
        let s0 = State { a: 0xDEAD_BEEF, b: 0x0123_4567, c: 0x89AB_CDEF, d: 0x5555_AAAA };
        let s1 = mix_i(mix(s0));
        assert_eq!(
            [s1.a, s1.b, s1.c, s1.d],
            [s0.a, s0.b, s0.c, s0.d],
            "mix_i(mix(s)) != s"
        );
    }

    #[test]
    fn deterministic_streams() {
        let w = |seed, ctr| -> Vec<u32> {
            let mut r = Tyche::new(seed, ctr);
            (0..16).map(|_| r.next_u32()).collect()
        };
        assert_eq!(w(1, 0), w(1, 0));
        assert_ne!(w(1, 0), w(1, 1));
        assert_ne!(w(1, 0), w(2, 0));
    }

    #[test]
    fn tyche_and_tyche_i_are_distinct_generators() {
        let mut t = Tyche::new(5, 0);
        let mut ti = TycheI::new(5, 0);
        let a: Vec<u32> = (0..8).map(|_| t.next_u32()).collect();
        let b: Vec<u32> = (0..8).map(|_| ti.next_u32()).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn set_position_sequential_equivalence() {
        let mut seq = Tyche::new(3, 3);
        let w: Vec<u32> = (0..24).map(|_| seq.next_u32()).collect();
        let mut r = Tyche::new(3, 3);
        r.set_position(10);
        assert_eq!(r.next_u32(), w[10]);
    }

    #[test]
    fn set_position_is_absolute_from_any_state() {
        // The trait contract: set_position targets an absolute word
        // index regardless of where the stream currently is. Tyche
        // replays from the warm-up origin, so jumping "back" works too.
        let mut seq = Tyche::new(3, 3);
        let w: Vec<u32> = (0..24).map(|_| seq.next_u32()).collect();
        let mut r = Tyche::new(3, 3);
        r.set_position(20);
        r.set_position(5); // second jump must not compound with the first
        assert_eq!(r.next_u32(), w[5]);

        let mut ri = TycheI::new(3, 3);
        let first = ri.next_u32();
        ri.next_u32();
        ri.set_position(0);
        assert_eq!(ri.next_u32(), first);
    }

    #[test]
    fn advance_steps_from_current_state() {
        let mut seq = Tyche::new(3, 3);
        let w: Vec<u32> = (0..24).map(|_| seq.next_u32()).collect();
        let mut r = Tyche::new(3, 3);
        r.advance(7);
        assert_eq!(r.next_u32(), w[7]);
        r.advance(4); // relative: 8 drawn + 4 skipped -> word 12
        assert_eq!(r.next_u32(), w[12]);

        let mut seqi = TycheI::new(3, 3);
        let wi: Vec<u32> = (0..8).map(|_| seqi.next_u32()).collect();
        let mut ri = TycheI::new(3, 3);
        ri.advance(5);
        assert_eq!(ri.next_u32(), wi[5]);

        // Cross-layer KAT: python/tests/test_jump_ahead.py pins the
        // identical literals from the jnp oracle.
        let mut k = Tyche::new(7, 1);
        k.advance(5);
        assert_eq!(k.next_u32(), 0x6912_D082);
        let mut ki = TycheI::new(7, 1);
        ki.advance(5);
        assert_eq!(ki.next_u32(), 0xC117_0F7E);
    }

    #[test]
    #[should_panic(expected = "jump() unsupported")]
    fn jump_panics_without_o1_skip() {
        Tyche::new(1, 0).jump();
    }

    #[test]
    fn warmup_gives_avalanche_on_ctr() {
        // Even though ctr only lands in word d, 20 warm-up rounds spread
        // it: first outputs of adjacent ctrs should differ in ~16 bits.
        let mut x = Tyche::new(42, 0);
        let mut y = Tyche::new(42, 1);
        let d = (x.next_u32() ^ y.next_u32()).count_ones();
        assert!((8..=24).contains(&d), "{d}");
    }
}
