//! Block-granular stream access — the engine-side contract behind the
//! buffer-oriented fill architecture.
//!
//! Every counter-based engine in the family produces output in fixed-size
//! *counter blocks* (4 words for Philox4x32/Threefry4x32, 2 for the 2x32
//! variants, 1 for Squares and the Tyche pair). The word-at-a-time
//! [`Rng`] API hides that structure behind a per-engine buffer; the
//! [`BlockRng`] trait exposes it, so bulk consumers (`core::fill`, the
//! simulation kernels, future SIMD/device backends) can generate a whole
//! block per call with no per-word bookkeeping.
//!
//! ## Contract (normative — see `docs/stream-contracts.md` §3)
//!
//! `generate_block(&mut self, out)` writes the **next
//! `WORDS_PER_BLOCK` words of the stream** into `out` — bit-identical to
//! `WORDS_PER_BLOCK` consecutive [`Rng::next_u32`] calls from the same
//! state — and leaves the stream positioned immediately after them. The
//! equivalence holds at *any* stream phase; engines take the raw
//! block-function fast path when the position is block-aligned and fall
//! back to the buffered path otherwise. `rust/tests/properties.rs`
//! (`prop_generate_block_equals_serial_draws`) pins this for every
//! engine.
//!
//! [`BlockBuffered`] closes the loop in the other direction: it adapts
//! any [`BlockRng`] back into a word-at-a-time [`Rng`] by buffering one
//! block, and its stream is bit-identical to the wrapped engine's.

use super::traits::{CounterRng, Rng};

/// A counter-based engine with fixed block structure.
///
/// Implementors produce `WORDS_PER_BLOCK` words per raw block-function
/// invocation; `Block` is always `[u32; WORDS_PER_BLOCK]`. The trait is
/// deliberately **not** object-safe (associated const + type): bulk
/// paths monomorphize, and dynamic dispatch keeps using `&mut dyn Rng`.
pub trait BlockRng: CounterRng {
    /// Words produced per counter block (4, 2, or 1 in this family).
    const WORDS_PER_BLOCK: usize;

    /// The block storage type — concretely `[u32; WORDS_PER_BLOCK]`.
    type Block: Copy + Default + AsRef<[u32]> + AsMut<[u32]> + core::fmt::Debug;

    /// Write the next `WORDS_PER_BLOCK` stream words into `out`,
    /// advancing the stream past them.
    ///
    /// Bit-identical to `WORDS_PER_BLOCK` consecutive
    /// [`Rng::next_u32`] calls at any stream phase (the normative
    /// block contract; see `docs/stream-contracts.md`).
    fn generate_block(&mut self, out: &mut Self::Block);
}

/// Word-at-a-time adapter over any [`BlockRng`]: buffers one block and
/// serves it word by word. The observable stream is bit-identical to
/// driving the wrapped engine directly through [`Rng`] — this is the
/// "safe buffered adapter" that lets bulk-oriented engine code keep the
/// existing draw semantics.
#[derive(Debug, Clone)]
pub struct BlockBuffered<G: BlockRng> {
    inner: G,
    buf: G::Block,
    /// Consumed words within `buf`; `WORDS_PER_BLOCK` means empty.
    pos: usize,
}

impl<G: BlockRng> BlockBuffered<G> {
    /// Wrap an engine at its current stream position.
    pub fn from_engine(inner: G) -> BlockBuffered<G> {
        BlockBuffered { inner, buf: G::Block::default(), pos: G::WORDS_PER_BLOCK }
    }

    /// Unwrap. The inner engine's position includes every word the
    /// adapter buffered, consumed or not (whole blocks are pulled at
    /// once) — callers that need word-exact positions should track them
    /// via [`CounterRng::set_position`].
    pub fn into_inner(self) -> G {
        self.inner
    }
}

impl<G: BlockRng> Rng for BlockBuffered<G> {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        if self.pos >= G::WORDS_PER_BLOCK {
            self.inner.generate_block(&mut self.buf);
            self.pos = 0;
        }
        let word = self.buf.as_ref()[self.pos];
        self.pos += 1;
        word
    }

    #[inline]
    fn fill_u32(&mut self, out: &mut [u32]) {
        let w = G::WORDS_PER_BLOCK;
        let mut i = 0;
        // Drain buffered words first so fill == repeated next_u32.
        while self.pos < w && i < out.len() {
            out[i] = self.buf.as_ref()[self.pos];
            self.pos += 1;
            i += 1;
        }
        // Whole blocks straight into the output slice.
        let mut blk = G::Block::default();
        while i + w <= out.len() {
            self.inner.generate_block(&mut blk);
            out[i..i + w].copy_from_slice(blk.as_ref());
            i += w;
        }
        while i < out.len() {
            out[i] = self.next_u32();
            i += 1;
        }
    }
}

impl<G: BlockRng> CounterRng for BlockBuffered<G> {
    /// Same stream family as the wrapped engine (the adapter changes
    /// access granularity, not stream identity).
    const NAME: &'static str = G::NAME;

    #[inline]
    fn new(seed: u64, ctr: u32) -> Self {
        BlockBuffered::from_engine(G::new(seed, ctr))
    }

    /// Same jump stride as the wrapped engine.
    const JUMP_LOG2: Option<u32> = G::JUMP_LOG2;

    #[inline]
    fn set_position(&mut self, pos: u64) {
        let w = G::WORDS_PER_BLOCK as u64;
        self.inner.set_position(pos - pos % w);
        self.inner.generate_block(&mut self.buf);
        self.pos = (pos % w) as usize;
    }

    #[inline]
    fn advance(&mut self, n: u64) {
        // The inner engine is already past every buffered word, so a
        // skip either stays inside the buffer or discards it and
        // advances the inner stream by the remainder — O(1) on top of
        // the engine's own advance.
        let buffered = (G::WORDS_PER_BLOCK - self.pos) as u64;
        if n < buffered {
            self.pos += n as usize;
        } else {
            self.pos = G::WORDS_PER_BLOCK;
            self.inner.advance(n - buffered);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Philox, Philox2x32, Squares, Threefry, Threefry2x32, Tyche, TycheI};

    fn block_equals_serial<G: BlockRng>(seed: u64, ctr: u32, pre: usize) {
        let mut a = G::new(seed, ctr);
        let mut b = G::new(seed, ctr);
        for _ in 0..pre {
            a.next_u32();
            b.next_u32();
        }
        for round in 0..5 {
            let mut blk = G::Block::default();
            a.generate_block(&mut blk);
            for (i, &w) in blk.as_ref().iter().enumerate() {
                assert_eq!(
                    w,
                    b.next_u32(),
                    "{} pre={pre} round={round} word={i}",
                    G::NAME
                );
            }
        }
        // Streams stay in lockstep afterwards.
        assert_eq!(a.next_u32(), b.next_u32(), "{} post", G::NAME);
    }

    #[test]
    fn generate_block_equals_serial_all_engines_all_phases() {
        for pre in 0..5 {
            block_equals_serial::<Philox>(0xAB, 3, pre);
            block_equals_serial::<Philox2x32>(0xAB, 3, pre);
            block_equals_serial::<Threefry>(0xAB, 3, pre);
            block_equals_serial::<Threefry2x32>(0xAB, 3, pre);
            block_equals_serial::<Squares>(0xAB, 3, pre);
            block_equals_serial::<Tyche>(0xAB, 3, pre);
            block_equals_serial::<TycheI>(0xAB, 3, pre);
        }
    }

    #[test]
    fn words_per_block_matches_block_type() {
        fn check<G: BlockRng>() {
            assert_eq!(G::Block::default().as_ref().len(), G::WORDS_PER_BLOCK);
        }
        check::<Philox>();
        check::<Philox2x32>();
        check::<Threefry>();
        check::<Threefry2x32>();
        check::<Squares>();
        check::<Tyche>();
        check::<TycheI>();
    }

    #[test]
    fn buffered_adapter_matches_raw_stream() {
        let mut raw = Philox::new(77, 9);
        let mut adapted = BlockBuffered::<Philox>::new(77, 9);
        for i in 0..40 {
            assert_eq!(raw.next_u32(), adapted.next_u32(), "word {i}");
        }
    }

    #[test]
    fn buffered_adapter_fill_matches_serial_any_phase() {
        for pre in 0..5 {
            for len in [0usize, 1, 3, 4, 5, 17] {
                let mut a = BlockBuffered::<Threefry>::new(5, 2);
                let mut b = Threefry::new(5, 2);
                for _ in 0..pre {
                    a.next_u32();
                    b.next_u32();
                }
                let mut buf = vec![0u32; len];
                a.fill_u32(&mut buf);
                for (i, &w) in buf.iter().enumerate() {
                    assert_eq!(w, b.next_u32(), "pre={pre} len={len} i={i}");
                }
                assert_eq!(a.next_u32(), b.next_u32());
            }
        }
    }

    #[test]
    fn buffered_adapter_advance_any_phase() {
        fn check<G: BlockRng>() {
            let mut seq = BlockBuffered::<G>::new(8, 1);
            let w: Vec<u32> = (0..48).map(|_| seq.next_u32()).collect();
            for start in 0..6usize {
                for n in [0u64, 1, 2, 3, 5, 8, 21] {
                    let mut r = BlockBuffered::<G>::new(8, 1);
                    for _ in 0..start {
                        r.next_u32();
                    }
                    r.advance(n);
                    assert_eq!(
                        r.next_u32(),
                        w[start + n as usize],
                        "{} start={start} n={n}",
                        G::NAME
                    );
                }
            }
        }
        check::<Philox>();
        check::<Threefry2x32>();
        check::<Squares>();
        check::<Tyche>();
    }

    #[test]
    fn buffered_adapter_set_position() {
        let mut seq = BlockBuffered::<Philox>::new(1, 2);
        let words: Vec<u32> = (0..24).map(|_| seq.next_u32()).collect();
        for pos in [0u64, 1, 4, 7, 13, 23] {
            let mut r = BlockBuffered::<Philox>::new(1, 2);
            r.set_position(pos);
            assert_eq!(r.next_u32(), words[pos as usize], "pos={pos}");
        }
        // Single-word-block engines too.
        let mut sseq = BlockBuffered::<Squares>::new(1, 2);
        let swords: Vec<u32> = (0..24).map(|_| sseq.next_u32()).collect();
        let mut s = BlockBuffered::<Squares>::new(1, 2);
        s.set_position(11);
        assert_eq!(s.next_u32(), swords[11]);
        // And the sequential Tyche, including a repeated (non-compounding)
        // jump after the adapter has already advanced.
        let mut tseq = BlockBuffered::<Tyche>::new(1, 2);
        let twords: Vec<u32> = (0..24).map(|_| tseq.next_u32()).collect();
        let mut t = BlockBuffered::<Tyche>::new(1, 2);
        t.set_position(19);
        t.next_u32();
        t.set_position(6);
        assert_eq!(t.next_u32(), twords[6]);
    }
}
