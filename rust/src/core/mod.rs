//! The OpenRAND core: counter-based random number generators (CBRNGs).
//!
//! This is the paper's primary contribution, reproduced in Rust: a single
//! family of counter-based generators behind one tiny API. A generator is
//! constructed from `(seed: u64, ctr: u32)` — the seed identifies a
//! logical processing element (a particle, a pixel, a cell), the counter
//! identifies a sub-stream for that element (a timestep, a kernel launch)
//! — and yields a statistically independent stream of 32-bit words
//! (`2^66` of them for Philox/Threefry, `2^33` for the 2x32 variants,
//! `2^32` for Squares).
//! Construction costs a few dozen integer ops and **no state** has to be
//! stored, initialized, or synchronized anywhere.
//!
//! ```
//! use openrand::core::{Philox, Rng, CounterRng};
//! let (pid, step) = (1234u64, 7u32);
//! let mut rng = Philox::new(pid, step);           // paper Fig. 1, line 15
//! let (r1, r2) = rng.draw_double2();              // paper Fig. 1, line 16
//! assert!(r1 < 1.0 && r2 < 1.0);
//! ```
//!
//! Engines: [`Philox`] (default, Philox4x32-10), [`Philox2x32`],
//! [`Threefry`] (Threefry4x32-20), [`Threefry2x32`], [`Squares`],
//! [`Tyche`], [`TycheI`]. All implement [`Rng`] (the draw API) and
//! [`CounterRng`] (the `(seed, ctr)` constructor); the Philox/Threefry
//! family additionally exposes its raw block function (Random123-style
//! low-level API) which the parallel-stream statistical tests and the
//! cross-layer bitwise tests consume.
//!
//! The `(seed, ctr)` → raw-counter mapping is the normative contract in
//! [`counter`], kept bit-identical with `python/compile/kernels/common.py`;
//! the full stream-consumption rules (word indexing, conversions, block
//! structure, fill sharding) are consolidated in `docs/stream-contracts.md`.
//!
//! Beyond the word-at-a-time draw API, every engine exposes its counter
//! blocks through [`BlockRng`], and [`fill`] builds the deterministic
//! (thread-count-invariant) bulk generation engine on top of that.

pub mod block;
pub mod counter;
pub mod fill;
pub mod philox;
pub mod squares;
pub mod threefry;
pub mod traits;
pub mod tyche;

pub use block::{BlockBuffered, BlockRng};
pub use philox::{Philox, Philox2x32};
pub use squares::Squares;
pub use threefry::{Threefry, Threefry2x32};
pub use traits::{CounterRng, Rng};
pub use tyche::{Tyche, TycheI};

/// The generator family, as a runtime tag (CLI / bench selection).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Generator {
    Philox,
    Philox2x32,
    Threefry,
    Threefry2x32,
    Squares,
    Tyche,
    TycheI,
}

impl Generator {
    pub const ALL: [Generator; 7] = [
        Generator::Philox,
        Generator::Philox2x32,
        Generator::Threefry,
        Generator::Threefry2x32,
        Generator::Squares,
        Generator::Tyche,
        Generator::TycheI,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Generator::Philox => "philox",
            Generator::Philox2x32 => "philox2x32",
            Generator::Threefry => "threefry",
            Generator::Threefry2x32 => "threefry2x32",
            Generator::Squares => "squares",
            Generator::Tyche => "tyche",
            Generator::TycheI => "tyche_i",
        }
    }

    pub fn parse(s: &str) -> Option<Generator> {
        Generator::ALL.iter().copied().find(|g| g.name() == s)
    }

    /// Internal state size in bytes (the paper's register-pressure story).
    pub fn state_bytes(self) -> usize {
        match self {
            Generator::Philox => Philox::STATE_BYTES,
            Generator::Philox2x32 => Philox2x32::STATE_BYTES,
            Generator::Threefry => Threefry::STATE_BYTES,
            Generator::Threefry2x32 => Threefry2x32::STATE_BYTES,
            Generator::Squares => Squares::STATE_BYTES,
            Generator::Tyche => Tyche::STATE_BYTES,
            Generator::TycheI => TycheI::STATE_BYTES,
        }
    }

    /// Run `f` with a monomorphized instance of the selected engine.
    pub fn with_rng<T>(self, seed: u64, ctr: u32, f: impl FnOnce(&mut dyn Rng) -> T) -> T {
        match self {
            Generator::Philox => f(&mut Philox::new(seed, ctr)),
            Generator::Philox2x32 => f(&mut Philox2x32::new(seed, ctr)),
            Generator::Threefry => f(&mut Threefry::new(seed, ctr)),
            Generator::Threefry2x32 => f(&mut Threefry2x32::new(seed, ctr)),
            Generator::Squares => f(&mut Squares::new(seed, ctr)),
            Generator::Tyche => f(&mut Tyche::new(seed, ctr)),
            Generator::TycheI => f(&mut TycheI::new(seed, ctr)),
        }
    }

    /// Boxed engine for stream `(seed, ctr)`, cursor at word 0 — the
    /// dispatch the CLI, batteries, and `stream::DynStream` share.
    #[cfg(feature = "std")]
    pub fn boxed(self, seed: u64, ctr: u32) -> Box<dyn Rng> {
        self.boxed_at(seed, ctr, 0)
    }

    /// Boxed engine positioned at absolute stream word `pos` (O(1)
    /// counter jump; Tyche/Tyche-i replay O(pos) per their documented
    /// `set_position` exception). `pos` is a full 64-bit word index —
    /// engines with shorter periods reduce it per their
    /// `set_position` contract.
    #[cfg(feature = "std")]
    pub fn boxed_at(self, seed: u64, ctr: u32, pos: u64) -> Box<dyn Rng> {
        fn mk<G: CounterRng + 'static>(seed: u64, ctr: u32, pos: u64) -> Box<dyn Rng> {
            let mut g = G::new(seed, ctr);
            if pos != 0 {
                g.set_position(pos);
            }
            Box::new(g)
        }
        match self {
            Generator::Philox => mk::<Philox>(seed, ctr, pos),
            Generator::Philox2x32 => mk::<Philox2x32>(seed, ctr, pos),
            Generator::Threefry => mk::<Threefry>(seed, ctr, pos),
            Generator::Threefry2x32 => mk::<Threefry2x32>(seed, ctr, pos),
            Generator::Squares => mk::<Squares>(seed, ctr, pos),
            Generator::Tyche => mk::<Tyche>(seed, ctr, pos),
            Generator::TycheI => mk::<TycheI>(seed, ctr, pos),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_roundtrip_names() {
        for g in Generator::ALL {
            assert_eq!(Generator::parse(g.name()), Some(g));
        }
        assert_eq!(Generator::parse("mt19937"), None);
    }

    #[test]
    fn state_sizes_fit_gpu_registers() {
        // The paper's claim: every member fits comfortably in per-thread
        // registers (cuRAND's Philox state by contrast is 64 B in global
        // memory). Bookkeeping included, every engine stays <= 48 B (12
        // u32 registers); mt19937 for comparison is ~2.5 kB.
        for g in Generator::ALL {
            assert!(g.state_bytes() <= 48, "{:?} = {}", g, g.state_bytes());
        }
    }

    #[test]
    fn with_rng_dispatches_all() {
        for g in Generator::ALL {
            let v = g.with_rng(42, 0, |r| r.draw_double());
            assert!((0.0..1.0).contains(&v), "{:?} -> {v}", g);
        }
    }

    #[test]
    fn boxed_matches_with_rng_and_positions() {
        for g in Generator::ALL {
            let want: Vec<u32> = g.with_rng(0xB0, 3, |r| (0..64).map(|_| r.next_u32()).collect());
            let mut b = g.boxed(0xB0, 3);
            let got: Vec<u32> = (0..64).map(|_| b.next_u32()).collect();
            assert_eq!(got, want, "{:?}", g);
            // boxed_at(pos) resumes at absolute word pos.
            let mut tail = g.boxed_at(0xB0, 3, 17);
            for (i, &w) in want[17..].iter().enumerate() {
                assert_eq!(tail.next_u32(), w, "{:?} word {}", g, 17 + i);
            }
        }
    }
}
