//! Dependency-free utility substrates.
//!
//! The build environment is fully offline (no clap / serde / criterion /
//! proptest), so the pieces a production launcher normally pulls from
//! crates.io are implemented here: a declarative CLI argument parser
//! ([`cli`]), FNV state hashing for reproducibility checks ([`hash`]),
//! and table/number formatting ([`format`]).

pub mod cli;
pub mod format;
pub mod hash;

pub use cli::Args;
pub use hash::Fnv1a;
