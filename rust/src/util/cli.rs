//! Minimal declarative CLI argument parser (clap substitute).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! subcommands, typed accessors with defaults, and generated `--help`
//! text. Unknown options are hard errors — a launcher that silently
//! ignores a typoed `--steps` would invalidate benchmark runs.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed argument set.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Subcommand (first bare word), if any.
    pub command: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

/// Declarative option spec used for validation + help.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

impl Args {
    /// Parse raw args (without argv[0]) against a spec. `specs` lists the
    /// accepted `--options`; the first bare word becomes the subcommand
    /// when `subcommands` is non-empty.
    pub fn parse(
        raw: impl IntoIterator<Item = String>,
        subcommands: &[&str],
        specs: &[OptSpec],
    ) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                let (key, inline_val) = match name.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (name.to_string(), None),
                };
                let spec = specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key} (try --help)"))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("--{key} is a flag and takes no value"));
                    }
                    out.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("--{key} requires a value"))?,
                    };
                    out.opts.insert(key, val);
                }
            } else if out.command.is_none() && !subcommands.is_empty() {
                if !subcommands.contains(&tok.as_str()) {
                    return Err(format!(
                        "unknown command '{tok}' (expected one of: {})",
                        subcommands.join(", ")
                    ));
                }
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Generated help text.
    pub fn help(program: &str, about: &str, subcommands: &[&str], specs: &[OptSpec]) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{program} — {about}\n");
        if !subcommands.is_empty() {
            let _ = writeln!(s, "USAGE: {program} <command> [options]\n");
            let _ = writeln!(s, "COMMANDS: {}\n", subcommands.join(", "));
        } else {
            let _ = writeln!(s, "USAGE: {program} [options]\n");
        }
        let _ = writeln!(s, "OPTIONS:");
        for spec in specs {
            let arg = if spec.is_flag {
                format!("--{}", spec.name)
            } else {
                format!("--{} <v>", spec.name)
            };
            let def = spec
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let _ = writeln!(s, "  {arg:24} {}{def}", spec.help);
        }
        s
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => parse_with_suffix(v)
                .ok_or_else(|| format!("--{name}: '{v}' is not a valid count")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        Ok(self.get_usize_as_u64(name, default)?)
    }

    fn get_usize_as_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => {
                // Allow hex seeds.
                if let Some(h) = v.strip_prefix("0x") {
                    return u64::from_str_radix(h, 16)
                        .map_err(|_| format!("--{name}: bad hex '{v}'"));
                }
                parse_with_suffix(v)
                    .map(|x| x as u64)
                    .ok_or_else(|| format!("--{name}: '{v}' is not a valid integer"))
            }
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad float '{v}'")),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Parse `123`, `64k`, `16M`, `2g` (binary suffixes).
pub fn parse_with_suffix(s: &str) -> Option<usize> {
    let s = s.trim();
    let (num, mult) = match s.chars().last()? {
        'k' | 'K' => (&s[..s.len() - 1], 1usize << 10),
        'm' | 'M' => (&s[..s.len() - 1], 1 << 20),
        'g' | 'G' => (&s[..s.len() - 1], 1 << 30),
        _ => (s, 1),
    };
    num.parse::<usize>().ok().map(|n| n * mult)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "n", help: "count", default: Some("100"), is_flag: false },
            OptSpec { name: "seed", help: "seed", default: Some("0"), is_flag: false },
            OptSpec { name: "verbose", help: "chatty", default: None, is_flag: true },
        ]
    }

    fn parse(toks: &[&str]) -> Result<Args, String> {
        Args::parse(toks.iter().map(|s| s.to_string()), &["run", "bench"], &specs())
    }

    #[test]
    fn subcommand_options_flags() {
        let a = parse(&["run", "--n", "64k", "--verbose"]).unwrap();
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 65536);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_syntax_and_hex() {
        let a = parse(&["bench", "--n=12", "--seed=0xDEAD"]).unwrap();
        assert_eq!(a.get_usize("n", 0).unwrap(), 12);
        assert_eq!(a.get_u64("seed", 0).unwrap(), 0xDEAD);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(parse(&["run", "--bogus", "1"]).is_err());
        assert!(parse(&["teleport"]).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parse(&["run", "--n"]).is_err());
        assert!(parse(&["run", "--verbose=yes"]).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["run"]).unwrap();
        assert_eq!(a.get_usize("n", 100).unwrap(), 100);
        assert_eq!(a.get_or("seed", "0"), "0");
    }

    #[test]
    fn suffixes() {
        assert_eq!(parse_with_suffix("2k"), Some(2048));
        assert_eq!(parse_with_suffix("3M"), Some(3 << 20));
        assert_eq!(parse_with_suffix("1g"), Some(1 << 30));
        assert_eq!(parse_with_suffix("zap"), None);
    }

    #[test]
    fn help_mentions_everything() {
        let h = Args::help("openrand", "rng", &["run"], &specs());
        for needle in ["openrand", "run", "--n", "--verbose", "default: 100"] {
            assert!(h.contains(needle), "missing {needle} in:\n{h}");
        }
    }
}
