//! FNV-1a streaming hash — the reproducibility fingerprint.
//!
//! The coordinator's repro checks hash entire particle arrays (bitwise,
//! via `to_bits`) and compare across thread counts / runs / host-vs-device
//! paths. FNV-1a is not cryptographic; it is deterministic, fast, and
//! order-sensitive, which is exactly what a trajectory fingerprint needs.

/// 64-bit FNV-1a.
#[derive(Debug, Clone, Copy)]
pub struct Fnv1a(u64);

impl Default for Fnv1a {
    fn default() -> Self {
        Fnv1a(0xCBF2_9CE4_8422_2325)
    }
}

impl Fnv1a {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn write_u8(&mut self, b: u8) {
        self.0 ^= b as u64;
        self.0 = self.0.wrapping_mul(0x100_0000_01B3);
    }

    #[inline]
    pub fn write_u32(&mut self, v: u32) {
        for b in v.to_le_bytes() {
            self.write_u8(b);
        }
    }

    #[inline]
    pub fn write_u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.write_u8(b);
        }
    }

    /// Bitwise hash of an f64 (NaN-safe: hashes the payload bits).
    #[inline]
    pub fn write_f64(&mut self, v: f64) {
        self.write_u64(v.to_bits());
    }

    pub fn write_f64_slice(&mut self, vs: &[f64]) {
        for &v in vs {
            self.write_f64(v);
        }
    }

    pub fn write_u32_slice(&mut self, vs: &[u32]) {
        for &v in vs {
            self.write_u32(v);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }

    /// One-shot helper.
    pub fn hash_f64s(vs: &[f64]) -> u64 {
        let mut h = Fnv1a::new();
        h.write_f64_slice(vs);
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        let h = |s: &str| {
            let mut f = Fnv1a::new();
            for b in s.bytes() {
                f.write_u8(b);
            }
            f.finish()
        };
        assert_eq!(h(""), 0xCBF2_9CE4_8422_2325);
        assert_eq!(h("a"), 0xAF63_DC4C_8601_EC8C);
        assert_eq!(h("foobar"), 0x85944171F73967E8);
    }

    #[test]
    fn order_sensitive() {
        assert_ne!(Fnv1a::hash_f64s(&[1.0, 2.0]), Fnv1a::hash_f64s(&[2.0, 1.0]));
    }

    #[test]
    fn bitwise_distinguishes_negative_zero() {
        assert_ne!(Fnv1a::hash_f64s(&[0.0]), Fnv1a::hash_f64s(&[-0.0]));
    }
}
