//! Number and duration formatting for bench tables and reports.

/// `1234567` -> `"1.23M"`, decimal engineering suffixes.
pub fn si(v: f64) -> String {
    let (div, suf) = match v.abs() {
        x if x >= 1e9 => (1e9, "G"),
        x if x >= 1e6 => (1e6, "M"),
        x if x >= 1e3 => (1e3, "k"),
        _ => (1.0, ""),
    };
    let scaled = v / div;
    if scaled >= 100.0 || suf.is_empty() && scaled.fract() == 0.0 {
        format!("{scaled:.0}{suf}")
    } else if scaled >= 10.0 {
        format!("{scaled:.1}{suf}")
    } else {
        format!("{scaled:.2}{suf}")
    }
}

/// Nanoseconds -> human time string.
pub fn ns(v: f64) -> String {
    match v.abs() {
        x if x >= 1e9 => format!("{:.2}s", v / 1e9),
        x if x >= 1e6 => format!("{:.2}ms", v / 1e6),
        x if x >= 1e3 => format!("{:.2}us", v / 1e3),
        _ => format!("{v:.1}ns"),
    }
}

/// Bytes -> human string (binary).
pub fn bytes(v: usize) -> String {
    match v {
        x if x >= 1 << 30 => format!("{:.2}GiB", v as f64 / (1u64 << 30) as f64),
        x if x >= 1 << 20 => format!("{:.2}MiB", v as f64 / (1 << 20) as f64),
        x if x >= 1 << 10 => format!("{:.2}KiB", v as f64 / (1 << 10) as f64),
        _ => format!("{v}B"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn si_suffixes() {
        assert_eq!(si(1_234_567.0), "1.23M");
        assert_eq!(si(999.0), "999");
        assert_eq!(si(45_600.0), "45.6k");
        assert_eq!(si(3.5e9), "3.50G");
    }

    #[test]
    fn time_suffixes() {
        assert_eq!(ns(1.4), "1.4ns");
        assert_eq!(ns(2_500.0), "2.50us");
        assert_eq!(ns(7.3e6), "7.30ms");
        assert_eq!(ns(1.2e9), "1.20s");
    }

    #[test]
    fn byte_suffixes() {
        assert_eq!(bytes(512), "512B");
        assert_eq!(bytes(64 << 20), "64.00MiB");
    }
}
