//! Special functions for p-values: log-gamma, regularized incomplete
//! gamma (chi-square CDF), erfc (normal CDF), and the Kolmogorov
//! distribution. Implementations follow Numerical Recipes' forms; unit
//! tests pin them against known values.

use std::f64::consts::PI;

/// ln Γ(x) — Lanczos approximation (g = 5, 6 coefficients).
pub fn ln_gamma(x: f64) -> f64 {
    assert!(x > 0.0, "ln_gamma domain: {x}");
    const COF: [f64; 6] = [
        76.180_091_729_471_46,
        -86.505_320_329_416_77,
        24.014_098_240_830_91,
        -1.231_739_572_450_155,
        0.120_865_097_386_617_9e-2,
        -0.539_523_938_495_3e-5,
    ];
    let mut y = x;
    let tmp = x + 5.5;
    let tmp = tmp - (x + 0.5) * tmp.ln();
    let mut ser = 1.000_000_000_190_015;
    for c in COF {
        y += 1.0;
        ser += c / y;
    }
    -tmp + (2.506_628_274_631_000_5 * ser / x).ln()
}

/// Regularized lower incomplete gamma P(a, x).
pub fn gamma_p(a: f64, x: f64) -> f64 {
    assert!(a > 0.0 && x >= 0.0);
    if x == 0.0 {
        return 0.0;
    }
    if x < a + 1.0 {
        // Series representation.
        let mut ap = a;
        let mut sum = 1.0 / a;
        let mut del = sum;
        for _ in 0..500 {
            ap += 1.0;
            del *= x / ap;
            sum += del;
            if del.abs() < sum.abs() * 1e-15 {
                break;
            }
        }
        sum * (-x + a * x.ln() - ln_gamma(a)).exp()
    } else {
        1.0 - gamma_q_cf(a, x)
    }
}

/// Regularized upper incomplete gamma Q(a, x) = 1 - P(a, x).
pub fn gamma_q(a: f64, x: f64) -> f64 {
    assert!(a > 0.0 && x >= 0.0);
    if x == 0.0 {
        return 1.0;
    }
    if x < a + 1.0 {
        1.0 - gamma_p(a, x)
    } else {
        gamma_q_cf(a, x)
    }
}

/// Q(a, x) by Lentz continued fraction (valid for x >= a + 1).
fn gamma_q_cf(a: f64, x: f64) -> f64 {
    let fpmin = 1e-300;
    let mut b = x + 1.0 - a;
    let mut c = 1.0 / fpmin;
    let mut d = 1.0 / b;
    let mut h = d;
    for i in 1..500 {
        let an = -(i as f64) * (i as f64 - a);
        b += 2.0;
        d = an * d + b;
        if d.abs() < fpmin {
            d = fpmin;
        }
        c = b + an / c;
        if c.abs() < fpmin {
            c = fpmin;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-15 {
            break;
        }
    }
    (-x + a * x.ln() - ln_gamma(a)).exp() * h
}

/// Chi-square survival function: P(X >= chi2) with k degrees of freedom.
/// Degenerate binning (k <= 0, e.g. a stream so broken that everything
/// pooled into one bin) is reported as a hard failure (p = 0).
pub fn chi2_sf(chi2: f64, k: f64) -> f64 {
    if k <= 0.0 {
        return 0.0;
    }
    gamma_q(k / 2.0, chi2 / 2.0)
}

/// erfc via the Chebyshev-fitted rational approximation (NR `erfcc`),
/// |error| < 1.2e-7 everywhere — adequate for 6-sigma-ish p-values; the
/// battery's FAIL threshold is 1e-6 on p, not on erfc's last digit.
pub fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 1.0 / (1.0 + 0.5 * z);
    let ans = t
        * (-z * z - 1.265_512_23
            + t * (1.000_023_68
                + t * (0.374_091_96
                    + t * (0.096_784_18
                        + t * (-0.186_288_06
                            + t * (0.278_868_07
                                + t * (-1.135_203_98
                                    + t * (1.488_515_87
                                        + t * (-0.822_152_23 + t * 0.170_872_77)))))))))
        .exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

/// Standard normal survival function P(Z >= z).
pub fn normal_sf(z: f64) -> f64 {
    0.5 * erfc(z / std::f64::consts::SQRT_2)
}

/// Two-sided p-value for an asymptotically standard-normal statistic.
pub fn normal_two_sided(z: f64) -> f64 {
    erfc(z.abs() / std::f64::consts::SQRT_2)
}

/// Kolmogorov distribution survival function
/// `Q_KS(λ) = 2 Σ_{j≥1} (-1)^{j-1} exp(-2 j² λ²)`.
///
/// The alternating series converges too slowly for small λ, so below
/// λ = 1.18 we use the Jacobi-theta-transformed CDF series instead
/// (Marsaglia, Tsang & Wang 2003).
pub fn kolmogorov_sf(lambda: f64) -> f64 {
    if lambda < 1e-8 {
        return 1.0;
    }
    if lambda < 1.18 {
        // CDF = sqrt(2π)/λ Σ_{j≥1} exp(-(2j-1)² π² / (8 λ²)).
        let mut cdf = 0.0;
        for j in 1..=20 {
            let t = (2 * j - 1) as f64;
            cdf += (-(t * t) * PI * PI / (8.0 * lambda * lambda)).exp();
        }
        cdf *= (2.0 * PI).sqrt() / lambda;
        return (1.0 - cdf).clamp(0.0, 1.0);
    }
    let mut sum = 0.0;
    let mut sign = 1.0;
    for j in 1..=100 {
        let term = (-2.0 * (j as f64) * (j as f64) * lambda * lambda).exp();
        sum += sign * term;
        sign = -sign;
        if term < 1e-16 {
            break;
        }
    }
    (2.0 * sum).clamp(0.0, 1.0)
}

/// Two-sided p-value for an observed Poisson(mu) count k (used by
/// birthday spacings: collision counts are asymptotically Poisson).
///
/// Uses `P(X <= k) + P(X >= k) - P(X = k)` rather than naive doubling:
/// the doubled form saturates at exactly 1.0 whenever k is the mode,
/// which the battery's "p suspiciously close to 1" rule would misread
/// as a failure (found by the CLI integration test — observing the mode
/// is the *most* ordinary outcome, not a defect).
pub fn poisson_two_sided(k: u64, mu: f64) -> f64 {
    let cdf = poisson_cdf(k, mu); // P(X <= k)
    let sf = if k == 0 { 1.0 } else { 1.0 - poisson_cdf(k - 1, mu) }; // P(X >= k)
    let pk = if k == 0 { cdf } else { cdf - poisson_cdf(k - 1, mu) }; // P(X = k)
    (2.0 * cdf.min(sf) - pk).clamp(0.0, 1.0)
}

/// Poisson CDF P(X <= k) = Q(k+1, mu).
pub fn poisson_cdf(k: u64, mu: f64) -> f64 {
    gamma_q((k + 1) as f64, mu)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() < tol, "{a} vs {b}");
    }

    #[test]
    fn ln_gamma_known() {
        close(ln_gamma(1.0), 0.0, 1e-10);
        close(ln_gamma(2.0), 0.0, 1e-10);
        close(ln_gamma(5.0), 24.0f64.ln(), 1e-10); // Γ(5)=24
        close(ln_gamma(0.5), (PI.sqrt()).ln(), 1e-10);
    }

    #[test]
    fn chi2_sf_known_values() {
        // chi2 = k: sf around 0.44 for k=10 (textbook: P(X>=10|k=10)=0.4405)
        close(chi2_sf(10.0, 10.0), 0.440_5, 5e-4);
        // 95th percentile of chi2(1) is 3.841.
        close(chi2_sf(3.841, 1.0), 0.05, 5e-4);
        // 99th percentile of chi2(5) is 15.086.
        close(chi2_sf(15.086, 5.0), 0.01, 5e-4);
    }

    #[test]
    fn gamma_p_q_complementary() {
        for (a, x) in [(0.5, 0.3), (3.0, 2.0), (10.0, 14.0), (100.0, 80.0)] {
            close(gamma_p(a, x) + gamma_q(a, x), 1.0, 1e-12);
        }
    }

    #[test]
    fn erfc_known_values() {
        close(erfc(0.0), 1.0, 1e-7);
        close(erfc(1.0), 0.157_299_2, 2e-7);
        close(erfc(2.0), 0.004_677_73, 2e-7);
        close(erfc(-1.0), 2.0 - 0.157_299_2, 2e-7);
    }

    #[test]
    fn normal_sf_tails() {
        close(normal_sf(0.0), 0.5, 1e-7);
        close(normal_sf(1.96), 0.025, 2e-4);
        close(normal_sf(3.0), 0.001_35, 5e-5);
    }

    #[test]
    fn kolmogorov_known() {
        // Q_KS(1.36) ≈ 0.049 (the classic 5% critical value).
        close(kolmogorov_sf(1.36), 0.049, 2e-3);
        close(kolmogorov_sf(0.0), 1.0, 1e-12);
        assert!(kolmogorov_sf(3.0) < 1e-6);
    }

    #[test]
    fn poisson_cdf_known() {
        // P(X <= 2 | mu=1) = e^-1 (1 + 1 + 0.5) = 0.9197.
        close(poisson_cdf(2, 1.0), 0.919_7, 5e-4);
        close(poisson_cdf(0, 2.0), (-2.0f64).exp(), 1e-10);
    }
}
