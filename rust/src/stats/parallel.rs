//! Parallel-stream correlation test — the HOOMD-blue procedure the paper
//! follows in §5.2 (E4 in the experiment index).
//!
//! "We simulated a scenario with 16,000 particles, generating
//! micro-streams comprising three random numbers for each particle. These
//! individual micro-streams for each particle were first combined into a
//! single concatenated stream. This unified stream was then lengthened
//! over successive iterations to examine correlations across the entire
//! system."
//!
//! Concretely: iteration `it` produces, for each particle `pid`, the
//! 3-word micro-stream of `G(seed = pid ^ global, ctr = it)`; all
//! micro-streams are concatenated in pid order and appended to the
//! unified stream, which is then subjected to the single-stream suite.
//! Any cross-stream correlation (e.g. a counter layout that makes
//! adjacent pids share blocks) shows up as serial structure here. This is
//! the test that actually validates the *counter-based* design, and per
//! the paper it is the first time Tyche and Squares get this treatment.

use super::battery::BufferedWords;
use super::suite::{StatTest, TestResult};
use crate::core::traits::{CounterRng, Rng};
use std::marker::PhantomData;

/// Streams the interleaved parallel construction as an `Rng`, so every
/// single-stream test can run on it without materializing gigabytes.
///
/// Each micro-stream is read through a **per-stream [`BufferedWords`]**
/// sized to the micro-stream length, so the suite exercises the buffered
/// bulk path (`Rng::fill_u32` per micro-stream) rather than per-word
/// draws — same words bit-for-bit by the `BufferedWords` contract, which
/// `interleaved_stream_layout` below pins against direct engine draws.
pub struct InterleavedStream<G: CounterRng + 'static> {
    n_particles: u64,
    words_per_micro: u32,
    global_seed: u64,
    // Cursor.
    iteration: u32,
    pid: u64,
    word: u32,
    cur: Option<BufferedWords>,
    _g: PhantomData<G>,
}

impl<G: CounterRng + 'static> InterleavedStream<G> {
    pub fn new(n_particles: u64, words_per_micro: u32, global_seed: u64) -> Self {
        InterleavedStream {
            n_particles,
            words_per_micro,
            global_seed,
            iteration: 0,
            pid: 0,
            word: 0,
            cur: None,
            _g: PhantomData,
        }
    }
}

impl<G: CounterRng + 'static> Rng for InterleavedStream<G> {
    fn next_u32(&mut self) -> u32 {
        if self.cur.is_none() {
            self.cur = Some(BufferedWords::new(
                Box::new(G::new(self.pid ^ self.global_seed, self.iteration)),
                self.words_per_micro as usize,
            ));
        }
        let w = self.cur.as_mut().unwrap().next_u32();
        self.word += 1;
        if self.word >= self.words_per_micro {
            self.word = 0;
            self.pid += 1;
            if self.pid >= self.n_particles {
                self.pid = 0;
                self.iteration += 1;
            }
            self.cur = None;
        }
        w
    }
}

/// The paper's parameters: 16,000 particles x 3-word micro-streams.
pub const HOOMD_PARTICLES: u64 = 16_000;
pub const HOOMD_WORDS: u32 = 3;

/// Run a set of single-stream tests over the interleaved construction.
pub fn run_parallel_suite<G: CounterRng + 'static>(
    global_seed: u64,
    words: usize,
) -> Vec<TestResult> {
    let tests: Vec<(&'static str, StatTest, f64)> = super::suite::all_tests();
    let mut out = Vec::new();
    for (_, test, weight) in tests {
        let mut stream: InterleavedStream<G> =
            InterleavedStream::new(HOOMD_PARTICLES, HOOMD_WORDS, global_seed);
        let budget = ((words as f64 * weight) as usize).max(1 << 14);
        out.push(test(&mut stream, budget));
    }
    out
}

/// Direct cross-stream check: Pearson correlation between the micro-
/// streams of adjacent pids over many iterations. Catches layouts where
/// neighboring seeds share raw counter blocks.
pub fn adjacent_stream_correlation<G: CounterRng>(global_seed: u64, iters: u32) -> TestResult {
    let mut sx = 0.0;
    let mut sy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    let mut sxy = 0.0;
    let mut n = 0.0;
    for it in 0..iters {
        for pid in 0..256u64 {
            let mut a = G::new(pid ^ global_seed, it);
            let mut b = G::new((pid + 1) ^ global_seed, it);
            for _ in 0..HOOMD_WORDS {
                let x = a.next_u32() as f64 / 2f64.powi(32);
                let y = b.next_u32() as f64 / 2f64.powi(32);
                sx += x;
                sy += y;
                sxx += x * x;
                syy += y * y;
                sxy += x * y;
                n += 1.0;
            }
        }
    }
    let mx = sx / n;
    let my = sy / n;
    let cov = sxy / n - mx * my;
    let vx = sxx / n - mx * mx;
    let vy = syy / n - my * my;
    let rho = cov / (vx * vy).sqrt();
    let z = rho * n.sqrt();
    TestResult {
        name: "adjacent_stream_corr",
        statistic: z,
        p: super::pvalue::normal_two_sided(z),
        words_used: n as usize * 2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Philox, Squares, Threefry, Tyche};
    use crate::stats::suite::Verdict;

    #[test]
    fn interleaved_stream_layout() {
        // First 3 words belong to pid 0 ctr 0; next 3 to pid 1 ctr 0...
        let mut s: InterleavedStream<Philox> = InterleavedStream::new(4, 3, 0);
        let mut direct = Philox::new(0, 0);
        for _ in 0..3 {
            assert_eq!(s.next_u32(), direct.next_u32());
        }
        let mut direct1 = Philox::new(1, 0);
        for _ in 0..3 {
            assert_eq!(s.next_u32(), direct1.next_u32());
        }
        // After all 4 particles, iteration bumps.
        for _ in 0..6 {
            s.next_u32();
        }
        let mut direct_it1 = Philox::new(0, 1);
        assert_eq!(s.next_u32(), direct_it1.next_u32());
    }

    #[test]
    fn buffered_micro_streams_match_direct_draws() {
        // The per-stream BufferedWords routing must not move a word:
        // replay the construction with direct engine draws over several
        // full pid/iteration cycles.
        let (particles, wpm) = (5u64, 3u32);
        let mut s: InterleavedStream<Squares> = InterleavedStream::new(particles, wpm, 0xAB);
        for it in 0..4u32 {
            for pid in 0..particles {
                let mut direct = Squares::new(pid ^ 0xAB, it);
                for w in 0..wpm {
                    assert_eq!(s.next_u32(), direct.next_u32(), "it={it} pid={pid} w={w}");
                }
            }
        }
    }

    #[test]
    fn philox_parallel_streams_pass() {
        for r in run_parallel_suite::<Philox>(0, 1 << 17) {
            assert_ne!(r.verdict(), Verdict::Fail, "{}: p={}", r.name, r.p);
        }
    }

    #[test]
    fn squares_and_tyche_parallel_pass() {
        // The paper: first parallel-stream correlation tests for these.
        for r in run_parallel_suite::<Squares>(42, 1 << 16) {
            assert_ne!(r.verdict(), Verdict::Fail, "squares {}: p={}", r.name, r.p);
        }
        for r in run_parallel_suite::<Tyche>(42, 1 << 16) {
            assert_ne!(r.verdict(), Verdict::Fail, "tyche {}: p={}", r.name, r.p);
        }
    }

    #[test]
    fn adjacent_streams_uncorrelated() {
        let r = adjacent_stream_correlation::<Philox>(7, 40);
        assert!(r.p > 1e-4, "p={}", r.p);
        let r = adjacent_stream_correlation::<Threefry>(7, 40);
        assert!(r.p > 1e-4, "p={}", r.p);
    }

    #[test]
    fn parallel_test_catches_shared_streams() {
        // A broken "CBRNG" that ignores the seed: every particle emits
        // the SAME micro-stream. The interleaved stream then has period
        // 3 and must fail hard.
        struct SharedStream(Philox);
        impl crate::core::traits::Rng for SharedStream {
            fn next_u32(&mut self) -> u32 {
                self.0.next_u32()
            }
        }
        impl crate::core::traits::CounterRng for SharedStream {
            const NAME: &'static str = "shared";
            fn new(_seed: u64, ctr: u32) -> Self {
                SharedStream(crate::core::CounterRng::new(0, ctr)) // seed ignored!
            }
            const JUMP_LOG2: Option<u32> = Some(33);
            fn set_position(&mut self, p: u64) {
                self.0.set_position(p)
            }
            fn advance(&mut self, n: u64) {
                self.0.advance(n)
            }
        }
        let results = run_parallel_suite::<SharedStream>(0, 1 << 16);
        let fails = results.iter().filter(|r| r.verdict() == Verdict::Fail).count();
        assert!(fails >= 3, "parallel suite lacks power: {fails} failures");
    }
}
