//! The battery: run the full suite against a generator and produce a
//! TestU01-style report (E3 in the experiment index).
//!
//! Stream words reach the tests through [`BufferedWords`]: bulk chunks
//! pulled via the engines' block-fill path (`Rng::fill_u32`), served one
//! word at a time. Bit-identical to drawing from the engine directly —
//! the fill contract (`docs/stream-contracts.md` §4) guarantees it. The
//! tests still pay one virtual `next_u32` per word either way; what the
//! chunk buys is that engine-side generation runs on the bulk block
//! path for engines that override `fill_u32` (the core family —
//! baselines on the default word-loop `fill_u32` see only the copy),
//! and it gives the battery a single knob (chunk size) for tuning word
//! delivery — [`DEFAULT_FILL_CHUNK`] is the shipped setting and
//! `openrand stats --chunk-sweep` ([`chunk_sweep`]) re-measures the
//! ladder on new hardware.

use super::suite::{all_tests, StatTest, TestResult, Verdict};
use crate::core::traits::Rng;
use std::fmt::Write as _;
use std::time::{Duration, Instant};

/// Words pulled per bulk refill of the battery's word source — the
/// default chunk for [`BufferedWords`] and the suite runners. 16k words
/// (64 KiB) amortizes the refill bookkeeping well past the 4k knee while
/// staying cache-resident; `openrand stats --chunk-sweep` measures the
/// {1k, 4k, 16k, 64k} ladder on the machine at hand, so this default can
/// be re-picked per deployment (throughput only — the chunk size is
/// bitwise invisible by the [`BufferedWords`] contract).
pub const DEFAULT_FILL_CHUNK: usize = 16 * 1024;

/// The chunk ladder `stats --chunk-sweep` measures.
pub const SWEEP_CHUNKS: [usize; 4] = [1 << 10, 1 << 12, 1 << 14, 1 << 16];

/// A word source that refills in bulk through `Rng::fill_u32` (the
/// engines' block path) and serves `next_u32` from the chunk. The
/// served stream is bit-identical to the inner engine's.
pub struct BufferedWords {
    inner: Box<dyn Rng>,
    buf: Vec<u32>,
    pos: usize,
}

impl BufferedWords {
    /// A word source refilling `chunk` words at a time. The chunk size
    /// is a pure throughput knob (see [`DEFAULT_FILL_CHUNK`]); the
    /// served stream is identical for every chunk.
    pub fn new(inner: Box<dyn Rng>, chunk: usize) -> BufferedWords {
        assert!(chunk > 0, "chunk must be positive");
        BufferedWords { inner, buf: vec![0; chunk], pos: chunk }
    }

    /// [`BufferedWords::new`] with the swept default chunk.
    pub fn with_default_chunk(inner: Box<dyn Rng>) -> BufferedWords {
        BufferedWords::new(inner, DEFAULT_FILL_CHUNK)
    }
}

impl Rng for BufferedWords {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        if self.pos == self.buf.len() {
            self.inner.fill_u32(&mut self.buf);
            self.pos = 0;
        }
        let word = self.buf[self.pos];
        self.pos += 1;
        word
    }

    #[inline]
    fn fill_u32(&mut self, out: &mut [u32]) {
        // Drain the chunk, then delegate the bulk to the engine directly.
        let mut i = 0;
        while self.pos < self.buf.len() && i < out.len() {
            out[i] = self.buf[self.pos];
            self.pos += 1;
            i += 1;
        }
        if i < out.len() {
            self.inner.fill_u32(&mut out[i..]);
        }
    }
}

/// Report for one generator across the whole suite.
#[derive(Debug, Clone)]
pub struct BatteryReport {
    pub generator: String,
    pub results: Vec<TestResult>,
    pub words_per_test: usize,
}

impl BatteryReport {
    pub fn failures(&self) -> usize {
        self.results.iter().filter(|r| r.verdict() == Verdict::Fail).count()
    }

    pub fn suspicious(&self) -> usize {
        self.results.iter().filter(|r| r.verdict() == Verdict::Suspicious).count()
    }

    pub fn passed(&self) -> bool {
        self.failures() == 0
    }

    /// TestU01-style summary table.
    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "=== battery: {} ({} words/test) ===",
            self.generator, self.words_per_test
        );
        let _ = writeln!(s, "{:<22} {:>14} {:>12}  verdict", "test", "statistic", "p-value");
        for r in &self.results {
            let v = match r.verdict() {
                Verdict::Pass => "pass",
                Verdict::Suspicious => "SUSPICIOUS",
                Verdict::Fail => "FAIL",
            };
            let _ = writeln!(s, "{:<22} {:>14.4} {:>12.3e}  {v}", r.name, r.statistic, r.p);
        }
        let _ = writeln!(
            s,
            "--- {}: {} tests, {} failures, {} suspicious ---",
            self.generator,
            self.results.len(),
            self.failures(),
            self.suspicious()
        );
        s
    }
}

/// Run an arbitrary `(name, test, weight)` suite against fresh streams
/// from `mk` — the one runner shared by the word-level battery and the
/// distribution battery ([`super::distcheck`]), so the budget policy
/// and re-seeding discipline cannot drift apart. The factory receives
/// the test index so each test gets an independent stream (TestU01
/// batteries equally re-seed between tests); `words` is the base
/// per-test budget (scaled by each test's weight).
pub fn run_suite(
    generator: &str,
    words: usize,
    tests: Vec<(&'static str, StatTest, f64)>,
    mk: impl FnMut(usize) -> Box<dyn Rng>,
) -> BatteryReport {
    run_suite_with_chunk(generator, words, tests, mk, DEFAULT_FILL_CHUNK)
}

/// [`run_suite`] with an explicit [`BufferedWords`] chunk size — the
/// `--chunk-sweep` entry point. Chunk size never changes results (the
/// buffered stream is bit-identical at any chunk), only throughput.
pub fn run_suite_with_chunk(
    generator: &str,
    words: usize,
    tests: Vec<(&'static str, StatTest, f64)>,
    mut mk: impl FnMut(usize) -> Box<dyn Rng>,
    chunk: usize,
) -> BatteryReport {
    let mut results = Vec::new();
    for (idx, (_, test, weight)) in tests.into_iter().enumerate() {
        // Words flow through the block-fill chunk buffer; same stream
        // bit-for-bit, engine-side generation on the bulk path.
        let mut rng = BufferedWords::new(mk(idx), chunk);
        let budget = ((words as f64 * weight) as usize).max(1 << 14);
        results.push(test(&mut rng, budget));
    }
    BatteryReport { generator: generator.to_string(), results, words_per_test: words }
}

/// One row of the chunk-size sweep.
#[derive(Debug, Clone, Copy)]
pub struct ChunkSweepRow {
    pub chunk: usize,
    /// Wall time for the full battery at this chunk size.
    pub wall: Duration,
    /// Words consumed per second of battery wall time.
    pub words_per_s: f64,
    pub failures: usize,
}

/// Measure battery throughput across the [`SWEEP_CHUNKS`] ladder (the
/// ROADMAP chunk-size sweep). Every run consumes the same streams —
/// chunking is bitwise invisible — so failure counts must agree across
/// rows; a per-row count is reported anyway as a sanity check.
pub fn chunk_sweep(
    generator: &str,
    words: usize,
    mut mk: impl FnMut(usize) -> Box<dyn Rng>,
) -> Vec<ChunkSweepRow> {
    SWEEP_CHUNKS
        .iter()
        .map(|&chunk| {
            let t0 = Instant::now();
            let report = run_suite_with_chunk(generator, words, all_tests(), &mut mk, chunk);
            let wall = t0.elapsed();
            let total_words: usize = report.results.iter().map(|r| r.words_used).sum();
            ChunkSweepRow {
                chunk,
                wall,
                words_per_s: total_words as f64 / wall.as_secs_f64().max(1e-9),
                failures: report.failures(),
            }
        })
        .collect()
}

/// The full word-level suite through [`run_suite`].
pub fn run_battery(
    generator: &str,
    words: usize,
    mk: impl FnMut(usize) -> Box<dyn Rng>,
) -> BatteryReport {
    run_suite(generator, words, all_tests(), mk)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::{Lcg64, WeakCounter};
    use crate::core::Generator;

    const WORDS: usize = 1 << 18;

    #[test]
    fn all_family_members_pass() {
        // The paper's core QA claim, at laptop scale: every OpenRAND
        // generator passes the whole battery.
        for g in Generator::ALL {
            let report = run_battery(g.name(), WORDS, |i| boxed(g, 0xBA77_0000 + i as u64));
            assert!(
                report.passed(),
                "{} failed battery:\n{}",
                g.name(),
                report.render()
            );
        }
    }

    fn boxed(g: Generator, seed: u64) -> Box<dyn crate::core::traits::Rng> {
        use crate::core::*;
        match g {
            Generator::Philox => Box::new(Philox::new(seed, 0)),
            Generator::Philox2x32 => Box::new(Philox2x32::new(seed, 0)),
            Generator::Threefry => Box::new(Threefry::new(seed, 0)),
            Generator::Threefry2x32 => Box::new(Threefry2x32::new(seed, 0)),
            Generator::Squares => Box::new(Squares::new(seed, 0)),
            Generator::Tyche => Box::new(Tyche::new(seed, 0)),
            Generator::TycheI => Box::new(TycheI::new(seed, 0)),
        }
    }

    #[test]
    fn buffered_words_bit_identical_to_engine() {
        use crate::core::{CounterRng, Philox};
        let mut direct = Philox::new(0xB0FF, 1);
        let mut buffered = BufferedWords::new(Box::new(Philox::new(0xB0FF, 1)), 64);
        for i in 0..1000 {
            assert_eq!(direct.next_u32(), buffered.next_u32(), "word {i}");
        }
        // Bulk path too, at sizes straddling the chunk boundary.
        let mut direct = Philox::new(0xB0FF, 2);
        let mut buffered = BufferedWords::new(Box::new(Philox::new(0xB0FF, 2)), 64);
        for len in [1usize, 7, 63, 64, 65, 200] {
            let mut a = vec![0u32; len];
            let mut b = vec![0u32; len];
            direct.fill_u32(&mut a);
            buffered.fill_u32(&mut b);
            assert_eq!(a, b, "len={len}");
        }
    }

    #[test]
    fn chunk_size_is_bitwise_invisible() {
        // The sweep's precondition: identical results at every chunk.
        let reports: Vec<BatteryReport> = SWEEP_CHUNKS
            .iter()
            .map(|&chunk| {
                run_suite_with_chunk(
                    "philox",
                    1 << 15,
                    crate::stats::suite::all_tests(),
                    |i| boxed(Generator::Philox, 0xC1 + i as u64),
                    chunk,
                )
            })
            .collect();
        for r in &reports[1..] {
            for (a, b) in reports[0].results.iter().zip(r.results.iter()) {
                assert_eq!(a.statistic.to_bits(), b.statistic.to_bits(), "{}", a.name);
                assert_eq!(a.p.to_bits(), b.p.to_bits(), "{}", a.name);
            }
        }
    }

    #[test]
    fn chunk_sweep_reports_all_rows() {
        let rows = chunk_sweep("philox", 1 << 14, |i| boxed(Generator::Philox, i as u64));
        assert_eq!(rows.len(), SWEEP_CHUNKS.len());
        for (row, &chunk) in rows.iter().zip(SWEEP_CHUNKS.iter()) {
            assert_eq!(row.chunk, chunk);
            assert!(row.words_per_s > 0.0);
            assert_eq!(row.failures, 0, "chunk={} failed battery", row.chunk);
        }
    }

    #[test]
    fn default_chunk_constructor_matches_explicit() {
        use crate::core::{CounterRng, Philox};
        let mut a = BufferedWords::with_default_chunk(Box::new(Philox::new(8, 8)));
        let mut b = BufferedWords::new(Box::new(Philox::new(8, 8)), DEFAULT_FILL_CHUNK);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn battery_has_power_weak_counter() {
        // DESIGN.md test plan: the battery must reject a raw counter.
        let report = run_battery("weak_counter", WORDS, |_| Box::new(WeakCounter::new(0)));
        assert!(
            report.failures() >= 5,
            "battery lacks power against counters:\n{}",
            report.render()
        );
    }

    #[test]
    fn battery_has_power_lcg_low_bits() {
        let report = run_battery("lcg64_low", WORDS, |_| Box::new(Lcg64::new(123)));
        assert!(
            report.failures() >= 1,
            "battery lacks power against LCG low bits:\n{}",
            report.render()
        );
    }

    #[test]
    fn report_renders_all_tests() {
        let report = run_battery("philox", 1 << 15, |i| boxed(Generator::Philox, i as u64));
        let text = report.render();
        for (name, _, _) in crate::stats::suite::all_tests() {
            assert!(text.contains(name), "missing {name}");
        }
    }
}
