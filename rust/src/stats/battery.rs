//! The battery: run the full suite against a generator and produce a
//! TestU01-style report (E3 in the experiment index).

use super::suite::{all_tests, StatTest, TestResult, Verdict};
use crate::core::traits::Rng;
use std::fmt::Write as _;

/// Report for one generator across the whole suite.
#[derive(Debug, Clone)]
pub struct BatteryReport {
    pub generator: String,
    pub results: Vec<TestResult>,
    pub words_per_test: usize,
}

impl BatteryReport {
    pub fn failures(&self) -> usize {
        self.results.iter().filter(|r| r.verdict() == Verdict::Fail).count()
    }

    pub fn suspicious(&self) -> usize {
        self.results.iter().filter(|r| r.verdict() == Verdict::Suspicious).count()
    }

    pub fn passed(&self) -> bool {
        self.failures() == 0
    }

    /// TestU01-style summary table.
    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "=== battery: {} ({} words/test) ===",
            self.generator, self.words_per_test
        );
        let _ = writeln!(s, "{:<22} {:>14} {:>12}  verdict", "test", "statistic", "p-value");
        for r in &self.results {
            let v = match r.verdict() {
                Verdict::Pass => "pass",
                Verdict::Suspicious => "SUSPICIOUS",
                Verdict::Fail => "FAIL",
            };
            let _ = writeln!(s, "{:<22} {:>14.4} {:>12.3e}  {v}", r.name, r.statistic, r.p);
        }
        let _ = writeln!(
            s,
            "--- {}: {} tests, {} failures, {} suspicious ---",
            self.generator,
            self.results.len(),
            self.failures(),
            self.suspicious()
        );
        s
    }
}

/// Run an arbitrary `(name, test, weight)` suite against fresh streams
/// from `mk` — the one runner shared by the word-level battery and the
/// distribution battery ([`super::distcheck`]), so the budget policy
/// and re-seeding discipline cannot drift apart. The factory receives
/// the test index so each test gets an independent stream (TestU01
/// batteries equally re-seed between tests); `words` is the base
/// per-test budget (scaled by each test's weight).
pub fn run_suite(
    generator: &str,
    words: usize,
    tests: Vec<(&'static str, StatTest, f64)>,
    mut mk: impl FnMut(usize) -> Box<dyn Rng>,
) -> BatteryReport {
    let mut results = Vec::new();
    for (idx, (_, test, weight)) in tests.into_iter().enumerate() {
        let mut rng = mk(idx);
        let budget = ((words as f64 * weight) as usize).max(1 << 14);
        results.push(test(rng.as_mut(), budget));
    }
    BatteryReport { generator: generator.to_string(), results, words_per_test: words }
}

/// The full word-level suite through [`run_suite`].
pub fn run_battery(
    generator: &str,
    words: usize,
    mk: impl FnMut(usize) -> Box<dyn Rng>,
) -> BatteryReport {
    run_suite(generator, words, all_tests(), mk)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::{Lcg64, WeakCounter};
    use crate::core::Generator;

    const WORDS: usize = 1 << 18;

    #[test]
    fn all_family_members_pass() {
        // The paper's core QA claim, at laptop scale: every OpenRAND
        // generator passes the whole battery.
        for g in Generator::ALL {
            let report = run_battery(g.name(), WORDS, |i| boxed(g, 0xBA77_0000 + i as u64));
            assert!(
                report.passed(),
                "{} failed battery:\n{}",
                g.name(),
                report.render()
            );
        }
    }

    fn boxed(g: Generator, seed: u64) -> Box<dyn crate::core::traits::Rng> {
        use crate::core::*;
        match g {
            Generator::Philox => Box::new(Philox::new(seed, 0)),
            Generator::Philox2x32 => Box::new(Philox2x32::new(seed, 0)),
            Generator::Threefry => Box::new(Threefry::new(seed, 0)),
            Generator::Threefry2x32 => Box::new(Threefry2x32::new(seed, 0)),
            Generator::Squares => Box::new(Squares::new(seed, 0)),
            Generator::Tyche => Box::new(Tyche::new(seed, 0)),
            Generator::TycheI => Box::new(TycheI::new(seed, 0)),
        }
    }

    #[test]
    fn battery_has_power_weak_counter() {
        // DESIGN.md test plan: the battery must reject a raw counter.
        let report = run_battery("weak_counter", WORDS, |_| Box::new(WeakCounter::new(0)));
        assert!(
            report.failures() >= 5,
            "battery lacks power against counters:\n{}",
            report.render()
        );
    }

    #[test]
    fn battery_has_power_lcg_low_bits() {
        let report = run_battery("lcg64_low", WORDS, |_| Box::new(Lcg64::new(123)));
        assert!(
            report.failures() >= 1,
            "battery lacks power against LCG low bits:\n{}",
            report.render()
        );
    }

    #[test]
    fn report_renders_all_tests() {
        let report = run_battery("philox", 1 << 15, |i| boxed(Generator::Philox, i as u64));
        let text = report.render();
        for (name, _, _) in crate::stats::suite::all_tests() {
            assert!(text.contains(name), "missing {name}");
        }
    }
}
