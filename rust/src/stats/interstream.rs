//! Inter-stream correlation battery (`openrand stats --inter-stream`).
//!
//! [`parallel`](super::parallel) reproduces the paper's HOOMD procedure:
//! a few words from each of 16k particles, re-keyed every iteration.
//! This module asks the complementary question the paper's §5.2 leaves
//! implicit: do *sibling streams of one key family* stay independent
//! when read side by side? It interleaves words round-robin from `K`
//! children of a single [`StreamKey`] — stream `s` is
//! `root(seed).child(s)` — and subjects the merged stream to the full
//! single-stream suite. Any cross-child structure (a weak
//! `derive_child_seed`, a counter layout that aliases siblings) becomes
//! serial structure here and fails the battery.
//!
//! The construction deliberately retains **no per-stream state**: each
//! word is produced by re-opening its child engine and `advance`-ing to
//! the right phase (O(1) for the counter engines). That keeps memory
//! flat in `K`, so `--streams 1000000` costs the same as `--streams 4`,
//! and it doubles as an end-to-end exercise of the jump-ahead contract:
//! a wrong `advance` *moves words* and the layout test below catches it.

use super::suite::{StatTest, TestResult};
use crate::core::traits::{CounterRng, Rng};
use crate::stream::StreamKey;
use std::marker::PhantomData;

/// Round-robin interleaving of `streams` sibling child streams, as an
/// `Rng` so every single-stream test can run on it without
/// materializing the merge.
///
/// Word `i` of the interleaving is word `(i / streams) * stride` of
/// child `i % streams`; `stride = 1` reads each child sequentially,
/// larger strides sample every `stride`-th word (a cheap decimation
/// check). Each draw re-derives the child key and `advance`s a fresh
/// engine to the phase, so the cursor is the whole state.
pub struct InterStream<G: CounterRng + 'static> {
    key: StreamKey,
    streams: u64,
    stride: u64,
    /// Next stream index in the round.
    s: u64,
    /// Completed rounds == words already taken per stream.
    q: u64,
    _g: PhantomData<G>,
}

impl<G: CounterRng + 'static> InterStream<G> {
    pub fn new(key: StreamKey, streams: u64, stride: u64) -> Self {
        assert!(streams > 0, "inter-stream battery needs at least one stream");
        assert!(stride > 0, "stride must be >= 1");
        InterStream { key, streams, stride, s: 0, q: 0, _g: PhantomData }
    }
}

impl<G: CounterRng + 'static> Rng for InterStream<G> {
    fn next_u32(&mut self) -> u32 {
        let child = self.key.child(self.s);
        let mut g = G::new(child.seed(), child.ctr());
        g.advance(self.q * self.stride);
        let w = g.next_u32();
        self.s += 1;
        if self.s == self.streams {
            self.s = 0;
            self.q += 1;
        }
        w
    }
}

/// Run the full single-stream suite over the `K`-way interleaving of
/// an arbitrary parent key's children — the CLI passes `--key` through
/// here, so child families under any epoch (`root(s).epoch(t)`) get the
/// same scrutiny as root families. Same budget shaping as
/// [`super::parallel::run_parallel_suite`].
pub fn run_inter_stream_suite_keyed<G: CounterRng + 'static>(
    key: StreamKey,
    streams: u64,
    stride: u64,
    words: usize,
) -> Vec<TestResult> {
    let tests: Vec<(&'static str, StatTest, f64)> = super::suite::all_tests();
    let mut out = Vec::new();
    for (_, test, weight) in tests {
        let mut stream: InterStream<G> = InterStream::new(key, streams, stride);
        let budget = ((words as f64 * weight) as usize).max(1 << 14);
        out.push(test(&mut stream, budget));
    }
    out
}

/// [`run_inter_stream_suite_keyed`] over `root(seed)`'s children.
pub fn run_inter_stream_suite<G: CounterRng + 'static>(
    seed: u64,
    streams: u64,
    stride: u64,
    words: usize,
) -> Vec<TestResult> {
    run_inter_stream_suite_keyed::<G>(StreamKey::root(seed), streams, stride, words)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Philox, Squares, Tyche};
    use crate::stats::suite::Verdict;

    #[test]
    fn interleaving_matches_direct_child_draws() {
        // Word (q*K + s) must be word q*stride of child s, checked
        // against plain sequential draws — this pins the advance() path
        // (a fresh engine advanced to phase q*stride) to the ground
        // truth (one engine stepped q*stride words).
        let (k, stride) = (4u64, 3u64);
        let root = StreamKey::root(0xFACE);
        let mut inter: InterStream<Philox> = InterStream::new(root, k, stride);
        let mut direct: Vec<Philox> = (0..k)
            .map(|s| {
                let c = root.child(s);
                Philox::new(c.seed(), c.ctr())
            })
            .collect();
        for q in 0..6u64 {
            for (s, d) in direct.iter_mut().enumerate() {
                let want = d.next_u32();
                // Burn the skipped stride-1 words of the direct engine.
                for _ in 0..stride - 1 {
                    d.next_u32();
                }
                assert_eq!(inter.next_u32(), want, "q={q} s={s}");
            }
        }
    }

    #[test]
    fn sequential_engines_interleave_too() {
        // Tyche has no O(1) skip (JUMP_LOG2 = None) but advance(n) is
        // still exact (O(n) stepping), so the battery must cover it.
        let root = StreamKey::root(9);
        let mut inter: InterStream<Tyche> = InterStream::new(root, 2, 1);
        let c0 = root.child(0);
        let c1 = root.child(1);
        let mut d0 = Tyche::new(c0.seed(), c0.ctr());
        let mut d1 = Tyche::new(c1.seed(), c1.ctr());
        for q in 0..5 {
            assert_eq!(inter.next_u32(), d0.next_u32(), "q={q} s=0");
            assert_eq!(inter.next_u32(), d1.next_u32(), "q={q} s=1");
        }
    }

    #[test]
    fn cursor_is_flat_in_stream_count() {
        // A million streams must construct instantly and draw from the
        // right children: word 0 is child 0's word 0, word 999_999 is
        // child 999_999's word 0.
        let k = 1_000_000u64;
        let root = StreamKey::root(3);
        let mut inter: InterStream<Squares> = InterStream::new(root, k, 1);
        let c0 = root.child(0);
        assert_eq!(inter.next_u32(), Squares::new(c0.seed(), c0.ctr()).next_u32());
        // Jump the cursor to the last stream of the round by hand.
        inter.s = k - 1;
        let clast = root.child(k - 1);
        assert_eq!(inter.next_u32(), Squares::new(clast.seed(), clast.ctr()).next_u32());
        assert_eq!((inter.s, inter.q), (0, 1));
    }

    #[test]
    fn interleaving_kat_matches_python_oracle() {
        // python/tests/test_jump_ahead.py pins the identical literals:
        // round 0 of InterStream<Philox> over root(7) with K=4, then
        // the first two words of round 1.
        let mut inter: InterStream<Philox> = InterStream::new(StreamKey::root(7), 4, 1);
        for want in [0xEF16_B664u32, 0xF128_2995, 0x89A6_8AC1, 0x079F_41FA] {
            assert_eq!(inter.next_u32(), want);
        }
        assert_eq!(inter.next_u32(), 0x2EDD_D51C);
        assert_eq!(inter.next_u32(), 0xB2BD_D7E0);
    }

    #[test]
    fn philox_inter_stream_passes() {
        for r in run_inter_stream_suite::<Philox>(0, 64, 1, 1 << 16) {
            assert_ne!(r.verdict(), Verdict::Fail, "{}: p={}", r.name, r.p);
        }
    }

    #[test]
    fn squares_inter_stream_passes() {
        for r in run_inter_stream_suite::<Squares>(42, 32, 1, 1 << 16) {
            assert_ne!(r.verdict(), Verdict::Fail, "{}: p={}", r.name, r.p);
        }
    }

    #[test]
    fn decimated_stride_passes() {
        // S > 1 reads every S-th word of each child — decimation must
        // not surface structure (this is the CI `--stride 3` tier).
        for r in run_inter_stream_suite::<Philox>(5, 32, 3, 1 << 15) {
            assert_ne!(r.verdict(), Verdict::Fail, "{}: p={}", r.name, r.p);
        }
    }

    #[test]
    fn child_mix_fuzz_over_random_parent_epochs() {
        // Battery-driven fuzzing of the campaign addressing shape:
        // child families under *randomly chosen* parent epochs
        // (`root(seed).epoch(t)`), at random decimation strides. A
        // child derivation that mishandles the ctr input would alias
        // siblings across epochs and fail here.
        use crate::core::counter::splitmix64;
        let mut s = 0x5EED_CAFE_u64;
        for round in 0..4u32 {
            s = splitmix64(s);
            let seed = splitmix64(s ^ 0xA5A5);
            let epoch = (splitmix64(s ^ 1) & 0xFFFF) as u32;
            let stride = 1 + splitmix64(s ^ 2) % 4;
            let key = StreamKey::root(seed).epoch(epoch);
            let results = if round % 2 == 0 {
                run_inter_stream_suite_keyed::<Philox>(key, 32, stride, 1 << 15)
            } else {
                run_inter_stream_suite_keyed::<Squares>(key, 32, stride, 1 << 15)
            };
            for r in results {
                assert_ne!(
                    r.verdict(),
                    Verdict::Fail,
                    "round {round} seed {seed:#x} epoch {epoch} stride {stride}: {}: p={}",
                    r.name,
                    r.p
                );
            }
        }
    }

    #[test]
    fn battery_catches_shared_children() {
        // Power self-test: a broken engine that ignores its seed makes
        // every child the SAME stream, so each round emits one word
        // repeated K times. The suite must fail hard, or this battery
        // has no detection power.
        struct SharedChild(Philox);
        impl crate::core::traits::Rng for SharedChild {
            fn next_u32(&mut self) -> u32 {
                self.0.next_u32()
            }
        }
        impl CounterRng for SharedChild {
            const NAME: &'static str = "shared-child";
            fn new(_seed: u64, ctr: u32) -> Self {
                SharedChild(CounterRng::new(0, ctr)) // seed ignored!
            }
            const JUMP_LOG2: Option<u32> = Some(33);
            fn set_position(&mut self, p: u64) {
                self.0.set_position(p)
            }
            fn advance(&mut self, n: u64) {
                self.0.advance(n)
            }
        }
        let results = run_inter_stream_suite::<SharedChild>(0, 16, 1, 1 << 16);
        let fails = results.iter().filter(|r| r.verdict() == Verdict::Fail).count();
        assert!(fails >= 3, "inter-stream battery lacks power: {fails} failures");
    }
}
