//! Statistical quality assurance — the TestU01/PractRand substitute.
//!
//! The paper validates every generator with TestU01's BigCrush and >= 1 TB
//! of PractRand (§5.2); neither tool exists in this offline environment,
//! so this module implements the same test *families* from scratch and
//! runs them at laptop scale (10^7–10^9 samples; see DESIGN.md
//! substitutions table):
//!
//! * bit-level: monobit frequency, Hamming-weight distribution, bit-serial
//!   autocorrelation, runs;
//! * value-level chi-square: byte equidistribution, serial pairs, gap,
//!   poker, permutation (order statistics);
//! * spacing/collision: birthday spacings (the TestU01 example the paper
//!   cites), collision counting;
//! * linear-algebra: GF(2) 32x32 matrix rank;
//! * continuous: Kolmogorov–Smirnov uniformity, maximum-of-t.
//!
//! [`battery`] orchestrates them into a Crush-style report; its own
//! *power* is tested by feeding known-bad generators (a raw counter, LCG
//! low bits) that MUST fail. [`parallel`] reproduces the HOOMD-blue
//! interleaved multi-stream correlation procedure the paper describes,
//! which is the part that actually exercises the counter-based design.
//! [`interstream`] is its key-family sibling: a round-robin interleave
//! of `K` `StreamKey::child` streams, each word reached by jump-ahead
//! (`openrand stats --inter-stream --streams K`). [`distcheck`] extends
//! the battery past raw words: KS / χ² / moment checks on the `dist`
//! samplers' outputs (`openrand stats --dist-battery`).

pub mod battery;
pub mod distcheck;
pub mod interstream;
pub mod parallel;
pub mod pvalue;
pub mod suite;

pub use battery::{
    chunk_sweep, run_battery, BatteryReport, BufferedWords, ChunkSweepRow, DEFAULT_FILL_CHUNK,
};
pub use distcheck::{run_dist_battery, run_dist_battery_keyed};
pub use interstream::{run_inter_stream_suite, run_inter_stream_suite_keyed, InterStream};
pub use suite::{TestResult, Verdict};
