//! Distribution-output battery: KS / χ² / moment checks on the `dist`
//! samplers, not on raw stream words.
//!
//! The word-level battery ([`super::battery`]) certifies the engines;
//! this module certifies the layer where reproducibility and quality
//! are usually lost — the transforms. Each test draws through the same
//! `&mut dyn Rng` interface as production code, constructs the sampler
//! under test internally, and reports the shared [`TestResult`] /
//! [`Verdict`] format so `BatteryReport::render` and the CLI verdict
//! logic apply unchanged (`openrand stats --dist-battery`).
//!
//! [`Verdict`]: super::suite::Verdict

use super::battery::BatteryReport;
use super::pvalue::{chi2_sf, erfc, kolmogorov_sf, ln_gamma, normal_two_sided};
use super::suite::TestResult;
use crate::core::traits::Rng;
use crate::dist::{
    Bernoulli, Binomial, BoxMuller, DiscreteAlias, Distribution, Exponential, Poisson, Uniform,
    ZigguratNormal,
};

/// Standard normal CDF via the battery's erfc.
fn normal_cdf(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

/// One-sample KS test of `xs` against a CDF; returns (D, p).
fn ks_against(xs: &mut [f64], cdf: impl Fn(f64) -> f64) -> (f64, f64) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = xs.len() as f64;
    let mut d: f64 = 0.0;
    for (i, &x) in xs.iter().enumerate() {
        let f = cdf(x);
        d = d.max((f - i as f64 / n).abs()).max(((i + 1) as f64 / n - f).abs());
    }
    let lambda = (n.sqrt() + 0.12 + 0.11 / n.sqrt()) * d;
    (d, kolmogorov_sf(lambda))
}

/// χ² of observed counts against expected counts, merging every bin
/// whose expectation is below 5 into its right neighbour (Cochran). A
/// sparse trailing remainder merges back into the last full group —
/// left standalone its tiny expectation would dominate the statistic
/// on a single unlucky tail event.
fn chi2_counts(observed: &[u64], expected: &[f64]) -> (f64, f64) {
    assert_eq!(observed.len(), expected.len());
    let mut groups: Vec<(f64, f64)> = Vec::new();
    let (mut o_acc, mut e_acc) = (0.0f64, 0.0f64);
    for (o, e) in observed.iter().zip(expected.iter()) {
        o_acc += *o as f64;
        e_acc += *e;
        if e_acc >= 5.0 {
            groups.push((o_acc, e_acc));
            o_acc = 0.0;
            e_acc = 0.0;
        }
    }
    if o_acc > 0.0 || e_acc > 0.0 {
        match groups.last_mut() {
            Some(last) => {
                last.0 += o_acc;
                last.1 += e_acc;
            }
            None => groups.push((o_acc, e_acc)),
        }
    }
    let chi2: f64 = groups.iter().map(|(o, e)| (o - e) * (o - e) / e.max(1e-300)).sum();
    let dof = (groups.len() as i64 - 1).max(1);
    (chi2, chi2_sf(chi2, dof as f64))
}

/// Poisson pmf bins 0..=hi plus a pooled tail.
fn poisson_expected(lambda: f64, hi: u64, n: usize) -> Vec<f64> {
    let mut exp: Vec<f64> = (0..=hi)
        .map(|k| {
            let lp = -lambda + k as f64 * lambda.ln() - ln_gamma(k as f64 + 1.0);
            lp.exp() * n as f64
        })
        .collect();
    let tail = n as f64 - exp.iter().sum::<f64>();
    exp.push(tail.max(0.0));
    exp
}

// ---------------------------------------------------------------------------
// The tests. Each takes (rng, n) where n is the 32-bit-word budget, to
// match the word-level battery's `StatTest` shape.
// ---------------------------------------------------------------------------

pub fn normal_box_muller_ks(rng: &mut dyn Rng, n: usize) -> TestResult {
    let m = (n / 4).clamp(100, 1 << 19);
    let d = BoxMuller::standard();
    // Sample buffer filled through the block-fill fast path (bit-identical
    // to repeated `sample`; `dist::normal` tests pin the equivalence).
    let mut xs = vec![0.0f64; m];
    d.sample_fill(rng, &mut xs);
    let (stat, p) = ks_against(&mut xs, normal_cdf);
    TestResult { name: "normal_box_muller_ks", statistic: stat, p, words_used: 4 * m }
}

pub fn normal_ziggurat_ks(rng: &mut dyn Rng, n: usize) -> TestResult {
    let m = (n / 2).clamp(100, 1 << 19);
    let d = ZigguratNormal::standard();
    let mut xs: Vec<f64> = (0..m).map(|_| d.sample(rng)).collect();
    let (stat, p) = ks_against(&mut xs, normal_cdf);
    TestResult { name: "normal_ziggurat_ks", statistic: stat, p, words_used: m }
}

pub fn normal_moments_z(rng: &mut dyn Rng, n: usize) -> TestResult {
    // z-statistics for the first two moments of Box–Muller output;
    // reported statistic is the worse of the two.
    let m = (n / 4).clamp(1000, 1 << 20);
    let d = BoxMuller::standard();
    let (mut s1, mut s2) = (0.0f64, 0.0f64);
    for _ in 0..m {
        let x = d.sample(rng);
        s1 += x;
        s2 += x * x;
    }
    let nf = m as f64;
    let mean = s1 / nf;
    let var = s2 / nf - mean * mean;
    let z_mean = mean * nf.sqrt(); // sd of mean = 1/sqrt(n)
    let z_var = (var - 1.0) * (nf / 2.0).sqrt(); // sd of var ≈ sqrt(2/n)
    let z = if z_mean.abs() >= z_var.abs() { z_mean } else { z_var };
    // Šidák-combine the two p-values (min over 2 independent tests).
    // Clamped Bonferroni (2p capped at 1) would sit at exactly p = 1 for
    // half of all healthy runs, which the verdict rule reads as failure.
    let p_min = normal_two_sided(z_mean).min(normal_two_sided(z_var));
    let p = 1.0 - (1.0 - p_min) * (1.0 - p_min);
    TestResult { name: "normal_moments_z", statistic: z, p, words_used: 4 * m }
}

pub fn exponential_ks(rng: &mut dyn Rng, n: usize) -> TestResult {
    let m = (n / 2).clamp(100, 1 << 19);
    let lambda = 1.7;
    let d = Exponential::new(lambda);
    let mut xs: Vec<f64> = (0..m).map(|_| d.sample(rng)).collect();
    let (stat, p) = ks_against(&mut xs, |x| 1.0 - (-lambda * x).exp());
    TestResult { name: "exponential_ks", statistic: stat, p, words_used: 2 * m }
}

pub fn uniform_interval_ks(rng: &mut dyn Rng, n: usize) -> TestResult {
    let m = (n / 2).clamp(100, 1 << 19);
    let d = Uniform::new(-1.0, 1.0);
    // Sample buffer filled through the block-fill fast path.
    let mut xs = vec![0.0f64; m];
    d.sample_fill(rng, &mut xs);
    let (stat, p) = ks_against(&mut xs, |x| (x + 1.0) / 2.0);
    TestResult { name: "uniform_interval_ks", statistic: stat, p, words_used: 2 * m }
}

pub fn poisson_knuth_chi2(rng: &mut dyn Rng, n: usize) -> TestResult {
    // λ = 4.5 exercises the Knuth branch; ~11 words per sample.
    let m = (n / 11).clamp(1000, 1 << 17);
    let lambda = 4.5;
    let d = Poisson::new(lambda);
    let hi = 15u64;
    let mut counts = vec![0u64; hi as usize + 2];
    for _ in 0..m {
        let k = d.sample(rng).min(hi + 1);
        counts[k as usize] += 1;
    }
    let (stat, p) = chi2_counts(&counts, &poisson_expected(lambda, hi, m));
    TestResult { name: "poisson_knuth_chi2", statistic: stat, p, words_used: 11 * m }
}

pub fn poisson_ptrs_chi2(rng: &mut dyn Rng, n: usize) -> TestResult {
    // λ = 40 exercises the PTRS branch; ~4.4 words per sample.
    let m = (n / 5).clamp(1000, 1 << 17);
    let lambda = 40.0;
    let d = Poisson::new(lambda);
    let hi = 80u64;
    let mut counts = vec![0u64; hi as usize + 2];
    for _ in 0..m {
        let k = d.sample(rng).min(hi + 1);
        counts[k as usize] += 1;
    }
    let (stat, p) = chi2_counts(&counts, &poisson_expected(lambda, hi, m));
    TestResult { name: "poisson_ptrs_chi2", statistic: stat, p, words_used: 5 * m }
}

pub fn bernoulli_freq_z(rng: &mut dyn Rng, n: usize) -> TestResult {
    let m = (n / 2).clamp(1000, 1 << 20);
    let p_true = 0.3;
    let d = Bernoulli::new(p_true);
    let hits = (0..m).filter(|_| d.sample(rng)).count();
    let z = (hits as f64 - m as f64 * p_true) / (m as f64 * p_true * (1.0 - p_true)).sqrt();
    TestResult { name: "bernoulli_freq_z", statistic: z, p: normal_two_sided(z), words_used: 2 * m }
}

pub fn binomial_chi2(rng: &mut dyn Rng, n: usize) -> TestResult {
    // Binomial(12, 0.4): 24 words per sample.
    let m = (n / 24).clamp(1000, 1 << 16);
    let (trials, p_true) = (12u32, 0.4f64);
    let d = Binomial::new(trials, p_true);
    let mut counts = vec![0u64; trials as usize + 1];
    for _ in 0..m {
        counts[d.sample(rng) as usize] += 1;
    }
    let expected: Vec<f64> = (0..=trials as u64)
        .map(|k| {
            let lp = ln_gamma(trials as f64 + 1.0)
                - ln_gamma(k as f64 + 1.0)
                - ln_gamma((trials as u64 - k) as f64 + 1.0)
                + k as f64 * p_true.ln()
                + (trials as u64 - k) as f64 * (1.0 - p_true).ln();
            lp.exp() * m as f64
        })
        .collect();
    let (stat, p) = chi2_counts(&counts, &expected);
    TestResult { name: "binomial_chi2", statistic: stat, p, words_used: 24 * m }
}

pub fn alias_weights_chi2(rng: &mut dyn Rng, n: usize) -> TestResult {
    // 8 categories with a 1..8 ramp; ~3 words per sample.
    let weights: Vec<f64> = (1..=8).map(|w| w as f64).collect();
    let total: f64 = weights.iter().sum();
    let m = (n / 3).clamp(1000, 1 << 18);
    let d = DiscreteAlias::new(&weights);
    let mut counts = vec![0u64; weights.len()];
    for _ in 0..m {
        counts[d.sample(rng)] += 1;
    }
    let expected: Vec<f64> = weights.iter().map(|w| w / total * m as f64).collect();
    let (stat, p) = chi2_counts(&counts, &expected);
    TestResult { name: "alias_weights_chi2", statistic: stat, p, words_used: 3 * m }
}

/// A distribution-output statistical test (the same shape as the
/// word-level suite's tests, so both batteries share one runner).
pub type DistTest = super::suite::StatTest;

/// The distribution battery, in execution order, with word-budget
/// weights (mirrors `suite::all_tests`).
pub fn all_dist_tests() -> Vec<(&'static str, DistTest, f64)> {
    vec![
        ("normal_box_muller_ks", normal_box_muller_ks as DistTest, 1.0),
        ("normal_ziggurat_ks", normal_ziggurat_ks, 1.0),
        ("normal_moments_z", normal_moments_z, 1.0),
        ("exponential_ks", exponential_ks, 1.0),
        ("uniform_interval_ks", uniform_interval_ks, 1.0),
        ("poisson_knuth_chi2", poisson_knuth_chi2, 1.0),
        ("poisson_ptrs_chi2", poisson_ptrs_chi2, 1.0),
        ("bernoulli_freq_z", bernoulli_freq_z, 0.5),
        ("binomial_chi2", binomial_chi2, 1.0),
        ("alias_weights_chi2", alias_weights_chi2, 0.5),
    ]
}

/// Run the distribution battery against fresh streams from `mk` (one
/// per test) through the shared [`super::battery::run_suite`] runner.
pub fn run_dist_battery(
    generator: &str,
    words: usize,
    mk: impl FnMut(usize) -> Box<dyn Rng>,
) -> BatteryReport {
    super::battery::run_suite(&format!("{generator} [distributions]"), words, all_dist_tests(), mk)
}

/// The hierarchically-addressed battery entry (`stats --dist-battery
/// --key ...`): test `i` draws from the derived stream `root.child(i)`
/// — the re-seeding discipline as structural key derivation instead of
/// ad-hoc seed arithmetic — served through
/// [`crate::stream::BackendWords`], so each test's word budget arrives
/// as one prefix fill on the calibrated default `Auto` backend (the
/// ROADMAP "Auto-backend consumers" item for the battery defaults).
/// Words served are bit-identical to draining each child stream
/// directly; only the delivery route differs.
pub fn run_dist_battery_keyed(
    gen: crate::core::Generator,
    root: crate::stream::StreamKey,
    words: usize,
) -> BatteryReport {
    // Prefetch what each test will actually draw — the same weighted
    // budget formula `run_suite` applies — so half-weight tests don't
    // materialize words they discard. Slight overdraw past the budget
    // (rejection samplers, clamp floors) spills to the word-at-a-time
    // tail, which BackendWords serves seamlessly.
    let weights: Vec<f64> = all_dist_tests().iter().map(|(_, _, w)| *w).collect();
    super::battery::run_suite(
        &format!("{} [distributions @ {root}]", gen.name()),
        words,
        all_dist_tests(),
        |i| -> Box<dyn Rng> {
            let budget = ((words as f64 * weights[i]) as usize).max(1 << 14);
            Box::new(crate::stream::BackendWords::auto(gen, root.child(i as u64), budget))
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{CounterRng, Philox, Squares, Tyche};
    use crate::stats::Verdict;

    const WORDS: usize = 1 << 18;

    #[test]
    fn dist_battery_passes_on_good_engines() {
        for (name, mk) in [
            ("philox", Box::new(|i: usize| -> Box<dyn Rng> {
                Box::new(Philox::new(0xD157_0000 + i as u64, 0))
            }) as Box<dyn Fn(usize) -> Box<dyn Rng>>),
            ("squares", Box::new(|i| Box::new(Squares::new(0xD157_1000 + i as u64, 0)))),
            ("tyche", Box::new(|i| Box::new(Tyche::new(0xD157_2000 + i as u64, 0)))),
        ] {
            let report = run_dist_battery(name, WORDS, |i| mk(i));
            assert!(report.passed(), "{name} failed:\n{}", report.render());
        }
    }

    #[test]
    fn dist_battery_has_power_against_biased_uniforms() {
        // An engine whose doubles live in [0, 0.5) must be caught by the
        // continuous tests (the transforms inherit the bias).
        struct Half(Philox);
        impl Rng for Half {
            fn next_u32(&mut self) -> u32 {
                self.0.next_u32() >> 1
            }
        }
        let report =
            run_dist_battery("half_philox", WORDS, |i| Box::new(Half(Philox::new(i as u64, 0))));
        assert!(
            report.failures() >= 4,
            "distribution battery lacks power:\n{}",
            report.render()
        );
    }

    #[test]
    fn keyed_battery_matches_direct_child_streams_and_passes() {
        use crate::core::Generator;
        use crate::stream::StreamKey;
        let root = StreamKey::root(0xD157_3000);
        let keyed = run_dist_battery_keyed(Generator::Philox, root, 1 << 16);
        assert!(keyed.passed(), "keyed battery failed:\n{}", keyed.render());
        // The BackendWords delivery is bitwise invisible: identical
        // statistics to serving each child stream directly.
        let direct = run_dist_battery("direct", 1 << 16, |i| {
            let k = root.child(i as u64);
            Generator::Philox.boxed(k.seed(), k.ctr())
        });
        for (a, b) in keyed.results.iter().zip(direct.results.iter()) {
            assert_eq!(a.statistic.to_bits(), b.statistic.to_bits(), "{}", a.name);
            assert_eq!(a.p.to_bits(), b.p.to_bits(), "{}", a.name);
        }
        // The report names the root so runs are attributable.
        assert!(keyed.generator.contains("distributions @"), "{}", keyed.generator);
    }

    #[test]
    fn report_renders_all_dist_tests() {
        let report = run_dist_battery("philox", 1 << 15, |i| Box::new(Philox::new(i as u64, 1)));
        let text = report.render();
        for (name, _, _) in all_dist_tests() {
            assert!(text.contains(name), "missing {name} in:\n{text}");
        }
        assert!(text.contains("[distributions]"));
    }

    #[test]
    fn every_dist_test_reports_verdict_fields() {
        let mut rng = Philox::new(42, 0);
        for (name, test, _) in all_dist_tests() {
            let r = test(&mut rng, 1 << 15);
            assert_eq!(r.name, name);
            assert!((0.0..=1.0).contains(&r.p), "{name}: p = {}", r.p);
            assert!(r.words_used > 0);
            // Smoke the verdict path too.
            let _ = matches!(r.verdict(), Verdict::Pass | Verdict::Suspicious | Verdict::Fail);
        }
    }

    #[test]
    fn chi2_counts_merges_sparse_bins() {
        // 3 well-filled bins + a sparse tail that must be pooled.
        let observed = [50u64, 52, 48, 1, 0, 1];
        let expected = [50.0, 50.0, 50.0, 0.7, 0.2, 0.1];
        let (chi2, p) = chi2_counts(&observed, &expected);
        assert!(chi2.is_finite() && (0.0..=1.0).contains(&p));
    }

    #[test]
    fn ks_against_detects_wrong_cdf() {
        // Uniform data tested against a normal CDF must fail hard.
        let mut rng = Philox::new(3, 3);
        let mut xs: Vec<f64> = (0..20_000).map(|_| rng.draw_double()).collect();
        let (_, p) = ks_against(&mut xs, normal_cdf);
        assert!(p < 1e-10, "p = {p}");
    }
}
