//! Bit-level tests: monobit frequency, Hamming weight, bit-serial
//! autocorrelation, and runs. These are the cheap, high-power tests that
//! catch gross structure (counters, alternating LCG low bits) instantly.

use super::TestResult;
use crate::core::traits::Rng;
use crate::stats::pvalue::{chi2_sf, normal_two_sided};

/// NIST monobit: total ones vs zeros across all bits of n words.
pub fn monobit(rng: &mut dyn Rng, n: usize) -> TestResult {
    let mut ones: u64 = 0;
    for _ in 0..n {
        ones += rng.next_u32().count_ones() as u64;
    }
    let bits = 32.0 * n as f64;
    let z = (2.0 * ones as f64 - bits) / bits.sqrt();
    TestResult { name: "monobit", statistic: z, p: normal_two_sided(z), words_used: n }
}

/// Hamming-weight distribution: popcount of each word vs Binomial(32, ½),
/// chi² over weight classes 0..=32 (tails pooled to keep expected ≥ 10).
pub fn hamming_weight(rng: &mut dyn Rng, n: usize) -> TestResult {
    let mut counts = [0u64; 33];
    for _ in 0..n {
        counts[rng.next_u32().count_ones() as usize] += 1;
    }
    // Binomial(32, 0.5) pmf.
    let mut pmf = [0f64; 33];
    let mut c = 1.0f64; // C(32, k)
    for (k, p) in pmf.iter_mut().enumerate() {
        *p = c / 2f64.powi(32);
        c = c * (32 - k) as f64 / (k + 1) as f64;
    }
    // Pool classes until expected >= 10.
    let (mut chi2, mut dof) = (0.0, 0usize);
    let (mut obs_acc, mut exp_acc) = (0.0, 0.0);
    for k in 0..=32 {
        obs_acc += counts[k] as f64;
        exp_acc += pmf[k] * n as f64;
        if exp_acc >= 10.0 || k == 32 {
            if exp_acc > 0.0 {
                chi2 += (obs_acc - exp_acc) * (obs_acc - exp_acc) / exp_acc;
                dof += 1;
            }
            obs_acc = 0.0;
            exp_acc = 0.0;
        }
    }
    let p = chi2_sf(chi2, (dof - 1) as f64);
    TestResult { name: "hamming_weight", statistic: chi2, p, words_used: n }
}

/// Bit-serial autocorrelation at lag `LAG` (in bits, over the
/// concatenated bit stream). Catches periodic structure: a raw counter
/// fails at small lags, an LCG's alternating low bit fails at lag 32.
pub fn autocorr_lag<const LAG: usize>(rng: &mut dyn Rng, n: usize) -> TestResult {
    // Work word-wise: matches between bit i and bit i+LAG.
    // For LAG < 32 we compare within/between adjacent words; for LAG=32
    // it is simply word[i] vs word[i+1].
    let mut matches: u64 = 0;
    let mut total: u64 = 0;
    let mut prev = rng.next_u32();
    for _ in 1..n {
        let cur = rng.next_u32();
        let (a, b) = if LAG == 32 {
            (prev, cur)
        } else {
            // bits of prev vs bits LAG later (spanning into cur).
            (prev, (prev >> LAG) | (cur << (32 - LAG)))
        };
        matches += (!(a ^ b)).count_ones() as u64;
        total += 32;
        prev = cur;
    }
    let z = (2.0 * matches as f64 - total as f64) / (total as f64).sqrt();
    let name: &'static str = match LAG {
        1 => "bit_autocorr_lag1",
        2 => "bit_autocorr_lag2",
        32 => "bit_autocorr_lag32",
        _ => "bit_autocorr",
    };
    TestResult { name, statistic: z, p: normal_two_sided(z), words_used: n }
}

/// Wald–Wolfowitz runs test on the bit stream (NIST runs): number of
/// 01/10 transitions vs expectation given the observed ones-fraction.
pub fn runs(rng: &mut dyn Rng, n: usize) -> TestResult {
    // Bit order: LSB-first within each word. Transitions inside a word
    // are popcount((w ^ (w >> 1)) & 0x7FFF_FFFF); across a word boundary
    // it is (MSB of prev) ^ (LSB of cur).
    let mut ones: u64 = 0;
    let mut transitions: u64 = 0;
    let mut prev_msb: Option<u32> = None;
    for _ in 0..n {
        let w = rng.next_u32();
        ones += w.count_ones() as u64;
        transitions += ((w ^ (w >> 1)) & 0x7FFF_FFFF).count_ones() as u64;
        if let Some(msb) = prev_msb {
            transitions += (msb ^ (w & 1)) as u64;
        }
        prev_msb = Some(w >> 31);
    }
    let bits = 32.0 * n as f64;
    let pi = ones as f64 / bits;
    // NIST: V_n ~ Normal(2 n pi (1-pi), 2 sqrt(n) pi (1-pi)) where V
    // counts runs = transitions + 1.
    let v = transitions as f64 + 1.0;
    let mean = 2.0 * bits * pi * (1.0 - pi);
    let sd = 2.0 * bits.sqrt() * pi * (1.0 - pi);
    let z = (v - mean) / sd;
    TestResult { name: "runs", statistic: z, p: normal_two_sided(z), words_used: n }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::{Lcg64, WeakCounter};
    use crate::core::{CounterRng, Philox};

    const N: usize = 200_000;

    #[test]
    fn good_generator_passes_all() {
        for (i, t) in [monobit, hamming_weight, autocorr_lag::<1>, autocorr_lag::<32>, runs]
            .iter()
            .enumerate()
        {
            let mut rng = Philox::new(1000 + i as u64, 0);
            let r = t(&mut rng, N);
            assert!(r.p > 1e-4, "{}: p={} stat={}", r.name, r.p, r.statistic);
        }
    }

    #[test]
    fn counter_fails_autocorrelation() {
        let mut rng = WeakCounter::new(0);
        let r = autocorr_lag::<32>(&mut rng, N);
        assert!(r.p < 1e-10, "counter must fail lag32: p={}", r.p);
    }

    #[test]
    fn counter_fails_hamming() {
        // Counter words have very non-binomial popcount dynamics.
        let mut rng = WeakCounter::new(0);
        let r = hamming_weight(&mut rng, N);
        assert!(r.p < 1e-10, "p={}", r.p);
    }

    #[test]
    fn lcg_low_bits_fail_lag32() {
        // The alternating low bit shows up at bit-lag 32 (same position,
        // consecutive words).
        let mut rng = Lcg64::new(12345);
        let r = autocorr_lag::<32>(&mut rng, N);
        assert!(r.p < 1e-10, "p={}", r.p);
    }

    #[test]
    fn all_ones_fails_monobit_and_runs() {
        struct Ones;
        impl crate::core::traits::Rng for Ones {
            fn next_u32(&mut self) -> u32 {
                u32::MAX
            }
        }
        assert!(monobit(&mut Ones, 1000).p < 1e-10);
        assert!(runs(&mut Ones, 1000).p < 1e-10);
    }
}
