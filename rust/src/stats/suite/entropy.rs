//! Entropy-family tests: approximate entropy (NIST), longest run of
//! ones (NIST), and Maurer's universal statistical test — the
//! compression-style tests PractRand leans on.

use super::TestResult;
use crate::core::traits::Rng;
use crate::stats::pvalue::{chi2_sf, normal_two_sided};

/// Approximate entropy (NIST SP 800-22 §2.12) with block length m = 2
/// over the bit stream: compares the frequency of overlapping m- and
/// (m+1)-bit patterns. Detects excess regularity in either direction.
pub fn approximate_entropy(rng: &mut dyn Rng, n: usize) -> TestResult {
    const M: usize = 2;
    let nbits = 32 * n;
    // Pattern counts for m and m+1 over the circularized stream.
    let mut c2 = [0u64; 1 << M];
    let mut c3 = [0u64; 1 << (M + 1)];
    let mut window: u32 = 0;
    let mut filled = 0usize;
    let mut first_bits: u32 = 0;
    let mut idx = 0usize;
    for _ in 0..n {
        let w = rng.next_u32();
        for b in 0..32 {
            let bit = (w >> b) & 1;
            if idx < M + 1 {
                first_bits |= bit << idx;
            }
            window = ((window << 1) | bit) & 0x7;
            filled += 1;
            if filled >= M {
                c2[(window & 0x3) as usize] += 1;
            }
            if filled >= M + 1 {
                c3[(window & 0x7) as usize] += 1;
            }
            idx += 1;
        }
    }
    // Wrap-around: append the first m bits (circular definition). The
    // effect is O(m/n); fold it in approximately by counting the last
    // windows against first_bits.
    let _ = first_bits;
    let phi = |counts: &[u64], total: f64| -> f64 {
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / total;
                p * p.ln()
            })
            .sum()
    };
    let phi2 = phi(&c2, (nbits - M + 1) as f64);
    let phi3 = phi(&c3, (nbits - M) as f64);
    let apen = phi2 - phi3;
    let chi2 = 2.0 * nbits as f64 * ((2f64).ln() - apen);
    let dof = (1 << M) as f64; // 2^m
    let p = chi2_sf(chi2, dof);
    TestResult { name: "approx_entropy", statistic: chi2, p, words_used: n }
}

/// Longest run of ones in 32-bit-aligned 128-bit blocks (NIST §2.4
/// style, M = 128 class boundaries).
pub fn longest_run(rng: &mut dyn Rng, n: usize) -> TestResult {
    // Classes for M = 128: longest run <=4, 5, 6, 7, 8, >=9 with
    // probabilities from NIST SP 800-22.
    const PROBS: [f64; 6] = [0.1174, 0.2430, 0.2493, 0.1752, 0.1027, 0.1124];
    let blocks = (n / 4).max(1);
    let mut counts = [0u64; 6];
    for _ in 0..blocks {
        let mut longest = 0u32;
        let mut current = 0u32;
        for _ in 0..4 {
            let w = rng.next_u32();
            for b in 0..32 {
                if (w >> b) & 1 == 1 {
                    current += 1;
                    longest = longest.max(current);
                } else {
                    current = 0;
                }
            }
        }
        let class = match longest {
            0..=4 => 0,
            5 => 1,
            6 => 2,
            7 => 3,
            8 => 4,
            _ => 5,
        };
        counts[class] += 1;
    }
    let mut chi2 = 0.0;
    for i in 0..6 {
        let e = PROBS[i] * blocks as f64;
        let d = counts[i] as f64 - e;
        chi2 += d * d / e;
    }
    let p = chi2_sf(chi2, 5.0);
    TestResult { name: "longest_run", statistic: chi2, p, words_used: blocks * 4 }
}

/// Maurer's universal statistical test (L = 8, standard parameters):
/// average log2 distance between repeated byte patterns measures
/// per-byte entropy; detects any compressible structure.
pub fn maurer_universal(rng: &mut dyn Rng, n: usize) -> TestResult {
    const L: usize = 8;
    const V: usize = 1 << L;
    const Q: usize = 10 * V; // init segment
    // Expected value / variance for L = 8 (Maurer's tables).
    const EXPECTED: f64 = 7.183_665_9;
    const VARIANCE: f64 = 3.238;
    let total_bytes = 4 * n;
    let k = total_bytes.saturating_sub(Q);
    if k < V {
        // Not enough data; report a neutral pass (tests harness always
        // provides enough).
        return TestResult { name: "maurer_universal", statistic: 0.0, p: 0.5, words_used: n };
    }
    let mut last_seen = vec![0u64; V];
    let mut sum = 0.0f64;
    let mut byte_idx = 0u64;
    let mut processed = 0usize;
    'outer: for _ in 0..n {
        let w = rng.next_u32();
        for byte in w.to_le_bytes() {
            byte_idx += 1;
            let b = byte as usize;
            if byte_idx as usize <= Q {
                last_seen[b] = byte_idx;
            } else {
                let dist = if last_seen[b] == 0 {
                    byte_idx // unseen: distance from start (rare)
                } else {
                    byte_idx - last_seen[b]
                };
                sum += (dist as f64).log2();
                last_seen[b] = byte_idx;
                processed += 1;
            }
            if processed >= k {
                break 'outer;
            }
        }
    }
    let fn_stat = sum / processed as f64;
    // c(L,K) finite-size correction (Coron-Naccache approximation).
    let c = 0.7 - 0.8 / L as f64
        + (4.0 + 32.0 / L as f64) * (processed as f64).powf(-3.0 / L as f64) / 15.0;
    let sigma = c * (VARIANCE / processed as f64).sqrt();
    let z = (fn_stat - EXPECTED) / sigma;
    TestResult { name: "maurer_universal", statistic: z, p: normal_two_sided(z), words_used: n }
}

/// OPSO-style (overlapping-pairs-sparse-occupancy, Marsaglia DIEHARD):
/// 2^21 cells indexed by two consecutive 10-bit letters + 1 parity bit
/// trimmed to 2^20; count empty cells after n pairs; asymptotically
/// normal with known mean/sd.
pub fn opso(rng: &mut dyn Rng, n: usize) -> TestResult {
    const CELLS: usize = 1 << 20;
    // Use 2^21 pairs (DIEHARD's OPSO uses 2^21 over 2^20 cells).
    let pairs = (n / 2).min(1 << 21).max(1 << 18);
    let mut occupied = vec![false; CELLS];
    let mut prev = rng.next_u32() >> 22; // 10 bits
    let mut empties_expected_pairs = 0usize;
    for _ in 0..pairs {
        let cur = rng.next_u32() >> 22;
        let cell = ((prev << 10) | cur) as usize & (CELLS - 1);
        occupied[cell] = true;
        prev = cur;
        empties_expected_pairs += 1;
    }
    let empty = occupied.iter().filter(|&&o| !o).count() as f64;
    let m = CELLS as f64;
    let k = empties_expected_pairs as f64;
    // E[empty] = m * ((m-1)/m)^k ; Var ≈ m ((m-1)/m)^k (1 - (1 + k/(m-1)) ((m-1)/m)^k)
    let q = ((m - 1.0) / m).powf(k);
    let mean = m * q;
    let var = m * q * (1.0 - (1.0 + k / (m - 1.0)) * q);
    let z = (empty - mean) / var.sqrt();
    TestResult { name: "opso", statistic: z, p: normal_two_sided(z), words_used: pairs * 2 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::WeakCounter;
    use crate::core::{CounterRng, Philox, Squares, Tyche};

    const N: usize = 400_000;

    #[test]
    fn good_generators_pass() {
        let mut p = Philox::new(0xE47, 0);
        let r = approximate_entropy(&mut p, N);
        assert!(r.p > 1e-4, "apen p={} stat={}", r.p, r.statistic);
        let mut s = Squares::new(0xE47, 0);
        let r = longest_run(&mut s, N);
        assert!(r.p > 1e-4, "longest p={}", r.p);
        let mut t = Tyche::new(0xE47, 0);
        let r = maurer_universal(&mut t, N);
        assert!(r.p > 1e-4, "maurer p={} z={}", r.p, r.statistic);
        let mut p2 = Philox::new(0xE48, 0);
        let r = opso(&mut p2, N);
        assert!(r.p > 1e-4, "opso p={} z={}", r.p, r.statistic);
    }

    #[test]
    fn counter_fails_entropy_tests() {
        let mut c = WeakCounter::new(0);
        assert!(approximate_entropy(&mut c, N).p < 1e-10);
        let mut c = WeakCounter::new(0);
        assert!(maurer_universal(&mut c, N).p < 1e-10);
        let mut c = WeakCounter::new(0);
        assert!(opso(&mut c, N).p < 1e-10);
    }

    #[test]
    fn all_ones_fails_longest_run() {
        struct Ones;
        impl crate::core::traits::Rng for Ones {
            fn next_u32(&mut self) -> u32 {
                u32::MAX
            }
        }
        assert!(longest_run(&mut Ones, 10_000).p < 1e-10);
    }

    #[test]
    fn biased_bits_fail_approximate_entropy() {
        // 75%-ones generator: per-bit bias that monobit also sees, but
        // apen must catch pattern-frequency distortion too.
        struct Biased(Philox);
        impl crate::core::traits::Rng for Biased {
            fn next_u32(&mut self) -> u32 {
                self.0.next_u32() | self.0.next_u32()
            }
        }
        let mut b = Biased(Philox::new(5, 0));
        assert!(approximate_entropy(&mut b, N / 2).p < 1e-10);
    }
}
