//! Continuous-distribution tests: Kolmogorov–Smirnov uniformity on
//! `draw_double`, and maximum-of-t (Knuth): max of 8 uniforms, raised to
//! the 8th power, must again be uniform.

use super::TestResult;
use crate::core::traits::Rng;
use crate::stats::pvalue::kolmogorov_sf;

/// KS statistic of a sorted sample against U[0,1).
fn ks_p(sorted: &[f64]) -> (f64, f64) {
    let n = sorted.len() as f64;
    let mut d: f64 = 0.0;
    for (i, &x) in sorted.iter().enumerate() {
        let lo = i as f64 / n;
        let hi = (i + 1) as f64 / n;
        d = d.max((x - lo).abs()).max((hi - x).abs());
    }
    // Asymptotic with the Stephens small-sample correction.
    let lambda = (n.sqrt() + 0.12 + 0.11 / n.sqrt()) * d;
    (d, kolmogorov_sf(lambda))
}

/// KS test on n/2 doubles (each consumes 2 words).
pub fn ks_uniform(rng: &mut dyn Rng, n: usize) -> TestResult {
    let m = (n / 2).clamp(100, 1 << 20);
    let mut xs: Vec<f64> = (0..m).map(|_| rng.draw_double()).collect();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (d, p) = ks_p(&xs);
    TestResult { name: "ks_uniform", statistic: d, p, words_used: 2 * m }
}

/// Maximum-of-t with t = 8: y = max(u_1..u_8)^8 ~ U[0,1); KS on y.
pub fn max_of_8(rng: &mut dyn Rng, n: usize) -> TestResult {
    let groups = (n / 8).clamp(100, 1 << 18);
    let mut ys: Vec<f64> = (0..groups)
        .map(|_| {
            let mut mx = 0f64;
            for _ in 0..8 {
                mx = mx.max(rng.draw_float() as f64);
            }
            mx.powi(8)
        })
        .collect();
    ys.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (d, p) = ks_p(&ys);
    TestResult { name: "max_of_8", statistic: d, p, words_used: groups * 8 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{CounterRng, Philox, Tyche};

    #[test]
    fn uniform_passes_ks() {
        let mut rng = Philox::new(0x6006, 0);
        let r = ks_uniform(&mut rng, 100_000);
        assert!(r.p > 1e-4, "p={} D={}", r.p, r.statistic);
    }

    #[test]
    fn max_of_8_passes_on_good() {
        let mut rng = Tyche::new(0x6006, 0);
        let r = max_of_8(&mut rng, 100_000);
        assert!(r.p > 1e-4, "p={} D={}", r.p, r.statistic);
    }

    #[test]
    fn shifted_distribution_fails_ks() {
        // A generator whose doubles live in [0, 0.5): u >> 1 effect.
        struct Half(Philox);
        impl crate::core::traits::Rng for Half {
            fn next_u32(&mut self) -> u32 {
                self.0.next_u32() >> 1
            }
        }
        let mut rng = Half(Philox::new(1, 0));
        let r = ks_uniform(&mut rng, 100_000);
        assert!(r.p < 1e-10, "p={}", r.p);
    }

    #[test]
    fn ks_p_exact_small_case() {
        // Perfectly spaced sample has tiny D.
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64 + 0.5) / 1000.0).collect();
        let (d, p) = ks_p(&xs);
        assert!(d <= 0.5e-3 + 1e-12);
        assert!(p > 0.999);
    }
}
