//! Value-level chi-square tests: equidistribution, serial pairs, serial
//! correlation, gap, poker, and permutation (Knuth TAOCP vol. 2 §3.3.2).

use super::TestResult;
use crate::core::traits::Rng;
use crate::stats::pvalue::{chi2_sf, normal_two_sided};

fn chi2_uniform_bins(counts: &[u64], n: f64) -> (f64, f64) {
    let k = counts.len() as f64;
    let expect = n / k;
    let chi2: f64 = counts
        .iter()
        .map(|&c| {
            let d = c as f64 - expect;
            d * d / expect
        })
        .sum();
    (chi2, chi2_sf(chi2, k - 1.0))
}

/// Byte equidistribution: all 4n bytes over 256 bins.
pub fn byte_equidist(rng: &mut dyn Rng, n: usize) -> TestResult {
    let mut counts = [0u64; 256];
    for _ in 0..n {
        let w = rng.next_u32();
        counts[(w & 0xFF) as usize] += 1;
        counts[((w >> 8) & 0xFF) as usize] += 1;
        counts[((w >> 16) & 0xFF) as usize] += 1;
        counts[(w >> 24) as usize] += 1;
    }
    let (chi2, p) = chi2_uniform_bins(&counts, 4.0 * n as f64);
    TestResult { name: "byte_equidist", statistic: chi2, p, words_used: n }
}

/// Top-10-bit equidistribution over 1024 bins.
pub fn equidist_10bit(rng: &mut dyn Rng, n: usize) -> TestResult {
    let mut counts = vec![0u64; 1024];
    for _ in 0..n {
        counts[(rng.next_u32() >> 22) as usize] += 1;
    }
    let (chi2, p) = chi2_uniform_bins(&counts, n as f64);
    TestResult { name: "equidist_10bit", statistic: chi2, p, words_used: n }
}

/// Serial pairs: consecutive (overlapping disabled) top-byte pairs over
/// 65536 bins — the workhorse that kills counters and short-period
/// structure.
pub fn serial_pairs_8bit(rng: &mut dyn Rng, n: usize) -> TestResult {
    let mut counts = vec![0u64; 65536];
    let pairs = n / 2;
    for _ in 0..pairs {
        let a = rng.next_u32() >> 24;
        let b = rng.next_u32() >> 24;
        counts[((a << 8) | b) as usize] += 1;
    }
    let (chi2, p) = chi2_uniform_bins(&counts, pairs as f64);
    TestResult { name: "serial_pairs_8bit", statistic: chi2, p, words_used: n }
}

/// First-order serial correlation of consecutive uniforms.
pub fn serial_correlation(rng: &mut dyn Rng, n: usize) -> TestResult {
    let mut prev = rng.next_u32() as f64 / 2f64.powi(32);
    let (mut sx, mut sxx, mut sxy) = (prev, prev * prev, 0.0);
    for _ in 1..n {
        let x = rng.next_u32() as f64 / 2f64.powi(32);
        sxy += prev * x;
        sx += x;
        sxx += x * x;
        prev = x;
    }
    let nf = n as f64;
    let mean = sx / nf;
    let var = sxx / nf - mean * mean;
    let cov = sxy / (nf - 1.0) - mean * mean;
    let rho = cov / var;
    let z = rho * (nf).sqrt();
    TestResult { name: "serial_correlation", statistic: z, p: normal_two_sided(z), words_used: n }
}

/// Gap test (Knuth): lengths of gaps between visits to [0, alpha) with
/// alpha = 1/8, chi² vs the geometric law, tail pooled.
pub fn gap(rng: &mut dyn Rng, n: usize) -> TestResult {
    const ALPHA_BITS: u32 = 3; // P(hit) = 2^-3 = 1/8
    const MAXGAP: usize = 64;
    let mut counts = [0u64; MAXGAP + 1];
    let mut gap_len = 0usize;
    let mut ngaps = 0u64;
    for _ in 0..n {
        let hit = (rng.next_u32() >> (32 - ALPHA_BITS)) == 0;
        if hit {
            counts[gap_len.min(MAXGAP)] += 1;
            ngaps += 1;
            gap_len = 0;
        } else {
            gap_len += 1;
        }
    }
    let p_hit: f64 = 1.0 / 8.0;
    let mut chi2 = 0.0;
    let mut dof = 0;
    let mut acc_obs = 0.0;
    let mut acc_exp = 0.0;
    for g in 0..=MAXGAP {
        // P(gap = g) geometric; the last bin pools P(gap >= MAXGAP).
        let pg = if g == MAXGAP {
            (1.0 - p_hit).powi(MAXGAP as i32)
        } else {
            p_hit * (1.0 - p_hit).powi(g as i32)
        };
        acc_obs += counts[g] as f64;
        acc_exp += pg * ngaps as f64;
        if acc_exp >= 10.0 || g == MAXGAP {
            if acc_exp > 0.0 {
                chi2 += (acc_obs - acc_exp) * (acc_obs - acc_exp) / acc_exp;
                dof += 1;
            }
            acc_obs = 0.0;
            acc_exp = 0.0;
        }
    }
    let p = chi2_sf(chi2, (dof - 1) as f64);
    TestResult { name: "gap", statistic: chi2, p, words_used: n }
}

/// Poker test (4-bit): classify non-overlapping groups of five 4-bit
/// "cards" by number of distinct values, chi² vs exact probabilities.
pub fn poker_4bit(rng: &mut dyn Rng, n: usize) -> TestResult {
    // Exact distinct-count distribution for 5 draws from 16 values:
    // P(r distinct) = S(5, r) * 16!/(16-r)! / 16^5, Stirling numbers
    // S(5,1..5) = 1, 15, 25, 10, 1.
    let stirling = [1.0, 15.0, 25.0, 10.0, 1.0];
    let mut probs = [0f64; 5];
    for (r, p) in probs.iter_mut().enumerate() {
        let r1 = r + 1;
        let mut falling = 1.0;
        for i in 0..r1 {
            falling *= (16 - i) as f64;
        }
        *p = stirling[r] * falling / 16f64.powi(5);
    }
    let hands = n * 8 / 5; // 8 cards per word
    let mut counts = [0u64; 5];
    let mut card_buf: u32 = 0;
    let mut cards_left = 0;
    for _ in 0..hands {
        let mut mask: u16 = 0;
        for _ in 0..5 {
            if cards_left == 0 {
                card_buf = rng.next_u32();
                cards_left = 8;
            }
            mask |= 1 << (card_buf & 0xF);
            card_buf >>= 4;
            cards_left -= 1;
        }
        counts[mask.count_ones() as usize - 1] += 1;
    }
    let mut chi2 = 0.0;
    for r in 0..5 {
        let e = probs[r] * hands as f64;
        let d = counts[r] as f64 - e;
        chi2 += d * d / e;
    }
    let p = chi2_sf(chi2, 4.0);
    TestResult { name: "poker_4bit", statistic: chi2, p, words_used: hands * 5 / 8 }
}

/// Permutation test: order pattern of non-overlapping 5-tuples of
/// uniforms, chi² over the 120 possible orderings.
pub fn permutation_5(rng: &mut dyn Rng, n: usize) -> TestResult {
    let tuples = n / 5;
    let mut counts = vec![0u64; 120];
    for _ in 0..tuples {
        let mut v = [0u32; 5];
        for x in v.iter_mut() {
            *x = rng.next_u32();
        }
        // Lehmer code -> permutation index.
        let mut idx = 0usize;
        for i in 0..5 {
            let mut smaller = 0usize;
            for j in (i + 1)..5 {
                if v[j] < v[i] {
                    smaller += 1;
                }
            }
            idx = idx * (5 - i) + smaller;
        }
        counts[idx] += 1;
    }
    let (chi2, p) = chi2_uniform_bins(&counts, tuples as f64);
    TestResult { name: "permutation_5", statistic: chi2, p, words_used: tuples * 5 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::{Lcg64, WeakCounter};
    use crate::core::{CounterRng, Philox, Squares, Threefry, Tyche};

    const N: usize = 200_000;

    #[test]
    fn good_generators_pass() {
        let tests: [(&str, super::super::StatTest); 6] = [
            ("byte_equidist", byte_equidist),
            ("equidist_10bit", equidist_10bit),
            ("serial_pairs_8bit", serial_pairs_8bit),
            ("serial_correlation", serial_correlation),
            ("gap", gap),
            ("permutation_5", permutation_5),
        ];
        for (name, t) in tests {
            let mut rng = Philox::new(0xA5A5, 0);
            let r = t(&mut rng, N);
            assert!(r.p > 1e-4, "{name}: p={} stat={}", r.p, r.statistic);
        }
    }

    #[test]
    fn poker_passes_on_good() {
        for seed in 0..3u64 {
            let mut rng = Squares::new(seed, 0);
            let r = poker_4bit(&mut rng, N);
            assert!(r.p > 1e-4, "seed {seed}: p={}", r.p);
        }
        let mut t = Threefry::new(7, 0);
        assert!(poker_4bit(&mut t, N).p > 1e-4);
        let mut ty = Tyche::new(7, 0);
        assert!(poker_4bit(&mut ty, N).p > 1e-4);
    }

    #[test]
    fn counter_fails_serial_pairs() {
        let mut rng = WeakCounter::new(0);
        let r = serial_pairs_8bit(&mut rng, N);
        assert!(r.p < 1e-10, "p={}", r.p);
    }

    #[test]
    fn counter_fails_equidist_at_scale() {
        // 200k consecutive counter values hit only a sliver of the
        // top-10-bit range.
        let mut rng = WeakCounter::new(0);
        let r = equidist_10bit(&mut rng, N);
        assert!(r.p < 1e-10, "p={}", r.p);
    }

    #[test]
    fn counter_fails_serial_correlation() {
        let mut rng = WeakCounter::new(0);
        let r = serial_correlation(&mut rng, N);
        assert!(r.p < 1e-10, "p={}", r.p);
    }

    #[test]
    fn counter_fails_poker() {
        // Consecutive integers share 7 of 8 nibbles between neighbors;
        // the distinct-count distribution is far from random.
        let mut rng = WeakCounter::new(0);
        let r = poker_4bit(&mut rng, N);
        assert!(r.p < 1e-10, "p={}", r.p);
    }

    #[test]
    fn lcg_top_bits_pass_value_tests() {
        // Negative control: the LCG's *top* bits are decent, so the
        // value-level tests here (which use top bits) should NOT flag it
        // — its defect lives in the low bits and is caught by
        // bit_autocorr_lag32 and matrix_rank (see bits.rs / battery.rs).
        let mut rng = Lcg64::new(99);
        assert!(serial_pairs_8bit(&mut rng, N).p > 1e-6);
        let mut rng = Lcg64::new(99);
        assert!(equidist_10bit(&mut rng, N).p > 1e-6);
    }
}
