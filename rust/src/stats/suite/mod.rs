//! The individual statistical tests and their shared result type.

pub mod bits;
pub mod chi2tests;
pub mod entropy;
pub mod ks;
pub mod rank;
pub mod spacings;

use crate::core::traits::Rng;

/// Outcome of one statistical test.
#[derive(Debug, Clone)]
pub struct TestResult {
    pub name: &'static str,
    /// The test statistic (chi², z, KS D, count — test-specific).
    pub statistic: f64,
    /// Two-sided p-value under the null "stream is uniform random".
    pub p: f64,
    /// Number of 32-bit words consumed.
    pub words_used: usize,
}

/// TestU01-style verdict thresholds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    Pass,
    /// p outside [1e-4, 1 - 1e-4] — rerun-worthy, as the paper notes
    /// happens occasionally even for cuRAND.
    Suspicious,
    /// p outside [1e-10, 1 - 1e-10] — clear failure.
    Fail,
}

impl TestResult {
    pub fn verdict(&self) -> Verdict {
        let edge = self.p.min(1.0 - self.p);
        if edge < 1e-10 {
            Verdict::Fail
        } else if edge < 1e-4 {
            Verdict::Suspicious
        } else {
            Verdict::Pass
        }
    }
}

/// A statistical test: consumes `n` words from the stream.
pub type StatTest = fn(&mut dyn Rng, usize) -> TestResult;

/// The full suite, in execution order. Each entry is (test, weight):
/// weight scales the word budget (cheap tests get more data).
pub fn all_tests() -> Vec<(&'static str, StatTest, f64)> {
    vec![
        ("monobit", bits::monobit as StatTest, 1.0),
        ("hamming_weight", bits::hamming_weight, 1.0),
        ("bit_autocorr_lag1", bits::autocorr_lag::<1>, 1.0),
        ("bit_autocorr_lag2", bits::autocorr_lag::<2>, 1.0),
        ("bit_autocorr_lag32", bits::autocorr_lag::<32>, 1.0),
        ("runs", bits::runs, 1.0),
        ("byte_equidist", chi2tests::byte_equidist, 1.0),
        ("equidist_10bit", chi2tests::equidist_10bit, 1.0),
        ("serial_pairs_8bit", chi2tests::serial_pairs_8bit, 1.0),
        ("serial_correlation", chi2tests::serial_correlation, 1.0),
        ("gap", chi2tests::gap, 1.0),
        ("poker_4bit", chi2tests::poker_4bit, 1.0),
        ("permutation_5", chi2tests::permutation_5, 1.0),
        ("birthday_spacings", spacings::birthday_spacings, 0.25),
        ("collision_20bit", spacings::collision_20bit, 0.5),
        ("matrix_rank_32", rank::matrix_rank_32, 0.5),
        ("ks_uniform", ks::ks_uniform, 0.25),
        ("max_of_8", ks::max_of_8, 0.5),
        ("approx_entropy", entropy::approximate_entropy, 0.5),
        ("longest_run", entropy::longest_run, 0.5),
        ("maurer_universal", entropy::maurer_universal, 0.5),
        ("opso", entropy::opso, 0.5),
    ]
}
