//! Spacing/collision tests: birthday spacings (Marsaglia; the TestU01
//! example the paper calls out) and collision counting.

use super::TestResult;
use crate::core::traits::Rng;
use crate::stats::pvalue::poisson_two_sided;

/// Birthday spacings: throw m = 2^12 birthdays into d = 2^32 days (one
/// word each), sort, count duplicate spacings. Under the null the count
/// is Poisson(λ = m³/(4d) = 4). Repeated `n / m` times, summing counts
/// (sum of Poissons is Poisson). This test is devastating for counters
/// and lattice structure.
pub fn birthday_spacings(rng: &mut dyn Rng, n: usize) -> TestResult {
    const M: usize = 1 << 12;
    let reps = (n / M).max(1);
    let lambda_per_rep = (M as f64).powi(3) / (4.0 * 2f64.powi(32));
    let mut total_dups = 0u64;
    let mut bdays = vec![0u32; M];
    let mut spacings = vec![0u32; M - 1];
    for _ in 0..reps {
        for b in bdays.iter_mut() {
            *b = rng.next_u32();
        }
        bdays.sort_unstable();
        for i in 1..M {
            spacings[i - 1] = bdays[i].wrapping_sub(bdays[i - 1]);
        }
        spacings.sort_unstable();
        for i in 1..spacings.len() {
            if spacings[i] == spacings[i - 1] {
                total_dups += 1;
            }
        }
    }
    let mu = lambda_per_rep * reps as f64;
    let p = poisson_two_sided(total_dups, mu);
    TestResult {
        name: "birthday_spacings",
        statistic: total_dups as f64,
        p,
        words_used: reps * M,
    }
}

/// Collision test: throw n balls into 2^20 urns (top 20 bits); the
/// number of collisions is asymptotically Poisson(n²/2m) for n ≪ m.
pub fn collision_20bit(rng: &mut dyn Rng, n: usize) -> TestResult {
    const URNS: usize = 1 << 20;
    // Keep n well below m for the Poisson regime; chunk if necessary.
    let chunk = 1 << 14; // λ per chunk = 2^28/2^21 = 128
    let reps = (n / chunk).max(1);
    let mut seen = vec![false; URNS];
    let mut collisions = 0u64;
    for _ in 0..reps {
        for s in seen.iter_mut() {
            *s = false;
        }
        for _ in 0..chunk {
            let u = (rng.next_u32() >> 12) as usize;
            if seen[u] {
                collisions += 1;
            } else {
                seen[u] = true;
            }
        }
    }
    // Exact expectation per chunk: chunk - m(1 - (1-1/m)^chunk); Poisson
    // approximation with that mean.
    let m = URNS as f64;
    let c = chunk as f64;
    let mu_per = c - m * (1.0 - (1.0 - 1.0 / m).powf(c));
    let mu = mu_per * reps as f64;
    let p = poisson_two_sided(collisions, mu);
    TestResult { name: "collision_20bit", statistic: collisions as f64, p, words_used: reps * chunk }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::WeakCounter;
    use crate::core::{CounterRng, Philox, Squares, Threefry, Tyche, TycheI};

    #[test]
    fn good_generators_pass_birthday() {
        let mut p = Philox::new(0xB1D, 0);
        assert!(birthday_spacings(&mut p, 1 << 16).p > 1e-4);
        let mut s = Squares::new(0xB1D, 0);
        assert!(birthday_spacings(&mut s, 1 << 16).p > 1e-4);
        let mut t = Threefry::new(0xB1D, 0);
        assert!(birthday_spacings(&mut t, 1 << 16).p > 1e-4);
    }

    #[test]
    fn good_generators_pass_collision() {
        let mut t = Tyche::new(3, 0);
        assert!(collision_20bit(&mut t, 1 << 16).p > 1e-4);
        let mut ti = TycheI::new(3, 0);
        assert!(collision_20bit(&mut ti, 1 << 16).p > 1e-4);
    }

    #[test]
    fn counter_fails_birthday_catastrophically() {
        // Consecutive integers: all spacings equal -> every spacing a
        // duplicate -> p ~ 0.
        let mut rng = WeakCounter::new(0);
        let r = birthday_spacings(&mut rng, 1 << 14);
        assert!(r.p < 1e-10, "p={} dups={}", r.p, r.statistic);
    }

    #[test]
    fn counter_fails_collision() {
        // A counter never collides: observed 0 vs expected ~128/chunk.
        let mut rng = WeakCounter::new(0);
        let r = collision_20bit(&mut rng, 1 << 15);
        assert!(r.p < 1e-10, "p={} collisions={}", r.p, r.statistic);
    }
}
