//! Binary matrix rank test (Marsaglia / NIST): rank distribution of
//! random 32×32 GF(2) matrices built from 32 consecutive words. Linear
//! generators (LFSRs, LCG low bits) produce rank-deficient matrices.

use super::TestResult;
use crate::core::traits::Rng;
use crate::stats::pvalue::chi2_sf;

/// GF(2) rank by Gaussian elimination over u32 rows.
pub fn gf2_rank(rows: &mut [u32; 32]) -> u32 {
    let mut rank = 0u32;
    for bit in (0..32).rev() {
        let mask = 1u32 << bit;
        // Find a pivot row at or below `rank`.
        let mut pivot = None;
        for r in rank as usize..32 {
            if rows[r] & mask != 0 {
                pivot = Some(r);
                break;
            }
        }
        if let Some(p) = pivot {
            rows.swap(rank as usize, p);
            let prow = rows[rank as usize];
            for (r, row) in rows.iter_mut().enumerate() {
                if r != rank as usize && *row & mask != 0 {
                    *row ^= prow;
                }
            }
            rank += 1;
            if rank == 32 {
                break;
            }
        }
    }
    rank
}

/// Probability that a random 32×32 GF(2) matrix has rank 32-k:
/// classes {32, 31, 30, ≤29}.
fn rank_probs() -> [f64; 4] {
    // Exact: P(rank = n - k) for a random n x n GF(2) matrix is
    // 2^{-k^2} * prod_{i=k+1..n} (1 - 2^-i)^2 / prod_{i=1..n-k} (1 - 2^-i)
    // — computed directly for n = 32, k = 0, 1, 2; the rest pooled.
    fn p_rank(n: i32, k: i32) -> f64 {
        // Marsaglia's product form:
        // P(rank = r) = 2^{r(2n-r) - n^2} * prod_{i=0..r-1} (1-2^{i-n})^2 / (1-2^{i-r})
        let r = n - k;
        let mut p = 2f64.powi(r * (2 * n - r) - n * n);
        for i in 0..r {
            let num = 1.0 - 2f64.powi(i - n);
            let den = 1.0 - 2f64.powi(i - r);
            p *= num * num / den;
        }
        p
    }
    let p32 = p_rank(32, 0);
    let p31 = p_rank(32, 1);
    let p30 = p_rank(32, 2);
    [p32, p31, p30, (1.0 - p32 - p31 - p30).max(0.0)]
}

/// The rank test proper.
pub fn matrix_rank_32(rng: &mut dyn Rng, n: usize) -> TestResult {
    let mats = (n / 32).max(100);
    let mut counts = [0u64; 4];
    let mut rows = [0u32; 32];
    for _ in 0..mats {
        for r in rows.iter_mut() {
            *r = rng.next_u32();
        }
        let rank = gf2_rank(&mut rows);
        let class = match rank {
            32 => 0,
            31 => 1,
            30 => 2,
            _ => 3,
        };
        counts[class] += 1;
    }
    let probs = rank_probs();
    let mut chi2 = 0.0;
    for i in 0..4 {
        let e = probs[i] * mats as f64;
        let d = counts[i] as f64 - e;
        chi2 += d * d / e.max(1e-9);
    }
    let p = chi2_sf(chi2, 3.0);
    TestResult { name: "matrix_rank_32", statistic: chi2, p, words_used: mats * 32 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::WeakCounter;
    use crate::core::{CounterRng, Philox};

    #[test]
    fn rank_of_identity_is_32() {
        let mut rows = [0u32; 32];
        for (i, r) in rows.iter_mut().enumerate() {
            *r = 1 << i;
        }
        assert_eq!(gf2_rank(&mut rows), 32);
    }

    #[test]
    fn rank_of_zero_is_0_and_rank_one_matrix_is_1() {
        let mut z = [0u32; 32];
        assert_eq!(gf2_rank(&mut z), 0);
        let mut one = [0xDEAD_BEEFu32; 32];
        assert_eq!(gf2_rank(&mut one), 1);
    }

    #[test]
    fn rank_of_dependent_rows() {
        let mut rows = [0u32; 32];
        for (i, r) in rows.iter_mut().enumerate() {
            *r = 1 << (i / 2); // each column pair repeated -> rank 16
        }
        assert_eq!(gf2_rank(&mut rows), 16);
    }

    #[test]
    fn philox_passes_rank() {
        let mut rng = Philox::new(0x5A5A, 0);
        let r = matrix_rank_32(&mut rng, 320_000);
        assert!(r.p > 1e-4, "p={} chi2={}", r.p, r.statistic);
    }

    #[test]
    fn counter_fails_rank() {
        // Consecutive integers differ in few low bits -> wildly
        // rank-deficient matrices.
        let mut rng = WeakCounter::new(0);
        let r = matrix_rank_32(&mut rng, 320_000);
        assert!(r.p < 1e-10, "p={}", r.p);
    }
}
