//! Dissipative Particle Dynamics — the paper's other motivating workload
//! (its reference [1], Phillips et al., is titled "Pseudo-random number
//! generation for Brownian Dynamics and Dissipative Particle Dynamics
//! simulations on GPU devices").
//!
//! DPD is the showcase for counter-based RNG that Brownian dynamics
//! cannot provide: the random force on a PAIR must be symmetric,
//! `F_ij = -F_ji`, or momentum is not conserved. With a stateful RNG the
//! two threads owning i and j would draw different numbers; with a CBRNG
//! both sides derive the SAME stream from the pair identity:
//!
//! ```text
//! seed = pair_seed(min(i,j), max(i,j)) ^ global,  ctr = step
//! ```
//!
//! so each side can independently regenerate θ_ij. Momentum conservation
//! to the last ulp is therefore a *direct test* of the reproducible-
//! stream machinery, and thread-count invariance holds for the same
//! reason as in the Brownian case.
//!
//! Model: standard Groot–Warren 2-D DPD fluid — soft conservative
//! repulsion `a(1-r)ê`, dissipative `-γ w²(r) (v̂·ê)ê`, random
//! `σ w(r) θ_ij ê / √dt` with `w(r) = 1 - r`, σ² = 2γkT, periodic box,
//! cell-list neighbor search, velocity-Verlet-style update (DPD-VV).

use crate::core::counter::splitmix64;
use crate::core::fill::u01_f64;
use crate::core::{BlockRng, Philox};
use crate::stream::{Stream, StreamKey};

/// Canonical pair seed: order-independent, well-mixed.
#[inline]
pub fn pair_seed(i: u64, j: u64, global: u64) -> u64 {
    let (lo, hi) = if i < j { (i, j) } else { (j, i) };
    splitmix64(lo.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ hi) ^ global
}

/// The stream address of one pair at one step — the seeding-discipline
/// pattern of `docs/stream-contracts.md` §7 as a typed key: the pair
/// identity is the seed ([`pair_seed`], order-independent), the step is
/// the epoch. Byte-identical to the raw spelling both sides of a pair
/// have always regenerated.
#[inline]
pub fn pair_key(i: u64, j: u64, global: u64, step: u32) -> StreamKey {
    StreamKey::raw(pair_seed(i, j, global), step)
}

/// Symmetric pair gaussian-ish variate (uniform-sum, variance 1): both
/// members of the pair regenerate this identically.
#[inline]
pub fn pair_theta(i: u64, j: u64, global: u64, step: u32) -> f64 {
    let mut stream = Stream::<Philox>::new(pair_key(i, j, global, step));
    let rng = stream.rng_mut();
    // Sum of 3 uniforms, centered/scaled to unit variance (Groot-Warren
    // use a plain uniform; a 3-sum is smoother at identical cost class).
    // The 3 uniforms are 6 stream words = 1.5 Philox blocks; drawing the
    // two blocks through the BlockRng fast path costs the same two raw
    // block calls as the buffered form but skips its per-word
    // bookkeeping. The uniforms come from words 0..6 in order (pinned by
    // `pair_theta_matches_word_at_a_time`); the second block's trailing
    // two words are generated-but-unused, which is unobservable because
    // the engine is local to this call.
    let (mut b0, mut b1) = ([0u32; 4], [0u32; 4]);
    rng.generate_block(&mut b0);
    rng.generate_block(&mut b1);
    let s = u01_f64(b0[0], b0[1]) + u01_f64(b0[2], b0[3]) + u01_f64(b1[0], b1[1]);
    (s - 1.5) * 2.0
}

/// DPD parameters (Groot–Warren conventions).
#[derive(Debug, Clone, Copy)]
pub struct DpdParams {
    pub n: usize,
    /// Periodic box side; cutoff is 1.
    pub box_side: f64,
    pub a: f64,
    pub gamma: f64,
    pub kt: f64,
    pub dt: f64,
    pub global_seed: u64,
}

impl DpdParams {
    pub fn sigma(&self) -> f64 {
        (2.0 * self.gamma * self.kt).sqrt()
    }
}

/// 2-D DPD fluid with cell-list neighbor search.
pub struct DpdSim {
    pub p: DpdParams,
    pub x: Vec<f64>,
    pub y: Vec<f64>,
    pub vx: Vec<f64>,
    pub vy: Vec<f64>,
    fx: Vec<f64>,
    fy: Vec<f64>,
    pub step: u32,
    cells: usize,
    head: Vec<i32>,
    next: Vec<i32>,
}

impl DpdSim {
    /// Deterministic lattice start with small deterministic velocity
    /// perturbations (stream (pid, ctr=u32::MAX) — reserved init ctr).
    pub fn new(p: DpdParams) -> DpdSim {
        let side = (p.n as f64).sqrt().ceil() as usize;
        let spacing = p.box_side / side as f64;
        let mut x = vec![0.0; p.n];
        let mut y = vec![0.0; p.n];
        let mut vx = vec![0.0; p.n];
        let mut vy = vec![0.0; p.n];
        for i in 0..p.n {
            x[i] = (i % side) as f64 * spacing + 0.25 * spacing;
            y[i] = (i / side) as f64 * spacing + 0.25 * spacing;
            // One counter block per particle (two f64s), via the block
            // path — bit-identical to the draw_double pair it replaces.
            // Addressing: the reserved init epoch (ctr = u32::MAX) of
            // the particle's stream, through the key facade.
            let mut stream =
                Stream::<Philox>::new(StreamKey::raw(i as u64 ^ p.global_seed, u32::MAX));
            let rng = stream.rng_mut();
            let mut blk = [0u32; 4];
            rng.generate_block(&mut blk);
            vx[i] = (u01_f64(blk[0], blk[1]) - 0.5) * 2.0 * p.kt.sqrt();
            vy[i] = (u01_f64(blk[2], blk[3]) - 0.5) * 2.0 * p.kt.sqrt();
        }
        // Zero net momentum exactly (pairwise cancellation trick:
        // subtract the mean, computed deterministically).
        let mx = vx.iter().sum::<f64>() / p.n as f64;
        let my = vy.iter().sum::<f64>() / p.n as f64;
        for i in 0..p.n {
            vx[i] -= mx;
            vy[i] -= my;
        }
        Self::from_state(p, x, y, vx, vy, 0)
    }

    /// Rebuild a simulation around caller-owned particle state at an
    /// arbitrary step — the campaign checkpoint/resume entry point.
    /// `(x, y, vx, vy, step)` plus the params fully determine every
    /// future draw: forces and cell lists are recomputed at the start
    /// of each step, and the pair streams are addressed by
    /// `(pair, global_seed, step)` alone, so no engine or neighbor
    /// state needs to survive a checkpoint.
    pub fn from_state(
        p: DpdParams,
        x: Vec<f64>,
        y: Vec<f64>,
        vx: Vec<f64>,
        vy: Vec<f64>,
        step: u32,
    ) -> DpdSim {
        assert_eq!(x.len(), p.n, "x length must match params.n");
        assert_eq!(y.len(), p.n, "y length must match params.n");
        assert_eq!(vx.len(), p.n, "vx length must match params.n");
        assert_eq!(vy.len(), p.n, "vy length must match params.n");
        let cells = (p.box_side.floor() as usize).max(1); // cell size >= cutoff 1
        DpdSim {
            p,
            x,
            y,
            vx,
            vy,
            fx: vec![0.0; p.n],
            fy: vec![0.0; p.n],
            step,
            cells,
            head: vec![-1; cells * cells],
            next: vec![-1; p.n],
        }
    }

    #[inline]
    fn cell_of(&self, i: usize) -> usize {
        let c = self.cells as f64 / self.p.box_side;
        let cx = ((self.x[i] * c) as usize).min(self.cells - 1);
        let cy = ((self.y[i] * c) as usize).min(self.cells - 1);
        cy * self.cells + cx
    }

    fn rebuild_cells(&mut self) {
        self.head.iter_mut().for_each(|h| *h = -1);
        for i in 0..self.p.n {
            let c = self.cell_of(i);
            self.next[i] = self.head[c];
            self.head[c] = i as i32;
        }
    }

    /// Minimum-image displacement.
    #[inline]
    fn min_image(&self, d: f64) -> f64 {
        let b = self.p.box_side;
        if d > 0.5 * b {
            d - b
        } else if d < -0.5 * b {
            d + b
        } else {
            d
        }
    }

    /// Pair force on i from j (conservative + dissipative + random).
    /// Symmetric by construction: swapping (i, j) negates the result
    /// exactly, because θ_ij is pair-seeded and ê flips sign.
    #[inline]
    fn pair_force(&self, i: usize, j: usize) -> (f64, f64) {
        let dx = self.min_image(self.x[i] - self.x[j]);
        let dy = self.min_image(self.y[i] - self.y[j]);
        let r2 = dx * dx + dy * dy;
        if r2 >= 1.0 || r2 == 0.0 {
            return (0.0, 0.0);
        }
        let r = r2.sqrt();
        let (ex, ey) = (dx / r, dy / r);
        let w = 1.0 - r;
        // Conservative.
        let fc = self.p.a * w;
        // Dissipative: -γ w² (v_ij · ê).
        let dvx = self.vx[i] - self.vx[j];
        let dvy = self.vy[i] - self.vy[j];
        let vdote = dvx * ex + dvy * ey;
        let fd = -self.p.gamma * w * w * vdote;
        // Random: σ w θ_ij / sqrt(dt) — θ identical on both sides.
        let theta = pair_theta(i as u64, j as u64, self.p.global_seed, self.step);
        let fr = self.p.sigma() * w * theta / self.p.dt.sqrt();
        let f = fc + fd + fr;
        (f * ex, f * ey)
    }

    /// Compute forces for particles in [lo, hi) (each pair evaluated from
    /// both sides; the pair-seeded RNG guarantees consistency).
    fn forces_range(&mut self, lo: usize, hi: usize) {
        for i in lo..hi {
            let (mut fx, mut fy) = (0.0, 0.0);
            let c = self.cells as i64;
            let ci = self.cell_of(i) as i64;
            let (cx, cy) = (ci % c, ci / c);
            for oy in -1..=1i64 {
                for ox in -1..=1i64 {
                    let nc = ((cy + oy).rem_euclid(c) * c + (cx + ox).rem_euclid(c)) as usize;
                    let mut j = self.head[nc];
                    while j >= 0 {
                        let ju = j as usize;
                        if ju != i {
                            let (dfx, dfy) = self.pair_force(i, ju);
                            fx += dfx;
                            fy += dfy;
                        }
                        j = self.next[ju];
                    }
                }
            }
            self.fx[i] = fx;
            self.fy[i] = fy;
        }
    }

    /// One DPD step (explicit Euler on v, drift on x — adequate for the
    /// reproducibility/momentum demonstrations; swap for DPD-VV for
    /// production physics).
    pub fn step_all(&mut self) {
        self.rebuild_cells();
        self.forces_range(0, self.p.n);
        let dt = self.p.dt;
        let b = self.p.box_side;
        for i in 0..self.p.n {
            self.vx[i] += self.fx[i] * dt;
            self.vy[i] += self.fy[i] * dt;
            self.x[i] = (self.x[i] + self.vx[i] * dt).rem_euclid(b);
            self.y[i] = (self.y[i] + self.vy[i] * dt).rem_euclid(b);
        }
        self.step += 1;
    }

    /// Parallel step via the coordinator pool: forces in deterministic
    /// stripes (reads are global, writes per-stripe), then integrate.
    pub fn step_parallel(&mut self, threads: usize) {
        self.rebuild_cells();
        let n = self.p.n;
        let ranges = crate::coordinator::partition_ranges(n, threads);
        // Split force accumulators into stripes; the force pass reads
        // positions/velocities immutably.
        let mut outputs: Vec<Vec<(f64, f64)>> = Vec::with_capacity(ranges.len());
        {
            let this: &DpdSim = self;
            let mut slots: Vec<Option<Vec<(f64, f64)>>> = Vec::with_capacity(ranges.len());
            slots.resize_with(ranges.len(), || None);
            std::thread::scope(|scope| {
                for (range, slot) in ranges.iter().cloned().zip(slots.iter_mut()) {
                    scope.spawn(move || {
                        let mut acc = Vec::with_capacity(range.len());
                        for i in range {
                            let (mut fx, mut fy) = (0.0, 0.0);
                            let c = this.cells as i64;
                            let ci = this.cell_of(i) as i64;
                            let (cx, cy) = (ci % c, ci / c);
                            for oy in -1..=1i64 {
                                for ox in -1..=1i64 {
                                    let nc = ((cy + oy).rem_euclid(c) * c
                                        + (cx + ox).rem_euclid(c))
                                        as usize;
                                    let mut j = this.head[nc];
                                    while j >= 0 {
                                        let ju = j as usize;
                                        if ju != i {
                                            let (dfx, dfy) = this.pair_force(i, ju);
                                            fx += dfx;
                                            fy += dfy;
                                        }
                                        j = this.next[ju];
                                    }
                                }
                            }
                            acc.push((fx, fy));
                        }
                        *slot = Some(acc);
                    });
                }
            });
            outputs.extend(slots.into_iter().map(|s| s.expect("force stripe")));
        }
        for (range, acc) in ranges.into_iter().zip(outputs) {
            for (i, (fx, fy)) in range.zip(acc) {
                self.fx[i] = fx;
                self.fy[i] = fy;
            }
        }
        let dt = self.p.dt;
        let b = self.p.box_side;
        for i in 0..n {
            self.vx[i] += self.fx[i] * dt;
            self.vy[i] += self.fy[i] * dt;
            self.x[i] = (self.x[i] + self.vx[i] * dt).rem_euclid(b);
            self.y[i] = (self.y[i] + self.vy[i] * dt).rem_euclid(b);
        }
        self.step += 1;
    }

    /// Total momentum (must be conserved by the symmetric pair forces).
    pub fn momentum(&self) -> (f64, f64) {
        (self.vx.iter().sum(), self.vy.iter().sum())
    }

    /// Instantaneous kinetic temperature (2-D: `kT = <v²>/2` per particle).
    pub fn temperature(&self) -> f64 {
        let v2: f64 = (0..self.p.n)
            .map(|i| self.vx[i] * self.vx[i] + self.vy[i] * self.vy[i])
            .sum();
        v2 / (2.0 * self.p.n as f64)
    }

    pub fn state_hash(&self) -> u64 {
        let mut h = crate::util::hash::Fnv1a::new();
        h.write_f64_slice(&self.x);
        h.write_f64_slice(&self.y);
        h.write_f64_slice(&self.vx);
        h.write_f64_slice(&self.vy);
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{CounterRng, Rng};

    fn params(n: usize) -> DpdParams {
        DpdParams {
            n,
            box_side: (n as f64 / 4.0).sqrt(), // density 4 (Groot-Warren ρ=4ish)
            a: 25.0,
            gamma: 4.5,
            kt: 1.0,
            dt: 0.01,
            global_seed: 99,
        }
    }

    #[test]
    fn pair_seed_symmetric_and_distinct() {
        assert_eq!(pair_seed(3, 7, 0), pair_seed(7, 3, 0));
        assert_ne!(pair_seed(3, 7, 0), pair_seed(3, 8, 0));
        assert_ne!(pair_seed(3, 7, 0), pair_seed(3, 7, 1));
        // (i,j) vs (j,i) with swapped identity must differ: (1,2) != (2,1)
        // collapses to the same canonical pair — but (1,3) != (2,3):
        assert_ne!(pair_seed(1, 3, 0), pair_seed(2, 3, 0));
    }

    #[test]
    fn pair_key_is_the_legacy_identity_and_symmetric() {
        // Zero drift: the typed pair address resolves to exactly the
        // raw (pair_seed, step) spelling, both pair orders.
        let k = pair_key(3, 7, 5, 2);
        assert_eq!((k.seed(), k.ctr()), (pair_seed(3, 7, 5), 2));
        assert_eq!(pair_key(7, 3, 5, 2), k);
        assert_ne!(pair_key(3, 7, 5, 3), k); // next step = next epoch
    }

    #[test]
    fn pair_theta_matches_word_at_a_time() {
        // The block-path rewrite consumes the same six stream words in
        // the same order as three buffered draw_double calls.
        for (i, j, g, s) in [(1u64, 2u64, 0u64, 0u32), (5, 9, 77, 3), (100, 7, 1, 12)] {
            let mut rng = Philox::new(pair_seed(i, j, g), s);
            let want =
                (rng.draw_double() + rng.draw_double() + rng.draw_double() - 1.5) * 2.0;
            assert_eq!(pair_theta(i, j, g, s).to_bits(), want.to_bits(), "({i},{j})");
        }
    }

    #[test]
    fn pair_theta_is_symmetric_zero_mean() {
        let mut acc = 0.0;
        for k in 0..2000u64 {
            assert_eq!(
                pair_theta(k, k + 1, 5, 3).to_bits(),
                pair_theta(k + 1, k, 5, 3).to_bits()
            );
            acc += pair_theta(k, k + 7, 5, 3);
        }
        assert!((acc / 2000.0).abs() < 0.05);
    }

    #[test]
    fn momentum_conserved_exactly_in_direction() {
        // Pairwise antisymmetric forces conserve momentum; with f64
        // addition the residual is summation noise, orders below the
        // per-particle momentum scale.
        let mut sim = DpdSim::new(params(400));
        let (px0, py0) = sim.momentum();
        for _ in 0..50 {
            sim.step_all();
        }
        let (px, py) = sim.momentum();
        assert!((px - px0).abs() < 1e-9, "{px} vs {px0}");
        assert!((py - py0).abs() < 1e-9, "{py} vs {py0}");
    }

    #[test]
    fn momentum_blows_up_with_asymmetric_rng() {
        // Negative control: replace θ_ij by a per-PARTICLE stream (what a
        // stateful RNG would do) and momentum conservation dies. This is
        // the paper's core argument made executable.
        let p = params(400);
        let mut sim = DpdSim::new(p);
        // one Euler step with asymmetric random kicks bolted on:
        sim.rebuild_cells();
        sim.forces_range(0, p.n);
        let mut vx = sim.vx.clone();
        let mut vy = sim.vy.clone();
        for i in 0..p.n {
            let mut rng = Philox::new(i as u64, 1); // per-particle, NOT per-pair
            vx[i] += sim.fx[i] * p.dt + (rng.draw_double() - 0.5) * 0.1;
            vy[i] += sim.fy[i] * p.dt + (rng.draw_double() - 0.5) * 0.1;
        }
        let px: f64 = vx.iter().sum();
        let py: f64 = vy.iter().sum();
        let (px0, py0) = sim.momentum();
        let drift = ((px - px0).powi(2) + (py - py0).powi(2)).sqrt();
        assert!(drift > 1e-3, "asymmetric kicks should break conservation: {drift}");
    }

    #[test]
    fn thread_count_invariance() {
        let run = |threads: usize| {
            let mut sim = DpdSim::new(params(256));
            for _ in 0..10 {
                if threads == 1 {
                    sim.step_all();
                } else {
                    sim.step_parallel(threads);
                }
            }
            sim.state_hash()
        };
        let h1 = run(1);
        assert_eq!(run(2), h1);
        assert_eq!(run(4), h1);
    }

    #[test]
    fn temperature_equilibrates_near_kt() {
        // The DPD thermostat drives kinetic temperature toward kT
        // (discretization offsets it a few percent at dt = 0.01).
        let mut sim = DpdSim::new(params(900));
        for _ in 0..400 {
            sim.step_all();
        }
        let t = sim.temperature();
        assert!((0.7..1.4).contains(&t), "temperature {t}");
    }

    #[test]
    fn from_state_resume_is_bitwise() {
        // (x, y, vx, vy, step) is the whole state: resuming mid-run
        // from copied arrays replays the uninterrupted trajectory
        // exactly (the campaign checkpoint contract for the DPD model).
        let p = params(128);
        let mut full = DpdSim::new(p);
        for _ in 0..8 {
            full.step_all();
        }
        let mut head = DpdSim::new(p);
        for _ in 0..3 {
            head.step_all();
        }
        let mut tail = DpdSim::from_state(
            p,
            head.x.clone(),
            head.y.clone(),
            head.vx.clone(),
            head.vy.clone(),
            head.step,
        );
        for _ in 0..5 {
            tail.step_all();
        }
        assert_eq!(tail.step, full.step);
        assert_eq!(tail.state_hash(), full.state_hash());
    }

    #[test]
    fn deterministic_rerun() {
        let mut a = DpdSim::new(params(128));
        let mut b = DpdSim::new(params(128));
        for _ in 0..5 {
            a.step_all();
            b.step_all();
        }
        assert_eq!(a.state_hash(), b.state_hash());
    }
}
