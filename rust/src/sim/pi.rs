//! Monte-Carlo π — the canonical reproducible-parallelism demo: each
//! logical chunk owns the stream [`chunk_key`] addresses (the legacy
//! `(chunk_id ^ seed, ctr = 0)` identity behind the `StreamKey` facade),
//! so the estimate is bitwise independent of how chunks are scheduled
//! onto threads.
//!
//! The sample loop draws through the block-fill engine
//! ([`crate::core::fill`]): stream words arrive in stack-tile batches
//! via `fill_from` instead of `4 * samples` buffered draw calls — same
//! stream words, same estimate, fewer per-word branches, no heap
//! allocation in the hot loop.

use crate::backend::FillBackend;
use crate::core::{fill, BlockRng, Generator};
use crate::stream::{self, StreamKey};

/// The stream address of one π chunk — the facade spelling of the
/// legacy `(chunk_id ^ global_seed, ctr = 0)` addressing, byte-identical
/// by the [`StreamKey::raw`] equivalence (zero drift: the estimates of
/// every prior release replay unchanged).
pub fn chunk_key(chunk_id: u64, global_seed: u64) -> StreamKey {
    StreamKey::raw(chunk_id ^ global_seed, 0)
}

/// Count hits inside the quarter circle for one chunk of samples.
/// Sample `k` uses stream words `4k..4k + 4` (x from the first pair, y
/// from the second) — identical consumption to the original
/// `draw_double` pair per sample.
pub fn chunk_hits<G: BlockRng>(chunk_id: u64, global_seed: u64, samples_per_chunk: usize) -> u64 {
    // Samples per stack tile (4 words each — 4 KiB of scratch).
    const TILE: usize = 256;
    let mut words = [0u32; 4 * TILE];
    let key = chunk_key(chunk_id, global_seed);
    let mut g = G::new(key.seed(), key.ctr());
    let mut pos = 0u64;
    let mut hits = 0u64;
    let mut done = 0usize;
    while done < samples_per_chunk {
        let n = (samples_per_chunk - done).min(TILE);
        let tile = &mut words[..4 * n];
        fill::fill_from(&mut g, pos, tile);
        pos = pos.wrapping_add((4 * n) as u64);
        for k in 0..n {
            let x = fill::u01_f64(tile[4 * k], tile[4 * k + 1]);
            let y = fill::u01_f64(tile[4 * k + 2], tile[4 * k + 3]);
            if x * x + y * y <= 1.0 {
                hits += 1;
            }
        }
        done += n;
    }
    hits
}

/// Sequential reference over `chunks` chunks.
pub fn estimate_pi<G: BlockRng>(chunks: u64, samples_per_chunk: usize, global_seed: u64) -> f64 {
    let hits: u64 = (0..chunks)
        .map(|c| chunk_hits::<G>(c, global_seed, samples_per_chunk))
        .sum();
    4.0 * hits as f64 / (chunks as f64 * samples_per_chunk as f64)
}

/// [`chunk_hits`] through a fill backend: the chunk's whole word budget
/// arrives as one `fill_f64` of `2·samples` doubles from stream
/// `(chunk_id ^ seed, 0)` — element `2k` is sample `k`'s x (words
/// `4k, 4k+1`), element `2k+1` its y (words `4k+2, 4k+3`), the exact
/// consumption of the serial tile loop, so the hit count is identical on
/// every backend arm by the backend contract.
pub fn chunk_hits_backend(
    backend: &mut dyn FillBackend,
    gen: Generator,
    chunk_id: u64,
    global_seed: u64,
    samples_per_chunk: usize,
) -> anyhow::Result<u64> {
    let mut xy = vec![0.0f64; 2 * samples_per_chunk];
    stream::fill_f64_key(Some(backend), gen, chunk_key(chunk_id, global_seed), &mut xy)?;
    Ok(hits_in(&xy))
}

fn hits_in(xy: &[f64]) -> u64 {
    let mut hits = 0u64;
    for pair in xy.chunks_exact(2) {
        if pair[0] * pair[0] + pair[1] * pair[1] <= 1.0 {
            hits += 1;
        }
    }
    hits
}

/// [`estimate_pi`] with an optional backend handle: `None` routes every
/// chunk through the calibrated default `Auto` arm
/// ([`stream::default_backend`]), `Some(backend)` through the given arm
/// (host-serial, host-parallel, or device) — the estimate is bitwise
/// identical on every arm by the backend contract.
pub fn estimate_pi_with(
    mut backend: Option<&mut dyn FillBackend>,
    gen: Generator,
    chunks: u64,
    samples_per_chunk: usize,
    global_seed: u64,
) -> anyhow::Result<f64> {
    // One xy buffer for the whole run; per-chunk allocation would put a
    // malloc/free pair in the hot loop this module promises is clean.
    // Each chunk routes through fill_f64_key, so the None case reuses
    // the thread-cached Auto instance instead of re-probing per call.
    let mut xy = vec![0.0f64; 2 * samples_per_chunk];
    let mut hits = 0u64;
    for c in 0..chunks {
        stream::fill_f64_key(backend.as_deref_mut(), gen, chunk_key(c, global_seed), &mut xy)?;
        hits += hits_in(&xy);
    }
    Ok(4.0 * hits as f64 / (chunks as f64 * samples_per_chunk as f64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Philox, Squares};

    #[test]
    fn converges_to_pi() {
        let est = estimate_pi::<Philox>(64, 10_000, 1);
        assert!((est - std::f64::consts::PI).abs() < 0.01, "{est}");
        let est = estimate_pi::<Squares>(64, 10_000, 1);
        assert!((est - std::f64::consts::PI).abs() < 0.01, "{est}");
    }

    #[test]
    fn batched_chunk_matches_word_at_a_time_draws() {
        // The block-fill rewrite must not move a single stream word: the
        // original draw_double pair loop gives the same hit count.
        use crate::core::{CounterRng, Rng};
        let mut rng = Philox::new(3 ^ 9, 0);
        let mut hits = 0u64;
        for _ in 0..1000 {
            let x = rng.draw_double();
            let y = rng.draw_double();
            if x * x + y * y <= 1.0 {
                hits += 1;
            }
        }
        assert_eq!(chunk_hits::<Philox>(3, 9, 1000), hits);
    }

    #[test]
    fn backend_chunks_match_serial_chunks() {
        use crate::backend::{HostParallel, HostSerial};
        let gen = Generator::Philox;
        for chunk_id in [0u64, 3, 17] {
            let want = chunk_hits::<Philox>(chunk_id, 9, 1000);
            let got = chunk_hits_backend(&mut HostSerial, gen, chunk_id, 9, 1000).unwrap();
            assert_eq!(got, want, "serial chunk {chunk_id}");
            let got =
                chunk_hits_backend(&mut HostParallel::new(4), gen, chunk_id, 9, 1000).unwrap();
            assert_eq!(got, want, "parallel chunk {chunk_id}");
        }
        // Whole-estimate equivalence, with and without a handle.
        let reference = estimate_pi::<Philox>(16, 500, 7);
        let none = estimate_pi_with(None, gen, 16, 500, 7).unwrap();
        assert_eq!(none.to_bits(), reference.to_bits());
        let mut par = HostParallel::new(3);
        let with = estimate_pi_with(Some(&mut par), gen, 16, 500, 7).unwrap();
        assert_eq!(with.to_bits(), reference.to_bits());
    }

    #[test]
    fn chunk_key_is_the_legacy_identity() {
        use crate::core::{CounterRng, Rng};
        // Zero drift: the facade addressing opens the byte-identical
        // stream the raw spelling always opened.
        let key = chunk_key(3, 9);
        assert_eq!((key.seed(), key.ctr()), (3 ^ 9, 0));
        let mut via_key = crate::stream::Stream::<Philox>::new(key);
        let mut legacy = Philox::new(3 ^ 9, 0);
        for _ in 0..32 {
            assert_eq!(via_key.next_u32(), legacy.next_u32());
        }
    }

    #[test]
    fn chunk_order_irrelevant() {
        let forward: u64 = (0..32).map(|c| chunk_hits::<Philox>(c, 9, 1000)).sum();
        let mut ids: Vec<u64> = (0..32).collect();
        ids.reverse();
        let backward: u64 = ids.iter().map(|&c| chunk_hits::<Philox>(c, 9, 1000)).sum();
        assert_eq!(forward, backward);
    }
}
