//! Monte-Carlo π — the canonical reproducible-parallelism demo: each
//! logical chunk owns stream (seed = chunk_id, ctr = 0), so the estimate
//! is bitwise independent of how chunks are scheduled onto threads.
//!
//! The sample loop draws through the block-fill engine
//! ([`crate::core::fill`]): stream words arrive in stack-tile batches
//! via `fill_from` instead of `4 * samples` buffered draw calls — same
//! stream words, same estimate, fewer per-word branches, no heap
//! allocation in the hot loop.

use crate::core::{fill, BlockRng};

/// Count hits inside the quarter circle for one chunk of samples.
/// Sample `k` uses stream words `4k..4k + 4` (x from the first pair, y
/// from the second) — identical consumption to the original
/// `draw_double` pair per sample.
pub fn chunk_hits<G: BlockRng>(chunk_id: u64, global_seed: u64, samples_per_chunk: usize) -> u64 {
    // Samples per stack tile (4 words each — 4 KiB of scratch).
    const TILE: usize = 256;
    let mut words = [0u32; 4 * TILE];
    let mut g = G::new(chunk_id ^ global_seed, 0);
    let mut pos = 0u32;
    let mut hits = 0u64;
    let mut done = 0usize;
    while done < samples_per_chunk {
        let n = (samples_per_chunk - done).min(TILE);
        let tile = &mut words[..4 * n];
        fill::fill_from(&mut g, pos, tile);
        pos = pos.wrapping_add((4 * n) as u32);
        for k in 0..n {
            let x = fill::u01_f64(tile[4 * k], tile[4 * k + 1]);
            let y = fill::u01_f64(tile[4 * k + 2], tile[4 * k + 3]);
            if x * x + y * y <= 1.0 {
                hits += 1;
            }
        }
        done += n;
    }
    hits
}

/// Sequential reference over `chunks` chunks.
pub fn estimate_pi<G: BlockRng>(chunks: u64, samples_per_chunk: usize, global_seed: u64) -> f64 {
    let hits: u64 = (0..chunks)
        .map(|c| chunk_hits::<G>(c, global_seed, samples_per_chunk))
        .sum();
    4.0 * hits as f64 / (chunks as f64 * samples_per_chunk as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Philox, Squares};

    #[test]
    fn converges_to_pi() {
        let est = estimate_pi::<Philox>(64, 10_000, 1);
        assert!((est - std::f64::consts::PI).abs() < 0.01, "{est}");
        let est = estimate_pi::<Squares>(64, 10_000, 1);
        assert!((est - std::f64::consts::PI).abs() < 0.01, "{est}");
    }

    #[test]
    fn batched_chunk_matches_word_at_a_time_draws() {
        // The block-fill rewrite must not move a single stream word: the
        // original draw_double pair loop gives the same hit count.
        use crate::core::{CounterRng, Rng};
        let mut rng = Philox::new(3 ^ 9, 0);
        let mut hits = 0u64;
        for _ in 0..1000 {
            let x = rng.draw_double();
            let y = rng.draw_double();
            if x * x + y * y <= 1.0 {
                hits += 1;
            }
        }
        assert_eq!(chunk_hits::<Philox>(3, 9, 1000), hits);
    }

    #[test]
    fn chunk_order_irrelevant() {
        let forward: u64 = (0..32).map(|c| chunk_hits::<Philox>(c, 9, 1000)).sum();
        let mut ids: Vec<u64> = (0..32).collect();
        ids.reverse();
        let backward: u64 = ids.iter().map(|&c| chunk_hits::<Philox>(c, 9, 1000)).sum();
        assert_eq!(forward, backward);
    }
}
