//! Monte-Carlo π — the canonical reproducible-parallelism demo: each
//! logical chunk owns stream (seed = chunk_id, ctr = 0), so the estimate
//! is bitwise independent of how chunks are scheduled onto threads.

use crate::core::CounterRng;

/// Count hits inside the quarter circle for one chunk of samples.
pub fn chunk_hits<G: CounterRng>(chunk_id: u64, global_seed: u64, samples_per_chunk: usize) -> u64 {
    let mut rng = G::new(chunk_id ^ global_seed, 0);
    let mut hits = 0u64;
    for _ in 0..samples_per_chunk {
        let x = rng.draw_double();
        let y = rng.draw_double();
        if x * x + y * y <= 1.0 {
            hits += 1;
        }
    }
    hits
}

/// Sequential reference over `chunks` chunks.
pub fn estimate_pi<G: CounterRng>(chunks: u64, samples_per_chunk: usize, global_seed: u64) -> f64 {
    let hits: u64 = (0..chunks)
        .map(|c| chunk_hits::<G>(c, global_seed, samples_per_chunk))
        .sum();
    4.0 * hits as f64 / (chunks as f64 * samples_per_chunk as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Philox, Squares};

    #[test]
    fn converges_to_pi() {
        let est = estimate_pi::<Philox>(64, 10_000, 1);
        assert!((est - std::f64::consts::PI).abs() < 0.01, "{est}");
        let est = estimate_pi::<Squares>(64, 10_000, 1);
        assert!((est - std::f64::consts::PI).abs() < 0.01, "{est}");
    }

    #[test]
    fn chunk_order_irrelevant() {
        let forward: u64 = (0..32).map(|c| chunk_hits::<Philox>(c, 9, 1000)).sum();
        let mut ids: Vec<u64> = (0..32).collect();
        ids.reverse();
        let backward: u64 = ids.iter().map(|&c| chunk_hits::<Philox>(c, 9, 1000)).sum();
        assert_eq!(forward, backward);
    }
}
