//! Physics observables for the Brownian benchmark: mean-squared
//! displacement and the diffusion-law check. These make the E2E example
//! a *validated* simulation, not just a timing loop.

use super::brownian::{BrownianSim, DT, GAMMA, MASS};

/// Mean-squared displacement of caller-owned position arrays from a
/// reference configuration — the slice form the campaign runner
/// (`crate::campaign::observables`) samples its MSD series through.
pub fn msd_xy(x: &[f64], y: &[f64], x0: &[f64], y0: &[f64]) -> f64 {
    assert_eq!(x.len(), x0.len());
    assert_eq!(y.len(), y0.len());
    let n = x.len();
    let mut acc = 0.0;
    for i in 0..n {
        let dx = x[i] - x0[i];
        let dy = y[i] - y0[i];
        acc += dx * dx + dy * dy;
    }
    acc / n as f64
}

/// Mean-squared displacement from the initial grid positions.
pub fn msd(sim: &BrownianSim, x0: &[f64], y0: &[f64]) -> f64 {
    msd_xy(&sim.x, &sim.y, x0, y0)
}

/// Theoretical long-time MSD slope for this integrator.
///
/// Kick variance per step per axis: Var[(2u-1)·√dt] = dt/3. With drag
/// factor a = 1 − γ/m·dt, stationary velocity variance per axis is
/// σ_v² = (dt/3)/(1−a²), and the long-time diffusion follows
/// MSD(t) ≈ 4·D·t with D = σ_v²·dt·(1+a)/(2·(1−a)) (discrete-time
/// Ornstein–Uhlenbeck position variance growth).
pub fn theoretical_msd_slope() -> f64 {
    let a = 1.0 - (GAMMA / MASS) * DT;
    let sigma_v2 = (DT / 3.0) / (1.0 - a * a);
    // Var[x_T] per axis ~ sigma_v2 * dt^2 * (1+a)/(1-a) * T  (T steps)
    let dvar_per_step = sigma_v2 * DT * DT * (1.0 + a) / (1.0 - a);
    2.0 * dvar_per_step // both axes
}

/// Mean velocity magnitude (kinetic sanity check).
pub fn mean_speed(sim: &BrownianSim) -> f64 {
    let n = sim.params.n_particles;
    (0..n)
        .map(|i| (sim.vx[i] * sim.vx[i] + sim.vy[i] * sim.vy[i]).sqrt())
        .sum::<f64>()
        / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::brownian::{BrownianParams, RngStyle};

    #[test]
    fn msd_grows_linearly_at_long_times() {
        let mut sim = BrownianSim::new(BrownianParams {
            n_particles: 8192,
            steps: 0,
            global_seed: 7,
            style: RngStyle::OpenRand,
        });
        let x0 = sim.x.clone();
        let y0 = sim.y.clone();
        // Warm past the velocity relaxation time (1/(γ dt) = 200 steps).
        for _ in 0..600 {
            sim.step_all();
        }
        let m1 = msd(&sim, &x0, &y0);
        for _ in 0..600 {
            sim.step_all();
        }
        let m2 = msd(&sim, &x0, &y0);
        let slope = (m2 - m1) / 600.0;
        let theory = theoretical_msd_slope();
        assert!(
            (slope / theory - 1.0).abs() < 0.15,
            "slope {slope:.3e} vs theory {theory:.3e}"
        );
    }

    #[test]
    fn velocities_reach_stationary_variance() {
        let mut sim = BrownianSim::new(BrownianParams {
            n_particles: 8192,
            steps: 0,
            global_seed: 3,
            style: RngStyle::OpenRand,
        });
        for _ in 0..1500 {
            sim.step_all();
        }
        let var_vx: f64 =
            sim.vx.iter().map(|v| v * v).sum::<f64>() / sim.params.n_particles as f64;
        let a = 1.0 - (GAMMA / MASS) * DT;
        let sigma_v2 = (DT / 3.0) / (1.0 - a * a);
        assert!(
            (var_vx / sigma_v2 - 1.0).abs() < 0.1,
            "var {var_vx:.3e} vs theory {sigma_v2:.3e}"
        );
    }

    #[test]
    fn msd_zero_at_start() {
        let sim = BrownianSim::new(BrownianParams::default());
        assert_eq!(msd(&sim, &sim.x, &sim.y), 0.0);
        assert_eq!(mean_speed(&sim), 0.0);
    }
}
