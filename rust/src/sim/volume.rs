//! Monte-Carlo integration: volume of the d-dimensional unit ball —
//! a second example workload exercising higher-dimensional equidistribution.

use crate::core::CounterRng;

/// Exact volume of the d-ball of radius 1.
pub fn exact_ball_volume(d: u32) -> f64 {
    // V_d = pi^{d/2} / Gamma(d/2 + 1)
    let half = d as f64 / 2.0;
    std::f64::consts::PI.powf(half) / crate::stats::pvalue::ln_gamma(half + 1.0).exp()
}

/// MC estimate with per-chunk streams.
pub fn estimate_ball_volume<G: CounterRng>(
    d: u32,
    chunks: u64,
    samples_per_chunk: usize,
    global_seed: u64,
) -> f64 {
    let mut hits = 0u64;
    for chunk in 0..chunks {
        let mut rng = G::new(chunk ^ global_seed, d);
        for _ in 0..samples_per_chunk {
            let mut r2 = 0.0;
            for _ in 0..d {
                let x = rng.draw_double() * 2.0 - 1.0;
                r2 += x * x;
            }
            if r2 <= 1.0 {
                hits += 1;
            }
        }
    }
    let cube = 2f64.powi(d as i32);
    cube * hits as f64 / (chunks as f64 * samples_per_chunk as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Threefry;

    #[test]
    fn exact_volumes_known() {
        assert!((exact_ball_volume(2) - std::f64::consts::PI).abs() < 1e-9);
        assert!((exact_ball_volume(3) - 4.0 / 3.0 * std::f64::consts::PI).abs() < 1e-9);
    }

    #[test]
    fn estimates_match_exact() {
        for d in [2u32, 3, 5] {
            let est = estimate_ball_volume::<Threefry>(d, 16, 20_000, 5);
            let exact = exact_ball_volume(d);
            assert!((est / exact - 1.0).abs() < 0.05, "d={d}: {est} vs {exact}");
        }
    }
}
