//! Simulation substrates — the workloads the paper's evaluation runs.
//!
//! [`brownian`] is the macro-benchmark (Fig. 4b): a 2-D Brownian dynamics
//! system with drag + uniform random kicks, implemented in all three API
//! styles (OpenRAND stateless / cuRAND-style stateful / Random123 raw)
//! so the benchmark isolates RNG-API cost with the physics held constant.
//! [`observables`] computes the physics checks (mean-squared displacement
//! vs. the diffusion law). [`pi`] and [`volume`] are the extra Monte-Carlo
//! example workloads.

pub mod brownian;
pub mod dpd;
pub mod observables;
pub mod pi;
pub mod volume;

pub use brownian::{BrownianParams, BrownianSim, RngStyle};
