//! The paper's Brownian-dynamics macro-benchmark (Fig. 1/2/3, Fig. 4b).
//!
//! One million independent particles diffuse under a velocity-
//! proportional drag force plus a uniform random kick; RNG cost dominates
//! the kernel, which is exactly why the paper uses it to compare RNG
//! APIs. Physics constants are the **normative pair** of
//! `python/compile/model.py` — the host path here and the device path
//! (AOT artifact `brownian_step_*`) must produce bitwise-identical RNG
//! draws and numerically identical trajectories.
//!
//! The three RNG styles of Figs. 1–3:
//! * [`RngStyle::OpenRand`] — `Philox::new(pid ^ seed, step)` per
//!   particle per step; zero state.
//! * [`RngStyle::CurandStyle`] — a 64 B heap state record per particle,
//!   loaded + stored every step, initialized by a separate pass.
//! * [`RngStyle::Raw123`] — counter-based like OpenRand but through the
//!   raw block API with manual u64 packing (Fig. 3 boilerplate).

use crate::baseline::stateful_philox::{init_states, CurandPhiloxState, StatefulPhilox};
use crate::baseline::raw123;
use crate::core::fill::u01_f64;
use crate::core::philox::philox4x32;
use crate::core::{BlockRng, CounterRng, Philox, Rng};
use crate::util::hash::Fnv1a;

/// Physics constants — keep identical to python/compile/model.py.
pub const GAMMA: f64 = 0.5;
pub const MASS: f64 = 1.0;
pub const DT: f64 = 0.01;

/// Deterministic grid initial positions — the normative pair of
/// `model.brownian_init`, shared by [`BrownianSim::new`] and the
/// campaign runner ([`crate::campaign`]). The campaign checkpoint
/// format stores no initial positions because this function recomputes
/// them from `n` alone.
pub fn grid_init(n: usize) -> (Vec<f64>, Vec<f64>) {
    let side = (n as f64).sqrt().ceil() as usize;
    let mut x = vec![0.0; n];
    let mut y = vec![0.0; n];
    for pid in 0..n {
        x[pid] = (pid / side) as f64;
        y[pid] = (pid % side) as f64;
    }
    (x, y)
}

/// One particle's drag + kick + drift update over caller-owned state —
/// the integrator body extracted so external drivers (the campaign
/// runner) can step particle arrays they own. Expression order matches
/// python/compile/model.py exactly so host and device trajectories
/// agree to the last ulp; do not "simplify" the algebra.
#[inline(always)]
pub fn kick_step(
    x: &mut f64,
    y: &mut f64,
    vx: &mut f64,
    vy: &mut f64,
    r1: f64,
    r2: f64,
    sqrt_dt: f64,
) {
    let mut v_x = *vx;
    let mut v_y = *vy;
    // Drag force.
    v_x = v_x - (GAMMA / MASS) * v_x * DT;
    v_y = v_y - (GAMMA / MASS) * v_y * DT;
    // Random kick.
    v_x += (r1 * 2.0 - 1.0) * sqrt_dt;
    v_y += (r2 * 2.0 - 1.0) * sqrt_dt;
    // Position update.
    *x += v_x * DT;
    *y += v_y * DT;
    *vx = v_x;
    *vy = v_y;
}

/// Which RNG API style drives the kick (the Fig. 4b x-axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RngStyle {
    /// Paper Fig. 1: stateless counter-based, seed = pid.
    OpenRand,
    /// Paper Fig. 2: cuRAND-style per-particle state array.
    CurandStyle,
    /// Paper Fig. 3: Random123 raw API (same streams as OpenRand).
    Raw123,
}

impl RngStyle {
    pub const ALL: [RngStyle; 3] = [RngStyle::OpenRand, RngStyle::CurandStyle, RngStyle::Raw123];

    pub fn name(self) -> &'static str {
        match self {
            RngStyle::OpenRand => "openrand",
            RngStyle::CurandStyle => "curand_style",
            RngStyle::Raw123 => "random123",
        }
    }
}

/// Simulation parameters.
#[derive(Debug, Clone, Copy)]
pub struct BrownianParams {
    pub n_particles: usize,
    pub steps: u32,
    pub global_seed: u64,
    pub style: RngStyle,
}

impl Default for BrownianParams {
    fn default() -> Self {
        BrownianParams { n_particles: 16_384, steps: 100, global_seed: 0, style: RngStyle::OpenRand }
    }
}

/// Particle system in structure-of-arrays layout (one cache-friendly
/// stripe per field; the device path uses the same logical layout).
pub struct BrownianSim {
    pub params: BrownianParams,
    pub x: Vec<f64>,
    pub y: Vec<f64>,
    pub vx: Vec<f64>,
    pub vy: Vec<f64>,
    /// cuRAND-style state array (allocated only for CurandStyle — the
    /// memory-cost line item of Fig. 4b).
    pub states: Vec<CurandPhiloxState>,
    pub step: u32,
}

impl BrownianSim {
    /// Deterministic grid init — normative pair of `model.brownian_init`.
    pub fn new(params: BrownianParams) -> Self {
        let n = params.n_particles;
        let (x, y) = grid_init(n);
        let states = if params.style == RngStyle::CurandStyle {
            // The separate init pass cuRAND requires (Fig. 2 rand_init).
            init_states(params.global_seed, n)
        } else {
            Vec::new()
        };
        BrownianSim { params, x, y, vx: vec![0.0; n], vy: vec![0.0; n], states, step: 0 }
    }

    /// Extra memory the RNG style costs (bytes) — E7.
    pub fn rng_state_bytes(&self) -> usize {
        self.states.len() * std::mem::size_of::<CurandPhiloxState>()
    }

    /// Advance one step over particle range [lo, hi) — the kernel body.
    /// Range-based so the coordinator can partition it across threads
    /// while preserving bitwise reproducibility (streams derive from pid,
    /// never from the executing thread).
    pub fn step_range(&mut self, lo: usize, hi: usize) {
        let sqrt_dt = DT.sqrt();
        let drag = 1.0 - (GAMMA / MASS) * DT;
        let step = self.step;
        let seed = self.params.global_seed;
        match self.params.style {
            RngStyle::OpenRand => {
                // Paper Fig. 1 semantics, batched per particle range:
                // each particle's kick is exactly one Philox counter
                // block, so a tile of kicks is generated through the
                // BlockRng fast path (one raw block call per particle,
                // no per-word buffer bookkeeping), then the physics loop
                // runs over the tile. Bit-identical to the word-at-a-time
                // form — pinned by `openrand_and_raw123_same_streams` and
                // `first_step_matches_hand_computation` below.
                const TILE: usize = 512;
                let mut kicks = [(0.0f64, 0.0f64); TILE];
                let mut base = lo;
                while base < hi {
                    let m = (hi - base).min(TILE);
                    for (k, kick) in kicks[..m].iter_mut().enumerate() {
                        let mut rng = Philox::new((base + k) as u64 ^ seed, step);
                        let mut blk = [0u32; 4];
                        rng.generate_block(&mut blk);
                        *kick = (u01_f64(blk[0], blk[1]), u01_f64(blk[2], blk[3]));
                    }
                    for k in 0..m {
                        let (r1, r2) = kicks[k];
                        self.kick(base + k, drag, sqrt_dt, r1, r2);
                    }
                    base += m;
                }
            }
            RngStyle::CurandStyle => {
                for pid in lo..hi {
                    // Paper Fig. 2: load state, draw, store state.
                    let mut rng = StatefulPhilox::load(&self.states, pid);
                    let (r1, r2) = rng.draw_double2();
                    rng.store(&mut self.states, pid);
                    self.kick(pid, drag, sqrt_dt, r1, r2);
                }
            }
            RngStyle::Raw123 => {
                for pid in lo..hi {
                    // Paper Fig. 3: raw counter/key plumbing by hand.
                    // Same stream identity as OpenRand (counter layout
                    // from core::counter), packed manually.
                    let pid_seed = pid as u64 ^ seed;
                    let block = philox4x32(
                        [0, step, 0, 0],
                        [pid_seed as u32, (pid_seed >> 32) as u32],
                    );
                    let xu = ((block[0] as u64) << 32) | block[1] as u64;
                    let yu = ((block[2] as u64) << 32) | block[3] as u64;
                    let (r1, r2) = (raw123::u01_u64(xu), raw123::u01_u64(yu));
                    self.kick(pid, drag, sqrt_dt, r1, r2);
                }
            }
        }
    }

    #[inline(always)]
    fn kick(&mut self, pid: usize, _drag: f64, sqrt_dt: f64, r1: f64, r2: f64) {
        kick_step(
            &mut self.x[pid],
            &mut self.y[pid],
            &mut self.vx[pid],
            &mut self.vy[pid],
            r1,
            r2,
            sqrt_dt,
        );
    }

    /// Bulk thermal kick: superpose a deterministic thermal velocity
    /// perturbation drawn in bulk from the stream
    /// `StreamKey::raw(global_seed, ctr)` of `gen` through a fill
    /// backend — `None` routes through the calibrated default `Auto`
    /// arm ([`crate::stream::default_backend`], the ROADMAP
    /// "Auto-backend consumers" item). Particle `pid` consumes doubles
    /// `2·pid` (vx) and `2·pid + 1` (vy) — a fixed word pattern, so by
    /// the backend contract the resulting state is byte-identical on
    /// every arm (serial, sharded-parallel, device) and composes with
    /// the step loop's own reproducibility. Pick a `ctr` outside the
    /// step range (steps use `ctr = step`) to keep streams disjoint.
    pub fn thermalize(
        &mut self,
        backend: Option<&mut dyn crate::backend::FillBackend>,
        gen: crate::core::Generator,
        ctr: u32,
        scale: f64,
    ) -> anyhow::Result<()> {
        let n = self.params.n_particles;
        let key = crate::stream::StreamKey::raw(self.params.global_seed, ctr);
        let mut u = vec![0.0f64; 2 * n];
        crate::stream::fill_f64_key(backend, gen, key, &mut u)?;
        for pid in 0..n {
            self.vx[pid] += scale * (2.0 * u[2 * pid] - 1.0);
            self.vy[pid] += scale * (2.0 * u[2 * pid + 1] - 1.0);
        }
        Ok(())
    }

    /// Single-threaded full step.
    pub fn step_all(&mut self) {
        self.step_range(0, self.params.n_particles);
        self.step += 1;
    }

    /// Run `steps` single-threaded.
    pub fn run(&mut self) {
        for _ in 0..self.params.steps {
            self.step_all();
        }
    }

    /// Bitwise trajectory fingerprint (reproducibility checks).
    pub fn state_hash(&self) -> u64 {
        let mut h = Fnv1a::new();
        h.write_f64_slice(&self.x);
        h.write_f64_slice(&self.y);
        h.write_f64_slice(&self.vx);
        h.write_f64_slice(&self.vy);
        h.finish()
    }

    /// Flatten to the device layout (N,4) row-major — for PJRT handoff.
    pub fn to_rows(&self) -> Vec<f64> {
        let n = self.params.n_particles;
        let mut out = Vec::with_capacity(4 * n);
        for i in 0..n {
            out.extend_from_slice(&[self.x[i], self.y[i], self.vx[i], self.vy[i]]);
        }
        out
    }

    /// Load from device layout.
    pub fn from_rows(&mut self, rows: &[f64]) {
        let n = self.params.n_particles;
        assert_eq!(rows.len(), 4 * n);
        for i in 0..n {
            self.x[i] = rows[4 * i];
            self.y[i] = rows[4 * i + 1];
            self.vx[i] = rows[4 * i + 2];
            self.vy[i] = rows[4 * i + 3];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(style: RngStyle) -> BrownianParams {
        BrownianParams { n_particles: 1024, steps: 20, global_seed: 42, style }
    }

    #[test]
    fn deterministic_per_style() {
        for style in RngStyle::ALL {
            let mut a = BrownianSim::new(params(style));
            let mut b = BrownianSim::new(params(style));
            a.run();
            b.run();
            assert_eq!(a.state_hash(), b.state_hash(), "{style:?}");
        }
    }

    #[test]
    fn openrand_and_raw123_same_streams() {
        // Fig. 1 and Fig. 3 draw from the same (pid, step) streams here
        // (we align Raw123 to the OpenRAND counter layout), so the
        // trajectories must coincide bitwise.
        let mut a = BrownianSim::new(params(RngStyle::OpenRand));
        let mut b = BrownianSim::new(params(RngStyle::Raw123));
        a.run();
        b.run();
        assert_eq!(a.state_hash(), b.state_hash());
    }

    #[test]
    fn curand_style_differs_but_is_valid() {
        let mut a = BrownianSim::new(params(RngStyle::OpenRand));
        let mut b = BrownianSim::new(params(RngStyle::CurandStyle));
        a.run();
        b.run();
        assert_ne!(a.state_hash(), b.state_hash()); // different stream layout
        // Same physics envelope though: bounded kicks.
        for i in 0..1024 {
            assert!(b.vx[i].abs() < 2.0 && b.vy[i].abs() < 2.0);
        }
    }

    #[test]
    fn state_memory_only_for_curand_style() {
        let a = BrownianSim::new(params(RngStyle::OpenRand));
        let b = BrownianSim::new(params(RngStyle::CurandStyle));
        assert_eq!(a.rng_state_bytes(), 0);
        assert_eq!(b.rng_state_bytes(), 1024 * 64); // paper's 64 B/particle
    }

    #[test]
    fn range_split_equals_full_step() {
        // Splitting the index range must not change anything — the
        // invariant that makes multithreading reproducible.
        let mut a = BrownianSim::new(params(RngStyle::OpenRand));
        let mut b = BrownianSim::new(params(RngStyle::OpenRand));
        a.step_range(0, 1024);
        a.step += 1;
        for chunk in [0..100, 100..777, 777..1024] {
            b.step_range(chunk.start, chunk.end);
        }
        b.step += 1;
        assert_eq!(a.state_hash(), b.state_hash());
    }

    #[test]
    fn thermalize_is_backend_invariant() {
        use crate::backend::{HostParallel, HostSerial};
        use crate::core::Generator;
        let mk = || BrownianSim::new(params(RngStyle::OpenRand));
        let mut a = mk();
        a.thermalize(Some(&mut HostSerial), Generator::Philox, u32::MAX, 0.3).unwrap();
        for t in [1usize, 2, 8] {
            let mut b = mk();
            b.thermalize(Some(&mut HostParallel::new(t)), Generator::Philox, u32::MAX, 0.3)
                .unwrap();
            assert_eq!(a.state_hash(), b.state_hash(), "threads={t}");
        }
        // The default (None = calibrated Auto arm) is byte-identical too.
        let mut auto = mk();
        auto.thermalize(None, Generator::Philox, u32::MAX, 0.3).unwrap();
        assert_eq!(a.state_hash(), auto.state_hash(), "default auto arm");
        // And it actually perturbed something.
        assert_ne!(a.state_hash(), mk().state_hash());
        // Composes with stepping: still bitwise reproducible end to end.
        let mut c = mk();
        c.thermalize(Some(&mut HostParallel::new(4)), Generator::Philox, u32::MAX, 0.3).unwrap();
        c.run();
        let mut d = mk();
        d.thermalize(None, Generator::Philox, u32::MAX, 0.3).unwrap();
        d.run();
        assert_eq!(c.state_hash(), d.state_hash());
    }

    #[test]
    fn rows_roundtrip() {
        let mut a = BrownianSim::new(params(RngStyle::OpenRand));
        a.run();
        let rows = a.to_rows();
        let mut b = BrownianSim::new(params(RngStyle::OpenRand));
        b.from_rows(&rows);
        assert_eq!(a.state_hash(), b.state_hash());
    }

    #[test]
    fn first_step_matches_hand_computation() {
        let mut sim = BrownianSim::new(BrownianParams {
            n_particles: 4,
            steps: 1,
            global_seed: 0,
            style: RngStyle::OpenRand,
        });
        sim.run();
        // Particle 2: stream (seed=2, ctr=0), block 0.
        let block = philox4x32([0, 0, 0, 0], [2, 0]);
        let xu = ((block[0] as u64) << 32) | block[1] as u64;
        let r1 = (xu >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let expected_vx = (r1 * 2.0 - 1.0) * DT.sqrt();
        assert_eq!(sim.vx[2], expected_vx);
        assert_eq!(sim.x[2], 1.0 + expected_vx * DT); // grid x + vx*dt (side=2)
    }
}
