//! Aligned text tables for bench output.

/// Simple column-aligned table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::new();
            for (c, cell) in cells.iter().enumerate() {
                if c > 0 {
                    s.push_str("  ");
                }
                // Right-align numbers-ish, left-align first column.
                if c == 0 {
                    s.push_str(&format!("{:<width$}", cell, width = widths[c]));
                } else {
                    s.push_str(&format!("{:>width$}", cell, width = widths[c]));
                }
            }
            s.push('\n');
            s
        };
        let mut out = fmt_row(&self.header);
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (cols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["gen", "ns/word", "ratio"]);
        t.row(&["philox".into(), "1.25".into(), "1.0x".into()]);
        t.row(&["mt19937_long_name".into(), "3.5".into(), "2.8x".into()]);
        let s = t.render();
        assert!(s.contains("philox"));
        assert!(s.contains("mt19937_long_name"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // All data lines same width.
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic]
    fn width_mismatch_panics() {
        Table::new(&["a", "b"]).row(&["only one".into()]);
    }
}
