//! Benchmark harness — the criterion substitute (offline environment).
//!
//! [`harness`] provides warmup, adaptive iteration-count calibration,
//! robust statistics (median, p10/p90) and throughput accounting;
//! [`table`] renders aligned result tables; [`series`] emits the
//! figure-shaped output (one series per generator/library, one point per
//! x value) that EXPERIMENTS.md compares against the paper's plots.

pub mod harness;
pub mod series;
pub mod table;

pub use harness::{bench_fn, BenchResult, Bencher};
pub use series::Series;
pub use table::Table;
