//! Figure-shaped output: named series over a shared x-axis, rendered as
//! both a table and a machine-greppable CSV block. The fig4a/fig4b
//! benches print these; EXPERIMENTS.md quotes them.

use std::fmt::Write as _;

/// A set of named series sharing an x axis (one paper figure).
#[derive(Debug, Clone)]
pub struct Series {
    pub title: String,
    pub x_label: String,
    pub y_label: String,
    pub x: Vec<f64>,
    pub series: Vec<(String, Vec<f64>)>,
}

impl Series {
    pub fn new(title: &str, x_label: &str, y_label: &str, x: Vec<f64>) -> Series {
        Series {
            title: title.to_string(),
            x_label: x_label.to_string(),
            y_label: y_label.to_string(),
            x,
            series: Vec::new(),
        }
    }

    pub fn push(&mut self, name: &str, ys: Vec<f64>) {
        assert_eq!(ys.len(), self.x.len(), "series length mismatch");
        self.series.push((name.to_string(), ys));
    }

    /// Render table + csv. `fmt` formats a y value.
    pub fn render(&self, fmt: impl Fn(f64) -> String) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "## {} ({} vs {})", self.title, self.y_label, self.x_label);
        let mut header: Vec<String> = vec![self.x_label.clone()];
        header.extend(self.series.iter().map(|(n, _)| n.clone()));
        let mut table = crate::bench::table::Table::new(
            &header.iter().map(|h| h.as_str()).collect::<Vec<_>>(),
        );
        for (i, &xv) in self.x.iter().enumerate() {
            let mut row = vec![crate::util::format::si(xv)];
            for (_, ys) in &self.series {
                row.push(fmt(ys[i]));
            }
            table.row(&row);
        }
        s.push_str(&table.render());
        // CSV block for downstream tooling.
        let _ = writeln!(s, "csv,{},{}", self.x_label, self.series.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>().join(","));
        for (i, &xv) in self.x.iter().enumerate() {
            let ys: Vec<String> = self.series.iter().map(|(_, ys)| format!("{:.6e}", ys[i])).collect();
            let _ = writeln!(s, "csv,{},{}", xv, ys.join(","));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_contains_points_and_csv() {
        let mut s = Series::new("fig4a", "stream_len", "ns_per_word", vec![1.0, 1024.0]);
        s.push("philox", vec![5.0, 1.2]);
        s.push("mt19937", vec![2000.0, 1.8]);
        let text = s.render(|y| format!("{y:.1}"));
        assert!(text.contains("fig4a"));
        assert!(text.contains("philox"));
        assert!(text.contains("csv,1,"));
        assert!(text.lines().filter(|l| l.starts_with("csv,")).count() == 3);
    }

    #[test]
    #[should_panic]
    fn mismatched_series_panics() {
        let mut s = Series::new("t", "x", "y", vec![1.0]);
        s.push("bad", vec![1.0, 2.0]);
    }
}
