//! Timing core: calibrated batches, robust statistics, black_box.

use std::time::{Duration, Instant};

/// Robust timing summary for one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time.
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    /// Iterations per batch sample.
    pub batch: u64,
    pub samples: usize,
    /// Optional elements processed per iteration (for throughput).
    pub elements: u64,
}

impl BenchResult {
    /// Elements per second (if `elements` set).
    pub fn throughput(&self) -> f64 {
        if self.elements == 0 {
            return 0.0;
        }
        self.elements as f64 / (self.median_ns * 1e-9)
    }

    pub fn summary(&self) -> String {
        let tput = if self.elements > 0 {
            format!("  {}/s", crate::util::format::si(self.throughput()))
        } else {
            String::new()
        };
        format!(
            "{:<38} {:>10} [{} .. {}]{}",
            self.name,
            crate::util::format::ns(self.median_ns),
            crate::util::format::ns(self.p10_ns),
            crate::util::format::ns(self.p90_ns),
            tput
        )
    }
}

/// Opaque value sink (std::hint::black_box wrapper, kept in one place so
/// future rustc changes need one edit).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Harness configuration.
#[derive(Debug, Clone, Copy)]
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub max_samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(150),
            measure: Duration::from_millis(600),
            max_samples: 60,
        }
    }
}

impl Bencher {
    /// Fast profile for CI / tests.
    pub fn quick() -> Bencher {
        Bencher {
            warmup: Duration::from_millis(20),
            measure: Duration::from_millis(80),
            max_samples: 15,
        }
    }

    /// Environment-controlled: OPENRAND_BENCH_QUICK=1 switches profiles.
    pub fn from_env() -> Bencher {
        if std::env::var("OPENRAND_BENCH_QUICK").map(|v| v == "1").unwrap_or(false) {
            Bencher::quick()
        } else {
            Bencher::default()
        }
    }

    /// Measure `f`, which performs ONE logical iteration per call.
    /// `elements` = items processed per iteration (throughput metric).
    pub fn run(&self, name: &str, elements: u64, mut f: impl FnMut()) -> BenchResult {
        // Calibrate batch size so one batch is ~1ms (amortizes timer
        // overhead) but at least 1.
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().max(Duration::from_nanos(20));
        let batch = (Duration::from_millis(1).as_nanos() / once.as_nanos()).clamp(1, 1 << 24) as u64;

        // Warmup.
        let wstart = Instant::now();
        while wstart.elapsed() < self.warmup {
            for _ in 0..batch {
                f();
            }
        }

        // Measure.
        let mut samples_ns: Vec<f64> = Vec::with_capacity(self.max_samples);
        let mstart = Instant::now();
        while mstart.elapsed() < self.measure && samples_ns.len() < self.max_samples {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples_ns.push(t.elapsed().as_nanos() as f64 / batch as f64);
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples_ns.len();
        let pct = |q: f64| samples_ns[((n as f64 - 1.0) * q).round() as usize];
        BenchResult {
            name: name.to_string(),
            median_ns: pct(0.5),
            mean_ns: samples_ns.iter().sum::<f64>() / n as f64,
            p10_ns: pct(0.1),
            p90_ns: pct(0.9),
            batch,
            samples: n,
            elements,
        }
    }
}

/// One-shot convenience with the env-selected profile.
pub fn bench_fn(name: &str, elements: u64, f: impl FnMut()) -> BenchResult {
    Bencher::from_env().run(name, elements, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let b = Bencher::quick();
        let mut acc = 0u64;
        let r = b.run("spin", 1000, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i * 31));
            }
        });
        black_box(acc);
        assert!(r.median_ns > 0.0);
        assert!(r.p10_ns <= r.median_ns && r.median_ns <= r.p90_ns);
        assert!(r.samples >= 1);
        assert!(r.throughput() > 0.0);
    }

    #[test]
    fn summary_contains_name_and_units() {
        let r = Bencher::quick().run("demo_case", 0, || {
            black_box(42u64.wrapping_mul(7));
        });
        let s = r.summary();
        assert!(s.contains("demo_case"));
        assert!(s.contains("ns") || s.contains("us"));
    }
}
