//! Property-based testing with generation + shrinking.
//!
//! Deliberately small but real: seeded reproducible case generation
//! (failures print the seed; re-running with `OPENRAND_PROP_SEED` replays
//! them), integer/tuple/vec/choice generators, and greedy shrinking
//! toward minimal counterexamples. The crate's own CBRNG (SplitMix64 —
//! *not* the engine under test) drives generation, so the framework's
//! randomness never aliases the randomness being tested.
//!
//! ```no_run
//! # // no_run: debug-profile doctest binaries fail to locate the
//! # // xla_extension libstdc++ via rpath in this container; the same
//! # // behaviour is exercised for real in this module's unit tests.
//! use openrand::testing::prop::{Gen, Prop};
//! Prop::new("addition commutes")
//!     .cases(100)
//!     .check2(Gen::u64(), Gen::u64(), |a, b| a.wrapping_add(b) == b.wrapping_add(a));
//! ```

use crate::baseline::SplitMix64;
use crate::core::traits::Rng as _;

/// A generator of test values: produce from a seed source, and shrink.
pub struct Gen<T> {
    produce: Box<dyn Fn(&mut SplitMix64) -> T>,
    shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl Gen<u64> {
    /// Full-range u64 with bias toward structure: zero, small, all-ones,
    /// single bits, and uniform.
    pub fn u64() -> Gen<u64> {
        Gen {
            produce: Box::new(|r| match r.next_u32() % 8 {
                0 => 0,
                1 => r.next_u64_native() % 16,
                2 => u64::MAX,
                3 => 1u64 << (r.next_u32() % 64),
                4 => (1u64 << (r.next_u32() % 63)) - 1,
                _ => r.next_u64_native(),
            }),
            shrink: Box::new(|&v| {
                let mut c = Vec::new();
                if v > 0 {
                    c.push(0);
                    c.push(v / 2);
                    c.push(v - 1);
                }
                c.dedup();
                c
            }),
        }
    }
}

impl Gen<u32> {
    pub fn u32() -> Gen<u32> {
        let inner = Gen::u64();
        Gen {
            produce: Box::new(move |r| (inner.produce)(r) as u32),
            shrink: Box::new(|&v| {
                let mut c = Vec::new();
                if v > 0 {
                    c.push(0);
                    c.push(v / 2);
                    c.push(v - 1);
                }
                c
            }),
        }
    }

    /// Uniform in `[0, bound)`.
    pub fn u32_below(bound: u32) -> Gen<u32> {
        assert!(bound > 0);
        Gen {
            produce: Box::new(move |r| r.range_u32(bound)),
            shrink: Box::new(|&v| if v > 0 { vec![0, v / 2, v - 1] } else { vec![] }),
        }
    }
}

impl Gen<usize> {
    pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
        assert!(lo < hi);
        Gen {
            produce: Box::new(move |r| lo + (r.next_u64_native() as usize) % (hi - lo)),
            shrink: Box::new(move |&v| {
                if v > lo {
                    vec![lo, lo + (v - lo) / 2, v - 1]
                } else {
                    vec![]
                }
            }),
        }
    }
}

impl<T: 'static> Gen<T> {
    pub fn map_into<U: 'static>(self, f: impl Fn(T) -> U + Clone + 'static) -> Gen<U> {
        let f2 = f.clone();
        Gen {
            produce: Box::new(move |r| f((self.produce)(r))),
            // Mapping loses shrink structure; shrink via nothing.
            shrink: Box::new(move |_| {
                let _ = &f2;
                Vec::new()
            }),
        }
    }
}

/// Property runner.
pub struct Prop {
    name: &'static str,
    cases: usize,
    seed: u64,
}

impl Prop {
    pub fn new(name: &'static str) -> Prop {
        let seed = std::env::var("OPENRAND_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(DEFAULT_PROP_SEED);
        Prop { name, cases: 200, seed }
    }

    pub fn cases(mut self, n: usize) -> Prop {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Prop {
        self.seed = s;
        self
    }

    /// Check a 1-argument property; panics with a shrunk counterexample.
    pub fn check1<A: Clone + std::fmt::Debug + 'static>(
        self,
        ga: Gen<A>,
        prop: impl Fn(A) -> bool,
    ) {
        let mut src = SplitMix64::new(self.seed);
        for case in 0..self.cases {
            let a = (ga.produce)(&mut src);
            if !prop(a.clone()) {
                let min = shrink1(&ga, a, &prop);
                panic!(
                    "property '{}' failed (case {case}, seed {:#x}):\n  counterexample: {min:?}",
                    self.name, self.seed
                );
            }
        }
    }

    /// Check a 2-argument property.
    pub fn check2<A, B>(self, ga: Gen<A>, gb: Gen<B>, prop: impl Fn(A, B) -> bool)
    where
        A: Clone + std::fmt::Debug + 'static,
        B: Clone + std::fmt::Debug + 'static,
    {
        let mut src = SplitMix64::new(self.seed);
        for case in 0..self.cases {
            let a = (ga.produce)(&mut src);
            let b = (gb.produce)(&mut src);
            if !prop(a.clone(), b.clone()) {
                let (ma, mb) = shrink2(&ga, &gb, a, b, &prop);
                panic!(
                    "property '{}' failed (case {case}, seed {:#x}):\n  counterexample: ({ma:?}, {mb:?})",
                    self.name, self.seed
                );
            }
        }
    }

    /// Check a 3-argument property.
    pub fn check3<A, B, C>(
        self,
        ga: Gen<A>,
        gb: Gen<B>,
        gc: Gen<C>,
        prop: impl Fn(A, B, C) -> bool,
    ) where
        A: Clone + std::fmt::Debug + 'static,
        B: Clone + std::fmt::Debug + 'static,
        C: Clone + std::fmt::Debug + 'static,
    {
        let mut src = SplitMix64::new(self.seed);
        for case in 0..self.cases {
            let a = (ga.produce)(&mut src);
            let b = (gb.produce)(&mut src);
            let c = (gc.produce)(&mut src);
            if !prop(a.clone(), b.clone(), c.clone()) {
                panic!(
                    "property '{}' failed (case {case}, seed {:#x}):\n  counterexample: ({a:?}, {b:?}, {c:?})",
                    self.name, self.seed
                );
            }
        }
    }
}

/// Fixed default seed: property failures are reproducible run-to-run.
const DEFAULT_PROP_SEED: u64 = 0x09E2_0D15_C0DE_5EED;

fn shrink1<A: Clone>(ga: &Gen<A>, mut cur: A, prop: &impl Fn(A) -> bool) -> A {
    // Greedy descent: keep taking the first shrink candidate that still
    // fails, until none do (bounded to avoid pathological loops).
    for _ in 0..1000 {
        let mut advanced = false;
        for cand in (ga.shrink)(&cur) {
            if !prop(cand.clone()) {
                cur = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    cur
}

fn shrink2<A: Clone, B: Clone>(
    ga: &Gen<A>,
    gb: &Gen<B>,
    mut a: A,
    mut b: B,
    prop: &impl Fn(A, B) -> bool,
) -> (A, B) {
    for _ in 0..1000 {
        let mut advanced = false;
        for ca in (ga.shrink)(&a) {
            if !prop(ca.clone(), b.clone()) {
                a = ca;
                advanced = true;
                break;
            }
        }
        for cb in (gb.shrink)(&b) {
            if !prop(a.clone(), cb.clone()) {
                b = cb;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    (a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        Prop::new("xor involution").cases(300).check2(Gen::u64(), Gen::u64(), |a, b| (a ^ b) ^ b == a);
    }

    #[test]
    fn failing_property_shrinks_to_minimum() {
        let caught = std::panic::catch_unwind(|| {
            Prop::new("all u64 < 100 (false)").check1(Gen::u64(), |a| a < 100);
        });
        let msg = *caught.unwrap_err().downcast::<String>().unwrap();
        // Greedy shrink must land exactly on the boundary: 100.
        assert!(msg.contains("counterexample: 100"), "{msg}");
    }

    #[test]
    fn deterministic_given_seed() {
        // Same seed -> same panic message (reproducibility of the harness
        // itself).
        let run = || {
            std::panic::catch_unwind(|| {
                Prop::new("always false").seed(42).cases(5).check1(Gen::u32(), |_| false);
            })
        };
        let m1 = *run().unwrap_err().downcast::<String>().unwrap();
        let m2 = *run().unwrap_err().downcast::<String>().unwrap();
        assert_eq!(m1, m2);
    }

    #[test]
    fn u32_below_respects_bound() {
        Prop::new("bounded").cases(500).check1(Gen::u32_below(17), |v| v < 17);
    }
}
