//! In-house property-based testing mini-framework (proptest substitute —
//! the offline environment has no proptest/quickcheck).

pub mod prop;

pub use prop::{Gen, Prop};
