//! Per-thread PJRT client.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`/`Sync`), so
//! the client — and everything compiled from it — is **thread-confined**.
//! The coordinator's design already matches this: the device path runs
//! its step loop on the driver thread while host parallelism happens in
//! the Rust kernels, so one lazily-created client per driver thread is
//! exactly what's needed. Clients are cheap to clone (`Rc` handle) but
//! expensive to create; `device_client()` creates at most one per thread.

use anyhow::Result;
use std::cell::RefCell;
use xla::PjRtClient;

thread_local! {
    static CLIENT: RefCell<Option<PjRtClient>> = const { RefCell::new(None) };
}

/// The calling thread's PJRT CPU client (stands in for the paper's
/// V100/A100 device — see DESIGN.md substitutions).
pub fn device_client() -> Result<PjRtClient> {
    CLIENT.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            let client =
                PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
            *slot = Some(client);
        }
        Ok(slot.as_ref().expect("client initialized").clone())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reused_within_thread() {
        let a = device_client().unwrap();
        let b = device_client().unwrap();
        assert!(a.device_count() >= 1);
        assert_eq!(a.platform_name(), "cpu");
        assert_eq!(b.platform_name(), "cpu");
    }
}
