//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once by `python/compile/aot.py`) and execute them from the Rust hot
//! path. Python never runs here.
//!
//! The interchange format is HLO **text** — xla_extension 0.5.1 rejects
//! serialized protos from jax >= 0.5 (64-bit instruction ids); the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod artifact;
pub mod client;
pub mod exec;

pub use artifact::{ArtifactStore, Manifest, ManifestEntry};
pub use client::device_client;
pub use exec::DeviceGraph;
