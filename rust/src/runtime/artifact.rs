//! Artifact discovery and compilation cache.
//!
//! `aot.py` writes a line-oriented manifest next to the HLO files:
//!
//! ```text
//! name|file|in=uint32[4];float64[16384,4]|out=float64[16384,4]
//! ```
//!
//! parsed here without any JSON dependency. [`ArtifactStore`] resolves
//! names to compiled executables, compiling each HLO at most once.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use super::client::device_client;

/// One tensor signature: dtype + shape.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSig {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSig {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(s: &str) -> Result<TensorSig> {
        // "float64[16384,4]" or "uint32[4]" or scalar "uint32[]".
        let (dtype, rest) = s
            .split_once('[')
            .ok_or_else(|| anyhow!("bad tensor sig '{s}'"))?;
        let dims = rest
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("bad tensor sig '{s}'"))?;
        let shape = if dims.is_empty() {
            Vec::new()
        } else {
            dims.split(',')
                .map(|d| d.trim().parse::<usize>().context("bad dim"))
                .collect::<Result<_>>()?
        };
        Ok(TensorSig { dtype: dtype.to_string(), shape })
    }
}

/// One artifact entry from the manifest.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
    /// Whether the graph returns a tuple (multi-output) or a bare array
    /// (single-output, buffer-chainable via execute_b).
    pub tuple: bool,
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split('|').collect();
            if parts.len() != 4 && parts.len() != 5 {
                bail!("manifest line {}: expected 4-5 fields, got {}", lineno + 1, parts.len());
            }
            let sigs = |field: &str, prefix: &str| -> Result<Vec<TensorSig>> {
                let body = field
                    .strip_prefix(prefix)
                    .ok_or_else(|| anyhow!("manifest line {}: missing {prefix}", lineno + 1))?;
                if body.is_empty() {
                    return Ok(Vec::new());
                }
                body.split(';').map(TensorSig::parse).collect()
            };
            // Older manifests lack the tuple field; default to tuple=1
            // (the conservative wrapper).
            let tuple = parts.get(4).map(|t| *t != "tuple=0").unwrap_or(true);
            entries.push(ManifestEntry {
                name: parts[0].to_string(),
                file: parts[1].to_string(),
                inputs: sigs(parts[2], "in=")?,
                outputs: sigs(parts[3], "out=")?,
                tuple,
            });
        }
        Ok(Manifest { entries })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Manifest::parse(&text)
    }

    pub fn get(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.name == name)
    }
}

/// Default artifact directory: $OPENRAND_ARTIFACTS or ./artifacts
/// (searched upward so tests work from target dirs).
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("OPENRAND_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.txt").exists() {
            return cand;
        }
        if !cur.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

/// Name → compiled executable store with a compile-once cache.
pub struct ArtifactStore {
    dir: PathBuf,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl ArtifactStore {
    pub fn open(dir: PathBuf) -> Result<ArtifactStore> {
        let manifest = Manifest::load(&dir)?;
        Ok(ArtifactStore { dir, manifest, cache: Mutex::new(HashMap::new()) })
    }

    pub fn open_default() -> Result<ArtifactStore> {
        Self::open(default_artifact_dir())
    }

    /// Compile (or fetch cached) the named graph.
    pub fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let entry = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest ({:?})", self.dir))?;
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = device_client()?
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_sig_parse() {
        let t = TensorSig::parse("float64[16384,4]").unwrap();
        assert_eq!(t.dtype, "float64");
        assert_eq!(t.shape, vec![16384, 4]);
        assert_eq!(t.elements(), 65536);
        let s = TensorSig::parse("uint32[]").unwrap();
        assert_eq!(s.shape, Vec::<usize>::new());
        assert!(TensorSig::parse("garbage").is_err());
    }

    #[test]
    fn manifest_parse_roundtrip() {
        let m = Manifest::parse(
            "a|a.hlo.txt|in=uint32[4]|out=uint32[65536]|tuple=0\n\
             # comment\n\
             b|b.hlo.txt|in=float64[8,4];uint32[4]|out=float64[8,4]\n",
        )
        .unwrap();
        assert_eq!(m.entries.len(), 2);
        assert_eq!(m.get("b").unwrap().inputs.len(), 2);
        assert!(!m.get("a").unwrap().tuple);
        assert!(m.get("b").unwrap().tuple); // legacy default
        assert!(m.get("zzz").is_none());
    }

    #[test]
    fn manifest_rejects_malformed() {
        assert!(Manifest::parse("too|few|fields").is_err());
        assert!(Manifest::parse("x|f|inputs=a[1]|out=b[1]").is_err());
    }
}
