//! Typed execution of a compiled device graph.
//!
//! [`DeviceGraph`] wraps an executable with its manifest signature and
//! marshals Rust slices ↔ XLA literals. All graphs are lowered with
//! `return_tuple=True`, so outputs always arrive as a tuple literal.

use anyhow::{anyhow, bail, Result};
use std::sync::Arc;

use super::artifact::{ArtifactStore, ManifestEntry, TensorSig};

/// Input argument for a device call.
pub enum Arg<'a> {
    U32(&'a [u32]),
    F64(&'a [f64]),
}

impl Arg<'_> {
    fn len(&self) -> usize {
        match self {
            Arg::U32(s) => s.len(),
            Arg::F64(s) => s.len(),
        }
    }

    fn dtype(&self) -> &'static str {
        match self {
            Arg::U32(_) => "uint32",
            Arg::F64(_) => "float64",
        }
    }

    fn to_literal(&self, sig: &TensorSig) -> Result<xla::Literal> {
        let lit = match self {
            Arg::U32(s) => xla::Literal::vec1(s),
            Arg::F64(s) => xla::Literal::vec1(s),
        };
        if sig.shape.len() <= 1 {
            Ok(lit)
        } else {
            let dims: Vec<i64> = sig.shape.iter().map(|&d| d as i64).collect();
            lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e}"))
        }
    }
}

/// Output tensor from a device call.
#[derive(Debug, Clone)]
pub enum Out {
    U32(Vec<u32>),
    F64(Vec<f64>),
}

impl Out {
    pub fn as_u32(&self) -> &[u32] {
        match self {
            Out::U32(v) => v,
            _ => panic!("expected u32 output"),
        }
    }

    pub fn as_f64(&self) -> &[f64] {
        match self {
            Out::F64(v) => v,
            _ => panic!("expected f64 output"),
        }
    }
}

/// A compiled graph plus its signature.
pub struct DeviceGraph {
    pub entry: ManifestEntry,
    exe: Arc<xla::PjRtLoadedExecutable>,
}

impl DeviceGraph {
    pub fn load(store: &ArtifactStore, name: &str) -> Result<DeviceGraph> {
        let entry = store
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown graph '{name}'"))?
            .clone();
        let exe = store.executable(name)?;
        Ok(DeviceGraph { entry, exe })
    }

    /// Execute with signature checking; returns all outputs.
    pub fn call(&self, args: &[Arg]) -> Result<Vec<Out>> {
        if args.len() != self.entry.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.entry.name,
                self.entry.inputs.len(),
                args.len()
            );
        }
        let mut lits = Vec::with_capacity(args.len());
        for (i, (arg, sig)) in args.iter().zip(self.entry.inputs.iter()).enumerate() {
            if arg.len() != sig.elements() {
                bail!(
                    "{} input {i}: expected {} elements ({:?}), got {}",
                    self.entry.name,
                    sig.elements(),
                    sig.shape,
                    arg.len()
                );
            }
            if arg.dtype() != sig.dtype {
                bail!(
                    "{} input {i}: expected dtype {}, got {}",
                    self.entry.name,
                    sig.dtype,
                    arg.dtype()
                );
            }
            lits.push(arg.to_literal(sig)?);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("{}: execute: {e}", self.entry.name))?;
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: to_literal: {e}", self.entry.name))?;
        // Single-output graphs are lowered without the tuple wrapper
        // (buffer-chainable); multi-output graphs keep it.
        let parts = if self.entry.tuple {
            root.to_tuple().map_err(|e| anyhow!("{}: untuple: {e}", self.entry.name))?
        } else {
            vec![root]
        };
        if parts.len() != self.entry.outputs.len() {
            bail!(
                "{}: manifest says {} outputs, device returned {}",
                self.entry.name,
                self.entry.outputs.len(),
                parts.len()
            );
        }
        let mut outs = Vec::with_capacity(parts.len());
        for (lit, sig) in parts.into_iter().zip(self.entry.outputs.iter()) {
            let out = match sig.dtype.as_str() {
                "uint32" => Out::U32(lit.to_vec::<u32>().map_err(|e| anyhow!("to_vec u32: {e}"))?),
                "float64" => Out::F64(lit.to_vec::<f64>().map_err(|e| anyhow!("to_vec f64: {e}"))?),
                other => bail!("{}: unsupported output dtype {other}", self.entry.name),
            };
            outs.push(out);
        }
        Ok(outs)
    }

    /// Whether this graph's output can be chained as a device buffer
    /// (single-output, lowered without the tuple wrapper).
    pub fn chainable(&self) -> bool {
        !self.entry.tuple && self.entry.outputs.len() == 1
    }

    /// Execute with device-resident buffers (no host round-trip). The
    /// §Perf device path: feed the previous step's output buffer back as
    /// the next step's input. Caller is responsible for buffer/signature
    /// agreement (the compiled executable still validates shapes).
    pub fn call_b(&self, args: &[&xla::PjRtBuffer]) -> Result<xla::PjRtBuffer> {
        if self.entry.tuple {
            bail!("{}: tuple-output graph is not buffer-chainable", self.entry.name);
        }
        let mut result = self
            .exe
            .execute_b(args)
            .map_err(|e| anyhow!("{}: execute_b: {e}", self.entry.name))?;
        Ok(result.remove(0).remove(0))
    }

    /// Upload a host slice as a device buffer shaped like input `idx`
    /// (input staging for call_b).
    pub fn buffer_from_f64(&self, data: &[f64], idx: usize) -> Result<xla::PjRtBuffer> {
        let client = super::client::device_client()?;
        client
            .buffer_from_host_buffer(data, &self.entry.inputs[idx].shape, None)
            .map_err(|e| anyhow!("buffer_from_host f64: {e}"))
    }

    /// Upload a u32 slice as a device buffer shaped like input `idx`.
    pub fn buffer_from_u32(&self, data: &[u32], idx: usize) -> Result<xla::PjRtBuffer> {
        let client = super::client::device_client()?;
        client
            .buffer_from_host_buffer(data, &self.entry.inputs[idx].shape, None)
            .map_err(|e| anyhow!("buffer_from_host u32: {e}"))
    }

    /// Download a device buffer to host f64s.
    pub fn buffer_to_f64(&self, buf: &xla::PjRtBuffer) -> Result<Vec<f64>> {
        let lit = buf.to_literal_sync().map_err(|e| anyhow!("to_literal: {e}"))?;
        lit.to_vec::<f64>().map_err(|e| anyhow!("to_vec f64: {e}"))
    }

    /// Download a device buffer to host u32s (the block-artifact word
    /// path used by `backend::DeviceFill`).
    pub fn buffer_to_u32(&self, buf: &xla::PjRtBuffer) -> Result<Vec<u32>> {
        let lit = buf.to_literal_sync().map_err(|e| anyhow!("to_literal: {e}"))?;
        lit.to_vec::<u32>().map_err(|e| anyhow!("to_vec u32: {e}"))
    }

    /// Convenience: single-output u32 graph.
    pub fn call_u32(&self, args: &[Arg]) -> Result<Vec<u32>> {
        match self.call(args)?.remove(0) {
            Out::U32(v) => Ok(v),
            Out::F64(_) => bail!("{}: expected u32 output", self.entry.name),
        }
    }

    /// Convenience: single-output f64 graph.
    pub fn call_f64(&self, args: &[Arg]) -> Result<Vec<f64>> {
        match self.call(args)?.remove(0) {
            Out::F64(v) => Ok(v),
            Out::U32(_) => bail!("{}: expected f64 output", self.entry.name),
        }
    }
}

// Integration tests against real artifacts live in rust/tests/; unit
// tests here only cover pure marshalling logic.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arg_metadata() {
        let xs = [1u32, 2, 3];
        let a = Arg::U32(&xs);
        assert_eq!(a.len(), 3);
        assert_eq!(a.dtype(), "uint32");
        let ys = [1.0f64];
        assert_eq!(Arg::F64(&ys).dtype(), "float64");
    }

    #[test]
    fn out_accessors() {
        assert_eq!(Out::U32(vec![5]).as_u32(), &[5]);
        assert_eq!(Out::F64(vec![2.5]).as_f64(), &[2.5]);
    }

    #[test]
    #[should_panic]
    fn out_type_mismatch_panics() {
        let _ = Out::U32(vec![5]).as_f64();
    }
}
