//! Deterministic work partitioning.
//!
//! `partition_ranges(n, k)` divides `[0, n)` into `k` contiguous ranges
//! whose boundaries depend only on `(n, k)` — never on runtime timing —
//! and that differ in length by at most 1. Combined with id-derived
//! streams this is what makes "same result on 1 or 64 threads" hold.

use std::ops::Range;

/// Split `[0, n)` into `k` near-equal contiguous ranges (first `n % k`
/// ranges get the extra element). Empty ranges are produced when k > n.
pub fn partition_ranges(n: usize, k: usize) -> Vec<Range<usize>> {
    assert!(k > 0, "k must be positive");
    let base = n / k;
    let extra = n % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for i in 0..k {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{Gen, Prop};

    #[test]
    fn covers_disjoint_ordered() {
        // Property: for any (n, k), the ranges exactly tile [0, n).
        Prop::new("partition tiles [0,n)").cases(300).check2(
            Gen::usize_in(0, 10_000),
            Gen::usize_in(1, 130),
            |n, k| {
                let ranges = partition_ranges(n, k);
                if ranges.len() != k {
                    return false;
                }
                let mut cursor = 0;
                for r in &ranges {
                    if r.start != cursor || r.end < r.start {
                        return false;
                    }
                    cursor = r.end;
                }
                cursor == n
            },
        );
    }

    #[test]
    fn balanced_within_one() {
        Prop::new("partition balanced").cases(300).check2(
            Gen::usize_in(0, 10_000),
            Gen::usize_in(1, 130),
            |n, k| {
                let lens: Vec<usize> = partition_ranges(n, k).iter().map(|r| r.len()).collect();
                let mx = *lens.iter().max().unwrap();
                let mn = *lens.iter().min().unwrap();
                mx - mn <= 1
            },
        );
    }

    #[test]
    fn deterministic() {
        assert_eq!(partition_ranges(1000, 7), partition_ranges(1000, 7));
    }

    #[test]
    fn exact_small_case() {
        assert_eq!(partition_ranges(10, 3), vec![0..4, 4..7, 7..10]);
        assert_eq!(partition_ranges(2, 4), vec![0..1, 1..2, 2..2, 2..2]);
    }
}
