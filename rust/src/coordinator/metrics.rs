//! Run metrics: wall time, throughput, memory — what the benches and the
//! CLI report (and what EXPERIMENTS.md records).

use std::time::{Duration, Instant};

/// Accumulated metrics for a simulation run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub steps: u64,
    pub particles: u64,
    pub wall: Duration,
    /// Time spent inside step kernels (host) or device calls.
    pub kernel: Duration,
    /// Extra bytes allocated for RNG state (0 for counter-based styles).
    pub rng_state_bytes: usize,
}

impl RunMetrics {
    /// Particle-steps per second — the Fig. 4b figure of merit.
    pub fn throughput(&self) -> f64 {
        let ps = self.steps as f64 * self.particles as f64;
        ps / self.wall.as_secs_f64().max(1e-12)
    }

    /// Random numbers per second (2 doubles = 4 words per particle-step).
    pub fn draws_per_sec(&self) -> f64 {
        self.throughput() * 4.0
    }

    pub fn summary(&self) -> String {
        format!(
            "steps={} particles={} wall={:.3}s kernel={:.3}s throughput={}/s rng_state={}",
            self.steps,
            self.particles,
            self.wall.as_secs_f64(),
            self.kernel.as_secs_f64(),
            crate::util::format::si(self.throughput()),
            crate::util::format::bytes(self.rng_state_bytes),
        )
    }
}

/// Simple scope timer.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Timer {
        Timer(Instant::now())
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let m = RunMetrics {
            steps: 10,
            particles: 1000,
            wall: Duration::from_secs(2),
            ..Default::default()
        };
        assert!((m.throughput() - 5_000.0).abs() < 1e-9);
        assert!((m.draws_per_sec() - 20_000.0).abs() < 1e-9);
        assert!(m.summary().contains("particles=1000"));
    }

    #[test]
    fn zero_wall_is_safe() {
        let m = RunMetrics::default();
        assert!(m.throughput().is_finite());
    }
}
