//! Simulation driver: orchestrates the Brownian benchmark over either
//! execution backend with identical semantics.
//!
//! * [`Backend::Host`] — multithreaded Rust: the coordinator partitions
//!   the particle range deterministically and steps each stripe on the
//!   scoped pool. Bitwise identical for any thread count.
//! * [`Backend::Device`] — PJRT: the whole step is one AOT-compiled XLA
//!   call (`brownian_step_<N>` lowered from the Pallas/JAX stack); the
//!   coordinator owns the step loop, the counter (= step index) and the
//!   buffers. This is the paper's GPU path with the CPU PJRT client
//!   standing in for the V100/A100.
//!
//! Both paths draw from the same (seed = pid ^ global, ctr = step)
//! streams, so RNG words agree bitwise across backends; trajectories
//! agree to float associativity (pinned by rust/tests/cross_layer.rs).

use anyhow::{bail, Result};

use super::metrics::{RunMetrics, Timer};
use super::pool::ThreadPool;
use crate::runtime::exec::{Arg, DeviceGraph};
use crate::runtime::ArtifactStore;
use crate::sim::brownian::{BrownianParams, BrownianSim, RngStyle};

/// Execution backend for a simulation run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Multithreaded Rust host path.
    Host { threads: usize },
    /// AOT device path via PJRT.
    Device,
}

/// Drives a [`BrownianSim`] to completion on a chosen backend.
pub struct SimDriver {
    pub backend: Backend,
}

impl SimDriver {
    pub fn new(backend: Backend) -> SimDriver {
        SimDriver { backend }
    }

    /// Run the simulation described by `params`; returns the final system
    /// and metrics.
    pub fn run(&self, params: BrownianParams) -> Result<(BrownianSim, RunMetrics)> {
        match self.backend {
            Backend::Host { threads } => self.run_host(params, threads),
            Backend::Device => self.run_device(params),
        }
    }

    fn run_host(&self, params: BrownianParams, threads: usize) -> Result<(BrownianSim, RunMetrics)> {
        let pool = ThreadPool::new(threads);
        let mut sim = BrownianSim::new(params);
        let n = params.n_particles;
        let wall = Timer::start();
        let mut kernel = std::time::Duration::ZERO;
        for _ in 0..params.steps {
            let t = Timer::start();
            if threads == 1 {
                sim.step_all();
            } else {
                step_parallel(&mut sim, &pool, n);
            }
            kernel += t.elapsed();
        }
        let metrics = RunMetrics {
            steps: params.steps as u64,
            particles: n as u64,
            wall: wall.elapsed(),
            kernel,
            rng_state_bytes: sim.rng_state_bytes(),
        };
        Ok((sim, metrics))
    }

    fn run_device(&self, params: BrownianParams) -> Result<(BrownianSim, RunMetrics)> {
        let store = ArtifactStore::open_default()?;
        let n = params.n_particles;
        let (step_graph, init_needed) = match params.style {
            RngStyle::OpenRand => (format!("brownian_step_{n}"), false),
            RngStyle::CurandStyle => (format!("brownian_step_stateful_{n}"), true),
            RngStyle::Raw123 => bail!(
                "device path has no separate raw123 variant (identical streams to openrand)"
            ),
        };
        let mut sim = BrownianSim::new(BrownianParams {
            // Host-side state array not used on device; build without it.
            style: RngStyle::OpenRand,
            ..params
        });
        let wall = Timer::start();
        let mut kernel = std::time::Duration::ZERO;
        let (lo, hi) = ((params.global_seed & 0xFFFF_FFFF) as u32, (params.global_seed >> 32) as u32);
        let mut rng_state_bytes = 0;
        // §Perf device path: the particle tensor lives on the device for
        // the whole run (execute_b buffer chaining); only the 16 B params
        // block is uploaded per step, and rows come back once at the end.
        let rows;
        if init_needed {
            // Split stateful graphs (both single-output => chainable):
            // positions half + the 64 B/particle state store-back half.
            let pos_graph = DeviceGraph::load(&store, &format!("brownian_step_stateful_pos_{n}"))?;
            let upd_graph = DeviceGraph::load(&store, &format!("curand_state_update_{n}"))?;
            let init = DeviceGraph::load(&store, &format!("curand_state_init_{n}"))?;
            if !pos_graph.chainable() || !upd_graph.chainable() {
                bail!("stateful split graphs must be chainable — re-run `make artifacts`");
            }
            let t = Timer::start();
            let state_host = init.call_u32(&[Arg::U32(&[lo, hi, 0, 0])])?;
            rng_state_bytes = state_host.len() * 4;
            // State buffer shaped per the update graph's input signature.
            let mut state_buf = upd_graph.buffer_from_u32(&state_host, 0)?;
            let mut rows_buf = pos_graph.buffer_from_f64(&sim.to_rows(), 0)?;
            kernel += t.elapsed();
            for _ in 0..params.steps {
                let t = Timer::start();
                let new_rows = pos_graph.call_b(&[&rows_buf, &state_buf])?;
                let new_state = upd_graph.call_b(&[&state_buf])?;
                rows_buf = new_rows;
                state_buf = new_state;
                kernel += t.elapsed();
            }
            rows = pos_graph.buffer_to_f64(&rows_buf)?;
        } else {
            let graph = DeviceGraph::load(&store, &step_graph)?;
            if !graph.chainable() {
                bail!("brownian_step must be chainable — re-run `make artifacts`");
            }
            let mut rows_buf = graph.buffer_from_f64(&sim.to_rows(), 0)?;
            for step in 0..params.steps {
                let params4 = [lo, hi, step, 0];
                let t = Timer::start();
                let params_buf = graph.buffer_from_u32(&params4, 1)?;
                rows_buf = graph.call_b(&[&rows_buf, &params_buf])?;
                kernel += t.elapsed();
            }
            rows = graph.buffer_to_f64(&rows_buf)?;
        }
        sim.from_rows(&rows);
        sim.step = params.steps;
        let metrics = RunMetrics {
            steps: params.steps as u64,
            particles: n as u64,
            wall: wall.elapsed(),
            kernel,
            rng_state_bytes,
        };
        Ok((sim, metrics))
    }
}

/// One parallel step: deterministic stripes via raw-pointer range split
/// (each worker touches a disjoint pid range of every field array).
fn step_parallel(sim: &mut BrownianSim, pool: &ThreadPool, n: usize) {
    // SAFETY-free formulation: temporarily move the field vectors into
    // stripes using split_at_mut chains through the pool's run_chunks on
    // an index array would obscure the physics; instead we use the
    // documented invariant that step_range(lo, hi) only touches indices
    // in [lo, hi) of each field. We split all four field slices into the
    // same deterministic ranges and reassemble a view-struct per worker.
    let ranges = super::partition::partition_ranges(n, pool.threads);
    let step = sim.step;
    let seed = sim.params.global_seed;
    let style = sim.params.style;
    let sqrt_dt = crate::sim::brownian::DT.sqrt();
    let drag_g = crate::sim::brownian::GAMMA / crate::sim::brownian::MASS;
    let dt = crate::sim::brownian::DT;

    // Split every field into per-range stripes. (The explicit 6-tuple of
    // stripe views is deliberate: one row per field keeps the disjoint-
    // range invariant visible at the split site.)
    #[allow(clippy::type_complexity)]
    let mut stripes: Vec<(
        &mut [f64],
        &mut [f64],
        &mut [f64],
        &mut [f64],
        &mut [crate::baseline::stateful_philox::CurandPhiloxState],
        usize,
    )> = Vec::with_capacity(ranges.len());
    {
        let mut x = sim.x.as_mut_slice();
        let mut y = sim.y.as_mut_slice();
        let mut vx = sim.vx.as_mut_slice();
        let mut vy = sim.vy.as_mut_slice();
        let mut st = sim.states.as_mut_slice();
        let mut offset = 0usize;
        for r in &ranges {
            let len = r.len();
            let (xh, xt) = x.split_at_mut(len);
            let (yh, yt) = y.split_at_mut(len);
            let (vxh, vxt) = vx.split_at_mut(len);
            let (vyh, vyt) = vy.split_at_mut(len);
            let (sth, stt) = if st.is_empty() {
                (&mut [][..], st)
            } else {
                st.split_at_mut(len)
            };
            stripes.push((xh, yh, vxh, vyh, sth, offset));
            x = xt;
            y = yt;
            vx = vxt;
            vy = vyt;
            st = stt;
            offset += len;
        }
    }

    std::thread::scope(|scope| {
        for (x, y, vx, vy, st, offset) in stripes {
            scope.spawn(move || {
                use crate::baseline::raw123;
                use crate::baseline::stateful_philox::StatefulPhilox;
                use crate::core::philox::philox4x32;
                use crate::core::{CounterRng, Philox, Rng};
                for j in 0..x.len() {
                    let pid = offset + j;
                    let (r1, r2) = match style {
                        RngStyle::OpenRand => {
                            let mut rng = Philox::new(pid as u64 ^ seed, step);
                            rng.draw_double2()
                        }
                        RngStyle::CurandStyle => {
                            let mut rng = StatefulPhilox::load(st, j);
                            let d = rng.draw_double2();
                            rng.store(st, j);
                            d
                        }
                        RngStyle::Raw123 => {
                            let pid_seed = pid as u64 ^ seed;
                            let block = philox4x32(
                                [0, step, 0, 0],
                                [pid_seed as u32, (pid_seed >> 32) as u32],
                            );
                            let xu = ((block[0] as u64) << 32) | block[1] as u64;
                            let yu = ((block[2] as u64) << 32) | block[3] as u64;
                            (raw123::u01_u64(xu), raw123::u01_u64(yu))
                        }
                    };
                    // Same expression order as BrownianSim::kick.
                    let mut v_x = vx[j];
                    let mut v_y = vy[j];
                    v_x = v_x - drag_g * v_x * dt;
                    v_y = v_y - drag_g * v_y * dt;
                    v_x += (r1 * 2.0 - 1.0) * sqrt_dt;
                    v_y += (r2 * 2.0 - 1.0) * sqrt_dt;
                    x[j] += v_x * dt;
                    y[j] += v_y * dt;
                    vx[j] = v_x;
                    vy[j] = v_y;
                }
            });
        }
    });
    sim.step += 1;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(n: usize, steps: u32) -> BrownianParams {
        BrownianParams { n_particles: n, steps, global_seed: 11, style: RngStyle::OpenRand }
    }

    #[test]
    fn host_thread_count_invariance() {
        // THE reproducibility claim: bitwise-identical trajectories on
        // 1, 2, 3, 8 threads.
        let h1 = {
            let (sim, _) = SimDriver::new(Backend::Host { threads: 1 })
                .run(params(2048, 10))
                .unwrap();
            sim.state_hash()
        };
        for t in [2, 3, 8] {
            let (sim, _) = SimDriver::new(Backend::Host { threads: t })
                .run(params(2048, 10))
                .unwrap();
            assert_eq!(sim.state_hash(), h1, "threads={t}");
        }
    }

    #[test]
    fn host_styles_all_run_parallel() {
        for style in RngStyle::ALL {
            let p = BrownianParams {
                n_particles: 512,
                steps: 5,
                global_seed: 0,
                style,
            };
            let (sim, m) = SimDriver::new(Backend::Host { threads: 4 }).run(p).unwrap();
            assert_eq!(sim.step, 5, "{style:?}");
            assert!(m.throughput() > 0.0);
            // Parallel result == sequential result per style.
            let (seq, _) = SimDriver::new(Backend::Host { threads: 1 }).run(p).unwrap();
            assert_eq!(sim.state_hash(), seq.state_hash(), "{style:?}");
        }
    }

    #[test]
    fn metrics_account_steps() {
        let (_, m) = SimDriver::new(Backend::Host { threads: 2 })
            .run(params(256, 7))
            .unwrap();
        assert_eq!(m.steps, 7);
        assert_eq!(m.particles, 256);
        assert!(m.kernel <= m.wall + std::time::Duration::from_millis(5));
    }
}
