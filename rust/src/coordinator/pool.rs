//! Scoped worker pool on std::thread (no rayon in this environment).
//!
//! `ThreadPool::run_partitioned` maps a closure over deterministic
//! partitions of an index space. Work assignment is static (partition i →
//! worker i); there is no stealing, because stealing introduces
//! scheduling-dependent execution orders that make performance runs
//! noisy — and the whole point of the library is that *correctness*
//! never depends on scheduling anyway.

use super::partition::partition_ranges;
use std::ops::Range;

/// A lightweight fork-join pool: threads are spawned per call via
/// `std::thread::scope` (spawn cost ≈ µs, negligible against the ≥ ms
/// step granularity the coordinator dispatches; measured in the perf
/// pass).
#[derive(Debug, Clone, Copy)]
pub struct ThreadPool {
    pub threads: usize,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        ThreadPool { threads }
    }

    /// Pool sized to the machine.
    pub fn default_parallel() -> Self {
        let t = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        ThreadPool { threads: t }
    }

    /// Apply `f` to `k = threads` deterministic ranges of `[0, n)` in
    /// parallel and collect the results in partition order (not
    /// completion order — ordering is part of reproducibility).
    pub fn run_partitioned<T: Send>(
        &self,
        n: usize,
        f: impl Fn(usize, Range<usize>) -> T + Sync,
    ) -> Vec<T> {
        let ranges = partition_ranges(n, self.threads);
        if self.threads == 1 {
            return ranges.into_iter().enumerate().map(|(i, r)| f(i, r)).collect();
        }
        let mut slots: Vec<Option<T>> = Vec::with_capacity(ranges.len());
        slots.resize_with(ranges.len(), || None);
        std::thread::scope(|scope| {
            let f = &f;
            let mut handles = Vec::with_capacity(ranges.len());
            for (i, (range, slot)) in ranges.into_iter().zip(slots.iter_mut()).enumerate() {
                handles.push(scope.spawn(move || {
                    *slot = Some(f(i, range));
                }));
            }
            for h in handles {
                h.join().expect("worker panicked");
            }
        });
        slots.into_iter().map(|s| s.expect("worker filled slot")).collect()
    }

    /// Map over mutable disjoint chunks of a slice, one per worker, with
    /// per-chunk results. Used for particle arrays: each worker owns its
    /// contiguous stripe.
    pub fn run_chunks<T: Send, E: Send>(
        &self,
        data: &mut [E],
        f: impl Fn(usize, usize, &mut [E]) -> T + Sync,
    ) -> Vec<T> {
        let n = data.len();
        let ranges = partition_ranges(n, self.threads);
        let mut pieces: Vec<(usize, usize, &mut [E])> = Vec::with_capacity(ranges.len());
        let mut rest = data;
        let mut offset = 0usize;
        for (i, r) in ranges.iter().enumerate() {
            let (head, tail) = rest.split_at_mut(r.len());
            pieces.push((i, offset, head));
            offset += r.len();
            rest = tail;
        }
        if self.threads == 1 {
            return pieces.into_iter().map(|(i, off, chunk)| f(i, off, chunk)).collect();
        }
        let mut slots: Vec<Option<T>> = Vec::with_capacity(self.threads);
        slots.resize_with(self.threads, || None);
        std::thread::scope(|scope| {
            let f = &f;
            for ((i, off, chunk), slot) in pieces.into_iter().zip(slots.iter_mut()) {
                scope.spawn(move || {
                    *slot = Some(f(i, off, chunk));
                });
            }
        });
        slots.into_iter().map(|s| s.expect("worker filled slot")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_partition_order() {
        let pool = ThreadPool::new(4);
        let out = pool.run_partitioned(100, |i, r| (i, r.start, r.end));
        assert_eq!(out.len(), 4);
        for (i, w) in out.iter().enumerate() {
            assert_eq!(w.0, i);
        }
        assert_eq!(out[0].1, 0);
        assert_eq!(out[3].2, 100);
    }

    #[test]
    fn same_sum_any_thread_count() {
        let total = |threads: usize| -> u64 {
            ThreadPool::new(threads)
                .run_partitioned(10_000, |_, r| r.map(|i| i as u64 * 7).sum::<u64>())
                .into_iter()
                .sum()
        };
        let t1 = total(1);
        for t in [2, 3, 8, 16] {
            assert_eq!(total(t), t1);
        }
    }

    #[test]
    fn chunks_cover_slice_disjointly() {
        let mut data = vec![0u32; 1000];
        let pool = ThreadPool::new(7);
        pool.run_chunks(&mut data, |_, off, chunk| {
            for (j, v) in chunk.iter_mut().enumerate() {
                *v += (off + j) as u32 + 1;
            }
        });
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as u32 + 1, "i={i}");
        }
    }

    #[test]
    fn single_thread_no_spawn_path() {
        let pool = ThreadPool::new(1);
        let out = pool.run_partitioned(10, |i, r| (i, r.len()));
        assert_eq!(out, vec![(0, 10)]);
    }
}
