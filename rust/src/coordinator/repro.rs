//! Reproducibility verification (experiment E6).
//!
//! `verify_thread_invariance` runs the same simulation across a ladder of
//! thread counts and asserts bitwise-equal trajectory hashes;
//! `verify_rerun` re-runs the identical configuration; `verify_backends`
//! compares the host path against the PJRT device path (RNG streams must
//! be bitwise equal; positions may differ only by float re-association,
//! so they are compared with an ulp-scale tolerance and separately
//! hash-checked at the RNG level by rust/tests/cross_layer.rs).

use anyhow::Result;

use super::driver::{Backend, SimDriver};
use crate::backend::{Auto, DeviceFill, FillBackend, HostParallel, HostSerial};
use crate::core::fill;
use crate::core::{BlockRng, CounterRng, Generator, Rng};
use crate::sim::brownian::BrownianParams;
use crate::util::hash::Fnv1a;

/// Result of one reproducibility probe.
#[derive(Debug, Clone)]
pub struct ReproReport {
    pub description: String,
    pub hashes: Vec<(String, u64)>,
    pub consistent: bool,
}

impl ReproReport {
    pub fn render(&self) -> String {
        let mut s = format!("repro: {} -> {}\n", self.description, if self.consistent { "CONSISTENT" } else { "MISMATCH" });
        for (label, h) in &self.hashes {
            s.push_str(&format!("  {label:<12} {h:016x}\n"));
        }
        s
    }
}

/// Same simulation, thread counts 1..=max (powers of two): hashes must
/// be identical.
pub fn verify_thread_invariance(params: BrownianParams, max_threads: usize) -> Result<ReproReport> {
    let mut hashes = Vec::new();
    let mut t = 1;
    while t <= max_threads {
        let (sim, _) = SimDriver::new(Backend::Host { threads: t }).run(params)?;
        hashes.push((format!("threads={t}"), sim.state_hash()));
        t *= 2;
    }
    let consistent = hashes.windows(2).all(|w| w[0].1 == w[1].1);
    Ok(ReproReport {
        description: format!(
            "host trajectory x thread count (n={}, steps={})",
            params.n_particles, params.steps
        ),
        hashes,
        consistent,
    })
}

/// Run twice with identical parameters: must be identical (no hidden
/// global state, no time-based seeding).
pub fn verify_rerun(params: BrownianParams, threads: usize) -> Result<ReproReport> {
    let h = |_: usize| -> Result<u64> {
        let (sim, _) = SimDriver::new(Backend::Host { threads }).run(params)?;
        Ok(sim.state_hash())
    };
    let a = h(0)?;
    let b = h(1)?;
    Ok(ReproReport {
        description: "re-run identical config".to_string(),
        hashes: vec![("run A".into(), a), ("run B".into(), b)],
        consistent: a == b,
    })
}

/// The block-fill engine across a thread ladder: `par_fill_u32` and
/// `par_fill_f64` output must be bitwise identical for every thread
/// count — and identical to a plain word-at-a-time `next_u32` /
/// `draw_double` loop (the gold contract the fill engine promises, see
/// `docs/stream-contracts.md` §4).
pub fn verify_fill_invariance<G: BlockRng>(n: usize, max_threads: usize, seed: u64) -> ReproReport {
    let ctr = 0u32;
    // Reference: the draw API, one word / one double at a time.
    let serial_hash = {
        let mut h = Fnv1a::new();
        let mut g = G::new(seed, ctr);
        for _ in 0..n {
            h.write_u32(g.next_u32());
        }
        let mut g = G::new(seed, ctr);
        for _ in 0..n / 2 {
            h.write_f64(g.draw_double());
        }
        h.finish()
    };
    let mut hashes = vec![("word-at-a-time".to_string(), serial_hash)];
    let mut t = 1;
    while t <= max_threads {
        let mut words = vec![0u32; n];
        fill::par_fill_u32::<G>(seed, ctr, &mut words, t);
        let mut doubles = vec![0.0f64; n / 2];
        fill::par_fill_f64::<G>(seed, ctr, &mut doubles, t);
        let mut h = Fnv1a::new();
        h.write_u32_slice(&words);
        h.write_f64_slice(&doubles);
        hashes.push((format!("threads={t}"), h.finish()));
        t *= 2;
    }
    let consistent = hashes.windows(2).all(|w| w[0].1 == w[1].1);
    ReproReport {
        description: format!("block-fill u32+f64 x thread count ({}, n={n})", G::NAME),
        hashes,
        consistent,
    }
}

/// The backend-invariance ladder: every fill backend must produce the
/// same **bytes** as the serial host arm for the same
/// `(gen, seed, ctr, len)` — `host` (serial reference), `par` across a
/// thread ladder capped at `max_threads` (the `repro --max-threads`
/// contract), `device` when a real PJRT backend + artifacts exist
/// (silently skipped otherwise, like the artifact-dependent tests), and
/// `auto`, which must match whichever arm it selects. Output vectors
/// are compared byte-for-byte (u32 words and f64 draws); the rendered
/// hashes are fingerprints of those bytes.
pub fn verify_backend_invariance(
    gen: Generator,
    n: usize,
    seed: u64,
    ctr: u32,
    max_threads: usize,
) -> ReproReport {
    let max_threads = max_threads.max(1);
    fn run(
        b: &mut dyn FillBackend,
        gen: Generator,
        seed: u64,
        ctr: u32,
        n: usize,
    ) -> Result<(Vec<u32>, Vec<f64>)> {
        let mut words = vec![0u32; n];
        b.fill_u32(gen, seed, ctr, &mut words)?;
        let mut doubles = vec![0.0f64; n / 2];
        b.fill_f64(gen, seed, ctr, &mut doubles)?;
        Ok((words, doubles))
    }
    fn fingerprint(words: &[u32], doubles: &[f64]) -> u64 {
        let mut h = Fnv1a::new();
        h.write_u32_slice(words);
        h.write_f64_slice(doubles);
        h.finish()
    }
    let (ref_words, ref_doubles) =
        run(&mut HostSerial, gen, seed, ctr, n).expect("host serial arm is infallible");
    let mut hashes = vec![("host".to_string(), fingerprint(&ref_words, &ref_doubles))];
    let mut consistent = true;
    let mut compare = |label: String, words: &[u32], doubles: &[f64], consistent: &mut bool| {
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        if words != ref_words || bits(doubles) != bits(&ref_doubles) {
            *consistent = false;
        }
        hashes.push((label, fingerprint(words, doubles)));
    };
    for t in [1usize, 2, 8].into_iter().filter(|&t| t <= max_threads) {
        match run(&mut HostParallel::new(t), gen, seed, ctr, n) {
            Ok((w, d)) => compare(format!("par t={t}"), &w, &d, &mut consistent),
            Err(_) => consistent = false,
        }
    }
    let device_note = match DeviceFill::try_new() {
        Ok(mut dev) if dev.supports_fill(gen, n) => match run(&mut dev, gen, seed, ctr, n) {
            Ok((w, d)) => {
                compare("device".to_string(), &w, &d, &mut consistent);
                "device ran"
            }
            Err(_) => {
                consistent = false;
                "device errored"
            }
        },
        Ok(_) => "device skipped (no stream-ordered artifact for this engine/size)",
        Err(_) => "device skipped (unavailable: no artifacts / PJRT stub)",
    };
    let mut auto = Auto::new(max_threads.min(8));
    let sel = auto.selection(gen, n);
    match run(&mut auto, gen, seed, ctr, n) {
        Ok((w, d)) => compare(format!("auto->{}", sel.name()), &w, &d, &mut consistent),
        Err(_) => consistent = false,
    }
    ReproReport {
        description: format!(
            "backend-invariance ladder ({}, n={n}; {device_note})",
            gen.name()
        ),
        hashes,
        consistent,
    }
}

/// The mixed-arm shard-scheduler ladder: [`crate::backend::Sched`]
/// output over pseudo-random shard plans — arbitrary word boundaries,
/// host and device shards interleaved — must be **byte-identical** to
/// the serial `core::fill` layout. The host arms are exercised
/// unconditionally: device shards in a plan degrade to the host fill of
/// their span when no device exists (the stub-build contract), so every
/// random plan is legal everywhere. When a real device + `_at`
/// artifacts are present, the same plans genuinely land interior spans
/// on the device (the note in the description says which happened).
/// Plans are derived deterministically from `seed` via the splitmix64
/// chain, so the ladder replays bitwise like everything else here.
pub fn verify_sched_invariance(
    gen: Generator,
    n: usize,
    seed: u64,
    ctr: u32,
    plans: usize,
    threads: usize,
) -> ReproReport {
    use crate::backend::{Sched, Shard, ShardArm, ShardPlan};
    use crate::core::counter::splitmix64;
    let fp = |words: &[u32]| {
        let mut h = Fnv1a::new();
        h.write_u32_slice(words);
        h.finish()
    };
    let mut reference = vec![0u32; n];
    fill::fill_u32_gen(gen, seed, ctr, &mut reference);
    let mut hashes = vec![("serial".to_string(), fp(&reference))];
    let mut consistent = true;
    let mut sched = Sched::new(threads.max(1));
    // Row 1: the scheduler's own cost-model plan (what `--backend
    // sched` runs).
    let model_plan = sched.plan_for(gen, n);
    let mut got = vec![0u32; n];
    match sched.fill_u32_plan(gen, seed, ctr, &model_plan, &mut got) {
        Ok(()) => {
            if got != reference {
                consistent = false;
            }
            hashes.push((format!("plan:model({})", model_plan.shards().len()), fp(&got)));
        }
        Err(_) => consistent = false,
    }
    // Rows 2..: deterministic random plans with arbitrary shard
    // boundaries and arms.
    let mut state = seed ^ 0x5EED_0F_5C_4ED0_1E5u64;
    let mut next = |state: &mut u64| {
        *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        splitmix64(*state)
    };
    let mut device_shards_ran = 0u64;
    for p in 0..plans {
        let mut shards = Vec::new();
        let mut pos = 0usize;
        while pos < n {
            let r = next(&mut state);
            let len = 1 + (r as usize >> 8) % (n / 4 + 1).min(n - pos + 1).max(1);
            let len = len.min(n - pos);
            let arm = if r & 1 == 0 { ShardArm::Host } else { ShardArm::Device };
            shards.push(Shard { start: pos as u64, len, arm });
            pos += len;
        }
        let plan = match ShardPlan::new(shards) {
            Ok(p) => p,
            Err(_) => {
                consistent = false;
                continue;
            }
        };
        device_shards_ran +=
            plan.shards().iter().filter(|s| s.arm == ShardArm::Device).count() as u64;
        let mut got = vec![0u32; n];
        match sched.fill_u32_plan(gen, seed, ctr, &plan, &mut got) {
            Ok(()) => {
                if got != reference {
                    consistent = false;
                }
                hashes.push((format!("plan{p}({})", plan.shards().len()), fp(&got)));
            }
            Err(_) => consistent = false,
        }
    }
    let note = if sched.device_available() {
        "device arm live"
    } else {
        "device shards degraded to host (stub/no artifacts)"
    };
    ReproReport {
        description: format!(
            "sched shard-plan ladder ({}, n={n}, plans={plans}, {device_shards_ran} device shards; {note})",
            gen.name()
        ),
        hashes,
        consistent,
    }
}

/// The `StreamKey` zero-drift ladder: for every engine,
/// `StreamKey::raw(seed, ctr)` must open the byte-identical stream as
/// `CounterRng::new(seed, ctr)` (the facade's documented equivalence),
/// and the hierarchical derivation must match the normative mix —
/// checked against the cross-layer KAT literal (`root(7).child(3)
/// .epoch(1)`, pinned identically in `python/tests/test_stream_keys.py`)
/// plus the epoch-absoluteness rule. One row per engine; each
/// fingerprint covers both spellings' words.
pub fn verify_key_equivalence(seed: u64, ctr: u32, n: usize) -> ReproReport {
    use crate::stream::{derive_child_seed, DynStream, StreamKey};
    let key = StreamKey::raw(seed, ctr);
    let mut hashes = Vec::new();
    let mut consistent = true;
    for gen in Generator::ALL {
        let mut legacy = vec![0u32; n];
        gen.with_rng(seed, ctr, |r| r.fill_u32(&mut legacy));
        let mut keyed = vec![0u32; n];
        let mut s = DynStream::open(gen, key);
        Rng::fill_u32(&mut s, &mut keyed);
        if legacy != keyed {
            consistent = false;
        }
        let mut h = Fnv1a::new();
        h.write_u32_slice(&legacy);
        h.write_u32_slice(&keyed);
        hashes.push((gen.name().to_string(), h.finish()));
    }
    // Derivation KAT + epoch absoluteness (the documented order rule).
    let derived = StreamKey::root(7).child(3).epoch(1);
    if (derived.seed(), derived.ctr()) != (0xBC83_12B7_34DE_4237, 1)
        || derive_child_seed(7, 0, 3) != derived.seed()
        || StreamKey::root(9).epoch(5).epoch(2) != StreamKey::raw(9, 2)
    {
        consistent = false;
    }
    ReproReport {
        description: format!(
            "StreamKey::raw vs CounterRng::new (seed={seed:#x}, ctr={ctr}, n={n}) + derivation KAT"
        ),
        hashes,
        consistent,
    }
}

/// Host vs device: positions agree within `tol` relative error per
/// coordinate (XLA may re-associate float ops; the RNG words themselves
/// are pinned bitwise by the cross-layer integration test).
pub fn verify_backends(params: BrownianParams, tol: f64) -> Result<ReproReport> {
    let (host, _) = SimDriver::new(Backend::Host { threads: 1 }).run(params)?;
    let (dev, _) = SimDriver::new(Backend::Device).run(params)?;
    let mut max_rel: f64 = 0.0;
    for i in 0..params.n_particles {
        for (a, b) in [
            (host.x[i], dev.x[i]),
            (host.y[i], dev.y[i]),
            (host.vx[i], dev.vx[i]),
            (host.vy[i], dev.vy[i]),
        ] {
            let denom = a.abs().max(1e-9);
            max_rel = max_rel.max((a - b).abs() / denom);
        }
    }
    Ok(ReproReport {
        description: format!("host vs device (max rel err {max_rel:.2e}, tol {tol:.1e})"),
        hashes: vec![
            ("host".into(), host.state_hash()),
            ("device".into(), dev.state_hash()),
        ],
        consistent: max_rel <= tol,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::brownian::RngStyle;

    fn params() -> BrownianParams {
        BrownianParams { n_particles: 1024, steps: 8, global_seed: 5, style: RngStyle::OpenRand }
    }

    #[test]
    fn thread_invariance_holds() {
        let r = verify_thread_invariance(params(), 8).unwrap();
        assert!(r.consistent, "{}", r.render());
        assert_eq!(r.hashes.len(), 4); // 1, 2, 4, 8
    }

    #[test]
    fn rerun_holds() {
        let r = verify_rerun(params(), 4).unwrap();
        assert!(r.consistent, "{}", r.render());
    }

    #[test]
    fn fill_invariance_holds() {
        use crate::core::{Philox, Squares, Tyche};
        let r = verify_fill_invariance::<Philox>(10_000, 8, 0xF17);
        assert!(r.consistent, "{}", r.render());
        assert_eq!(r.hashes.len(), 5); // word-at-a-time + threads 1,2,4,8
        let r = verify_fill_invariance::<Squares>(10_000, 4, 0xF17);
        assert!(r.consistent, "{}", r.render());
        let r = verify_fill_invariance::<Tyche>(2_000, 4, 0xF17);
        assert!(r.consistent, "{}", r.render());
    }

    #[test]
    fn backend_invariance_holds() {
        // Philox (device-eligible when artifacts exist) and Tyche
        // (host-only; device row must self-skip without failing).
        let r = verify_backend_invariance(Generator::Philox, 20_000, 0xBEEF, 3, 8);
        assert!(r.consistent, "{}", r.render());
        // host + par{1,2,8} + auto, plus device when available.
        assert!(r.hashes.len() >= 5, "{}", r.render());
        let r = verify_backend_invariance(Generator::Tyche, 4_000, 0xBEEF, 3, 8);
        assert!(r.consistent, "{}", r.render());
        assert!(r.description.contains("tyche"), "{}", r.description);
        // --max-threads 1 keeps the par ladder to a single thread.
        let r = verify_backend_invariance(Generator::Philox, 4_000, 0xBEEF, 3, 1);
        assert!(r.consistent, "{}", r.render());
        assert!(
            !r.hashes.iter().any(|(label, _)| label.contains("t=2") || label.contains("t=8")),
            "{}",
            r.render()
        );
    }

    #[test]
    fn sched_invariance_holds() {
        // Counter engine and a sequential engine; device shards degrade
        // to host on stub builds, so this is unconditional.
        let r = verify_sched_invariance(Generator::Philox, 20_000, 0x5EED, 3, 5, 4);
        assert!(r.consistent, "{}", r.render());
        // serial + model plan + 5 random plans.
        assert_eq!(r.hashes.len(), 7, "{}", r.render());
        let r = verify_sched_invariance(Generator::Tyche, 4_000, 0x5EED, 3, 3, 2);
        assert!(r.consistent, "{}", r.render());
        assert!(r.description.contains("sched"), "{}", r.description);
    }

    #[test]
    fn key_equivalence_holds() {
        let r = verify_key_equivalence(0xFEED_F00D, 11, 4096);
        assert!(r.consistent, "{}", r.render());
        assert_eq!(r.hashes.len(), Generator::ALL.len());
        assert!(r.description.contains("StreamKey"), "{}", r.description);
    }

    #[test]
    fn report_renders() {
        let r = verify_rerun(params(), 1).unwrap();
        let text = r.render();
        assert!(text.contains("CONSISTENT"));
        assert!(text.contains("run A"));
    }
}
