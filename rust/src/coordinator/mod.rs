//! L3 coordination: parallel execution that preserves the paper's
//! reproducibility guarantee.
//!
//! The guarantee comes from the *stream identity* design (streams derive
//! from logical ids, never from thread ids), but the coordinator must
//! not squander it: [`partition`] produces deterministic, thread-count-
//! independent work ranges; [`pool`] executes them on scoped threads;
//! [`repro`] verifies bitwise equality across thread counts and across
//! host/device paths; [`driver`] orchestrates whole simulations over
//! either the host (multithreaded Rust) or device (PJRT) execution path;
//! [`metrics`] collects per-run counters for the benches and the CLI.

pub mod driver;
pub mod metrics;
pub mod partition;
pub mod pool;
pub mod repro;

pub use driver::{Backend, SimDriver};
pub use partition::partition_ranges;
pub use pool::ThreadPool;
