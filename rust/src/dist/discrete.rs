//! Discrete distributions: Bernoulli, Binomial, and Walker's alias
//! method for arbitrary weighted categorical draws.

use super::Distribution;
use crate::core::traits::Rng;

/// Bernoulli(p): `true` with probability `p`.
///
/// Words consumed per sample: 2 (one `draw_double` compared against p).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Bernoulli {
    p: f64,
}

impl Bernoulli {
    /// Requires `0 ≤ p ≤ 1`.
    pub fn new(p: f64) -> Bernoulli {
        assert!((0.0..=1.0).contains(&p), "bad Bernoulli(p = {p})");
        Bernoulli { p }
    }

    pub fn p(&self) -> f64 {
        self.p
    }
}

impl Distribution<bool> for Bernoulli {
    #[inline]
    fn sample(&self, rng: &mut dyn Rng) -> bool {
        rng.draw_double() < self.p
    }
}

/// Binomial(n, p) as n sequential Bernoulli trials.
///
/// Words consumed per sample: exactly `2·n` — fixed, which keeps this
/// sampler stream-alignable (the contract table in [`super`]). The
/// O(n) cost is the price; for large-n hot paths prefer a normal
/// approximation at the call site.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Binomial {
    n: u32,
    bern: Bernoulli,
}

impl Binomial {
    pub fn new(n: u32, p: f64) -> Binomial {
        Binomial { n, bern: Bernoulli::new(p) }
    }

    pub fn trials(&self) -> u32 {
        self.n
    }

    pub fn p(&self) -> f64 {
        self.bern.p()
    }
}

impl Distribution<u64> for Binomial {
    fn sample(&self, rng: &mut dyn Rng) -> u64 {
        let mut k = 0u64;
        for _ in 0..self.n {
            k += self.bern.sample(rng) as u64;
        }
        k
    }
}

/// Weighted categorical sampling in O(1) per draw via Walker's alias
/// method (Vose's stable construction).
///
/// `new` preprocesses arbitrary non-negative weights into a probability
/// table + alias table in O(n); each sample then costs one bounded
/// integer draw (`range_u32`, Lemire — 1 word plus rare rejections) and
/// one `draw_double` (2 words), regardless of how many categories exist.
/// (`std`: the tables are heap-allocated.)
#[cfg(feature = "std")]
#[derive(Debug, Clone, PartialEq)]
pub struct DiscreteAlias {
    /// Acceptance probability of column i's own index.
    prob: Vec<f64>,
    /// Donor index used when column i rejects.
    alias: Vec<u32>,
}

#[cfg(feature = "std")]
impl DiscreteAlias {
    /// Build the alias table. Requires at least one weight, all finite
    /// and non-negative, with a positive sum.
    pub fn new(weights: &[f64]) -> DiscreteAlias {
        assert!(!weights.is_empty(), "alias table needs at least one weight");
        assert!(
            weights.iter().all(|w| w.is_finite() && *w >= 0.0),
            "weights must be finite and non-negative: {weights:?}"
        );
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must not all be zero");
        let n = weights.len();
        // Vose: split columns into under-full ("small") and over-full
        // ("large"), then pair each small column with a large donor.
        let mut scaled: Vec<f64> = weights.iter().map(|w| w * n as f64 / total).collect();
        let mut prob = vec![0.0f64; n];
        let mut alias = vec![0u32; n];
        let mut small: Vec<usize> = (0..n).filter(|&i| scaled[i] < 1.0).collect();
        let mut large: Vec<usize> = (0..n).filter(|&i| scaled[i] >= 1.0).collect();
        loop {
            let (Some(s), Some(l)) = (small.last().copied(), large.last().copied()) else {
                break;
            };
            small.pop();
            prob[s] = scaled[s];
            alias[s] = l as u32;
            scaled[l] = (scaled[l] + scaled[s]) - 1.0;
            if scaled[l] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // Whatever remains (numerically ~1.0) accepts its own index.
        for &i in small.iter().chain(large.iter()) {
            prob[i] = 1.0;
        }
        DiscreteAlias { prob, alias }
    }

    /// Number of categories.
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }
}

#[cfg(feature = "std")]
impl Distribution<usize> for DiscreteAlias {
    #[inline]
    fn sample(&self, rng: &mut dyn Rng) -> usize {
        let i = rng.range_u32(self.prob.len() as u32) as usize;
        if rng.draw_double() < self.prob[i] {
            i
        } else {
            self.alias[i] as usize
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{CounterRng, Philox, Squares};

    #[test]
    fn bernoulli_frequency() {
        for p in [0.0, 0.1, 0.5, 0.93, 1.0] {
            let d = Bernoulli::new(p);
            let mut rng = Philox::new(0xBE2, 0);
            let n = 100_000;
            let hits = (0..n).filter(|_| d.sample(&mut rng)).count();
            let freq = hits as f64 / n as f64;
            // 6σ band around p (degenerate p gives exact 0/1).
            let tol = 6.0 * (p * (1.0 - p) / n as f64).sqrt() + 1e-12;
            assert!((freq - p).abs() <= tol, "p={p}: freq {freq}");
        }
    }

    #[test]
    fn binomial_moments_and_range() {
        let d = Binomial::new(20, 0.3);
        let mut rng = Philox::new(0xB10, 1);
        let n = 50_000;
        let mut sum = 0.0f64;
        let mut sumsq = 0.0f64;
        for _ in 0..n {
            let k = d.sample(&mut rng);
            assert!(k <= 20);
            sum += k as f64;
            sumsq += (k * k) as f64;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!((mean - 6.0).abs() < 0.06, "mean {mean}");
        assert!((var - 4.2).abs() < 0.2, "var {var}");
    }

    #[test]
    fn binomial_consumes_2n_words() {
        let d = Binomial::new(13, 0.5);
        let mut a = Philox::new(5, 5);
        let mut b = Philox::new(5, 5);
        let _ = d.sample(&mut a);
        for _ in 0..13 {
            let _ = b.draw_double();
        }
        assert_eq!(a.next_u32(), b.next_u32());
    }

    #[test]
    fn alias_matches_weights() {
        let weights = [1.0, 2.0, 3.0, 4.0];
        let d = DiscreteAlias::new(&weights);
        let mut rng = Philox::new(0xA11A5, 0);
        let n = 200_000usize;
        let mut counts = [0usize; 4];
        for _ in 0..n {
            counts[d.sample(&mut rng)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let want = weights[i] / 10.0;
            let got = c as f64 / n as f64;
            let tol = 6.0 * (want * (1.0 - want) / n as f64).sqrt();
            assert!((got - want).abs() < tol, "category {i}: {got} vs {want}");
        }
    }

    #[test]
    fn alias_handles_extreme_weights() {
        // One dominant category plus near-zero ones must not lose mass.
        let d = DiscreteAlias::new(&[1e-9, 1.0, 1e-9]);
        let mut rng = Squares::new(1, 1);
        let picks = (0..10_000).filter(|_| d.sample(&mut rng) == 1).count();
        assert!(picks > 9_990, "{picks}");
        // Zero-weight categories are never drawn.
        let z = DiscreteAlias::new(&[0.0, 1.0]);
        let mut rng = Squares::new(2, 2);
        assert!((0..10_000).all(|_| z.sample(&mut rng) == 1));
    }

    #[test]
    fn alias_single_category() {
        let d = DiscreteAlias::new(&[42.0]);
        let mut rng = Philox::new(0, 0);
        for _ in 0..32 {
            assert_eq!(d.sample(&mut rng), 0);
        }
    }

    #[test]
    fn alias_uniform_weights_accept_everywhere() {
        // Equal weights scale to exactly 1.0 per column: every column
        // accepts itself and the alias table is never consulted.
        let d = DiscreteAlias::new(&[2.5; 8]);
        assert!(d.prob.iter().all(|&p| p == 1.0));
    }

    #[test]
    fn deterministic_per_stream() {
        let d = DiscreteAlias::new(&[0.2, 0.5, 0.3]);
        let a: Vec<usize> = {
            let mut r = Philox::new(11, 4);
            (0..256).map(|_| d.sample(&mut r)).collect()
        };
        let b: Vec<usize> = {
            let mut r = Philox::new(11, 4);
            (0..256).map(|_| d.sample(&mut r)).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn alias_rejects_all_zero() {
        let _ = DiscreteAlias::new(&[0.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn bernoulli_rejects_out_of_range() {
        let _ = Bernoulli::new(1.5);
    }
}
