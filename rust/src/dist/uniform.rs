//! Uniform distribution over an `[lo, hi)` interval.

use super::Distribution;
use crate::core::traits::Rng;

/// Uniform `f64` on `[lo, hi)`.
///
/// Words consumed per sample: 2 (one `draw_double`). The affine map is
/// evaluated as `lo + (hi - lo) * u`, the same expression as
/// `Rng::range_f64`, so the two paths agree bitwise.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Uniform {
    lo: f64,
    hi: f64,
}

impl Uniform {
    /// Uniform on `[lo, hi)`. Requires `lo < hi` and both finite.
    pub fn new(lo: f64, hi: f64) -> Uniform {
        assert!(lo.is_finite() && hi.is_finite() && lo < hi, "bad interval [{lo}, {hi})");
        Uniform { lo, hi }
    }

    /// The canonical `[0, 1)` uniform.
    pub fn standard() -> Uniform {
        Uniform { lo: 0.0, hi: 1.0 }
    }

    pub fn lo(&self) -> f64 {
        self.lo
    }

    pub fn hi(&self) -> f64 {
        self.hi
    }
}

impl Distribution<f64> for Uniform {
    #[inline]
    fn sample(&self, rng: &mut dyn Rng) -> f64 {
        self.lo + (self.hi - self.lo) * rng.draw_double()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{CounterRng, Philox, Tyche};

    #[test]
    fn standard_matches_draw_double() {
        let d = Uniform::standard();
        let mut a = Philox::new(5, 0);
        let mut b = Philox::new(5, 0);
        for _ in 0..64 {
            assert_eq!(d.sample(&mut a).to_bits(), b.draw_double().to_bits());
        }
    }

    #[test]
    fn matches_range_f64() {
        let d = Uniform::new(-3.0, 11.5);
        let mut a = Tyche::new(7, 7);
        let mut b = Tyche::new(7, 7);
        for _ in 0..64 {
            assert_eq!(d.sample(&mut a).to_bits(), b.range_f64(-3.0, 11.5).to_bits());
        }
    }

    #[test]
    fn stays_in_bounds() {
        let d = Uniform::new(-1.0, 1.0);
        let mut rng = Philox::new(0, 0);
        for _ in 0..10_000 {
            let x = d.sample(&mut rng);
            assert!((-1.0..1.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn mean_is_midpoint() {
        let d = Uniform::new(10.0, 20.0);
        let mut rng = Philox::new(0xABCD, 3);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64;
        assert!((mean - 15.0).abs() < 0.05, "{mean}");
    }

    #[test]
    #[should_panic]
    fn rejects_empty_interval() {
        let _ = Uniform::new(2.0, 2.0);
    }
}
