//! Uniform distribution over an `[lo, hi)` interval.

use super::Distribution;
use crate::core::fill::u01_f64;
use crate::core::traits::Rng;

/// Uniform `f64` on `[lo, hi)`.
///
/// Words consumed per sample: 2 (one `draw_double`). The affine map is
/// evaluated as `lo + (hi - lo) * u`, the same expression as
/// `Rng::range_f64`, so the two paths agree bitwise.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Uniform {
    lo: f64,
    hi: f64,
}

impl Uniform {
    /// Uniform on `[lo, hi)`. Requires `lo < hi` and both finite.
    pub fn new(lo: f64, hi: f64) -> Uniform {
        assert!(lo.is_finite() && hi.is_finite() && lo < hi, "bad interval [{lo}, {hi})");
        Uniform { lo, hi }
    }

    /// The canonical `[0, 1)` uniform.
    pub fn standard() -> Uniform {
        Uniform { lo: 0.0, hi: 1.0 }
    }

    pub fn lo(&self) -> f64 {
        self.lo
    }

    pub fn hi(&self) -> f64 {
        self.hi
    }

    /// Bulk sampling fast path: pulls stream words in tiles through
    /// `Rng::fill_u32` (the engines' block path) and applies the affine
    /// map in place. Bit-identical to `out.len()` repeated
    /// [`Distribution::sample`] calls — sample `i` still consumes stream
    /// words `2i, 2i + 1` (see the contract table in [`super`]).
    pub fn sample_fill<R: Rng + ?Sized>(&self, rng: &mut R, out: &mut [f64]) {
        const TILE: usize = 512;
        let mut words = [0u32; 2 * TILE];
        let mut done = 0usize;
        while done < out.len() {
            let n = (out.len() - done).min(TILE);
            let tile = &mut words[..2 * n];
            rng.fill_u32(tile);
            for k in 0..n {
                let u = u01_f64(tile[2 * k], tile[2 * k + 1]);
                out[done + k] = self.lo + (self.hi - self.lo) * u;
            }
            done += n;
        }
    }

}

impl Distribution<f64> for Uniform {
    #[inline]
    fn sample(&self, rng: &mut dyn Rng) -> f64 {
        self.lo + (self.hi - self.lo) * rng.draw_double()
    }

    /// Backend bulk path: draw the whole `[0, 1)` buffer from stream
    /// `(seed, ctr)` of `gen` on the chosen arm and apply the affine map
    /// in place (the identical expression, so the output is
    /// byte-identical to [`Uniform::sample_fill`] on a fresh `gen`
    /// engine at `(seed, ctr)` — on every arm, by the backend contract).
    #[cfg(feature = "std")]
    fn fill_backend(
        &self,
        backend: &mut dyn crate::backend::FillBackend,
        gen: crate::core::Generator,
        seed: u64,
        ctr: u32,
        out: &mut [f64],
    ) -> anyhow::Result<()> {
        backend.fill_f64(gen, seed, ctr, out)?;
        for slot in out.iter_mut() {
            *slot = self.lo + (self.hi - self.lo) * *slot;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{CounterRng, Philox, Tyche};

    #[test]
    fn standard_matches_draw_double() {
        let d = Uniform::standard();
        let mut a = Philox::new(5, 0);
        let mut b = Philox::new(5, 0);
        for _ in 0..64 {
            assert_eq!(d.sample(&mut a).to_bits(), b.draw_double().to_bits());
        }
    }

    #[test]
    fn matches_range_f64() {
        let d = Uniform::new(-3.0, 11.5);
        let mut a = Tyche::new(7, 7);
        let mut b = Tyche::new(7, 7);
        for _ in 0..64 {
            assert_eq!(d.sample(&mut a).to_bits(), b.range_f64(-3.0, 11.5).to_bits());
        }
    }

    #[test]
    fn sample_fill_matches_repeated_sample() {
        let d = Uniform::new(-3.0, 11.5);
        for n in [0usize, 1, 511, 512, 513, 1500] {
            let mut a = Philox::new(21, 4);
            let mut b = Philox::new(21, 4);
            let mut buf = vec![0.0f64; n];
            d.sample_fill(&mut a, &mut buf);
            for (i, &v) in buf.iter().enumerate() {
                assert_eq!(v.to_bits(), d.sample(&mut b).to_bits(), "n={n} i={i}");
            }
            // Streams left at the same position.
            assert_eq!(a.next_u32(), b.next_u32(), "n={n}");
        }
    }

    #[test]
    fn fill_backend_matches_engine_path() {
        use crate::backend::{HostParallel, HostSerial};
        use crate::core::Generator;
        let d = Uniform::new(-3.0, 11.5);
        let mut want = vec![0.0f64; 700];
        d.sample_fill(&mut Philox::new(21, 4), &mut want);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        let mut a = vec![0.0f64; 700];
        d.fill_backend(&mut HostSerial, Generator::Philox, 21, 4, &mut a).unwrap();
        assert_eq!(bits(&a), bits(&want));
        let mut b = vec![0.0f64; 700];
        d.fill_backend(&mut HostParallel::new(3), Generator::Philox, 21, 4, &mut b)
            .unwrap();
        assert_eq!(bits(&b), bits(&want));
    }

    #[test]
    fn stays_in_bounds() {
        let d = Uniform::new(-1.0, 1.0);
        let mut rng = Philox::new(0, 0);
        for _ in 0..10_000 {
            let x = d.sample(&mut rng);
            assert!((-1.0..1.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn mean_is_midpoint() {
        let d = Uniform::new(10.0, 20.0);
        let mut rng = Philox::new(0xABCD, 3);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64;
        assert!((mean - 15.0).abs() < 0.05, "{mean}");
    }

    #[test]
    #[should_panic]
    fn rejects_empty_interval() {
        let _ = Uniform::new(2.0, 2.0);
    }
}
