//! Exponential distribution by inversion.

use super::Distribution;
use crate::core::traits::Rng;

/// Exponential with rate `lambda` (mean `1/lambda`), sampled by CDF
/// inversion: `x = -ln(1 - u) / λ`.
///
/// Words consumed per sample: exactly 2 (one `draw_double`). Inversion
/// is chosen over rejection so consumption is fixed — this sampler is
/// safe to interleave with device-aligned streams (see the contract
/// table in [`super`]). `1 - u` maps the `[0, 1)` draw onto `(0, 1]`,
/// so the logarithm never sees zero.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Exponential {
    lambda: f64,
}

impl Exponential {
    /// Rate parameterization. Requires `lambda > 0` and finite.
    pub fn new(lambda: f64) -> Exponential {
        assert!(lambda.is_finite() && lambda > 0.0, "bad Exp(λ = {lambda})");
        Exponential { lambda }
    }

    pub fn lambda(&self) -> f64 {
        self.lambda
    }
}

impl Distribution<f64> for Exponential {
    #[inline]
    fn sample(&self, rng: &mut dyn Rng) -> f64 {
        -(1.0 - rng.draw_double()).ln() / self.lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{CounterRng, Philox, Threefry};

    #[test]
    fn nonnegative_and_finite() {
        let d = Exponential::new(0.25);
        let mut rng = Philox::new(8, 8);
        for _ in 0..10_000 {
            let x = d.sample(&mut rng);
            assert!(x >= 0.0 && x.is_finite(), "{x}");
        }
    }

    #[test]
    fn consumes_exactly_one_double() {
        let d = Exponential::new(3.0);
        let mut a = Threefry::new(1, 1);
        let mut b = Threefry::new(1, 1);
        for _ in 0..16 {
            let _ = d.sample(&mut a);
            let _ = b.draw_double();
        }
        assert_eq!(a.next_u32(), b.next_u32());
    }

    #[test]
    fn mean_is_inverse_rate() {
        for lambda in [0.5, 2.0, 10.0] {
            let d = Exponential::new(lambda);
            let mut rng = Philox::new(0xE4B, 1);
            let n = 100_000;
            let mean = (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64;
            // sd of the sample mean is (1/λ)/sqrt(n); allow 6σ.
            let tol = 6.0 / (lambda * (n as f64).sqrt());
            assert!((mean - 1.0 / lambda).abs() < tol, "λ={lambda}: mean {mean}");
        }
    }

    #[test]
    fn rate_scales_samples_exactly() {
        // Inversion makes Exp(λ) = Exp(1)/λ bitwise up to the division.
        let e1 = Exponential::new(1.0);
        let e4 = Exponential::new(4.0);
        let mut a = Philox::new(2, 2);
        let mut b = Philox::new(2, 2);
        for _ in 0..32 {
            let x1 = e1.sample(&mut a);
            let x4 = e4.sample(&mut b);
            assert_eq!((x1 / 4.0).to_bits(), x4.to_bits());
        }
    }

    #[test]
    #[should_panic]
    fn rejects_nonpositive_rate() {
        let _ = Exponential::new(0.0);
    }
}
