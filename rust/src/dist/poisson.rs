//! Poisson distribution: Knuth's product method for small rates, the
//! PTRS transformed-rejection sampler (Hörmann 1993) for large ones.
//!
//! Both branches draw only through the `Rng` trait, so results are a
//! pure function of the `(seed, ctr)` stream — deterministic across
//! threads and platforms even though the number of words consumed is
//! data-dependent (see the contract table in [`super`]).

use super::Distribution;
use crate::core::traits::Rng;
use crate::stats::pvalue::ln_gamma;

/// Rate threshold between the two samplers. Knuth's method costs
/// O(λ) uniforms per sample; PTRS costs ~1.1 attempts of 2 uniforms
/// regardless of λ but needs λ large enough for its constants.
const PTRS_CUTOFF: f64 = 10.0;

#[derive(Debug, Clone, Copy, PartialEq)]
enum Method {
    /// Multiply uniforms until the product drops below e^-λ.
    Knuth { exp_neg_lambda: f64 },
    /// Transformed rejection with squeeze (PTRS).
    Ptrs { b: f64, a: f64, inv_alpha: f64, v_r: f64, ln_lambda: f64 },
}

/// Poisson(λ) over the natural numbers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Poisson {
    lambda: f64,
    method: Method,
}

impl Poisson {
    /// Requires `lambda > 0` and finite. The sampling method is chosen
    /// once here (λ < 10: Knuth; λ ≥ 10: PTRS).
    pub fn new(lambda: f64) -> Poisson {
        assert!(lambda.is_finite() && lambda > 0.0, "bad Poisson(λ = {lambda})");
        let method = if lambda < PTRS_CUTOFF {
            Method::Knuth { exp_neg_lambda: (-lambda).exp() }
        } else {
            let b = 0.931 + 2.53 * lambda.sqrt();
            let a = -0.059 + 0.02483 * b;
            Method::Ptrs {
                b,
                a,
                inv_alpha: 1.1239 + 1.1328 / (b - 3.4),
                v_r: 0.9277 - 3.6224 / (b - 2.0),
                ln_lambda: lambda.ln(),
            }
        };
        Poisson { lambda, method }
    }

    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    fn sample_knuth(&self, exp_neg_lambda: f64, rng: &mut dyn Rng) -> u64 {
        // Knuth: count how many uniforms multiply before the product
        // drops below e^-λ. Expected λ+1 draws of 2 words each.
        let mut k = 0u64;
        let mut prod = rng.draw_double();
        while prod > exp_neg_lambda {
            k += 1;
            prod *= rng.draw_double();
        }
        k
    }

    #[allow(clippy::too_many_arguments)]
    fn sample_ptrs(
        &self,
        b: f64,
        a: f64,
        inv_alpha: f64,
        v_r: f64,
        ln_lambda: f64,
        rng: &mut dyn Rng,
    ) -> u64 {
        // Hörmann's PTRS (the sampler numpy uses for λ ≥ 10): 4 words
        // per attempt, acceptance ≳ 0.9 for all λ above the cutoff.
        loop {
            let u = rng.draw_double() - 0.5;
            let v = rng.draw_double();
            let us = 0.5 - u.abs();
            let k = ((2.0 * a / us + b) * u + self.lambda + 0.43).floor();
            if us >= 0.07 && v <= v_r {
                return k as u64;
            }
            if k < 0.0 || (us < 0.013 && v > us) {
                continue;
            }
            if v.ln() + inv_alpha.ln() - (a / (us * us) + b).ln()
                <= -self.lambda + k * ln_lambda - ln_gamma(k + 1.0)
            {
                return k as u64;
            }
        }
    }
}

impl Distribution<u64> for Poisson {
    fn sample(&self, rng: &mut dyn Rng) -> u64 {
        match self.method {
            Method::Knuth { exp_neg_lambda } => self.sample_knuth(exp_neg_lambda, rng),
            Method::Ptrs { b, a, inv_alpha, v_r, ln_lambda } => {
                self.sample_ptrs(b, a, inv_alpha, v_r, ln_lambda, rng)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{CounterRng, Philox, Tyche};

    fn moments(lambda: f64, seed: u64, n: usize) -> (f64, f64) {
        let d = Poisson::new(lambda);
        let mut rng = Philox::new(seed, 0);
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let k = d.sample(&mut rng) as f64;
            s1 += k;
            s2 += k * k;
        }
        let mean = s1 / n as f64;
        (mean, s2 / n as f64 - mean * mean)
    }

    #[test]
    fn knuth_branch_mean_and_variance() {
        // λ < 10 exercises Knuth. Mean and variance are both λ.
        for lambda in [0.3, 1.0, 4.5] {
            let n = 100_000;
            let (mean, var) = moments(lambda, 0xA0A0, n);
            let tol = 6.0 * (lambda / n as f64).sqrt();
            assert!((mean - lambda).abs() < tol, "λ={lambda}: mean {mean}");
            assert!((var - lambda).abs() < 12.0 * tol.max(0.02), "λ={lambda}: var {var}");
        }
    }

    #[test]
    fn ptrs_branch_mean_and_variance() {
        for lambda in [10.0, 42.0, 500.0] {
            let n = 100_000;
            let (mean, var) = moments(lambda, 0xB1B1, n);
            let tol = 6.0 * (lambda / n as f64).sqrt();
            assert!((mean - lambda).abs() < tol, "λ={lambda}: mean {mean}");
            assert!((var - lambda).abs() < 20.0 * tol, "λ={lambda}: var {var}");
        }
    }

    #[test]
    fn small_lambda_pmf_head() {
        // For λ = 1: P(0) = P(1) = e^-1 ≈ 0.3679.
        let d = Poisson::new(1.0);
        let mut rng = Philox::new(7, 3);
        let n = 200_000;
        let mut zeros = 0usize;
        let mut ones = 0usize;
        for _ in 0..n {
            match d.sample(&mut rng) {
                0 => zeros += 1,
                1 => ones += 1,
                _ => {}
            }
        }
        let e1 = (-1.0f64).exp();
        for (count, name) in [(zeros, "P(0)"), (ones, "P(1)")] {
            let p = count as f64 / n as f64;
            assert!((p - e1).abs() < 0.006, "{name} = {p}, want {e1}");
        }
    }

    #[test]
    fn deterministic_both_branches() {
        for lambda in [4.5, 40.0] {
            let d = Poisson::new(lambda);
            let a: Vec<u64> = {
                let mut r = Tyche::new(3, 9);
                (0..128).map(|_| d.sample(&mut r)).collect()
            };
            let b: Vec<u64> = {
                let mut r = Tyche::new(3, 9);
                (0..128).map(|_| d.sample(&mut r)).collect()
            };
            assert_eq!(a, b, "λ={lambda}");
        }
    }

    #[test]
    fn branch_selection_at_cutoff() {
        assert!(matches!(Poisson::new(9.99).method, Method::Knuth { .. }));
        assert!(matches!(Poisson::new(10.0).method, Method::Ptrs { .. }));
    }

    #[test]
    #[should_panic]
    fn rejects_nonpositive_lambda() {
        let _ = Poisson::new(-1.0);
    }
}
