//! Normal (Gaussian) sampling: the normative Box–Muller transform and
//! the Marsaglia–Tsang ziggurat fast path.
//!
//! [`BoxMuller`] is the **normative** normal: it consumes exactly one
//! `draw_double2` pair (with Philox, exactly one 4-word counter block)
//! per sample, which keeps it bit-compatible with the AOT device graphs
//! (`normal_f64_*`, lowered from `python/compile/kernels/normal.py` /
//! `model.py::normal_f64_block` — `tests/cross_layer.rs` holds the two
//! sides together). Pinned KAT vectors below are shared verbatim with
//! `python/tests/test_kat.py`.
//!
//! [`ZigguratNormal`] is the host fast path: ~1 stream word per sample
//! on the ~98% fast path versus Box–Muller's 4 words + `ln`/`sqrt`/
//! `cos`/`sin` (`cargo bench --bench fig_dist` quantifies the gap). Its
//! rejection loop makes word consumption data-dependent, so it is
//! deterministic per `(seed, ctr)` but **not** device-graph-aligned —
//! see the contract table in [`super`].

use super::Distribution;
use crate::core::fill::u01_f64;
use crate::core::traits::Rng;
use std::sync::OnceLock;

/// Smallest positive `draw_double` step; substituted for an exact 0.0
/// draw before `ln` (same guard as the device graph).
const MIN_POS: f64 = 1.0 / (1u64 << 53) as f64;

/// Normal via the Box–Muller transform (polar-free, trig form).
///
/// Words consumed per `sample`/`sample_pair`: exactly 4 (one
/// `draw_double2`). `sample` returns the cosine branch — the value the
/// device graph emits; `sample_pair` returns (cos, sin) branches.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BoxMuller {
    mean: f64,
    sigma: f64,
}

impl BoxMuller {
    /// Standard normal N(0, 1).
    pub fn standard() -> BoxMuller {
        BoxMuller { mean: 0.0, sigma: 1.0 }
    }

    /// N(mean, sigma²). Requires `sigma > 0`.
    pub fn new(mean: f64, sigma: f64) -> BoxMuller {
        assert!(mean.is_finite() && sigma.is_finite() && sigma > 0.0, "bad N({mean}, {sigma}²)");
        BoxMuller { mean, sigma }
    }

    /// Two independent normals from one `draw_double2` pair:
    /// `r = sqrt(-2 ln u1)`, `θ = 2π u2`, returning
    /// `(mean + σ·r·cos θ, mean + σ·r·sin θ)`.
    ///
    /// Monomorphizing (`R: Rng`) hot-path form; the trait's `sample`
    /// takes the cosine branch of one pair.
    #[inline]
    pub fn sample_pair<R: Rng + ?Sized>(&self, rng: &mut R) -> (f64, f64) {
        let (u1, u2) = rng.draw_double2();
        let u1 = u1.max(MIN_POS);
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = std::f64::consts::TAU * u2;
        (
            self.mean + self.sigma * (r * theta.cos()),
            self.mean + self.sigma * (r * theta.sin()),
        )
    }

    /// Bulk sampling fast path: pulls stream words in tiles through
    /// `Rng::fill_u32` (the engines' block path) and applies the
    /// cosine-branch transform in place. Bit-identical to `out.len()`
    /// repeated [`Distribution::sample`] calls — sample `i` still
    /// consumes stream words `4i..4i + 4` (with Philox, exactly counter
    /// block `i`), preserving the device-graph alignment.
    pub fn sample_fill<R: Rng + ?Sized>(&self, rng: &mut R, out: &mut [f64]) {
        const TILE: usize = 256;
        let mut words = [0u32; 4 * TILE];
        let mut done = 0usize;
        while done < out.len() {
            let n = (out.len() - done).min(TILE);
            let tile = &mut words[..4 * n];
            rng.fill_u32(tile);
            for k in 0..n {
                // Same expression order as sample_pair's cosine branch.
                let u1 = u01_f64(tile[4 * k], tile[4 * k + 1]).max(MIN_POS);
                let r = (-2.0 * u1.ln()).sqrt();
                let theta = std::f64::consts::TAU * u01_f64(tile[4 * k + 2], tile[4 * k + 3]);
                out[done + k] = self.mean + self.sigma * (r * theta.cos());
            }
            done += n;
        }
    }

    /// The normative word→normal transform applied to already-fetched
    /// stream words: sample `k` ← words `4k..4k+4` (one `draw_double2`
    /// pair), cosine branch. `words.len()` must be `4 * out.len()`.
    ///
    /// This is the single definition the engine path
    /// ([`BoxMuller::sample_fill`]), the backend path
    /// ([`Distribution::fill_backend`]), and the serve layer
    /// (`openrand::serve`) all reduce to, so no surface can drift.
    pub fn transform_words(&self, words: &[u32], out: &mut [f64]) {
        assert_eq!(words.len(), 4 * out.len(), "need 4 stream words per normal sample");
        for (k, slot) in out.iter_mut().enumerate() {
            // Same expression order as sample_pair's cosine branch.
            let u1 = u01_f64(words[4 * k], words[4 * k + 1]).max(MIN_POS);
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = std::f64::consts::TAU * u01_f64(words[4 * k + 2], words[4 * k + 3]);
            *slot = self.mean + self.sigma * (r * theta.cos());
        }
    }
}

impl Distribution<f64> for BoxMuller {
    #[inline]
    fn sample(&self, rng: &mut dyn Rng) -> f64 {
        self.sample_pair(rng).0
    }

    /// Backend bulk path: fetch the `4·out.len()` stream words of
    /// `(seed, ctr)` on the chosen arm and apply the identical
    /// cosine-branch transform, so the output is byte-identical to
    /// [`BoxMuller::sample_fill`] on a fresh `gen` engine — on every
    /// arm, by the backend contract. (The *device-trig* graphs
    /// `normal_f64_*` are a separate, tolerance-compared path; this one
    /// moves only raw words across the backend boundary and keeps the
    /// transform in libm, which is what makes it bitwise.)
    fn fill_backend(
        &self,
        backend: &mut dyn crate::backend::FillBackend,
        gen: crate::core::Generator,
        seed: u64,
        ctr: u32,
        out: &mut [f64],
    ) -> anyhow::Result<()> {
        let mut words = vec![0u32; 4 * out.len()];
        backend.fill_u32(gen, seed, ctr, &mut words)?;
        self.transform_words(&words, out);
        Ok(())
    }
}

/// The ziggurat tables (Marsaglia & Tsang 2000, 128 strips).
struct ZigTables {
    /// Strip acceptance thresholds, scaled to i32 range.
    kn: [u32; 128],
    /// Strip widths, scaled so `hz as f64 * wn[iz]` is the candidate x.
    wn: [f64; 128],
    /// Density values at the strip boundaries.
    fn_: [f64; 128],
}

/// Right edge of the base strip (the tail cutoff r).
const ZIG_R: f64 = 3.442619855899;
/// Area of each strip.
const ZIG_V: f64 = 9.91256303526217e-3;

fn tables() -> &'static ZigTables {
    static TABLES: OnceLock<ZigTables> = OnceLock::new();
    TABLES.get_or_init(|| {
        let m1 = 2147483648.0f64; // 2^31: i32 draws map onto [-m1, m1)
        let mut dn = ZIG_R;
        let mut tn = dn;
        let q = ZIG_V / (-0.5 * dn * dn).exp();
        let mut kn = [0u32; 128];
        let mut wn = [0.0f64; 128];
        let mut fn_ = [0.0f64; 128];
        kn[0] = ((dn / q) * m1) as u32;
        kn[1] = 0;
        wn[0] = q / m1;
        wn[127] = dn / m1;
        fn_[0] = 1.0;
        fn_[127] = (-0.5 * dn * dn).exp();
        for i in (1..=126usize).rev() {
            dn = (-2.0 * (ZIG_V / dn + (-0.5 * dn * dn).exp()).ln()).sqrt();
            kn[i + 1] = ((dn / tn) * m1) as u32;
            tn = dn;
            fn_[i] = (-0.5 * dn * dn).exp();
            wn[i] = dn / m1;
        }
        ZigTables { kn, wn, fn_ }
    })
}

/// Normal via the 128-strip ziggurat (Marsaglia & Tsang 2000).
///
/// Words consumed per sample: 1 on the fast path (~98% of draws); each
/// rejection round costs 2 more (one `draw_double`) plus occasionally a
/// fresh 1-word candidate; the base-strip tail costs 4 per tail round.
/// Counter-stream-deterministic, not device-graph-aligned.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ZigguratNormal {
    mean: f64,
    sigma: f64,
}

impl ZigguratNormal {
    /// Standard normal N(0, 1).
    pub fn standard() -> ZigguratNormal {
        ZigguratNormal { mean: 0.0, sigma: 1.0 }
    }

    /// N(mean, sigma²). Requires `sigma > 0`.
    pub fn new(mean: f64, sigma: f64) -> ZigguratNormal {
        assert!(mean.is_finite() && sigma.is_finite() && sigma > 0.0, "bad N({mean}, {sigma}²)");
        ZigguratNormal { mean, sigma }
    }

    /// One standard-normal draw (monomorphizing hot path).
    #[inline]
    pub fn sample_std<R: Rng + ?Sized>(&self, rng: &mut R) -> f64 {
        let t = tables();
        let mut hz = rng.next_u32() as i32;
        loop {
            let iz = (hz & 127) as usize;
            if (hz.unsigned_abs() as u64) < t.kn[iz] as u64 {
                // Fast path: the candidate lies strictly inside strip iz.
                return hz as f64 * t.wn[iz];
            }
            // Slow path (Marsaglia–Tsang "nfix").
            let x = hz as f64 * t.wn[iz];
            if iz == 0 {
                // Base strip: sample the tail x > r by Marsaglia's
                // exponential-majorant method.
                loop {
                    let xt = -(rng.draw_double().max(MIN_POS)).ln() * (1.0 / ZIG_R);
                    let yt = -(rng.draw_double().max(MIN_POS)).ln();
                    if yt + yt >= xt * xt {
                        return if hz > 0 { ZIG_R + xt } else { -(ZIG_R + xt) };
                    }
                }
            }
            if t.fn_[iz] + rng.draw_double() * (t.fn_[iz - 1] - t.fn_[iz]) < (-0.5 * x * x).exp() {
                return x;
            }
            hz = rng.next_u32() as i32;
        }
    }
}

impl Distribution<f64> for ZigguratNormal {
    #[inline]
    fn sample(&self, rng: &mut dyn Rng) -> f64 {
        self.mean + self.sigma * self.sample_std(rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{CounterRng, Philox, Squares};

    fn rel_close(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() <= tol * b.abs().max(1.0), "{a} vs {b}");
    }

    /// KAT: pinned against the plain-python transcription in
    /// `python/tests/test_kat.py::test_box_muller_kat` — identical
    /// constants on both sides. Stream (seed=7, ctr=1), the pair used by
    /// the `normal_f64_32768` device graph.
    #[test]
    fn box_muller_kat_seed7_ctr1() {
        let bm = BoxMuller::standard();
        let mut rng = Philox::new(7, 1);
        let want = [
            (1.7940642507332762, -0.42571280804811),
            (-1.3802003915778076, 0.9859339489835747),
            (0.8571078589741805, -0.6694835432076371),
            (0.16486889524918932, -1.9207164773300667),
        ];
        for (z0, z1) in want {
            let (a, b) = bm.sample_pair(&mut rng);
            rel_close(a, z0, 1e-12);
            rel_close(b, z1, 1e-12);
        }
    }

    #[test]
    fn box_muller_kat_seed42_ctr0() {
        let bm = BoxMuller::standard();
        let mut rng = Philox::new(42, 0);
        let want = [
            (0.8864975059014412, 0.43935606943792666),
            (-0.15660962291201797, -0.01371867883021048),
        ];
        for (z0, z1) in want {
            let (a, b) = bm.sample_pair(&mut rng);
            rel_close(a, z0, 1e-12);
            rel_close(b, z1, 1e-12);
        }
    }

    #[test]
    fn box_muller_consumes_exactly_one_block() {
        // sample == first f64-pair transform of the next counter block:
        // 4 words per call, no internal caching.
        let bm = BoxMuller::standard();
        let mut a = Philox::new(123, 9);
        let mut b = Philox::new(123, 9);
        for _ in 0..8 {
            let _ = bm.sample(&mut a);
            b.draw_double2();
        }
        assert_eq!(a.next_u32(), b.next_u32());
    }

    #[test]
    fn fill_backend_matches_engine_path() {
        use crate::backend::{HostParallel, HostSerial};
        use crate::core::Generator;
        let dist = BoxMuller::new(10.0, 2.0);
        let mut want = vec![0.0f64; 300];
        dist.sample_fill(&mut Philox::new(55, 6), &mut want);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        let mut a = vec![0.0f64; 300];
        dist.fill_backend(&mut HostSerial, Generator::Philox, 55, 6, &mut a).unwrap();
        assert_eq!(bits(&a), bits(&want));
        let mut b = vec![0.0f64; 300];
        dist.fill_backend(&mut HostParallel::new(4), Generator::Philox, 55, 6, &mut b)
            .unwrap();
        assert_eq!(bits(&b), bits(&want));
        // transform_words over pre-fetched words is the same definition.
        let mut words = vec![0u32; 4 * 300];
        crate::core::fill::fill_u32::<Philox>(55, 6, &mut words);
        let mut c = vec![0.0f64; 300];
        dist.transform_words(&words, &mut c);
        assert_eq!(bits(&c), bits(&want));
    }

    #[test]
    fn sample_fill_matches_repeated_sample() {
        for dist in [BoxMuller::standard(), BoxMuller::new(10.0, 2.0)] {
            for n in [0usize, 1, 255, 256, 257, 700] {
                let mut a = Philox::new(55, 6);
                let mut b = Philox::new(55, 6);
                let mut buf = vec![0.0f64; n];
                dist.sample_fill(&mut a, &mut buf);
                for (i, &v) in buf.iter().enumerate() {
                    assert_eq!(v.to_bits(), dist.sample(&mut b).to_bits(), "n={n} i={i}");
                }
                assert_eq!(a.next_u32(), b.next_u32(), "n={n}");
            }
        }
    }

    #[test]
    fn sample_fill_reproduces_kat_stream() {
        // First four fills of (seed=7, ctr=1) == the cosine-branch KAT
        // values shared with the python layer.
        let mut rng = Philox::new(7, 1);
        let mut buf = [0.0f64; 4];
        BoxMuller::standard().sample_fill(&mut rng, &mut buf);
        let want = [
            1.7940642507332762,
            -1.3802003915778076,
            0.8571078589741805,
            0.16486889524918932,
        ];
        for (got, want) in buf.iter().zip(want) {
            rel_close(*got, want, 1e-12);
        }
    }

    #[test]
    fn box_muller_mean_sigma_affine() {
        let std = BoxMuller::standard();
        let scaled = BoxMuller::new(10.0, 2.0);
        let mut a = Philox::new(4, 4);
        let mut b = Philox::new(4, 4);
        for _ in 0..32 {
            let z = std.sample(&mut a);
            let x = scaled.sample(&mut b);
            rel_close(x, 10.0 + 2.0 * z, 1e-15);
        }
    }

    /// KAT pinning the ziggurat table itself (the satellite requirement):
    /// spot values computed independently from the Marsaglia–Tsang
    /// recurrence (plain-python transcription). kn are integer truncations
    /// of transcendental expressions, so allow ±1 count for libm ulps.
    #[test]
    fn ziggurat_table_kat() {
        let t = tables();
        for (i, want) in
            [(0usize, 1991057938u32), (2, 1611602771), (64, 2128463758), (127, 2010539237)]
        {
            assert!(
                (t.kn[i] as i64 - want as i64).abs() <= 1,
                "kn[{i}] = {} want {want}",
                t.kn[i]
            );
        }
        assert_eq!(t.kn[1], 0);
        rel_close(t.wn[0], 1.729040521542798e-09, 1e-12);
        rel_close(t.wn[64], 7.138996746735849e-10, 1e-12);
        rel_close(t.wn[127], 1.6030947938091123e-09, 1e-12);
        assert_eq!(t.fn_[0], 1.0);
        rel_close(t.fn_[1], 0.9635996931270862, 1e-12);
        rel_close(t.fn_[64], 0.3087636380061811, 1e-12);
        rel_close(t.fn_[127], 0.002669629083880923, 1e-12);
        // Structural invariants: densities strictly decreasing, widths
        // positive.
        for i in 1..128 {
            assert!(t.fn_[i] < t.fn_[i - 1], "fn_ not decreasing at {i}");
            assert!(t.wn[i] > 0.0);
        }
    }

    #[test]
    fn ziggurat_deterministic_per_stream() {
        let z = ZigguratNormal::standard();
        let a: Vec<u64> =
            { let mut r = Philox::new(77, 5); (0..256).map(|_| z.sample(&mut r).to_bits()).collect() };
        let b: Vec<u64> =
            { let mut r = Philox::new(77, 5); (0..256).map(|_| z.sample(&mut r).to_bits()).collect() };
        assert_eq!(a, b);
    }

    #[test]
    fn ziggurat_moments_standard_normal() {
        let z = ZigguratNormal::standard();
        let mut rng = Philox::new(0x516, 0);
        let n = 200_000usize;
        let (mut s1, mut s2, mut s3, mut s4) = (0.0, 0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = z.sample(&mut rng);
            s1 += x;
            s2 += x * x;
            s3 += x * x * x;
            s4 += x * x * x * x;
        }
        let nf = n as f64;
        let mean = s1 / nf;
        let var = s2 / nf - mean * mean;
        let skew = s3 / nf;
        let kurt = s4 / nf;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
        assert!(skew.abs() < 0.08, "skew {skew}");
        assert!((kurt - 3.0).abs() < 0.2, "kurtosis {kurt}");
    }

    #[test]
    fn ziggurat_tail_reachable() {
        // The |x| > r tail must actually be sampled (base-strip branch).
        let z = ZigguratNormal::standard();
        let mut rng = Squares::new(0xF00D, 0);
        let mut tail = 0usize;
        for _ in 0..300_000 {
            if z.sample(&mut rng).abs() > ZIG_R {
                tail += 1;
            }
        }
        // P(|Z| > 3.4426) ≈ 5.76e-4 -> expect ~173 of 300k.
        assert!(tail > 60 && tail < 400, "tail count {tail}");
    }

    #[test]
    fn ziggurat_agrees_with_box_muller_distribution() {
        // Same distribution, different transforms: compare empirical CDFs
        // (two-sample KS at a loose threshold — this is a smoke test; the
        // calibrated version lives in stats::distcheck).
        let n = 40_000usize;
        let mut a: Vec<f64> = {
            let z = ZigguratNormal::standard();
            let mut r = Philox::new(1, 0);
            (0..n).map(|_| z.sample(&mut r)).collect()
        };
        let mut b: Vec<f64> = {
            let bm = BoxMuller::standard();
            let mut r = Philox::new(2, 0);
            (0..n).map(|_| bm.sample(&mut r)).collect()
        };
        a.sort_by(|x, y| x.partial_cmp(y).unwrap());
        b.sort_by(|x, y| x.partial_cmp(y).unwrap());
        let (mut i, mut j, mut d) = (0usize, 0usize, 0.0f64);
        while i < n && j < n {
            if a[i] <= b[j] {
                i += 1;
            } else {
                j += 1;
            }
            d = d.max((i as f64 / n as f64 - j as f64 / n as f64).abs());
        }
        // KS 1e-6 critical value for two samples of 40k is ~0.0246.
        assert!(d < 0.025, "two-sample KS D = {d}");
    }
}
