//! Distribution sampling on top of the counter-based draw API.
//!
//! This is the layer where cross-platform reproducibility is usually
//! lost (Randompack builds an entire library around exactly this
//! problem; PRAND ships distribution layers atop its parallel engines).
//! OpenRAND's answer is the same discipline the raw streams follow:
//! every sampler consumes a **documented, fixed word pattern** from the
//! underlying stream, so `(seed, ctr)` identifies the sample sequence
//! bitwise — on any thread, any platform, and (for the normative
//! Box–Muller path) on the device graphs too.
//!
//! ## The word-consumption contract (normative)
//!
//! Mirrors the conversion notes in `core/traits.rs`; the build-time
//! layer (`python/compile/kernels/normal.py` and `model.py`) implements
//! the same discipline for the device.
//!
//! | sampler                        | stream words consumed per sample |
//! |--------------------------------|----------------------------------|
//! | [`Uniform`]                    | 2 (one `draw_double`)            |
//! | [`BoxMuller`] `sample`/`sample_pair` | 4 (one `draw_double2`; with Philox, exactly one counter block) |
//! | [`ZigguratNormal`]             | 1 + variable (rejection; ~1.02 expected) |
//! | [`Exponential`]                | 2 (one `draw_double`, inversion) |
//! | [`Poisson`] (λ < 10, Knuth)    | 2·(k+1) for a sample of value k  |
//! | [`Poisson`] (λ ≥ 10, PTRS)     | 4 per attempt, variable          |
//! | [`Bernoulli`]                  | 2                                |
//! | [`Binomial`]                   | 2·n (n Bernoulli trials)         |
//! | [`DiscreteAlias`]              | 1 (+ rare Lemire rejection) + 2  |
//!
//! [`Uniform`] and [`BoxMuller`] additionally expose `sample_fill` bulk
//! fast paths that pull words through the engines' block-fill machinery;
//! they consume the identical word pattern (bit-identical output to
//! repeated `sample`), so the table above covers them unchanged. Bulk
//! sampling through a [`crate::backend::FillBackend`] arm goes through
//! the one trait surface [`Distribution::fill_backend`] (what
//! [`crate::stream::Stream::sample_fill`] routes) — still byte-identical
//! on every arm, per `docs/backends.md`.
//!
//! "Variable" samplers are still **counter-stream-deterministic**: the
//! number of words consumed is a pure function of the stream contents,
//! so the same `(seed, ctr)` always yields the same samples and leaves
//! the stream at the same position. What variable consumption does cost
//! is *cross-sampler* alignment: if device and host must agree bitwise,
//! use the fixed-pattern samplers ([`BoxMuller`], [`Uniform`],
//! [`Exponential`]) — that is why Box–Muller, not the ziggurat, is the
//! normative normal shared with the AOT graphs
//! (`normal_f64_*` artifacts, checked by `tests/cross_layer.rs`).
//!
//! ## Quick start
//!
//! ```
//! use openrand::core::{CounterRng, Philox};
//! use openrand::dist::{BoxMuller, Distribution, Poisson};
//! let mut rng = Philox::new(42, 0);
//! let z = BoxMuller::standard().sample(&mut rng);   // N(0,1)
//! let k = Poisson::new(4.5).sample(&mut rng);       // counts
//! assert!(z.is_finite());
//! assert!(k < 100);
//! ```

// Sampler availability under `--no-default-features`: the scalar
// fixed-pattern samplers that need only integer ops and f64 arithmetic
// (Uniform, Bernoulli, Binomial) are `no_std`; the transcendental
// samplers (BoxMuller/Ziggurat need ln/sqrt/sin/cos, Exponential ln,
// Poisson exp/ln/floor — `f64` intrinsics that live in `std`, and no
// libm is vendored) and the alias table (heap) are `std`-gated.
pub mod discrete;
#[cfg(feature = "std")]
pub mod exponential;
#[cfg(feature = "std")]
pub mod normal;
#[cfg(feature = "std")]
pub mod poisson;
pub mod uniform;

#[cfg(feature = "std")]
pub use discrete::DiscreteAlias;
pub use discrete::{Bernoulli, Binomial};
#[cfg(feature = "std")]
pub use exponential::Exponential;
#[cfg(feature = "std")]
pub use normal::{BoxMuller, ZigguratNormal};
#[cfg(feature = "std")]
pub use poisson::Poisson;
pub use uniform::Uniform;

use crate::core::traits::Rng;

/// A distribution that can be sampled from any OpenRAND engine.
///
/// Object-safe by design: the CLI streams continuous families through
/// boxed `Distribution<f64>` trait objects, and the `&mut dyn Rng`
/// parameter accepts any concrete engine by unsized coercion. Hot
/// paths that need monomorphization use the samplers' inherent generic
/// methods (e.g. [`BoxMuller::sample_pair`]) instead.
pub trait Distribution<T> {
    /// Draw one sample, advancing the stream per the module-level
    /// word-consumption contract.
    fn sample(&self, rng: &mut dyn Rng) -> T;

    /// Fill a slice with samples (identical to repeated [`sample`]
    /// calls — the contract makes this equivalence testable).
    ///
    /// [`sample`]: Distribution::sample
    fn fill(&self, rng: &mut dyn Rng, out: &mut [T]) {
        for slot in out.iter_mut() {
            *slot = self.sample(rng);
        }
    }

    /// Collect `n` samples.
    #[cfg(feature = "std")]
    fn sample_n(&self, rng: &mut dyn Rng, n: usize) -> Vec<T>
    where
        T: Default + Clone,
    {
        let mut out = vec![T::default(); n];
        self.fill(rng, &mut out);
        out
    }

    /// Key-addressed bulk sampling through a fill backend: write samples
    /// `0..out.len()` of the `(seed, ctr)` sample sequence of `gen` —
    /// bit-identical to [`fill`] over a fresh engine at `(seed, ctr)`.
    ///
    /// This is the one bulk surface the [`crate::stream::Stream`] facade
    /// routes through (the per-sampler `sample_fill_backend` spellings
    /// it replaced are gone). The default implementation draws
    /// host-side from a fresh engine — correct for every sampler,
    /// including the data-dependent-consumption ones, which have no
    /// bulk word pattern to ship across a backend. Fixed-pattern
    /// samplers ([`Uniform`], [`BoxMuller`]) override it to move raw
    /// stream words through the backend arm (byte-identical on every
    /// arm, per `docs/backends.md`) and transform host-side.
    ///
    /// [`fill`]: Distribution::fill
    #[cfg(feature = "std")]
    fn fill_backend(
        &self,
        backend: &mut dyn crate::backend::FillBackend,
        gen: crate::core::Generator,
        seed: u64,
        ctr: u32,
        out: &mut [T],
    ) -> anyhow::Result<()> {
        let _ = backend; // no fixed bulk word pattern -> host-side draw
        gen.with_rng(seed, ctr, |rng| self.fill(rng, out));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{CounterRng, Philox};

    #[test]
    fn trait_is_object_safe_and_dispatches() {
        let dists: Vec<Box<dyn Distribution<f64>>> = vec![
            Box::new(Uniform::new(0.0, 1.0)),
            Box::new(BoxMuller::standard()),
            Box::new(ZigguratNormal::standard()),
            Box::new(Exponential::new(1.0)),
        ];
        let mut rng = Philox::new(9, 9);
        for d in &dists {
            assert!(d.sample(&mut rng).is_finite());
        }
    }

    #[test]
    fn fill_matches_repeated_sample() {
        let d = BoxMuller::standard();
        let mut a = Philox::new(3, 1);
        let mut b = Philox::new(3, 1);
        let mut buf = [0.0f64; 17];
        d.fill(&mut a, &mut buf);
        for (i, &v) in buf.iter().enumerate() {
            assert_eq!(v.to_bits(), d.sample(&mut b).to_bits(), "sample {i}");
        }
        // Streams left at the same position.
        assert_eq!(a.next_u32(), b.next_u32());
    }

    #[test]
    fn fill_backend_default_matches_host_fill() {
        use crate::backend::{HostParallel, HostSerial};
        use crate::core::Generator;
        // The trait default must equal `fill` on a fresh engine for a
        // data-dependent sampler (no bulk pattern), on any arm.
        let d = ZigguratNormal::standard();
        let mut want = vec![0.0f64; 129];
        d.fill(&mut Philox::new(6, 2), &mut want);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        let mut a = vec![0.0f64; 129];
        d.fill_backend(&mut HostSerial, Generator::Philox, 6, 2, &mut a).unwrap();
        assert_eq!(bits(&a), bits(&want));
        let mut b = vec![0.0f64; 129];
        d.fill_backend(&mut HostParallel::new(4), Generator::Philox, 6, 2, &mut b).unwrap();
        assert_eq!(bits(&b), bits(&want));
    }

    #[test]
    fn sample_n_length_and_determinism() {
        let d = Exponential::new(2.0);
        let xs = d.sample_n(&mut Philox::new(1, 2), 64);
        let ys = d.sample_n(&mut Philox::new(1, 2), 64);
        assert_eq!(xs.len(), 64);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&xs), bits(&ys));
    }
}
