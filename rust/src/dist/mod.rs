//! Distribution sampling on top of the counter-based draw API.
//!
//! This is the layer where cross-platform reproducibility is usually
//! lost (Randompack builds an entire library around exactly this
//! problem; PRAND ships distribution layers atop its parallel engines).
//! OpenRAND's answer is the same discipline the raw streams follow:
//! every sampler consumes a **documented, fixed word pattern** from the
//! underlying stream, so `(seed, ctr)` identifies the sample sequence
//! bitwise — on any thread, any platform, and (for the normative
//! Box–Muller path) on the device graphs too.
//!
//! ## The word-consumption contract (normative)
//!
//! Mirrors the conversion notes in `core/traits.rs`; the build-time
//! layer (`python/compile/kernels/normal.py` and `model.py`) implements
//! the same discipline for the device.
//!
//! | sampler                        | stream words consumed per sample |
//! |--------------------------------|----------------------------------|
//! | [`Uniform`]                    | 2 (one `draw_double`)            |
//! | [`BoxMuller`] `sample`/`sample_pair` | 4 (one `draw_double2`; with Philox, exactly one counter block) |
//! | [`ZigguratNormal`]             | 1 + variable (rejection; ~1.02 expected) |
//! | [`Exponential`]                | 2 (one `draw_double`, inversion) |
//! | [`Poisson`] (λ < 10, Knuth)    | 2·(k+1) for a sample of value k  |
//! | [`Poisson`] (λ ≥ 10, PTRS)     | 4 per attempt, variable          |
//! | [`Bernoulli`]                  | 2                                |
//! | [`Binomial`]                   | 2·n (n Bernoulli trials)         |
//! | [`DiscreteAlias`]              | 1 (+ rare Lemire rejection) + 2  |
//!
//! [`Uniform`] and [`BoxMuller`] additionally expose `sample_fill` bulk
//! fast paths that pull words through the engines' block-fill machinery;
//! they consume the identical word pattern (bit-identical output to
//! repeated `sample`), so the table above covers them unchanged. Their
//! `sample_fill_backend` variants route the same word pattern through a
//! [`crate::backend::FillBackend`] handle (serial, sharded-parallel, or
//! device) — still byte-identical on every arm, per `docs/backends.md`.
//!
//! "Variable" samplers are still **counter-stream-deterministic**: the
//! number of words consumed is a pure function of the stream contents,
//! so the same `(seed, ctr)` always yields the same samples and leaves
//! the stream at the same position. What variable consumption does cost
//! is *cross-sampler* alignment: if device and host must agree bitwise,
//! use the fixed-pattern samplers ([`BoxMuller`], [`Uniform`],
//! [`Exponential`]) — that is why Box–Muller, not the ziggurat, is the
//! normative normal shared with the AOT graphs
//! (`normal_f64_*` artifacts, checked by `tests/cross_layer.rs`).
//!
//! ## Quick start
//!
//! ```
//! use openrand::core::{CounterRng, Philox};
//! use openrand::dist::{BoxMuller, Distribution, Poisson};
//! let mut rng = Philox::new(42, 0);
//! let z = BoxMuller::standard().sample(&mut rng);   // N(0,1)
//! let k = Poisson::new(4.5).sample(&mut rng);       // counts
//! assert!(z.is_finite());
//! assert!(k < 100);
//! ```

pub mod discrete;
pub mod exponential;
pub mod normal;
pub mod poisson;
pub mod uniform;

pub use discrete::{Bernoulli, Binomial, DiscreteAlias};
pub use exponential::Exponential;
pub use normal::{BoxMuller, ZigguratNormal};
pub use poisson::Poisson;
pub use uniform::Uniform;

use crate::core::traits::Rng;

/// A distribution that can be sampled from any OpenRAND engine.
///
/// Object-safe by design: the CLI streams continuous families through
/// boxed `Distribution<f64>` trait objects, and the `&mut dyn Rng`
/// parameter accepts any concrete engine by unsized coercion. Hot
/// paths that need monomorphization use the samplers' inherent generic
/// methods (e.g. [`BoxMuller::sample_pair`]) instead.
pub trait Distribution<T> {
    /// Draw one sample, advancing the stream per the module-level
    /// word-consumption contract.
    fn sample(&self, rng: &mut dyn Rng) -> T;

    /// Fill a slice with samples (identical to repeated [`sample`]
    /// calls — the contract makes this equivalence testable).
    ///
    /// [`sample`]: Distribution::sample
    fn fill(&self, rng: &mut dyn Rng, out: &mut [T]) {
        for slot in out.iter_mut() {
            *slot = self.sample(rng);
        }
    }

    /// Collect `n` samples.
    fn sample_n(&self, rng: &mut dyn Rng, n: usize) -> Vec<T>
    where
        T: Default + Clone,
    {
        let mut out = vec![T::default(); n];
        self.fill(rng, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{CounterRng, Philox};

    #[test]
    fn trait_is_object_safe_and_dispatches() {
        let dists: Vec<Box<dyn Distribution<f64>>> = vec![
            Box::new(Uniform::new(0.0, 1.0)),
            Box::new(BoxMuller::standard()),
            Box::new(ZigguratNormal::standard()),
            Box::new(Exponential::new(1.0)),
        ];
        let mut rng = Philox::new(9, 9);
        for d in &dists {
            assert!(d.sample(&mut rng).is_finite());
        }
    }

    #[test]
    fn fill_matches_repeated_sample() {
        let d = BoxMuller::standard();
        let mut a = Philox::new(3, 1);
        let mut b = Philox::new(3, 1);
        let mut buf = [0.0f64; 17];
        d.fill(&mut a, &mut buf);
        for (i, &v) in buf.iter().enumerate() {
            assert_eq!(v.to_bits(), d.sample(&mut b).to_bits(), "sample {i}");
        }
        // Streams left at the same position.
        assert_eq!(a.next_u32(), b.next_u32());
    }

    #[test]
    fn sample_n_length_and_determinism() {
        let d = Exponential::new(2.0);
        let xs = d.sample_n(&mut Philox::new(1, 2), 64);
        let ys = d.sample_n(&mut Philox::new(1, 2), 64);
        assert_eq!(xs.len(), 64);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&xs), bits(&ys));
    }
}
