//! The pinned KAT smoke — one `no_std`-safe battery of the cross-layer
//! known-answer vectors, runnable from every language surface.
//!
//! These are the *same literals* asserted by the Rust unit tests
//! (`core/philox.rs`, `stream/mod.rs`, …), pinned against the jnp
//! oracle by `python/tests/` (`test_kat.py`, `test_stream_keys.py`,
//! `test_jump_ahead.py`, `test_ffi_vectors.py`), and replayed through
//! the C ABI by `ffi/tests/kat_harness.c`. Three languages, one table.
//!
//! The module deliberately avoids everything `std`: no allocation, no
//! formatting machinery beyond `&'static str`, no panics — each check
//! returns `Err(name)` naming the first vector that failed, so the FFI
//! layer can surface it as an error code and a freestanding caller can
//! print it. `rust/tests/properties.rs` runs [`run`] in both feature
//! lanes (the feature-matrix guard): the words must be identical with
//! and without `std` because nothing below this module is allowed to
//! change behavior across that boundary.

use crate::core::{
    CounterRng, Generator, Philox, Philox2x32, Rng, Squares, Threefry, Threefry2x32, Tyche, TycheI,
};
use crate::stream::{derive_child_seed, StreamKey};

/// The shared engine-word table: stream words `0..10` of `(seed = 7,
/// ctr = 1)` for every engine, in [`Generator::ALL`] order. Mirrored
/// verbatim in `python/tests/test_ffi_vectors.py` and
/// `ffi/tests/kat_harness.c`.
pub const ENGINE_WORDS_S7_C1: [[u32; 10]; 7] = [
    // philox (Philox4x32-10)
    [
        0x2EC4_F55D, 0x249E_F5F4, 0xF681_EC7F, 0x807A_6601, 0x3CBE_7593, 0x2195_1225, 0x66BA_2E25,
        0x5159_B36A, 0x8DB4_CE21, 0x498F_F58B,
    ],
    // philox2x32
    [
        0x5DD0_9A2F, 0x6B00_841E, 0xAC55_AAD4, 0x858C_5948, 0xDCC2_23D7, 0xB92B_6CAC, 0x0724_2571,
        0x304D_3D15, 0x20C6_D682, 0xC8FC_CB4F,
    ],
    // threefry (Threefry4x32-20)
    [
        0xD73C_EA92, 0xD56D_C136, 0xD744_F371, 0x6D23_9EE4, 0xBE20_0A6E, 0x0048_1B5C, 0xF8EB_5F46,
        0x3405_B98C, 0xDF0D_1159, 0x35B5_42BA,
    ],
    // threefry2x32
    [
        0x3AA7_5E81, 0x7DBD_B64C, 0xECA7_0012, 0x97F1_6955, 0x636D_7473, 0x6ECE_15CE, 0xC93D_5ECF,
        0xD022_2576, 0x1E98_EC3E, 0x975E_8B5F,
    ],
    // squares
    [
        0xC58E_0D20, 0x4C1E_EAB3, 0xB2CF_997F, 0x7900_D050, 0x6B50_E8E1, 0x648D_D2AA, 0x7BCC_BCFB,
        0xCE63_EFD7, 0x5B52_36D3, 0xD33D_98F1,
    ],
    // tyche
    [
        0x3CB8_0C83, 0x0128_E5AF, 0x9C1F_4904, 0xECA4_6A3C, 0x2ACC_26BE, 0x6912_D082, 0x9831_8013,
        0x44F8_C1FA, 0x0870_3B44, 0xFD4C_1C53,
    ],
    // tyche_i
    [
        0x208B_EFEA, 0x3079_BF27, 0xA860_6EB3, 0x8839_063A, 0x6473_30F1, 0xC117_0F7E, 0xC298_E6A6,
        0x4192_5E91, 0x5902_AA9D, 0xC3E5_37E3,
    ],
];

/// `next_u64` of Philox `(7, 1)` — words 0, 1 first-word-high (§2).
pub const PHILOX_S7_C1_U64: u64 = 0x2EC4_F55D_249E_F5F4;
/// `draw_double` of Philox `(7, 1)` as an f64 bit pattern (top 53 bits
/// of [`PHILOX_S7_C1_U64`]; the value is 0.1826928474807763).
pub const PHILOX_S7_C1_F64_BITS: u64 = 0x3FC7_627A_AE92_4F78;
/// `draw_float` of Philox `(7, 1)` as an f32 bit pattern (top 24 bits
/// of word 0; the value is ~0.18269283).
pub const PHILOX_S7_C1_F32_BITS: u32 = 0x3E3B_13D4;

/// splitmix64(0) — the published reference vector the key mix builds on.
pub const SPLITMIX64_ZERO: u64 = 0xE220_A839_7B1D_CDAF;
/// `derive_child_seed(7, 0, 3)` — `root(7).child(3)`.
pub const CHILD_SEED_R7_C3: u64 = 0xBC83_12B7_34DE_4237;
/// `root(7).child(3).child(5)` — the grandchild literal.
pub const GRANDCHILD_SEED_R7_C3_C5: u64 = 0x2D4C_1D0A_8595_6C49;
/// `root(7).epoch(2).child(3)` — epoch separates child spaces.
pub const CHILD_SEED_R7_E2_C3: u64 = 0x2E49_EAED_C17E_2B71;
/// Philox words 0, 1 of the derived stream `root(7).child(3).epoch(1)`.
pub const CHILD_STREAM_WORDS: [u32; 2] = [0x9022_9F37, 0x89AF_95F5];
/// `draw_double` bits of that derived stream (0.5630282888975542).
pub const CHILD_STREAM_F64_BITS: u64 = 0x3FE2_0453_E6F1_35F2;

/// Run every pinned check; `Err` names the first failing vector.
pub fn run() -> Result<(), &'static str> {
    engine_words()?;
    conversions()?;
    key_derivation()?;
    jump_ahead()?;
    Ok(())
}

/// Words `0..10` of `(7, 1)` for all seven engines, drawn twice: word
/// at a time through [`Rng::next_u32`] and bulk through
/// [`Rng::fill_u32`] (the block path) — both must hit the table.
pub fn engine_words() -> Result<(), &'static str> {
    for (gi, g) in Generator::ALL.into_iter().enumerate() {
        let want = &ENGINE_WORDS_S7_C1[gi];
        let serial_ok = g.with_rng(7, 1, |r| {
            let mut ok = true;
            for w in want.iter() {
                ok &= r.next_u32() == *w;
            }
            ok
        });
        if !serial_ok {
            return Err("engine_words: next_u32 diverged from the pinned table");
        }
        let mut buf = [0u32; 10];
        g.with_rng(7, 1, |r| r.fill_u32(&mut buf));
        if buf != *want {
            return Err("engine_words: fill_u32 diverged from the pinned table");
        }
    }
    Ok(())
}

/// The §2 conversions: u64 word order, f64 top-53, f32 top-24.
pub fn conversions() -> Result<(), &'static str> {
    let mut r = Philox::new(7, 1);
    if r.next_u64() != PHILOX_S7_C1_U64 {
        return Err("conversions: next_u64 word order");
    }
    let mut r = Philox::new(7, 1);
    if r.draw_double().to_bits() != PHILOX_S7_C1_F64_BITS {
        return Err("conversions: draw_double bits");
    }
    let mut r = Philox::new(7, 1);
    if r.draw_float().to_bits() != PHILOX_S7_C1_F32_BITS {
        return Err("conversions: draw_float bits");
    }
    Ok(())
}

/// The normative key mix and the streams it addresses.
pub fn key_derivation() -> Result<(), &'static str> {
    if crate::core::counter::splitmix64(0) != SPLITMIX64_ZERO {
        return Err("key_derivation: splitmix64 reference vector");
    }
    if derive_child_seed(7, 0, 3) != CHILD_SEED_R7_C3 {
        return Err("key_derivation: derive_child_seed(7, 0, 3)");
    }
    let k = StreamKey::root(7).child(3).epoch(1);
    if k.seed() != CHILD_SEED_R7_C3 || k.ctr() != 1 {
        return Err("key_derivation: root(7).child(3).epoch(1) address");
    }
    if StreamKey::root(7).child(3).child(5).seed() != GRANDCHILD_SEED_R7_C3_C5 {
        return Err("key_derivation: grandchild seed");
    }
    if StreamKey::root(7).epoch(2).child(3).seed() != CHILD_SEED_R7_E2_C3 {
        return Err("key_derivation: epoch-separated child seed");
    }
    let mut s = Philox::new(k.seed(), k.ctr());
    if s.next_u32() != CHILD_STREAM_WORDS[0] || s.next_u32() != CHILD_STREAM_WORDS[1] {
        return Err("key_derivation: derived stream words");
    }
    let mut s = Philox::new(k.seed(), k.ctr());
    if s.draw_double().to_bits() != CHILD_STREAM_F64_BITS {
        return Err("key_derivation: derived stream draw_double bits");
    }
    Ok(())
}

/// The jump-ahead contract literals (`test_jump_ahead.py`): per-engine
/// `jump()` strides, period wraps, and Tyche's O(n) stepping.
pub fn jump_ahead() -> Result<(), &'static str> {
    let mut j = Philox::new(7, 1);
    j.jump(); // 2^33 words
    if j.next_u32() != 0x3A29_4131 {
        return Err("jump_ahead: philox jump 2^33");
    }
    let mut far = Philox::new(7, 1);
    far.set_position((1 << 34) + 2); // block 2^32, lane 2
    if far.next_u32() != 0x275A_0C0F {
        return Err("jump_ahead: philox word 2^34+2");
    }
    let mut a = Philox::new(7, 1);
    a.advance(9);
    if a.next_u32() != ENGINE_WORDS_S7_C1[0][9] {
        return Err("jump_ahead: philox advance(9)");
    }
    let mut j = Philox2x32::new(7, 1);
    j.jump(); // 2^16 words
    if j.next_u32() != 0x44EF_38AA {
        return Err("jump_ahead: philox2x32 jump 2^16");
    }
    let mut w = Philox2x32::new(7, 1);
    w.advance((1 << 33) + 5); // period 2^33 wrap: == advance(5)
    if w.next_u32() != ENGINE_WORDS_S7_C1[1][5] {
        return Err("jump_ahead: philox2x32 period wrap");
    }
    let mut j = Threefry::new(2, 6);
    j.jump();
    if j.next_u32() != 0xDFC6_93FF {
        return Err("jump_ahead: threefry jump 2^33");
    }
    let mut far = Threefry::new(2, 6);
    far.set_position(1 << 34); // block 2^32, lane 0
    if far.next_u32() != 0x31AD_C0A0 {
        return Err("jump_ahead: threefry word 2^34");
    }
    let mut j = Threefry2x32::new(5, 3);
    j.jump();
    if j.next_u32() != 0xFB12_54E1 {
        return Err("jump_ahead: threefry2x32 jump 2^16");
    }
    let mut j = Squares::new(7, 1);
    j.jump(); // 2^16 words
    if j.next_u32() != 0x853F_0F97 {
        return Err("jump_ahead: squares jump 2^16");
    }
    let mut w = Squares::new(7, 1);
    w.advance((1u64 << 32) + 3); // period 2^32 wrap: == advance(3)
    if w.next_u32() != ENGINE_WORDS_S7_C1[4][3] {
        return Err("jump_ahead: squares period wrap");
    }
    // Tyche/Tyche-i: no O(1) jump (JUMP_LOG2 == None is part of the
    // contract); advance is exact stepping.
    if Tyche::JUMP_LOG2.is_some() || TycheI::JUMP_LOG2.is_some() {
        return Err("jump_ahead: tyche must not advertise a jump stride");
    }
    let mut t = Tyche::new(7, 1);
    t.advance(5);
    if t.next_u32() != ENGINE_WORDS_S7_C1[5][5] {
        return Err("jump_ahead: tyche advance(5)");
    }
    let mut t = TycheI::new(7, 1);
    t.advance(5);
    if t.next_u32() != ENGINE_WORDS_S7_C1[6][5] {
        return Err("jump_ahead: tyche_i advance(5)");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn selftest_passes() {
        super::run().unwrap();
    }
}
