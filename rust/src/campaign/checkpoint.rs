//! The versioned campaign checkpoint format (normative spec:
//! `docs/campaigns.md` §"Checkpoint format v1").
//!
//! A checkpoint is the **complete** identity of a paused campaign:
//! the model, the generator, the `StreamKey` address, the tile size,
//! the epoch count, and the particle arrays. Deliberately absent: any
//! engine state. Counter-based streams are addressed, not carried —
//! `key.epoch(t).child(tile)` reconstructs every future draw, which is
//! what makes resume == never-stopped provable bitwise.
//!
//! Layout (all integers little-endian; `n` = particle count):
//!
//! ```text
//! offset  size  field
//!      0     8  magic          b"ORCAMPCK"
//!      8     4  version        u32, currently 1
//!     12     4  model tag      0 = brownian, 1 = dpd
//!     16     4  generator tag  normative table (see [`generator_tag`])
//!     20     4  epoch          completed epochs; resume continues here
//!     24     8  key seed       u64 (root seed of the campaign key)
//!     32     4  key ctr        u32, must be 0 in v1 (epochs are derived)
//!     36     4  tile           particles per tile (addressing identity)
//!     40     8  n              u64 particle count
//!     48   8·n  x              f64 bit patterns
//!  48+8n   8·n  y
//! 48+16n   8·n  vx
//! 48+24n   8·n  vy
//! 48+32n     8  checksum       FNV-1a 64 over all preceding bytes
//! ```
//!
//! Decoding rejects malformed input with a typed [`CheckpointError`]
//! (never a panic): magic, then version, then size (derived from the
//! header `n`, checked before any allocation so a corrupt length can't
//! OOM), then checksum, then field validation.

use super::Model;
use crate::core::Generator;
use crate::stream::StreamKey;
use crate::util::hash::Fnv1a;
use std::fmt;
use std::path::Path;

/// File magic — the first 8 bytes of every campaign checkpoint.
pub const MAGIC: [u8; 8] = *b"ORCAMPCK";

/// Current (and only) format version.
pub const FORMAT_VERSION: u32 = 1;

/// Fixed header size in bytes (through the `n` field).
pub const HEADER_BYTES: usize = 48;

/// Trailing checksum size in bytes.
pub const TRAILER_BYTES: usize = 8;

/// Why a checkpoint failed to decode. Every malformed input maps to a
/// typed variant; decoding never panics.
#[derive(Debug)]
pub enum CheckpointError {
    /// Underlying file I/O failed.
    Io(std::io::Error),
    /// The first 8 bytes are not [`MAGIC`].
    BadMagic,
    /// The header declares a version this build cannot read.
    UnsupportedVersion(u32),
    /// Fewer bytes than the header-derived size (`expected` is the full
    /// size the header implies; for inputs shorter than a header it is
    /// the minimum decodable size).
    Truncated { expected: u64, got: u64 },
    /// More bytes than the header-derived size.
    TrailingBytes { expected: u64, got: u64 },
    /// The FNV-1a trailer does not match the content.
    ChecksumMismatch { stored: u64, computed: u64 },
    /// Unknown model tag.
    BadModel(u32),
    /// Unknown generator tag.
    BadGenerator(u32),
    /// The stored key carries a non-zero counter — v1 keys must be
    /// epoch-free (epochs are derived per timestep).
    BadKey(u32),
    /// Zero or over-large tile size.
    BadTile(u32),
}

impl fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "checkpoint i/o: {e}"),
            CheckpointError::BadMagic => write!(f, "not a campaign checkpoint (bad magic)"),
            CheckpointError::UnsupportedVersion(v) => {
                write!(f, "unsupported checkpoint version {v} (this build reads {FORMAT_VERSION})")
            }
            CheckpointError::Truncated { expected, got } => {
                write!(f, "truncated checkpoint: {got} bytes, expected {expected}")
            }
            CheckpointError::TrailingBytes { expected, got } => {
                write!(f, "trailing bytes after checkpoint: {got} bytes, expected {expected}")
            }
            CheckpointError::ChecksumMismatch { stored, computed } => write!(
                f,
                "checkpoint checksum mismatch: stored {stored:016x}, computed {computed:016x}"
            ),
            CheckpointError::BadModel(t) => write!(f, "unknown model tag {t}"),
            CheckpointError::BadGenerator(t) => write!(f, "unknown generator tag {t}"),
            CheckpointError::BadKey(ctr) => {
                write!(f, "checkpoint key has non-zero ctr {ctr} (v1 keys are epoch-free)")
            }
            CheckpointError::BadTile(t) => write!(f, "bad tile size {t}"),
        }
    }
}

impl std::error::Error for CheckpointError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CheckpointError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::Io(e)
    }
}

/// Normative model tags of format v1 — never reorder.
pub fn model_tag(m: Model) -> u32 {
    match m {
        Model::Brownian => 0,
        Model::Dpd => 1,
    }
}

/// Inverse of [`model_tag`].
pub fn model_from_tag(t: u32) -> Option<Model> {
    match t {
        0 => Some(Model::Brownian),
        1 => Some(Model::Dpd),
        _ => None,
    }
}

/// Normative generator tags of format v1 — never reorder. (These are
/// part of the on-disk contract; `Generator` enum order is not.)
pub fn generator_tag(g: Generator) -> u32 {
    match g {
        Generator::Philox => 0,
        Generator::Philox2x32 => 1,
        Generator::Threefry => 2,
        Generator::Threefry2x32 => 3,
        Generator::Squares => 4,
        Generator::Tyche => 5,
        Generator::TycheI => 6,
    }
}

/// Inverse of [`generator_tag`].
pub fn generator_from_tag(t: u32) -> Option<Generator> {
    match t {
        0 => Some(Generator::Philox),
        1 => Some(Generator::Philox2x32),
        2 => Some(Generator::Threefry),
        3 => Some(Generator::Threefry2x32),
        4 => Some(Generator::Squares),
        5 => Some(Generator::Tyche),
        6 => Some(Generator::TycheI),
        _ => None,
    }
}

/// A decoded (or to-be-encoded) campaign checkpoint.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub model: Model,
    pub gen: Generator,
    /// The campaign's stream address (ctr always 0 in v1).
    pub key: StreamKey,
    /// Completed epochs; resume continues from here.
    pub epoch: u32,
    /// Particles per tile — part of the trajectory identity.
    pub tile: u32,
    pub x: Vec<f64>,
    pub y: Vec<f64>,
    pub vx: Vec<f64>,
    pub vy: Vec<f64>,
}

impl Checkpoint {
    pub fn n_particles(&self) -> usize {
        self.x.len()
    }

    /// Total encoded size in bytes for `n` particles.
    pub fn encoded_len(n: usize) -> usize {
        HEADER_BYTES + 32 * n + TRAILER_BYTES
    }

    /// Serialize to the v1 byte layout (deterministic: the same state
    /// always encodes to the same bytes, which is what lets CI `cmp`
    /// resumed-vs-uninterrupted end checkpoints).
    pub fn encode(&self) -> Vec<u8> {
        let n = self.x.len();
        debug_assert_eq!(self.y.len(), n);
        debug_assert_eq!(self.vx.len(), n);
        debug_assert_eq!(self.vy.len(), n);
        let mut out = Vec::with_capacity(Self::encoded_len(n));
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        out.extend_from_slice(&model_tag(self.model).to_le_bytes());
        out.extend_from_slice(&generator_tag(self.gen).to_le_bytes());
        out.extend_from_slice(&self.epoch.to_le_bytes());
        out.extend_from_slice(&self.key.seed().to_le_bytes());
        out.extend_from_slice(&self.key.ctr().to_le_bytes());
        out.extend_from_slice(&self.tile.to_le_bytes());
        out.extend_from_slice(&(n as u64).to_le_bytes());
        for arr in [&self.x, &self.y, &self.vx, &self.vy] {
            for v in arr.iter() {
                out.extend_from_slice(&v.to_bits().to_le_bytes());
            }
        }
        let mut h = Fnv1a::new();
        for &b in &out {
            h.write_u8(b);
        }
        out.extend_from_slice(&h.finish().to_le_bytes());
        out
    }

    /// Decode the v1 byte layout, rejecting malformed input with a
    /// typed error (see the module docs for the validation order).
    pub fn decode(bytes: &[u8]) -> Result<Checkpoint, CheckpointError> {
        let min = (HEADER_BYTES + TRAILER_BYTES) as u64;
        if (bytes.len() as u64) < min {
            return Err(CheckpointError::Truncated { expected: min, got: bytes.len() as u64 });
        }
        if bytes[..8] != MAGIC {
            return Err(CheckpointError::BadMagic);
        }
        let u32at = |o: usize| u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
        let u64at = |o: usize| u64::from_le_bytes(bytes[o..o + 8].try_into().unwrap());
        let version = u32at(8);
        if version != FORMAT_VERSION {
            return Err(CheckpointError::UnsupportedVersion(version));
        }
        // Size check from the header-declared n, in u64 so a garbage n
        // can't overflow — and before any allocation, so it can't OOM.
        let n64 = u64at(40);
        let expected = n64
            .checked_mul(32)
            .and_then(|p| p.checked_add(min))
            .ok_or(CheckpointError::Truncated { expected: u64::MAX, got: bytes.len() as u64 })?;
        match (bytes.len() as u64).cmp(&expected) {
            std::cmp::Ordering::Less => {
                return Err(CheckpointError::Truncated { expected, got: bytes.len() as u64 })
            }
            std::cmp::Ordering::Greater => {
                return Err(CheckpointError::TrailingBytes { expected, got: bytes.len() as u64 })
            }
            std::cmp::Ordering::Equal => {}
        }
        let body = &bytes[..bytes.len() - TRAILER_BYTES];
        let stored = u64at(bytes.len() - TRAILER_BYTES);
        let mut h = Fnv1a::new();
        for &b in body {
            h.write_u8(b);
        }
        let computed = h.finish();
        if stored != computed {
            return Err(CheckpointError::ChecksumMismatch { stored, computed });
        }
        let model = model_from_tag(u32at(12)).ok_or(CheckpointError::BadModel(u32at(12)))?;
        let gen = generator_from_tag(u32at(16)).ok_or(CheckpointError::BadGenerator(u32at(16)))?;
        let epoch = u32at(20);
        let seed = u64at(24);
        let ctr = u32at(32);
        if ctr != 0 {
            return Err(CheckpointError::BadKey(ctr));
        }
        let tile = u32at(36);
        if tile == 0 || tile as usize > super::MAX_TILE {
            return Err(CheckpointError::BadTile(tile));
        }
        let n = n64 as usize;
        let mut off = HEADER_BYTES;
        let mut read_arr = || {
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                v.push(f64::from_bits(u64::from_le_bytes(
                    bytes[off..off + 8].try_into().unwrap(),
                )));
                off += 8;
            }
            v
        };
        let x = read_arr();
        let y = read_arr();
        let vx = read_arr();
        let vy = read_arr();
        Ok(Checkpoint { model, gen, key: StreamKey::root(seed), epoch, tile, x, y, vx, vy })
    }

    /// Write the encoded checkpoint to a file.
    pub fn write_file(&self, path: impl AsRef<Path>) -> Result<(), CheckpointError> {
        std::fs::write(path, self.encode()).map_err(CheckpointError::Io)
    }

    /// Read and decode a checkpoint file.
    pub fn read_file(path: impl AsRef<Path>) -> Result<Checkpoint, CheckpointError> {
        let bytes = std::fs::read(path).map_err(CheckpointError::Io)?;
        Self::decode(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(n: usize) -> Checkpoint {
        Checkpoint {
            model: Model::Brownian,
            gen: Generator::Threefry,
            key: StreamKey::root(0xDEAD_BEEF),
            epoch: 17,
            tile: 4096,
            x: (0..n).map(|i| i as f64 * 0.5).collect(),
            y: (0..n).map(|i| -(i as f64)).collect(),
            vx: vec![0.25; n],
            vy: vec![-0.0; n], // -0.0 must survive bitwise
        }
    }

    #[test]
    fn byte_layout_is_pinned_little_endian() {
        // The endianness pin (portability audit, docs/ffi.md §Layout):
        // the v1 format is little-endian byte for byte, including the
        // f64 payloads (IEEE 754 bits, LE) and the FNV-1a trailer. The
        // expected octets — trailer included — were computed by an
        // independent implementation, so a host-endian encode (which
        // every roundtrip test would miss) or an accidental change to
        // the hash constants fails here on any machine.
        let ck = Checkpoint {
            model: Model::Brownian,
            gen: Generator::Threefry,
            key: StreamKey::root(0x0102_0304_0506_0708),
            epoch: 7,
            tile: 128,
            x: vec![1.5],
            y: vec![-0.0],
            vx: vec![-2.0],
            vy: vec![f64::from_bits(1)], // smallest subnormal
        };
        #[rustfmt::skip]
        let want: [u8; 88] = [
            0x4F, 0x52, 0x43, 0x41, 0x4D, 0x50, 0x43, 0x4B, // "ORCAMPCK"
            0x01, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // version, model
            0x02, 0x00, 0x00, 0x00, 0x07, 0x00, 0x00, 0x00, // gen, epoch
            0x08, 0x07, 0x06, 0x05, 0x04, 0x03, 0x02, 0x01, // seed u64le
            0x00, 0x00, 0x00, 0x00, 0x80, 0x00, 0x00, 0x00, // ctr, tile
            0x01, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // n u64le
            0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xF8, 0x3F, // x = 1.5
            0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x80, // y = -0.0
            0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xC0, // vx = -2.0
            0x01, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // vy subnormal
            0x72, 0xFF, 0x43, 0x73, 0xB3, 0x9E, 0xC8, 0x39, // fnv1a trailer
        ];
        assert_eq!(ck.encode(), want);
        assert_eq!(Checkpoint::decode(&want).unwrap(), ck);
    }

    /// Recompute the trailer after a test mutates the body (so the
    /// mutation under test is the *only* defect).
    fn rehash(bytes: &mut Vec<u8>) {
        let body_len = bytes.len() - TRAILER_BYTES;
        let mut h = Fnv1a::new();
        for &b in &bytes[..body_len] {
            h.write_u8(b);
        }
        bytes.truncate(body_len);
        bytes.extend_from_slice(&h.finish().to_le_bytes());
    }

    #[test]
    fn roundtrip_is_exact() {
        let ck = sample(37);
        let bytes = ck.encode();
        assert_eq!(bytes.len(), Checkpoint::encoded_len(37));
        let back = Checkpoint::decode(&bytes).unwrap();
        assert_eq!(back, ck);
        // -0.0 kept its sign bit.
        assert_eq!(back.vy[0].to_bits(), (-0.0f64).to_bits());
        // Deterministic bytes: encode(decode(encode(x))) == encode(x).
        assert_eq!(back.encode(), bytes);
    }

    #[test]
    fn empty_campaign_roundtrips() {
        let ck = sample(0);
        assert_eq!(Checkpoint::decode(&ck.encode()).unwrap(), ck);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = sample(4).encode();
        bytes[0] ^= 0xFF;
        assert!(matches!(Checkpoint::decode(&bytes), Err(CheckpointError::BadMagic)));
    }

    #[test]
    fn version_mismatch_rejected() {
        let mut bytes = sample(4).encode();
        bytes[8..12].copy_from_slice(&2u32.to_le_bytes());
        rehash(&mut bytes);
        assert!(matches!(
            Checkpoint::decode(&bytes),
            Err(CheckpointError::UnsupportedVersion(2))
        ));
    }

    #[test]
    fn truncation_rejected_at_every_length() {
        let bytes = sample(3).encode();
        for cut in [0, 7, HEADER_BYTES - 1, HEADER_BYTES + 5, bytes.len() - 1] {
            match Checkpoint::decode(&bytes[..cut]) {
                Err(CheckpointError::Truncated { .. }) => {}
                other => panic!("cut at {cut}: expected Truncated, got {other:?}"),
            }
        }
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut bytes = sample(3).encode();
        bytes.push(0);
        assert!(matches!(
            Checkpoint::decode(&bytes),
            Err(CheckpointError::TrailingBytes { .. })
        ));
    }

    #[test]
    fn payload_corruption_rejected_by_checksum() {
        let mut bytes = sample(8).encode();
        let mid = HEADER_BYTES + 11;
        bytes[mid] ^= 0x40;
        assert!(matches!(
            Checkpoint::decode(&bytes),
            Err(CheckpointError::ChecksumMismatch { .. })
        ));
    }

    #[test]
    fn corrupt_header_n_cannot_allocate() {
        // A garbage particle count must fail the size check, not drive
        // an allocation: set n to u64::MAX and rehash so only the size
        // check can object.
        let mut bytes = sample(2).encode();
        bytes[40..48].copy_from_slice(&u64::MAX.to_le_bytes());
        rehash(&mut bytes);
        assert!(matches!(Checkpoint::decode(&bytes), Err(CheckpointError::Truncated { .. })));
    }

    #[test]
    fn bad_tags_rejected() {
        let mut bad_model = sample(2).encode();
        bad_model[12..16].copy_from_slice(&9u32.to_le_bytes());
        rehash(&mut bad_model);
        assert!(matches!(Checkpoint::decode(&bad_model), Err(CheckpointError::BadModel(9))));

        let mut bad_gen = sample(2).encode();
        bad_gen[16..20].copy_from_slice(&42u32.to_le_bytes());
        rehash(&mut bad_gen);
        assert!(matches!(Checkpoint::decode(&bad_gen), Err(CheckpointError::BadGenerator(42))));

        let mut bad_ctr = sample(2).encode();
        bad_ctr[32..36].copy_from_slice(&7u32.to_le_bytes());
        rehash(&mut bad_ctr);
        assert!(matches!(Checkpoint::decode(&bad_ctr), Err(CheckpointError::BadKey(7))));

        let mut bad_tile = sample(2).encode();
        bad_tile[36..40].copy_from_slice(&0u32.to_le_bytes());
        rehash(&mut bad_tile);
        assert!(matches!(Checkpoint::decode(&bad_tile), Err(CheckpointError::BadTile(0))));
    }

    #[test]
    fn generator_tags_roundtrip_and_are_pinned() {
        for g in Generator::ALL {
            assert_eq!(generator_from_tag(generator_tag(g)), Some(g));
        }
        // The on-disk table is normative — pin the literals.
        assert_eq!(generator_tag(Generator::Philox), 0);
        assert_eq!(generator_tag(Generator::Philox2x32), 1);
        assert_eq!(generator_tag(Generator::Threefry), 2);
        assert_eq!(generator_tag(Generator::Threefry2x32), 3);
        assert_eq!(generator_tag(Generator::Squares), 4);
        assert_eq!(generator_tag(Generator::Tyche), 5);
        assert_eq!(generator_tag(Generator::TycheI), 6);
        assert_eq!(generator_from_tag(7), None);
        assert_eq!((model_tag(Model::Brownian), model_tag(Model::Dpd)), (0, 1));
        assert_eq!(model_from_tag(2), None);
    }

    #[test]
    fn io_error_is_typed() {
        match Checkpoint::read_file("/nonexistent/campaign.ck") {
            Err(CheckpointError::Io(_)) => {}
            other => panic!("expected Io error, got {other:?}"),
        }
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("openrand_ck_test_{}.ck", std::process::id()));
        let ck = sample(16);
        ck.write_file(&path).unwrap();
        let back = Checkpoint::read_file(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back, ck);
    }
}
