//! Campaign-level physics observables: the MSD time series and the
//! diffusion-constant recovery that backs `openrand campaign validate`.
//!
//! The estimators are deliberately dumb — an ordinary least-squares
//! line through (epoch, MSD) samples — because the point is not a
//! clever fit but a *gate*: if per-tile epoch addressing ever draws the
//! wrong words (reused tiles, swapped axes, off-by-one epochs), the
//! recovered diffusion constant leaves its tolerance band long before
//! any statistical battery would notice.

use crate::sim::brownian::DT;
use crate::sim::observables::theoretical_msd_slope;

/// One MSD observation: mean-squared displacement from the initial
/// configuration after `epoch` completed steps.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MsdSample {
    pub epoch: u32,
    pub msd: f64,
}

/// Default relative tolerance for the recovered diffusion constant
/// (documented in `docs/campaigns.md`; generous enough for the CI
/// reduced-N arm, tight enough to catch mis-addressed randomness).
pub const DIFFUSION_TOLERANCE: f64 = 0.05;

/// Least-squares slope of MSD vs epoch (with a free intercept, so the
/// ballistic transient before sampling starts doesn't bias the fit).
pub fn fit_msd_slope(samples: &[MsdSample]) -> anyhow::Result<f64> {
    if samples.len() < 2 {
        anyhow::bail!("MSD fit needs at least 2 samples, got {}", samples.len());
    }
    let n = samples.len() as f64;
    let mean_t = samples.iter().map(|s| s.epoch as f64).sum::<f64>() / n;
    let mean_m = samples.iter().map(|s| s.msd).sum::<f64>() / n;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    for s in samples {
        let dt = s.epoch as f64 - mean_t;
        sxx += dt * dt;
        sxy += dt * (s.msd - mean_m);
    }
    if sxx == 0.0 {
        anyhow::bail!("MSD fit needs samples at distinct epochs");
    }
    Ok(sxy / sxx)
}

/// Result of a diffusion-constant recovery.
#[derive(Debug, Clone, Copy)]
pub struct DiffusionEstimate {
    /// Fitted MSD slope per step.
    pub slope_per_step: f64,
    /// Recovered diffusion constant (MSD(t) = 4·D·t in 2D).
    pub d_est: f64,
    /// Theoretical diffusion constant for this integrator.
    pub d_theory: f64,
    /// Number of MSD samples the fit used.
    pub samples: usize,
}

impl DiffusionEstimate {
    /// Relative error of the recovered constant against theory.
    pub fn rel_err(&self) -> f64 {
        (self.d_est / self.d_theory - 1.0).abs()
    }

    /// Does the estimate sit within the given relative tolerance?
    pub fn within(&self, tolerance: f64) -> bool {
        self.rel_err() <= tolerance
    }
}

/// Recover the diffusion constant from an MSD time series.
///
/// In 2D, MSD(t) = 4·D·t at long times; with the slope measured per
/// step, D = slope / (4·dt). `theoretical_msd_slope` is MSD growth per
/// *step* for this integrator, so D_theory follows the same route.
pub fn recover_diffusion_constant(samples: &[MsdSample]) -> anyhow::Result<DiffusionEstimate> {
    let slope = fit_msd_slope(samples)?;
    let d_est = slope / DT / 4.0;
    let d_theory = theoretical_msd_slope() / DT / 4.0;
    Ok(DiffusionEstimate { slope_per_step: slope, d_est, d_theory, samples: samples.len() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::observables::msd_xy;

    #[test]
    fn linear_series_recovers_slope_exactly() {
        // msd = 3 + 0.25·epoch — slope must come back exactly, with the
        // intercept absorbed by the fit.
        let samples: Vec<MsdSample> = (0..20)
            .map(|i| MsdSample { epoch: 10 + 5 * i, msd: 3.0 + 0.25 * (10 + 5 * i) as f64 })
            .collect();
        let slope = fit_msd_slope(&samples).unwrap();
        assert!((slope - 0.25).abs() < 1e-12, "slope {slope}");
        let est = recover_diffusion_constant(&samples).unwrap();
        assert!((est.d_est - 0.25 / DT / 4.0).abs() < 1e-9);
        assert_eq!(est.samples, 20);
    }

    #[test]
    fn zero_motion_recovers_zero_diffusion() {
        let samples: Vec<MsdSample> =
            (0..10).map(|i| MsdSample { epoch: i * 7, msd: 0.0 }).collect();
        let est = recover_diffusion_constant(&samples).unwrap();
        assert_eq!(est.slope_per_step, 0.0);
        assert_eq!(est.d_est, 0.0);
        assert!(!est.within(DIFFUSION_TOLERANCE)); // rel err vs D>0 is 1
    }

    #[test]
    fn straight_line_trajectory_has_quadratic_msd() {
        // A particle moving ballistically at speed (3e, 4e) per step has
        // displacement 5e·t, so msd_xy = 25e²t² — and the campaign MSD
        // helper must agree with the hand computation.
        let e = 0.01;
        let n = 64;
        let x0 = vec![0.0; n];
        let y0 = vec![0.0; n];
        for t in [1u32, 10, 100] {
            let x: Vec<f64> = vec![3.0 * e * t as f64; n];
            let y: Vec<f64> = vec![4.0 * e * t as f64; n];
            let m = msd_xy(&x, &y, &x0, &y0);
            let want = 25.0 * e * e * (t as f64) * (t as f64);
            assert!((m - want).abs() < 1e-12, "t={t}: {m} vs {want}");
        }
    }

    #[test]
    fn degenerate_fits_are_typed_errors() {
        assert!(fit_msd_slope(&[]).is_err());
        assert!(fit_msd_slope(&[MsdSample { epoch: 5, msd: 1.0 }]).is_err());
        // Two samples at the same epoch: no slope.
        let same = [MsdSample { epoch: 5, msd: 1.0 }, MsdSample { epoch: 5, msd: 2.0 }];
        assert!(fit_msd_slope(&same).is_err());
    }

    #[test]
    fn tolerance_band_behaves() {
        let est = DiffusionEstimate {
            slope_per_step: 0.0,
            d_est: 1.04,
            d_theory: 1.0,
            samples: 2,
        };
        assert!(est.within(0.05));
        assert!(!est.within(0.03));
        assert!((est.rel_err() - 0.04).abs() < 1e-12);
    }
}
