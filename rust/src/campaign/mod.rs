//! `openrand::campaign` — large-N simulation campaigns with bitwise
//! checkpoint/resume and a physics validation gate.
//!
//! This is the crate's Tier-1 end-to-end scenario: the paper's
//! reproducibility claim ("identical trajectories regardless of how the
//! work is parallelized") stressed at million-particle scale instead of
//! toy sizes. The design rests on three invariants, all inherited from
//! lower layers:
//!
//! 1. **Epoch addressing.** Timestep `t` of a campaign draws from
//!    `key.epoch(t)`; tile `k` of that timestep draws words
//!    `0..2·tile_len` of `key.epoch(t).child(k)`. The child derivation
//!    mixes the epoch counter, so no two (epoch, tile) cells ever share
//!    a stream, and a tile never materializes another tile's state —
//!    backends reach interior words through the PR-7 jump-ahead
//!    contract (`set_position`), not by generating prefixes.
//! 2. **Arm-identical fills.** Every `FillBackend` arm produces
//!    byte-identical words, so the trajectory is invariant across
//!    thread counts and host/par fill arms (proved by a property test
//!    in `tests/properties.rs`).
//! 3. **Stateless checkpoints.** A [`Checkpoint`] carries the particle
//!    arrays plus the `StreamKey` *address* — no engine state. Keys and
//!    epochs reconstruct every future draw, so resume == never-stopped,
//!    bitwise.
//!
//! [`validate`] layers the physics gate on top: sample the MSD series,
//! fit the slope, and require the recovered diffusion constant to sit
//! within tolerance of the integrator's theoretical value.

pub mod checkpoint;
pub mod observables;

pub use checkpoint::{Checkpoint, CheckpointError};
pub use observables::{DiffusionEstimate, MsdSample, DIFFUSION_TOLERANCE};

use crate::backend::FillBackend;
use crate::coordinator::partition_ranges;
use crate::core::Generator;
use crate::sim::brownian::{grid_init, kick_step, DT};
use crate::sim::dpd::{DpdParams, DpdSim};
use crate::sim::observables::msd_xy;
use crate::stream::{self, StreamKey};
use crate::util::hash::Fnv1a;

/// Default particles per tile — one fill request covers
/// `2 · DEFAULT_TILE` stream words (f64 elements take two words each).
pub const DEFAULT_TILE: usize = 1 << 16;

/// Upper bound on the tile size (checkpoint field validation).
pub const MAX_TILE: usize = 1 << 24;

/// Which physics model a campaign drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Model {
    /// Overdamped-kick Brownian particles (the paper's fig. 4 walk).
    Brownian,
    /// Groot–Warren DPD fluid with pair-symmetric streams.
    Dpd,
}

impl Model {
    pub const ALL: [Model; 2] = [Model::Brownian, Model::Dpd];

    pub fn name(self) -> &'static str {
        match self {
            Model::Brownian => "brownian",
            Model::Dpd => "dpd",
        }
    }

    pub fn parse(s: &str) -> Option<Model> {
        Model::ALL.iter().copied().find(|m| m.name() == s)
    }
}

/// Canonical DPD parameters for a campaign of `n` particles: density 4
/// (Groot–Warren), standard a/γ/kT, and the campaign key's seed as the
/// global pair-stream seed.
pub fn dpd_params(n: usize, global_seed: u64) -> DpdParams {
    DpdParams {
        n,
        box_side: (n as f64 / 4.0).sqrt(),
        a: 25.0,
        gamma: 4.5,
        kt: 1.0,
        dt: 0.01,
        global_seed,
    }
}

/// Full identity of a campaign trajectory. Everything here is part of
/// the bitwise contract: changing any field (including `tile`) changes
/// which stream words land on which particle. `threads` is the one
/// exception — it only schedules work and provably does not affect the
/// trajectory.
#[derive(Debug, Clone, Copy)]
pub struct CampaignParams {
    pub model: Model,
    pub n_particles: usize,
    /// Root stream address; must carry ctr 0 (epochs are derived from
    /// the step index, never baked into the key).
    pub key: StreamKey,
    pub gen: Generator,
    /// Worker threads for stepping (not part of the trajectory
    /// identity).
    pub threads: usize,
    /// Particles per tile (part of the trajectory identity).
    pub tile: usize,
}

impl CampaignParams {
    pub fn new(model: Model, n_particles: usize, key: StreamKey) -> CampaignParams {
        CampaignParams {
            model,
            n_particles,
            key,
            gen: Generator::Philox,
            threads: 1,
            tile: DEFAULT_TILE,
        }
    }

    fn validate(&self) -> anyhow::Result<()> {
        if self.n_particles == 0 {
            anyhow::bail!("campaign needs at least 1 particle");
        }
        if self.tile == 0 || self.tile > MAX_TILE {
            anyhow::bail!("tile must be in 1..={MAX_TILE}, got {}", self.tile);
        }
        if self.key.ctr() != 0 {
            anyhow::bail!(
                "campaign key must carry ctr 0 (got ctr {}): epochs are derived per step, \
                 not baked into the key",
                self.key.ctr()
            );
        }
        if self.threads == 0 {
            anyhow::bail!("threads must be positive");
        }
        Ok(())
    }
}

/// Caller-visible particle state of one model.
enum ModelState {
    Brownian { x: Vec<f64>, y: Vec<f64>, vx: Vec<f64>, vy: Vec<f64> },
    Dpd(Box<DpdSim>),
}

/// A running campaign: params + particle state + epoch count.
pub struct Campaign {
    params: CampaignParams,
    state: ModelState,
    epoch: u32,
}

/// Walk the tiles `first_tile..` covering the given particle stripe:
/// fill `2·len` kick words from `epoch_key.child(t)` and integrate the
/// particles of tile `t`. `buf` must hold at least `2·min(tile, stripe)`
/// elements.
#[allow(clippy::too_many_arguments)]
fn step_tiles(
    mut backend: Option<&mut dyn FillBackend>,
    gen: Generator,
    epoch_key: StreamKey,
    tile: usize,
    first_tile: u64,
    x: &mut [f64],
    y: &mut [f64],
    vx: &mut [f64],
    vy: &mut [f64],
    buf: &mut [f64],
) -> anyhow::Result<()> {
    let sqrt_dt = DT.sqrt();
    let n = x.len();
    let mut off = 0usize;
    let mut t = first_tile;
    while off < n {
        let len = tile.min(n - off);
        let kicks = &mut buf[..2 * len];
        stream::fill_f64_key(backend.as_deref_mut(), gen, epoch_key.child(t), kicks)?;
        for i in 0..len {
            kick_step(
                &mut x[off + i],
                &mut y[off + i],
                &mut vx[off + i],
                &mut vy[off + i],
                kicks[2 * i],
                kicks[2 * i + 1],
                sqrt_dt,
            );
        }
        off += len;
        t += 1;
    }
    Ok(())
}

/// One Brownian epoch over caller-owned state. Parallelism carves the
/// tile list into contiguous whole-tile stripes (deterministic
/// [`partition_ranges`]); each worker fills its own tiles through the
/// thread-local auto backend, so the words — hence the trajectory — are
/// independent of the thread count.
fn step_brownian(
    gen: Generator,
    epoch_key: StreamKey,
    tile: usize,
    threads: usize,
    x: &mut [f64],
    y: &mut [f64],
    vx: &mut [f64],
    vy: &mut [f64],
) -> anyhow::Result<()> {
    let n = x.len();
    let n_tiles = n.div_ceil(tile);
    if threads <= 1 || n_tiles <= 1 {
        let mut buf = vec![0.0f64; 2 * tile.min(n)];
        return step_tiles(None, gen, epoch_key, tile, 0, x, y, vx, vy, &mut buf);
    }
    let workers = threads.min(n_tiles);
    let tile_ranges = partition_ranges(n_tiles, workers);
    std::thread::scope(|scope| -> anyhow::Result<()> {
        let mut handles = Vec::with_capacity(workers);
        let mut xs = x;
        let mut ys = y;
        let mut vxs = vx;
        let mut vys = vy;
        let mut lo = 0usize;
        for r in &tile_ranges {
            let hi = (r.end * tile).min(n);
            let len = hi - lo;
            let (xh, xt) = xs.split_at_mut(len);
            let (yh, yt) = ys.split_at_mut(len);
            let (vxh, vxt) = vxs.split_at_mut(len);
            let (vyh, vyt) = vys.split_at_mut(len);
            xs = xt;
            ys = yt;
            vxs = vxt;
            vys = vyt;
            let first_tile = r.start as u64;
            handles.push(scope.spawn(move || -> anyhow::Result<()> {
                let mut buf = vec![0.0f64; 2 * tile.min(len.max(1))];
                step_tiles(None, gen, epoch_key, tile, first_tile, xh, yh, vxh, vyh, &mut buf)
            }));
            lo = hi;
        }
        for h in handles {
            h.join().expect("campaign worker panicked")?;
        }
        Ok(())
    })
}

impl Campaign {
    /// Start a fresh campaign at epoch 0 (Brownian: grid positions,
    /// zero velocities; DPD: its deterministic lattice start).
    pub fn new(params: CampaignParams) -> anyhow::Result<Campaign> {
        params.validate()?;
        let n = params.n_particles;
        let state = match params.model {
            Model::Brownian => {
                let (x, y) = grid_init(n);
                ModelState::Brownian { x, y, vx: vec![0.0; n], vy: vec![0.0; n] }
            }
            Model::Dpd => {
                ModelState::Dpd(Box::new(DpdSim::new(dpd_params(n, params.key.seed()))))
            }
        };
        Ok(Campaign { params, state, epoch: 0 })
    }

    /// Rebuild a campaign from a checkpoint, resuming at its epoch.
    /// `threads` is free to differ from the run that wrote the
    /// checkpoint — it does not affect the trajectory.
    pub fn resume(ck: &Checkpoint, threads: usize) -> anyhow::Result<Campaign> {
        let n = ck.n_particles();
        let params = CampaignParams {
            model: ck.model,
            n_particles: n,
            key: ck.key,
            gen: ck.gen,
            threads,
            tile: ck.tile as usize,
        };
        params.validate()?;
        let state = match ck.model {
            Model::Brownian => ModelState::Brownian {
                x: ck.x.clone(),
                y: ck.y.clone(),
                vx: ck.vx.clone(),
                vy: ck.vy.clone(),
            },
            Model::Dpd => ModelState::Dpd(Box::new(DpdSim::from_state(
                dpd_params(n, ck.key.seed()),
                ck.x.clone(),
                ck.y.clone(),
                ck.vx.clone(),
                ck.vy.clone(),
                ck.epoch,
            ))),
        };
        Ok(Campaign { params, state, epoch: ck.epoch })
    }

    pub fn params(&self) -> CampaignParams {
        self.params
    }

    /// Completed epochs.
    pub fn epoch(&self) -> u32 {
        self.epoch
    }

    /// Advance one epoch through the default (thread-local auto)
    /// backend, parallelized across `params.threads` workers.
    pub fn step(&mut self) -> anyhow::Result<()> {
        match &mut self.state {
            ModelState::Brownian { x, y, vx, vy } => {
                let epoch_key = self.params.key.epoch(self.epoch);
                step_brownian(
                    self.params.gen,
                    epoch_key,
                    self.params.tile,
                    self.params.threads,
                    x,
                    y,
                    vx,
                    vy,
                )?;
                self.epoch += 1;
            }
            ModelState::Dpd(sim) => {
                if self.params.threads > 1 {
                    sim.step_parallel(self.params.threads);
                } else {
                    sim.step_all();
                }
                self.epoch = sim.step;
            }
        }
        Ok(())
    }

    /// Advance one Brownian epoch through an explicit fill backend —
    /// the arm-identity surface the property test drives (`HostSerial`
    /// vs `HostParallel` must yield bitwise-equal trajectories). DPD
    /// draws its pair streams engine-side, so the backend does not
    /// apply there and this falls through to [`Campaign::step`].
    pub fn step_with(&mut self, backend: &mut dyn FillBackend) -> anyhow::Result<()> {
        if let ModelState::Brownian { x, y, vx, vy } = &mut self.state {
            let epoch_key = self.params.key.epoch(self.epoch);
            let tile = self.params.tile;
            let mut buf = vec![0.0f64; 2 * tile.min(x.len())];
            step_tiles(Some(backend), self.params.gen, epoch_key, tile, 0, x, y, vx, vy, &mut buf)?;
            self.epoch += 1;
            return Ok(());
        }
        self.step()
    }

    /// Run (forward only) to the target epoch.
    pub fn run_to(&mut self, target: u32) -> anyhow::Result<()> {
        if target < self.epoch {
            anyhow::bail!("cannot run backwards: at epoch {}, target {target}", self.epoch);
        }
        while self.epoch < target {
            self.step()?;
        }
        Ok(())
    }

    /// Snapshot the full trajectory identity + particle state.
    pub fn checkpoint(&self) -> Checkpoint {
        let (x, y, vx, vy) = match &self.state {
            ModelState::Brownian { x, y, vx, vy } => {
                (x.clone(), y.clone(), vx.clone(), vy.clone())
            }
            ModelState::Dpd(sim) => {
                (sim.x.clone(), sim.y.clone(), sim.vx.clone(), sim.vy.clone())
            }
        };
        Checkpoint {
            model: self.params.model,
            gen: self.params.gen,
            key: self.params.key,
            epoch: self.epoch,
            tile: self.params.tile as u32,
            x,
            y,
            vx,
            vy,
        }
    }

    /// FNV-1a digest of (epoch, x, y, vx, vy) — the campaign's compact
    /// reproducibility fingerprint.
    pub fn state_hash(&self) -> u64 {
        let mut h = Fnv1a::new();
        h.write_u32(self.epoch);
        let (x, y, vx, vy) = match &self.state {
            ModelState::Brownian { x, y, vx, vy } => (x, y, vx, vy),
            ModelState::Dpd(sim) => (&sim.x, &sim.y, &sim.vx, &sim.vy),
        };
        h.write_f64_slice(x);
        h.write_f64_slice(y);
        h.write_f64_slice(vx);
        h.write_f64_slice(vy);
        h.finish()
    }

    /// Mean-squared displacement from the initial configuration
    /// (Brownian only — DPD has no fixed reference grid once thermal).
    pub fn msd(&self) -> anyhow::Result<f64> {
        match &self.state {
            ModelState::Brownian { x, y, .. } => {
                let (x0, y0) = grid_init(self.params.n_particles);
                Ok(msd_xy(x, y, &x0, &y0))
            }
            ModelState::Dpd(_) => anyhow::bail!("msd is defined for the brownian model"),
        }
    }
}

/// Sampling plan for [`validate`].
#[derive(Debug, Clone, Copy)]
pub struct ValidateConfig {
    /// Epochs to discard before sampling. The integrator's velocity
    /// relaxation time is 1/(γ·dt) = 200 steps, and the MSD *slope*
    /// approaches its asymptote on the same timescale (residual bias
    /// ∝ (1 − γ·dt/m)^t, i.e. ~17% at t = 350 but < 1% past t = 1000),
    /// so the default discards five relaxation times.
    pub relax_epochs: u32,
    /// Sample the MSD every this many epochs after relaxation.
    pub sample_every: u32,
    /// Relative tolerance the CLI gate applies to the recovered D.
    pub tolerance: f64,
}

impl Default for ValidateConfig {
    fn default() -> ValidateConfig {
        ValidateConfig { relax_epochs: 1000, sample_every: 50, tolerance: DIFFUSION_TOLERANCE }
    }
}

/// Run a fresh Brownian campaign for `steps` epochs, sample the MSD
/// series per `cfg`, and recover the diffusion constant. The caller
/// gates on [`DiffusionEstimate::within`].
pub fn validate(
    params: CampaignParams,
    steps: u32,
    cfg: ValidateConfig,
) -> anyhow::Result<DiffusionEstimate> {
    if params.model != Model::Brownian {
        anyhow::bail!("campaign validate is defined for the brownian model");
    }
    if cfg.sample_every == 0 {
        anyhow::bail!("sample-every must be positive");
    }
    let need = cfg.relax_epochs + 2 * cfg.sample_every;
    if steps < need {
        anyhow::bail!(
            "validate needs steps >= relax + 2*sample-every = {need}, got {steps} \
             (the fit needs at least two post-relaxation samples)"
        );
    }
    let mut c = Campaign::new(params)?;
    let (x0, y0) = grid_init(params.n_particles);
    let mut samples = Vec::new();
    while c.epoch < steps {
        c.step()?;
        if c.epoch >= cfg.relax_epochs && (c.epoch - cfg.relax_epochs) % cfg.sample_every == 0 {
            if let ModelState::Brownian { x, y, .. } = &c.state {
                samples.push(MsdSample { epoch: c.epoch, msd: msd_xy(x, y, &x0, &y0) });
            }
        }
    }
    observables::recover_diffusion_constant(&samples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{HostParallel, HostSerial};

    fn brownian_params(n: usize, tile: usize, threads: usize) -> CampaignParams {
        let mut p = CampaignParams::new(Model::Brownian, n, StreamKey::root(42));
        p.tile = tile;
        p.threads = threads;
        p
    }

    #[test]
    fn fresh_campaign_starts_on_the_grid() {
        let c = Campaign::new(brownian_params(100, 16, 1)).unwrap();
        assert_eq!(c.epoch(), 0);
        assert_eq!(c.msd().unwrap(), 0.0);
        let ck = c.checkpoint();
        let (x0, y0) = grid_init(100);
        assert_eq!(ck.x, x0);
        assert_eq!(ck.y, y0);
        assert!(ck.vx.iter().chain(ck.vy.iter()).all(|&v| v == 0.0));
    }

    #[test]
    fn same_params_same_trajectory() {
        let mut a = Campaign::new(brownian_params(300, 64, 1)).unwrap();
        let mut b = Campaign::new(brownian_params(300, 64, 1)).unwrap();
        a.run_to(9).unwrap();
        b.run_to(9).unwrap();
        assert_eq!(a.checkpoint().encode(), b.checkpoint().encode());
    }

    #[test]
    fn trajectory_is_thread_count_invariant() {
        let mut reference = Campaign::new(brownian_params(300, 64, 1)).unwrap();
        reference.run_to(7).unwrap();
        let want = reference.checkpoint().encode();
        for threads in [2, 3, 8] {
            let mut c = Campaign::new(brownian_params(300, 64, threads)).unwrap();
            c.run_to(7).unwrap();
            assert_eq!(c.checkpoint().encode(), want, "threads={threads}");
        }
    }

    #[test]
    fn explicit_backend_arms_match_default_path() {
        let mut auto = Campaign::new(brownian_params(300, 64, 4)).unwrap();
        let mut serial = Campaign::new(brownian_params(300, 64, 1)).unwrap();
        let mut par = Campaign::new(brownian_params(300, 64, 1)).unwrap();
        let mut hs = HostSerial;
        let mut hp = HostParallel::new(4);
        for _ in 0..6 {
            auto.step().unwrap();
            serial.step_with(&mut hs).unwrap();
            par.step_with(&mut hp).unwrap();
        }
        assert_eq!(serial.state_hash(), auto.state_hash());
        assert_eq!(par.state_hash(), auto.state_hash());
    }

    #[test]
    fn tile_size_is_part_of_the_identity() {
        // Different tilings address different (epoch, tile) streams, so
        // they are *different experiments* — documented, and pinned here
        // so an accidental tile-independence "fix" can't slip in.
        let mut a = Campaign::new(brownian_params(300, 64, 1)).unwrap();
        let mut b = Campaign::new(brownian_params(300, 32, 1)).unwrap();
        a.run_to(3).unwrap();
        b.run_to(3).unwrap();
        assert_ne!(a.state_hash(), b.state_hash());
    }

    #[test]
    fn resume_is_bitwise_brownian() {
        let mut full = Campaign::new(brownian_params(500, 128, 2)).unwrap();
        full.run_to(12).unwrap();
        let want = full.checkpoint().encode();

        let mut head = Campaign::new(brownian_params(500, 128, 1)).unwrap();
        head.run_to(5).unwrap();
        let mid = Checkpoint::decode(&head.checkpoint().encode()).unwrap();
        for resume_threads in [1, 3, 8] {
            let mut tail = Campaign::resume(&mid, resume_threads).unwrap();
            assert_eq!(tail.epoch(), 5);
            tail.run_to(12).unwrap();
            assert_eq!(tail.checkpoint().encode(), want, "resume_threads={resume_threads}");
        }
    }

    #[test]
    fn resume_is_bitwise_dpd() {
        let mut p = CampaignParams::new(Model::Dpd, 64, StreamKey::root(99));
        p.threads = 2;
        let mut full = Campaign::new(p).unwrap();
        full.run_to(6).unwrap();
        let want = full.state_hash();

        let mut head = Campaign::new(p).unwrap();
        head.run_to(3).unwrap();
        let mid = Checkpoint::decode(&head.checkpoint().encode()).unwrap();
        assert_eq!(mid.model, Model::Dpd);
        let mut tail = Campaign::resume(&mid, 1).unwrap();
        tail.run_to(6).unwrap();
        assert_eq!(tail.state_hash(), want);
        assert_eq!(tail.checkpoint().encode(), full.checkpoint().encode());
    }

    #[test]
    fn generator_choice_changes_trajectory_but_stays_reproducible() {
        let mut p = brownian_params(200, 64, 1);
        p.gen = Generator::Threefry;
        let mut a = Campaign::new(p).unwrap();
        let mut b = Campaign::new(p).unwrap();
        let mut philox = Campaign::new(brownian_params(200, 64, 1)).unwrap();
        a.run_to(4).unwrap();
        b.run_to(4).unwrap();
        philox.run_to(4).unwrap();
        assert_eq!(a.state_hash(), b.state_hash());
        assert_ne!(a.state_hash(), philox.state_hash());
    }

    #[test]
    fn bad_params_are_typed_errors() {
        let mut p = brownian_params(0, 64, 1);
        assert!(Campaign::new(p).is_err());
        p = brownian_params(100, 0, 1);
        assert!(Campaign::new(p).is_err());
        p = brownian_params(100, MAX_TILE + 1, 1);
        assert!(Campaign::new(p).is_err());
        p = brownian_params(100, 64, 0);
        assert!(Campaign::new(p).is_err());
        p = brownian_params(100, 64, 1);
        p.key = StreamKey::raw(42, 7); // epoch baked into the key
        assert!(Campaign::new(p).is_err());
        let c = Campaign::new(brownian_params(100, 64, 1)).unwrap();
        assert!(validate(c.params(), 10, ValidateConfig::default()).is_err()); // too few steps
        assert!(validate(
            CampaignParams::new(Model::Dpd, 64, StreamKey::root(1)),
            1000,
            ValidateConfig::default()
        )
        .is_err());
    }

    #[test]
    fn run_backwards_rejected() {
        let mut c = Campaign::new(brownian_params(100, 64, 1)).unwrap();
        c.run_to(5).unwrap();
        assert!(c.run_to(3).is_err());
        assert_eq!(c.epoch(), 5);
    }

    #[test]
    fn validate_recovers_diffusion_constant() {
        // Reduced-N arm of the physics gate (CI runs a larger one via
        // the CLI; the 1M-particle claim is the bench/docs tier). Finite
        // N puts statistical noise on the MSD slope, hence the wider
        // band than DIFFUSION_TOLERANCE here.
        let mut p = brownian_params(8192, 1024, 2);
        p.key = StreamKey::root(7);
        let cfg = ValidateConfig { relax_epochs: 1000, sample_every: 60, tolerance: 0.15 };
        let est = validate(p, 1600, cfg).unwrap();
        assert!(
            est.within(cfg.tolerance),
            "D_est {:.4} vs D_theory {:.4} (rel err {:.3})",
            est.d_est,
            est.d_theory,
            est.rel_err()
        );
        assert_eq!(est.samples, 11); // epochs 300, 360, …, 900
    }

    #[test]
    fn model_names_roundtrip() {
        for m in Model::ALL {
            assert_eq!(Model::parse(m.name()), Some(m));
        }
        assert_eq!(Model::parse("ising"), None);
    }
}
