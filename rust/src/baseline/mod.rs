//! Baseline generators — every comparator the paper's evaluation uses.
//!
//! * [`Mt19937`] — GNU libstdc++'s default engine, the Fig. 4a baseline.
//!   Full 624-word Mersenne Twister with the standard (expensive) seeding,
//!   because that init cost *is* the short-stream effect the paper shows.
//! * [`StatefulPhilox`] — the cuRAND-usage analogue (Fig. 2 / Fig. 4b):
//!   the identical Philox4x32-10 core, but driven through a 64-byte
//!   heap-resident state record that must be loaded and stored around
//!   every draw, plus a separate bulk init pass (`init_states`).
//! * [`raw123`] — the Random123-style low-level API (Fig. 3): caller
//!   builds counters/keys by hand and packs u64s from 4-word blocks.
//! * [`Pcg32`], [`Xoshiro256pp`], [`SplitMix64`], [`Lcg64`] — classic
//!   sequential baselines for the statistical battery (known-good) and
//!   its self-test (known-bad: `Lcg64` low bits, `WeakCounter`). Each
//!   carries its native skip-ahead (`Pcg32::advance` / `Lcg64::advance`
//!   O(log n), `SplitMix64::advance` O(1), `Xoshiro256pp::jump` fixed
//!   2^128 stride) so jump-ahead bench comparisons against the counter
//!   engines stay honest; [`Mt19937`] documents `advance` as
//!   unsupported.
//! * [`WeakCounter`] — a deliberately broken "generator" (raw counter)
//!   that the battery MUST flag; used to prove the tests have power.

pub mod mt19937;
pub mod pcg;
pub mod raw123;
pub mod stateful_philox;
pub mod xoshiro;

pub use mt19937::Mt19937;
pub use pcg::{Lcg64, Pcg32, SplitMix64, WeakCounter};
pub use stateful_philox::{CurandPhiloxState, StatefulPhilox};
pub use xoshiro::Xoshiro256pp;
