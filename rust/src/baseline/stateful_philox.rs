//! The cuRAND-usage analogue (paper Fig. 2): identical Philox4x32-10
//! core, but used the way cuRAND forces you to — a 64-byte state record
//! per processing element, allocated up front, initialized by a separate
//! pass, and loaded/stored around every kernel body.
//!
//! Layout mirrors `curandStatePhilox4_32_10_t` (and the L2 graph
//! `model.brownian_step_stateful`): 128-bit counter, 64-bit key, 4 words
//! of buffered output, buffer position, padding to 64 B. With the RNG
//! algorithm held constant, any Fig. 4b performance difference between
//! this and `core::Philox` is pure state traffic + init overhead — the
//! isolation the paper's comparison needed but could not fully get with
//! the closed-source cuRAND.

use crate::core::philox::philox4x32;
use crate::core::traits::Rng;

/// One cuRAND-style Philox state record: exactly 64 bytes.
#[derive(Debug, Clone, Copy)]
#[repr(C)]
pub struct CurandPhiloxState {
    /// 128-bit counter (little-endian words).
    pub ctr: [u32; 4],
    /// Key = the global seed.
    pub key: [u32; 2],
    /// Buffered block output.
    pub out: [u32; 4],
    /// Words consumed from `out` (0..=4).
    pub pos: u32,
    pub _pad: [u32; 5],
}

impl CurandPhiloxState {
    /// `curand_init(seed, subsequence, offset)` with offset = 0:
    /// subsequence selects ctr word 0, key is the seed.
    pub fn init(seed: u64, subsequence: u32) -> Self {
        CurandPhiloxState {
            ctr: [subsequence, 0, 0, 0],
            key: [seed as u32, (seed >> 32) as u32],
            out: [0; 4],
            pos: 4,
            _pad: [0; 5],
        }
    }

    /// 128-bit counter increment.
    #[inline]
    pub fn bump(&mut self) {
        for w in self.ctr.iter_mut() {
            *w = w.wrapping_add(1);
            if *w != 0 {
                break;
            }
        }
    }
}

/// The separate init kernel: allocate + initialize N states (the pass
/// cuRAND runs as `rand_init<<<...>>>` before any random numbers flow).
pub fn init_states(seed: u64, n: usize) -> Vec<CurandPhiloxState> {
    (0..n).map(|i| CurandPhiloxState::init(seed, i as u32)).collect()
}

/// A by-value handle emulating the kernel-body pattern: load the state
/// from the array, draw through it, store it back. The load + store are
/// explicit so the benchmark measures the same memory traffic cuRAND
/// incurs per kernel invocation.
pub struct StatefulPhilox {
    state: CurandPhiloxState,
}

impl StatefulPhilox {
    /// "Load" — copy the 64 B record out of the state array.
    #[inline]
    pub fn load(states: &[CurandPhiloxState], i: usize) -> Self {
        StatefulPhilox { state: states[i] }
    }

    /// "Store" — copy the 64 B record back.
    #[inline]
    pub fn store(self, states: &mut [CurandPhiloxState], i: usize) {
        states[i] = self.state;
    }

    /// Direct access for tests/benches.
    pub fn state(&self) -> &CurandPhiloxState {
        &self.state
    }
}

impl Rng for StatefulPhilox {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        if self.state.pos >= 4 {
            self.state.out = philox4x32(self.state.ctr, self.state.key);
            self.state.bump();
            self.state.pos = 0;
        }
        let w = self.state.out[self.state.pos as usize];
        self.state.pos += 1;
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::philox::philox4x32;

    #[test]
    fn record_is_64_bytes() {
        // The paper's "~64 MB of GPU memory per million particles".
        assert_eq!(std::mem::size_of::<CurandPhiloxState>(), 64);
    }

    #[test]
    fn same_core_as_openrand_philox() {
        // First block of (seed, subsequence=i) == raw philox([i,0,0,0], key).
        let states = init_states(0xAABB_CCDD_EEFF_0011, 4);
        let mut h = StatefulPhilox::load(&states, 3);
        let w: Vec<u32> = (0..4).map(|_| h.next_u32()).collect();
        let expect = philox4x32([3, 0, 0, 0], [0xEEFF_0011, 0xAABB_CCDD]);
        assert_eq!(w, expect);
    }

    #[test]
    fn load_draw_store_roundtrip_advances() {
        let mut states = init_states(7, 2);
        let mut h = StatefulPhilox::load(&states, 0);
        let a = h.next_u32();
        h.store(&mut states, 0);
        // Next load continues the stream, not restarts it.
        let mut h2 = StatefulPhilox::load(&states, 0);
        let b = h2.next_u32();
        assert_ne!(a, b);
        assert_eq!(states[0].pos, 1);
    }

    #[test]
    fn counter_bump_carries() {
        let mut s = CurandPhiloxState::init(0, 0);
        s.ctr = [u32::MAX, u32::MAX, 5, 0];
        s.bump();
        assert_eq!(s.ctr, [0, 0, 6, 0]);
    }

    #[test]
    fn init_states_costs_n_records() {
        let states = init_states(1, 1000);
        assert_eq!(states.len() * std::mem::size_of::<CurandPhiloxState>(), 64_000);
        // Distinct subsequences -> distinct first outputs.
        let mut a = StatefulPhilox::load(&states, 0);
        let mut b = StatefulPhilox::load(&states, 1);
        assert_ne!(a.next_u32(), b.next_u32());
    }
}
