//! The Random123-style low-level API (paper Fig. 3): the caller builds
//! counter and key by hand, invokes the bijection, and packs doubles from
//! raw words with `u01`-style helpers. Functionally identical to
//! `core::Philox`; the point of keeping it is to measure (Fig. 4b "on
//! par") and to illustrate (example `api_comparison`) the boilerplate
//! cost the paper's API eliminates.

use crate::core::philox::philox4x32;

/// `r123::Philox4x32::operator()(ctr, key)`.
#[inline]
pub fn philox4x32_raw(ctr: [u32; 4], key: [u32; 2]) -> [u32; 4] {
    philox4x32(ctr, key)
}

/// `r123::u01<double, uint64_t>` — convert a packed u64 to a double in
/// (0, 1]-ish the Random123 way; we use the OpenRAND [0,1) convention so
/// results remain comparable across API styles.
#[inline]
pub fn u01_u64(bits: u64) -> f64 {
    (bits >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// The Fig. 3 kernel-body idiom: one call site packs 4 words into 2
/// doubles for a particle's kick.
#[inline]
pub fn double2_from_block(pid: u32, counter: u32) -> (f64, f64) {
    // Fig. 3 lines 15-26, transcribed: uk[0] = pid; c[0] = counter.
    let uk: [u32; 2] = [pid, 0];
    let c: [u32; 4] = [counter, 0, 0, 0];
    let r = philox4x32_raw(c, uk);
    let xu = ((r[0] as u64) << 32) | r[1] as u64;
    let yu = ((r[2] as u64) << 32) | r[3] as u64;
    (u01_u64(xu), u01_u64(yu))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{CounterRng, Philox, Rng};

    #[test]
    fn u01_bounds() {
        assert_eq!(u01_u64(0), 0.0);
        assert!(u01_u64(u64::MAX) < 1.0);
    }

    #[test]
    fn fig3_and_fig1_draws_differ_only_in_counter_layout() {
        // Same algorithm, different (ctr, key) layouts: Fig. 3 puts the
        // counter in c[0] and pid in the key; OpenRAND puts the block
        // index in c[0] and the counter in c[1]. Document the difference
        // by construction.
        let (a1, _a2) = double2_from_block(77, 5);
        let mut openrand = Philox::new(77, 5);
        let (b1, _b2) = openrand.draw_double2();
        assert_ne!(a1, b1); // different stream layouts...
        // ...but identical core: swap layouts and they coincide.
        let r = philox4x32_raw([0, 5, 0, 0], [77, 0]);
        let xu = ((r[0] as u64) << 32) | r[1] as u64;
        assert_eq!(u01_u64(xu), b1);
    }
}
