//! Sequential baselines: PCG32, SplitMix64, a raw LCG, and a deliberately
//! broken generator. PCG/SplitMix are "known-good" controls for the
//! statistical battery; `Lcg64`'s low bits and [`WeakCounter`] are the
//! "known-bad" controls that prove the battery has detection power
//! (DESIGN.md test plan: the battery must fail them).

use crate::core::traits::Rng;

/// O'Neill's O(log n) LCG skip-ahead: the state after `delta` steps of
/// `state = state * mult + inc`, by binary exponentiation of the affine
/// map (PCG paper §4.3.1 / Brown's "Random number generation with
/// arbitrary strides"). Shared by the [`Pcg32`] and [`Lcg64`] jumps so
/// the baseline bench comparisons against the counter engines'
/// `advance` stay honest.
#[inline]
pub fn lcg_skip(state: u64, mult: u64, inc: u64, mut delta: u64) -> u64 {
    let (mut acc_mult, mut acc_plus) = (1u64, 0u64);
    let (mut cur_mult, mut cur_plus) = (mult, inc);
    while delta > 0 {
        if delta & 1 == 1 {
            acc_mult = acc_mult.wrapping_mul(cur_mult);
            acc_plus = acc_plus.wrapping_mul(cur_mult).wrapping_add(cur_plus);
        }
        cur_plus = cur_mult.wrapping_add(1).wrapping_mul(cur_plus);
        cur_mult = cur_mult.wrapping_mul(cur_mult);
        delta >>= 1;
    }
    state.wrapping_mul(acc_mult).wrapping_add(acc_plus)
}

/// PCG32 (O'Neill 2014): 64-bit LCG state, XSH-RR output.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub const MULT: u64 = 6_364_136_223_846_793_005;

    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Advance by `n` outputs in O(log n) — bit-identical to `n`
    /// [`Rng::next_u32`] calls (one LCG step each). Wraps mod the
    /// 2^64-step period.
    pub fn advance(&mut self, n: u64) {
        self.state = lcg_skip(self.state, Self::MULT, self.inc, n);
    }

    /// Far jump: 2^32 outputs (sqrt of the 2^64 period), mirroring the
    /// counter engines' [`crate::core::CounterRng::jump`] contract.
    pub fn jump(&mut self) {
        self.advance(1 << 32);
    }
}

impl Rng for Pcg32 {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(Self::MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }
}

/// SplitMix64 as a sequential generator (Weyl increment + finalizer).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// The Weyl increment (golden-ratio gamma).
    pub const GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Advance by `n` outputs in O(1): the state is a Weyl sequence, so
    /// `n` steps are one multiply. Counts *native* steps — both
    /// [`Rng::next_u32`] and [`Rng::next_u64`] consume exactly one.
    pub fn advance(&mut self, n: u64) {
        self.state = self.state.wrapping_add(n.wrapping_mul(Self::GAMMA));
    }

    /// Far jump: 2^32 outputs, as for [`Pcg32::jump`].
    pub fn jump(&mut self) {
        self.advance(1 << 32);
    }

    #[inline]
    pub fn next_u64_native(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

impl Rng for SplitMix64 {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        (self.next_u64_native() >> 32) as u32
    }

    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.next_u64_native()
    }
}

/// Raw 64-bit multiplicative LCG (MMIX constants), emitting its LOW 32
/// bits — a classic statistical-quality failure (low bits have short
/// periods). Battery self-test material.
#[derive(Debug, Clone)]
pub struct Lcg64 {
    state: u64,
}

impl Lcg64 {
    pub const MULT: u64 = 6_364_136_223_846_793_005;
    pub const INC: u64 = 1_442_695_040_888_963_407;

    pub fn new(seed: u64) -> Self {
        Lcg64 { state: seed }
    }

    /// Advance by `n` outputs in O(log n) ([`lcg_skip`]).
    pub fn advance(&mut self, n: u64) {
        self.state = lcg_skip(self.state, Self::MULT, Self::INC, n);
    }

    /// Far jump: 2^32 outputs, as for [`Pcg32::jump`].
    pub fn jump(&mut self) {
        self.advance(1 << 32);
    }
}

impl Rng for Lcg64 {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        self.state = self.state.wrapping_mul(Self::MULT).wrapping_add(Self::INC);
        self.state as u32 // deliberately the weak low half
    }
}

/// Not a generator at all: returns consecutive integers. The battery MUST
/// reject this instantly; if it does not, the battery is broken.
#[derive(Debug, Clone)]
pub struct WeakCounter {
    state: u32,
}

impl WeakCounter {
    pub fn new(seed: u32) -> Self {
        WeakCounter { state: seed }
    }
}

impl Rng for WeakCounter {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        self.state = self.state.wrapping_add(1);
        self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg32_reference_vector() {
        // pcg32_srandom(42, 54) first outputs, from the PCG reference
        // implementation's demo output.
        let mut rng = Pcg32::new(42, 54);
        let first: Vec<u32> = (0..6).map(|_| rng.next_u32()).collect();
        assert_eq!(
            first,
            vec![0xA15C_02B7, 0x7B47_F409, 0xBA1D_3330, 0x83D2_F293, 0xBFA4_784B, 0xCBED_606E]
        );
    }

    #[test]
    fn splitmix_matches_counter_mix() {
        // Sequential SplitMix64 from state s == stateless splitmix64(s + k*gamma)?
        // Not in general (state advances before mixing); but the first
        // output must equal counter::splitmix64(seed).
        let mut rng = SplitMix64::new(987);
        assert_eq!(rng.next_u64_native(), crate::core::counter::splitmix64(987));
    }

    #[test]
    fn advance_matches_stepping() {
        // Pcg32 / Lcg64 / SplitMix64: skip-ahead == n sequential outputs.
        for n in [0u64, 1, 2, 13, 100] {
            let mut a = Pcg32::new(42, 54);
            let mut b = Pcg32::new(42, 54);
            a.advance(n);
            for _ in 0..n {
                b.next_u32();
            }
            assert_eq!(a.next_u32(), b.next_u32(), "pcg n={n}");

            let mut a = Lcg64::new(7);
            let mut b = Lcg64::new(7);
            a.advance(n);
            for _ in 0..n {
                b.next_u32();
            }
            assert_eq!(a.next_u32(), b.next_u32(), "lcg n={n}");

            let mut a = SplitMix64::new(9);
            let mut b = SplitMix64::new(9);
            a.advance(n);
            for _ in 0..n {
                b.next_u64_native();
            }
            assert_eq!(a.next_u64_native(), b.next_u64_native(), "splitmix n={n}");
        }
    }

    #[test]
    fn jump_is_2_32_steps() {
        // lcg_skip is O(log n), so the far jump can be cross-checked
        // against two half-jumps (exponent additivity) rather than 2^32
        // actual steps.
        let mut once = Pcg32::new(3, 1);
        once.advance(1 << 32);
        let mut twice = Pcg32::new(3, 1);
        twice.advance(1 << 31);
        twice.advance(1 << 31);
        assert_eq!(once.next_u32(), twice.next_u32());
        let mut j = Pcg32::new(3, 1);
        j.jump();
        let mut a = Pcg32::new(3, 1);
        a.advance(1 << 32);
        assert_eq!(j.next_u32(), a.next_u32());
    }

    #[test]
    fn weak_counter_is_a_counter() {
        let mut w = WeakCounter::new(10);
        assert_eq!((w.next_u32(), w.next_u32(), w.next_u32()), (11, 12, 13));
    }

    #[test]
    fn lcg_low_bits_alternate() {
        // Low bit of an LCG with odd increment alternates — the canonical
        // defect the battery's frequency/serial tests must catch.
        let mut rng = Lcg64::new(77);
        let bits: Vec<u32> = (0..8).map(|_| rng.next_u32() & 1).collect();
        for i in 1..bits.len() {
            assert_ne!(bits[i], bits[i - 1], "low bit must alternate: {bits:?}");
        }
    }
}
