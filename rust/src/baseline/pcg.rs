//! Sequential baselines: PCG32, SplitMix64, a raw LCG, and a deliberately
//! broken generator. PCG/SplitMix are "known-good" controls for the
//! statistical battery; `Lcg64`'s low bits and [`WeakCounter`] are the
//! "known-bad" controls that prove the battery has detection power
//! (DESIGN.md test plan: the battery must fail them).

use crate::core::traits::Rng;

/// PCG32 (O'Neill 2014): 64-bit LCG state, XSH-RR output.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub const MULT: u64 = 6_364_136_223_846_793_005;

    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }
}

impl Rng for Pcg32 {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(Self::MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }
}

/// SplitMix64 as a sequential generator (Weyl increment + finalizer).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64_native(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

impl Rng for SplitMix64 {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        (self.next_u64_native() >> 32) as u32
    }

    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.next_u64_native()
    }
}

/// Raw 64-bit multiplicative LCG (MMIX constants), emitting its LOW 32
/// bits — a classic statistical-quality failure (low bits have short
/// periods). Battery self-test material.
#[derive(Debug, Clone)]
pub struct Lcg64 {
    state: u64,
}

impl Lcg64 {
    pub fn new(seed: u64) -> Self {
        Lcg64 { state: seed }
    }
}

impl Rng for Lcg64 {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        self.state = self
            .state
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(1_442_695_040_888_963_407);
        self.state as u32 // deliberately the weak low half
    }
}

/// Not a generator at all: returns consecutive integers. The battery MUST
/// reject this instantly; if it does not, the battery is broken.
#[derive(Debug, Clone)]
pub struct WeakCounter {
    state: u32,
}

impl WeakCounter {
    pub fn new(seed: u32) -> Self {
        WeakCounter { state: seed }
    }
}

impl Rng for WeakCounter {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        self.state = self.state.wrapping_add(1);
        self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg32_reference_vector() {
        // pcg32_srandom(42, 54) first outputs, from the PCG reference
        // implementation's demo output.
        let mut rng = Pcg32::new(42, 54);
        let first: Vec<u32> = (0..6).map(|_| rng.next_u32()).collect();
        assert_eq!(
            first,
            vec![0xA15C_02B7, 0x7B47_F409, 0xBA1D_3330, 0x83D2_F293, 0xBFA4_784B, 0xCBED_606E]
        );
    }

    #[test]
    fn splitmix_matches_counter_mix() {
        // Sequential SplitMix64 from state s == stateless splitmix64(s + k*gamma)?
        // Not in general (state advances before mixing); but the first
        // output must equal counter::splitmix64(seed).
        let mut rng = SplitMix64::new(987);
        assert_eq!(rng.next_u64_native(), crate::core::counter::splitmix64(987));
    }

    #[test]
    fn weak_counter_is_a_counter() {
        let mut w = WeakCounter::new(10);
        assert_eq!((w.next_u32(), w.next_u32(), w.next_u32()), (11, 12, 13));
    }

    #[test]
    fn lcg_low_bits_alternate() {
        // Low bit of an LCG with odd increment alternates — the canonical
        // defect the battery's frequency/serial tests must catch.
        let mut rng = Lcg64::new(77);
        let bits: Vec<u32> = (0..8).map(|_| rng.next_u32() & 1).collect();
        for i in 1..bits.len() {
            assert_ne!(bits[i], bits[i - 1], "low bit must alternate: {bits:?}");
        }
    }
}
