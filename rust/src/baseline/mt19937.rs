//! MT19937 (Matsumoto & Nishimura 1998) — `std::mt19937`, the Fig. 4a
//! baseline. Faithful reproduction including the standard `init_genrand`
//! seeding: 624 words of state are fully initialized on construction,
//! which is exactly why short streams are expensive (the paper's point),
//! and why 2.5 kB of state disqualifies it from GPU per-thread use.
//!
//! **No `advance`/`jump`**: skipping n MT19937 outputs requires either n
//! twists or a GF(2) polynomial jump over a degree-19937 characteristic
//! polynomial (Haramoto et al. 2008) — far outside this baseline's
//! scope, and exactly the contrast with the counter engines' O(1)
//! `advance` that `docs/stream-contracts.md` §5 documents.

use crate::core::traits::Rng;

const N: usize = 624;
const M: usize = 397;
const MATRIX_A: u32 = 0x9908_B0DF;
const UPPER_MASK: u32 = 0x8000_0000;
const LOWER_MASK: u32 = 0x7FFF_FFFF;

/// The C++ `std::mt19937` default seed.
pub const DEFAULT_SEED: u32 = 5489;

/// Mersenne Twister with the standard 32-bit seeding routine.
#[derive(Clone)]
pub struct Mt19937 {
    mt: [u32; N],
    mti: usize,
}

impl Mt19937 {
    /// `init_genrand` — the standard Knuth-multiplier seeding.
    pub fn new(seed: u32) -> Self {
        let mut mt = [0u32; N];
        mt[0] = seed;
        for i in 1..N {
            mt[i] = 1_812_433_253u32
                .wrapping_mul(mt[i - 1] ^ (mt[i - 1] >> 30))
                .wrapping_add(i as u32);
        }
        Mt19937 { mt, mti: N } // N: force twist on first draw
    }

    fn twist(&mut self) {
        for i in 0..N {
            let y = (self.mt[i] & UPPER_MASK) | (self.mt[(i + 1) % N] & LOWER_MASK);
            let mut next = self.mt[(i + M) % N] ^ (y >> 1);
            if y & 1 != 0 {
                next ^= MATRIX_A;
            }
            self.mt[i] = next;
        }
        self.mti = 0;
    }
}

impl Default for Mt19937 {
    fn default() -> Self {
        Mt19937::new(DEFAULT_SEED)
    }
}

impl Rng for Mt19937 {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        if self.mti >= N {
            self.twist();
        }
        let mut y = self.mt[self.mti];
        self.mti += 1;
        // Tempering.
        y ^= y >> 11;
        y ^= (y << 7) & 0x9D2C_5680;
        y ^= (y << 15) & 0xEFC6_0000;
        y ^= y >> 18;
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_cpp_std_mt19937_10000th() {
        // The C++ standard pins mt19937's 10000th consecutive invocation
        // (default-seeded) to 4123659995 ([rand.predef]).
        let mut rng = Mt19937::default();
        let mut last = 0;
        for _ in 0..10_000 {
            last = rng.next_u32();
        }
        assert_eq!(last, 4_123_659_995);
    }

    #[test]
    fn reference_first_outputs_seed_5489() {
        // First outputs of the canonical mt19937ar with seed 5489.
        let mut rng = Mt19937::new(5489);
        let first: Vec<u32> = (0..4).map(|_| rng.next_u32()).collect();
        assert_eq!(first, vec![3_499_211_612, 581_869_302, 3_890_346_734, 3_586_334_585]);
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let w = |seed| -> Vec<u32> {
            let mut r = Mt19937::new(seed);
            (0..8).map(|_| r.next_u32()).collect()
        };
        assert_eq!(w(1), w(1));
        assert_ne!(w(1), w(2));
    }

    #[test]
    fn state_is_2_5_kilobytes() {
        // The GPU-disqualification number from the paper's background.
        assert!(std::mem::size_of::<Mt19937>() >= 624 * 4);
    }
}
