//! xoshiro256++ (Blackman & Vigna) — a modern sequential baseline,
//! seeded via splitmix64 as its authors prescribe, with the authors'
//! polynomial `jump()`/`long_jump()` (2^128 / 2^192 steps) so the bench
//! comparison against the counter engines' O(1) `advance` is honest:
//! this is the strongest skip-ahead a sequential xoshiro offers — fixed
//! strides only, no arbitrary-`n` advance without a GF(2) matrix power.

use crate::core::counter::splitmix64;
use crate::core::traits::Rng;

/// Characteristic-polynomial table for `jump()`: 2^128 steps
/// (Blackman & Vigna's reference `xoshiro256plusplus.c`).
const JUMP: [u64; 4] =
    [0x180E_C6D3_3CFD_0ABA, 0xD5A6_1266_F0C9_392C, 0xA958_2618_E03F_C9AA, 0x39AB_DC45_29B1_661C];
/// Table for `long_jump()`: 2^192 steps.
const LONG_JUMP: [u64; 4] =
    [0x76E1_5D3E_FEFD_CBBF, 0xC500_4E44_1C52_2FB3, 0x7771_0069_854E_E241, 0x3910_9BB0_2ACB_E635];

#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    pub fn new(seed: u64) -> Self {
        // Authors' recommended seeding: four splitmix64 outputs.
        let mut sm = seed;
        let mut s = [0u64; 4];
        for w in s.iter_mut() {
            *w = splitmix64(sm);
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
        }
        Xoshiro256pp { s }
    }

    #[inline]
    pub fn next_u64_native(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Apply a jump polynomial: the new state is the GF(2)-linear
    /// combination of the trajectory states selected by the table's
    /// bits — the authors' reference algorithm verbatim.
    fn jump_with(&mut self, table: &[u64; 4]) {
        let mut s = [0u64; 4];
        for &word in table {
            for b in 0..64 {
                if (word >> b) & 1 == 1 {
                    for (acc, cur) in s.iter_mut().zip(self.s.iter()) {
                        *acc ^= *cur;
                    }
                }
                self.next_u64_native();
            }
        }
        self.s = s;
    }

    /// Jump 2^128 native steps (= 2^128 `next_u32` outputs here, since
    /// one output consumes one native step): partitions the 2^256-step
    /// period into 2^128 non-overlapping subsequences.
    pub fn jump(&mut self) {
        self.jump_with(&JUMP);
    }

    /// Jump 2^192 native steps — for distributing starting points to
    /// 2^64 coarse partitions that are themselves `jump()`-splittable.
    pub fn long_jump(&mut self) {
        self.jump_with(&LONG_JUMP);
    }
}

impl Rng for Xoshiro256pp {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        (self.next_u64_native() >> 32) as u32
    }

    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.next_u64_native()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let w = |seed| -> Vec<u64> {
            let mut r = Xoshiro256pp::new(seed);
            (0..8).map(|_| r.next_u64_native()).collect()
        };
        assert_eq!(w(1), w(1));
        assert_ne!(w(1), w(2));
    }

    #[test]
    fn known_algebra_first_step() {
        // First output is rotl(s0 + s3, 23) + s0 for the seeded state —
        // check against a hand-computed value from the seeding path.
        let mut sm = 42u64;
        let mut s = [0u64; 4];
        for w in s.iter_mut() {
            *w = splitmix64(sm);
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
        }
        let expect = s[0].wrapping_add(s[3]).rotate_left(23).wrapping_add(s[0]);
        assert_eq!(Xoshiro256pp::new(42).next_u64_native(), expect);
    }

    #[test]
    fn jump_commutes_with_stepping() {
        // jump() is a polynomial in the (linear) transition map, so it
        // must commute with single steps: T(J(s)) == J(T(s)). Catches
        // accumulation bugs in the table walk independently of the
        // (unverifiable-by-stepping) 2^128 stride.
        let mut a = Xoshiro256pp::new(9);
        a.next_u64_native();
        a.jump();
        let mut b = Xoshiro256pp::new(9);
        b.jump();
        b.next_u64_native();
        assert_eq!(a.next_u64_native(), b.next_u64_native());
    }

    #[test]
    fn jumps_are_deterministic_and_distinct() {
        let jumped = |long: bool| -> Vec<u64> {
            let mut r = Xoshiro256pp::new(5);
            if long {
                r.long_jump();
            } else {
                r.jump();
            }
            (0..4).map(|_| r.next_u64_native()).collect()
        };
        assert_eq!(jumped(false), jumped(false));
        assert_ne!(jumped(false), jumped(true));
        let base: Vec<u64> = {
            let mut r = Xoshiro256pp::new(5);
            (0..4).map(|_| r.next_u64_native()).collect()
        };
        assert_ne!(jumped(false), base);
    }

    #[test]
    fn no_trivial_zero_sink() {
        let mut r = Xoshiro256pp::new(0);
        assert!((0..16).map(|_| r.next_u64_native()).any(|v| v != 0));
    }
}
