//! xoshiro256++ (Blackman & Vigna) — a modern sequential baseline,
//! seeded via splitmix64 as its authors prescribe.

use crate::core::counter::splitmix64;
use crate::core::traits::Rng;

#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    pub fn new(seed: u64) -> Self {
        // Authors' recommended seeding: four splitmix64 outputs.
        let mut sm = seed;
        let mut s = [0u64; 4];
        for w in s.iter_mut() {
            *w = splitmix64(sm);
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
        }
        Xoshiro256pp { s }
    }

    #[inline]
    pub fn next_u64_native(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }
}

impl Rng for Xoshiro256pp {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        (self.next_u64_native() >> 32) as u32
    }

    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.next_u64_native()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let w = |seed| -> Vec<u64> {
            let mut r = Xoshiro256pp::new(seed);
            (0..8).map(|_| r.next_u64_native()).collect()
        };
        assert_eq!(w(1), w(1));
        assert_ne!(w(1), w(2));
    }

    #[test]
    fn known_algebra_first_step() {
        // First output is rotl(s0 + s3, 23) + s0 for the seeded state —
        // check against a hand-computed value from the seeding path.
        let mut sm = 42u64;
        let mut s = [0u64; 4];
        for w in s.iter_mut() {
            *w = splitmix64(sm);
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
        }
        let expect = s[0].wrapping_add(s[3]).rotate_left(23).wrapping_add(s[0]);
        assert_eq!(Xoshiro256pp::new(42).next_u64_native(), expect);
    }

    #[test]
    fn no_trivial_zero_sink() {
        let mut r = Xoshiro256pp::new(0);
        assert!((0..16).map(|_| r.next_u64_native()).any(|v| v != 0));
    }
}
