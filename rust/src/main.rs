//! `openrand` — the launcher.
//!
//! Subcommands:
//!
//! * `generate`  — stream random numbers from any engine to stdout;
//!   `--dist normal|ziggurat|exp|poisson|uniform|bernoulli|binomial|alias`
//!   streams distribution samples instead of raw words.
//! * `brownian`  — run the Brownian-dynamics macro-benchmark on the host
//!   (multithreaded) or device (PJRT AOT artifact) backend.
//! * `stats`     — run the Crush-lite statistical battery (E3), the
//!   HOOMD-style parallel-stream suite (E4), the `--inter-stream`
//!   key-family correlation battery (round-robin interleave of
//!   `--streams` StreamKey children, jump-ahead addressed), or with
//!   `--dist-battery` the KS/χ²/moment checks on distribution outputs.
//! * `repro`     — reproducibility verification ladder (E6);
//!   `--verbose` adds device buffer-pool observability.
//! * `artifacts` — list the AOT artifacts the runtime can execute.
//! * `serve`     — keyed-stream RNG daemon over TCP (`docs/serve.md`):
//!   replies byte-identical to `generate --key`, with an LRU block
//!   cache, request coalescing, and BUSY backpressure.
//! * `fetch`     — client for `serve`: fetch a keyed fill (printed
//!   exactly like `generate`), server STATS, or remote shutdown.
//! * `campaign`  — large-N simulation campaigns (`docs/campaigns.md`):
//!   `run` a Brownian/DPD trajectory with tiled epoch-addressed fills
//!   and optional checkpointing, `resume` one bitwise from a checkpoint
//!   file, or `validate` the recovered diffusion constant against
//!   theory.
//!
//! `openrand --help` for options. Benchmarks that regenerate the paper's
//! figures live under `cargo bench` (see DESIGN.md experiment index).

use openrand::backend::{self, BackendKind, CrossoverTable, FillBackend};
use openrand::baseline::{Mt19937, Pcg32, Xoshiro256pp};
use openrand::coordinator::repro;
use openrand::coordinator::{Backend, SimDriver};
use openrand::core::{Generator, Rng};
use openrand::dist::{
    Bernoulli, Binomial, BoxMuller, DiscreteAlias, Distribution, Exponential, Poisson, Uniform,
    ZigguratNormal,
};
use openrand::runtime::ArtifactStore;
use openrand::serve::{Client, FillRequest, PayloadKind, ServeConfig, Server};
use openrand::sim::brownian::{BrownianParams, RngStyle};
use openrand::stats::parallel;
use openrand::stats::{run_battery, run_dist_battery, Verdict};
use openrand::stream::{DynStream, StreamKey};
use openrand::util::cli::{Args, OptSpec};

const COMMANDS: [&str; 8] =
    ["generate", "brownian", "stats", "repro", "artifacts", "serve", "fetch", "campaign"];

fn specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "help", help: "show this help", default: None, is_flag: true },
        OptSpec { name: "generator", help: "philox|philox2x32|threefry|threefry2x32|squares|tyche|tyche_i", default: Some("philox"), is_flag: false },
        OptSpec { name: "seed", help: "64-bit seed (hex ok)", default: Some("0"), is_flag: false },
        OptSpec { name: "ctr", help: "32-bit stream counter", default: Some("0"), is_flag: false },
        OptSpec { name: "key", help: "hierarchical stream key path 'SEED[/cID|/eT]...' (e.g. 7/c3/e1 = root(7).child(3).epoch(1)); replaces --seed/--ctr — '7/e1' is byte-identical to --seed 7 --ctr 1 (brownian/repro take the seed and derive epochs internally)", default: None, is_flag: false },
        OptSpec { name: "n", help: "count (supports k/M/G suffix)", default: Some("16"), is_flag: false },
        OptSpec { name: "format", help: "generate/fetch output: u32|u64|f32|f64 (fetch also: normal)", default: Some("u32"), is_flag: false },
        OptSpec { name: "crossover", help: "generate: auto/sched device crossover in words (k/M/G ok; overrides the persisted calibration; env OPENRAND_BACKEND_CROSSOVER elsewhere)", default: None, is_flag: false },
        OptSpec { name: "chunk-sweep", help: "stats: sweep BufferedWords chunk sizes {1k,4k,16k,64k} and report battery throughput per size", default: None, is_flag: true },
        OptSpec { name: "dist", help: "generate: sample a distribution instead of raw words: none|uniform|normal|ziggurat|exp|poisson|bernoulli|binomial|alias", default: Some("none"), is_flag: false },
        OptSpec { name: "lambda", help: "dist: rate for exp/poisson", default: Some("1.0"), is_flag: false },
        OptSpec { name: "lo", help: "dist: uniform lower bound", default: Some("0"), is_flag: false },
        OptSpec { name: "hi", help: "dist: uniform upper bound", default: Some("1"), is_flag: false },
        OptSpec { name: "p", help: "dist: success probability for bernoulli/binomial", default: Some("0.5"), is_flag: false },
        OptSpec { name: "trials", help: "dist: binomial trial count", default: Some("10"), is_flag: false },
        OptSpec { name: "weights", help: "dist: comma-separated alias-table weights", default: Some("1,2,3,4"), is_flag: false },
        OptSpec { name: "steps", help: "brownian/campaign: simulation steps (campaign resume: the *total* target epoch)", default: Some("100"), is_flag: false },
        OptSpec { name: "threads", help: "brownian/generate/campaign: host threads", default: Some("1"), is_flag: false },
        OptSpec { name: "model", help: "campaign: brownian|dpd", default: Some("brownian"), is_flag: false },
        OptSpec { name: "tile", help: "campaign: particles per tile (part of the trajectory identity; k/M ok)", default: Some("64k"), is_flag: false },
        OptSpec { name: "checkpoint", help: "campaign run/resume: write the end-state checkpoint to this file", default: None, is_flag: false },
        OptSpec { name: "from", help: "campaign resume: checkpoint file to resume from", default: None, is_flag: false },
        OptSpec { name: "relax", help: "campaign validate: epochs to discard before MSD sampling", default: Some("1000"), is_flag: false },
        OptSpec { name: "sample-every", help: "campaign validate: epochs between MSD samples", default: Some("50"), is_flag: false },
        OptSpec { name: "tolerance", help: "campaign validate: relative tolerance on the recovered diffusion constant", default: Some("0.05"), is_flag: false },
        OptSpec { name: "backend", help: "generate: host|par|device|auto|sched (fill backend); brownian: host|device", default: None, is_flag: false },
        OptSpec { name: "style", help: "brownian: openrand|curand_style|random123", default: Some("openrand"), is_flag: false },
        OptSpec { name: "words", help: "stats: words per test", default: Some("4M"), is_flag: false },
        OptSpec { name: "parallel", help: "stats: run the HOOMD parallel-stream suite", default: None, is_flag: true },
        OptSpec { name: "inter-stream", help: "stats: run the suite over a round-robin interleave of --streams StreamKey children (jump-ahead addressed)", default: None, is_flag: true },
        OptSpec { name: "streams", help: "inter-stream: number of sibling child streams to interleave", default: Some("4096"), is_flag: false },
        OptSpec { name: "stride", help: "inter-stream: per-stream word stride (sample every stride-th word)", default: Some("1"), is_flag: false },
        OptSpec { name: "dist-battery", help: "stats: run KS/chi2/moment checks on distribution outputs", default: None, is_flag: true },
        OptSpec { name: "baselines", help: "stats: also run mt19937/pcg32/xoshiro baselines", default: None, is_flag: true },
        OptSpec { name: "max-threads", help: "repro: thread ladder upper bound", default: Some("8"), is_flag: false },
        OptSpec { name: "verbose", help: "repro: also report device buffer-pool stats", default: None, is_flag: true },
        OptSpec { name: "addr", help: "serve: bind HOST:PORT (port 0 = ephemeral); fetch: server address", default: None, is_flag: false },
        OptSpec { name: "workers", help: "serve: worker threads (one connection at a time each)", default: Some("4"), is_flag: false },
        OptSpec { name: "queue", help: "serve: bounded connection-queue depth (beyond it, BUSY is shed)", default: Some("64"), is_flag: false },
        OptSpec { name: "cache-blocks", help: "serve: LRU cache capacity in 4096-word blocks (0 disables)", default: Some("1024"), is_flag: false },
        OptSpec { name: "fill-threads", help: "serve: host threads inside each worker's auto backend", default: Some("1"), is_flag: false },
        OptSpec { name: "metrics-interval", help: "serve: seconds between one-line metrics summaries on stderr", default: None, is_flag: false },
        OptSpec { name: "offset", help: "fetch: first element index (elements, not words)", default: Some("0"), is_flag: false },
        OptSpec { name: "stats", help: "fetch: print the server's STATS counters and exit", default: None, is_flag: true },
        OptSpec { name: "shutdown", help: "fetch: ask the server to shut down cleanly and exit", default: None, is_flag: true },
    ]
}

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let wants_help = raw.iter().any(|a| a == "--help" || a == "-h") || raw.is_empty();
    if wants_help {
        print!(
            "{}",
            Args::help(
                "openrand",
                "reproducible counter-based RNG for parallel computations (paper reproduction)",
                &COMMANDS,
                &specs()
            )
        );
        return;
    }
    let args = match Args::parse(raw, &COMMANDS, &specs()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let result = match args.command.as_deref() {
        Some("generate") => cmd_generate(&args),
        Some("brownian") => cmd_brownian(&args),
        Some("stats") => cmd_stats(&args),
        Some("repro") => cmd_repro(&args),
        Some("artifacts") => cmd_artifacts(),
        Some("serve") => cmd_serve(&args),
        Some("fetch") => cmd_fetch(&args),
        Some("campaign") => cmd_campaign(&args),
        _ => {
            eprintln!("error: missing command (try --help)");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_generator(args: &Args) -> Result<Generator, anyhow::Error> {
    let name = args.get_or("generator", "philox");
    Generator::parse(name).ok_or_else(|| anyhow::anyhow!("unknown generator '{name}'"))
}

/// Resolve the stream address: `--key PATH` (hierarchical, exclusive
/// with the legacy flags) or `--seed/--ctr` (the `StreamKey::raw`
/// equivalence — byte-identical streams either way).
fn resolve_key(args: &Args) -> anyhow::Result<StreamKey> {
    match args.get("key") {
        Some(spec) => {
            if args.get("seed").is_some() || args.get("ctr").is_some() {
                anyhow::bail!("--key replaces --seed/--ctr (pick one addressing)");
            }
            StreamKey::parse_path(spec).map_err(|e| anyhow::anyhow!("--key: {e}"))
        }
        None => {
            let seed = args.get_u64("seed", 0).map_err(anyhow::Error::msg)?;
            let ctr = args.get_u64("ctr", 0).map_err(anyhow::Error::msg)? as u32;
            Ok(StreamKey::raw(seed, ctr))
        }
    }
}

fn cmd_generate(args: &Args) -> anyhow::Result<()> {
    let gen = parse_generator(args)?;
    let key = resolve_key(args)?;
    let (seed, ctr) = (key.seed(), key.ctr());
    let n = args.get_usize("n", 16).map_err(anyhow::Error::msg)?;
    let dist = args.get_or("dist", "none").to_string();
    // Validate --format once, up front, so both the word-at-a-time and
    // backend paths report the identical error the identical way.
    let format = args.get_or("format", "u32").to_string();
    if dist == "none" && !matches!(format.as_str(), "u32" | "u64" | "f32" | "f64") {
        anyhow::bail!("unknown format '{format}' (u32|u64|f32|f64)");
    }
    let kind = match args.get("backend") {
        Some(s) => Some(
            BackendKind::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown backend '{s}' (host|par|device|auto|sched)"))?,
        ),
        None => None,
    };
    if args.get("crossover").is_some()
        && !matches!(kind, Some(BackendKind::Auto) | Some(BackendKind::Sched))
    {
        anyhow::bail!("--crossover only applies to --backend auto|sched");
    }
    if let Some(kind) = kind {
        if dist != "none" {
            anyhow::bail!("--backend applies to raw formats (drop --dist)");
        }
        let threads = args.get_usize("threads", 1).map_err(anyhow::Error::msg)?;
        if threads == 0 {
            anyhow::bail!("--threads must be positive");
        }
        // The backend path materializes the whole buffer (that is the
        // point — one deterministic bulk fill), so bound it: both by
        // the 2^32-word stream period and by a memory-sane CLI ceiling.
        // Larger runs stream through the plain path or split across
        // --ctr values.
        const CLI_FILL_CAP: usize = 1 << 26; // 64M elements (<= 512 MiB)
        if n > CLI_FILL_CAP {
            anyhow::bail!(
                "--n {n} is above the backend buffer cap ({CLI_FILL_CAP}); \
                 use the word-at-a-time path or split across --ctr values"
            );
        }
        return generate_backend(args, gen, seed, ctr, n, &format, kind, threads);
    }
    if dist != "none" {
        return generate_dist(args, gen, seed, ctr, n, &dist);
    }
    gen.with_rng(seed, ctr, |rng| {
        for _ in 0..n {
            match format.as_str() {
                "u32" => println!("{}", rng.next_u32()),
                "u64" => println!("{}", rng.next_u64()),
                "f32" => println!("{}", rng.draw_float()),
                "f64" => println!("{}", rng.draw_double()),
                other => unreachable!("format '{other}' validated above"),
            }
        }
    });
    Ok(())
}

/// `generate --backend <arm>`: batch-generate through the selected fill
/// backend (`openrand::backend`). Every arm is
/// byte-identical to the word-at-a-time path for every format — the
/// backend contract (`docs/backends.md`); `rust/tests/cli.rs` pins it
/// end to end. `--crossover N` overrides the calibrated host/device
/// switch point of the `auto` and `sched` arms.
#[allow(clippy::too_many_arguments)]
fn generate_backend(
    args: &Args,
    gen: Generator,
    seed: u64,
    ctr: u32,
    n: usize,
    format: &str,
    kind: BackendKind,
    threads: usize,
) -> anyhow::Result<()> {
    use std::io::Write as _;
    let mut b: Box<dyn backend::FillBackend> = match (kind, args.get("crossover")) {
        (BackendKind::Auto, Some(v)) => {
            let table = CrossoverTable::from_env_value(v)
                .ok_or_else(|| anyhow::anyhow!("--crossover: '{v}' is not a word count"))?;
            Box::new(backend::Auto::with_table(threads, table))
        }
        (BackendKind::Sched, Some(v)) => {
            let table = CrossoverTable::from_env_value(v)
                .ok_or_else(|| anyhow::anyhow!("--crossover: '{v}' is not a word count"))?;
            let mut model = backend::CostModel::load();
            model.crossover = table;
            Box::new(backend::Sched::with_model(threads, model))
        }
        _ => backend::make(kind, threads)?,
    };
    let stdout = std::io::stdout();
    let mut out = std::io::BufWriter::new(stdout.lock());
    match format {
        "u32" => {
            let mut buf = vec![0u32; n];
            b.fill_u32(gen, seed, ctr, &mut buf)?;
            for v in &buf {
                writeln!(out, "{v}")?;
            }
        }
        "u64" => {
            let mut buf = vec![0u64; n];
            b.fill_u64(gen, seed, ctr, &mut buf)?;
            for v in &buf {
                writeln!(out, "{v}")?;
            }
        }
        "f32" => {
            let mut buf = vec![0.0f32; n];
            b.fill_f32(gen, seed, ctr, &mut buf)?;
            for v in &buf {
                writeln!(out, "{v}")?;
            }
        }
        "f64" => {
            let mut buf = vec![0.0f64; n];
            b.fill_f64(gen, seed, ctr, &mut buf)?;
            for v in &buf {
                writeln!(out, "{v}")?;
            }
        }
        other => unreachable!("format '{other}' validated in cmd_generate"),
    }
    Ok(())
}

/// `generate --dist <name>`: stream distribution samples instead of raw
/// words (same engine/stream selection as the raw path).
fn generate_dist(
    args: &Args,
    gen: Generator,
    seed: u64,
    ctr: u32,
    n: usize,
    dist: &str,
) -> anyhow::Result<()> {
    let lambda = args.get_f64("lambda", 1.0).map_err(anyhow::Error::msg)?;
    let lo = args.get_f64("lo", 0.0).map_err(anyhow::Error::msg)?;
    let hi = args.get_f64("hi", 1.0).map_err(anyhow::Error::msg)?;
    let p = args.get_f64("p", 0.5).map_err(anyhow::Error::msg)?;
    let trials = args.get_u64("trials", 10).map_err(anyhow::Error::msg)?;
    // Parameter validation happens in the constructors; turn their
    // panics into CLI errors up front.
    match dist {
        "exp" | "poisson" if !(lambda.is_finite() && lambda > 0.0) => {
            anyhow::bail!("--lambda must be positive, got {lambda}")
        }
        "uniform" if !(lo.is_finite() && hi.is_finite() && lo < hi) => {
            anyhow::bail!("--lo/--hi must be finite with lo < hi (got {lo}, {hi})")
        }
        "bernoulli" | "binomial" if !(0.0..=1.0).contains(&p) => {
            anyhow::bail!("--p must be in [0, 1], got {p}")
        }
        // The O(n)-per-sample Bernoulli loop makes huge trial counts a
        // hang, and a silent u32 cast would truncate them to garbage.
        "binomial" if trials > 1_000_000 => {
            anyhow::bail!("--trials too large ({trials}; max 1000000)")
        }
        _ => {}
    }
    // Build the sampler up front (parameter errors surface before any
    // output), then stream through one shared loop: continuous
    // families as boxed `Distribution<f64>` trait objects, discrete
    // families widened to u64.
    enum Sampler {
        F(Box<dyn Distribution<f64>>),
        I(Box<dyn Fn(&mut dyn Rng) -> u64>),
    }
    let sampler = match dist {
        "uniform" => Sampler::F(Box::new(Uniform::new(lo, hi))),
        "normal" => Sampler::F(Box::new(BoxMuller::standard())),
        "ziggurat" => Sampler::F(Box::new(ZigguratNormal::standard())),
        "exp" => Sampler::F(Box::new(Exponential::new(lambda))),
        "poisson" => {
            let d = Poisson::new(lambda);
            Sampler::I(Box::new(move |r: &mut dyn Rng| d.sample(r)))
        }
        "bernoulli" => {
            let d = Bernoulli::new(p);
            Sampler::I(Box::new(move |r: &mut dyn Rng| d.sample(r) as u64))
        }
        "binomial" => {
            let d = Binomial::new(trials as u32, p);
            Sampler::I(Box::new(move |r: &mut dyn Rng| d.sample(r)))
        }
        "alias" => {
            let weights = args
                .get_or("weights", "1,2,3,4")
                .split(',')
                .map(|w| w.trim().parse::<f64>())
                .collect::<Result<Vec<f64>, _>>()
                .map_err(|e| anyhow::anyhow!("--weights: {e}"))?;
            if weights.iter().any(|w| !w.is_finite() || *w < 0.0)
                || weights.iter().sum::<f64>() <= 0.0
            {
                anyhow::bail!("--weights must be non-negative with a positive sum");
            }
            let d = DiscreteAlias::new(&weights);
            Sampler::I(Box::new(move |r: &mut dyn Rng| d.sample(r) as u64))
        }
        other => anyhow::bail!("unknown dist '{other}' (try --help)"),
    };
    gen.with_rng(seed, ctr, |rng| match &sampler {
        Sampler::F(d) => (0..n).for_each(|_| println!("{}", d.sample(rng))),
        Sampler::I(f) => (0..n).for_each(|_| println!("{}", f(rng))),
    });
    Ok(())
}

fn cmd_brownian(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 16_384).map_err(anyhow::Error::msg)?;
    let steps = args.get_usize("steps", 100).map_err(anyhow::Error::msg)? as u32;
    // Unified addressing here too — but brownian derives its per-step
    // sub-streams internally (ctr = step), so an epoch in the key would
    // be silently discarded; reject it rather than ignore it.
    let key = resolve_key(args)?;
    if key.ctr() != 0 {
        anyhow::bail!(
            "brownian derives per-step epochs internally (ctr = step); \
             give a key without /e (got {key})"
        );
    }
    let seed = key.seed();
    let threads = args.get_usize("threads", 1).map_err(anyhow::Error::msg)?;
    let style = match args.get_or("style", "openrand") {
        "openrand" => RngStyle::OpenRand,
        "curand_style" => RngStyle::CurandStyle,
        "random123" => RngStyle::Raw123,
        other => anyhow::bail!("unknown style '{other}'"),
    };
    let backend = match args.get_or("backend", "host") {
        "host" => Backend::Host { threads },
        "device" => Backend::Device,
        other => anyhow::bail!("unknown backend '{other}'"),
    };
    let params = BrownianParams { n_particles: n, steps, global_seed: seed, style };
    let (sim, metrics) = SimDriver::new(backend).run(params)?;
    println!("brownian {:?} style={}", backend, style.name());
    println!("  {}", metrics.summary());
    println!("  trajectory hash: {:016x}", sim.state_hash());
    Ok(())
}

fn cmd_stats(args: &Args) -> anyhow::Result<()> {
    let words = args.get_usize("words", 4 << 20).map_err(anyhow::Error::msg)?;
    let key = resolve_key(args)?;
    let seed = key.seed();
    let keyed = args.get("key").is_some();
    let gen = parse_generator(args)?;
    // Per-test stream addressing: with --key, test i draws from the
    // derived child root.child(i) (structural derivation); the legacy
    // --seed path keeps its historical `seed ^ (i << 32)` re-seeding
    // byte-for-byte.
    let test_stream = |i: usize| -> Box<dyn Rng> {
        if keyed {
            Box::new(DynStream::open(gen, key.child(i as u64)))
        } else {
            gen.boxed(seed ^ ((i as u64) << 32), 0)
        }
    };
    if args.flag("chunk-sweep") {
        println!("chunk-size sweep: {} ({} words/test budget)", gen.name(), words);
        println!(
            "{:<10} {:>14} {:>12} {:>10}",
            "chunk", "battery wall", "words/s", "failures"
        );
        let rows = openrand::stats::battery::chunk_sweep(gen.name(), words, test_stream);
        for r in &rows {
            println!(
                "{:<10} {:>14} {:>12} {:>10}",
                r.chunk,
                format!("{:.1} ms", r.wall.as_secs_f64() * 1e3),
                openrand::util::format::si(r.words_per_s),
                r.failures
            );
        }
        println!(
            "\nshipped default: {} words (stats::battery::DEFAULT_FILL_CHUNK);\n\
             re-run this sweep after hardware changes — see docs/backends.md.",
            openrand::stats::battery::DEFAULT_FILL_CHUNK
        );
        if rows.iter().any(|r| r.failures > 0) {
            anyhow::bail!("battery reported failures during the sweep");
        }
        return Ok(());
    }
    if args.flag("dist-battery") {
        let report = if keyed {
            // Child-derived per-test streams, word delivery through the
            // calibrated default Auto backend (stream::BackendWords).
            openrand::stats::distcheck::run_dist_battery_keyed(gen, key, words)
        } else {
            run_dist_battery(gen.name(), words, test_stream)
        };
        print!("{}", report.render());
        if !report.passed() {
            anyhow::bail!("distribution battery reported failures");
        }
        return Ok(());
    }
    if args.flag("inter-stream") {
        let streams = args.get_u64("streams", 4096).map_err(anyhow::Error::msg)?;
        let stride = args.get_u64("stride", 1).map_err(anyhow::Error::msg)?;
        if streams == 0 {
            anyhow::bail!("--streams must be >= 1");
        }
        if stride == 0 {
            anyhow::bail!("--stride must be >= 1");
        }
        println!(
            "inter-stream suite: {} x {} children of {} (stride {})",
            gen.name(),
            streams,
            key,
            stride
        );
        // Keyed variant: children are derived under the *full* key, so
        // `--key 7/e3` scrutinizes the child family of epoch 3 — the
        // exact addressing shape the campaign runner draws from. The
        // default key (ctr 0) is byte-identical to the historical
        // root-seed behavior.
        use openrand::stats::interstream::run_inter_stream_suite_keyed as run;
        let results = match gen {
            Generator::Philox => run::<openrand::core::Philox>(key, streams, stride, words),
            Generator::Philox2x32 => run::<openrand::core::Philox2x32>(key, streams, stride, words),
            Generator::Threefry => run::<openrand::core::Threefry>(key, streams, stride, words),
            Generator::Threefry2x32 => {
                run::<openrand::core::Threefry2x32>(key, streams, stride, words)
            }
            Generator::Squares => run::<openrand::core::Squares>(key, streams, stride, words),
            Generator::Tyche => run::<openrand::core::Tyche>(key, streams, stride, words),
            Generator::TycheI => run::<openrand::core::TycheI>(key, streams, stride, words),
        };
        let mut fails = 0;
        for r in &results {
            let v = match r.verdict() {
                Verdict::Pass => "pass",
                Verdict::Suspicious => "SUSPICIOUS",
                Verdict::Fail => {
                    fails += 1;
                    "FAIL"
                }
            };
            println!("  {:<22} p={:<12.3e} {v}", r.name, r.p);
        }
        println!("{} failures", fails);
        if fails > 0 {
            anyhow::bail!("inter-stream suite reported failures");
        }
        return Ok(());
    }
    if args.flag("parallel") {
        println!("parallel-stream suite (HOOMD procedure): {}", gen.name());
        let results = match gen {
            Generator::Philox => parallel::run_parallel_suite::<openrand::core::Philox>(seed, words),
            Generator::Philox2x32 => parallel::run_parallel_suite::<openrand::core::Philox2x32>(seed, words),
            Generator::Threefry => parallel::run_parallel_suite::<openrand::core::Threefry>(seed, words),
            Generator::Threefry2x32 => parallel::run_parallel_suite::<openrand::core::Threefry2x32>(seed, words),
            Generator::Squares => parallel::run_parallel_suite::<openrand::core::Squares>(seed, words),
            Generator::Tyche => parallel::run_parallel_suite::<openrand::core::Tyche>(seed, words),
            Generator::TycheI => parallel::run_parallel_suite::<openrand::core::TycheI>(seed, words),
        };
        let mut fails = 0;
        for r in &results {
            let v = match r.verdict() {
                Verdict::Pass => "pass",
                Verdict::Suspicious => "SUSPICIOUS",
                Verdict::Fail => {
                    fails += 1;
                    "FAIL"
                }
            };
            println!("  {:<22} p={:<12.3e} {v}", r.name, r.p);
        }
        println!("{} failures", fails);
        return Ok(());
    }
    let report = run_battery(gen.name(), words, test_stream);
    print!("{}", report.render());
    if args.flag("baselines") {
        for name in ["mt19937", "pcg32", "xoshiro256pp"] {
            let report = run_battery(name, words, |i| -> Box<dyn Rng> {
                let s = seed ^ ((i as u64) << 32);
                match name {
                    "mt19937" => Box::new(Mt19937::new(s as u32)),
                    "pcg32" => Box::new(Pcg32::new(s, 54)),
                    _ => Box::new(Xoshiro256pp::new(s)),
                }
            });
            print!("{}", report.render());
        }
    }
    Ok(())
}

fn cmd_repro(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 16_384).map_err(anyhow::Error::msg)?;
    let steps = args.get_usize("steps", 50).map_err(anyhow::Error::msg)? as u32;
    let key = resolve_key(args)?;
    let seed = key.seed();
    let max_threads = args.get_usize("max-threads", 8).map_err(anyhow::Error::msg)?;
    let params = BrownianParams {
        n_particles: n,
        steps,
        global_seed: seed,
        style: RngStyle::OpenRand,
    };
    let r1 = repro::verify_thread_invariance(params, max_threads)?;
    print!("{}", r1.render());
    let r2 = repro::verify_rerun(params, max_threads.max(2))?;
    print!("{}", r2.render());
    let r3 = repro::verify_backends(params, 1e-9)?;
    print!("{}", r3.render());
    let r4 = repro::verify_fill_invariance::<openrand::core::Philox>(1 << 20, max_threads, seed);
    print!("{}", r4.render());
    // The backend-invariance ladder: host / par{1,2,8} / device (when
    // artifacts exist) / auto, byte-compared against the serial arm.
    let gen = parse_generator(args)?;
    let r5 = repro::verify_backend_invariance(gen, 1 << 20, seed, key.ctr(), max_threads);
    print!("{}", r5.render());
    // The StreamKey zero-drift ladder: raw-key streams == legacy
    // CounterRng::new streams for all seven engines, plus the
    // cross-layer derivation KAT.
    let r6 = repro::verify_key_equivalence(seed, key.ctr(), 1 << 16);
    print!("{}", r6.render());
    // The mixed-arm shard-scheduler ladder: sched output over random
    // shard plans byte-equal to the serial fill; device shards degrade
    // to host on stub builds (the note in the row says which ran).
    let r7 = repro::verify_sched_invariance(gen, 1 << 18, seed, key.ctr(), 6, max_threads);
    print!("{}", r7.render());
    if args.flag("verbose") {
        // Device buffer-pool observability (the serve metrics layer
        // aggregates the same counters fleet-wide): repeated fills of
        // one artifact-sized buffer should hit the param pool after the
        // first upload.
        match backend::DeviceFill::try_new() {
            Ok(mut dev) => {
                let mut buf = vec![0u32; 65_536];
                for _ in 0..3 {
                    if let Err(e) = dev.fill_u32(Generator::Philox, seed, 0, &mut buf) {
                        println!("device buffer pool: fill failed ({e:#})");
                        break;
                    }
                }
                let (hits, uploads) = dev.pool_stats();
                println!("device buffer pool: hits={hits} uploads={uploads}");
            }
            Err(e) => println!("device buffer pool: unavailable ({e:#})"),
        }
    }
    if r1.consistent
        && r2.consistent
        && r3.consistent
        && r4.consistent
        && r5.consistent
        && r6.consistent
        && r7.consistent
    {
        println!("ALL REPRODUCIBILITY CHECKS PASSED");
        Ok(())
    } else {
        anyhow::bail!("reproducibility violated");
    }
}

/// `openrand serve --addr HOST:PORT`: run the keyed-stream daemon in
/// the foreground until a client sends SHUTDOWN (`fetch --shutdown`).
/// Binding port 0 picks an ephemeral port; the resolved address is the
/// first stdout line (`serving on HOST:PORT` — CI greps it).
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    use std::io::Write as _;
    let addr = args
        .get("addr")
        .ok_or_else(|| anyhow::anyhow!("serve requires --addr HOST:PORT (port 0 = ephemeral)"))?
        .to_string();
    let metrics_interval = match args.get("metrics-interval") {
        Some(_) => {
            let secs = args.get_f64("metrics-interval", 10.0).map_err(anyhow::Error::msg)?;
            if !(secs.is_finite() && secs > 0.0) {
                anyhow::bail!("--metrics-interval must be positive seconds, got {secs}");
            }
            Some(std::time::Duration::from_secs_f64(secs))
        }
        None => None,
    };
    let cfg = ServeConfig {
        addr,
        workers: args.get_usize("workers", 4).map_err(anyhow::Error::msg)?,
        queue: args.get_usize("queue", 64).map_err(anyhow::Error::msg)?,
        cache_blocks: args.get_usize("cache-blocks", 1024).map_err(anyhow::Error::msg)?,
        fill_threads: args.get_usize("fill-threads", 1).map_err(anyhow::Error::msg)?,
        metrics_interval,
    };
    let server = Server::start(cfg)?;
    println!("serving on {}", server.local_addr());
    std::io::stdout().flush()?;
    server.run();
    Ok(())
}

/// `openrand fetch --addr A`: client for the serve daemon. Three
/// exclusive modes: a keyed FILL (default; printed with the identical
/// `{}` formatting `generate` uses, so `cmp` holds line for line),
/// `--stats`, or `--shutdown`.
fn cmd_fetch(args: &Args) -> anyhow::Result<()> {
    use std::io::Write as _;
    let addr = args
        .get("addr")
        .ok_or_else(|| anyhow::anyhow!("fetch requires --addr HOST:PORT"))?;
    if args.flag("stats") && args.flag("shutdown") {
        anyhow::bail!("--stats and --shutdown are exclusive");
    }
    let mut client = Client::connect(addr)?;
    if args.flag("stats") {
        print!("{}", client.stats()?);
        return Ok(());
    }
    if args.flag("shutdown") {
        client.shutdown()?;
        println!("server shut down");
        return Ok(());
    }
    let gen = parse_generator(args)?;
    let kind = PayloadKind::parse(args.get_or("format", "u32")).ok_or_else(|| {
        anyhow::anyhow!("unknown fetch format '{}' (u32|u64|f32|f64|normal)", args.get_or("format", "u32"))
    })?;
    let n = args.get_usize("n", 16).map_err(anyhow::Error::msg)?;
    if n as u64 > openrand::serve::proto::MAX_FILL_ELEMS as u64 {
        anyhow::bail!(
            "--n {n} is above the per-request cap ({}); split across --offset windows",
            openrand::serve::proto::MAX_FILL_ELEMS
        );
    }
    let offset = args.get_u64("offset", 0).map_err(anyhow::Error::msg)?;
    // Split --key into the tenant root (the leading seed segment) and
    // the relative derivation path shipped on the wire; the server
    // re-resolves `{tenant}/{path}` through the same parse_path grammar,
    // so the reply is byte-identical to `generate --key` (offset 0).
    let spec = args.get_or("key", "0");
    let (root_spec, rel) = match spec.split_once('/') {
        Some((root, rest)) => (root, rest),
        None => (spec, ""),
    };
    let root = StreamKey::parse_path(root_spec).map_err(|e| anyhow::anyhow!("--key: {e}"))?;
    let req = FillRequest {
        tenant: root.seed(),
        path: rel.to_string(),
        gen,
        kind,
        offset,
        len: n as u32,
    };
    let bytes = client.fill(&req)?;
    let stdout = std::io::stdout();
    let mut out = std::io::BufWriter::new(stdout.lock());
    match kind {
        PayloadKind::U32 => {
            for c in bytes.chunks_exact(4) {
                writeln!(out, "{}", u32::from_le_bytes(c.try_into().unwrap()))?;
            }
        }
        PayloadKind::U64 => {
            for c in bytes.chunks_exact(8) {
                writeln!(out, "{}", u64::from_le_bytes(c.try_into().unwrap()))?;
            }
        }
        PayloadKind::F32 => {
            for c in bytes.chunks_exact(4) {
                writeln!(out, "{}", f32::from_le_bytes(c.try_into().unwrap()))?;
            }
        }
        PayloadKind::F64 | PayloadKind::Normal => {
            for c in bytes.chunks_exact(8) {
                writeln!(out, "{}", f64::from_le_bytes(c.try_into().unwrap()))?;
            }
        }
    }
    Ok(())
}

/// `openrand campaign run|resume|validate` (`docs/campaigns.md`): the
/// Tier-1 end-to-end scenario. `run` starts a fresh trajectory and can
/// write its end-state checkpoint; `resume` rebuilds bitwise from a
/// checkpoint file (`--steps` is the *total* target epoch, so an
/// interrupted run resumed to the same target writes a byte-identical
/// end checkpoint — CI `cmp`s exactly that); `validate` recovers the
/// Brownian diffusion constant and gates it against theory.
fn cmd_campaign(args: &Args) -> anyhow::Result<()> {
    use openrand::campaign::{self, Campaign, CampaignParams, Checkpoint, Model, ValidateConfig};
    let action = match args.positional().first() {
        Some(a) => a.as_str(),
        None => anyhow::bail!("campaign needs an action: run|resume|validate"),
    };
    if args.positional().len() > 1 {
        anyhow::bail!("campaign takes one action, got {:?}", args.positional());
    }
    let steps = args.get_usize("steps", 100).map_err(anyhow::Error::msg)? as u32;
    let threads = args.get_usize("threads", 1).map_err(anyhow::Error::msg)?;
    let out_path = args.get("checkpoint").map(str::to_string);

    // Fresh-trajectory params (run/validate). Resume takes its identity
    // from the checkpoint file instead and rejects these flags' intent
    // implicitly: only --steps/--threads/--checkpoint apply there.
    let fresh_params = |args: &Args| -> anyhow::Result<CampaignParams> {
        let model = args.get_or("model", "brownian");
        let model = Model::parse(model).ok_or_else(|| {
            anyhow::anyhow!("unknown model '{model}' (brownian|dpd)")
        })?;
        let key = resolve_key(args)?;
        if key.ctr() != 0 {
            anyhow::bail!(
                "campaign derives per-step epochs internally (key.epoch(t)); \
                 give a key without /e (got {key})"
            );
        }
        let mut p = CampaignParams::new(
            model,
            args.get_usize("n", 1 << 20).map_err(anyhow::Error::msg)?,
            key,
        );
        p.gen = parse_generator(args)?;
        p.threads = threads;
        p.tile = args.get_usize("tile", campaign::DEFAULT_TILE).map_err(anyhow::Error::msg)?;
        Ok(p)
    };

    let report = |c: &Campaign, wall: std::time::Duration, epochs_run: u32| {
        let p = c.params();
        println!(
            "campaign {} n={} tile={} gen={} threads={}",
            p.model.name(),
            p.n_particles,
            p.tile,
            p.gen.name(),
            p.threads
        );
        let rate = p.n_particles as f64 * epochs_run as f64 / wall.as_secs_f64().max(1e-9);
        println!(
            "  {} epochs in {:.2} s ({:.1} Mparticle-steps/s)",
            epochs_run,
            wall.as_secs_f64(),
            rate / 1e6
        );
        println!("  epoch: {}  trajectory hash: {:016x}", c.epoch(), c.state_hash());
    };

    match action {
        "run" => {
            let mut c = Campaign::new(fresh_params(args)?)?;
            let t0 = std::time::Instant::now();
            c.run_to(steps)?;
            report(&c, t0.elapsed(), steps);
            if let Some(path) = out_path {
                c.checkpoint().write_file(&path)?;
                println!("  checkpoint: {path} ({} bytes)", Checkpoint::encoded_len(c.params().n_particles));
            }
            Ok(())
        }
        "resume" => {
            let from = args
                .get("from")
                .ok_or_else(|| anyhow::anyhow!("campaign resume requires --from CHECKPOINT"))?;
            let ck = Checkpoint::read_file(from)?;
            if steps < ck.epoch {
                anyhow::bail!(
                    "--steps {steps} is before the checkpoint epoch {} \
                     (--steps is the total target epoch)",
                    ck.epoch
                );
            }
            let mut c = Campaign::resume(&ck, threads)?;
            let epochs_run = steps - ck.epoch;
            let t0 = std::time::Instant::now();
            c.run_to(steps)?;
            report(&c, t0.elapsed(), epochs_run);
            println!("  resumed from {from} at epoch {}", ck.epoch);
            if let Some(path) = out_path {
                c.checkpoint().write_file(&path)?;
                println!("  checkpoint: {path} ({} bytes)", Checkpoint::encoded_len(c.params().n_particles));
            }
            Ok(())
        }
        "validate" => {
            let cfg = ValidateConfig {
                relax_epochs: args.get_usize("relax", 1000).map_err(anyhow::Error::msg)? as u32,
                sample_every: args.get_usize("sample-every", 50).map_err(anyhow::Error::msg)?
                    as u32,
                tolerance: args.get_f64("tolerance", campaign::DIFFUSION_TOLERANCE)
                    .map_err(anyhow::Error::msg)?,
            };
            if !(cfg.tolerance.is_finite() && cfg.tolerance > 0.0) {
                anyhow::bail!("--tolerance must be positive, got {}", cfg.tolerance);
            }
            let params = fresh_params(args)?;
            let est = campaign::validate(params, steps, cfg)?;
            println!(
                "campaign validate {} n={} steps={} (relax {}, sample every {})",
                params.model.name(),
                params.n_particles,
                steps,
                cfg.relax_epochs,
                cfg.sample_every
            );
            println!(
                "  D_est {:.6}  D_theory {:.6}  rel err {:.4} ({} MSD samples)",
                est.d_est,
                est.d_theory,
                est.rel_err(),
                est.samples
            );
            if est.within(cfg.tolerance) {
                println!("  PASS (tolerance {})", cfg.tolerance);
                Ok(())
            } else {
                anyhow::bail!(
                    "diffusion constant outside tolerance: rel err {:.4} > {}",
                    est.rel_err(),
                    cfg.tolerance
                );
            }
        }
        other => anyhow::bail!("unknown campaign action '{other}' (run|resume|validate)"),
    }
}

fn cmd_artifacts() -> anyhow::Result<()> {
    let store = ArtifactStore::open_default()?;
    println!("artifact dir: {:?}", store.dir());
    for e in &store.manifest.entries {
        let ins: Vec<String> = e.inputs.iter().map(|t| format!("{}{:?}", t.dtype, t.shape)).collect();
        let outs: Vec<String> = e.outputs.iter().map(|t| format!("{}{:?}", t.dtype, t.shape)).collect();
        println!("  {:<34} {} -> {}", e.name, ins.join(", "), outs.join(", "));
    }
    println!("{} artifacts", store.manifest.entries.len());
    Ok(())
}
