//! E2 — Fig. 4b: the Brownian-dynamics macro-benchmark.
//!
//! "Wall time for various libraries executing the Brownian Dynamics
//! benchmark on different GPUs, using the Philox generator in each
//! library." Paper result: OpenRAND ≈ Random123, both ~1.8x faster than
//! cuRAND, plus ~64 MB/Mparticle memory saved.
//!
//! Here "different GPUs" becomes two backends (DESIGN.md substitutions):
//! the multithreaded host path and the PJRT device path. The three
//! "libraries" are the three API styles with the identical Philox core.
//!
//! ```bash
//! cargo bench --bench fig4b_brownian                    # default scale
//! N=1048576 STEPS=10000 cargo bench --bench fig4b_brownian  # paper scale
//! ```

use openrand::coordinator::{Backend, SimDriver};
use openrand::sim::brownian::{BrownianParams, RngStyle};
use openrand::util::format;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let quick = std::env::var("OPENRAND_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let n = env_usize("N", if quick { 16_384 } else { 262_144 });
    let steps = env_usize("STEPS", if quick { 50 } else { 400 }) as u32;
    let threads = env_usize("THREADS", std::thread::available_parallelism().map(|x| x.get()).unwrap_or(4));
    println!("fig4b macro-benchmark: brownian dynamics, n={n}, steps={steps}");
    println!("(paper scale: N=1048576 STEPS=10000 — pass via env)\n");

    println!(
        "{:<26} {:>12} {:>14} {:>12} {:>12}",
        "backend/style", "wall (s)", "Mpstep/s", "vs openrand", "rng state"
    );
    println!("{}", "-".repeat(80));

    let mut openrand_wall = f64::NAN;
    // Host backend: all three styles.
    for style in RngStyle::ALL {
        let params = BrownianParams { n_particles: n, steps, global_seed: 1, style };
        let (_, m) = SimDriver::new(Backend::Host { threads }).run(params).unwrap();
        let wall = m.wall.as_secs_f64();
        if style == RngStyle::OpenRand {
            openrand_wall = wall;
        }
        println!(
            "{:<26} {:>12.3} {:>14.2} {:>11.2}x {:>12}",
            format!("host[{}t]/{}", threads, style.name()),
            wall,
            m.throughput() / 1e6,
            wall / openrand_wall,
            format::bytes(m.rng_state_bytes)
        );
    }

    // Campaign runner (rust/src/campaign): the tiled epoch-addressed
    // large-N path at the same n/steps — this is the row to read at
    // paper scale (N=1048576), where the per-tile fills amortize and
    // checkpointability costs nothing per step. Zero persistent engine
    // state: every word is re-derived from (key, epoch, tile).
    {
        use openrand::campaign::{Campaign, CampaignParams, Model};
        use openrand::stream::StreamKey;
        let mut p = CampaignParams::new(Model::Brownian, n, StreamKey::root(1));
        p.threads = threads;
        let mut c = Campaign::new(p).unwrap();
        let t0 = std::time::Instant::now();
        c.run_to(steps).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{:<26} {:>12.3} {:>14.2} {:>11.2}x {:>12}",
            format!("campaign[{}t]", threads),
            wall,
            n as f64 * steps as f64 / wall / 1e6,
            wall / openrand_wall,
            format::bytes(0)
        );
    }

    // Device backend: openrand + curand_style (raw123 is stream-identical
    // to openrand on device — the API difference is host-side only).
    let mut dev_openrand_wall = f64::NAN;
    // Device artifacts exist for n in {16384, 1048576}.
    let dev_n = if n > 65_536 { 1_048_576 } else { 16_384 };
    let dev_steps = if dev_n == n { steps } else { steps.min(100) };
    for style in [RngStyle::OpenRand, RngStyle::CurandStyle] {
        let params = BrownianParams { n_particles: dev_n, steps: dev_steps, global_seed: 1, style };
        match SimDriver::new(Backend::Device).run(params) {
            Ok((_, m)) => {
                let wall = m.wall.as_secs_f64();
                if style == RngStyle::OpenRand {
                    dev_openrand_wall = wall;
                }
                println!(
                    "{:<26} {:>12.3} {:>14.2} {:>11.2}x {:>12}",
                    format!("device[n={dev_n}]/{}", style.name()),
                    wall,
                    m.throughput() / 1e6,
                    wall / dev_openrand_wall,
                    format::bytes(m.rng_state_bytes)
                );
            }
            Err(e) => {
                println!("device/{}: unavailable ({e}) — run `make artifacts`", style.name());
            }
        }
    }

    println!(
        "\npaper shape: openrand ~ random123, curand-style slower (paper: 1.8x on V100/A100)\n\
         and curand-style pays {} of RNG state per million particles (paper: ~64 MB).",
        format::bytes(64 * 1_000_000)
    );
}
