//! A1 — ablation: round count vs speed vs statistical quality.
//!
//! The paper fixes Philox at 10 rounds and Threefry at 20 (Random123's
//! "safe" defaults; Salmon et al. showed 7/13 pass BigCrush with less
//! margin). This ablation regenerates that design-choice evidence on our
//! battery: reduced-round variants get faster roughly linearly, and the
//! battery starts flagging Philox below ~6 rounds.

use openrand::bench::harness::black_box;
use openrand::bench::Bencher;
use openrand::core::philox::philox4x32_r;
use openrand::core::threefry::threefry4x32_r;
use openrand::core::Rng;
use openrand::stats::run_battery;

/// Wrap a reduced-round philox as a counter-mode Rng for the battery.
struct PhiloxR {
    rounds: u32,
    key: [u32; 2],
    blk: u32,
    buf: [u32; 4],
    pos: u8,
}

impl PhiloxR {
    fn new(rounds: u32, seed: u64) -> PhiloxR {
        PhiloxR { rounds, key: [seed as u32, (seed >> 32) as u32], blk: 0, buf: [0; 4], pos: 4 }
    }
}

impl Rng for PhiloxR {
    fn next_u32(&mut self) -> u32 {
        if self.pos >= 4 {
            self.buf = philox4x32_r([self.blk, 0, 0, 0], self.key, self.rounds);
            self.blk = self.blk.wrapping_add(1);
            self.pos = 0;
        }
        let w = self.buf[self.pos as usize];
        self.pos += 1;
        w
    }
}

fn main() {
    let quick = std::env::var("OPENRAND_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let words = if quick { 1 << 17 } else { 1 << 21 };
    let b = Bencher::from_env();
    println!("ablation A1: rounds vs speed vs battery quality ({words} words/test)\n");
    println!(
        "{:<16} {:>12} {:>12} {:>10} {:>11}",
        "variant", "ns/block", "words/s", "failures", "suspicious"
    );
    println!("{}", "-".repeat(66));

    for rounds in [4u32, 6, 7, 8, 10, 12] {
        let mut ctr = [0u32; 4];
        let r = b.run(&format!("philox4x32-{rounds}"), 4, || {
            ctr[0] = ctr[0].wrapping_add(1);
            black_box(philox4x32_r(black_box(ctr), [1, 2], rounds));
        });
        let report = run_battery(
            &format!("philox-{rounds}"),
            words,
            |i| Box::new(PhiloxR::new(rounds, 0xAB0000 + i as u64)),
        );
        println!(
            "{:<16} {:>12.2} {:>12} {:>10} {:>11}",
            format!("philox4x32-{rounds}"),
            r.median_ns,
            openrand::util::format::si(4.0 / (r.median_ns * 1e-9)),
            report.failures(),
            report.suspicious()
        );
    }
    println!();
    for rounds in [8u32, 12, 16, 20, 24] {
        let mut ctr = [0u32; 4];
        let r = b.run(&format!("threefry4x32-{rounds}"), 4, || {
            ctr[0] = ctr[0].wrapping_add(1);
            black_box(threefry4x32_r(black_box(ctr), [1, 2, 3, 4], rounds));
        });
        println!(
            "{:<16} {:>12.2} {:>12} {:>10} {:>11}",
            format!("threefry4x32-{rounds}"),
            r.median_ns,
            openrand::util::format::si(4.0 / (r.median_ns * 1e-9)),
            "-",
            "-"
        );
    }
    println!("\npaper context: Random123 showed Philox-7/Threefry-13 pass BigCrush;\nOpenRAND ships 10/20 for margin. The quality column above shows where\nthe margin actually is on this battery.");
}
