//! fig_serve — serve-daemon throughput vs concurrent-client count.
//!
//! Spins up the real TCP daemon on an ephemeral port and hammers it
//! with C concurrent protocol clients, each fetching keyed u32 spans;
//! plots requests/sec and words/sec as C grows. Like the other figure
//! benches, a repro gate runs first (one fetched span byte-compared
//! against the local fill contract) so the bench can never publish
//! throughput for wrong bytes. The closing STATS line shows how much
//! of the load the LRU cache and request coalescing absorbed.
//!
//! ```bash
//! cargo bench --bench fig_serve
//! OPENRAND_BENCH_QUICK=1 cargo bench --bench fig_serve   # CI smoke
//! ```

use std::thread;
use std::time::Instant;

use openrand::core::fill::fill_u32_gen;
use openrand::core::Generator;
use openrand::serve::{Client, FillRequest, PayloadKind, ServeConfig, Server};

/// Elements per request (one cache block's worth of u32 words).
const REQ_ELEMS: u32 = 4096;

fn request(client_id: u64, i: u32) -> FillRequest {
    // Mixed workload: half the requests land on a hot shared span
    // (cache/coalescing territory), half walk per-client cold offsets.
    let (path, offset) = if i % 2 == 0 {
        ("c3".to_string(), (i % 8) as u64 * REQ_ELEMS as u64)
    } else {
        (format!("c{client_id}/e{i}"), 0)
    };
    FillRequest {
        tenant: 7,
        path,
        gen: Generator::Philox,
        kind: PayloadKind::U32,
        offset,
        len: REQ_ELEMS,
    }
}

fn main() {
    let quick = std::env::var("OPENRAND_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let clients: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4, 8, 16] };
    let per_client: u32 = if quick { 40 } else { 400 };

    let mut server = Server::start(ServeConfig {
        addr: "127.0.0.1:0".into(),
        workers: thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8),
        queue: 256,
        cache_blocks: 1024,
        fill_threads: 1,
        metrics_interval: None,
    })
    .expect("server starts");
    let addr = server.local_addr();

    // Repro gate: one fetched span must be byte-identical to the local
    // fill contract for the same key before any timing happens.
    {
        let req = request(0, 2); // hot-path request, offset 4096 elems
        let key = openrand::serve::resolve_key(req.tenant, &req.path).unwrap();
        let mut want = vec![0u32; (req.offset as usize + REQ_ELEMS as usize).max(1)];
        fill_u32_gen(req.gen, key.seed(), key.ctr(), &mut want);
        let want_bytes: Vec<u8> = want[req.offset as usize..]
            .iter()
            .flat_map(|w| w.to_le_bytes())
            .collect();
        let got = Client::connect(addr).unwrap().fill(&req).unwrap();
        assert_eq!(got, want_bytes, "serve bytes diverge from the fill contract — refusing to bench");
        eprintln!("repro gate: fetched span byte-identical to local fill ... ok");
    }

    eprintln!(
        "fig_serve: {} u32 elems/request, {} requests/client, daemon on {addr}\n",
        REQ_ELEMS, per_client
    );
    println!("{:<10} {:>12} {:>14} {:>12}", "clients", "req/s", "words/s", "ms/req");
    println!("{}", "-".repeat(52));

    for &c in clients {
        let t = Instant::now();
        let handles: Vec<_> = (0..c as u64)
            .map(|id| {
                thread::spawn(move || {
                    let mut client = Client::connect(addr).expect("connect");
                    for i in 0..per_client {
                        let req = request(id, i);
                        let bytes = client.fill(&req).expect("fill");
                        assert_eq!(bytes.len(), REQ_ELEMS as usize * 4);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("client thread");
        }
        let secs = t.elapsed().as_secs_f64();
        let total = c as f64 * per_client as f64;
        println!(
            "{:<10} {:>12.0} {:>14.3e} {:>12.3}",
            c,
            total / secs,
            total * REQ_ELEMS as f64 / secs,
            secs * 1e3 / total,
        );
    }

    let stats = Client::connect(addr).unwrap().stats().expect("stats");
    println!("\nfinal server counters:");
    for line in stats.lines() {
        println!("  {line}");
    }
    Client::connect(addr).unwrap().shutdown().expect("shutdown");
    server.join();
    println!(
        "\nreading: past one client, throughput is bounded by worker count and\n\
         cache reuse — the hot spans ride the LRU/coalescing path (cache_hits,\n\
         coalesced above), the cold spans pay one backend fill each."
    );
}
