//! fig_backend — fill-backend throughput sweep and host/device
//! crossover calibration.
//!
//! Sweeps buffer size across the backend arms (serial host, sharded
//! parallel host, device when available) to plot where device dispatch
//! amortizes — the number the `Auto` arm's [`CrossoverTable`] encodes.
//! Every run also byte-checks the arms against the serial reference
//! (a repro gate, like fig_fill's), so the bench can never publish
//! throughput for wrong bytes.
//!
//! ```bash
//! cargo bench --bench fig_backend
//! OPENRAND_BENCH_QUICK=1 cargo bench --bench fig_backend   # CI smoke
//! OPENRAND_PERSIST_CROSSOVER=1 cargo bench --bench fig_backend
//! # ^ writes <artifacts>/backend_crossover.txt for the Auto arm and
//! #   <artifacts>/backend_cost_model.txt (rates) for the Sched arm
//! ```

use openrand::backend::{auto, Auto, CostModel, CrossoverTable, DeviceFill, HostSerial};
use openrand::coordinator::repro;
use openrand::core::Generator;
use openrand::stream::{self, StreamKey};

const SIZES: [usize; 4] = [1 << 12, 1 << 16, 1 << 18, 1 << 20];

fn main() {
    let quick = std::env::var("OPENRAND_BENCH_QUICK").is_ok();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8);
    let sizes: &[usize] = if quick { &SIZES[..2] } else { &SIZES };
    let reps = if quick { 3 } else { 15 };

    // Repro gates first: all arms byte-identical, and the StreamKey
    // facade byte-identical to the legacy spelling, before any timing.
    let gate = repro::verify_backend_invariance(Generator::Philox, 65_536, 0xF16, 1, threads);
    eprint!("{}", gate.render());
    assert!(gate.consistent, "backend arms disagree — refusing to bench wrong bytes");
    let key_gate = repro::verify_key_equivalence(0xF16, 1, 8_192);
    eprint!("{}", key_gate.render());
    assert!(key_gate.consistent, "StreamKey drifted from CounterRng::new — refusing to bench");

    let device_note = match DeviceFill::try_new() {
        Ok(_) => "device arm available".to_string(),
        Err(e) => format!("device arm unavailable ({e:#}); host rows only"),
    };
    eprintln!("fig_backend: philox u32 fill, {threads} host threads; {device_note}\n");

    println!(
        "{:<12} {:>14} {:>14} {:>14} {:>10}",
        "n (u32)", "host ns/w", "par ns/w", "device ns/w", "auto arm"
    );
    println!("{}", "-".repeat(68));

    // Serial host baseline, measured the same way the calibration
    // measures par/device (median of reps) so columns are comparable.
    // Addressing goes through the key facade (epoch per rep) — the
    // same bytes as the raw spelling, by the key_gate above.
    let serial_ns: Vec<f64> = sizes
        .iter()
        .map(|&n| {
            let mut buf = vec![0u32; n];
            let mut ns: Vec<f64> = (0..reps)
                .map(|rep| {
                    let key = StreamKey::root(1).epoch(rep as u32);
                    let t = std::time::Instant::now();
                    stream::fill_u32_key(Some(&mut HostSerial), Generator::Philox, key, &mut buf)
                        .unwrap();
                    t.elapsed().as_nanos() as f64
                })
                .collect();
            ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
            ns[ns.len() / 2]
        })
        .collect();

    let samples = auto::measure_crossover(threads, sizes, reps).expect("host measurement");
    let preview = Auto::new(threads);
    for (i, s) in samples.iter().enumerate() {
        let per = |ns: f64| ns / s.words as f64;
        println!(
            "{:<12} {:>14.3} {:>14.3} {:>14} {:>10}",
            s.words,
            per(serial_ns[i]),
            per(s.host_ns),
            s.device_ns.map(|d| format!("{:.3}", per(d))).unwrap_or_else(|| "-".into()),
            preview.selection(Generator::Philox, s.words).name(),
        );
    }

    match auto::recommend(&samples) {
        Some(table) => {
            println!("\nmeasured crossover: device from {} words", table.device_min_words);
            if std::env::var("OPENRAND_PERSIST_CROSSOVER").as_deref() == Ok("1") {
                let path = CrossoverTable::default_path();
                table.persist(&path).expect("persist crossover table");
                println!("persisted to {path:?} (Auto arms on this machine now use it)");
            }
        }
        None => println!(
            "\nno device win in this sweep (unavailable or host-dominant); \
             Auto keeps its current table (default: {} words)",
            CrossoverTable::DEFAULT_DEVICE_MIN_WORDS
        ),
    }
    // The generalized calibration: crossover + per-arm sustained rates,
    // which the shard scheduler uses to size device vs host shards.
    let model = auto::cost_model(&samples, CostModel::load().crossover);
    println!(
        "cost model: host {} words/s, device {}, device_fraction {:.2}",
        model.host_words_per_sec.map(|v| format!("{v:.3e}")).unwrap_or_else(|| "-".into()),
        model.device_words_per_sec.map(|v| format!("{v:.3e}")).unwrap_or_else(|| "-".into()),
        model.device_fraction(),
    );
    if std::env::var("OPENRAND_PERSIST_CROSSOVER").as_deref() == Ok("1") {
        let path = CostModel::default_path();
        model.persist(&path).expect("persist cost model");
        println!("persisted to {path:?} (sched arms on this machine now use it)");
    }
    println!(
        "\nreading: the device column only beats the host past the dispatch-\n\
         amortization point (ablation A3); the Auto arm flips exactly there."
    );
}
