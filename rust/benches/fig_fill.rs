//! E8 / fig_fill — buffer-fill throughput: word-at-a-time draws vs the
//! block-fill engine vs parallel block-fill.
//!
//! The claim under test (paper §4: counter blocks carry 4-words-per-call
//! parallelism that word-granular APIs throw away): generating a large
//! u32 buffer through `core::fill` must beat a `next_u32` loop by ≥ 1.5×
//! on Philox, and `par_fill_*` must scale further while staying bitwise
//! identical for every thread count (the repro ladder at the end proves
//! the latter on every run of this bench).
//!
//! ```bash
//! cargo bench --bench fig_fill          # full
//! OPENRAND_BENCH_QUICK=1 cargo bench --bench fig_fill
//! ```

use openrand::bench::harness::black_box;
use openrand::bench::{Bencher, Series};
use openrand::coordinator::repro;
use openrand::core::{fill, BlockRng, Philox, Squares, Threefry, Tyche};

/// Buffer size: large enough to amortize thread spawn in the parallel
/// rows (1 Mword = 4 MB).
const N: usize = 1 << 20;

/// ns per word for one u32-fill strategy.
fn bench_fill(b: &Bencher, name: &str, mut f: impl FnMut(u32, &mut [u32])) -> f64 {
    let mut buf = vec![0u32; N];
    let mut ctr = 0u32;
    let r = b.run(name, N as u64, || {
        ctr = ctr.wrapping_add(1);
        f(ctr, &mut buf);
        black_box(buf[N - 1]);
    });
    eprintln!("  {}", r.summary());
    r.median_ns / N as f64
}

/// The three strategies for one engine: word-at-a-time, serial block
/// fill, parallel block fill.
fn engine_rows<G: BlockRng>(b: &Bencher, engine: &str, threads: usize) -> Vec<f64> {
    vec![
        bench_fill(b, &format!("{engine}/word_at_a_time"), |ctr, out| {
            let mut g = G::new(1, ctr);
            for w in out.iter_mut() {
                *w = g.next_u32();
            }
        }),
        bench_fill(b, &format!("{engine}/block_fill"), |ctr, out| {
            fill::fill_u32::<G>(1, ctr, out);
        }),
        bench_fill(b, &format!("{engine}/par_fill_t{threads}"), |ctr, out| {
            fill::par_fill_u32::<G>(1, ctr, out, threads);
        }),
    ]
}

fn main() {
    let b = Bencher::from_env();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8);
    eprintln!("fig_fill: ns/word for {N}-word u32 fills (parallel rows use {threads} threads)");

    let mut fig = Series::new(
        "Fig F — block-fill engine",
        "strategy",
        "ns_per_word",
        (0..3).map(|i| i as f64).collect(),
    );
    for (i, name) in ["word_at_a_time", "block_fill", "par_fill"].iter().enumerate() {
        eprintln!("  row {i} = {name}");
    }

    let philox = engine_rows::<Philox>(&b, "philox", threads);
    let threefry = engine_rows::<Threefry>(&b, "threefry", threads);
    let squares = engine_rows::<Squares>(&b, "squares", threads);
    let tyche = engine_rows::<Tyche>(&b, "tyche", threads);
    fig.push("philox", philox.clone());
    fig.push("threefry", threefry);
    fig.push("squares", squares);
    fig.push("tyche", tyche);
    println!("{}", fig.render(|y| format!("{y:.3}")));

    // f64 fill for the macro-consumer shape (brownian/pi draw doubles).
    let mut dbuf = vec![0.0f64; N / 2];
    let mut ctr = 0u32;
    let r = b.run("philox/fill_f64", (N / 2) as u64, || {
        ctr = ctr.wrapping_add(1);
        fill::fill_f64::<Philox>(1, ctr, &mut dbuf);
        black_box(dbuf[N / 2 - 1]);
    });
    eprintln!("  {}", r.summary());

    // Determinism: the repro ladder must hold on the machine that just
    // ran the perf rows (acceptance gate for the parallel path).
    let rep = repro::verify_fill_invariance::<Philox>(1 << 18, 8, 0xF117);
    println!("{}", rep.render());
    assert!(rep.consistent, "parallel fill output varied with thread count");

    // The headline shape, asserted like fig4a/fig_dist do. The full
    // profile enforces the acceptance bar (block-fill >= 1.5x on Philox
    // u32); the quick profile (CI smoke on noisy shared runners) only
    // checks the direction, with a noise margin so a scheduling blip
    // cannot redden CI without a real regression.
    let quick = std::env::var("OPENRAND_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let target = if quick { 0.8 } else { 1.5 };
    let (word_ns, block_ns, par_ns) = (philox[0], philox[1], philox[2]);
    let speedup = word_ns / block_ns;
    let par_speedup = word_ns / par_ns;
    println!(
        "shape check: block-fill {speedup:.2}x word-at-a-time on philox u32 {}",
        if speedup >= 1.5 {
            "(>= 1.5x target — OK)"
        } else if speedup > 1.0 {
            "(positive, below the 1.5x target)"
        } else {
            "(UNEXPECTED)"
        }
    );
    println!("shape check: parallel block-fill {par_speedup:.2}x word-at-a-time ({threads} threads)");
    assert!(
        speedup >= target,
        "block fill ({block_ns:.2} ns/word) must beat word-at-a-time ({word_ns:.2} ns/word) by >= {target}x"
    );
}
