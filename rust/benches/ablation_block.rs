//! A3 — ablation: device dispatch granularity.
//!
//! How large must a device block-generation call be before PJRT dispatch
//! overhead is amortized? Sweeps the generators' block artifacts and
//! compares against the host fill path — this sets the crossover point a
//! user should know when choosing host vs device generation.

use openrand::bench::harness::black_box;
use openrand::bench::Bencher;
use openrand::core::{CounterRng, Philox, Rng};
use openrand::runtime::exec::{Arg, DeviceGraph};
use openrand::runtime::ArtifactStore;

fn main() {
    let b = Bencher::from_env();
    println!("ablation A3: device block-generation throughput by size\n");
    let store = match ArtifactStore::open_default() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("artifacts unavailable ({e}); run `make artifacts`");
            std::process::exit(1);
        }
    };

    println!(
        "{:<26} {:>12} {:>14} {:>12}",
        "path", "n (u32)", "time/call", "words/s"
    );
    println!("{}", "-".repeat(68));

    for gen in ["philox", "threefry", "squares", "tyche"] {
        for n in [65_536usize, 1_048_576] {
            let name = format!("{gen}_u32_{n}");
            if store.manifest.get(&name).is_none() {
                continue;
            }
            let graph = DeviceGraph::load(&store, &name).unwrap();
            let mut ctr = 0u32;
            let r = b.run(&name, n as u64, || {
                ctr = ctr.wrapping_add(1);
                let out = graph.call_u32(&[Arg::U32(&[1, 0, ctr, 0])]).unwrap();
                black_box(out[0]);
            });
            println!(
                "{:<26} {:>12} {:>14} {:>12}",
                format!("device/{gen}"),
                n,
                openrand::util::format::ns(r.median_ns),
                openrand::util::format::si(r.throughput())
            );
        }
    }

    // Host fill for comparison.
    for n in [65_536usize, 1_048_576] {
        let mut buf = vec![0u32; n];
        let mut ctr = 0u32;
        let r = b.run(&format!("host_fill_{n}"), n as u64, || {
            ctr = ctr.wrapping_add(1);
            let mut rng = Philox::new(1, ctr);
            rng.fill_u32(&mut buf);
            black_box(buf[0]);
        });
        println!(
            "{:<26} {:>12} {:>14} {:>12}",
            "host/philox fill",
            n,
            openrand::util::format::ns(r.median_ns),
            openrand::util::format::si(r.throughput())
        );
    }
    println!("\nreading: device wins only past the dispatch-amortization point;\nfor small blocks the host path dominates — the coordinator's step\ngranularity (whole simulation step per call) sits on the right side.");
}
