//! E7 — distribution-sampling throughput: Box–Muller (normative,
//! device-aligned) vs the ziggurat fast path vs the raw-uniform
//! baseline, across engines.
//!
//! The claim under test: the ziggurat's ~1-word fast path beats
//! Box–Muller's 4 words + `ln`/`sqrt`/`cos`/`sin` per sample, while the
//! distribution layer as a whole stays within a small factor of raw
//! `draw_double` throughput.
//!
//! ```bash
//! cargo bench --bench fig_dist          # full
//! OPENRAND_BENCH_QUICK=1 cargo bench --bench fig_dist
//! ```

use openrand::bench::harness::black_box;
use openrand::bench::{Bencher, Series};
use openrand::core::{CounterRng, Philox, Rng, Squares, Tyche};
use openrand::dist::{
    BoxMuller, DiscreteAlias, Distribution, Exponential, Poisson, ZigguratNormal,
};

const SAMPLES_PER_ITER: usize = 4096;

/// ns per sample for `f` run over a fresh stream each iteration.
/// `samples_per_call` is how many samples one `f` call yields (2 for
/// the pair-amortized Box–Muller row).
fn bench_sampler<R: Rng>(
    b: &Bencher,
    name: &str,
    samples_per_call: u64,
    mut make: impl FnMut(u64) -> R,
    mut f: impl FnMut(&mut R) -> f64,
) -> f64 {
    let mut seed = 1u64;
    let r = b.run(name, SAMPLES_PER_ITER as u64 * samples_per_call, || {
        seed = seed.wrapping_add(1);
        let mut rng = make(seed);
        let mut acc = 0.0f64;
        for _ in 0..SAMPLES_PER_ITER {
            acc += f(&mut rng);
        }
        black_box(acc);
    });
    eprintln!("  {}", r.summary());
    r.median_ns / (SAMPLES_PER_ITER as u64 * samples_per_call) as f64
}

fn engine_column<R: CounterRng>(b: &Bencher, engine: &str) -> Vec<f64> {
    let bm = BoxMuller::standard();
    let zig = ZigguratNormal::standard();
    let expo = Exponential::new(1.7);
    let pois_small = Poisson::new(4.5);
    let pois_large = Poisson::new(40.0);
    let alias = DiscreteAlias::new(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
    vec![
        bench_sampler(b, &format!("{engine}/draw_double"), 1, |s| R::new(s, 0), |r| {
            r.draw_double()
        }),
        bench_sampler(b, &format!("{engine}/box_muller"), 1, |s| R::new(s, 0), |r| bm.sample(r)),
        bench_sampler(
            b,
            &format!("{engine}/box_muller_pair"),
            2, // each call yields both branches of the pair
            |s| R::new(s, 0),
            |r| {
                let (a, z) = bm.sample_pair(r);
                (a + z) * 0.5
            },
        ),
        bench_sampler(b, &format!("{engine}/ziggurat"), 1, |s| R::new(s, 0), |r| zig.sample(r)),
        bench_sampler(b, &format!("{engine}/exponential"), 1, |s| R::new(s, 0), |r| {
            expo.sample(r)
        }),
        bench_sampler(
            b,
            &format!("{engine}/poisson_knuth"),
            1,
            |s| R::new(s, 0),
            |r| pois_small.sample(r) as f64,
        ),
        bench_sampler(
            b,
            &format!("{engine}/poisson_ptrs"),
            1,
            |s| R::new(s, 0),
            |r| pois_large.sample(r) as f64,
        ),
        bench_sampler(
            b,
            &format!("{engine}/alias8"),
            1,
            |s| R::new(s, 0),
            |r| alias.sample(r) as f64,
        ),
    ]
}

const ROWS: [&str; 8] = [
    "draw_double",
    "box_muller",
    "box_muller_pair",
    "ziggurat",
    "exponential",
    "poisson_knuth",
    "poisson_ptrs",
    "alias8",
];

fn main() {
    let b = Bencher::from_env();
    eprintln!("fig_dist: ns/sample for distribution draws (fresh stream per iteration)");

    let mut fig = Series::new(
        "Fig D — distribution sampling",
        "sampler",
        "ns_per_sample",
        (0..ROWS.len()).map(|i| i as f64).collect(),
    );
    for (i, name) in ROWS.iter().enumerate() {
        eprintln!("  row {i} = {name}");
    }

    let philox = engine_column::<Philox>(&b, "philox");
    let squares = engine_column::<Squares>(&b, "squares");
    let tyche = engine_column::<Tyche>(&b, "tyche");
    fig.push("philox", philox.clone());
    fig.push("squares", squares);
    fig.push("tyche", tyche);
    println!("{}", fig.render(|y| format!("{y:.2}")));

    // The headline shape, asserted like fig4a does: the ziggurat must
    // beat the normative Box–Muller per standard-normal sample.
    let bm_ns = philox[1];
    let zig_ns = philox[3];
    let speedup = bm_ns / zig_ns;
    println!(
        "shape check: ziggurat vs box_muller on philox: {speedup:.2}x {}",
        if speedup > 1.0 { "(fast path wins — OK)" } else { "(UNEXPECTED)" }
    );
    // And the pair-amortized Box–Muller must beat the single-branch
    // form per sample (same work per call, two samples kept instead of
    // one — expect ~2x).
    let pair_ns = philox[2];
    println!(
        "shape check: box_muller pair-amortized {:.2}x single {}",
        bm_ns / pair_ns,
        if bm_ns / pair_ns > 1.5 { "(both branches kept — OK)" } else { "(UNEXPECTED)" }
    );
    assert!(
        speedup > 1.0,
        "ziggurat ({zig_ns:.1} ns) must outperform Box–Muller ({bm_ns:.1} ns)"
    );
}
