//! fig_sched — heterogeneous shard-scheduler throughput.
//!
//! Times one keyed fill through the serial host arm, the sharded
//! parallel host arm, and the shard scheduler (`Sched`: host threads
//! and the device filling disjoint contiguous shards of the same
//! stream concurrently), and reports the per-plan split the cost model
//! chose. Every run byte-checks the scheduler over random mixed-arm
//! plans first (the `repro` r7 rung), so the bench can never publish
//! throughput for wrong bytes.
//!
//! On stub builds the scheduler plans host-only and should track the
//! parallel arm; with a real device + `_at` artifacts the device tail
//! overlaps the host prefix and sched should meet or beat the best
//! single host arm on large (>= 64M-word) fills.
//!
//! ```bash
//! cargo bench --bench fig_sched
//! OPENRAND_BENCH_QUICK=1 cargo bench --bench fig_sched   # CI smoke
//! ```

use openrand::backend::{CostModel, FillBackend, HostParallel, HostSerial, Sched};
use openrand::coordinator::repro;
use openrand::core::Generator;

const SIZES: [usize; 3] = [1 << 20, 1 << 23, 1 << 26];

fn median(mut v: Vec<f64>) -> f64 {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[v.len() / 2]
}

/// Median fill latency (ns) of `b` on an `n`-word Philox fill, ctr
/// bumped per rep so pooled device state is honestly exercised.
fn time_arm(b: &mut dyn FillBackend, n: usize, reps: usize, ctr: &mut u32) -> f64 {
    let mut buf = vec![0u32; n];
    median(
        (0..reps.max(1))
            .map(|_| {
                *ctr = ctr.wrapping_add(1);
                let t = std::time::Instant::now();
                b.fill_u32(Generator::Philox, 1, *ctr, &mut buf).expect("bench fill");
                t.elapsed().as_nanos() as f64
            })
            .collect(),
    )
}

fn main() {
    let quick = std::env::var("OPENRAND_BENCH_QUICK").is_ok();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8);
    let quick_sizes = [1 << 16, 1 << 18];
    let sizes: &[usize] = if quick { &quick_sizes } else { &SIZES };
    let reps = if quick { 3 } else { 7 };

    // Repro gate: the stitch guarantee over random mixed-arm plans,
    // before any timing.
    let gate = repro::verify_sched_invariance(Generator::Philox, 1 << 18, 0x5C_4ED, 1, 4, threads);
    eprint!("{}", gate.render());
    assert!(gate.consistent, "sched plans disagree with serial — refusing to bench wrong bytes");

    let model = CostModel::load();
    let mut sched = Sched::with_model(threads, model);
    eprintln!(
        "fig_sched: philox u32 fill, {threads} host threads; device arm {}; \
         cost model: crossover={}w, device_fraction={:.2}\n",
        if sched.device_available() { "available" } else { "unavailable (host-only plans)" },
        model.crossover.device_min_words,
        model.device_fraction(),
    );

    println!(
        "{:<12} {:>12} {:>12} {:>12}  {:<18}",
        "n (u32)", "host ns/w", "par ns/w", "sched ns/w", "plan (shards/dev words)"
    );
    println!("{}", "-".repeat(72));

    let mut ctr = 0u32;
    let mut last = None;
    for &n in sizes {
        let host_ns = time_arm(&mut HostSerial, n, reps, &mut ctr);
        let par_ns = time_arm(&mut HostParallel::new(threads), n, reps, &mut ctr);
        let plan = sched.plan_for(Generator::Philox, n);
        let sched_ns = time_arm(&mut sched, n, reps, &mut ctr);
        let per = |ns: f64| ns / n as f64;
        println!(
            "{:<12} {:>12.3} {:>12.3} {:>12.3}  {:<18}",
            n,
            per(host_ns),
            per(par_ns),
            per(sched_ns),
            format!("{}sh / {}w dev", plan.shards().len(), plan.device_words()),
        );
        last = Some((n, host_ns.min(par_ns), sched_ns));
    }

    if let Some((n, best_host_ns, sched_ns)) = last {
        let ratio = best_host_ns / sched_ns;
        println!(
            "\nlargest fill ({n} words): sched is {ratio:.2}x the best single host arm \
             ({})",
            if ratio >= 0.95 {
                "on par or better — shards overlap as intended"
            } else {
                "slower — expected only on stub builds at small sizes, where \
                 scheduling adds overhead with no device to overlap"
            }
        );
    }
    println!(
        "reading: the scheduler only wins when the device tail genuinely overlaps\n\
         the host prefix; the plan column shows the split the cost model chose."
    );
}
