//! A2 — ablation: state size / construction cost across the family.
//!
//! The register-pressure argument of the paper's background section,
//! measured: bytes of state, construction (seeding) cost, and steady-
//! state draw cost for every engine plus mt19937. The interesting
//! contrast is construction: CBRNGs construct in ~ns (a few dozen integer
//! ops) while mt19937 pays its 624-word init — this is the whole Fig.-4a
//! short-stream story in one table.

use openrand::baseline::Mt19937;
use openrand::bench::harness::black_box;
use openrand::bench::Bencher;
use openrand::core::{
    CounterRng, Generator, Philox, Philox2x32, Rng, Squares, Threefry, Threefry2x32, Tyche,
    TycheI,
};

fn bench_engine<R: Rng>(
    b: &Bencher,
    name: &str,
    state_bytes: usize,
    mut construct: impl FnMut(u64) -> R,
) {
    let mut seed = 0u64;
    // Construction + first draw (what a GPU thread pays per kernel).
    let ctor = b.run(&format!("{name}/construct+1"), 1, || {
        seed = seed.wrapping_add(1);
        let mut r = construct(seed);
        black_box(r.next_u32());
    });
    // Steady-state draw.
    let mut rng = construct(42);
    let draw = b.run(&format!("{name}/draw"), 1, || {
        black_box(rng.next_u32());
    });
    println!(
        "{:<14} {:>10} {:>16.1} {:>14.2}",
        name,
        state_bytes,
        ctor.median_ns,
        draw.median_ns
    );
}

fn main() {
    let b = Bencher::from_env();
    println!("ablation A2: state footprint & construction cost\n");
    println!(
        "{:<14} {:>10} {:>16} {:>14}",
        "engine", "state B", "construct+1 ns", "draw ns"
    );
    println!("{}", "-".repeat(58));
    bench_engine(&b, "philox", Generator::Philox.state_bytes(), |s| Philox::new(s, 0));
    bench_engine(&b, "philox2x32", Generator::Philox2x32.state_bytes(), |s| Philox2x32::new(s, 0));
    bench_engine(&b, "threefry", Generator::Threefry.state_bytes(), |s| Threefry::new(s, 0));
    bench_engine(&b, "threefry2x32", Generator::Threefry2x32.state_bytes(), |s| {
        Threefry2x32::new(s, 0)
    });
    bench_engine(&b, "squares", Generator::Squares.state_bytes(), |s| Squares::new(s, 0));
    bench_engine(&b, "tyche", Generator::Tyche.state_bytes(), |s| Tyche::new(s, 0));
    bench_engine(&b, "tyche_i", Generator::TycheI.state_bytes(), |s| TycheI::new(s, 0));
    bench_engine(&b, "mt19937", std::mem::size_of::<Mt19937>(), |s| Mt19937::new(s as u32));
    println!(
        "\nGPU context (paper): CUDA allows at most 255 32-bit registers per\n\
         thread (~1 KiB); every OpenRAND engine fits with room to spare,\n\
         mt19937's 2.5 KiB does not — hence MTGP's shared-state redesign."
    );
}
