//! E3/E4 — §5.2: statistical evaluation, as a bench target so
//! `cargo bench` regenerates the paper's quality table.
//!
//! Runs the Crush-lite battery on every OpenRAND generator (plus the
//! known-good and known-bad controls) and the HOOMD parallel-stream
//! suite. Word budget via WORDS env (default 4M per test; the paper used
//! ~1 TB of PractRand — see DESIGN.md substitutions).

use openrand::baseline::{Lcg64, Mt19937, Pcg32, WeakCounter, Xoshiro256pp};
use openrand::core::{Generator, Rng};
use openrand::stats::parallel;
use openrand::stats::suite::Verdict;
use openrand::stats::run_battery;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn boxed(gen: Generator, seed: u64) -> Box<dyn Rng> {
    use openrand::core::*;
    match gen {
        Generator::Philox => Box::new(Philox::new(seed, 0)),
        Generator::Philox2x32 => Box::new(Philox2x32::new(seed, 0)),
        Generator::Threefry => Box::new(Threefry::new(seed, 0)),
        Generator::Threefry2x32 => Box::new(Threefry2x32::new(seed, 0)),
        Generator::Squares => Box::new(Squares::new(seed, 0)),
        Generator::Tyche => Box::new(Tyche::new(seed, 0)),
        Generator::TycheI => Box::new(TycheI::new(seed, 0)),
    }
}

fn main() {
    let quick = std::env::var("OPENRAND_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let words = env_usize("WORDS", if quick { 1 << 18 } else { 4 << 20 });
    println!("statistical battery, {words} words/test (paper: TestU01 BigCrush + 1TB PractRand)\n");

    let mut all_pass = true;
    for g in Generator::ALL {
        let report = run_battery(g.name(), words, |i| boxed(g, 0x5EED_0000 + i as u64));
        println!(
            "{:<14} {:>2} tests  {:>2} failures  {:>2} suspicious",
            g.name(),
            report.results.len(),
            report.failures(),
            report.suspicious()
        );
        all_pass &= report.passed();
    }
    println!();

    // Known-good controls.
    for (name, mk) in [
        ("mt19937", Box::new(|i: usize| -> Box<dyn Rng> { Box::new(Mt19937::new(i as u32 + 1)) })
            as Box<dyn Fn(usize) -> Box<dyn Rng>>),
        ("pcg32", Box::new(|i| Box::new(Pcg32::new(i as u64, 54)))),
        ("xoshiro256pp", Box::new(|i| Box::new(Xoshiro256pp::new(i as u64 + 9)))),
    ] {
        let report = run_battery(name, words, |i| mk(i));
        println!(
            "{:<14} {:>2} tests  {:>2} failures  {:>2} suspicious  (known-good control)",
            name,
            report.results.len(),
            report.failures(),
            report.suspicious()
        );
    }

    // Known-bad controls: the battery MUST flag these.
    for (name, mk) in [
        ("weak_counter", Box::new(|_: usize| -> Box<dyn Rng> { Box::new(WeakCounter::new(0)) })
            as Box<dyn Fn(usize) -> Box<dyn Rng>>),
        ("lcg64_low", Box::new(|_| Box::new(Lcg64::new(123)))),
    ] {
        let report = run_battery(name, words, |i| mk(i));
        println!(
            "{:<14} {:>2} tests  {:>2} failures  {:>2} suspicious  (known-BAD control; failures expected)",
            name,
            report.results.len(),
            report.failures(),
            report.suspicious()
        );
        assert!(report.failures() > 0, "battery failed to flag {name}!");
    }
    println!();

    // E4: parallel-stream suite for the family (paper: first time for
    // Tyche and Squares).
    let pwords = words / 4;
    for g in Generator::ALL {
        let results = match g {
            Generator::Philox => parallel::run_parallel_suite::<openrand::core::Philox>(0, pwords),
            Generator::Philox2x32 => parallel::run_parallel_suite::<openrand::core::Philox2x32>(0, pwords),
            Generator::Threefry => parallel::run_parallel_suite::<openrand::core::Threefry>(0, pwords),
            Generator::Threefry2x32 => parallel::run_parallel_suite::<openrand::core::Threefry2x32>(0, pwords),
            Generator::Squares => parallel::run_parallel_suite::<openrand::core::Squares>(0, pwords),
            Generator::Tyche => parallel::run_parallel_suite::<openrand::core::Tyche>(0, pwords),
            Generator::TycheI => parallel::run_parallel_suite::<openrand::core::TycheI>(0, pwords),
        };
        let fails = results.iter().filter(|r| r.verdict() == Verdict::Fail).count();
        let susp = results.iter().filter(|r| r.verdict() == Verdict::Suspicious).count();
        println!(
            "parallel[{:<12}] {:>2} tests  {fails} failures  {susp} suspicious  (16000 particles x 3-word micro-streams)",
            g.name(),
            results.len()
        );
        all_pass &= fails == 0;
    }

    println!(
        "\n{}",
        if all_pass {
            "ALL OPENRAND GENERATORS PASS (single + parallel streams)"
        } else {
            "SOME GENERATOR FAILED — investigate above"
        }
    );
    assert!(all_pass);
}
