//! `fig_campaign` — campaign throughput: particles/sec vs N and
//! backend arm, plus the resume reproducibility gate.
//!
//! The campaign runner (`rust/src/campaign`) is the Tier-1 end-to-end
//! scenario: tiled epoch-addressed fills driving the Brownian
//! integrator at large N with bitwise checkpoint/resume. This bench
//! answers the two questions the docs make claims about:
//!
//! 1. **Scaling** — particle-steps/sec as N grows from cache-resident
//!    to memory-bound, per thread arm (serial vs all cores).
//! 2. **Resume is free and exact** — a mid-trajectory checkpoint +
//!    resume (at a different thread count) must reproduce the
//!    uninterrupted end state byte-for-byte; the gate asserts it.
//!
//! ```bash
//! cargo bench --bench fig_campaign                 # full sizes (incl. 1M)
//! OPENRAND_BENCH_QUICK=1 cargo bench --bench fig_campaign   # CI tier
//! N=4194304 STEPS=20 cargo bench --bench fig_campaign       # custom
//! ```

use openrand::campaign::{Campaign, CampaignParams, Model};
use openrand::stream::StreamKey;
use std::time::Instant;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn params(n: usize, threads: usize) -> CampaignParams {
    let mut p = CampaignParams::new(Model::Brownian, n, StreamKey::root(1));
    p.threads = threads;
    p
}

fn main() {
    let quick = std::env::var("OPENRAND_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let cores = std::thread::available_parallelism().map(|x| x.get()).unwrap_or(4);
    let sizes: Vec<usize> = match std::env::var("N").ok().and_then(|v| v.parse().ok()) {
        Some(n) => vec![n],
        None if quick => vec![16_384, 65_536],
        None => vec![65_536, 262_144, 1_048_576],
    };
    let steps = env_usize("STEPS", if quick { 10 } else { 25 }) as u32;

    println!("fig_campaign: brownian campaign throughput (steps={steps})");
    println!("(paper-scale claim: N >= 1M — the default full tier includes it)\n");
    println!(
        "{:<14} {:>10} {:>12} {:>16} {:>12}",
        "arm", "N", "wall (s)", "pstep/s", "vs serial"
    );
    println!("{}", "-".repeat(68));

    let arms: Vec<usize> = if cores > 1 { vec![1, cores] } else { vec![1] };
    for &n in &sizes {
        let mut serial_wall = f64::NAN;
        for &threads in &arms {
            let mut c = Campaign::new(params(n, threads)).unwrap();
            let t0 = Instant::now();
            c.run_to(steps).unwrap();
            let wall = t0.elapsed().as_secs_f64();
            if threads == 1 {
                serial_wall = wall;
            }
            let rate = n as f64 * steps as f64 / wall;
            println!(
                "{:<14} {:>10} {:>12.3} {:>16} {:>11.2}x",
                format!("host[{threads}t]"),
                n,
                wall,
                openrand::util::format::si(rate),
                serial_wall / wall
            );
        }
    }

    // Repro gate: checkpoint at a mid epoch, resume at a different
    // thread count, and require the byte-identical end checkpoint the
    // docs promise. A bench that silently stopped being reproducible
    // would be measuring the wrong thing.
    let (gate_n, gate_steps, split) = (2_048, 6u32, 3u32);
    let mut p = params(gate_n, 2);
    p.tile = 256;
    let mut full = Campaign::new(p).unwrap();
    full.run_to(gate_steps).unwrap();
    let mut head = Campaign::new(p).unwrap();
    head.run_to(split).unwrap();
    let mut tail = Campaign::resume(&head.checkpoint(), 4).unwrap();
    tail.run_to(gate_steps).unwrap();
    assert_eq!(
        full.checkpoint().encode(),
        tail.checkpoint().encode(),
        "campaign resume diverged from the uninterrupted run"
    );
    println!("\ncampaign repro gate: ok (resume == never-stopped, bitwise)");
}
