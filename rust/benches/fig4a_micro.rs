//! E1 — Fig. 4a: host micro-benchmark.
//!
//! "Time taken by OpenRAND generators versus baselines (std::mt19937 and
//! r123::philox) to produce specified stream lengths on the host."
//!
//! For each stream length the benchmark constructs a FRESH generator and
//! produces the stream — construction cost included, exactly as in the
//! paper (that is the effect being measured: mt19937's 624-word init
//! dominates short streams, the bread-and-butter case of parallel code).
//! Output: ns per 32-bit word, one series per generator.
//!
//! ```bash
//! cargo bench --bench fig4a_micro          # full
//! OPENRAND_BENCH_QUICK=1 cargo bench --bench fig4a_micro
//! ```

use openrand::baseline::{Mt19937, Pcg32, Xoshiro256pp};
use openrand::bench::harness::black_box;
use openrand::bench::{Bencher, Series};
use openrand::core::{
    CounterRng, Philox, Philox2x32, Rng, Squares, Threefry, Threefry2x32, Tyche, TycheI,
};

/// Produce `len` words from a freshly-constructed generator, xor-folded
/// so nothing is optimized away.
fn produce<R: Rng>(mut rng: R, len: usize) -> u32 {
    let mut acc = 0u32;
    // Words are drawn one by one (the paper's loop), not via fill, so
    // per-call overhead is part of the measurement for every library.
    for _ in 0..len {
        acc ^= rng.next_u32();
    }
    acc
}

fn bench_series<R: Rng>(
    b: &Bencher,
    name: &str,
    lens: &[usize],
    mut make: impl FnMut(u64) -> R,
) -> Vec<f64> {
    let mut out = Vec::with_capacity(lens.len());
    let mut seed = 1u64;
    for &len in lens {
        let r = b.run(&format!("{name}/len={len}"), len as u64, || {
            seed = seed.wrapping_add(1);
            black_box(produce(make(seed), len));
        });
        eprintln!("  {}", r.summary());
        out.push(r.median_ns / len as f64);
    }
    out
}

fn main() {
    let b = Bencher::from_env();
    let lens: Vec<usize> = (0..=21).step_by(3).map(|e| 1usize << e).collect(); // 1 .. 2M
    eprintln!("fig4a micro-benchmark: ns/word for fresh-generator streams");

    let mut fig = Series::new(
        "Fig 4a — host stream generation",
        "stream_len",
        "ns_per_word",
        lens.iter().map(|&l| l as f64).collect(),
    );

    fig.push("philox", bench_series(&b, "philox", &lens, |s| Philox::new(s, 0)));
    fig.push("philox2x32", bench_series(&b, "philox2x32", &lens, |s| Philox2x32::new(s, 0)));
    fig.push("threefry", bench_series(&b, "threefry", &lens, |s| Threefry::new(s, 0)));
    fig.push(
        "threefry2x32",
        bench_series(&b, "threefry2x32", &lens, |s| Threefry2x32::new(s, 0)),
    );
    fig.push("squares", bench_series(&b, "squares", &lens, |s| Squares::new(s, 0)));
    fig.push("tyche", bench_series(&b, "tyche", &lens, |s| Tyche::new(s, 0)));
    fig.push("tyche_i", bench_series(&b, "tyche_i", &lens, |s| TycheI::new(s, 0)));
    // Baselines: the paper's std::mt19937 and r123::philox; plus two
    // modern sequential generators for context.
    fig.push("mt19937", bench_series(&b, "mt19937", &lens, |s| Mt19937::new(s as u32)));
    fig.push("r123_philox", bench_series(&b, "r123_philox", &lens, |s| Philox::new(s, 1)));
    fig.push("pcg32", bench_series(&b, "pcg32", &lens, |s| Pcg32::new(s, 54)));
    fig.push("xoshiro256pp", bench_series(&b, "xoshiro256pp", &lens, |s| Xoshiro256pp::new(s)));

    println!("{}", fig.render(|y| format!("{y:.2}")));

    // The paper's headline shape for Fig. 4a, asserted:
    let mt = &fig.series.iter().find(|(n, _)| n == "mt19937").unwrap().1;
    let short_idx = 0; // len = 1
    for gen in ["philox", "squares", "tyche"] {
        let ys = &fig.series.iter().find(|(n, _)| n == gen).unwrap().1;
        let ratio = mt[short_idx] / ys[short_idx];
        println!(
            "shape check: {gen} beats mt19937 at len=1 by {ratio:.0}x {}",
            if ratio > 2.0 { "(paper: strong disparity — OK)" } else { "(UNEXPECTED)" }
        );
    }
    let long_idx = fig.x.len() - 1;
    for gen in ["squares", "tyche"] {
        let ys = &fig.series.iter().find(|(n, _)| n == gen).unwrap().1;
        let ratio = mt[long_idx] / ys[long_idx];
        println!(
            "shape check: {gen} vs mt19937 at len={}: {ratio:.2}x {}",
            fig.x[long_idx],
            if ratio > 1.0 { "(paper: sustained advantage — OK)" } else { "(UNEXPECTED)" }
        );
    }
}
