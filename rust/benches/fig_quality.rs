//! fig_quality — jump-ahead cost + the inter-stream correlation battery.
//!
//! Two tables for the quality/skip-ahead story:
//!
//! 1. **Jump-ahead cost**: ns to `advance(n)` far into a stream (plus
//!    one draw), for every engine that offers sub-linear skip-ahead —
//!    the counter engines (O(1) counter arithmetic), PCG32/LCG64
//!    (O(log n) [`lcg_skip`]), SplitMix64 (O(1) Weyl multiply) and
//!    xoshiro256++'s fixed-stride polynomial `jump()`. Tyche has no
//!    sub-linear skip (`JUMP_LOG2 = None`) and is timed at a small,
//!    honest `n` so the O(n) cost is visible, not hidden.
//! 2. **Inter-stream battery**: `stats::interstream` — the full
//!    single-stream suite over a round-robin interleave of K
//!    `StreamKey::child` streams, each word addressed by jump-ahead.
//!    Asserted zero failures for every engine at every K (this is the
//!    bench-side acceptance gate for `stats --inter-stream`).
//!
//! ```bash
//! cargo bench --bench fig_quality          # full
//! OPENRAND_BENCH_QUICK=1 cargo bench --bench fig_quality
//! ```

use openrand::baseline::{Lcg64, Pcg32, SplitMix64, Xoshiro256pp};
use openrand::bench::harness::black_box;
use openrand::bench::{Bencher, Series};
use openrand::core::traits::CounterRng;
use openrand::core::{Philox, Philox2x32, Rng, Squares, Threefry, Threefry2x32, Tyche, TycheI};
use openrand::stats::interstream::run_inter_stream_suite;
use openrand::stats::suite::{TestResult, Verdict};
use std::time::Instant;

/// Far enough that an accidental O(n) implementation would visibly hang
/// (2^40 words), with a ragged offset so block-aligned shortcuts can't
/// fake it.
const FAR: u64 = (1 << 40) + 12_345;

fn bench_advance(b: &Bencher, name: &str, mut f: impl FnMut() -> u32) -> f64 {
    let r = b.run(name, 1, || {
        black_box(f());
    });
    eprintln!("  {}", r.summary());
    r.median_ns
}

fn counter_advance<G: CounterRng>(b: &Bencher, n: u64) -> f64 {
    bench_advance(b, &format!("advance/{}", G::NAME), || {
        let mut g = G::new(0xF1C5, 1);
        g.advance(n);
        g.next_u32()
    })
}

fn jump_rows(b: &Bencher) -> Vec<(&'static str, f64)> {
    let mut rows = vec![
        ("philox", counter_advance::<Philox>(b, FAR)),
        ("philox2x32", counter_advance::<Philox2x32>(b, FAR)),
        ("threefry", counter_advance::<Threefry>(b, FAR)),
        ("threefry2x32", counter_advance::<Threefry2x32>(b, FAR)),
        ("squares", counter_advance::<Squares>(b, FAR)),
    ];
    rows.push((
        "pcg32",
        bench_advance(b, "advance/pcg32", || {
            let mut g = Pcg32::new(0xF1C5, 54);
            g.advance(FAR);
            g.next_u32()
        }),
    ));
    rows.push((
        "lcg64",
        bench_advance(b, "advance/lcg64", || {
            let mut g = Lcg64::new(0xF1C5);
            g.advance(FAR);
            g.next_u32()
        }),
    ));
    rows.push((
        "splitmix64",
        bench_advance(b, "advance/splitmix64", || {
            let mut g = SplitMix64::new(0xF1C5);
            g.advance(FAR);
            g.next_u32()
        }),
    ));
    rows.push((
        "xoshiro256pp",
        bench_advance(b, "jump/xoshiro256pp (fixed 2^128)", || {
            let mut g = Xoshiro256pp::new(0xF1C5);
            g.jump();
            g.next_u32()
        }),
    ));
    // Tyche: O(n) stepping only — timed at 4096 words so the linear
    // cost shows as ns/4k-words, not an hour-long hang.
    rows.push((
        "tyche (O(n), n=4k)",
        bench_advance(b, "advance/tyche (O(n), n=4096)", || {
            let mut g = Tyche::new(0xF1C5, 1);
            g.advance(4096);
            g.next_u32()
        }),
    ));
    rows
}

fn battery_row(engine: &str, results: &[TestResult], wall_s: f64) -> (usize, usize) {
    let fails = results.iter().filter(|r| r.verdict() == Verdict::Fail).count();
    let susp = results.iter().filter(|r| r.verdict() == Verdict::Suspicious).count();
    let min_p = results.iter().map(|r| r.p).fold(1.0f64, f64::min);
    let words: usize = results.iter().map(|r| r.words_used).sum();
    println!(
        "  {:<22} {:>2} tests  {fails} failures  {susp} suspicious  min-p={min_p:<9.2e} {:>9} words/s",
        engine,
        results.len(),
        openrand::util::format::si(words as f64 / wall_s)
    );
    (fails, susp)
}

fn main() {
    let b = Bencher::from_env();
    let quick = std::env::var("OPENRAND_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);

    // ── Table 1: jump-ahead cost ─────────────────────────────────────
    eprintln!("fig_quality: advance({FAR}) + 1 draw, ns (engine re-created each sample)");
    let rows = jump_rows(&b);
    let mut fig = Series::new(
        "Fig Q1 — jump-ahead cost (ns per far advance + draw)",
        "generator",
        "ns",
        (0..rows.len()).map(|i| i as f64).collect(),
    );
    fig.push("advance_ns", rows.iter().map(|(_, ns)| *ns).collect());
    for (i, (name, _)) in rows.iter().enumerate() {
        eprintln!("  col {i} = {name}");
    }
    println!("{}", fig.render(|y| format!("{y:.1}")));

    // Shape check: every O(1)/O(log n) far advance must be far cheaper
    // than stepping there could ever be — bound it at 1 ms/advance
    // (an O(n) regression at n=2^40 would take minutes to hours).
    for (name, ns) in &rows {
        if !name.starts_with("tyche") {
            assert!(*ns < 1e6, "{name}: far advance took {ns:.0} ns — O(n) regression?");
        }
    }

    // ── Table 2: inter-stream correlation battery ────────────────────
    let words = if quick { 1 << 16 } else { 1 << 18 };
    let ks: &[u64] = if quick { &[64, 1024] } else { &[64, 4096, 65_536] };
    let mut all_pass = true;
    let mut throughput: Vec<(u64, Vec<f64>)> = ks.iter().map(|&k| (k, Vec::new())).collect();
    for &k in ks {
        println!("inter-stream battery: K={k} child streams, {words} words/test budget");
        macro_rules! engines {
            ($(($name:literal, $g:ty)),+ $(,)?) => {{
                $(
                    let t0 = Instant::now();
                    let results = run_inter_stream_suite::<$g>(0x0DDB_A11, k, 1, words);
                    let wall = t0.elapsed().as_secs_f64();
                    let (fails, _susp) = battery_row($name, &results, wall);
                    all_pass &= fails == 0;
                    let total: usize = results.iter().map(|r| r.words_used).sum();
                    throughput
                        .iter_mut()
                        .find(|(kk, _)| *kk == k)
                        .unwrap()
                        .1
                        .push(total as f64 / wall / 1e6);
                )+
            }};
        }
        if quick {
            engines!(("philox", Philox), ("squares", Squares));
        } else {
            engines!(
                ("philox", Philox),
                ("philox2x32", Philox2x32),
                ("threefry", Threefry),
                ("threefry2x32", Threefry2x32),
                ("squares", Squares),
                ("tyche", Tyche),
                ("tyche_i", TycheI),
            );
        }
    }

    let n_engines = throughput[0].1.len();
    let mut fig2 = Series::new(
        "Fig Q2 — inter-stream battery throughput (Mwords/s; flat in K = jump-ahead works)",
        "engine",
        "Mwords_per_s",
        (0..n_engines).map(|i| i as f64).collect(),
    );
    for (k, vals) in throughput {
        fig2.push(&format!("K={k}"), vals);
    }
    println!("{}", fig2.render(|y| format!("{y:.1}")));

    println!(
        "{}",
        if all_pass {
            "ALL ENGINES PASS the inter-stream battery at every K"
        } else {
            "INTER-STREAM FAILURES — investigate above"
        }
    );
    assert!(all_pass, "inter-stream battery reported failures");
}
