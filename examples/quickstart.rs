//! Quickstart: the OpenRAND API in 60 seconds.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Covers: hierarchical stream keys, the one-handle `Stream` facade
//! (draws, bulk fills, distributions), per-entity streams and
//! sub-streams per timestep, and the legacy `(seed, ctr)` equivalence —
//! the paper's §3.1 walk-through as runnable code.

use openrand::core::{CounterRng, Philox, Rng, Squares, Tyche};
use openrand::dist::{
    BoxMuller, DiscreteAlias, Distribution, Exponential, Poisson, Uniform, ZigguratNormal,
};
use openrand::stream::{Stream, StreamKey};

fn main() -> anyhow::Result<()> {
    // 1. A stream is named by a typed hierarchical key — no global
    //    state, no init call, no hand-packed integers. Same key ->
    //    same stream, forever.
    let run = StreamKey::root(42);
    let mut s = Stream::<Philox>::new(run);
    println!("u32      : {}", s.next_u32());
    println!("f64      : {:.6}", s.draw_double());
    let (a, b) = s.draw_double2(); // the paper's draw_double2
    println!("double2  : ({a:.6}, {b:.6})");

    // 2. Distributions compose with the same handle. Each sampler
    //    consumes a documented word pattern from the stream (the
    //    contract table in `dist`), so distribution draws replay
    //    bitwise too. BoxMuller is the normative normal: exactly one
    //    draw_double2 pair (= one Philox counter block) per sample,
    //    shared with the device graphs.
    let normal = BoxMuller::standard();
    let expo = Exponential::new(2.0);
    let pois = Poisson::new(4.5);
    let uni = Uniform::new(-1.0, 1.0);
    // Both directions compose: the handle samples a distribution, and a
    // distribution draws from the handle (Stream implements Rng).
    println!("gaussian : {:.6}", normal.sample(&mut s));
    println!("exp(2)   : {:.6}", s.sample(&expo));
    println!("poisson  : {}", s.sample(&pois));
    println!("uniform  : {:.6}", s.sample(&uni));

    // 2b. The ziggurat is the host fast path for normals: ~1 stream
    //     word per sample against Box-Muller's 4 + ln/sqrt/cos/sin (see
    //     `cargo bench --bench fig_dist`). Deterministic per stream,
    //     but variable word consumption — use BoxMuller where
    //     host/device streams must stay aligned.
    let zig = ZigguratNormal::standard();
    println!("ziggurat : {:.6}", s.sample(&zig));

    // 2c. Weighted categorical draws in O(1) per sample via Walker's
    //     alias method (table built once in O(n)).
    let loot = DiscreteAlias::new(&[60.0, 30.0, 9.0, 1.0]);
    let names = ["common", "uncommon", "rare", "legendary"];
    println!("alias    : {}", names[s.sample(&loot)]);

    // 3. The parallel pattern (paper Fig. 1): one stream per logical
    //    entity, derived from the entity's OWN id via the normative
    //    child mix — reproducible no matter which thread runs it, and
    //    collision-proof without xor-packing seeds by hand.
    let total: f64 = (0..8u64)
        .map(|particle_id| {
            let mut r = Stream::<Philox>::new(run.child(particle_id).epoch(/*timestep=*/ 7));
            r.draw_double()
        })
        .sum();
    println!("8 per-particle draws, timestep 7, sum = {total:.6}");

    // 4. Sub-streams: epoch(t) selects an independent stream of the
    //    same entity (next timestep, next kernel, ...). Absolute:
    //    epoch(1) means "sub-stream 1", not "advance once".
    let entity = run.child(1234);
    let mut t0 = Stream::<Philox>::new(entity.epoch(0));
    let mut t1 = Stream::<Philox>::new(entity.epoch(1));
    println!("particle 1234 @ t0: {:.6}, @ t1: {:.6}", t0.draw_double(), t1.draw_double());

    // 5. Bulk generation through the same handle: key-addressed fills
    //    and bulk sampling, routed through a fill backend — None picks
    //    the calibrated Auto arm. Byte-identical to the scalar draws
    //    on every arm (the backend contract).
    let mut words = vec![0u32; 8];
    s.fill_u32(None, &mut words)?;
    let mut first = Stream::<Philox>::new(s.key());
    assert_eq!(words[0], first.next_u32()); // fills re-read from word 0
    let mut normals = vec![0.0f64; 4];
    s.sample_fill(&normal, None, &mut normals)?;
    println!("bulk     : {words:?}");
    println!("normals  : {normals:?}");

    // 6. The legacy spelling is a thin, documented equivalence:
    //    StreamKey::raw(seed, ctr) opens the byte-identical stream
    //    CounterRng::new(seed, ctr) always opened — existing code and
    //    every pinned KAT replay unchanged. Other engines, same API.
    let mut via_key = Stream::<Squares>::new(StreamKey::raw(42, 0));
    let mut legacy = Squares::new(42, 0);
    assert_eq!(via_key.next_u32(), legacy.next_u32());
    let mut ty = Stream::<Tyche>::new(StreamKey::raw(42, 0));
    println!("squares  : {}", legacy.next_u32());
    println!("tyche    : {}", ty.next_u32());

    // 7. Reproducibility is bitwise: re-opening the key replays the
    //    stream exactly.
    let keyed_words = |key: StreamKey| -> Vec<u32> {
        let mut r = Stream::<Philox>::new(key);
        (0..4).map(|_| r.next_u32()).collect()
    };
    let w1 = keyed_words(run.child(3).epoch(1));
    let w2 = keyed_words(run.child(3).epoch(1));
    assert_eq!(w1, w2);
    println!("replayed derived stream bitwise: OK {w1:?}");
    Ok(())
}
