//! Quickstart: the OpenRAND API in 60 seconds.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Covers: construction from (seed, counter), draws, distributions,
//! per-entity streams, and sub-streams per kernel/timestep — the paper's
//! §3.1 walk-through as runnable code.

use openrand::core::{CounterRng, Philox, Rng, Squares, Tyche};
use openrand::dist::{
    BoxMuller, DiscreteAlias, Distribution, Exponential, Poisson, Uniform, ZigguratNormal,
};

fn main() {
    // 1. A generator is just (seed, counter). No global state, no init
    //    call, no warm-up to manage. Same pair -> same stream, forever.
    let mut rng = Philox::new(/*seed=*/ 42, /*ctr=*/ 0);
    println!("u32      : {}", rng.next_u32());
    println!("f64      : {:.6}", rng.draw_double());
    let (a, b) = rng.draw_double2(); // the paper's draw_double2
    println!("double2  : ({a:.6}, {b:.6})");

    // 2. Distributions compose with any engine. Each sampler consumes a
    //    documented word pattern from the stream (the contract table in
    //    `dist`), so distribution draws replay bitwise too. BoxMuller is
    //    the normative normal: exactly one draw_double2 pair (= one
    //    Philox counter block) per sample, shared with the device graphs.
    let normal = BoxMuller::standard();
    let expo = Exponential::new(2.0);
    let pois = Poisson::new(4.5);
    let uni = Uniform::new(-1.0, 1.0);
    println!("gaussian : {:.6}", normal.sample(&mut rng));
    println!("exp(2)   : {:.6}", expo.sample(&mut rng));
    println!("poisson  : {}", pois.sample(&mut rng));
    println!("uniform  : {:.6}", uni.sample(&mut rng));

    // 2b. The ziggurat is the host fast path for normals: ~1 stream word
    //     per sample against Box-Muller's 4 + ln/sqrt/cos/sin (see
    //     `cargo bench --bench fig_dist`). Deterministic per stream, but
    //     variable word consumption — use BoxMuller where host/device
    //     streams must stay aligned.
    let zig = ZigguratNormal::standard();
    println!("ziggurat : {:.6}", zig.sample(&mut rng));

    // 2c. Weighted categorical draws in O(1) per sample via Walker's
    //     alias method (table built once in O(n)).
    let loot = DiscreteAlias::new(&[60.0, 30.0, 9.0, 1.0]);
    let names = ["common", "uncommon", "rare", "legendary"];
    println!("alias    : {}", names[loot.sample(&mut rng)]);

    // 3. The parallel pattern (paper Fig. 1): one stream per logical
    //    entity, derived from the entity's OWN id — reproducible no
    //    matter which thread runs it, or how many threads exist.
    let total: f64 = (0..8u64)
        .map(|particle_id| {
            let mut r = Philox::new(particle_id, /*timestep=*/ 7);
            r.draw_double()
        })
        .sum();
    println!("8 per-particle draws, timestep 7, sum = {total:.6}");

    // 4. Sub-streams: bump the counter for a new independent stream of
    //    the same entity (next timestep, next kernel, ...).
    let mut t0 = Philox::new(1234, 0);
    let mut t1 = Philox::new(1234, 1);
    println!("particle 1234 @ t0: {:.6}, @ t1: {:.6}", t0.draw_double(), t1.draw_double());

    // 5. Other engines, same API (pick per DESIGN.md guidance: Philox
    //    default; Squares/Tyche for CPU speed; Threefry where multipliers
    //    are slow).
    let mut sq = Squares::new(42, 0);
    let mut ty = Tyche::new(42, 0);
    println!("squares  : {}", sq.next_u32());
    println!("tyche    : {}", ty.next_u32());

    // 6. Reproducibility is bitwise: re-creating the generator replays
    //    the stream exactly.
    let w1: Vec<u32> = {
        let mut r = Philox::new(42, 0);
        (0..4).map(|_| r.next_u32()).collect()
    };
    let w2: Vec<u32> = {
        let mut r = Philox::new(42, 0);
        (0..4).map(|_| r.next_u32()).collect()
    };
    assert_eq!(w1, w2);
    println!("replayed stream bitwise: OK {w1:?}");
}
