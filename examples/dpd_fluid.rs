//! DPD fluid — the workload where counter-based RNG is *necessary*, not
//! just convenient (paper reference [1]: Brownian Dynamics and
//! Dissipative Particle Dynamics on GPUs).
//!
//! The random pair force F_ij must equal -F_ji exactly, so both
//! particles regenerate the SAME variate from the pair identity
//! (seed = pair_seed(i, j), ctr = step). The demo proves, at runtime:
//!   1. total momentum is conserved to summation noise,
//!   2. the thermostat equilibrates kinetic temperature to ~kT,
//!   3. trajectories are bitwise identical across thread counts,
//!   4. with per-particle (stateful-style) kicks instead, momentum
//!      conservation visibly breaks — the paper's argument, executed.
//!
//! ```bash
//! cargo run --release --example dpd_fluid
//! ```

use openrand::core::{CounterRng, Philox, Rng};
use openrand::sim::dpd::{DpdParams, DpdSim};

fn main() {
    let p = DpdParams {
        n: 1600,
        box_side: 20.0, // density 4
        a: 25.0,
        gamma: 4.5,
        kt: 1.0,
        dt: 0.01,
        global_seed: 7,
    };
    println!("DPD fluid: n={} box={} a={} gamma={} kT={} dt={}", p.n, p.box_side, p.a, p.gamma, p.kt, p.dt);

    let mut sim = DpdSim::new(p);
    let (px0, py0) = sim.momentum();
    println!("\nstep   temperature   |momentum drift|");
    for block in 0..10 {
        for _ in 0..40 {
            sim.step_all();
        }
        let (px, py) = sim.momentum();
        let drift = ((px - px0).powi(2) + (py - py0).powi(2)).sqrt();
        println!("{:>4}   {:>11.4}   {:>15.3e}", (block + 1) * 40, sim.temperature(), drift);
    }
    let (px, py) = sim.momentum();
    let drift = ((px - px0).powi(2) + (py - py0).powi(2)).sqrt();
    assert!(drift < 1e-8, "momentum leaked: {drift}");
    println!("\nmomentum conserved to {drift:.2e} over 400 steps: OK (symmetric pair RNG)");
    let t = sim.temperature();
    assert!((0.6..1.5).contains(&t), "thermostat failed: T={t}");
    println!("thermostat equilibrated at T = {t:.3} (target kT = 1, Euler-discretization offset expected)");

    // Thread-count invariance.
    let run = |threads: usize| {
        let mut s = DpdSim::new(p);
        for _ in 0..25 {
            if threads == 1 {
                s.step_all()
            } else {
                s.step_parallel(threads)
            }
        }
        s.state_hash()
    };
    let h1 = run(1);
    for t in [2usize, 4, 8] {
        assert_eq!(run(t), h1, "threads={t}");
    }
    println!("trajectory hash {h1:016x} identical for 1/2/4/8 threads: OK");

    // Negative control: per-particle kicks (what a stateful RNG gives you
    // in a pairwise force loop) break conservation immediately.
    let mut bad = DpdSim::new(p);
    bad.step_all();
    let mut vx: f64 = 0.0;
    let mut vy: f64 = 0.0;
    for i in 0..p.n {
        let mut rng = Philox::new(i as u64, 12345);
        vx += (rng.draw_double() - 0.5) * 0.1;
        vy += (rng.draw_double() - 0.5) * 0.1;
    }
    let bad_drift = (vx * vx + vy * vy).sqrt();
    println!(
        "\nnegative control: per-particle random kicks accumulate net momentum {bad_drift:.3e} in ONE step\n\
         (vs {drift:.2e} over 400 steps with pair-symmetric streams) — \n\
         this asymmetry is why DPD codes need counter-based RNG."
    );
    assert!(bad_drift > 1e-3);
}
