//! Reproducibility verification (experiment E6): the claims of §1/§6 as
//! executable checks.
//!
//! 1. Trajectory hash invariant across 1/2/4/8 threads.
//! 2. Trajectory hash invariant across re-runs.
//! 3. Host vs device (PJRT) trajectories agree.
//! 4. Host vs device RNG *bitstream* agrees exactly (u32-level).
//!
//! ```bash
//! make artifacts && cargo run --release --example repro_check
//! ```

use openrand::coordinator::repro;
use openrand::core::{CounterRng, Philox, Rng};
use openrand::runtime::exec::{Arg, DeviceGraph};
use openrand::runtime::ArtifactStore;
use openrand::sim::brownian::{BrownianParams, RngStyle};

fn main() -> anyhow::Result<()> {
    let params = BrownianParams {
        n_particles: 16_384,
        steps: 40,
        global_seed: 0xC0FFEE,
        style: RngStyle::OpenRand,
    };

    println!("[1/4] thread-count invariance");
    let r = repro::verify_thread_invariance(params, 8)?;
    print!("{}", r.render());
    anyhow::ensure!(r.consistent, "thread invariance violated");

    println!("[2/4] re-run invariance");
    let r = repro::verify_rerun(params, 4)?;
    print!("{}", r.render());
    anyhow::ensure!(r.consistent, "re-run invariance violated");

    println!("[3/4] host vs device trajectories");
    let r = repro::verify_backends(params, 1e-9)?;
    print!("{}", r.render());
    anyhow::ensure!(r.consistent, "backend agreement violated");

    println!("[4/4] host vs device RNG bitstream (u32 exact)");
    let store = ArtifactStore::open_default()?;
    let graph = DeviceGraph::load(&store, "philox_u32_65536")?;
    let seed = 0xDEAD_BEEF_0BAD_F00Du64;
    let ctr = 3u32;
    let dev = graph.call_u32(&[Arg::U32(&[seed as u32, (seed >> 32) as u32, ctr, 0])])?;
    let mut host = vec![0u32; dev.len()];
    Philox::new(seed, ctr).fill_u32(&mut host);
    anyhow::ensure!(dev == host, "device and host Philox bitstreams differ");
    println!("  {} words bitwise identical across Rust / JAX+Pallas paths", dev.len());

    println!("\nALL REPRODUCIBILITY CHECKS PASSED");
    Ok(())
}
