//! END-TO-END DRIVER (deliverable (b) / EXPERIMENTS.md §E2E): the full
//! three-layer stack on a real workload.
//!
//! Runs the paper's Brownian-dynamics benchmark on BOTH backends —
//!
//! * host: multithreaded Rust coordinator calling the Rust Philox,
//! * device: the PJRT runtime executing `brownian_step_16384.hlo.txt`,
//!   which was AOT-lowered from the JAX model calling the Pallas
//!   Philox kernel —
//!
//! then proves the layers compose: identical RNG streams, matching
//! trajectories, physics observables on the diffusion law, and
//! thread-count-invariant hashes. Logs an MSD "loss curve" over time.
//!
//! ```bash
//! make artifacts && cargo run --release --example brownian_e2e
//! # larger run:
//! N=1048576 STEPS=2000 cargo run --release --example brownian_e2e
//! ```

use openrand::coordinator::repro;
use openrand::coordinator::{Backend, SimDriver};
use openrand::sim::brownian::{BrownianParams, BrownianSim, RngStyle};
use openrand::sim::observables;
use openrand::util::format;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let n = env_usize("N", 16_384);
    let steps = env_usize("STEPS", 400) as u32;
    let seed = 2026;
    println!("=== OpenRAND E2E: Brownian dynamics, n={n}, steps={steps} ===\n");

    // --- Host path with MSD logging (the "loss curve"). -----------------
    let params = BrownianParams { n_particles: n, steps: 0, global_seed: seed, style: RngStyle::OpenRand };
    let mut sim = BrownianSim::new(params);
    let x0 = sim.x.clone();
    let y0 = sim.y.clone();
    let t_host = std::time::Instant::now();
    let log_every = (steps / 10).max(1);
    println!("step      MSD        mean|v|   (host, 1 thread)");
    for s in 0..steps {
        sim.step_all();
        if (s + 1) % log_every == 0 {
            println!(
                "{:>5}  {:>9.5}  {:>9.5}",
                s + 1,
                observables::msd(&sim, &x0, &y0),
                observables::mean_speed(&sim)
            );
        }
    }
    let host_time = t_host.elapsed();
    let host_hash = sim.state_hash();
    let slope_theory = observables::theoretical_msd_slope();
    let msd_final = observables::msd(&sim, &x0, &y0);
    println!("\nhost wall: {:.3}s ({}/s particle-steps)", host_time.as_secs_f64(),
        format::si(n as f64 * steps as f64 / host_time.as_secs_f64()));
    println!("final MSD {msd_final:.4} (diffusion-law slope theory {slope_theory:.3e}/step)");

    // --- Device path: same physics, AOT artifact. -----------------------
    let dev_params = BrownianParams { n_particles: n, steps, global_seed: seed, style: RngStyle::OpenRand };
    match SimDriver::new(Backend::Device).run(dev_params) {
        Ok((dev_sim, m)) => {
            println!("\ndevice wall: {:.3}s ({}/s) [PJRT, artifact brownian_step_{n}]",
                m.wall.as_secs_f64(), format::si(m.throughput()));
            // Compare trajectories: XLA may re-associate floats, so use a
            // tight relative tolerance rather than bitwise.
            let mut max_rel: f64 = 0.0;
            for i in 0..n {
                for (a, b) in [(sim.x[i], dev_sim.x[i]), (sim.y[i], dev_sim.y[i])] {
                    max_rel = max_rel.max((a - b).abs() / a.abs().max(1e-9));
                }
            }
            println!("host vs device max relative position error: {max_rel:.3e}");
            assert!(max_rel < 1e-9, "host/device trajectories diverged");
            println!("host/device agreement: OK");
        }
        Err(e) => {
            println!("\ndevice path unavailable ({e}); run `make artifacts` for the full E2E");
            std::process::exit(1);
        }
    }

    // --- Reproducibility ladder (the paper's core claim). ----------------
    println!();
    let ladder_params = BrownianParams { n_particles: n.min(65_536), steps: steps.min(50), global_seed: seed, style: RngStyle::OpenRand };
    let ladder = repro::verify_thread_invariance(ladder_params, 8)?;
    print!("{}", ladder.render());
    assert!(ladder.consistent);
    println!("single-thread hash {host_hash:016x} reproduced across thread ladder: OK");

    // --- Physics validation. ---------------------------------------------
    // After the velocity autocorrelation time (~1/(γ·dt) = 200 steps) the
    // MSD grows linearly with the theoretical slope.
    if steps >= 400 {
        let mut probe = BrownianSim::new(BrownianParams { n_particles: n.min(16_384), steps: 0, global_seed: 99, style: RngStyle::OpenRand });
        let px0 = probe.x.clone();
        let py0 = probe.y.clone();
        for _ in 0..400 {
            probe.step_all();
        }
        let m1 = observables::msd(&probe, &px0, &py0);
        for _ in 0..400 {
            probe.step_all();
        }
        let m2 = observables::msd(&probe, &px0, &py0);
        let slope = (m2 - m1) / 400.0;
        let rel = (slope / slope_theory - 1.0).abs();
        println!("diffusion law: measured slope {slope:.3e}, theory {slope_theory:.3e} (rel err {rel:.2})");
        assert!(rel < 0.2, "diffusion law violated");
        println!("physics validation: OK");
    }

    println!("\nE2E: ALL LAYERS COMPOSE");
    Ok(())
}
