//! Monte-Carlo π with reproducible parallelism.
//!
//! Each CHUNK of samples owns stream (seed = chunk_id, ctr = 0). Threads
//! pick up chunks in whatever order scheduling dictates — the estimate is
//! bitwise identical for every thread count, which this example proves by
//! running the ladder.
//!
//! ```bash
//! cargo run --release --example monte_carlo_pi
//! ```

use openrand::coordinator::ThreadPool;
use openrand::core::{Philox, Squares};
use openrand::sim::pi::chunk_hits;
use openrand::util::format;

fn parallel_hits<G: openrand::core::BlockRng>(
    threads: usize,
    chunks: u64,
    samples_per_chunk: usize,
    seed: u64,
) -> u64 {
    ThreadPool::new(threads)
        .run_partitioned(chunks as usize, |_, range| {
            range
                .map(|c| chunk_hits::<G>(c as u64, seed, samples_per_chunk))
                .sum::<u64>()
        })
        .into_iter()
        .sum()
}

fn main() {
    let chunks = 512u64;
    let samples = 20_000usize;
    let seed = 7;
    let total = chunks as f64 * samples as f64;
    println!("Monte-Carlo pi: {} samples in {chunks} chunks", format::si(total));

    let mut last = None;
    for threads in [1usize, 2, 4, 8] {
        let t = std::time::Instant::now();
        let hits = parallel_hits::<Philox>(threads, chunks, samples, seed);
        let est = 4.0 * hits as f64 / total;
        println!(
            "threads={threads:<2} pi={est:.8} hits={hits} ({:.0} ms)",
            t.elapsed().as_secs_f64() * 1e3
        );
        if let Some(prev) = last {
            assert_eq!(prev, hits, "estimate changed with thread count!");
        }
        last = Some(hits);
    }
    println!("bitwise identical across thread counts: OK");

    // Squares engine, same exercise.
    let h1 = parallel_hits::<Squares>(1, chunks, samples, seed);
    let h8 = parallel_hits::<Squares>(8, chunks, samples, seed);
    assert_eq!(h1, h8);
    println!("squares engine agrees too: pi={:.8}", 4.0 * h1 as f64 / total);
    println!("|est - pi| = {:.2e}", (4.0 * h1 as f64 / total - std::f64::consts::PI).abs());
}
