//! API compactness comparison (experiment E5, paper Figs. 1–3): the same
//! Brownian kick written in the three API styles, with the paper's
//! line-count and state-cost claims measured from this very file.
//!
//! ```bash
//! cargo run --release --example api_comparison
//! ```

use openrand::baseline::raw123;
use openrand::baseline::stateful_philox::{init_states, StatefulPhilox};
use openrand::core::{CounterRng, Philox, Rng};

// --- Style 1: OpenRAND (paper Fig. 1) — 2 lines of RNG code. -----------
// BEGIN:openrand
fn kick_openrand(pid: u64, step: u32) -> (f64, f64) {
    let mut rng = Philox::new(pid, step);
    rng.draw_double2()
}
// END:openrand

// --- Style 2: cuRAND-like (paper Fig. 2) — allocate, init pass, load, --
// --- draw, store. -------------------------------------------------------
// BEGIN:curand
struct CurandSim {
    states: Vec<openrand::baseline::CurandPhiloxState>,
}

impl CurandSim {
    fn new(seed: u64, n: usize) -> CurandSim {
        // cudaMalloc(...) analogue:
        // rand_init<<<...>>> analogue (a whole separate pass):
        CurandSim { states: init_states(seed, n) }
    }

    fn kick(&mut self, pid: usize) -> (f64, f64) {
        // Load the 64-byte state record...
        let mut rng = StatefulPhilox::load(&self.states, pid);
        let d = rng.draw_double2();
        // ...and store it back, every kernel, every thread.
        rng.store(&mut self.states, pid);
        d
    }
}
// END:curand

// --- Style 3: Random123 raw (paper Fig. 3) — manual counters, keys, ----
// --- block invocation and u64 packing. ----------------------------------
// BEGIN:raw123
fn kick_raw123(pid: u32, counter: u32) -> (f64, f64) {
    let uk: [u32; 2] = [pid, 0];
    let mut c: [u32; 4] = [0; 4];
    c[0] = counter;
    c[1] = 0;
    let r = raw123::philox4x32_raw(c, uk);
    let xu = ((r[0] as u64) << 32) | r[1] as u64;
    let yu = ((r[2] as u64) << 32) | r[3] as u64;
    (raw123::u01_u64(xu), raw123::u01_u64(yu))
}
// END:raw123

fn region_lines(src: &str, tag: &str) -> usize {
    let begin = format!("// BEGIN:{tag}");
    let end = format!("// END:{tag}");
    let mut counting = false;
    let mut count = 0;
    for line in src.lines() {
        if line.contains(&end) {
            break;
        }
        if counting && !line.trim().is_empty() && !line.trim().starts_with("//") {
            count += 1;
        }
        if line.contains(&begin) {
            counting = true;
        }
    }
    count
}

fn main() {
    let n = 1_000_000usize;
    // All three produce valid kicks.
    let a = kick_openrand(77, 5);
    let mut curand = CurandSim::new(0, 128);
    let b = curand.kick(77);
    let c = kick_raw123(77, 5);
    for (r1, r2) in [a, b, c] {
        assert!((0.0..1.0).contains(&r1) && (0.0..1.0).contains(&r2));
    }

    let src = include_str!("api_comparison.rs");
    println!("API style comparison (paper E5, Figs. 1-3)\n");
    println!("{:<12} {:>12} {:>16} {:>14}", "style", "code lines", "state bytes/1M", "init pass");
    println!("{}", "-".repeat(58));
    println!(
        "{:<12} {:>12} {:>16} {:>14}",
        "openrand",
        region_lines(src, "openrand"),
        "0",
        "none"
    );
    println!(
        "{:<12} {:>12} {:>16} {:>14}",
        "curand",
        region_lines(src, "curand"),
        openrand::util::format::bytes(n * 64),
        "required"
    );
    println!(
        "{:<12} {:>12} {:>16} {:>14}",
        "random123",
        region_lines(src, "raw123"),
        "0",
        "none"
    );
    println!(
        "\npaper: OpenRAND needs 'just two lines for generator initialization\n\
         and random number computation — over 14 fewer lines than the\n\
         competing libraries', and saves ~64 MB of GPU memory per million\n\
         particles vs cuRAND. Both claims measured above from this file."
    );
}
