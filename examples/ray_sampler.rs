//! Ray-tracing-style pixel sampling — the paper's other motivating
//! workload ("a pixel index in a ray tracing application").
//!
//! Renders a tiny anti-aliased scene statistic: for each pixel, stream
//! (seed = pixel_id, ctr = sample_batch) drives jittered supersampling
//! of a procedural signed-distance circle. Reproducibility: tiles are
//! rendered in parallel in scan order AND in reverse order; images must
//! be bitwise identical because streams belong to pixels, not threads.
//!
//! ```bash
//! cargo run --release --example ray_sampler
//! ```

use openrand::coordinator::ThreadPool;
use openrand::core::{CounterRng, Philox, Rng};
use openrand::util::hash::Fnv1a;

const W: usize = 256;
const H: usize = 128;
const SPP: u32 = 16; // samples per pixel

/// Coverage of a circle at scene center, supersampled with jitter.
fn shade_pixel(px: usize, py: usize, batch: u32) -> f64 {
    let pixel_id = (py * W + px) as u64;
    let mut rng = Philox::new(pixel_id, batch);
    let mut hits = 0u32;
    for _ in 0..SPP {
        let (jx, jy) = rng.draw_double2();
        let x = (px as f64 + jx) / W as f64 * 2.0 - 1.0;
        let y = (py as f64 + jy) / H as f64 * 2.0 - 1.0;
        // Anisotropic circle (ellipse) SDF.
        if (x * x * 2.0 + y * y) < 0.5 {
            hits += 1;
        }
    }
    hits as f64 / SPP as f64
}

fn render(threads: usize, reverse: bool) -> Vec<f64> {
    let mut img = vec![0.0f64; W * H];
    let pool = ThreadPool::new(threads);
    pool.run_chunks(&mut img, |_, offset, chunk| {
        // Optionally shade the chunk's pixels in reverse order — the
        // image must not care.
        let idxs: Vec<usize> = if reverse {
            (0..chunk.len()).rev().collect()
        } else {
            (0..chunk.len()).collect()
        };
        for j in idxs {
            let pid = offset + j;
            chunk[j] = shade_pixel(pid % W, pid / W, 0);
        }
    });
    img
}

fn main() {
    println!("ray sampler: {W}x{H}, {SPP} jittered samples/pixel\n");

    let img1 = render(1, false);
    let img4 = render(4, false);
    let img4r = render(4, true);
    let h = |img: &[f64]| Fnv1a::hash_f64s(img);
    println!("hash (1 thread, scan order)     : {:016x}", h(&img1));
    println!("hash (4 threads, scan order)    : {:016x}", h(&img4));
    println!("hash (4 threads, reverse order) : {:016x}", h(&img4r));
    assert_eq!(h(&img1), h(&img4));
    assert_eq!(h(&img1), h(&img4r));
    println!("bitwise identical regardless of threading/order: OK\n");

    // Coverage estimate converges to the analytic ellipse area fraction:
    // area of x²·2 + y² < 0.5 in [-1,1]² is π·a·b / 4 with a=0.5, b=sqrt(0.5).
    let coverage: f64 = img1.iter().sum::<f64>() / (W * H) as f64;
    let analytic = std::f64::consts::PI * 0.5 * 0.5f64.sqrt() / 4.0;
    println!("coverage: sampled {coverage:.5}, analytic {analytic:.5}");
    assert!((coverage - analytic).abs() < 0.005);

    // Progressive refinement: batches are independent sub-streams per
    // pixel (ctr = batch index) — accumulating batches halves the noise
    // per 4x samples, and never reuses a random number.
    let mut acc = vec![0.0f64; W * H];
    for batch in 0..4u32 {
        for py in 0..H {
            for px in 0..W {
                acc[py * W + px] += shade_pixel(px, py, batch);
            }
        }
        let est = acc.iter().sum::<f64>() / ((W * H) as f64 * (batch + 1) as f64);
        println!("after batch {batch}: coverage {est:.6} (err {:+.2e})", est - analytic);
    }

    // ASCII thumbnail, because every ray tracer needs output.
    println!();
    for ty in 0..16 {
        let mut line = String::new();
        for tx in 0..64 {
            let px = tx * W / 64;
            let py = ty * H / 16;
            let v = img1[py * W + px];
            line.push(match (v * 4.0) as u32 {
                0 => ' ',
                1 => '.',
                2 => 'o',
                3 => 'O',
                _ => '@',
            });
        }
        println!("{line}");
    }
}
