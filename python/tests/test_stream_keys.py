"""Cross-layer KATs for hierarchical StreamKey derivation.

``common.derive_child_seed`` / ``common.stream_key_path`` are the python
mirror of ``rust/src/stream/mod.rs`` (the normative child mix and the CLI
path spelling). These tests pin the exact literals the Rust doctests and
unit suite pin — ``root(7).child(3).epoch(1)`` and friends — and then
check that the *derived streams themselves* agree by pushing the derived
key through the jnp Philox oracle, so host and device layers agree on
derived streams end to end, not just on the key arithmetic.
"""

import numpy as np
import pytest

from compile.kernels import common as cm
from compile.kernels import ref

# The shared derivation KAT: root(7).child(3).epoch(1). The Rust side
# pins the identical literals (stream/mod.rs doctest + unit tests,
# coordinator::repro::verify_key_equivalence).
KAT_CHILD_SEED = 0xBC8312B734DE4237
KAT_GRANDCHILD_SEED = 0x2D4C1D0A85956C49  # root(7).child(3).child(5)
KAT_EPOCH2_CHILD_SEED = 0x2E49EAEDC17E2B71  # root(7).epoch(2).child(3)


def test_child_mix_kat():
    assert cm.derive_child_seed(7, 0, 3) == KAT_CHILD_SEED
    assert cm.derive_child_seed(KAT_CHILD_SEED, 0, 5) == KAT_GRANDCHILD_SEED
    assert cm.derive_child_seed(7, 2, 3) == KAT_EPOCH2_CHILD_SEED


def test_path_kat_matches_rust_doctest():
    assert cm.stream_key_path("7/c3/e1") == (KAT_CHILD_SEED, 1)


def test_root_and_epoch_are_the_legacy_spelling():
    # Zero drift: root/epoch never re-mix the seed, so simple paths
    # resolve to exactly the legacy (seed, ctr) pair.
    assert cm.stream_key_path("7") == (7, 0)
    assert cm.stream_key_path("7/e1") == (7, 1)
    assert cm.stream_key_path("0x1f/e3") == (0x1F, 3)
    # Epoch is absolute (last wins) — the documented order independence.
    assert cm.stream_key_path("9/e5/e2") == (9, 2)


def test_path_errors():
    # Same rejection set as Rust's StreamKey::parse_path: bad segments,
    # missing values, epoch overflow, signed/underscored/oversized ints
    # (python's int() is laxer than u64 parse; the mirror must not be).
    for bad in (
        "",
        "x",
        "7/z3",
        "7/c",
        "7/e",
        "7/e4294967296",
        "7/e-1",
        "7/c-1",
        "-7",
        "+7",
        "0x+1F",
        "1_000",
        "18446744073709551616",  # 2^64
    ):
        with pytest.raises(ValueError):
            cm.stream_key_path(bad)


def test_child_ids_injective_for_fixed_parent():
    seen = {cm.derive_child_seed(0xABCD, 4, i) for i in range(4096)}
    assert len(seen) == 4096


def test_parent_ctr_separates_child_spaces():
    assert cm.derive_child_seed(7, 0, 3) != cm.derive_child_seed(7, 1, 3)


def test_derived_stream_words_kat():
    """The derived stream itself, through the jnp Philox oracle: the
    first words of root(7).child(3).epoch(1) — the same literals pinned
    by rust/src/stream/mod.rs::derived_stream_kat_philox_words, so both
    layers agree on derived streams, not just derived keys."""
    seed, ctr = cm.stream_key_path("7/c3/e1")
    words = [int(w) for w in np.asarray(ref.philox4x32_stream(seed, ctr, 4))]
    assert words == [0x90229F37, 0x89AF95F5, 0x5048DAB1, 0xAE0C227C]
    # ... and the f64 view of the first pair (first word high, top 53
    # bits), matching Stream::draw_double on the Rust side.
    composed = (words[0] << 32) | words[1]
    assert (composed >> 11) * 2.0**-53 == 0.5630282888975542
