"""The shared three-language KAT table (Rust/Python/C bitwise agreement).

This file pins the exact vector set that `rust/src/selftest.rs` asserts
natively and `ffi/tests/kat_harness.c` replays through the C ABI: stream
words 0..10 of ``(seed=7, ctr=1)`` for every engine, the normative u64 /
f64 / f32 conversions, the ``StreamKey`` derivation literals, and the
derived-stream opening words. One table, three languages — the repro
claim of the FFI subsystem (docs/ffi.md).
"""

import struct

import numpy as np

from compile.kernels import common as cm
from compile.kernels import ref

# Engine order matches Rust's `Generator::ALL` and the C `gen_tag`
# strings accepted by `openrand_create`.
ENGINE_WORDS_S7_C1 = {
    "philox": [0x2EC4F55D, 0x249EF5F4, 0xF681EC7F, 0x807A6601, 0x3CBE7593,
               0x21951225, 0x66BA2E25, 0x5159B36A, 0x8DB4CE21, 0x498FF58B],
    "philox2x32": [0x5DD09A2F, 0x6B00841E, 0xAC55AAD4, 0x858C5948, 0xDCC223D7,
                   0xB92B6CAC, 0x07242571, 0x304D3D15, 0x20C6D682, 0xC8FCCB4F],
    "threefry": [0xD73CEA92, 0xD56DC136, 0xD744F371, 0x6D239EE4, 0xBE200A6E,
                 0x00481B5C, 0xF8EB5F46, 0x3405B98C, 0xDF0D1159, 0x35B542BA],
    "threefry2x32": [0x3AA75E81, 0x7DBDB64C, 0xECA70012, 0x97F16955, 0x636D7473,
                     0x6ECE15CE, 0xC93D5ECF, 0xD0222576, 0x1E98EC3E, 0x975E8B5F],
    "squares": [0xC58E0D20, 0x4C1EEAB3, 0xB2CF997F, 0x7900D050, 0x6B50E8E1,
                0x648DD2AA, 0x7BCCBCFB, 0xCE63EFD7, 0x5B5236D3, 0xD33D98F1],
    "tyche": [0x3CB80C83, 0x0128E5AF, 0x9C1F4904, 0xECA46A3C, 0x2ACC26BE,
              0x6912D082, 0x98318013, 0x44F8C1FA, 0x08703B44, 0xFD4C1C53],
    "tyche_i": [0x208BEFEA, 0x3079BF27, 0xA8606EB3, 0x8839063A, 0x647330F1,
                0xC1170F7E, 0xC298E6A6, 0x41925E91, 0x5902AA9D, 0xC3E537E3],
}

PHILOX_S7_C1_U64 = 0x2EC4F55D249EF5F4
PHILOX_S7_C1_F64_BITS = 0x3FC7627AAE924F78
PHILOX_S7_C1_F32_BITS = 0x3E3B13D4
CHILD_SEED_R7_C3 = 0xBC8312B734DE4237
CHILD_STREAM_WORDS = [0x90229F37, 0x89AF95F5]
CHILD_STREAM_F64_BITS = 0x3FE20453E6F135F2


def _stream(name, seed, ctr, n):
    return {
        "philox": lambda: ref.philox4x32_stream(seed, ctr, n),
        "philox2x32": lambda: ref.philox2x32_stream(seed, ctr, n),
        "threefry": lambda: ref.threefry4x32_stream(seed, ctr, n),
        "threefry2x32": lambda: ref.threefry2x32_stream(seed, ctr, n),
        "squares": lambda: ref.squares_stream(seed, ctr, n),
        "tyche": lambda: ref.tyche_stream_api(seed, ctr, n),
        "tyche_i": lambda: ref.tyche_stream_api(seed, ctr, n, inverse=True),
    }[name]()


def test_engine_word_table_matches_oracle():
    for name, want in ENGINE_WORDS_S7_C1.items():
        got = [int(w) for w in _stream(name, 7, 1, 10)]
        assert got == want, name


def test_conversion_bits_match_oracle():
    w = [int(v) for v in ref.philox4x32_stream(7, 1, 2)]
    u64 = (w[0] << 32) | w[1]
    assert u64 == PHILOX_S7_C1_U64
    f64 = (u64 >> 11) * 2.0**-53
    assert struct.unpack("<Q", struct.pack("<d", f64))[0] == PHILOX_S7_C1_F64_BITS
    f32 = np.float32(np.float32(w[0] >> 8) * np.float32(2.0**-24))
    assert struct.unpack("<I", struct.pack("<f", f32))[0] == PHILOX_S7_C1_F32_BITS


def test_derived_stream_vectors_match_oracle():
    child = cm.derive_child_seed(7, 0, 3)
    assert child == CHILD_SEED_R7_C3
    w = [int(v) for v in ref.philox4x32_stream(child, 1, 2)]
    assert w == CHILD_STREAM_WORDS
    u64 = (w[0] << 32) | w[1]
    f64 = (u64 >> 11) * 2.0**-53
    assert struct.unpack("<Q", struct.pack("<d", f64))[0] == CHILD_STREAM_F64_BITS
