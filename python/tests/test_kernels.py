"""Pallas kernels vs pure-jnp oracles — bitwise, plus hypothesis sweeps.

The kernels are a second, independent implementation of each generator
(explicit unrolled arithmetic inside a pallas_call); equality here is the
L1 correctness signal required before anything is lowered to artifacts.
"""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:
    # Offline container without hypothesis: the @given sweeps become
    # skips; the fixed-case tests below still run.
    class _StrategyStub:
        def __getattr__(self, _name):
            return lambda *a, **kw: None

    st = _StrategyStub()

    def settings(**_kw):
        return lambda fn: fn

    def given(**_kw):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

from compile.kernels import common as cm
from compile.kernels import normal as knormal
from compile.kernels import philox as kphilox
from compile.kernels import ref
from compile.kernels import squares as ksquares
from compile.kernels import threefry as kthreefry
from compile.kernels import tyche as ktyche

U32 = jnp.uint32
BLOCK = kphilox.BLOCK


def params4(seed, ctr):
    lo, hi = cm.split_seed(seed)
    return jnp.asarray([int(lo), int(hi), ctr & 0xFFFFFFFF, 0], U32)


def params2(seed, ctr):
    lo, hi = cm.split_seed(seed)
    k = (int(lo) ^ (int(hi) * 0x9E3779B9)) & 0xFFFFFFFF
    return jnp.asarray([k, ctr & 0xFFFFFFFF, 0, 0], U32)


def params_squares(seed, ctr):
    key = cm.squares_key(seed)
    return jnp.asarray([key & 0xFFFFFFFF, key >> 32, ctr & 0xFFFFFFFF, 0], U32)


CASES = [
    ("philox", kphilox.philox4x32_block, params4, ref.philox4x32_stream, 4 * BLOCK),
    ("philox2x32", kphilox.philox2x32_block, params2, ref.philox2x32_stream, 2 * BLOCK),
    ("threefry", kthreefry.threefry4x32_block, params4, ref.threefry4x32_stream, 4 * BLOCK),
    ("threefry2x32", kthreefry.threefry2x32_block, params2_tf := params4, ref.threefry2x32_stream, 2 * BLOCK),
    ("squares", ksquares.squares_block, params_squares, ref.squares_stream, BLOCK),
]


@pytest.mark.parametrize("name,kern,mkparams,oracle,quantum", CASES, ids=[c[0] for c in CASES])
@pytest.mark.parametrize("seed,ctr", [(0, 0), (42, 0), (42, 7), (0xDEADBEEF12345678, 3)])
def test_kernel_matches_oracle_bitwise(name, kern, mkparams, oracle, quantum, seed, ctr):
    n = 2 * quantum  # two grid tiles -> exercises the BlockSpec index map
    got = np.asarray(kern(mkparams(seed, ctr), n))
    want = np.asarray(oracle(seed, ctr, n))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("seed,ctr", [(0, 0), (123456789, 5)])
def test_tyche_kernel_matches_oracle(seed, ctr):
    n = 2 * BLOCK  # words=1: lane i == first word of stream (seed, ctr ^ i)
    got = np.asarray(ktyche.tyche_block(params4(seed, ctr), n, words=1))
    lo, hi = cm.split_seed(seed)
    lanes = jnp.arange(n, dtype=U32) ^ jnp.asarray(ctr & 0xFFFFFFFF, U32)
    want = np.asarray(ref.tyche_stream(lo, hi, lanes, 1)).reshape(-1)
    np.testing.assert_array_equal(got, want)


def test_tyche_kernel_words_layout():
    """words>1: word-major within a tile (single-tile case)."""
    n, words = BLOCK * 4, 4
    got = np.asarray(ktyche.tyche_block(params4(9, 0), n, words=words))
    lo, hi = cm.split_seed(9)
    lanes = jnp.arange(BLOCK, dtype=U32)
    want = np.asarray(ref.tyche_stream(lo, hi, lanes, words))  # (BLOCK, words)
    np.testing.assert_array_equal(got.reshape(words, BLOCK), want.T)


def test_tyche_inverse_kernel():
    n = BLOCK
    got = np.asarray(ktyche.tyche_block(params4(77, 1), n, words=1, inverse=True))
    lo, hi = cm.split_seed(77)
    lanes = jnp.arange(n, dtype=U32) ^ jnp.asarray(1, U32)
    want = np.asarray(ref.tyche_stream(lo, hi, lanes, 1, inverse=True)).reshape(-1)
    np.testing.assert_array_equal(got, want)


AT_CASES = [
    # (name, offset kernel, prefix kernel, params fn, oracle, words/tile, words/base-unit)
    ("philox", kphilox.philox4x32_block_at, kphilox.philox4x32_block, params4,
     ref.philox4x32_stream, 4 * BLOCK, 4),
    ("threefry", kthreefry.threefry4x32_block_at, kthreefry.threefry4x32_block, params4,
     ref.threefry4x32_stream, 4 * BLOCK, 4),
    ("squares", ksquares.squares_block_at, ksquares.squares_block, params_squares,
     ref.squares_stream, BLOCK, 1),
]


def params_at(mkparams, seed, ctr, base):
    p = np.asarray(mkparams(seed, ctr)).copy()
    p[3] = np.uint32(base)
    return jnp.asarray(p, U32)


@pytest.mark.parametrize("name,kern_at,kern,mkparams,oracle,quantum,wpb",
                         AT_CASES, ids=[c[0] for c in AT_CASES])
@pytest.mark.parametrize("seed,ctr,base", [(7, 1, 3), (42, 0, 1027), (0xDEADBEEF12345678, 3, 9)])
def test_offset_kernel_matches_oracle_slice(name, kern_at, kern, mkparams, oracle,
                                            quantum, wpb, seed, ctr, base):
    """The `_at` kernels serve interior stream spans: starting at base
    blocks (philox/threefry) or base words (squares), the output equals
    the same slice of the serial stream oracle — the offset-fill layout
    contract the Rust scheduler stitches against."""
    n = 2 * quantum  # two grid tiles -> exercises the BlockSpec index map
    got = np.asarray(kern_at(params_at(mkparams, seed, ctr, base), n))
    want = np.asarray(oracle(seed, ctr, base * wpb + n))[base * wpb:]
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("name,kern_at,kern,mkparams,oracle,quantum,wpb",
                         AT_CASES, ids=[c[0] for c in AT_CASES])
def test_offset_kernel_base_zero_is_prefix(name, kern_at, kern, mkparams, oracle, quantum, wpb):
    """base=0 `_at` output is bitwise the prefix kernel's output, so one
    artifact family can serve both prefix and interior fills."""
    n = quantum
    got = np.asarray(kern_at(params_at(mkparams, 9, 2, 0), n))
    want = np.asarray(kern(mkparams(9, 2), n))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("inverse", [False, True], ids=["tyche", "tyche_i"])
@pytest.mark.parametrize("base", [0, 17])
def test_tyche_stream_block_matches_oracle(inverse, base):
    """The stream-ordered tyche graph (sequential scan, NOT the lane-major
    block) reproduces words base..base+n of the single host stream —
    the artifact that lets the device arm stop refusing tyche."""
    n = 256
    got = np.asarray(ktyche.tyche_stream_block(params_at(params4, 7, 1, base), n, inverse=inverse))
    want = np.asarray(ref.tyche_stream_api(7, 1, base + n, inverse=inverse))[base:]
    np.testing.assert_array_equal(got, want)


def test_offset_kernel_base_wraps_mod_period_squares():
    """Squares has a 2^32-word period; the u32 base add must wrap exactly
    like the host engine's counter arithmetic."""
    base = (1 << 32) - 512  # wraps into words 0.. after 512 words
    n = BLOCK
    got = np.asarray(ksquares.squares_block_at(params_at(params_squares, 5, 0, base), n))
    head = np.asarray(ref.squares_stream(5, 0, 1 << 10))
    tail = np.asarray(
        ref.squares32(jnp.arange(base, base + 512, dtype=jnp.uint64) & jnp.uint64(0xFFFFFFFF),
                      jnp.full((512,), np.uint64(cm.squares_key(5)), jnp.uint64)))
    np.testing.assert_array_equal(got[:512], tail)
    np.testing.assert_array_equal(got[512:], head[:n - 512])


def test_philox_rounds_ablation_kernel():
    """The R-rounds variants (ablation A1) also match the oracle."""
    for rounds in (6, 7, 10):
        got = np.asarray(kphilox.philox4x32_block(params4(5, 2), 4 * BLOCK, rounds=rounds))
        want = np.asarray(ref.philox4x32_stream(5, 2, 4 * BLOCK)) if rounds == 10 else None
        if rounds == 10:
            np.testing.assert_array_equal(got, want)
        else:
            lo, hi = cm.split_seed(5)
            j = jnp.arange(BLOCK, dtype=U32)
            ctr = jnp.stack([j, jnp.full_like(j, 2), jnp.zeros_like(j), jnp.zeros_like(j)], -1)
            key = jnp.broadcast_to(jnp.asarray([int(lo), int(hi)], U32), (BLOCK, 2))
            want = np.asarray(ref.philox4x32(ctr, key, rounds=rounds)).reshape(-1)
            np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**64 - 1),
    ctr=st.integers(min_value=0, max_value=2**32 - 1),
)
def test_hypothesis_philox_kernel_vs_oracle(seed, ctr):
    got = np.asarray(kphilox.philox4x32_block(params4(seed, ctr), 4 * BLOCK))
    want = np.asarray(ref.philox4x32_stream(seed, ctr, 4 * BLOCK))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**64 - 1),
    ctr=st.integers(min_value=0, max_value=2**32 - 1),
    gen=st.sampled_from(["threefry", "squares"]),
)
def test_hypothesis_other_kernels_vs_oracle(seed, ctr, gen):
    if gen == "threefry":
        got = np.asarray(kthreefry.threefry4x32_block(params4(seed, ctr), 4 * BLOCK))
        want = np.asarray(ref.threefry4x32_stream(seed, ctr, 4 * BLOCK))
    else:
        got = np.asarray(ksquares.squares_block(params_squares(seed, ctr), BLOCK))
        want = np.asarray(ref.squares_stream(seed, ctr, BLOCK))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**64 - 1))
def test_hypothesis_determinism(seed):
    a = np.asarray(kphilox.philox4x32_block(params4(seed, 0), 4 * BLOCK))
    b = np.asarray(kphilox.philox4x32_block(params4(seed, 0), 4 * BLOCK))
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("seed,ctr", [(0, 0), (7, 1), (42, 0), (0xDEADBEEF12345678, 3)])
def test_normal_kernel_matches_oracle(seed, ctr):
    """The Pallas Box-Muller kernel vs the ref.py oracle — the same
    double-implementation discipline as the u32 kernels. Both sides run
    identical jnp ops in float64, so the comparison is bitwise."""
    n = 2 * BLOCK  # two grid tiles -> exercises the BlockSpec index map
    got = np.asarray(knormal.normal_block(params4(seed, ctr), n))
    want = np.asarray(ref.normal_f64_stream(seed, ctr, n))
    np.testing.assert_array_equal(got, want)


def test_normal_kernel_matches_model_graph():
    """The L1 kernel and the L2 graph (model.normal_f64_block — what the
    normal_f64_* artifacts are lowered from) must agree on the same
    params: same stream discipline on both layers."""
    from compile import model

    n = BLOCK
    p = params4(7, 1)
    got = np.asarray(knormal.normal_block(p, n))
    want = np.asarray(model.normal_f64_block(p, n))
    np.testing.assert_allclose(got, want, rtol=1e-15, atol=0)


def test_normal_kernel_finite_and_standard():
    n = 4 * BLOCK
    z = np.asarray(knormal.normal_block(params4(123, 5), n))
    assert np.isfinite(z).all()
    assert abs(z.mean()) < 6.0 / np.sqrt(n)
    assert abs(z.var() - 1.0) < 6.0 * np.sqrt(2.0 / n)


def test_uniform_conversion_bounds():
    u = np.asarray(cm.u32_to_f32(jnp.asarray([0, 1, 0xFFFFFFFF], U32)))
    assert u[0] == 0.0 and u[2] < 1.0
    d = np.asarray(cm.u32x2_to_f64(jnp.asarray([0xFFFFFFFF], U32), jnp.asarray([0xFFFFFFFF], U32)))
    assert 0.0 <= d[0] < 1.0
