"""Known-answer tests for the pure-jnp oracle cores.

Vectors are from the Random123 distribution's ``kat_vectors`` file (Salmon
et al., SC'11) — zeros, all-ones, and pi-digit counter/key patterns. These
pin the oracle to the published algorithms; everything else in the stack
(Pallas kernels, Rust engines) is then pinned to the oracle.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import common as cm
from compile.kernels import ref

U32 = jnp.uint32
M = 0xFFFFFFFF
PI = [0x243F6A88, 0x85A308D3, 0x13198A2E, 0x03707344, 0xA4093822, 0x299F31D0]


def u32s(*xs):
    return jnp.asarray([x & M for x in xs], U32)


def check(got, want):
    got = [int(v) for v in np.asarray(got).reshape(-1)]
    assert got == [w & M for w in want], (
        " ".join(f"{g:08x}" for g in got) + " != " + " ".join(f"{w:08x}" for w in want)
    )


@pytest.mark.parametrize(
    "ctr,key,want",
    [
        ((0, 0, 0, 0), (0, 0), (0x6627E8D5, 0xE169C58D, 0xBC57AC4C, 0x9B00DBD8)),
        ((M, M, M, M), (M, M), (0x408F276D, 0x41C83B0E, 0xA20BC7C6, 0x6D5451FD)),
        (tuple(PI[:4]), tuple(PI[4:]), (0xD16CFE09, 0x94FDCCEB, 0x5001E420, 0x24126EA1)),
    ],
)
def test_philox4x32_kat(ctr, key, want):
    check(ref.philox4x32(u32s(*ctr), u32s(*key)), want)


@pytest.mark.parametrize(
    "ctr,key,want",
    [
        ((0, 0), 0, (0xFF1DAE59, 0x6CD10DF2)),
        ((M, M), M, (0x2C3F628B, 0xAB4FD7AD)),
        ((PI[0], PI[1]), PI[2], (0xDD7CE038, 0xF62A4C12)),
    ],
)
def test_philox2x32_kat(ctr, key, want):
    check(ref.philox2x32(u32s(*ctr), jnp.asarray(key & M, U32)), want)


@pytest.mark.parametrize(
    "ctr,key,want",
    [
        ((0, 0, 0, 0), (0, 0, 0, 0), (0x9C6CA96A, 0xE17EAE66, 0xFC10ECD4, 0x5256A7D8)),
        ((M, M, M, M), (M, M, M, M), (0x2A881696, 0x57012287, 0xF6C7446E, 0xA16A6732)),
    ],
)
def test_threefry4x32_kat(ctr, key, want):
    check(ref.threefry4x32(u32s(*ctr), u32s(*key)), want)


@pytest.mark.parametrize(
    "ctr,key,want",
    [
        ((0, 0), (0, 0), (0x6B200159, 0x99BA4EFE)),
        ((M, M), (M, M), (0x1CB996FC, 0xBB002BE7)),
    ],
)
def test_threefry2x32_kat(ctr, key, want):
    check(ref.threefry2x32(u32s(*ctr), u32s(*key)), want)


def test_squares_matches_plain_python():
    """Independent check: jnp squares32 vs a plain-python-int transcription."""

    def py_squares32(ctr, key):
        m64 = 0xFFFFFFFFFFFFFFFF
        x = (ctr * key) & m64
        y = x
        z = (y + key) & m64
        for w in (y, z, y):
            x = (x * x + w) & m64
            x = ((x >> 32) | (x << 32)) & m64
        return ((x * x + z) & m64) >> 32

    key = cm.squares_key(0xDEADBEEF12345678)
    ctrs = [0, 1, 2, 0xFFFFFFFF, 0x123456789ABCDEF0]
    got = ref.squares32(
        jnp.asarray([c & 0xFFFFFFFFFFFFFFFF for c in ctrs], jnp.uint64),
        jnp.full((len(ctrs),), np.uint64(key), jnp.uint64),
    )
    want = [py_squares32(c & 0xFFFFFFFFFFFFFFFF, key) for c in ctrs]
    check(got, want)


def test_tyche_matches_plain_python():
    """Independent check: jnp tyche vs a plain-python-int transcription."""

    def rotl(x, n):
        return ((x << n) | (x >> (32 - n))) & M

    def mix(a, b, c, d):
        a = (a + b) & M
        d = rotl(d ^ a, 16)
        c = (c + d) & M
        b = rotl(b ^ c, 12)
        a = (a + b) & M
        d = rotl(d ^ a, 8)
        c = (c + d) & M
        b = rotl(b ^ c, 7)
        return a, b, c, d

    seed, ctr, n = 0x0123456789ABCDEF, 7, 8
    a, b, c, d = seed >> 32, seed & M, 2654435769, 1367130551 ^ ctr
    for _ in range(20):
        a, b, c, d = mix(a, b, c, d)
    want = []
    for _ in range(n):
        a, b, c, d = mix(a, b, c, d)
        want.append(b)
    got = ref.tyche_stream_api(seed, ctr, n)
    check(got, want)


def test_next_u64_word_order_kat():
    """Pin the u64/f64 word composition: two consecutive stream words,
    FIRST WORD HIGH — the contract of Rust's ``Rng::next_u64`` (see the
    doctest in rust/src/core/traits.rs, which asserts these exact
    literals) and of ``common.u32x2_to_f64``. If either side reorders
    the words, the f64 path silently diverges; this KAT makes that a
    test failure instead."""
    words = [int(w) for w in np.asarray(ref.philox4x32_stream(7, 1, 4))]
    assert words[:2] == [0x2EC4F55D, 0x249EF5F4]
    composed = (words[0] << 32) | words[1]
    assert composed == 0x2EC4F55D249EF5F4
    assert composed != ((words[1] << 32) | words[0])  # not low-word-first
    # f64 in [0,1): top 53 bits of the composition.
    want_f64 = (composed >> 11) * 2.0**-53
    assert want_f64 == 0.1826928474807763
    got = cm.u32x2_to_f64(
        jnp.asarray([words[0]], U32), jnp.asarray([words[1]], U32)
    )
    assert float(np.asarray(got)[0]) == want_f64


def test_avalanche_single_bit_seed_flip():
    """CBRNG avalanche: flipping one seed bit flips ~half the output bits."""
    n = 256
    base = np.asarray(ref.philox4x32_stream(42, 0, n)).view(np.uint8)
    for bit in (0, 17, 33, 63):
        other = np.asarray(ref.philox4x32_stream(42 ^ (1 << bit), 0, n)).view(np.uint8)
        flipped = np.unpackbits(base ^ other).mean()
        assert 0.45 < flipped < 0.55, (bit, flipped)


def test_streams_distinct_across_ctr():
    a = np.asarray(ref.philox4x32_stream(1, 0, 64))
    b = np.asarray(ref.philox4x32_stream(1, 1, 64))
    assert (a != b).mean() > 0.9


# ---------------------------------------------------------------------------
# Normal (Box-Muller) KATs — shared verbatim with the Rust side
# (rust/src/dist/normal.rs::tests::box_muller_kat_*). Values computed by
# a plain-python transcription of the normative pipeline: Philox block i
# -> (u1, u2) f64 pair -> sqrt(-2 ln max(u1, 2^-53)) * {cos, sin}(2π u2).
# ---------------------------------------------------------------------------

# Stream (seed=7, ctr=1): the pair the normal_f64_32768 device graph and
# cross_layer.rs::normal_graph_matches_box_muller_shape exercise.
NORMAL_KAT_SEED7_CTR1 = [
    1.7940642507332762,
    -1.3802003915778076,
    0.8571078589741805,
    0.16486889524918932,
]
# Stream (seed=42, ctr=0), cos branch.
NORMAL_KAT_SEED42_CTR0 = [0.8864975059014412, -0.15660962291201797]


def test_normal_stream_kat_seed7_ctr1():
    got = np.asarray(ref.normal_f64_stream(7, 1, 4))
    np.testing.assert_allclose(got, NORMAL_KAT_SEED7_CTR1, rtol=1e-12, atol=0)


def test_normal_stream_kat_seed42_ctr0():
    got = np.asarray(ref.normal_f64_stream(42, 0, 2))
    np.testing.assert_allclose(got, NORMAL_KAT_SEED42_CTR0, rtol=1e-12, atol=0)


def test_box_muller_kat_plain_python():
    """Independent check: the jnp box_muller_pair vs a from-scratch
    python-float transcription driven off the pinned Philox words."""
    import math

    words = [int(w) for w in np.asarray(ref.philox4x32_stream(7, 1, 16))]
    want_cos, want_sin = [], []
    for i in range(4):
        w0, w1, w2, w3 = words[4 * i : 4 * i + 4]
        u1 = (((w0 << 32) | w1) >> 11) * 2.0**-53
        u2 = (((w2 << 32) | w3) >> 11) * 2.0**-53
        u1 = max(u1, 2.0**-53)
        r = math.sqrt(-2.0 * math.log(u1))
        want_cos.append(r * math.cos(2.0 * math.pi * u2))
        want_sin.append(r * math.sin(2.0 * math.pi * u2))
    w = np.asarray(ref.philox4x32_stream(7, 1, 16)).reshape(4, 4)
    u1 = cm.u32x2_to_f64(jnp.asarray(w[:, 0], U32), jnp.asarray(w[:, 1], U32))
    u2 = cm.u32x2_to_f64(jnp.asarray(w[:, 2], U32), jnp.asarray(w[:, 3], U32))
    zc, zs = ref.box_muller_pair(u1, u2)
    np.testing.assert_allclose(np.asarray(zc), want_cos, rtol=1e-12)
    np.testing.assert_allclose(np.asarray(zs), want_sin, rtol=1e-12)


def test_normal_stream_word_discipline():
    """Normal i must consume exactly counter block i: recomputing any
    single block's normal from its 4 words reproduces stream position i."""
    n = 8
    stream = np.asarray(ref.normal_f64_stream(0xDEADBEEF, 3, n))
    words = np.asarray(ref.philox4x32_stream(0xDEADBEEF, 3, 4 * n)).reshape(n, 4)
    for i in (0, 3, 7):
        u1 = cm.u32x2_to_f64(
            jnp.asarray(words[i : i + 1, 0], U32), jnp.asarray(words[i : i + 1, 1], U32)
        )
        u2 = cm.u32x2_to_f64(
            jnp.asarray(words[i : i + 1, 2], U32), jnp.asarray(words[i : i + 1, 3], U32)
        )
        z = np.asarray(ref.box_muller_pair(u1, u2)[0])[0]
        assert z == stream[i], (i, z, stream[i])
