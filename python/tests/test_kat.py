"""Known-answer tests for the pure-jnp oracle cores.

Vectors are from the Random123 distribution's ``kat_vectors`` file (Salmon
et al., SC'11) — zeros, all-ones, and pi-digit counter/key patterns. These
pin the oracle to the published algorithms; everything else in the stack
(Pallas kernels, Rust engines) is then pinned to the oracle.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import common as cm
from compile.kernels import ref

U32 = jnp.uint32
M = 0xFFFFFFFF
PI = [0x243F6A88, 0x85A308D3, 0x13198A2E, 0x03707344, 0xA4093822, 0x299F31D0]


def u32s(*xs):
    return jnp.asarray([x & M for x in xs], U32)


def check(got, want):
    got = [int(v) for v in np.asarray(got).reshape(-1)]
    assert got == [w & M for w in want], (
        " ".join(f"{g:08x}" for g in got) + " != " + " ".join(f"{w:08x}" for w in want)
    )


@pytest.mark.parametrize(
    "ctr,key,want",
    [
        ((0, 0, 0, 0), (0, 0), (0x6627E8D5, 0xE169C58D, 0xBC57AC4C, 0x9B00DBD8)),
        ((M, M, M, M), (M, M), (0x408F276D, 0x41C83B0E, 0xA20BC7C6, 0x6D5451FD)),
        (tuple(PI[:4]), tuple(PI[4:]), (0xD16CFE09, 0x94FDCCEB, 0x5001E420, 0x24126EA1)),
    ],
)
def test_philox4x32_kat(ctr, key, want):
    check(ref.philox4x32(u32s(*ctr), u32s(*key)), want)


@pytest.mark.parametrize(
    "ctr,key,want",
    [
        ((0, 0), 0, (0xFF1DAE59, 0x6CD10DF2)),
        ((M, M), M, (0x2C3F628B, 0xAB4FD7AD)),
        ((PI[0], PI[1]), PI[2], (0xDD7CE038, 0xF62A4C12)),
    ],
)
def test_philox2x32_kat(ctr, key, want):
    check(ref.philox2x32(u32s(*ctr), jnp.asarray(key & M, U32)), want)


@pytest.mark.parametrize(
    "ctr,key,want",
    [
        ((0, 0, 0, 0), (0, 0, 0, 0), (0x9C6CA96A, 0xE17EAE66, 0xFC10ECD4, 0x5256A7D8)),
        ((M, M, M, M), (M, M, M, M), (0x2A881696, 0x57012287, 0xF6C7446E, 0xA16A6732)),
    ],
)
def test_threefry4x32_kat(ctr, key, want):
    check(ref.threefry4x32(u32s(*ctr), u32s(*key)), want)


@pytest.mark.parametrize(
    "ctr,key,want",
    [
        ((0, 0), (0, 0), (0x6B200159, 0x99BA4EFE)),
        ((M, M), (M, M), (0x1CB996FC, 0xBB002BE7)),
    ],
)
def test_threefry2x32_kat(ctr, key, want):
    check(ref.threefry2x32(u32s(*ctr), u32s(*key)), want)


def test_squares_matches_plain_python():
    """Independent check: jnp squares32 vs a plain-python-int transcription."""

    def py_squares32(ctr, key):
        m64 = 0xFFFFFFFFFFFFFFFF
        x = (ctr * key) & m64
        y = x
        z = (y + key) & m64
        for w in (y, z, y):
            x = (x * x + w) & m64
            x = ((x >> 32) | (x << 32)) & m64
        return ((x * x + z) & m64) >> 32

    key = cm.squares_key(0xDEADBEEF12345678)
    ctrs = [0, 1, 2, 0xFFFFFFFF, 0x123456789ABCDEF0]
    got = ref.squares32(
        jnp.asarray([c & 0xFFFFFFFFFFFFFFFF for c in ctrs], jnp.uint64),
        jnp.full((len(ctrs),), np.uint64(key), jnp.uint64),
    )
    want = [py_squares32(c & 0xFFFFFFFFFFFFFFFF, key) for c in ctrs]
    check(got, want)


def test_tyche_matches_plain_python():
    """Independent check: jnp tyche vs a plain-python-int transcription."""

    def rotl(x, n):
        return ((x << n) | (x >> (32 - n))) & M

    def mix(a, b, c, d):
        a = (a + b) & M
        d = rotl(d ^ a, 16)
        c = (c + d) & M
        b = rotl(b ^ c, 12)
        a = (a + b) & M
        d = rotl(d ^ a, 8)
        c = (c + d) & M
        b = rotl(b ^ c, 7)
        return a, b, c, d

    seed, ctr, n = 0x0123456789ABCDEF, 7, 8
    a, b, c, d = seed >> 32, seed & M, 2654435769, 1367130551 ^ ctr
    for _ in range(20):
        a, b, c, d = mix(a, b, c, d)
    want = []
    for _ in range(n):
        a, b, c, d = mix(a, b, c, d)
        want.append(b)
    got = ref.tyche_stream_api(seed, ctr, n)
    check(got, want)


def test_avalanche_single_bit_seed_flip():
    """CBRNG avalanche: flipping one seed bit flips ~half the output bits."""
    n = 256
    base = np.asarray(ref.philox4x32_stream(42, 0, n)).view(np.uint8)
    for bit in (0, 17, 33, 63):
        other = np.asarray(ref.philox4x32_stream(42 ^ (1 << bit), 0, n)).view(np.uint8)
        flipped = np.unpackbits(base ^ other).mean()
        assert 0.45 < flipped < 0.55, (bit, flipped)


def test_streams_distinct_across_ctr():
    a = np.asarray(ref.philox4x32_stream(1, 0, 64))
    b = np.asarray(ref.philox4x32_stream(1, 1, 64))
    assert (a != b).mean() > 0.9
